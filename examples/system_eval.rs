//! End-to-end system evaluation — the repo's E2E driver (DESIGN.md §7).
//!
//! Reproduces the paper's full evaluation pipeline on a real (simulated)
//! workload suite: all 35 workloads, single- and multi-core, baseline DDR3
//! vs AL-DRAM timings (Fig 4), then the §8.4 sensitivity and power
//! analyses and the §6 stress analogue. Headline metric: the multi-core
//! speedup split by memory intensity.
//!
//! Run: `cargo run --release --example system_eval -- \
//!           [cycles] [reps] [--jobs N]`

use std::path::PathBuf;

use aldram::cli::Args;
use aldram::eval::{power_eval, power_saving, sensitivity_jobs, stress,
                   PAPER_REDUCTIONS_55C};
use aldram::figures::fig4;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cycles: u64 = args.sub(0).and_then(|s| s.parse().ok())
        .unwrap_or(300_000);
    let reps: usize = args.sub(1).and_then(|s| s.parse().ok()).unwrap_or(3);
    let jobs = args.jobs();
    let out = PathBuf::from(args.str("out", "results"));

    // Fig 4: the headline result, fanned out over the job pool.
    let r = fig4::fig4(cycles, reps, jobs, &out)?;

    // §8.4 sensitivity.
    println!("\n== §8.4: sensitivity (memory-intensive gmean) ==");
    for row in sensitivity_jobs(cycles / 2, PAPER_REDUCTIONS_55C, jobs) {
        println!("{:<18} {:>6.1}%", row.label,
                 100.0 * (row.gmean_speedup - 1.0));
    }

    // §8.4 power.
    let rows = power_eval(cycles / 2, PAPER_REDUCTIONS_55C);
    println!("\n== §8.4: DRAM power ==");
    println!("average energy-per-work reduction: {:.1}%  (paper 5.8%)",
             100.0 * power_saving(&rows));

    // §6 stress analogue.
    let s = stress(0, 16, 50_000)?;
    println!("\n== §6 stress analogue: {} epochs, {} errors, min margin {:.4} ==",
             s.epochs, s.errors, s.min_margin);
    anyhow::ensure!(s.errors == 0);

    println!(
        "\nHEADLINE: multi-core speedup — memory-intensive {:+.1}% \
         (paper 14.0%), non-intensive {:+.1}% (paper 2.9%), \
         all-35 {:+.1}% (paper 10.5%)",
        100.0 * (r.gmean_intensive_multi - 1.0),
        100.0 * (r.gmean_nonintensive_multi - 1.0),
        100.0 * (r.mean_all_multi - 1.0)
    );
    Ok(())
}
