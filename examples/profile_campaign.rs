//! The characterization campaign: Fig 2 (representative module) and
//! Fig 3 (population study) in one run, writing CSVs under `results/`.
//!
//! Run: `cargo run --release --example profile_campaign -- [n_dimms] [cells]`
//! Defaults profile a 30-module slice at half resolution; the full paper
//! campaign (115 modules x 131k sampled cells) is
//! `cargo run --release --example profile_campaign -- 115 2048`.

use std::path::PathBuf;

use aldram::figures::{fig2, fig3};
use aldram::model::params;
use aldram::population::generate_dimm;
use aldram::runtime::{artifacts_dir, auto_backend};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_dimms: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(30);
    let cells: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(1024);
    let out = PathBuf::from("results");

    let mut backend = auto_backend(&artifacts_dir(), cells);
    println!("backend: {} | {} modules at {} cells/(bank,chip)\n",
             backend.name(), n_dimms, cells);

    // Fig 2: the representative module.
    let rep = generate_dimm(fig2::REPRESENTATIVE_DIMM, cells, params());
    let refresh = fig2::fig2a(backend.as_mut(), &rep.arrays, &out)?;
    fig2::fig2bc(backend.as_mut(), &rep.arrays, &refresh, &out)?;
    println!();

    // Fig 3: the population.
    fig3::fig3(backend.as_mut(), n_dimms, cells, &out)?;
    Ok(())
}
