//! The characterization campaign: Fig 2 (representative module) and
//! Fig 3 (population study) in one run, writing CSVs under `results/`.
//!
//! Run: `cargo run --release --example profile_campaign -- \
//!           [n_dimms] [cells] [--jobs N]`
//! Defaults profile a 30-module slice at half resolution; the full paper
//! campaign (115 modules x 131k sampled cells) is
//! `cargo run --release --example profile_campaign -- 115 2048`.
//!
//! The population study is one independent profile per DIMM, so it fans
//! out over the `exec::Pool` job pool — each worker owns its backend
//! (PJRT artifact if built and available, native mirror otherwise).

use std::path::PathBuf;

use aldram::cli::Args;
use aldram::figures::{fig2, fig3};
use aldram::model::params;
use aldram::population::generate_dimm;
use aldram::runtime::{artifacts_dir, auto_backend};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n_dimms: usize = args.sub(0).and_then(|s| s.parse().ok()).unwrap_or(30);
    let cells: usize = args.sub(1).and_then(|s| s.parse().ok()).unwrap_or(1024);
    let jobs = args.jobs();
    let out = PathBuf::from(args.str("out", "results"));

    let mut backend = auto_backend(&artifacts_dir(), cells);
    println!("backend: {} | {} modules at {} cells/(bank,chip) | {} jobs\n",
             backend.name(), n_dimms, cells, jobs);

    // Fig 2: the representative module (one DIMM — stays on one backend).
    let rep = generate_dimm(fig2::REPRESENTATIVE_DIMM, cells, params());
    let refresh = fig2::fig2a(backend.as_mut(), &rep.arrays, &out)?;
    fig2::fig2bc(backend.as_mut(), &rep.arrays, &refresh, &out)?;
    println!();

    // Fig 3: the population, one pool job per DIMM with a worker-owned
    // backend (profile() takes &mut self).
    fig3::fig3_par(|| auto_backend(&artifacts_dir(), cells), n_dimms, cells,
                   jobs, &out)?;
    Ok(())
}
