//! Quickstart: the whole AL-DRAM flow on one DIMM in ~a minute.
//!
//!   1. generate a synthetic DIMM,
//!   2. profile it (refresh sweep + timing sweeps) through the profiling
//!      backend (PJRT artifact if built, native mirror otherwise),
//!   3. build the temperature-indexed AL-DRAM timing table,
//!   4. run a memory-intensive workload on the cycle-level simulator with
//!      standard vs AL-DRAM timings and print the speedup.
//!
//! Run: `cargo run --release --example quickstart`

use aldram::aldram::AlDram;
use aldram::mem::{System, SystemConfig};
use aldram::model::params;
use aldram::population::generate_dimm;
use aldram::profiler::profile_dimm;
use aldram::runtime::{artifacts_dir, auto_backend};
use aldram::timing::TimingParams;
use aldram::workloads::by_name;

fn main() -> anyhow::Result<()> {
    // 1. a DIMM (deterministic: dimm 3 is the Fig-2 representative module)
    let cells = 512; // quickstart resolution; figures use 2048
    let dimm = generate_dimm(3, cells, params());
    println!("DIMM {:03} from {}", dimm.id, dimm.vendor);

    // 2. profile
    let mut backend = auto_backend(&artifacts_dir(), cells);
    println!("profiling backend: {}", backend.name());
    let profile = profile_dimm(backend.as_mut(), &dimm)?;
    println!(
        "max error-free refresh @85C: read {:.0} ms / write {:.0} ms",
        profile.refresh85.module_max_read_ms,
        profile.refresh85.module_max_write_ms
    );
    for tp in [&profile.at85, &profile.at55] {
        let r = tp.param_reductions();
        println!(
            "@{:>2.0}C acceptable reductions: tRCD {:.1}% tRAS {:.1}% tWR {:.1}% tRP {:.1}%",
            tp.temp_c, 100.0 * r[0], 100.0 * r[1], 100.0 * r[2], 100.0 * r[3]
        );
    }

    // 3. the mechanism: a temperature-indexed timing table
    let table = AlDram::from_profile(&profile, 10.0);
    println!("AL-DRAM table ({} bins):", table.entries().len());
    for e in table.entries() {
        let t = &e.timings;
        println!(
            "  <= {:>5.1}C: tRCD {:5.2} tRAS {:5.2} tWR {:5.2} tRP {:5.2} ns",
            e.max_c, t.trcd_ns, t.tras_ns, t.twr_ns, t.trp_ns
        );
    }

    // 4. base vs AL-DRAM on the simulator
    let w = by_name("mcf").expect("workload");
    let cycles = 200_000;
    let mut run = |timings: TimingParams| {
        let cfg = SystemConfig::paper_default().with_timings(timings);
        let wl: Vec<_> = (0..4).map(|i| (w.clone(), format!("qs/{i}"))).collect();
        let mut sys = System::new(&cfg, &wl);
        let s = sys.run_fast(cycles);
        s.cores.iter().map(|c| c.ipc).sum::<f64>()
    };
    let base = run(TimingParams::ddr3_standard());
    let fast = run(table.timings_for(55.0));
    println!(
        "4-core {} throughput: {:.3} -> {:.3} ipc  ({:+.1}% with AL-DRAM @55C)",
        w.name, base, fast, 100.0 * (fast / base - 1.0)
    );
    Ok(())
}
