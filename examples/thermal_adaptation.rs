//! Dynamic adaptation demo: AL-DRAM tracking a changing thermal
//! environment (the mechanism of §4, exercised end to end).
//!
//! Sweeps the ambient temperature of the server, lets the thermal model
//! settle under load, and shows (a) which timing-table bin the mechanism
//! selects, (b) the delivered throughput, and (c) that the installed
//! timings remain verified error-free at every operating point.
//!
//! Run: `cargo run --release --example thermal_adaptation`

use aldram::aldram::AlDram;
use aldram::mem::{System, SystemConfig};
use aldram::model::params;
use aldram::population::generate_dimm;
use aldram::profiler::{profile_dimm, verify_timings};
use aldram::runtime::NativeBackend;
use aldram::workloads::by_name;

fn main() -> anyhow::Result<()> {
    let cells = 256;
    let dimm = generate_dimm(7, cells, params());
    let mut backend = NativeBackend::new();
    let profile = profile_dimm(&mut backend, &dimm)?;
    let table = AlDram::from_profile(&profile, 5.0);
    println!("profiled dimm {:03} ({}); table has {} bins",
             dimm.id, dimm.vendor, table.entries().len());

    let w = by_name("stream.add").expect("workload");
    println!("\n{:>9} {:>9} {:>8} {:>8} {:>8} {:>10}",
             "ambient C", "settled C", "tRCD", "tRAS", "tRP", "throughput");
    for ambient in [25.0, 35.0, 45.0, 55.0, 65.0, 80.0] {
        let cfg = SystemConfig::paper_default()
            .with_aldram(Some(table.clone()))
            .with_ambient(ambient);
        let wl: Vec<_> = (0..4).map(|i| (w.clone(), format!("ta/{i}"))).collect();
        let mut sys = System::new(&cfg, &wl);
        let s = sys.run_fast(150_000);
        let t = table.timings_for(s.mean_temp_c);
        let ipc: f64 = s.cores.iter().map(|c| c.ipc).sum();
        println!("{ambient:>9.1} {:>9.1} {:>8.2} {:>8.2} {:>8.2} {ipc:>10.3}",
                 s.mean_temp_c, t.trcd_ns, t.tras_ns, t.trp_ns);

        // Safety: the installed timings verify error-free at the settled
        // temperature (clamped to the profiled range).
        let ok = verify_timings(&mut backend, &dimm, &t,
                                s.mean_temp_c.max(55.0),
                                profile.at55.tref_read_ms,
                                profile.at55.tref_write_ms)?;
        anyhow::ensure!(ok, "unsafe timings selected at ambient {ambient}");
    }
    println!("\nall operating points verified error-free");
    Ok(())
}
