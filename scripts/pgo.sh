#!/usr/bin/env bash
# Profile-guided optimization build for the `repro` binary.
#
# Three stages (DESIGN.md §14, "PGO recipe"):
#   1. instrumented build (-Cprofile-generate)
#   2. training run: `bench-sim` + `bench-profile` — the two suites that
#      cover the simulator hot path (controller slab queues, time-skip
#      scans, lockstep grids) and the profiler kernels
#   3. merge profiles with llvm-profdata, rebuild with -Cprofile-use
#
# Usage:
#   scripts/pgo.sh                # full pipeline, optimized binary in
#                                 # target/release/repro
#   scripts/pgo.sh --train-only   # stages 1–2 only (the CI smoke: proves
#                                 # the instrumented binary runs and
#                                 # emits .profraw without needing
#                                 # llvm-profdata on the runner)
#
# Env:
#   PGO_DIR     profile data directory (default: target/pgo-profiles)
#   PGO_CYCLES  training-run simulated cycles (default: 40000)
#   PGO_CELLS   training-run profiler cells  (default: 192)

set -euo pipefail
cd "$(dirname "$0")/.."

PGO_DIR="${PGO_DIR:-$PWD/target/pgo-profiles}"
PGO_CYCLES="${PGO_CYCLES:-40000}"
PGO_CELLS="${PGO_CELLS:-192}"
TRAIN_ONLY=0
[ "${1:-}" = "--train-only" ] && TRAIN_ONLY=1

rm -rf "$PGO_DIR"
mkdir -p "$PGO_DIR"

echo "== PGO stage 1: instrumented build =="
RUSTFLAGS="-Cprofile-generate=$PGO_DIR" cargo build --release

echo "== PGO stage 2: training run (bench-sim + bench-profile) =="
BIN=target/release/repro
BENCH_FAST=1 "$BIN" bench-sim --cycles "$PGO_CYCLES"
BENCH_FAST=1 "$BIN" bench-profile --cells "$PGO_CELLS"

ls "$PGO_DIR"/*.profraw >/dev/null 2>&1 || {
    echo "PGO training produced no .profraw files" >&2
    exit 1
}
echo "training profiles: $(ls "$PGO_DIR"/*.profraw | wc -l) file(s)"

if [ "$TRAIN_ONLY" = 1 ]; then
    echo "== PGO --train-only: stopping before merge/rebuild =="
    exit 0
fi

echo "== PGO stage 3: merge + optimized rebuild =="
# llvm-profdata must match the rustc LLVM major; prefer the one shipped
# with the toolchain when present.
PROFDATA=$(find "$(rustc --print sysroot)" -name llvm-profdata 2>/dev/null \
           | head -n1)
PROFDATA="${PROFDATA:-llvm-profdata}"
"$PROFDATA" merge -o "$PGO_DIR/merged.profdata" "$PGO_DIR"/*.profraw

RUSTFLAGS="-Cprofile-use=$PGO_DIR/merged.profdata" cargo build --release
echo "== PGO done: optimized binary rebuilt with merged profile =="
