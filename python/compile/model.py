"""Layer-2 JAX model: the profiling step graph around the Pallas kernel.

The L2 graph is deliberately thin for this paper — AL-DRAM's contribution
is a characterization + a memory-controller mechanism (Layer 3), and the
compute hot-spot is the per-cell test-chain evaluation (Layer 1). L2
composes the kernel with the surrounding reductions that the rust
coordinator wants per batch:

  profile_step  : full per-(bank, chip) reductions + per-combo totals
  margin_step   : per-cell margins for one combo (repeatability analysis)
  ode_step      : Euler-integrated sense margins (analytic-model ablation)

Everything here is lowered once by ``aot.py`` to HLO text and executed from
rust via PJRT; python never runs on the profiling path at runtime.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import bitline_ode, cell_charge
from .kernels import ref as kref
from .params import PARAMS


def profile_step(qcap, tau_s, tau_r, tau_p, lam85, combos):
    """cell params [B,C,N], combos [K,6] ->
    (err_r, err_w, mmin_r, mmin_w) [K,B,C] + (tot_r, tot_w) [K].

    The per-combo totals are computed here (fused into the same HLO) so the
    rust sweep loop can binary-search on a single scalar per combo without
    re-reducing on the host.
    """
    err_r, err_w, mmin_r, mmin_w = cell_charge.profile_kernel(
        qcap, tau_s, tau_r, tau_p, lam85, combos, PARAMS)
    tot_r = jnp.sum(err_r, axis=(1, 2))
    tot_w = jnp.sum(err_w, axis=(1, 2))
    return err_r, err_w, mmin_r, mmin_w, tot_r, tot_w


def margin_step(qcap, tau_s, tau_r, tau_p, lam85, combo):
    """Per-cell read/write margins for a single combo [6] (no reduction)."""
    return kref.margins_ref(qcap, tau_s, tau_r, tau_p, lam85, combo, PARAMS)


def ode_step(q0, tau_s, tau_p, scalars):
    """Euler-integrated sense margins (see kernels/bitline_ode.py)."""
    return (bitline_ode.sense_margin_ode(q0, tau_s, tau_p, scalars, PARAMS),)
