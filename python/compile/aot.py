"""AOT compiler: lower the L2 graphs to HLO *text* artifacts.

HLO text (not ``lowered.compile().serialize()`` and not the serialized
``HloModuleProto``) is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Usage (from python/):  python -m compile.aot --out ../artifacts

Artifacts:
  profile_full.hlo.txt   profiling step, [8,8,2048] cells, K=64 combos
  profile_small.hlo.txt  same graph at [8,8,256] for tests/CI
  margin_full.hlo.txt    per-cell margins for one combo (repeatability)
  ode_check.hlo.txt      Euler-integrated sense margins (ablation)
  manifest.json          shapes + combo batch size for the rust loader
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .params import PARAMS


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_profile(n_cells: int):
    g = PARAMS.geometry
    b, c, k = g["banks"], g["chips"], g["combo_batch"]
    cell = _spec((b, c, n_cells))
    return jax.jit(model.profile_step).lower(
        cell, cell, cell, cell, cell, _spec((k, 6)))


def lower_margin(n_cells: int):
    g = PARAMS.geometry
    b, c = g["banks"], g["chips"]
    cell = _spec((b, c, n_cells))
    return jax.jit(model.margin_step).lower(
        cell, cell, cell, cell, cell, _spec((6,)))


def lower_ode(n_cells: int):
    cell = _spec((n_cells,))
    return jax.jit(model.ode_step).lower(cell, cell, cell, _spec((8,)))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    g = PARAMS.geometry
    n_full = g["cells_per_chip_bank"]
    n_small = g["cells_per_chip_bank_small"]
    ode_n = 16384

    jobs = {
        "profile_full": (lower_profile(n_full),
                         {"cells": n_full, "kind": "profile"}),
        "profile_small": (lower_profile(n_small),
                          {"cells": n_small, "kind": "profile"}),
        "margin_full": (lower_margin(n_full),
                        {"cells": n_full, "kind": "margin"}),
        "ode_check": (lower_ode(ode_n), {"cells": ode_n, "kind": "ode"}),
    }

    manifest = {
        "banks": g["banks"],
        "chips": g["chips"],
        "combo_batch": g["combo_batch"],
        "artifacts": {},
    }
    for name, (lowered, meta) in jobs.items():
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {**meta, "file": f"{name}.hlo.txt",
                                       "hlo_bytes": len(text)}
        print(f"wrote {path} ({len(text)} bytes)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
