"""Layer-1 Pallas kernel: explicit-Euler bitline/sense-amp integration.

High-fidelity cross-check for the closed-form sensing equation in
``charge_math.sense_margin``. Instead of the analytic
``amp * (1 - exp(-t/tau))`` development, this kernel integrates the
first-order sense dynamics

    dv/dt = (amp - v) / tau_s(T)

with a fixed number of Euler steps over the [t_soff, tRCD] window (static
step count, dynamic dt, so one compiled artifact serves every tRCD). The
``repro ablate ode`` command and ``python/tests/test_ode.py`` compare the
integrated margin against the analytic margin; agreement validates that the
closed form used by the fast profiling path is not hiding integration
error.

Cells are tiled in VMEM blocks of ``BLOCK`` along a flat cell axis; the
timing scalars arrive as a [8]-vector (trcd, trp, tref_ms, temp_c, pad...).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..params import PARAMS, ModelParams
from . import charge_math as cm

BLOCK = 1024
N_STEPS = 128


def _kernel(q0_ref, tau_s_ref, tau_p_ref, scal_ref, margin_ref,
            *, p: ModelParams):
    q0 = q0_ref[...]
    tau_s = tau_s_ref[...]
    tau_p = tau_p_ref[...]
    trcd = scal_ref[0]
    trp = scal_ref[1]
    temp = scal_ref[3]

    amp = p.a_max * jnp.minimum((q0 / p.q_knee) ** p.knee_pow, 1.0)
    tau_t = tau_s * (1.0 + p.alpha_t_per_c * jnp.maximum(temp - 55.0, 0.0))
    window = jnp.maximum(trcd - p.t_soff_ns, 0.0)
    dt = window / N_STEPS

    def step(_, v):
        return v + dt * (amp - v) / tau_t

    v = jax.lax.fori_loop(0, N_STEPS, step, jnp.zeros_like(q0))
    off = cm.precharge_offset(tau_p, trp, p)
    margin_ref[...] = v - p.g_off * off - p.v_read


def sense_margin_ode(q0, tau_s, tau_p, scalars, p: ModelParams = PARAMS):
    """q0/tau_s/tau_p [N] f32, scalars [8] f32 -> margin [N] f32."""
    (n,) = q0.shape
    assert n % BLOCK == 0, f"cell count {n} must be a multiple of {BLOCK}"
    grid = (n // BLOCK,)

    cell_spec = pl.BlockSpec((BLOCK,), lambda i: (i,))
    scal_spec = pl.BlockSpec((8,), lambda i: (0,))

    kern = functools.partial(_kernel, p=p)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[cell_spec, cell_spec, cell_spec, scal_spec],
        out_specs=cell_spec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(q0, tau_s, tau_p, scalars)


def sense_margin_analytic(q0, tau_s, tau_p, scalars, p: ModelParams = PARAMS):
    """Closed-form twin of ``sense_margin_ode`` for the comparison."""
    trcd, trp, _tref, temp = scalars[0], scalars[1], scalars[2], scalars[3]
    off = cm.precharge_offset(tau_p, trp, p)
    return cm.sense_margin(q0, tau_s, trcd, off, temp, p)
