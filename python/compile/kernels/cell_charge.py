"""Layer-1 Pallas kernel: batched DRAM-cell test-chain evaluation.

This is the profiling hot-spot. One invocation evaluates a batch of K
timing combinations against a full DIMM's sampled cell population and
reduces to per-(bank, chip) error counts and minimum margins.

Tiling (see DESIGN.md §Hardware-Adaptation): the grid iterates over the
(bank, chip) plane; each grid step holds one chip-bank's cell-parameter
vectors (5 x N f32) resident in VMEM and loops over all K combos against
them. This is the same reuse structure the FPGA testbed gets by re-running
test sequences against the same physical cells: the expensive operand (the
cell arrays) is loaded once per (bank, chip) and amortized over the whole
combo batch. The combo table ([K, 6]) is tiny and replicated to every step.

The kernel is elementwise-transcendental (VPU work, no MXU); on a real TPU
the roofline is HBM-bandwidth on the cell-parameter streams. VMEM footprint
per step: 5 * N * 4 B (N = 2048 -> 40 KiB) + outputs 4 * K * 4 B — far
under VMEM, leaving room for double-buffering the next chip-bank's params.

Must be lowered with ``interpret=True``: CPU PJRT cannot execute Mosaic
custom-calls (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..params import PARAMS, ModelParams
from . import charge_math as cm
from .ref import SENTINEL_MARGIN


def _kernel(qcap_ref, tau_s_ref, tau_r_ref, tau_p_ref, lam_ref, combos_ref,
            err_r_ref, err_w_ref, mmin_r_ref, mmin_w_ref,
            *, n_combos: int, p: ModelParams):
    """Kernel body for one (bank, chip) grid step.

    Inputs are [1, 1, N] cell-parameter blocks plus the full [K, 6] combo
    table; outputs are [K, 1, 1] per-combo reductions for this chip-bank.
    """
    qcap = qcap_ref[0, :, :]
    tau_s = tau_s_ref[0, :, :]
    tau_r = tau_r_ref[0, :, :]
    tau_p = tau_p_ref[0, :, :]
    lam85 = lam_ref[0, :, :]

    def body(k, _):
        trcd = combos_ref[k, 0]
        tras = combos_ref[k, 1]
        twr = combos_ref[k, 2]
        trp = combos_ref[k, 3]
        tref = combos_ref[k, 4]
        temp = combos_ref[k, 5]

        m_r, m_w = cm.test_margins(qcap, tau_s, tau_r, tau_p, lam85,
                                   trcd, tras, twr, trp, tref, temp, p)
        valid = temp >= 0.0
        m_r = jnp.where(valid, m_r, SENTINEL_MARGIN)
        m_w = jnp.where(valid, m_w, SENTINEL_MARGIN)

        # reduce over the cell axis only: per-(combo, chip) outputs.
        err_r_ref[k, 0, :] = jnp.sum((m_r < 0.0).astype(jnp.float32), axis=-1)
        err_w_ref[k, 0, :] = jnp.sum((m_w < 0.0).astype(jnp.float32), axis=-1)
        mmin_r_ref[k, 0, :] = jnp.min(m_r, axis=-1)
        mmin_w_ref[k, 0, :] = jnp.min(m_w, axis=-1)
        return 0

    jax.lax.fori_loop(0, n_combos, body, 0)


def profile_kernel(qcap, tau_s, tau_r, tau_p, lam85, combos,
                   p: ModelParams = PARAMS):
    """Pallas entry point; same contract as ``ref.profile_ref``.

    cell params [B, C, N] f32, combos [K, 6] f32 ->
    (err_r, err_w, mmin_r, mmin_w) each [K, B, C] f32.
    """
    b, c, n = qcap.shape
    k = combos.shape[0]

    # Perf (EXPERIMENTS.md §Perf, L1): grid over banks only, with the full
    # (chips x cells) plane of one bank resident per step. Fewer grid
    # steps (8 vs 64) at 8x wider vector work amortizes the per-step loop
    # overhead of the interpret-lowered HLO while keeping the VMEM block
    # at 5 params x C x N x 4 B (= 320 KiB at full resolution) — still
    # comfortably double-bufferable on a real TPU.
    cell_spec = pl.BlockSpec((1, c, n), lambda i: (i, 0, 0))
    combo_spec = pl.BlockSpec((k, 6), lambda i: (0, 0))
    out_spec = pl.BlockSpec((k, 1, c), lambda i: (0, i, 0))
    out_shape = jax.ShapeDtypeStruct((k, b, c), jnp.float32)

    kern = functools.partial(_kernel, n_combos=k, p=p)
    return pl.pallas_call(
        kern,
        grid=(b,),
        in_specs=[cell_spec] * 5 + [combo_spec],
        out_specs=[out_spec] * 4,
        out_shape=[out_shape] * 4,
        interpret=True,
    )(qcap, tau_s, tau_r, tau_p, lam85, combos)
