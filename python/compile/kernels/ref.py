"""Pure-jnp oracle for the profiling kernel (no Pallas).

This is the correctness reference: ``python/tests`` asserts the Pallas
kernel (interpret mode) matches this implementation to float tolerance, and
the rust native model is cross-checked against the AOT artifact that wraps
the Pallas kernel. Shapes:

  cell params : [B, C, N]  (banks x chips x cells-per-chip-per-bank)
  combos      : [K, 6]     (trcd, tras, twr, trp, tref_ms, temp_c)

A combo with ``temp_c < 0`` is a padding sentinel: it contributes zero
errors and +inf margins.
"""

from __future__ import annotations

import jax.numpy as jnp

from ..params import PARAMS, ModelParams
from . import charge_math as cm

SENTINEL_MARGIN = 1.0e9


def profile_ref(qcap, tau_s, tau_r, tau_p, lam85, combos,
                p: ModelParams = PARAMS):
    """Evaluate every combo against every cell; reduce to per-(bank, chip).

    Returns ``(err_r, err_w, mmin_r, mmin_w)`` each of shape [K, B, C]:
    error counts (as f32) and minimum margins for the read test and the
    write test.
    """
    # Broadcast combos over the cell axes: [K, 1, 1, 1] vs [B, C, N].
    col = lambda j: combos[:, j][:, None, None, None]
    trcd, tras, twr, trp, tref, temp = (col(j) for j in range(6))

    m_r, m_w = cm.test_margins(
        qcap[None], tau_s[None], tau_r[None], tau_p[None], lam85[None],
        trcd, tras, twr, trp, tref, temp, p,
    )

    valid = (temp >= 0.0)
    m_r = jnp.where(valid, m_r, SENTINEL_MARGIN)
    m_w = jnp.where(valid, m_w, SENTINEL_MARGIN)

    err_r = jnp.sum((m_r < 0.0).astype(jnp.float32), axis=-1)
    err_w = jnp.sum((m_w < 0.0).astype(jnp.float32), axis=-1)
    mmin_r = jnp.min(m_r, axis=-1)
    mmin_w = jnp.min(m_w, axis=-1)
    return err_r, err_w, mmin_r, mmin_w


def margins_ref(qcap, tau_s, tau_r, tau_p, lam85, combo,
                p: ModelParams = PARAMS):
    """Per-cell margins for a single combo (no reduction) — used by the
    repeatability analysis and the ODE cross-check."""
    trcd, tras, twr, trp, tref, temp = (combo[j] for j in range(6))
    return cm.test_margins(qcap, tau_s, tau_r, tau_p, lam85,
                           trcd, tras, twr, trp, tref, temp, p)
