"""Closed-form charge dynamics shared by the Pallas kernel and the oracle.

These are the elementwise equations of DESIGN.md §4. They are written as
plain jnp functions over arrays so that:

  * ``ref.py`` can apply them directly (pure-jnp oracle),
  * ``cell_charge.py`` can apply them to VMEM-resident blocks inside the
    Pallas kernel body,
  * the rust native mirror (rust/src/model/charge.rs) implements the exact
    same expressions scalar-by-scalar.

All times are in ns except refresh intervals (ms). Charge is normalized to
VDD = 1. Temperatures are degC.
"""

from __future__ import annotations

import jax.numpy as jnp

from ..params import ModelParams


def leak_factor(lam85, temp_c, tref_ms, p: ModelParams):
    """Multiplicative charge decay over one refresh window.

    ``lam85`` is the per-cell leak rate (1/ms) at the 85degC reference;
    leakage doubles every ``leak_doubling_c`` degC (retention halves), the
    standard DRAM retention/temperature model [Liu+ ISCA'13].
    """
    lam = lam85 * 2.0 ** ((temp_c - p.t_ref_base_c) / p.leak_doubling_c)
    return jnp.exp(-lam * tref_ms)


def restore_read(qcap, tau_r, tras_ns, p: ModelParams):
    """Cell charge at the end of a read access (ACT .. PRE window = tRAS).

    After the sense amplifier latches (at ``t_rest0_ns``) the cell sits at
    ``q_share`` of full charge and is restored exponentially toward its full
    per-cell capacity ``qcap`` with time constant ``tau_r``. Cutting tRAS
    truncates restoration — the paper's second charge/latency coupling.
    """
    w = jnp.maximum(tras_ns - p.t_rest0_ns, 0.0)
    return qcap * (1.0 - (1.0 - p.q_share) * jnp.exp(-w / tau_r))


def restore_write(qcap, tau_r, twr_ns, p: ModelParams):
    """Cell charge at the end of a write-recovery window (tWR).

    Writes drive the cell from the opposite rail, so restoration starts from
    zero stored charge; ``kw_pattern`` derates the final level for the
    worst-case coupling data pattern (writes are the harder test — Fig 2a).
    """
    tau_w = p.wr_tau_ratio * tau_r
    return qcap * p.kw_pattern * (1.0 - jnp.exp(-(twr_ns + p.t_wr0_ns) / tau_w))


def precharge_offset(tau_p, trp_ns, p: ModelParams):
    """Residual bitline differential left by a truncated precharge (tRP).

    The bitline equalizes toward VDD/2 exponentially; whatever offset is
    left over subtracts from the *next* access's sense margin — the paper's
    third coupling.
    """
    w = jnp.maximum(trp_ns - p.t_pre0_ns, 0.0)
    return p.v_bl * jnp.exp(-w / tau_p)


def sense_margin(q0, tau_s, trcd_ns, offset, temp_c, p: ModelParams):
    """Sense margin after tRCD given initial charge ``q0``.

    Charge sharing produces an initial differential whose amplitude
    saturates at ``a_max`` once the cell holds more than ``q_knee`` charge
    and collapses steeply (a ``knee_pow`` power law — the retention cliff)
    below it. The cliff is what decouples the retention tail from sensing
    speed and lets tRCD shrink even at a 200 ms refresh interval: a cell
    either retains enough charge to sense at full amplitude or it fails
    outright. The differential then develops exponentially with the
    per-cell ``tau_s`` (slower when hot, via ``alpha_t_per_c``). A read is
    correct iff the developed differential, less the residual precharge
    offset, reaches ``v_read``. Margin >= 0 means PASS.
    """
    amp = p.a_max * jnp.minimum((q0 / p.q_knee) ** p.knee_pow, 1.0)
    tau_t = tau_s * (1.0 + p.alpha_t_per_c * jnp.maximum(temp_c - 55.0, 0.0))
    w = jnp.maximum(trcd_ns - p.t_soff_ns, 0.0)
    v = amp * (1.0 - jnp.exp(-w / tau_t))
    return v - p.g_off * offset - p.v_read


def test_margins(qcap, tau_s, tau_r, tau_p, lam85,
                 trcd, tras, twr, trp, tref_ms, temp_c, p: ModelParams):
    """Full test chains for one timing combination; returns
    ``(margin_read, margin_write)`` per cell (negative margin = error).

    Read test (tRCD x tRAS x tRP): access with the combo's reduced
    timings — truncated restoration (tRAS), leak over one refresh window,
    sense with the combo's tRCD against the residual precharge offset of
    the combo's tRP.

    Write test (tRCD x tWR x tRP): write with the combo's reduced
    timings, then *read back with standard timings* (the tester verifies
    with safe timings — this is why the paper's write test tolerates far
    more aggressive tRCD/tRP than the read test, Fig 3d vs 3c). In the
    write test, tRCD gates the ACT -> WRITE driver-settle window and tRP
    gates bitline equalization before the write; both are modeled as
    linear slack terms scaled by ``k_lin`` (V/ns) since the write drivers
    overpower the bitline rather than racing a sense threshold.
    """
    decay = leak_factor(lam85, temp_c, tref_ms, p)
    tau_t = tau_s * (1.0 + p.alpha_t_per_c * jnp.maximum(temp_c - 55.0, 0.0))

    # --- read test ---
    off = precharge_offset(tau_p, trp, p)
    q_r = restore_read(qcap, tau_r, tras, p) * decay
    m_r = sense_margin(q_r, tau_s, trcd, off, temp_c, p)

    # --- write test ---
    q_w = restore_write(qcap, tau_r, twr, p) * decay
    spec = p.spec
    off_std = precharge_offset(tau_p, spec["trp_ns"], p)
    m_w_rb = sense_margin(q_w, tau_s, spec["trcd_ns"], off_std, temp_c, p)
    m_w_rcd = p.k_lin * (trcd - (p.t_soff_ns + p.c_rcd_w * tau_t))
    m_w_rp = p.k_lin * (trp - (p.t_pre0_ns + p.c_rp_w * tau_p))
    m_w = jnp.minimum(m_w_rb, jnp.minimum(m_w_rcd, m_w_rp))
    return m_r, m_w
