"""Charge-model parameters, loaded from the repo-level ``model_params.json``.

This module is the *only* python-side reader of the JSON so that the AOT
artifacts and the rust native model (rust/src/model/params.rs) are guaranteed
to agree on constants. The constants are baked into the lowered HLO at
``make artifacts`` time; the rust side re-reads the JSON at runtime for its
native mirror, and ``rust/tests/runtime_native_xcheck.rs`` asserts the two
paths produce identical error counts.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import List

_JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "..", "model_params.json")


@dataclass(frozen=True)
class Vendor:
    name: str
    share: float
    mu_ln_tau_s: float
    lam_shift: float
    tau_shift: float


@dataclass(frozen=True)
class ModelParams:
    """Analytic charge-model constants (see DESIGN.md §4)."""

    # --- sensing ---
    t_soff_ns: float       # wordline dead time before differential develops
    a_max: float           # saturated charge-sharing amplitude (V, VDD=1)
    q_knee: float          # charge knee below which amplitude degrades
    knee_pow: float        # cliff steepness of the amplitude below the knee
    v_read_frac: float     # required amplification fraction of a_max
    g_off: float           # gain of residual precharge offset into margin
    alpha_t_per_c: float   # tau_s thermal coefficient (per degC above 55)
    # --- restoration ---
    q_share: float         # fractional charge right after sense latch
    t_rest0_ns: float      # latch point; restoration starts here
    # --- write ---
    t_wr0_ns: float        # fixed write-path time before tWR window
    wr_tau_ratio: float    # tau_w = ratio * tau_r
    kw_pattern: float      # worst-case coupling derating of written charge
    # --- precharge ---
    v_bl: float            # bitline swing to equalize
    t_pre0_ns: float       # precharge driver dead time
    # --- leakage ---
    leak_doubling_c: float # leak doubles every this many degC
    t_ref_base_c: float    # temperature at which lam85 is specified
    # --- write-access settle terms (write test) ---
    c_rcd_w: float         # ACT->WRITE settle, in units of tau_s
    c_rp_w: float          # pre-write equalization, in units of tau_p
    k_lin: float           # linear-slack margin scale (V/ns)
    # --- spec / floors / geometry (dicts straight from JSON) ---
    spec: dict
    floors: dict
    geometry: dict
    population: dict

    @property
    def v_read(self) -> float:
        return self.v_read_frac * self.a_max

    @property
    def vendors(self) -> List[Vendor]:
        return [Vendor(**v) for v in self.population["vendors"]]


def load(path: str = _JSON_PATH) -> ModelParams:
    with open(path) as f:
        raw = json.load(f)
    raw.pop("_comment", None)
    return ModelParams(**raw)


PARAMS = load()
