"""Euler-integrated bitline dynamics vs the analytic closed form.

Validates that the fast profiling path's ``amp * (1 - exp(-t/tau))``
sensing model is the true solution of the first-order sense dynamics the
ODE kernel integrates (DESIGN.md §4 ablation)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bitline_ode


def _inputs(seed, n):
    rng = np.random.default_rng(seed)
    q0 = rng.uniform(0.05, 1.1, n).astype(np.float32)
    tau_s = rng.lognormal(1.61, 0.05, n).astype(np.float32)
    tau_p = rng.lognormal(0.615, 0.04, n).astype(np.float32)
    return jnp.asarray(q0), jnp.asarray(tau_s), jnp.asarray(tau_p)


def _scalars(trcd, trp, temp):
    return jnp.asarray([trcd, trp, 64.0, temp, 0, 0, 0, 0], jnp.float32)


@pytest.mark.parametrize("trcd,trp,temp", [
    (13.75, 13.75, 55.0),
    (13.75, 13.75, 85.0),
    (8.75, 8.75, 55.0),
    (5.0, 5.0, 85.0),
])
def test_ode_matches_analytic(trcd, trp, temp):
    q0, tau_s, tau_p = _inputs(3, bitline_ode.BLOCK * 2)
    s = _scalars(trcd, trp, temp)
    ode = np.asarray(bitline_ode.sense_margin_ode(q0, tau_s, tau_p, s))
    ana = np.asarray(bitline_ode.sense_margin_analytic(q0, tau_s, tau_p, s))
    # Explicit Euler with 128 steps: first-order global error ~ dt.
    np.testing.assert_allclose(ode, ana, atol=2e-3)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       trcd=st.floats(3.0, 13.75), trp=st.floats(3.0, 13.75),
       temp=st.floats(25.0, 85.0))
def test_ode_matches_analytic_hypothesis(seed, trcd, trp, temp):
    q0, tau_s, tau_p = _inputs(seed, bitline_ode.BLOCK)
    s = _scalars(trcd, trp, temp)
    ode = np.asarray(bitline_ode.sense_margin_ode(q0, tau_s, tau_p, s))
    ana = np.asarray(bitline_ode.sense_margin_analytic(q0, tau_s, tau_p, s))
    np.testing.assert_allclose(ode, ana, atol=3e-3)


def test_ode_sign_agreement():
    """The pass/fail decision (the thing the profiler consumes) agrees
    between the ODE and analytic paths away from the decision boundary."""
    q0, tau_s, tau_p = _inputs(11, bitline_ode.BLOCK * 4)
    s = _scalars(9.0, 9.0, 85.0)
    ode = np.asarray(bitline_ode.sense_margin_ode(q0, tau_s, tau_p, s))
    ana = np.asarray(bitline_ode.sense_margin_analytic(q0, tau_s, tau_p, s))
    boundary = np.abs(ana) < 5e-3
    assert (np.sign(ode[~boundary]) == np.sign(ana[~boundary])).all()
