"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import cell_charge, ref
from compile.params import PARAMS

from .conftest import STD, make_cells, make_combos


def run_both(cells, combos):
    args = tuple(jnp.asarray(a) for a in cells) + (jnp.asarray(combos),)
    return ref.profile_ref(*args), cell_charge.profile_kernel(*args)


class TestKernelVsRef:
    def test_matches_oracle(self, small_pop, combos16):
        r, k = run_both(small_pop, combos16)
        for name, a, b in zip(["err_r", "err_w", "mmin_r", "mmin_w"], r, k):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6, err_msg=name)

    def test_output_shapes(self, small_pop, combos16):
        _, out = run_both(small_pop, combos16)
        b, c, _ = small_pop[0].shape
        k = combos16.shape[0]
        for o in out:
            assert o.shape == (k, b, c)

    def test_error_counts_are_integral(self, small_pop, combos16):
        _, (err_r, err_w, _, _) = run_both(small_pop, combos16)
        for e in (err_r, err_w):
            a = np.asarray(e)
            assert np.all(a == np.round(a))
            assert np.all(a >= 0)

    def test_sentinel_combo_is_error_free(self, small_pop, combos16):
        _, (err_r, err_w, mmin_r, mmin_w) = run_both(small_pop, combos16)
        assert float(err_r[-1].sum()) == 0.0
        assert float(err_w[-1].sum()) == 0.0
        assert float(np.min(np.asarray(mmin_r)[-1])) == ref.SENTINEL_MARGIN
        assert float(np.min(np.asarray(mmin_w)[-1])) == ref.SENTINEL_MARGIN


class TestPhysicalInvariants:
    """Direction-of-effect checks on the oracle (and, by the equivalence
    test above, on the kernel): each timing parameter moves margins the way
    §3 of the paper says it must."""

    @pytest.fixture(scope="class")
    def cells(self):
        rng = np.random.default_rng(7)
        return make_cells(rng, (1, 1, 512))

    def margins(self, cells, trcd=13.75, tras=35.0, twr=15.0, trp=13.75,
                tref=64.0, temp=55.0):
        combo = jnp.asarray([trcd, tras, twr, trp, tref, temp], jnp.float32)
        args = tuple(jnp.asarray(a) for a in cells) + (combo,)
        m_r, m_w = ref.margins_ref(*args)
        return np.asarray(m_r), np.asarray(m_w)

    def test_std_timings_pass_at_85c(self, cells):
        m_r, m_w = self.margins(cells, temp=85.0)
        assert (m_r >= 0).all() and (m_w >= 0).all()

    def test_lower_trcd_lowers_margin(self, cells):
        hi, _ = self.margins(cells, trcd=13.75)
        lo, _ = self.margins(cells, trcd=7.5)
        assert (lo <= hi + 1e-7).all() and lo.mean() < hi.mean()

    def test_lower_tras_lowers_read_margin_only(self, cells):
        hi_r, hi_w = self.margins(cells, tras=35.0)
        lo_r, lo_w = self.margins(cells, tras=15.0)
        assert (lo_r <= hi_r + 1e-7).all()
        np.testing.assert_allclose(lo_w, hi_w, rtol=1e-6)

    def test_lower_twr_lowers_write_margin_only(self, cells):
        hi_r, hi_w = self.margins(cells, twr=15.0)
        lo_r, lo_w = self.margins(cells, twr=5.0)
        assert (lo_w <= hi_w + 1e-7).all()
        np.testing.assert_allclose(lo_r, hi_r, rtol=1e-6)

    def test_lower_trp_lowers_margin(self, cells):
        hi_r, _ = self.margins(cells, trp=13.75)
        lo_r, _ = self.margins(cells, trp=5.0)
        assert (lo_r <= hi_r + 1e-7).all() and lo_r.mean() < hi_r.mean()

    def test_hotter_is_worse(self, cells):
        cool_r, cool_w = self.margins(cells, temp=55.0, tref=200.0)
        hot_r, hot_w = self.margins(cells, temp=85.0, tref=200.0)
        assert (hot_r <= cool_r + 1e-7).all()
        assert (hot_w <= cool_w + 1e-7).all()

    def test_longer_refresh_is_worse(self, cells):
        short_r, _ = self.margins(cells, tref=64.0, temp=85.0)
        long_r, _ = self.margins(cells, tref=448.0, temp=85.0)
        assert (long_r <= short_r + 1e-7).all()

    def test_write_test_is_harder_than_read(self, cells):
        # kw_pattern < 1 means the write chain stores less charge. Above
        # the amplitude knee both saturate to identical margins, so the
        # difference shows once leakage drags the (smaller) written-back
        # charge below the knee first (Fig 2a: write max refresh interval
        # 160 ms < read 208 ms). Stress with a long refresh interval and
        # compare failure counts.
        m_r, m_w = self.margins(cells, tref=560.0, temp=85.0)
        assert (m_w <= m_r + 1e-7).all()
        assert (m_w < 0).sum() > (m_r < 0).sum()

    def test_refresh_latency_tradeoff(self, cells):
        """§7.1: refreshing more often enables more latency reduction —
        at aggressive timings, failures shrink as tref shrinks. (Margins of
        knee-saturated cells are tref-invariant by design, so the signal is
        in the leak-dominated tail: compare margins and counts at long
        refresh intervals.)"""
        aggressive = dict(trcd=10.0, tras=22.5, twr=7.5, trp=8.75, temp=85.0)
        m200, w200 = self.margins(cells, tref=200.0, **aggressive)
        m560, w560 = self.margins(cells, tref=560.0, **aggressive)
        m900, w900 = self.margins(cells, tref=900.0, **aggressive)
        assert (m560 <= m200 + 1e-7).all() and (m900 <= m560 + 1e-7).all()
        fails = lambda m: int((m < 0).sum())
        assert fails(w200) <= fails(w560) <= fails(w900)
        assert fails(w900) > fails(w200)


def test_large_batch_matches(small_pop):
    combos = make_combos(PARAMS.geometry["combo_batch"])
    r, k = run_both(small_pop, combos)
    for a, b in zip(r, k):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
