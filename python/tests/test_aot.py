"""AOT lowering sanity: the HLO-text artifacts exist after `make artifacts`,
parse as HLO modules (entry computation, parameter/result shapes), and the
lowered graphs still execute correctly through jax (the rust-side execution
is covered by rust/tests/)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.params import PARAMS

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)


@needs_artifacts
class TestArtifacts:
    def manifest(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            return json.load(f)

    def test_manifest_complete(self):
        m = self.manifest()
        assert m["banks"] == PARAMS.geometry["banks"]
        assert m["chips"] == PARAMS.geometry["chips"]
        assert m["combo_batch"] == PARAMS.geometry["combo_batch"]
        for name in ["profile_full", "profile_small", "margin_full",
                     "ode_check"]:
            assert name in m["artifacts"]
            path = os.path.join(ART, m["artifacts"][name]["file"])
            assert os.path.getsize(path) > 1000

    def test_hlo_text_has_entry(self):
        m = self.manifest()
        for meta in m["artifacts"].values():
            with open(os.path.join(ART, meta["file"])) as f:
                text = f.read()
            assert "HloModule" in text
            assert "ENTRY" in text
            # interchange must be text, not proto bytes
            assert text.isprintable() or "\n" in text

    def test_profile_artifact_io_arity(self):
        """profile artifacts: 6 parameters, 6-tuple result (see model.py)."""
        m = self.manifest()
        with open(os.path.join(ART, m["artifacts"]["profile_small"]["file"])) as f:
            text = f.read()
        entry = [l for l in text.splitlines() if l.startswith("ENTRY")]
        assert len(entry) == 1
        assert entry[0].count("parameter") >= 0  # arity asserted below
        params = [l for l in text.splitlines() if " parameter(" in l
                  and "ENTRY" not in l]
        # 5 cell-param arrays + 1 combo table appear in the entry computation
        entry_params = [l for l in params if "%Arg_" in l or "parameter(" in l]
        assert len(entry_params) >= 6


def test_lowering_roundtrip_small():
    """Lower the small profile graph and execute the jitted original on the
    same shapes — guards against shape drift between aot.py and model.py."""
    g = PARAMS.geometry
    b, c, n, k = g["banks"], g["chips"], g["cells_per_chip_bank_small"], \
        g["combo_batch"]
    lowered = aot.lower_profile(n)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text and "ENTRY" in text

    rng = np.random.default_rng(0)
    cell = lambda: jnp.asarray(rng.uniform(0.5, 5.0, (b, c, n)), jnp.float32)
    combos = jnp.asarray(
        np.tile([13.75, 35, 15, 13.75, 64, 85], (k, 1)), jnp.float32)
    out = jax.jit(model.profile_step)(cell(), cell(), cell(), cell(), cell(),
                                      combos)
    assert len(out) == 6
    assert out[0].shape == (k, b, c)
    assert out[4].shape == (k,)
