"""Shared fixtures: synthetic cell populations matching the rust generator's
distributional shape (the exact rust RNG streams are cross-checked in
rust/tests/, not here — here we only need representative parameter ranges).
"""

from __future__ import annotations

import numpy as np
import pytest

from compile.params import PARAMS


def make_cells(rng: np.random.Generator, shape):
    """Draw per-cell parameters from the calibrated population families."""
    pop = PARAMS.population
    tau_s = rng.lognormal(1.61, pop["sigma_tau_s"], shape)
    tau_r = pop["tau_r_ratio"] * tau_s * rng.lognormal(0.0, pop["sigma_tau_r"], shape)
    tau_p = rng.lognormal(pop["mu_ln_tau_p"], pop["sigma_tau_p"], shape)
    lam85 = rng.lognormal(pop["mu_ln_lam85"], pop["sigma_lam"], shape)
    qcap = np.clip(rng.lognormal(0.0, pop["sigma_qcap"], shape),
                   pop["qcap_clip_lo"], pop["qcap_clip_hi"])
    to32 = lambda a: a.astype(np.float32)
    return tuple(map(to32, (qcap, tau_s, tau_r, tau_p, lam85)))


STD = [13.75, 35.0, 15.0, 13.75]  # tRCD, tRAS, tWR, tRP (DDR3 spec)


def make_combos(k: int) -> np.ndarray:
    """A representative spread of combos: std timings, reduced timings,
    aggressive timings, varying refresh/temperature, plus one sentinel."""
    rng = np.random.default_rng(1234)
    combos = np.zeros((k, 6), dtype=np.float32)
    for i in range(k):
        combos[i, 0] = rng.uniform(5.0, 13.75)    # tRCD
        combos[i, 1] = rng.uniform(16.25, 35.0)   # tRAS
        combos[i, 2] = rng.uniform(5.0, 15.0)     # tWR
        combos[i, 3] = rng.uniform(5.0, 13.75)    # tRP
        combos[i, 4] = rng.uniform(16.0, 448.0)   # refresh interval (ms)
        combos[i, 5] = rng.choice([45.0, 55.0, 70.0, 85.0])
    combos[0] = STD + [64.0, 85.0]
    combos[1] = STD + [64.0, 55.0]
    combos[-1, 5] = -1.0  # sentinel / padding
    return combos


@pytest.fixture(scope="session")
def small_pop():
    rng = np.random.default_rng(42)
    return make_cells(rng, (2, 2, 256))


@pytest.fixture(scope="session")
def combos16():
    return make_combos(16)
