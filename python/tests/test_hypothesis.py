"""Property-based sweeps of the kernel: shapes, dtypes, and parameter
ranges drawn by hypothesis; every draw must match the oracle and respect
the model's monotonicity invariants."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import cell_charge, ref
from compile.kernels import charge_math as cm
from compile.params import PARAMS

from .conftest import make_cells


def _cells(seed, shape):
    return make_cells(np.random.default_rng(seed), shape)


combo_st = st.tuples(
    st.floats(3.0, 13.75),    # tRCD
    st.floats(12.0, 35.0),    # tRAS
    st.floats(3.0, 15.0),     # tWR
    st.floats(3.0, 13.75),    # tRP
    st.floats(8.0, 512.0),    # tref (ms)
    st.floats(25.0, 85.0),    # temp (C)
)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    b=st.integers(1, 3),
    c=st.integers(1, 3),
    n_pow=st.integers(4, 8),
    combos=st.lists(combo_st, min_size=1, max_size=9),
)
def test_kernel_matches_ref_any_shape(seed, b, c, n_pow, combos):
    cells = _cells(seed, (b, c, 2 ** n_pow))
    carr = np.asarray(combos, dtype=np.float32)
    args = tuple(jnp.asarray(a) for a in cells) + (jnp.asarray(carr),)
    r = ref.profile_ref(*args)
    k = cell_charge.profile_kernel(*args)
    for a_, b_ in zip(r, k):
        np.testing.assert_allclose(np.asarray(a_), np.asarray(b_),
                                   rtol=1e-5, atol=1e-6)


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), combo=combo_st,
       scale=st.floats(0.3, 0.95))
def test_scaling_any_timing_down_never_helps(seed, combo, scale):
    """Monotonicity: uniformly shrinking all four timing parameters can
    only reduce (or keep) every cell's margin."""
    cells = _cells(seed, (1, 1, 64))
    full = np.asarray(combo, dtype=np.float32)
    cut = full.copy()
    cut[:4] *= scale
    args = tuple(jnp.asarray(a) for a in cells)
    m_full = ref.margins_ref(*args, jnp.asarray(full))
    m_cut = ref.margins_ref(*args, jnp.asarray(cut))
    for mf, mc in zip(m_full, m_cut):
        assert (np.asarray(mc) <= np.asarray(mf) + 1e-6).all()


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), combo=combo_st,
       dtemp=st.floats(1.0, 40.0))
def test_heating_never_helps(seed, combo, dtemp):
    cells = _cells(seed, (1, 1, 64))
    cool = np.asarray(combo, dtype=np.float32)
    hot = cool.copy()
    hot[5] = min(hot[5] + dtemp, 85.0)
    args = tuple(jnp.asarray(a) for a in cells)
    m_cool = ref.margins_ref(*args, jnp.asarray(cool))
    m_hot = ref.margins_ref(*args, jnp.asarray(hot))
    for mc, mh in zip(m_cool, m_hot):
        assert (np.asarray(mh) <= np.asarray(mc) + 1e-6).all()


@settings(max_examples=100, deadline=None)
@given(
    qcap=st.floats(0.5, 1.2), tau_r=st.floats(0.5, 12.0),
    tras=st.floats(0.0, 40.0), twr=st.floats(0.0, 20.0),
)
def test_restore_bounded_by_capacity(qcap, tau_r, tras, twr):
    """Restoration can never exceed the cell's own full charge, and write
    restoration can never exceed the pattern-derated level."""
    p = PARAMS
    q_r = float(cm.restore_read(jnp.float32(qcap), jnp.float32(tau_r),
                                jnp.float32(tras), p))
    q_w = float(cm.restore_write(jnp.float32(qcap), jnp.float32(tau_r),
                                 jnp.float32(twr), p))
    assert 0.0 <= q_r <= qcap + 1e-6
    assert 0.0 <= q_w <= p.kw_pattern * qcap + 1e-6
    assert q_r >= p.q_share * qcap - 1e-6  # latch floor


@settings(max_examples=100, deadline=None)
@given(tau_p=st.floats(0.5, 5.0), trp=st.floats(0.0, 20.0))
def test_precharge_offset_bounded(tau_p, trp):
    off = float(cm.precharge_offset(jnp.float32(tau_p), jnp.float32(trp),
                                    PARAMS))
    assert 0.0 <= off <= PARAMS.v_bl + 1e-6
