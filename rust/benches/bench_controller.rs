//! L3 hot-path benchmarks: the memory-controller scheduling loop and the
//! full-system step, per workload pattern and timing set. EXPERIMENTS.md
//! §Perf (L3) tracks the cmd/s and cycles/s figures here.

use aldram::mem::{AddrMap, Controller, Request, RowPolicy, System,
                  SystemConfig};
use aldram::timing::TimingParams;
use aldram::util::bench::Bench;
use aldram::workloads::{by_name, NamedSource, SOURCE_BATCH};

/// Drive one controller for `cycles` with synthetic open-loop traffic.
fn controller_run(cycles: u64, stride: u64, timings: TimingParams) -> u64 {
    let mut ctrl = Controller::new(AddrMap::ddr3_2gb(1), timings,
                                   RowPolicy::Open);
    let mut id = 0u64;
    for now in 0..cycles {
        if now % 3 == 0 {
            id += 1;
            ctrl.enqueue(Request {
                id,
                core: 0,
                addr: (id * stride) % (1 << 30) & !63,
                is_write: id % 4 == 0,
                arrival: now,
            });
        }
        ctrl.tick(now);
    }
    ctrl.stats.reads_done + ctrl.stats.writes_done
}

fn main() {
    let mut b = Bench::from_env("controller");
    let std = TimingParams::ddr3_standard();
    let fast = std.reduced(0.27, 0.32, 0.33, 0.18);

    const CYC: u64 = 10_000;
    b.bench_batch("ctrl/streaming/std", CYC, || {
        controller_run(CYC, 64, std)
    });
    b.bench_batch("ctrl/streaming/aldram", CYC, || {
        controller_run(CYC, 64, fast)
    });
    b.bench_batch("ctrl/row-conflict/std", CYC, || {
        controller_run(CYC, 65536, std)
    });
    b.bench_batch("ctrl/row-conflict/aldram", CYC, || {
        controller_run(CYC, 65536, fast)
    });

    // Full system step rate (4 cores, 1 channel) per workload family,
    // cycle-stepped oracle vs the event-driven time-skip driver
    // (bit-identical stats; the TIMESKIP lines isolate wall-clock).
    for name in ["stream.copy", "gups", "mcf", "povray"] {
        let w = by_name(name).unwrap();
        let cfg = SystemConfig::paper_default();
        let wl: Vec<_> = (0..4).map(|i| (w.clone(), format!("b/{i}"))).collect();
        let mut sys = System::new(&cfg, &wl);
        b.bench_batch(&format!("system/4core/{name}"), 2_000, || {
            sys.run(2_000).cycles
        });
        let mut sys_fast = System::new(&cfg, &wl);
        b.bench_batch(&format!("system/4core/{name}/timeskip"), 2_000, || {
            sys_fast.run_fast(2_000).cycles
        });
        b.report_speedup_tagged("TIMESKIP", &format!("system/4core/{name}"),
                                &format!("system/4core/{name}/timeskip"));
    }

    // Request-source refill batching: one virtual `fill` call per
    // SOURCE_BATCH references vs one per reference (the pre-refactor
    // regime, batch = 1). Same stream, same stats — wall clock only.
    for name in ["stream.copy", "gups"] {
        let w = by_name(name).unwrap();
        let run = |batch: usize| {
            let cfg = SystemConfig::paper_default();
            let src = NamedSource {
                name: w.name.to_string(),
                seed: "srcbench".to_string(),
                footprint: w.footprint,
                source: w.source_with_batch("srcbench", batch),
            };
            System::with_sources(&cfg, vec![src]).run_fast(4_000).reads_done
        };
        assert_eq!(run(1), run(SOURCE_BATCH),
                   "batch size changed the stream for {name}");
        b.bench_batch(&format!("source/{name}/batch1"), 4_000, || run(1));
        b.bench_batch(&format!("source/{name}/batch{SOURCE_BATCH}"), 4_000,
                      || run(SOURCE_BATCH));
        b.report_speedup_tagged(
            "SOURCE", &format!("source/{name}/batch1"),
            &format!("source/{name}/batch{SOURCE_BATCH}"));
    }

    // Inline protocol-checker overhead: identical runs with and without
    // the conformance audit attached (observation-only, asserted). The
    // CHECK tag is the overhead EXPERIMENTS.md §Perf records — expect a
    // ratio just under 1.0.
    for name in ["stream.copy", "gups"] {
        let w = by_name(name).unwrap();
        let run = |checked: bool| {
            let cfg = SystemConfig::paper_default();
            let src = NamedSource {
                name: w.name.to_string(),
                seed: "checkbench".to_string(),
                footprint: w.footprint,
                source: w.source_with_batch("checkbench", SOURCE_BATCH),
            };
            let mut sys = System::with_sources(&cfg, vec![src]);
            if checked {
                sys.enable_check();
            }
            let stats = sys.run_fast(4_000);
            if let Some(sum) = sys.check_summary() {
                assert_eq!(sum.violations, 0, "{name}: {}", sum.line());
            }
            stats.reads_done
        };
        assert_eq!(run(false), run(true),
                   "the checker changed the stream for {name}");
        b.bench_batch(&format!("check/{name}/off"), 4_000, || run(false));
        b.bench_batch(&format!("check/{name}/on"), 4_000, || run(true));
        b.report_speedup_tagged("CHECK", &format!("check/{name}/off"),
                                &format!("check/{name}/on"));
    }

    b.finish();
}
