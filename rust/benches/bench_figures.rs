//! One benchmark per paper table/figure: times the full regeneration
//! pipeline at reduced scale (the full-scale runs are the `repro figure`
//! commands recorded in EXPERIMENTS.md). Keeps the figure pipelines
//! regression-tested for performance, and — since the evaluation and
//! profiling fan-outs run through `exec::Pool` — reports the
//! sequential-vs-parallel wall-clock speedup of the two big pipelines
//! (fig4 and the population campaign) at `--jobs 4`.

use aldram::eval::PAPER_REDUCTIONS_55C;
use aldram::figures::{calibrate, fig2};
use aldram::model::params;
use aldram::population::generate_dimm;
use aldram::profiler::{profile_dimm, profile_refresh, sweep, TestKind};
use aldram::runtime::{NativeBackend, ProfilingBackend};
use aldram::util::bench::Bench;

/// Job width for the parallel legs (the acceptance configuration; the
/// machine may have fewer cores, in which case the SPEEDUP line simply
/// reports what the hardware delivers).
const PAR_JOBS: usize = 4;

fn main() {
    let mut b = Bench::from_env("figures").with_window(200, 1500);

    // Fig 2a: refresh sweep on the representative module.
    let rep = generate_dimm(fig2::REPRESENTATIVE_DIMM, 256, params());
    let mut nb = NativeBackend::new();
    b.bench("fig2a/refresh_sweep/256c", || {
        profile_refresh(&mut nb, &rep.arrays, 85.0).unwrap().module_max_read_ms
    });

    // Fig 2b/2c: one timing sweep each (wave bisection).
    b.bench("fig2b/read_sweep/256c", || {
        sweep(&mut nb, &rep.arrays, TestKind::Read, 55.0, 200.0)
            .unwrap().best.unwrap().sum_ns
    });
    b.bench("fig2c/write_sweep/256c", || {
        sweep(&mut nb, &rep.arrays, TestKind::Write, 55.0, 152.0)
            .unwrap().best.unwrap().sum_ns
    });

    // Fig 3: one full-DIMM profile (the per-module unit of the campaign).
    let d = generate_dimm(11, 256, params());
    b.bench("fig3/profile_dimm/256c", || {
        profile_dimm(&mut nb, &d).unwrap().at55.read.sum_ns
    });

    // Fig 3 population slice end to end (campaign kernel), sequential vs
    // the job pool: one worker-owned backend per DIMM.
    let factory = || -> Box<dyn ProfilingBackend> {
        Box::new(NativeBackend::new())
    };
    b.bench("fig3/campaign_8dimms/64c/jobs1", || {
        calibrate::run_par(factory, 8, 64, 1)
            .unwrap().summary.read_reduction_55
    });
    b.bench(&format!("fig3/campaign_8dimms/64c/jobs{PAR_JOBS}"), || {
        calibrate::run_par(factory, 8, 64, PAR_JOBS)
            .unwrap().summary.read_reduction_55
    });
    b.report_speedup("fig3/campaign_8dimms/64c/jobs1",
                     &format!("fig3/campaign_8dimms/64c/jobs{PAR_JOBS}"));

    // Fig 4 at reduced cycles, sequential vs the job pool: one job per
    // (workload, cores, rep, timing-set) simulation. The pool guarantees
    // identical results for any job count (asserted in eval's tests), so
    // this pair isolates pure wall-clock. `fig4_jobs` runs the
    // event-driven time-skip driver.
    b.bench("fig4/35workloads/6kcyc/jobs1", || {
        aldram::eval::fig4_jobs(6_000, 1, PAPER_REDUCTIONS_55C, 1)
            .per_workload.len()
    });
    b.bench(&format!("fig4/35workloads/6kcyc/jobs{PAR_JOBS}"), || {
        aldram::eval::fig4_jobs(6_000, 1, PAPER_REDUCTIONS_55C, PAR_JOBS)
            .per_workload.len()
    });
    b.report_speedup("fig4/35workloads/6kcyc/jobs1",
                     &format!("fig4/35workloads/6kcyc/jobs{PAR_JOBS}"));

    // TIMESKIP: the same grid on the cycle-stepped oracle vs the
    // event-driven driver (bit-identical results, pure wall-clock — the
    // equivalence matrix lives in tests/integration_timeskip.rs).
    b.bench("fig4/35workloads/6kcyc/jobs1/cyclestep", || {
        aldram::eval::fig4_jobs_with(6_000, 1, PAPER_REDUCTIONS_55C, 1,
                                     aldram::eval::Driver::CycleStepped)
            .per_workload.len()
    });
    b.report_speedup_tagged("TIMESKIP",
                            "fig4/35workloads/6kcyc/jobs1/cyclestep",
                            "fig4/35workloads/6kcyc/jobs1");

    // §7.6 repeatability battery.
    b.bench("s7.6/repeatability/256c", || {
        aldram::profiler::repeatability(
            &d.arrays,
            &aldram::model::Combo { trcd: 8.75, tras: 20.0, twr: 6.25,
                                    trp: 7.5, tref_ms: 448.0, temp_c: 85.0 },
            5,
        )
        .unwrap()
        .base_failures
    });

    // §8.4 power model.
    let pi = aldram::power::PowerInputs {
        cycles: 1_000_000, tck_ns: 1.25, n_act: 10_000, n_read: 60_000,
        n_write: 20_000, n_refresh: 160, open_bank_cycles: 3_000_000,
        banks: 8, tras_cycles: 28, trfc_cycles: 128, burst_cycles: 4,
    };
    let spec = aldram::power::IddSpec::default();
    b.bench_batch("s8.4/power_model", 1000, || {
        (0..1000)
            .map(|_| aldram::power::power(&pi, &spec).total_w())
            .sum::<f64>()
    });

    b.finish();
}
