//! One benchmark per paper table/figure: times the full regeneration
//! pipeline at reduced scale (the full-scale runs are the `repro figure`
//! commands recorded in EXPERIMENTS.md). Keeps the figure pipelines
//! regression-tested for performance.

use std::path::PathBuf;

use aldram::eval::PAPER_REDUCTIONS_55C;
use aldram::figures::{calibrate, fig2};
use aldram::model::params;
use aldram::population::generate_dimm;
use aldram::profiler::{profile_dimm, profile_refresh, sweep, TestKind};
use aldram::runtime::NativeBackend;
use aldram::util::bench::Bench;

fn main() {
    let mut b = Bench::from_env("figures").with_window(200, 1500);
    let out = PathBuf::from(std::env::temp_dir().join("aldram_bench_fig"));

    // Fig 2a: refresh sweep on the representative module.
    let rep = generate_dimm(fig2::REPRESENTATIVE_DIMM, 256, params());
    let mut nb = NativeBackend::new();
    b.bench("fig2a/refresh_sweep/256c", || {
        profile_refresh(&mut nb, &rep.arrays, 85.0).unwrap().module_max_read_ms
    });

    // Fig 2b/2c: one timing sweep each (wave bisection).
    b.bench("fig2b/read_sweep/256c", || {
        sweep(&mut nb, &rep.arrays, TestKind::Read, 55.0, 200.0)
            .unwrap().best.unwrap().sum_ns
    });
    b.bench("fig2c/write_sweep/256c", || {
        sweep(&mut nb, &rep.arrays, TestKind::Write, 55.0, 152.0)
            .unwrap().best.unwrap().sum_ns
    });

    // Fig 3: one full-DIMM profile (the per-module unit of the campaign).
    let d = generate_dimm(11, 256, params());
    b.bench("fig3/profile_dimm/256c", || {
        profile_dimm(&mut nb, &d).unwrap().at55.read.sum_ns
    });

    // Fig 3 population slice end to end (campaign kernel).
    b.bench("fig3/campaign_4dimms/64c", || {
        calibrate::run(&mut nb, 4, 64).unwrap().summary.read_reduction_55
    });

    // Fig 4: one workload speedup measurement at reduced cycles.
    b.bench("fig4/one_workload_speedup/20kcyc", || {
        let r = aldram::eval::fig4(20_000, 1, PAPER_REDUCTIONS_55C);
        let _ = &out;
        r.per_workload.len()
    });

    // §7.6 repeatability battery.
    b.bench("s7.6/repeatability/256c", || {
        aldram::profiler::repeatability(
            &d.arrays,
            &aldram::model::Combo { trcd: 8.75, tras: 20.0, twr: 6.25,
                                    trp: 7.5, tref_ms: 448.0, temp_c: 85.0 },
            5,
        )
        .unwrap()
        .base_failures
    });

    // §8.4 power model.
    let pi = aldram::power::PowerInputs {
        cycles: 1_000_000, tck_ns: 1.25, n_act: 10_000, n_read: 60_000,
        n_write: 20_000, n_refresh: 160, open_bank_cycles: 3_000_000,
        banks: 8, tras_cycles: 28, trfc_cycles: 128, burst_cycles: 4,
    };
    let spec = aldram::power::IddSpec::default();
    b.bench_batch("s8.4/power_model", 1000, || {
        (0..1000)
            .map(|_| aldram::power::power(&pi, &spec).total_w())
            .sum::<f64>()
    });

    b.finish();
}
