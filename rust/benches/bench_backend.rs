//! Backend benchmarks: profiling throughput of the PJRT artifact vs the
//! native scalar mirror vs the vectorized simd kernel vs the early-exit
//! pass probe (the L1/L2 hot path), at both artifact resolutions and
//! batch sizes {1, 16, 256}. These are the numbers behind EXPERIMENTS.md
//! §Perf (L1/L2 and the PROFILE/SWEEP speedup tables).

use aldram::model::{params, Combo};
use aldram::population::generate_dimm;
use aldram::profiler::{sweep, sweep_seeded, TestKind};
use aldram::runtime::{NativeBackend, PassCriterion, ProbeKind,
                      ProfilingBackend, SimdBackend};
use aldram::util::bench::Bench;

fn combos(n: usize) -> Vec<Combo> {
    (0..n)
        .map(|i| Combo {
            trcd: 13.75 - (i % 7) as f32 * 1.25,
            tras: 35.0 - (i % 11) as f32 * 1.25,
            twr: 15.0 - (i % 8) as f32 * 1.25,
            trp: 13.75 - (i % 7) as f32 * 1.25,
            tref_ms: 64.0 + (i % 48) as f32 * 8.0,
            temp_c: if i % 2 == 0 { 85.0 } else { 55.0 },
        })
        .collect()
}

fn main() {
    let mut b = Bench::from_env("backend");

    for cells in [256usize, 2048] {
        let d = generate_dimm(0, cells, params());

        let mut native = NativeBackend::new();
        let mut simd = SimdBackend::new();
        for batch in [1usize, 16, 256] {
            let kombos = combos(batch);
            b.bench(&format!("native/cells{cells}/combos{batch}"), || {
                native.profile(&d.arrays, &kombos).unwrap().tot_r[0]
            });
            b.bench(&format!("simd/cells{cells}/combos{batch}"), || {
                simd.profile(&d.arrays, &kombos).unwrap().tot_r[0]
            });
            b.bench(&format!("probe/cells{cells}/combos{batch}"), || {
                simd.pass_probe(&d.arrays, &kombos, ProbeKind::Read,
                                PassCriterion::Module { budget: 0.0 })
                    .unwrap()
                    .len()
            });
        }
        // The headline vectorization ratio at the sweep-wave batch size.
        b.report_speedup_tagged(
            "PROFILE",
            &format!("native/cells{cells}/combos256"),
            &format!("simd/cells{cells}/combos256"),
        );
        b.report_speedup_tagged(
            "PROFILE",
            &format!("native/cells{cells}/combos256"),
            &format!("probe/cells{cells}/combos256"),
        );

        #[cfg(feature = "pjrt")]
        match aldram::runtime::PjrtBackend::for_cells(
            &aldram::runtime::artifacts_dir(), cells) {
            Ok(mut pjrt) => {
                for batch in [1usize, 64, 256] {
                    let kombos = combos(batch);
                    b.bench(&format!("pjrt/cells{cells}/combos{batch}"), || {
                        pjrt.profile(&d.arrays, &kombos).unwrap().tot_r[0]
                    });
                }
            }
            Err(e) => eprintln!("skipping pjrt at {cells} cells: {e}"),
        }
        #[cfg(not(feature = "pjrt"))]
        eprintln!("skipping pjrt benches at {cells} cells (built without \
                   the `pjrt` feature)");
    }

    // The sweep ladder as the fig3 campaign runs it: cold full-profile
    // sweeps on the scalar backend vs probed + warm-started sweeps on the
    // simd backend (identical frontiers; runtime_simd_xcheck asserts it).
    {
        let d = generate_dimm(0, 2048, params());
        let mut native = NativeBackend::new();
        let mut simd = SimdBackend::new();
        b.bench("sweep/native-cold/cells2048", || {
            let hot =
                sweep(&mut native, &d.arrays, TestKind::Read, 85.0, 200.0)
                    .unwrap();
            let cool =
                sweep(&mut native, &d.arrays, TestKind::Read, 55.0, 200.0)
                    .unwrap();
            (hot.best.map(|x| x.sum_ns), cool.best.map(|x| x.sum_ns))
        });
        b.bench("sweep/simd-probe-warm/cells2048", || {
            let hot = sweep(&mut simd, &d.arrays, TestKind::Read, 85.0,
                            200.0)
                .unwrap();
            let cool = sweep_seeded(&mut simd, &d.arrays, TestKind::Read,
                                    55.0, 200.0, Some(&hot))
                .unwrap();
            (hot.best.map(|x| x.sum_ns), cool.best.map(|x| x.sum_ns))
        });
        b.report_speedup_tagged("SWEEP", "sweep/native-cold/cells2048",
                                "sweep/simd-probe-warm/cells2048");
    }

    // Population generation (the other substrate on the campaign path;
    // now includes the one-time screening-order sort).
    b.bench("population/generate_dimm_2048", || {
        generate_dimm(9, 2048, params()).arrays.qcap[0]
    });

    b.finish();
}
