//! Backend benchmarks: profiling throughput of the PJRT artifact vs the
//! native mirror (the L1/L2 hot path), at both artifact resolutions and
//! several combo-batch sizes. These are the numbers behind EXPERIMENTS.md
//! §Perf (L1/L2).

use aldram::model::{params, Combo};
use aldram::population::generate_dimm;
use aldram::runtime::{NativeBackend, ProfilingBackend};
use aldram::util::bench::Bench;

fn combos(n: usize) -> Vec<Combo> {
    (0..n)
        .map(|i| Combo {
            trcd: 13.75 - (i % 7) as f32 * 1.25,
            tras: 35.0 - (i % 11) as f32 * 1.25,
            twr: 15.0 - (i % 8) as f32 * 1.25,
            trp: 13.75 - (i % 7) as f32 * 1.25,
            tref_ms: 64.0 + (i % 48) as f32 * 8.0,
            temp_c: if i % 2 == 0 { 85.0 } else { 55.0 },
        })
        .collect()
}

fn main() {
    let mut b = Bench::from_env("backend");

    for cells in [256usize, 2048] {
        let d = generate_dimm(0, cells, params());
        let batch = combos(64);

        let mut native = NativeBackend::new();
        b.bench(&format!("native/cells{cells}/combos64"), || {
            native.profile(&d.arrays, &batch).unwrap().tot_r[0]
        });

        #[cfg(feature = "pjrt")]
        match aldram::runtime::PjrtBackend::for_cells(
            &aldram::runtime::artifacts_dir(), cells) {
            Ok(mut pjrt) => {
                b.bench(&format!("pjrt/cells{cells}/combos64"), || {
                    pjrt.profile(&d.arrays, &batch).unwrap().tot_r[0]
                });
                let one = combos(1);
                b.bench(&format!("pjrt/cells{cells}/combos1"), || {
                    pjrt.profile(&d.arrays, &one).unwrap().tot_r[0]
                });
                let big = combos(256);
                b.bench(&format!("pjrt/cells{cells}/combos256"), || {
                    pjrt.profile(&d.arrays, &big).unwrap().tot_r[0]
                });
            }
            Err(e) => eprintln!("skipping pjrt at {cells} cells: {e}"),
        }
        #[cfg(not(feature = "pjrt"))]
        eprintln!("skipping pjrt benches at {cells} cells (built without \
                   the `pjrt` feature)");
    }

    // Population generation (the other substrate on the campaign path).
    b.bench("population/generate_dimm_2048", || {
        generate_dimm(9, 2048, params()).arrays.qcap[0]
    });

    b.finish();
}
