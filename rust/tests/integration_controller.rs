//! Integration: the memory-system simulator end to end — cores, the
//! FR-FCFS controller, refresh, and the AL-DRAM timing swap.

use aldram::mem::{AddrMap, Controller, Request, RowPolicy, System,
                  SystemConfig};
use aldram::timing::TimingParams;
use aldram::workloads::{by_name, suite};

fn drain(ctrl: &mut Controller, limit: u64) -> u64 {
    let mut now = 0;
    while ctrl.pending() > 0 && now < limit {
        ctrl.tick(now);
        now += 1;
    }
    assert!(now < limit, "controller did not drain");
    now
}

#[test]
fn mixed_traffic_drains_and_accounts() {
    let mut ctrl = Controller::new(AddrMap::ddr3_2gb(1),
                                   TimingParams::ddr3_standard(),
                                   RowPolicy::Open);
    let mut id = 0;
    for i in 0..24u64 {
        id += 1;
        ctrl.enqueue(Request { id, core: 0, addr: i * 64, is_write: i % 3 == 0,
                               arrival: 0 });
    }
    for i in 0..8u64 {
        id += 1;
        ctrl.enqueue(Request { id, core: 1, addr: (1 << 26) + i * 131072,
                               is_write: false, arrival: 0 });
    }
    drain(&mut ctrl, 1_000_000);
    let s = &ctrl.stats;
    assert_eq!(s.reads_done + s.writes_done, 32);
    assert!(s.row_hits > 0 && s.row_misses > 0);
    assert!(s.avg_read_latency() > 0.0);
}

#[test]
fn timing_swap_mid_stream_is_seamless() {
    // AL-DRAM's runtime timing change must not corrupt scheduling: run
    // traffic, swap timings in the middle, keep running, everything drains.
    let mut ctrl = Controller::new(AddrMap::ddr3_2gb(1),
                                   TimingParams::ddr3_standard(),
                                   RowPolicy::Open);
    let mut now = 0u64;
    let mut id = 0u64;
    let mut issued = 0u64;
    let mut swapped = false;
    while now < 200_000 {
        if now % 7 == 0 && issued < 2000 {
            id += 1;
            let addr = (id * 2_654_435_761) % (1 << 30) & !63;
            if ctrl.enqueue(Request { id, core: 0, addr, is_write: id % 4 == 0,
                                      arrival: now }) {
                issued += 1;
            }
        }
        if now == 100_000 && !swapped {
            ctrl.set_timings(TimingParams::ddr3_standard()
                .reduced(0.27, 0.32, 0.33, 0.18));
            swapped = true;
        }
        ctrl.tick(now);
        now += 1;
    }
    while ctrl.pending() > 0 && now < 400_000 {
        ctrl.tick(now);
        now += 1;
    }
    assert_eq!(ctrl.stats.reads_done + ctrl.stats.writes_done, issued);
}

#[test]
fn more_channels_increase_throughput() {
    let w = by_name("gups").unwrap();
    let run = |channels: usize| {
        let cfg = SystemConfig::paper_default().with_channels(channels);
        let wl: Vec<_> = (0..4).map(|i| (w.clone(), format!("ch/{i}"))).collect();
        let mut sys = System::new(&cfg, &wl);
        let s = sys.run(120_000);
        s.cores.iter().map(|c| c.ipc).sum::<f64>()
    };
    let one = run(1);
    let two = run(2);
    assert!(two > one * 1.15, "2ch {two} vs 1ch {one}");
}

#[test]
fn open_policy_beats_closed_on_streams() {
    let w = by_name("libquantum").unwrap();
    let run = |policy| {
        let cfg = SystemConfig { policy, ..SystemConfig::paper_default() };
        let mut sys = System::new(&cfg, &[(w.clone(), "p".into())]);
        sys.run(120_000).cores[0].ipc
    };
    let open = run(RowPolicy::Open);
    let closed = run(RowPolicy::Closed);
    assert!(open >= closed * 0.98,
            "open {open} should not lose to closed {closed} on streams");
}

#[test]
fn every_suite_workload_simulates() {
    // Smoke every generator through the full system briefly.
    let cfg = SystemConfig::paper_default();
    for w in suite() {
        let mut sys = System::new(&cfg, &[(w.clone(), "smoke".into())]);
        let s = sys.run(5_000);
        assert!(s.cores[0].insts > 0, "{} made no progress", w.name);
    }
}

#[test]
fn stream_workload_cannot_postpone_refresh() {
    // Regression for the refresh-starvation bug: a row-hit-heavy Stream
    // workload used to let the scheduler keep issuing to a refresh-pending
    // rank, postponing REF unboundedly. The rank fence in the controller
    // guarantees the refresh rate tracks tREFI regardless of traffic.
    let cycles = 100_000u64;
    let trefi =
        TimingParams::ddr3_standard().to_cycles(1.25).trefi as u64;
    let w = by_name("stream.copy").unwrap();
    let wl: Vec<_> = (0..4).map(|i| (w.clone(), format!("refr/{i}"))).collect();
    let mut sys = System::new(&SystemConfig::paper_default(), &wl);
    let s = sys.run(cycles);
    let expect = cycles as f64 / trefi as f64;
    let got = s.refreshes as f64;
    assert!((got - expect).abs() <= expect * 0.25,
            "stream refreshes {got} drifted from cycles/tREFI = {expect:.1}");
}

#[test]
fn aldram_managed_system_tracks_temperature() {
    use aldram::aldram::AlDram;
    // A fixed-table AL-DRAM config runs and reports a plausible DIMM temp.
    let cfg = SystemConfig::paper_default()
        .with_aldram(Some(AlDram::fixed(
            TimingParams::ddr3_standard().reduced(0.27, 0.32, 0.33, 0.18))))
        .with_ambient(30.0);
    let w = by_name("stream.copy").unwrap();
    let wl: Vec<_> = (0..4).map(|i| (w.clone(), format!("t/{i}"))).collect();
    let mut sys = System::new(&cfg, &wl);
    let s = sys.run(200_000);
    assert!(s.mean_temp_c >= 30.0 && s.mean_temp_c < 45.0,
            "temp {}", s.mean_temp_c);
}
