//! The characterization→evaluation loop, closed: profile a module, save
//! it to a registry, reload it, and drive the evaluation from the loaded
//! artifact — with results identical to a profile-fresh run.

use std::path::PathBuf;

use aldram::aldram::AlDram;
use aldram::aldram::DEFAULT_BIN_C;
use aldram::eval;
use aldram::model::params;
use aldram::population::generate_dimm;
use aldram::profiler::{profile_dimm, DimmProfile};
use aldram::registry;
use aldram::runtime::NativeBackend;

fn profile(id: usize, cells: usize) -> DimmProfile {
    let d = generate_dimm(id, cells, params());
    let mut b = NativeBackend::new();
    profile_dimm(&mut b, &d).unwrap()
}

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("aldram_reg_it_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn saved_registry_fig4_matches_profile_fresh_run() {
    // The acceptance contract of the registry: a fig4 evaluation driven
    // by a reloaded profile is bit-identical to one driven by the fresh
    // profile, for every statistic (json round-trips f64 exactly, and
    // the evaluation is a function of the table alone).
    let p = profile(3, 64);
    let dir = fresh_dir("fig4");
    registry::save_profile(&dir, &p).unwrap();
    let loaded = registry::load_registry(&dir).unwrap();
    assert_eq!(loaded.len(), 1);
    assert_eq!(loaded[0], p);

    let fresh_table = AlDram::from_profile(&p, DEFAULT_BIN_C);
    let loaded_table = AlDram::from_profile(&loaded[0], DEFAULT_BIN_C);
    assert_eq!(fresh_table.entries(), loaded_table.entries());

    let fresh = eval::fig4_profiled(3_000, 1, &fresh_table, 2);
    let reloaded = eval::fig4_profiled(3_000, 1, &loaded_table, 2);
    assert_eq!(fresh.per_workload.len(), reloaded.per_workload.len());
    for (a, b) in fresh.per_workload.iter().zip(&reloaded.per_workload) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.single_speedup, b.single_speedup, "{}", a.name);
        assert_eq!(a.multi_speedup, b.multi_speedup, "{}", a.name);
    }
    assert_eq!(fresh.gmean_intensive_multi, reloaded.gmean_intensive_multi);
    assert_eq!(fresh.gmean_nonintensive_multi,
               reloaded.gmean_nonintensive_multi);
    assert_eq!(fresh.mean_all_multi, reloaded.mean_all_multi);
    assert_eq!(fresh.max_multi, reloaded.max_multi);
}

#[test]
fn saved_registry_drives_hetero_eval() {
    // A population saved once feeds the module-heterogeneity eval: the
    // channels host distinct reloaded DIMMs and the result matches the
    // profile-fresh population exactly.
    let dir = fresh_dir("hetero");
    let fresh: Vec<DimmProfile> = (0..2).map(|id| profile(id, 64)).collect();
    registry::save_registry(&dir, &fresh).unwrap();
    let loaded = registry::load_registry(&dir).unwrap();
    assert_eq!(loaded, fresh);

    let a = eval::hetero_eval(10_000, 2, 2, &fresh);
    let b = eval::hetero_eval(10_000, 2, 2, &loaded);
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.mix, y.mix);
        assert_eq!(x.dimm_ids, y.dimm_ids);
        assert_ne!(x.dimm_ids[0], x.dimm_ids[1],
                   "channels must host distinct modules");
        assert_eq!(x.weighted_speedup, y.weighted_speedup);
        assert_eq!(x.channel_latency_reduction, y.channel_latency_reduction);
        assert_eq!(x.channel_spread, y.channel_spread);
    }
}
