//! Property tests on the memory controller: protocol legality under random
//! traffic, conservation of requests, and timing-monotonicity.

use aldram::mem::{AddrMap, Controller, Request, RowPolicy};
use aldram::timing::TimingParams;
use aldram::util::quick::forall;
use aldram::util::rng::Rng;

fn random_traffic(rng: &mut Rng, n: usize) -> Vec<Request> {
    (0..n)
        .map(|i| Request {
            id: i as u64 + 1,
            core: rng.below(4) as usize,
            addr: (rng.next_u64() % (1 << 31)) & !63,
            is_write: rng.chance(0.3),
            arrival: 0,
        })
        .collect()
}

/// Drive a controller with the given requests trickled in; return
/// (completions, cycles).
fn run(reqs: &[Request], timings: TimingParams, policy: RowPolicy,
       rng: &mut Rng) -> (u64, u64) {
    let mut ctrl = Controller::new(AddrMap::ddr3_2gb(1), timings, policy);
    let mut now = 0u64;
    let mut pending: Vec<Request> = reqs.to_vec();
    pending.reverse();
    let mut done = 0u64;
    while (done as usize) < reqs.len() {
        // Trickle arrivals with random gaps.
        if !pending.is_empty() && rng.chance(0.6) {
            let mut r = *pending.last().unwrap();
            r.arrival = now;
            if ctrl.enqueue(r) {
                pending.pop();
            }
        }
        done += ctrl.tick(now).len() as u64;
        now += 1;
        assert!(now < 10_000_000, "controller wedged");
    }
    (done, now)
}

#[test]
fn all_requests_complete_exactly_once() {
    forall(25, |rng| {
        let reqs = random_traffic(rng, 60);
        let (done, _) =
            run(&reqs, TimingParams::ddr3_standard(), RowPolicy::Open, rng);
        assert_eq!(done, reqs.len() as u64);
    });
}

#[test]
fn closed_policy_also_conserves() {
    forall(15, |rng| {
        let reqs = random_traffic(rng, 40);
        let (done, _) =
            run(&reqs, TimingParams::ddr3_standard(), RowPolicy::Closed, rng);
        assert_eq!(done, reqs.len() as u64);
    });
}

#[test]
fn faster_timings_never_slow_the_drain() {
    // Same traffic and arrival pattern: AL-DRAM timings must finish the
    // batch no later than the standard (modulo refresh phase, hence 2%).
    forall(15, |rng| {
        let reqs = random_traffic(rng, 50);
        let mut rng_a = Rng::new(rng.next_u64());
        let mut rng_b = rng_a.clone();
        let (_, base) = run(&reqs, TimingParams::ddr3_standard(),
                            RowPolicy::Open, &mut rng_a);
        let fast_t =
            TimingParams::ddr3_standard().reduced(0.27, 0.32, 0.33, 0.18);
        let (_, fast) = run(&reqs, fast_t, RowPolicy::Open, &mut rng_b);
        assert!(fast as f64 <= base as f64 * 1.02,
                "fast {fast} vs base {base}");
    });
}

#[test]
fn random_timing_reductions_are_protocol_safe() {
    // Any legal reduced timing set keeps the bank FSM consistent (the
    // debug_asserts inside issue_* fire on violation in test builds).
    forall(20, |rng| {
        let std = TimingParams::ddr3_standard();
        let t = std.reduced(
            rng.range(0.0, 0.5),
            rng.range(0.0, 0.4),
            rng.range(0.0, 0.6),
            rng.range(0.0, 0.5),
        );
        let reqs = random_traffic(rng, 30);
        let (done, _) = run(&reqs, t, RowPolicy::Open, rng);
        assert_eq!(done, 30);
    });
}

#[test]
fn address_map_roundtrips_under_random_addresses() {
    forall(200, |rng| {
        for ranks in [1usize, 2] {
            let m = AddrMap::ddr3_2gb(ranks);
            let addr = (rng.next_u64() % m.capacity_bytes()) & !63;
            let d = m.decode(addr);
            assert_eq!(m.encode(&d), addr);
            assert!(d.bank < m.banks());
            assert!(d.rank < m.ranks());
        }
    });
}
