//! Conformance matrix for the independent protocol checker (DESIGN.md
//! §13). Four legs:
//!
//! * real simulations — workloads and adversarial fuzz — audit
//!   violation-free under BOTH drivers, and the two drivers audit the
//!   same number of commands (the conformance leg of the run/run_fast
//!   equivalence matrix);
//! * refresh x region interactions: the tRFC fence against per-region
//!   tRP/tRCD at the refresh boundary, scaled-refresh cadence, and
//!   refresh while a page-placement remap is active;
//! * the command-trace round trip: capture to an ALCT file, replay it
//!   offline, same audit verdict;
//! * the full gate-mutation sensitivity sweep: a clean baseline and
//!   every seeded controller mutant detected.

use aldram::aldram::AlDram;
use aldram::check::cmd_trace;
use aldram::check::mutate::{self, DEFAULT_CYCLES};
use aldram::check::{CheckSummary, Constraint, N_CONSTRAINTS};
use aldram::exec;
use aldram::mem::{AddrMap, ChannelConfig, RegionRemap, System, SystemConfig,
                  SystemStats};
use aldram::timing::TimingParams;
use aldram::workloads::fuzz::FuzzSource;
use aldram::workloads::{by_name, NamedSource};

const CYCLES: u64 = 30_000;

fn fast_timings() -> TimingParams {
    TimingParams::ddr3_standard().reduced(0.27, 0.32, 0.33, 0.18)
}

fn fuzz_sources(map: AddrMap, seed: &str) -> Vec<NamedSource> {
    (0..2)
        .map(|i| FuzzSource::named(map, &format!("{seed}/{i}")))
        .collect()
}

/// Run the same config + sources under the cycle-stepped oracle and the
/// time-skip driver, checker attached to both. Asserts both audits are
/// violation-free, both drivers audited the *same command count*, and
/// the visible stats agree; returns the (shared) audit summary.
fn audit_both(label: &str, cfg: &SystemConfig, map: AddrMap, seed: &str,
              cycles: u64, refresh_scale: Option<f64>)
              -> (SystemStats, CheckSummary) {
    let run = |fast: bool| {
        let mut sys = System::with_sources_map(cfg, map,
                                               fuzz_sources(map, seed));
        sys.enable_check();
        if let Some(s) = refresh_scale {
            sys.set_refresh_scale(s);
        }
        let stats = if fast { sys.run_fast(cycles) } else { sys.run(cycles) };
        let sum = sys.check_summary().expect("checker was attached");
        (stats, sum)
    };
    let (sa, ka) = run(false);
    let (sb, kb) = run(true);
    assert_eq!(ka.violations, 0, "{label}/step: {}", ka.line());
    assert_eq!(kb.violations, 0, "{label}/fast: {}", kb.line());
    assert_eq!(ka.commands, kb.commands,
               "{label}: drivers audited different command counts");
    assert_eq!(ka.checks, kb.checks,
               "{label}: drivers exercised constraints differently");
    assert_eq!(sa.reads_done, sb.reads_done, "{label}: reads diverged");
    assert_eq!(sa.writes_done, sb.writes_done, "{label}: writes diverged");
    assert_eq!(sa.refreshes, sb.refreshes, "{label}: refreshes diverged");
    assert!(ka.commands > 1_000, "{label}: audit saw only {} commands",
            ka.commands);
    (sa, ka)
}

fn exercised(sum: &CheckSummary, c: Constraint) -> bool {
    sum.checks[c as usize] > 0
}

#[test]
fn refresh_against_region_table() {
    // The adversarial region grid (fast low rows, standard high rows)
    // under default refresh cadence: the tRFC fence must compose with
    // per-region tRP/tRCD at every refresh boundary — an ACT right after
    // REF is gated by tRFC even when its region's own tRCD/tRP windows
    // have long expired, and the checker resolves the post-refresh ACT
    // against the *region's* set, not the module collapse.
    let cfg = SystemConfig::uniform(
        1, ChannelConfig::profiled_regions(mutate::harness_table(), 55.0));
    let (stats, sum) = audit_both("refresh-x-region", &cfg,
                                  AddrMap::ddr3_2gb(1), "rxr", CYCLES, None);
    assert!(stats.refreshes > 0, "no refreshes in {} cycles", stats.cycles);
    for c in [Constraint::Trfc, Constraint::Trefi, Constraint::Trcd,
              Constraint::Trp, Constraint::Tras] {
        assert!(exercised(&sum, c), "{} never exercised", c.name());
    }
    assert!(sum.region_hits.iter().filter(|&&h| h > 0).count() > 1,
            "audit resolved only one region: {:?}", sum.region_hits);
}

#[test]
fn scaled_refresh_against_region_table() {
    // 10x refresh frequency: every bank sees a refresh boundary every
    // ~624 cycles, so fuzz traffic constantly straddles the tRFC fence
    // while region lookups stay active. Both drivers, zero violations,
    // and the audit must have checked the scaled tREFI cadence.
    let cfg = SystemConfig::uniform(
        1, ChannelConfig::profiled_regions(mutate::harness_table(), 55.0));
    let (stats, sum) = audit_both("scaled-refresh-x-region", &cfg,
                                  AddrMap::ddr3_2gb(1), "srr", CYCLES,
                                  Some(0.1));
    assert!(stats.refreshes > 20,
            "scaled refresh barely fired: {}", stats.refreshes);
    assert!(exercised(&sum, Constraint::Trfc));
    assert!(exercised(&sum, Constraint::Trefi));
}

#[test]
fn refresh_while_placement_remap_active() {
    // Region table + page-placement remap: logical rows are permuted so
    // the fast region fills first, while refresh keeps fencing banks.
    // The checker sees *physical* rows (commands are post-decode), so
    // region resolution must stay correct through the remap.
    // An explicit non-identity permutation: the harness table's fast
    // region is already first, so `fastest_first` would be the identity.
    let table = mutate::harness_table();
    let base = AddrMap::ddr3_2gb(1);
    let map = base.with_remap(RegionRemap::new(base.row_bits, &[1, 0]));
    let cfg = SystemConfig::uniform(
        1, ChannelConfig::profiled_regions(table, 55.0));
    let (stats, sum) = audit_both("refresh-x-remap", &cfg, map, "rxm",
                                  CYCLES, None);
    assert!(stats.refreshes > 0);
    assert!(sum.region_hits.iter().filter(|&&h| h > 0).count() > 1,
            "remap collapsed the audit onto one region: {:?}",
            sum.region_hits);
}

#[test]
fn fuzz_property_zero_violations_across_table_shapes() {
    // The property: for every seed and every table shape — uniform
    // standard, uniform AL-DRAM, region-indexed, region + placement —
    // the controller's command stream conforms. Each leg runs both
    // drivers (audit_both) at a shorter horizon to bound test time.
    let base = AddrMap::ddr3_2gb(1);
    let cycles = 12_000;
    for seed in ["p0", "p1", "p2"] {
        let uniform = SystemConfig::paper_default();
        audit_both(&format!("prop/{seed}/uniform"), &uniform, base, seed,
                   cycles, None);

        let aldram = SystemConfig::uniform(
            1, ChannelConfig::profiled(AlDram::fixed(fast_timings()), 55.0));
        audit_both(&format!("prop/{seed}/aldram"), &aldram, base, seed,
                   cycles, None);

        let table = mutate::harness_table();
        let region = SystemConfig::uniform(
            1, ChannelConfig::profiled_regions(table.clone(), 55.0));
        audit_both(&format!("prop/{seed}/region"), &region, base, seed,
                   cycles, None);

        let map = base.with_remap(RegionRemap::new(base.row_bits, &[1, 0]));
        audit_both(&format!("prop/{seed}/region+placement"), &region, map,
                   seed, cycles, None);
    }
}

#[test]
fn workload_simulations_audit_clean() {
    // Not just fuzz: the suite workloads the figures actually run must
    // audit clean too, on both a standard and an AL-DRAM system.
    for (label, cfg) in [
        ("std", SystemConfig::paper_default()),
        ("aldram", SystemConfig::paper_default()
             .with_timings(fast_timings())),
    ] {
        for wname in ["stream.copy", "gups", "mcf"] {
            let w = by_name(wname).unwrap();
            let sources = (0..2)
                .map(|i| w.named_source(&format!("chk/{label}/{i}")))
                .collect();
            let mut sys = System::with_sources(&cfg, sources);
            sys.enable_check();
            sys.run_fast(CYCLES);
            let sum = sys.check_summary().unwrap();
            assert_eq!(sum.violations, 0, "{label}/{wname}: {}", sum.line());
            assert!(sum.commands > 0);
        }
    }
}

#[test]
fn cmd_trace_capture_replay_round_trip() {
    // Capture the command stream of a region-table fuzz run to an ALCT
    // file, then audit it offline: same command count as a live audit of
    // the identical run, zero violations, and the header geometry
    // round-trips.
    let map = AddrMap::ddr3_2gb(1);
    let cfg = SystemConfig::uniform(
        1, ChannelConfig::profiled_regions(mutate::harness_table(), 55.0));

    // Live audit (reference command count).
    let mut live = System::with_sources_map(&cfg, map,
                                            fuzz_sources(map, "cap"));
    live.enable_check();
    live.run_fast(CYCLES);
    let live_sum = live.check_summary().unwrap();
    assert_eq!(live_sum.violations, 0, "{}", live_sum.line());

    // Captured run (same sources, tap instead of checker).
    let path = std::env::temp_dir()
        .join(format!("alct_it_{}.alct", std::process::id()));
    let mut sys = System::with_sources_map(&cfg, map,
                                           fuzz_sources(map, "cap"));
    let tck = sys.controllers()[0].tck_ns();
    let w = cmd_trace::create_shared(map.ranks(), map.banks(), map.row_bits,
                                     tck);
    sys.attach_cmd_tap(0, w.clone());
    sys.run_fast(CYCLES);
    drop(sys);
    let n = cmd_trace::finish_shared(w, &path).unwrap();
    assert!(n > 0);

    let info = cmd_trace::info(&path).unwrap();
    assert_eq!((info.ranks, info.banks, info.row_bits),
               (map.ranks(), map.banks(), map.row_bits));
    assert_eq!(info.commands, live_sum.commands,
               "offline trace carries a different command count than the \
                live audit of the same run");
    assert!(info.region_updates > 0, "region install was not captured");

    let sum = cmd_trace::replay_summary(&path).unwrap();
    assert_eq!(sum.violations, 0, "offline audit: {}", sum.line());
    assert_eq!(sum.commands, live_sum.commands);
    assert_eq!(sum.checks, live_sum.checks,
               "offline audit exercised constraints differently");
    std::fs::remove_file(&path).ok();
}

#[test]
fn mutation_sweep_every_mutant_detected() {
    // The full sensitivity sweep the CI gate runs: one clean baseline
    // plus every seeded controller-gate mutant, each audited over
    // DEFAULT_CYCLES of adversarial traffic. 100% detection required.
    let report = mutate::run_harness(DEFAULT_CYCLES, "it",
                                     exec::default_jobs());
    assert!(report.results.len() >= 10,
            "only {} mutants", report.results.len());
    // The clean baseline must also prove the coverage matrix is full:
    // every constraint the checker knows was exercised at least once.
    assert_eq!(report.baseline.exercised(), N_CONSTRAINTS,
               "baseline left constraints unexercised: {}",
               report.baseline.line());
    for r in &report.results {
        assert!(r.detected(), "mutant {:?} escaped ({} commands audited)",
                r.mutation, r.commands);
    }
    report.require_all_detected().unwrap();
}
