//! Time-skip equivalence matrix: the event-driven `System::run_fast`
//! driver must produce *bit-identical* statistics to the cycle-stepped
//! oracle `System::run` — every core counter, every controller counter,
//! every derived float — across row policies, core counts, channel
//! counts, AL-DRAM management, scaled refresh, and reduced timing sets.
//! (The Python mirror harness carries the same matrix in
//! `.claude/skills/verify/mirror/timeskip_checks.py`.)

use aldram::aldram::{AlDram, RegionTable};
use aldram::mem::{AddrMap, ChannelConfig, RegionRemap, RowPolicy, System,
                  SystemConfig, SystemStats};
use aldram::timing::TimingParams;
use aldram::workloads::by_name;

fn fast_timings() -> TimingParams {
    TimingParams::ddr3_standard().reduced(0.27, 0.32, 0.33, 0.18)
}

fn workload_list(names: &[(&str, usize)]) -> Vec<(aldram::workloads::WorkloadSpec, String)> {
    let mut wl = Vec::new();
    for (name, cores) in names {
        let w = by_name(name).unwrap();
        for i in 0..*cores {
            wl.push((w.clone(), format!("ts/{i}")));
        }
    }
    wl
}

/// Field-by-field equality, floats compared exactly: the two drivers must
/// walk the same state trajectory, so even the derived ratios match to
/// the last bit.
fn assert_stats_identical(label: &str, a: &SystemStats, b: &SystemStats) {
    assert_eq!(a.cycles, b.cycles, "{label}: cycles");
    assert_eq!(a.reads_done, b.reads_done, "{label}: reads_done");
    assert_eq!(a.writes_done, b.writes_done, "{label}: writes_done");
    assert_eq!(a.refreshes, b.refreshes, "{label}: refreshes");
    assert_eq!(a.avg_read_latency_cycles, b.avg_read_latency_cycles,
               "{label}: avg_read_latency");
    assert_eq!(a.row_hit_rate, b.row_hit_rate, "{label}: row_hit_rate");
    assert_eq!(a.bus_utilization, b.bus_utilization,
               "{label}: bus_utilization");
    assert_eq!(a.mean_temp_c, b.mean_temp_c, "{label}: mean_temp_c");
    assert_eq!(a.final_temp_c, b.final_temp_c, "{label}: final_temp_c");
    assert_eq!(a.channels.len(), b.channels.len(), "{label}: channel count");
    for (i, (ha, hb)) in a.channels.iter().zip(&b.channels).enumerate() {
        assert_eq!(ha.reads_done, hb.reads_done, "{label}/ch{i}: reads");
        assert_eq!(ha.writes_done, hb.writes_done, "{label}/ch{i}: writes");
        assert_eq!(ha.avg_read_latency_cycles, hb.avg_read_latency_cycles,
                   "{label}/ch{i}: read latency");
        assert_eq!(ha.mean_temp_c, hb.mean_temp_c, "{label}/ch{i}: mean temp");
        assert_eq!(ha.final_temp_c, hb.final_temp_c,
                   "{label}/ch{i}: final temp");
        assert_eq!(ha.timing_switches, hb.timing_switches,
                   "{label}/ch{i}: timing switches");
    }
    assert_eq!(a.cores.len(), b.cores.len(), "{label}: core count");
    for (ca, cb) in a.cores.iter().zip(&b.cores) {
        assert_eq!(ca.insts, cb.insts, "{label}/{}: insts", ca.name);
        assert_eq!(ca.ipc, cb.ipc, "{label}/{}: ipc", ca.name);
        assert_eq!(ca.reads, cb.reads, "{label}/{}: reads", ca.name);
        assert_eq!(ca.writes, cb.writes, "{label}/{}: writes", ca.name);
        assert_eq!(ca.stall_cycles, cb.stall_cycles,
                   "{label}/{}: stall_cycles", ca.name);
    }
    for (i, (pa, pb)) in
        a.power_inputs.iter().zip(&b.power_inputs).enumerate()
    {
        assert_eq!(pa.n_act, pb.n_act, "{label}/ch{i}: n_act");
        assert_eq!(pa.n_read, pb.n_read, "{label}/ch{i}: n_read");
        assert_eq!(pa.n_write, pb.n_write, "{label}/ch{i}: n_write");
        assert_eq!(pa.n_refresh, pb.n_refresh, "{label}/ch{i}: n_refresh");
        assert_eq!(pa.open_bank_cycles, pb.open_bank_cycles,
                   "{label}/ch{i}: open_bank_cycles");
    }
}

fn check(label: &str, cfg: &SystemConfig, names: &[(&str, usize)],
         cycles: u64, refresh_scale: Option<f64>) {
    check_with_map(label, cfg, AddrMap::ddr3_2gb(cfg.ranks_per_channel),
                   names, cycles, refresh_scale);
}

fn check_with_map(label: &str, cfg: &SystemConfig, map: AddrMap,
                  names: &[(&str, usize)], cycles: u64,
                  refresh_scale: Option<f64>) {
    let wl = workload_list(names);
    let mut oracle = System::new_with_map(cfg, map, &wl);
    let mut fast = System::new_with_map(cfg, map, &wl);
    if let Some(s) = refresh_scale {
        oracle.set_refresh_scale(s);
        fast.set_refresh_scale(s);
    }
    let sa = oracle.run(cycles);
    let sb = fast.run_fast(cycles);
    assert_stats_identical(label, &sa, &sb);
    // The raw per-channel controller counters too (issued/busy cycles and
    // the row-stat split are not all visible through SystemStats).
    for (i, (ca, cb)) in oracle
        .controllers()
        .iter()
        .zip(fast.controllers())
        .enumerate()
    {
        assert_eq!(ca.stats, cb.stats, "{label}/ch{i}: CtrlStats");
    }
}

const CYCLES: u64 = 30_000;

#[test]
fn open_policy_single_core_streams() {
    let cfg = SystemConfig::paper_default();
    check("open/1core/stream.copy", &cfg, &[("stream.copy", 1)], CYCLES,
          None);
    check("open/1core/mcf", &cfg, &[("mcf", 1)], CYCLES, None);
    check("open/1core/gups", &cfg, &[("gups", 1)], CYCLES, None);
    check("open/1core/povray", &cfg, &[("povray", 1)], CYCLES, None);
}

#[test]
fn open_policy_multicore() {
    let cfg = SystemConfig::paper_default();
    check("open/4core/stream.copy", &cfg, &[("stream.copy", 4)], CYCLES,
          None);
    check("open/mix", &cfg, &[("mcf", 1), ("gups", 1), ("hmmer", 2)],
          CYCLES, None);
}

#[test]
fn closed_policy() {
    let cfg = SystemConfig { policy: RowPolicy::Closed,
                             ..SystemConfig::paper_default() };
    check("closed/4core/gups", &cfg, &[("gups", 4)], CYCLES, None);
    check("closed/1core/libquantum", &cfg, &[("libquantum", 1)], CYCLES,
          None);
}

#[test]
fn multi_channel() {
    let cfg = SystemConfig::paper_default().with_channels(2);
    check("2ch/4core/stream.add", &cfg, &[("stream.add", 4)], CYCLES, None);
}

#[test]
fn heterogeneous_channels() {
    // Distinct DIMM identity per channel: different fixed AL-DRAM tables
    // *and* different ambient temperatures. The per-channel thermal and
    // timing-switch trajectories must stay bit-identical across drivers.
    let slower = TimingParams::ddr3_standard()
        .reduced(0.10, 0.12, 0.15, 0.08);
    let cfg = SystemConfig {
        channels: vec![
            ChannelConfig {
                timings: TimingParams::ddr3_standard(),
                aldram: Some(RegionTable::uniform(
                    AlDram::fixed(fast_timings()))),
                ambient_c: 30.0,
            },
            ChannelConfig {
                timings: TimingParams::ddr3_standard(),
                aldram: Some(RegionTable::uniform(AlDram::fixed(slower))),
                ambient_c: 70.0,
            },
        ],
        ranks_per_channel: 1,
        policy: RowPolicy::Open,
    };
    check("hetero-ch/4core/gups", &cfg, &[("gups", 4)], CYCLES, None);
    check("hetero-ch/mix", &cfg, &[("stream.copy", 2), ("mcf", 2)], CYCLES,
          None);
}

#[test]
fn aldram_managed() {
    let cfg = SystemConfig::paper_default()
        .with_aldram(Some(AlDram::fixed(fast_timings())))
        .with_ambient(30.0);
    check("aldram/4core/stream.copy", &cfg, &[("stream.copy", 4)], CYCLES,
          None);
}

/// A deliberately non-uniform region grid: 8 banks x 2 row regions,
/// region 0 fast and region 1 slower, with a per-bank wobble so banks
/// differ too.
fn region_grid() -> RegionTable {
    let entries: Vec<AlDram> = (0..16)
        .map(|i| {
            let (bank, region) = (i / 2, i % 2);
            let f = 1.0 - 0.02 * bank as f64;
            let t = if region == 0 {
                fast_timings().with_core(
                    fast_timings().trcd_ns * f,
                    fast_timings().tras_ns * f,
                    fast_timings().twr_ns * f,
                    fast_timings().trp_ns * f,
                )
            } else {
                TimingParams::ddr3_standard()
                    .reduced(0.10, 0.12, 0.15, 0.08)
            };
            AlDram::fixed(t)
        })
        .collect();
    RegionTable::from_regions(8, 2, entries).unwrap()
}

#[test]
fn region_indexed_timing() {
    // Region-granular tables: ACT/PRE/WR deadlines now depend on the
    // decoded row's region, exercising the per-row timing lookup in both
    // drivers. The stats must stay bit-identical.
    let cfg = SystemConfig::paper_default()
        .with_region_table(Some(region_grid()))
        .with_ambient(30.0);
    check("regions/4core/gups", &cfg, &[("gups", 4)], CYCLES, None);
    check("regions/mix", &cfg, &[("stream.copy", 2), ("mcf", 2)], CYCLES,
          None);
}

#[test]
fn region_placement_remap() {
    // Variation-aware page placement on top of region timing: the remap
    // permutes row regions inside `decode`, so the drivers must agree on
    // the remapped trajectory too.
    let table = region_grid();
    let map = AddrMap::ddr3_2gb(1);
    let map = map.with_remap(RegionRemap::fastest_first(&table,
                                                        map.row_bits));
    let cfg = SystemConfig::paper_default()
        .with_region_table(Some(table))
        .with_ambient(30.0);
    check_with_map("regions-remap/4core/gups", &cfg, map, &[("gups", 4)],
                   CYCLES, None);
}

#[test]
fn reduced_timing_set() {
    let cfg = SystemConfig::paper_default().with_timings(fast_timings());
    check("fast-timings/2core/milc", &cfg, &[("milc", 2)], CYCLES, None);
}

#[test]
fn scaled_refresh() {
    let cfg = SystemConfig::paper_default();
    check("refscale2/1core/hmmer", &cfg, &[("hmmer", 1)], CYCLES,
          Some(2.0));
    check("refscale05/1core/gups", &cfg, &[("gups", 1)], CYCLES, Some(0.5));
}

#[test]
fn epoch_resumed_runs_stay_identical() {
    // eval::stress drives the same system through many run() epochs; the
    // fast driver must resume mid-stream without drift.
    let cfg = SystemConfig::paper_default();
    let wl = workload_list(&[("stream.copy", 1)]);
    let mut oracle = System::new(&cfg, &wl);
    let mut fast = System::new(&cfg, &wl);
    for epoch in 0..4 {
        let sa = oracle.run(8_000);
        let sb = fast.run_fast(8_000);
        assert_stats_identical(&format!("epoch{epoch}"), &sa, &sb);
    }
}
