//! Integration: population -> profiler -> AL-DRAM table, end to end on the
//! native backend (the PJRT path is covered by runtime_native_xcheck).

use aldram::aldram::AlDram;
use aldram::model::params;
use aldram::population::{generate_dimm, generate_population};
use aldram::profiler::{profile_dimm, summarize, verify_timings};
use aldram::runtime::NativeBackend;
use aldram::timing::TimingParams;

#[test]
fn every_module_meets_ddr3_spec() {
    // DDR3 compliance across a population slice: standard timings at 64 ms
    // and 85degC are error-free for every DIMM (the manufacturers' bar).
    let mut b = NativeBackend::new();
    let std = TimingParams::ddr3_standard();
    for id in (0..params().population.n_dimms).step_by(7) {
        let d = generate_dimm(id, 128, params());
        let ok = verify_timings(&mut b, &d, &std, 85.0, 64.0, 64.0).unwrap();
        assert!(ok, "dimm {id} violates the DDR3 standard");
    }
}

#[test]
fn population_statistics_match_paper_shape() {
    // Small-resolution campaign over a population slice: the paper's
    // orderings must hold (full-resolution numbers live in EXPERIMENTS.md).
    let mut b = NativeBackend::new();
    let profiles: Vec<_> = (0..10)
        .map(|id| {
            let d = generate_dimm(id, 128, params());
            profile_dimm(&mut b, &d).unwrap()
        })
        .collect();
    let s = summarize(&profiles);

    // 55C allows more reduction than 85C, for both tests.
    assert!(s.read_reduction_55 > s.read_reduction_85);
    assert!(s.write_reduction_55 > s.write_reduction_85);
    // Write test allows more total reduction than read (Fig 3d vs 3c).
    assert!(s.write_reduction_55 > s.read_reduction_55);
    assert!(s.write_reduction_85 > s.read_reduction_85);
    // tWR has the largest single-parameter potential at 55C; tRCD smallest
    // (paper: 54.8% vs 17.3%).
    let p55 = s.param_reduction_55;
    assert!(p55[2] > p55[0] && p55[2] > p55[3], "{p55:?}");
    assert!(p55[1] > p55[0], "{p55:?}");
    // Everything positive and sane.
    for x in p55 {
        assert!((0.0..0.75).contains(&x), "{p55:?}");
    }
}

#[test]
fn vendors_differ_in_retention() {
    // The three synthetic vendors have distinct leakage distributions;
    // their module-max refresh intervals must separate statistically.
    let mut b = NativeBackend::new();
    let pop = generate_population(64);
    let mut by_vendor: std::collections::BTreeMap<String, Vec<f64>> =
        Default::default();
    for d in pop.iter().take(40) {
        let r = aldram::profiler::profile_refresh(&mut b, &d.arrays, 85.0)
            .unwrap();
        by_vendor
            .entry(d.vendor.clone())
            .or_default()
            .push(r.module_max_read_ms);
    }
    let means: Vec<f64> = by_vendor
        .values()
        .map(|v| v.iter().sum::<f64>() / v.len() as f64)
        .collect();
    assert!(by_vendor.len() == 3, "all vendors present in first 40 dimms");
    let spread = means.iter().cloned().fold(f64::MIN, f64::max)
        - means.iter().cloned().fold(f64::MAX, f64::min);
    assert!(spread > 10.0, "vendor retention means too close: {means:?}");
}

#[test]
fn aldram_table_is_safe_across_its_bins() {
    // Build a table from a profile, then verify every bin's timing set
    // against the charge model at that bin's temperature.
    let mut b = NativeBackend::new();
    let d = generate_dimm(2, 128, params());
    let prof = profile_dimm(&mut b, &d).unwrap();
    let table = AlDram::from_profile(&prof, 5.0);
    for temp in [30.0, 45.0, 55.0, 60.0, 70.0, 80.0, 85.0] {
        let t = table.timings_for(temp);
        let ok = verify_timings(
            &mut b, &d, &t, temp.max(55.0),
            prof.at55.tref_read_ms, prof.at55.tref_write_ms,
        )
        .unwrap();
        assert!(ok, "table timings unsafe at {temp}C: {t:?}");
    }
}
