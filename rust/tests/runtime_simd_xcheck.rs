//! The vectorized-engine equivalence matrix: `SimdBackend` (lane-chunked
//! kernel + guard-band exact fallback) vs `NativeBackend` (the scalar
//! oracle, itself bit-identical to the AOT artifact). Mirrors the
//! PR-2 run/run_fast methodology: the fast engine must reproduce the
//! oracle's *decisions* exactly — error counts bit-equal, margins within
//! the documented guard band, sweep frontiers pair-for-pair identical —
//! across random populations, random combos (sentinels included), all
//! three pass criteria, and warm-started sweeps from both directions.

use aldram::model::charge::Cell;
use aldram::model::profile_simd::GUARD;
use aldram::model::{params, CellArrays, Combo};
use aldram::population::generate_dimm;
use aldram::profiler::{profile_dimm, sweep, sweep_exhaustive, sweep_seeded,
                       TestKind};
use aldram::runtime::{NativeBackend, PassCriterion, ProbeKind,
                      ProfilingBackend, SimdBackend};
use aldram::util::quick::forall;
use aldram::util::rng::Rng;

fn rand_cell(rng: &mut Rng) -> Cell {
    Cell {
        qcap: rng.range(0.7, 1.2) as f32,
        tau_s: rng.lognormal(1.6, 0.2) as f32,
        tau_r: rng.lognormal(2.2, 0.3) as f32,
        tau_p: rng.lognormal(0.5, 0.1) as f32,
        lam85: rng.lognormal(-7.3, 0.6) as f32,
    }
}

fn rand_combo(rng: &mut Rng) -> Combo {
    Combo {
        trcd: rng.range(3.0, 13.75) as f32,
        tras: rng.range(12.0, 35.0) as f32,
        twr: rng.range(3.0, 15.0) as f32,
        trp: rng.range(3.0, 13.75) as f32,
        tref_ms: rng.range(8.0, 512.0) as f32,
        temp_c: rng.range(25.0, 85.0) as f32,
    }
}

/// Random population with a *manually filled* CellArrays: exercises the
/// no-screening fallback and non-multiple-of-LANES cell counts.
fn rand_arrays(rng: &mut Rng, banks: usize, chips: usize, cells: usize)
               -> CellArrays {
    let mut a = CellArrays::zeroed(banks, chips, cells);
    for i in 0..a.len() {
        a.set(i, rand_cell(rng));
    }
    a
}

fn rand_batch(rng: &mut Rng, n: usize) -> Vec<Combo> {
    let mut v: Vec<Combo> = (0..n).map(|_| rand_combo(rng)).collect();
    // A sentinel somewhere in the batch, as the PJRT padding produces.
    let slot = rng.below(n as u64) as usize;
    v[slot] = Combo::sentinel();
    v
}

#[test]
fn simd_error_counts_exactly_match_native() {
    forall(25, |rng| {
        // Cell counts straddle the LANES=8 chunking (remainder paths).
        let cells = 17 + rng.below(40) as usize;
        let arrays = rand_arrays(rng, 2, 2, cells);
        let combos = rand_batch(rng, 8);
        let a = SimdBackend::new().profile(&arrays, &combos).unwrap();
        let b = NativeBackend::new().profile(&arrays, &combos).unwrap();
        assert_eq!(a.err_r, b.err_r, "per-(combo,bank,chip) read counts");
        assert_eq!(a.err_w, b.err_w, "per-(combo,bank,chip) write counts");
        assert_eq!(a.tot_r, b.tot_r);
        assert_eq!(a.tot_w, b.tot_w);
        for (x, y) in a.mmin_r.iter().zip(&b.mmin_r) {
            assert!((x - y).abs() <= GUARD, "mmin_r {x} vs {y}");
        }
        for (x, y) in a.mmin_w.iter().zip(&b.mmin_w) {
            assert!((x - y).abs() <= GUARD, "mmin_w {x} vs {y}");
        }
    });
}

#[test]
fn simd_matches_native_on_generated_dimms() {
    // The realistic path: vendor-shifted lognormal populations with the
    // weak-cell mixture tail and the precomputed screening order.
    let mut simd = SimdBackend::new();
    let mut native = NativeBackend::new();
    forall(6, |rng| {
        let id = rng.below(115) as usize;
        let d = generate_dimm(id, 64, params());
        let combos = rand_batch(rng, 12);
        let a = simd.profile(&d.arrays, &combos).unwrap();
        let b = native.profile(&d.arrays, &combos).unwrap();
        assert_eq!(a.err_r, b.err_r, "dimm {id}");
        assert_eq!(a.err_w, b.err_w, "dimm {id}");
        for (x, y) in a.mmin_r.iter().zip(&b.mmin_r) {
            assert!((x - y).abs() <= GUARD);
        }
    });
}

#[test]
fn pass_probe_matches_profile_for_all_three_criteria() {
    let mut simd = SimdBackend::new();
    let mut native = NativeBackend::new();
    let mut case = 0usize;
    forall(8, |rng| {
        // Alternate between generated populations (screening order
        // present) and manual ones (empty screen -> array-order fallback).
        case += 1;
        let arrays = if case % 2 == 0 {
            generate_dimm(rng.below(50) as usize, 48, params()).arrays
        } else {
            rand_arrays(rng, 8, 2, 48)
        };
        let combos = rand_batch(rng, 10);
        let criteria = [
            PassCriterion::Module { budget: 0.0 },
            PassCriterion::Module { budget: rng.below(64) as f64 },
            PassCriterion::Bank { bank: rng.below(8) as usize },
        ];
        for kind in [ProbeKind::Read, ProbeKind::Write] {
            for criterion in criteria {
                let fast = simd
                    .pass_probe(&arrays, &combos, kind, criterion)
                    .unwrap();
                let oracle = native
                    .pass_probe(&arrays, &combos, kind, criterion)
                    .unwrap();
                assert_eq!(fast, oracle, "{kind:?} {criterion:?}");
            }
        }
    });
}

#[test]
fn probed_warm_sweep_matches_exhaustive_oracle() {
    // The acceptance contract: sweeps with pass_probe + warm start enabled
    // (the SimdBackend path) stay pair-for-pair identical to the
    // exhaustive full-grid oracle on the scalar backend.
    let mut simd = SimdBackend::new();
    let mut native = NativeBackend::new();
    for id in [1usize, 11] {
        let d = generate_dimm(id, 96, params());
        for kind in [TestKind::Read, TestKind::Write] {
            let hot = sweep(&mut simd, &d.arrays, kind, 85.0, 200.0).unwrap();
            let warm = sweep_seeded(&mut simd, &d.arrays, kind, 55.0, 200.0,
                                    Some(&hot))
                .unwrap();
            for (s, temp) in [(&hot, 85.0), (&warm, 55.0)] {
                let full =
                    sweep_exhaustive(&mut native, &d.arrays, kind, temp,
                                     200.0)
                        .unwrap();
                assert_eq!(s.frontier.len(), full.frontier.len());
                for (a, o) in s.frontier.iter().zip(&full.frontier) {
                    assert_eq!(a.trcd_ns, o.trcd_ns);
                    assert_eq!(a.trp_ns, o.trp_ns);
                    assert_eq!(
                        a.min_third_ns, o.min_third_ns,
                        "dimm {id} {kind:?} @{temp}C pair ({}, {})",
                        a.trcd_ns, a.trp_ns
                    );
                }
            }
        }
    }
}

#[test]
fn full_dimm_profile_agrees_across_engines() {
    // End-to-end: the whole characterization battery (refresh sweep +
    // warm-started timing sweeps) lands on identical operational timings
    // whichever engine runs it.
    let mut simd = SimdBackend::new();
    let mut native = NativeBackend::new();
    for id in [5usize, 23] {
        let d = generate_dimm(id, 64, params());
        let a = profile_dimm(&mut simd, &d).unwrap();
        let b = profile_dimm(&mut native, &d).unwrap();
        assert_eq!(a.refresh85.module_max_read_ms,
                   b.refresh85.module_max_read_ms);
        assert_eq!(a.refresh85.module_max_write_ms,
                   b.refresh85.module_max_write_ms);
        assert_eq!(a.refresh85.bank_max_read_ms,
                   b.refresh85.bank_max_read_ms);
        assert_eq!(a.at85.combined(), b.at85.combined());
        assert_eq!(a.at55.combined(), b.at55.combined());
    }
}
