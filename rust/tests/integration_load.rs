//! Open-loop equivalence matrix (DESIGN.md §16): with arrival-driven
//! cores, the event-driven `System::run_fast` driver must produce
//! *bit-identical* statistics — every counter, every derived float, and
//! the whole latency histogram (`OpenLoopStats`, `PartialEq` down to
//! the bins) — to the cycle-stepped oracle `System::run`, across
//! {Poisson, bursty, diurnal} arrivals x {uniform, region-indexed}
//! timing. Plus the saturation fail-loud contract (bounded FIFO, halt
//! at the next epoch), arrival-seed determinism, and the
//! shared-stream guarantee that K lockstep consumers see identical
//! arrivals. (The Python mirror carries the same matrix in
//! `.claude/skills/verify/mirror/load_checks.py`.)

use aldram::aldram::AlDram;
use aldram::eval::load::{self, LoadSetup};
use aldram::eval::lockstep::SharedSourceSet;
use aldram::eval::Driver;
use aldram::mem::system::THERMAL_EPOCH;
use aldram::mem::{System, SystemConfig, SystemStats};
use aldram::timing::TimingParams;
use aldram::workloads::arrival::{ArrivalKind, ArrivalSpec};
use aldram::workloads::{by_name, MemRef, NamedSource};

const CYCLES: u64 = 30_000;
const BOUND: usize = 256;

fn kind(name: &str) -> ArrivalKind {
    ArrivalKind::by_name(name).unwrap()
}

fn fast_timings() -> TimingParams {
    TimingParams::ddr3_standard().reduced(0.27, 0.32, 0.33, 0.18)
}

/// A deliberately non-uniform region grid (8 banks x 2 row regions with
/// a per-bank wobble), as in `integration_timeskip`.
fn region_grid() -> aldram::aldram::RegionTable {
    let entries: Vec<AlDram> = (0..16)
        .map(|i| {
            let (bank, region) = (i / 2, i % 2);
            let f = 1.0 - 0.02 * bank as f64;
            let t = if region == 0 {
                fast_timings().with_core(
                    fast_timings().trcd_ns * f,
                    fast_timings().tras_ns * f,
                    fast_timings().twr_ns * f,
                    fast_timings().trp_ns * f,
                )
            } else {
                TimingParams::ddr3_standard()
                    .reduced(0.10, 0.12, 0.15, 0.08)
            };
            AlDram::fixed(t)
        })
        .collect();
    aldram::aldram::RegionTable::from_regions(8, 2, entries).unwrap()
}

fn sources(kind: ArrivalKind, load: f64, cores: usize, seed: &str)
           -> Vec<NamedSource> {
    let spec = ArrivalSpec { kind, load };
    let w = by_name("gups").unwrap();
    (0..cores)
        .map(|c| spec.named_source(&w, &format!("{seed}/core{c}")))
        .collect()
}

fn open_system(cfg: &SystemConfig, kind: ArrivalKind, load: f64,
               cores: usize, seed: &str) -> System {
    let mut sys = System::with_sources(cfg, sources(kind, load, cores, seed));
    sys.set_open_loop(BOUND);
    sys
}

/// Field-by-field bit-exact equality, including the open-loop block
/// (offered/saturated/halted and every histogram bin).
fn assert_stats_identical(label: &str, a: &SystemStats, b: &SystemStats) {
    assert_eq!(a.cycles, b.cycles, "{label}: cycles");
    assert_eq!(a.reads_done, b.reads_done, "{label}: reads_done");
    assert_eq!(a.writes_done, b.writes_done, "{label}: writes_done");
    assert_eq!(a.refreshes, b.refreshes, "{label}: refreshes");
    assert_eq!(a.avg_read_latency_cycles, b.avg_read_latency_cycles,
               "{label}: avg_read_latency");
    assert_eq!(a.row_hit_rate, b.row_hit_rate, "{label}: row_hit_rate");
    assert_eq!(a.bus_utilization, b.bus_utilization,
               "{label}: bus_utilization");
    assert_eq!(a.mean_temp_c, b.mean_temp_c, "{label}: mean_temp_c");
    assert_eq!(a.final_temp_c, b.final_temp_c, "{label}: final_temp_c");
    for (ca, cb) in a.cores.iter().zip(&b.cores) {
        assert_eq!(ca.insts, cb.insts, "{label}/{}: insts", ca.name);
        assert_eq!(ca.ipc, cb.ipc, "{label}/{}: ipc", ca.name);
        assert_eq!(ca.reads, cb.reads, "{label}/{}: reads", ca.name);
        assert_eq!(ca.writes, cb.writes, "{label}/{}: writes", ca.name);
        assert_eq!(ca.stall_cycles, cb.stall_cycles,
                   "{label}/{}: stall_cycles", ca.name);
    }
    assert_eq!(a.open_loop, b.open_loop, "{label}: open-loop block");
    let ol = a.open_loop.as_ref().expect("open-loop stats present");
    assert!(ol.offered >= a.reads_done + a.writes_done,
            "{label}: completions exceed arrivals");
}

fn check(label: &str, cfg: &SystemConfig, kind: ArrivalKind, load: f64) {
    let sa = open_system(cfg, kind, load, 2, "eqv").run(CYCLES);
    let sb = open_system(cfg, kind, load, 2, "eqv").run_fast(CYCLES);
    assert_stats_identical(label, &sa, &sb);
}

#[test]
fn drivers_agree_uniform_timing_all_arrival_kinds() {
    let cfg = SystemConfig::paper_default();
    for name in ["poisson", "bursty", "diurnal"] {
        for load in [0.01, 0.08] {
            check(&format!("uniform/{name}/{load}"), &cfg, kind(name),
                  load);
        }
    }
}

#[test]
fn drivers_agree_region_indexed_timing_all_arrival_kinds() {
    let cfg = SystemConfig::paper_default()
        .with_region_table(Some(region_grid()))
        .with_ambient(30.0);
    for name in ["poisson", "bursty", "diurnal"] {
        check(&format!("regions/{name}"), &cfg, kind(name), 0.05);
    }
}

#[test]
fn drivers_agree_past_saturation() {
    // Past the knee both drivers must latch saturation and halt at the
    // *same* epoch boundary with identical partial stats.
    let cfg = SystemConfig::paper_default();
    let sa = open_system(&cfg, kind("poisson"), 4.0, 1, "sat").run(CYCLES);
    let sb = open_system(&cfg, kind("poisson"), 4.0, 1, "sat")
        .run_fast(CYCLES);
    assert_stats_identical("saturated", &sa, &sb);
    let ol = sa.open_loop.as_ref().unwrap();
    assert!(ol.saturated && ol.halted, "overload must saturate and halt");
}

#[test]
fn saturation_at_twice_the_knee_halts_early() {
    // The fail-loud regression: at 2x the measured knee the run must
    // (a) latch the saturated marker, (b) halt well short of the cycle
    // budget (at an epoch boundary + 1), and (c) never have held more
    // than `bound` queued arrivals — offered stays within completions +
    // in-flight capacity + FIFO bound per core.
    let cfg = SystemConfig::paper_default();
    let setup = LoadSetup {
        workload: by_name("gups").unwrap(),
        kind: kind("poisson"),
        cores: 1,
        cycles: CYCLES,
        seed: "knee".into(),
        bound: BOUND,
    };
    let curve = load::knee_search(&cfg, &setup, 0.1, Driver::TimeSkip);
    assert!(curve.knee > 0.0);
    let stats = open_system(&cfg, kind("poisson"), 2.0 * curve.knee,
                            1, "knee").run_fast(CYCLES);
    let ol = stats.open_loop.as_ref().unwrap();
    assert!(ol.saturated, "2x knee must saturate");
    assert!(ol.halted, "saturation must halt the run");
    assert!(stats.cycles < CYCLES, "halt must cut the budget short");
    assert_eq!((stats.cycles - 1) % THERMAL_EPOCH, 0,
               "halt lands right after an epoch boundary");
    let in_flight_cap = 64; // generous bound on per-core MLP
    assert!(ol.offered
            <= stats.reads_done + stats.writes_done
                + (BOUND + in_flight_cap) as u64,
            "admissions exceeded the bounded-FIFO contract: {} offered, \
             {} done", ol.offered, stats.reads_done + stats.writes_done);
}

#[test]
fn same_seed_is_bit_identical_and_seeds_differ() {
    let cfg = SystemConfig::paper_default();
    let a = open_system(&cfg, kind("bursty"), 0.05, 1, "s1")
        .run_fast(CYCLES);
    let b = open_system(&cfg, kind("bursty"), 0.05, 1, "s1")
        .run_fast(CYCLES);
    assert_stats_identical("same-seed", &a, &b);
    let c = open_system(&cfg, kind("bursty"), 0.05, 1, "s2")
        .run_fast(CYCLES);
    assert!(a.open_loop != c.open_loop
                || a.reads_done != c.reads_done
                || a.cycles != c.cycles,
            "distinct seeds must yield distinct arrival streams");
}

#[test]
fn lockstep_consumers_see_identical_arrival_streams() {
    // The shared-stream guarantee `eval load` rests on: K consumers of
    // one SharedSourceSet yield bit-identical MemRef sequences
    // (addresses AND arrival gaps), so per-table differences are purely
    // the timing tables' doing.
    let shared = SharedSourceSet::new(sources(kind("diurnal"), 0.03,
                                              2, "lk"));
    let mut consumers: Vec<Vec<NamedSource>> =
        (0..3).map(|_| shared.consumer()).collect();
    for core in 0..2 {
        let mut streams: Vec<Vec<MemRef>> = Vec::new();
        for consumer in &mut consumers {
            let mut buf: Vec<MemRef> = Vec::new();
            while buf.len() < 4096 {
                assert!(consumer[core].source.fill(&mut buf) > 0);
            }
            streams.push(buf);
        }
        for s in &streams[1..] {
            assert_eq!(&streams[0], s,
                       "consumers diverged on core {core}'s stream");
        }
    }
}

#[test]
fn chunked_lockstep_run_matches_single_call() {
    // run_point drives systems in LOCKSTEP_CHUNK spans; a chunked
    // run_fast must land on the same stats as one full-length call.
    let cfg = SystemConfig::paper_default();
    let whole = open_system(&cfg, kind("poisson"), 0.05, 1, "ck")
        .run_fast(CYCLES);
    let mut sys = open_system(&cfg, kind("poisson"), 0.05, 1, "ck");
    let mut left = CYCLES;
    while left > 7_000 {
        sys.run_fast(7_000);
        left -= 7_000;
    }
    let chunked = sys.run_fast(left);
    assert_stats_identical("chunked", &whole, &chunked);
}
