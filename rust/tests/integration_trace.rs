//! Trace-subsystem integration matrix (mirrored in
//! `.claude/skills/verify/mirror/source_checks.py`):
//!
//! * record → replay reproduces the recorded run's `SystemStats`
//!   bit-identically, under both the cycle-stepped oracle (`run`) and the
//!   event-driven driver (`run_fast`), for single-core workloads and
//!   multi-programmed mixes;
//! * truncated / corrupt trace files fail loudly at open time;
//! * the DRAMSim3 text format round-trips through files and replays;
//! * the `--seed` contract: same seed ⇒ bit-identical stats, different
//!   seed ⇒ different address streams.

use std::path::{Path, PathBuf};

use aldram::mem::{System, SystemConfig, SystemStats};
use aldram::workloads::{by_name, mix, trace, MemRef, NamedSource,
                        RequestSource, WorkloadSpec};

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("aldram_trace_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Field-by-field bit equality (SystemStats carries floats, so `to_bits`
/// comparisons — the same contract the time-skip equivalence matrix
/// uses).
fn assert_stats_eq(a: &SystemStats, b: &SystemStats) {
    assert_eq!(a.cycles, b.cycles);
    assert_eq!(a.reads_done, b.reads_done);
    assert_eq!(a.writes_done, b.writes_done);
    assert_eq!(a.refreshes, b.refreshes);
    assert_eq!(a.avg_read_latency_cycles.to_bits(),
               b.avg_read_latency_cycles.to_bits());
    assert_eq!(a.row_hit_rate.to_bits(), b.row_hit_rate.to_bits());
    assert_eq!(a.bus_utilization.to_bits(), b.bus_utilization.to_bits());
    assert_eq!(a.mean_temp_c.to_bits(), b.mean_temp_c.to_bits());
    assert_eq!(a.final_temp_c.to_bits(), b.final_temp_c.to_bits());
    assert_eq!(a.cores.len(), b.cores.len());
    for (x, y) in a.cores.iter().zip(&b.cores) {
        assert_eq!(x.name, y.name);
        assert_eq!(x.insts, y.insts);
        assert_eq!(x.ipc.to_bits(), y.ipc.to_bits());
        assert_eq!(x.reads, y.reads);
        assert_eq!(x.writes, y.writes);
        assert_eq!(x.stall_cycles, y.stall_cycles);
    }
    assert_eq!(a.channels.len(), b.channels.len());
    for (x, y) in a.channels.iter().zip(&b.channels) {
        assert_eq!(x.reads_done, y.reads_done);
        assert_eq!(x.writes_done, y.writes_done);
        assert_eq!(x.avg_read_latency_cycles.to_bits(),
                   y.avg_read_latency_cycles.to_bits());
        assert_eq!(x.row_hit_rate.to_bits(), y.row_hit_rate.to_bits());
        assert_eq!(x.mean_temp_c.to_bits(), y.mean_temp_c.to_bits());
        assert_eq!(x.final_temp_c.to_bits(), y.final_temp_c.to_bits());
        assert_eq!(x.timing_switches, y.timing_switches);
    }
    for (x, y) in a.power_inputs.iter().zip(&b.power_inputs) {
        assert_eq!(x.n_act, y.n_act);
        assert_eq!(x.n_read, y.n_read);
        assert_eq!(x.n_write, y.n_write);
        assert_eq!(x.n_refresh, y.n_refresh);
        assert_eq!(x.open_bank_cycles, y.open_bank_cycles);
    }
}

/// Record `sources` for `cycles` and return (recorded stats, refs).
fn record(path: &Path, sources: Vec<NamedSource>, cycles: u64,
          fast: bool) -> (SystemStats, u64) {
    let cfg = SystemConfig::paper_default();
    let mut sys = System::with_sources(&cfg, sources);
    let w = sys.record_to(path).unwrap();
    let stats = if fast { sys.run_fast(cycles) } else { sys.run(cycles) };
    trace::finish_shared(&w).unwrap();
    let n = w.borrow().count();
    (stats, n)
}

fn replay(path: &Path, cycles: u64, fast: bool) -> SystemStats {
    let (_, sources) = trace::open_sources(path).unwrap();
    let cfg = SystemConfig::paper_default();
    let mut sys = System::with_sources(&cfg, sources);
    if fast { sys.run_fast(cycles) } else { sys.run(cycles) }
}

#[test]
fn record_replay_is_bit_identical_single_core() {
    let path = tmp("single.altr");
    let w = by_name("milc").unwrap();
    let cycles = 30_000;
    let (rec, n) = record(&path, vec![w.named_source("trace/0/core0")],
                          cycles, true);
    assert!(n > 0, "nothing recorded");

    let inf = trace::info(&path).unwrap();
    assert_eq!(inf.version, trace::VERSION);
    assert_eq!(inf.streams.len(), 1);
    assert_eq!(inf.streams[0].name, "milc");
    assert_eq!(inf.streams[0].seed, "trace/0/core0");
    assert_eq!(inf.streams[0].footprint, w.footprint);
    assert_eq!(inf.total_refs, n);

    // Replay under both drivers: bit-identical to the recorded run.
    assert_stats_eq(&rec, &replay(&path, cycles, true));
    assert_stats_eq(&rec, &replay(&path, cycles, false));
}

#[test]
fn record_replay_is_bit_identical_for_mixes() {
    let path = tmp("mix.altr");
    let m = mix::mix_by_name("mcf+gobmk").unwrap();
    let cycles = 20_000;
    let (rec, n) = record(&path, m.sources("trace/7"), cycles, true);
    assert!(n > 0);
    let inf = trace::info(&path).unwrap();
    assert_eq!(inf.streams.len(), 4);
    assert_eq!(inf.streams[0].name, "mcf");
    assert_eq!(inf.streams[3].name, "gobmk");
    assert!(inf.per_stream_refs.iter().all(|&c| c > 0),
            "every core's stream recorded: {:?}", inf.per_stream_refs);
    assert_stats_eq(&rec, &replay(&path, cycles, true));
    assert_stats_eq(&rec, &replay(&path, cycles, false));
}

#[test]
fn recording_under_the_cycle_stepped_oracle_matches() {
    // The drivers are bit-identical, so a trace recorded under run()
    // replays identically under run_fast() and vice versa.
    let path = tmp("stepped.altr");
    let w = by_name("libquantum").unwrap();
    let cycles = 15_000;
    let (rec, _) = record(&path, vec![w.named_source("trace/0/core0")],
                          cycles, false);
    assert_stats_eq(&rec, &replay(&path, cycles, true));
}

#[test]
fn replay_past_the_recorded_horizon_idles() {
    let path = tmp("horizon.altr");
    let w = by_name("hmmer").unwrap();
    let (rec, n) = record(&path, vec![w.named_source("trace/0/core0")],
                          10_000, true);
    // Twice the horizon: the source exhausts and the core stalls; no
    // panic, and no more requests than were recorded can be served.
    let long = replay(&path, 20_000, true);
    assert!(long.reads_done + long.writes_done <= n);
    assert!(long.reads_done >= rec.reads_done);
    // The two drivers agree about the exhausted regime too.
    assert_stats_eq(&long, &replay(&path, 20_000, false));
}

#[test]
fn truncated_and_corrupt_traces_fail_loudly() {
    let path = tmp("donor.altr");
    let w = by_name("hmmer").unwrap();
    record(&path, vec![w.named_source("trace/0/core0")], 5_000, true);
    let bytes = std::fs::read(&path).unwrap();
    assert!(bytes.len() > 64);

    let write = |name: &str, b: &[u8]| {
        let p = tmp(name);
        std::fs::write(&p, b).unwrap();
        p
    };

    // Truncated header.
    let p = write("trunc-header.altr", &bytes[..6]);
    assert!(trace::info(&p).is_err());
    assert!(trace::open_sources(&p).is_err());
    // Truncated body (footer cut off).
    let p = write("trunc-body.altr", &bytes[..bytes.len() - 10]);
    assert!(trace::info(&p).is_err());
    // Bad magic.
    let mut m = bytes.clone();
    m[0] = b'X';
    let p = write("bad-magic.altr", &m);
    assert!(trace::info(&p).is_err());
    // Unsupported version.
    let mut v = bytes.clone();
    v[4] = 99;
    let p = write("bad-version.altr", &v);
    assert!(trace::info(&p).is_err());
    // Corrupt footer count.
    let mut c = bytes.clone();
    let at = c.len() - 1;
    c[at] ^= 0x5A;
    let p = write("bad-count.altr", &c);
    assert!(trace::info(&p).is_err());
    // The donor itself still opens.
    assert!(trace::info(&path).is_ok());
}

/// Pull references one at a time out of a batched source.
fn drain(src: &mut dyn RequestSource, n: usize) -> Vec<MemRef> {
    let mut out = Vec::new();
    while out.len() < n {
        if src.fill(&mut out) == 0 {
            break;
        }
    }
    out.truncate(n);
    out
}

#[test]
fn dramsim3_text_roundtrips_through_files_and_replays() {
    let w = by_name("gups").unwrap();
    let want = drain(w.source("text/0").as_mut(), 500);
    let path = tmp("gups.trc");
    {
        let mut f = std::fs::File::create(&path).unwrap();
        trace::write_text(&mut f, want.iter().copied()).unwrap();
    }
    let (count, mut src) = trace::open_text(&path).unwrap();
    assert_eq!(count, 500);
    assert_eq!(src.name, "gups"); // named after the file stem
    let got = drain(src.source.as_mut(), 500);
    assert_eq!(got, want, "gaps/addresses/ops must survive the text form");

    // A text trace is accepted wherever a trace is (open_any sniffs).
    let (inf, sources) = trace::open_any(&path).unwrap();
    assert_eq!(inf.total_refs, 500);
    assert_eq!(sources.len(), 1);
    let cfg = SystemConfig::paper_default();
    let s = System::with_sources(&cfg, sources).run_fast(50_000);
    assert!(s.reads_done > 0);

    // Corrupt text fails loudly at open.
    let bad = tmp("bad.trc");
    std::fs::write(&bad, "0x10 READ 5\n0x20 NOPE 6\n").unwrap();
    assert!(trace::open_text(&bad).is_err());
    assert!(trace::open_any(&bad).is_err());
}

fn seeded_run(spec: &WorkloadSpec, seed: &str, cycles: u64) -> SystemStats {
    // The CLI's seed plumbing in miniature: the --seed label folds into
    // every core's source seed.
    let cfg = SystemConfig::paper_default();
    let src = spec.named_source(&format!("run/{seed}/core0"));
    System::with_sources(&cfg, vec![src]).run_fast(cycles)
}

#[test]
fn same_seed_is_bit_identical_different_seed_is_not() {
    let w = by_name("milc").unwrap();
    let a = seeded_run(&w, "42", 20_000);
    let b = seeded_run(&w, "42", 20_000);
    assert_stats_eq(&a, &b);

    // Different seed ⇒ different address streams (checked directly at
    // the source level) ...
    let sa = drain(w.source("run/42/core0").as_mut(), 64);
    let sb = drain(w.source("run/43/core0").as_mut(), 64);
    assert_ne!(sa, sb, "seed change must move the address stream");
    // ... and (for a memory-intensive workload) visibly different stats.
    let c = seeded_run(&w, "43", 20_000);
    assert_ne!(
        (a.reads_done, a.cores[0].insts, a.avg_read_latency_cycles.to_bits()),
        (c.reads_done, c.cores[0].insts, c.avg_read_latency_cycles.to_bits()),
        "seed change left the run bit-identical"
    );
}

#[test]
fn mix_weighted_speedup_accounting() {
    // The weighted-speedup metric the mixes report: mean over cores of
    // per-core IPC ratios — recomputed here by hand against the method.
    use aldram::timing::TimingParams;
    let m = mix::mix_by_name("gups+h264ref").unwrap();
    let cfg = SystemConfig::paper_default();
    let fast_cfg = SystemConfig::paper_default().with_timings(
        TimingParams::ddr3_standard().reduced(0.27, 0.32, 0.33, 0.18));
    let base = System::with_sources(&cfg, m.sources("ws/0")).run_fast(30_000);
    let fast =
        System::with_sources(&fast_cfg, m.sources("ws/0")).run_fast(30_000);
    let ws = fast.weighted_speedup(&base);
    let by_hand: f64 = fast
        .cores
        .iter()
        .zip(&base.cores)
        .map(|(f, b)| f.ipc / b.ipc)
        .sum::<f64>() / 4.0;
    assert!((ws - by_hand).abs() < 1e-15);
    assert!(ws > 1.0, "reduced timings must help the mix: {ws}");
}
