//! Integration: the AL-DRAM mechanism end to end — Fig 4 machinery,
//! sensitivity, power, and the stress analogue.

use aldram::eval::{fig4, power_eval, power_saving, sensitivity, stress,
                   PAPER_REDUCTIONS_55C};

const CYCLES: u64 = 40_000; // small but steady-state enough for ordering

#[test]
fn fig4_orderings_hold() {
    let r = fig4(CYCLES, 1, PAPER_REDUCTIONS_55C);
    assert_eq!(r.per_workload.len(), 35);
    // The paper's three key conclusions:
    // 1. significant improvement for memory-intensive workloads,
    assert!(r.gmean_intensive_multi > 1.05,
            "intensive gmean {}", r.gmean_intensive_multi);
    // 2. multi-core pressure amplifies the benefit vs single-core,
    assert!(r.gmean_intensive_multi > r.gmean_intensive_single * 0.99);
    // 3. memory-intensive gains exceed non-intensive by a wide margin.
    assert!(r.gmean_intensive_multi > r.gmean_nonintensive_multi + 0.04,
            "{} vs {}", r.gmean_intensive_multi, r.gmean_nonintensive_multi);
    // No workload is badly hurt.
    for w in &r.per_workload {
        assert!(w.multi_speedup > 0.97, "{} regressed: {}", w.name,
                w.multi_speedup);
    }
}

#[test]
fn sensitivity_helps_in_every_config() {
    for row in sensitivity(CYCLES, PAPER_REDUCTIONS_55C) {
        assert!(row.gmean_speedup > 1.0,
                "AL-DRAM must help in {}: {}", row.label, row.gmean_speedup);
    }
}

#[test]
fn power_is_saved() {
    let rows = power_eval(CYCLES, PAPER_REDUCTIONS_55C);
    assert!(!rows.is_empty());
    let saving = power_saving(&rows);
    assert!(saving > 0.0, "AL-DRAM must save energy per work: {saving}");
    assert!(saving < 0.25, "implausibly large saving: {saving}");
}

#[test]
fn stress_analogue_is_error_free() {
    let r = stress(3, 8, 25_000).unwrap();
    assert_eq!(r.errors, 0);
    assert!(r.min_margin > 0.0);
}
