//! Property tests on the charge model and the profiler (in-tree `forall`
//! harness; the offline mirror has no proptest — see util::quick).

use aldram::model::charge::{self, Cell, Combo};
use aldram::model::{params, profile, CellArrays};
use aldram::util::quick::forall;
use aldram::util::rng::Rng;

fn rand_cell(rng: &mut Rng) -> Cell {
    Cell {
        qcap: rng.range(0.7, 1.2) as f32,
        tau_s: rng.lognormal(1.6, 0.2) as f32,
        tau_r: rng.lognormal(2.2, 0.3) as f32,
        tau_p: rng.lognormal(0.5, 0.1) as f32,
        lam85: rng.lognormal(-7.3, 0.6) as f32,
    }
}

fn rand_combo(rng: &mut Rng) -> Combo {
    Combo {
        trcd: rng.range(3.0, 13.75) as f32,
        tras: rng.range(12.0, 35.0) as f32,
        twr: rng.range(3.0, 15.0) as f32,
        trp: rng.range(3.0, 13.75) as f32,
        tref_ms: rng.range(8.0, 512.0) as f32,
        temp_c: rng.range(25.0, 85.0) as f32,
    }
}

#[test]
fn uniformly_faster_timings_never_raise_margins() {
    let p = params();
    forall(300, |rng| {
        let c = rand_cell(rng);
        let k = rand_combo(rng);
        let scale = rng.range(0.3, 0.99) as f32;
        let cut = Combo { trcd: k.trcd * scale, tras: k.tras * scale,
                          twr: k.twr * scale, trp: k.trp * scale, ..k };
        let (r0, w0) = charge::test_margins(&c, &k, p);
        let (r1, w1) = charge::test_margins(&c, &cut, p);
        assert!(r1 <= r0 + 1e-6, "read {r0} -> {r1}");
        assert!(w1 <= w0 + 1e-6, "write {w0} -> {w1}");
    });
}

#[test]
fn heating_and_longer_refresh_never_raise_margins() {
    let p = params();
    forall(300, |rng| {
        let c = rand_cell(rng);
        let k = rand_combo(rng);
        let hot = Combo { temp_c: (k.temp_c + rng.range(1.0, 30.0) as f32)
            .min(85.0), ..k };
        let long = Combo { tref_ms: k.tref_ms * 2.0, ..k };
        let (r0, w0) = charge::test_margins(&c, &k, p);
        for other in [hot, long] {
            let (r1, w1) = charge::test_margins(&c, &other, p);
            assert!(r1 <= r0 + 1e-6);
            assert!(w1 <= w0 + 1e-6);
        }
    });
}

#[test]
fn profile_counts_equal_margin_signs() {
    let p = params();
    forall(40, |rng| {
        let mut arrays = CellArrays::zeroed(2, 2, 32);
        for i in 0..arrays.len() {
            arrays.set(i, rand_cell(rng));
        }
        let combos = [rand_combo(rng), rand_combo(rng), Combo::sentinel()];
        let out = profile::profile_native(&arrays, &combos, p);
        for (ki, combo) in combos.iter().enumerate() {
            let expect: f64 = if combo.is_sentinel() {
                0.0
            } else {
                (0..arrays.len())
                    .filter(|i| {
                        charge::test_margins(&arrays.cell(*i), combo, p).0
                            < 0.0
                    })
                    .count() as f64
            };
            assert_eq!(out.read_errors(ki), expect);
        }
    });
}

#[test]
fn bank_chip_reductions_partition_totals() {
    let p = params();
    forall(40, |rng| {
        let mut arrays = CellArrays::zeroed(4, 2, 16);
        for i in 0..arrays.len() {
            arrays.set(i, rand_cell(rng));
        }
        let combos = [rand_combo(rng)];
        let out = profile::profile_native(&arrays, &combos, p);
        let bank_sum: f64 = out.bank_errors_read(0).iter().sum();
        let chip_sum: f64 = out.chip_errors_read(0).iter().sum();
        assert_eq!(bank_sum, out.read_errors(0));
        assert_eq!(chip_sum, out.read_errors(0));
    });
}

#[test]
fn downsampled_population_is_a_subset() {
    // Profiling a downsample can only see a subset of failures.
    let p = params();
    forall(20, |rng| {
        let mut arrays = CellArrays::zeroed(2, 2, 64);
        for i in 0..arrays.len() {
            arrays.set(i, rand_cell(rng));
        }
        let combo = [rand_combo(rng)];
        let full = profile::profile_native(&arrays, &combo, p);
        let small = profile::profile_native(&arrays.downsample(16), &combo, p);
        assert!(small.read_errors(0) <= full.read_errors(0));
        assert!(small.write_errors(0) <= full.write_errors(0));
    });
}
