//! Lockstep-engine equivalence matrix: `eval::lockstep::run_cells` must
//! produce *bit-identical* statistics to the independent-system oracle
//! (one freshly-sourced `System` per cell, run unchunked) for every
//! cell, across {uniform, region-indexed, region+placement} config
//! sets × {run, run_fast} × {protocol checker on, off} — and, with the
//! checker attached, identical audited command counts. Sharing one
//! stream generation across K systems, and advancing them in
//! `LOCKSTEP_CHUNK` rounds, must be invisible in every counter.

use aldram::aldram::{AlDram, RegionTable};
use aldram::check::CheckSummary;
use aldram::eval::lockstep::{grid, run_cells, Engine};
use aldram::eval::Driver;
use aldram::mem::{AddrMap, RegionRemap, System, SystemConfig, SystemStats};
use aldram::timing::TimingParams;
use aldram::workloads::{by_name, NamedSource};

const CYCLES: u64 = 30_000;

fn fast_timings() -> TimingParams {
    TimingParams::ddr3_standard().reduced(0.27, 0.32, 0.33, 0.18)
}

/// The non-uniform 8-bank × 2-region grid from the time-skip matrix:
/// region 0 fast with a per-bank wobble, region 1 mildly reduced.
fn region_grid() -> RegionTable {
    let entries: Vec<AlDram> = (0..16)
        .map(|i| {
            let (bank, region) = (i / 2, i % 2);
            let f = 1.0 - 0.02 * bank as f64;
            let t = if region == 0 {
                fast_timings().with_core(
                    fast_timings().trcd_ns * f,
                    fast_timings().tras_ns * f,
                    fast_timings().twr_ns * f,
                    fast_timings().trp_ns * f,
                )
            } else {
                TimingParams::ddr3_standard().reduced(0.10, 0.12, 0.15, 0.08)
            };
            AlDram::fixed(t)
        })
        .collect();
    RegionTable::from_regions(8, 2, entries).unwrap()
}

fn sources(names: &[&str], seed: &str) -> Vec<NamedSource> {
    names
        .iter()
        .enumerate()
        .map(|(i, n)| by_name(n).unwrap()
             .named_source(&format!("lockstep/{seed}/core{i}")))
        .collect()
}

fn assert_stats_identical(label: &str, a: &SystemStats, b: &SystemStats) {
    assert_eq!(a.cycles, b.cycles, "{label}: cycles");
    assert_eq!(a.reads_done, b.reads_done, "{label}: reads_done");
    assert_eq!(a.writes_done, b.writes_done, "{label}: writes_done");
    assert_eq!(a.refreshes, b.refreshes, "{label}: refreshes");
    assert_eq!(a.avg_read_latency_cycles, b.avg_read_latency_cycles,
               "{label}: avg_read_latency");
    assert_eq!(a.row_hit_rate, b.row_hit_rate, "{label}: row_hit_rate");
    assert_eq!(a.bus_utilization, b.bus_utilization,
               "{label}: bus_utilization");
    assert_eq!(a.mean_temp_c, b.mean_temp_c, "{label}: mean_temp_c");
    assert_eq!(a.final_temp_c, b.final_temp_c, "{label}: final_temp_c");
    assert_eq!(a.channels.len(), b.channels.len(), "{label}: channel count");
    for (i, (ha, hb)) in a.channels.iter().zip(&b.channels).enumerate() {
        assert_eq!(ha.reads_done, hb.reads_done, "{label}/ch{i}: reads");
        assert_eq!(ha.writes_done, hb.writes_done, "{label}/ch{i}: writes");
        assert_eq!(ha.avg_read_latency_cycles, hb.avg_read_latency_cycles,
                   "{label}/ch{i}: read latency");
        assert_eq!(ha.mean_temp_c, hb.mean_temp_c, "{label}/ch{i}: mean temp");
        assert_eq!(ha.final_temp_c, hb.final_temp_c,
                   "{label}/ch{i}: final temp");
        assert_eq!(ha.timing_switches, hb.timing_switches,
                   "{label}/ch{i}: timing switches");
    }
    assert_eq!(a.cores.len(), b.cores.len(), "{label}: core count");
    for (ca, cb) in a.cores.iter().zip(&b.cores) {
        assert_eq!(ca.insts, cb.insts, "{label}/{}: insts", ca.name);
        assert_eq!(ca.ipc, cb.ipc, "{label}/{}: ipc", ca.name);
        assert_eq!(ca.reads, cb.reads, "{label}/{}: reads", ca.name);
        assert_eq!(ca.writes, cb.writes, "{label}/{}: writes", ca.name);
        assert_eq!(ca.stall_cycles, cb.stall_cycles,
                   "{label}/{}: stall_cycles", ca.name);
    }
    for (i, (pa, pb)) in
        a.power_inputs.iter().zip(&b.power_inputs).enumerate()
    {
        assert_eq!(pa.n_act, pb.n_act, "{label}/ch{i}: n_act");
        assert_eq!(pa.n_read, pb.n_read, "{label}/ch{i}: n_read");
        assert_eq!(pa.n_write, pb.n_write, "{label}/ch{i}: n_write");
        assert_eq!(pa.n_refresh, pb.n_refresh, "{label}/ch{i}: n_refresh");
        assert_eq!(pa.open_bank_cycles, pb.open_bank_cycles,
                   "{label}/ch{i}: open_bank_cycles");
    }
}

/// The audited coverage counters must match exactly: same command count,
/// same violation count, same per-constraint check counts, same
/// per-region hit histogram.
fn assert_summaries_identical(label: &str, a: &CheckSummary,
                              b: &CheckSummary) {
    assert_eq!(a.systems, b.systems, "{label}: audited systems");
    assert_eq!(a.commands, b.commands, "{label}: audited commands");
    assert_eq!(a.violations, b.violations, "{label}: violations");
    assert_eq!(a.checks, b.checks, "{label}: per-constraint checks");
    assert_eq!(a.region_hits, b.region_hits, "{label}: region hits");
}

/// Run `cells` both ways — lockstep (shared generation, chunked
/// advance) and the independent oracle (fresh sources per cell, one
/// unchunked run) — and require bit-identical stats and, when checking,
/// identical audit counters for every cell.
fn check_matrix(label: &str, cells: &[(SystemConfig, AddrMap)],
                names: &[&str], driver: Driver, check: bool) {
    let lockstep = run_cells(cells, sources(names, label), CYCLES, driver,
                             check);
    assert_eq!(lockstep.len(), cells.len());
    for (k, ((cfg, map), (stats, summary))) in
        cells.iter().zip(&lockstep).enumerate()
    {
        let mut sys =
            System::with_sources_map(cfg, *map, sources(names, label));
        if check {
            sys.enable_check();
        }
        let oracle = match driver {
            Driver::CycleStepped => sys.run(CYCLES),
            Driver::TimeSkip => sys.run_fast(CYCLES),
        };
        let cell = format!("{label}/cell{k}");
        assert_stats_identical(&cell, &oracle, stats);
        match (sys.check_summary(), summary) {
            (None, None) => assert!(!check, "{cell}: checker missing"),
            (Some(a), Some(b)) => {
                assert!(check || aldram::check::inline_enabled());
                assert_summaries_identical(&cell, &a, b);
                assert_eq!(a.violations, 0, "{cell}: protocol violations");
            }
            _ => panic!("{cell}: checker attached on one side only"),
        }
    }
}

fn uniform_cells() -> Vec<(SystemConfig, AddrMap)> {
    // Three uniform-timing variants: JEDEC standard, the paper's 55 °C
    // point, and a mild midpoint — one shared stream, K=3 systems.
    let map = AddrMap::ddr3_2gb(1);
    [TimingParams::ddr3_standard(),
     TimingParams::ddr3_standard().reduced(0.10, 0.12, 0.15, 0.08),
     fast_timings()]
        .into_iter()
        .map(|t| (SystemConfig::paper_default().with_timings(t), map))
        .collect()
}

fn region_cells() -> Vec<(SystemConfig, AddrMap)> {
    // Baseline vs region-granular table: per-(bank, row-region) timing
    // lookups diverge the two systems' command schedules maximally.
    let map = AddrMap::ddr3_2gb(1);
    vec![
        (SystemConfig::paper_default().with_ambient(30.0), map),
        (SystemConfig::paper_default()
             .with_region_table(Some(region_grid()))
             .with_ambient(30.0),
         map),
    ]
}

fn placement_cells() -> Vec<(SystemConfig, AddrMap)> {
    // Region timing plus variation-aware page placement on the AL-DRAM
    // cell only — per-cell address maps, the page-placement axis the
    // FLY-DRAM follow-up multiplies.
    let table = region_grid();
    let base_map = AddrMap::ddr3_2gb(1);
    let remapped = base_map
        .with_remap(RegionRemap::fastest_first(&table, base_map.row_bits));
    let mut cells = region_cells();
    cells[1].0 = SystemConfig::paper_default()
        .with_region_table(Some(table))
        .with_ambient(30.0);
    cells[1].1 = remapped;
    cells
}

const WORKLOADS: [&str; 2] = ["gups", "stream.copy"];

#[test]
fn uniform_run_fast() {
    check_matrix("uniform/fast", &uniform_cells(), &WORKLOADS,
                 Driver::TimeSkip, false);
}

#[test]
fn uniform_run_fast_checked() {
    check_matrix("uniform/fast/check", &uniform_cells(), &WORKLOADS,
                 Driver::TimeSkip, true);
}

#[test]
fn uniform_cycle_stepped() {
    check_matrix("uniform/step", &uniform_cells(), &WORKLOADS,
                 Driver::CycleStepped, false);
}

#[test]
fn uniform_cycle_stepped_checked() {
    check_matrix("uniform/step/check", &uniform_cells(), &WORKLOADS,
                 Driver::CycleStepped, true);
}

#[test]
fn regions_run_fast() {
    check_matrix("regions/fast", &region_cells(), &WORKLOADS,
                 Driver::TimeSkip, false);
}

#[test]
fn regions_run_fast_checked() {
    check_matrix("regions/fast/check", &region_cells(), &WORKLOADS,
                 Driver::TimeSkip, true);
}

#[test]
fn regions_cycle_stepped() {
    check_matrix("regions/step", &region_cells(), &WORKLOADS,
                 Driver::CycleStepped, false);
}

#[test]
fn regions_cycle_stepped_checked() {
    check_matrix("regions/step/check", &region_cells(), &WORKLOADS,
                 Driver::CycleStepped, true);
}

#[test]
fn placement_run_fast() {
    check_matrix("placement/fast", &placement_cells(), &WORKLOADS,
                 Driver::TimeSkip, false);
}

#[test]
fn placement_run_fast_checked() {
    check_matrix("placement/fast/check", &placement_cells(), &WORKLOADS,
                 Driver::TimeSkip, true);
}

#[test]
fn placement_cycle_stepped() {
    check_matrix("placement/step", &placement_cells(), &WORKLOADS,
                 Driver::CycleStepped, false);
}

#[test]
fn placement_cycle_stepped_checked() {
    check_matrix("placement/step/check", &placement_cells(), &WORKLOADS,
                 Driver::CycleStepped, true);
}

#[test]
fn lockstep_grid_is_jobs_invariant() {
    // The pool fans lockstep jobs by (workload, core-config, rep); the
    // input-indexed slots make the grid identical for any worker count.
    let cfgs: Vec<SystemConfig> = [TimingParams::ddr3_standard(),
                                   fast_timings()]
        .into_iter()
        .map(|t| SystemConfig::paper_default().with_timings(t))
        .collect();
    let w = vec![by_name("gups").unwrap(), by_name("mcf").unwrap()];
    let one = grid(&cfgs, &w, &[1, 2], 8_000, 2, 1, Driver::TimeSkip,
                   Engine::Lockstep);
    let four = grid(&cfgs, &w, &[1, 2], 8_000, 2, 4, Driver::TimeSkip,
                    Engine::Lockstep);
    assert_eq!(one, four, "lockstep grid varied with --jobs");
}

#[test]
fn lockstep_grid_matches_the_independent_oracle() {
    let cfgs: Vec<SystemConfig> = [TimingParams::ddr3_standard(),
                                   TimingParams::ddr3_standard()
                                       .reduced(0.10, 0.12, 0.15, 0.08),
                                   fast_timings()]
        .into_iter()
        .map(|t| SystemConfig::paper_default().with_timings(t))
        .collect();
    let w = vec![by_name("milc").unwrap()];
    let ind = grid(&cfgs, &w, &[1, 4], 8_000, 2, 2, Driver::TimeSkip,
                   Engine::Independent);
    let lck = grid(&cfgs, &w, &[1, 4], 8_000, 2, 2, Driver::TimeSkip,
                   Engine::Lockstep);
    assert_eq!(ind, lck, "lockstep grid diverged from the oracle");
}
