//! The load-bearing equivalence test: the AOT artifact (python/JAX/Pallas
//! -> HLO text -> PJRT) and the native rust mirror must produce the same
//! profiling results on identical inputs. This guards (a) the math in
//! `charge_math.py` vs `charge.rs`, (b) the constants baked at AOT time vs
//! the embedded `model_params.json`, and (c) the runtime plumbing
//! (batch padding, output unpacking).
//!
//! Requires `make artifacts`; each test skips cleanly when absent.

use aldram::model::{params, Combo};
use aldram::population::generate_dimm;
use aldram::profiler::{profile_dimm, profile_refresh};
use aldram::runtime::{artifacts_dir, NativeBackend, PjrtBackend,
                      ProfilingBackend};

fn pjrt_small() -> Option<PjrtBackend> {
    match PjrtBackend::new(&artifacts_dir(), "profile_small") {
        Ok(b) => Some(b),
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e}");
            None
        }
    }
}

fn combos_spread() -> Vec<Combo> {
    let mut v = Vec::new();
    for (trcd, tras, twr, trp) in [
        (13.75, 35.0, 15.0, 13.75),
        (11.25, 22.5, 6.25, 8.75),
        (8.75, 20.0, 5.0, 7.5),
        (5.0, 15.0, 5.0, 5.0),
    ] {
        for (tref, temp) in [(64.0, 85.0), (200.0, 85.0), (200.0, 55.0),
                             (448.0, 85.0), (96.0, 45.0)] {
            v.push(Combo { trcd, tras, twr, trp, tref_ms: tref,
                           temp_c: temp });
        }
    }
    v.push(Combo::sentinel());
    v
}

#[test]
fn pjrt_matches_native_error_counts() {
    let Some(mut pjrt) = pjrt_small() else { return };
    let cells = pjrt.supported_cells().unwrap();
    let mut native = NativeBackend::new();
    let combos = combos_spread();

    for id in [0usize, 7, 42] {
        let d = generate_dimm(id, cells, params());
        let a = pjrt.profile(&d.arrays, &combos).unwrap();
        let b = native.profile(&d.arrays, &combos).unwrap();
        assert_eq!(a.k, b.k);
        for k in 0..combos.len() {
            assert_eq!(a.read_errors(k), b.read_errors(k),
                       "dimm {id} combo {k} read errors");
            assert_eq!(a.write_errors(k), b.write_errors(k),
                       "dimm {id} combo {k} write errors");
        }
        // Margins agree to float tolerance.
        for (x, y) in a.mmin_r.iter().zip(&b.mmin_r) {
            assert!((x - y).abs() < 2e-5 * (1.0 + x.abs()),
                    "margin mismatch {x} vs {y}");
        }
    }
}

#[test]
fn pjrt_handles_odd_batch_sizes() {
    let Some(mut pjrt) = pjrt_small() else { return };
    let cells = pjrt.supported_cells().unwrap();
    let d = generate_dimm(1, cells, params());
    let mut native = NativeBackend::new();
    // 1, exactly K, K+1 and 3K-1 sized batches (padding / chunking paths).
    let k = pjrt.combo_batch();
    for n in [1usize, k, k + 1, 3 * k - 1] {
        let combos: Vec<Combo> = combos_spread().into_iter().cycle().take(n)
            .collect();
        let a = pjrt.profile(&d.arrays, &combos).unwrap();
        let b = native.profile(&d.arrays, &combos).unwrap();
        assert_eq!(a.k, n);
        for i in 0..n {
            assert_eq!(a.read_errors(i), b.read_errors(i), "batch {n} idx {i}");
        }
    }
}

#[test]
fn full_dimm_profile_agrees_across_backends() {
    let Some(mut pjrt) = pjrt_small() else { return };
    let cells = pjrt.supported_cells().unwrap();
    let d = generate_dimm(5, cells, params());
    let mut native = NativeBackend::new();

    let rp = profile_refresh(&mut pjrt, &d.arrays, 85.0).unwrap();
    let rn = profile_refresh(&mut native, &d.arrays, 85.0).unwrap();
    assert_eq!(rp.module_max_read_ms, rn.module_max_read_ms);
    assert_eq!(rp.module_max_write_ms, rn.module_max_write_ms);
    assert_eq!(rp.bank_max_read_ms, rn.bank_max_read_ms);

    let pp = profile_dimm(&mut pjrt, &d).unwrap();
    let pn = profile_dimm(&mut native, &d).unwrap();
    assert_eq!(pp.at55.combined(), pn.at55.combined());
    assert_eq!(pp.at85.combined(), pn.at85.combined());
}

#[test]
fn rejects_mismatched_cell_resolution() {
    let Some(mut pjrt) = pjrt_small() else { return };
    let cells = pjrt.supported_cells().unwrap();
    let d = generate_dimm(0, cells / 2, params());
    let err = pjrt.profile(&d.arrays, &[Combo::sentinel()]);
    assert!(err.is_err(), "wrong-shape arrays must be rejected");
}
