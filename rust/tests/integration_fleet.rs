//! Fleet campaign determinism: a campaign's streamed summary must be a
//! pure function of `(spec, nodes)` — bit-identical across worker
//! counts, chunk sizes, and the profile-memoization path (cache on vs
//! off), because the fold is an exact commutative monoid and the cache
//! stores exactly what profiling would have produced. Also pins the
//! summary's JSON round-trip (what `fleet report` reloads) and the
//! budget sweep's anchoring.

use aldram::fleet::{run_campaign, FleetSpec, FleetSummary};
use aldram::util::json::Json;

/// Small enough to profile a few archetypes quickly, big enough that
/// every archetype and workload is drawn and chunk boundaries land
/// mid-fleet.
fn small_spec() -> FleetSpec {
    FleetSpec {
        nodes: 24,
        archetypes: 4,
        cells: 48,
        cycles: 3_000,
        seed: "itest".into(),
        chunk: 5,
        memoize: true,
        workloads: 3,
    }
}

#[test]
fn summary_is_identical_across_jobs_and_chunks() {
    let spec = small_spec();
    let baseline = run_campaign(&spec, 1);
    assert_eq!(baseline.summary.nodes, spec.nodes as u64);
    for (jobs, chunk) in [(1, 1), (4, 1), (4, 5), (4, 64), (2, 7)] {
        let r = run_campaign(&FleetSpec { chunk, ..spec.clone() }, jobs);
        assert_eq!(r.summary, baseline.summary,
                   "summary diverged at jobs={jobs} chunk={chunk}");
    }
}

#[test]
fn memoized_campaign_matches_profile_every_node() {
    let spec = small_spec();
    let memo = run_campaign(&spec, 2);
    let fresh = run_campaign(&FleetSpec { memoize: false, ..spec.clone() }, 2);
    assert_eq!(memo.summary, fresh.summary,
               "profile cache changed campaign results");
    // The cache collapses the fleet to O(archetypes) characterizations;
    // the baseline profiles every node.
    assert_eq!(memo.unique_profiles, spec.archetypes);
    assert_eq!(memo.hits + memo.misses, fresh.misses);
    assert!(memo.hits > 0, "no cache hits over {} nodes", spec.nodes);
}

#[test]
fn summary_round_trips_through_fleet_report_json() {
    let spec = small_spec();
    let r = run_campaign(&spec, 2);
    let text = r.summary.to_json().to_string_pretty();
    let back = FleetSummary::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back, r.summary);
}

#[test]
fn budget_sweep_is_anchored_and_complete() {
    let spec = small_spec();
    let r = run_campaign(&spec, 2);
    let sweep = r.summary.budget_sweep();
    assert_eq!(sweep.len(), spec.archetypes + 1);
    assert_eq!(sweep[0], (0, 1.0), "zero budget must mean standard timings");
    let full = sweep.last().unwrap().1;
    assert!((full - r.summary.speedup.mean()).abs() < 1e-9,
            "full budget {full} != fleet mean {}", r.summary.speedup.mean());
}
