//! IDD-based DRAM power model (Micron power-calculator methodology,
//! simplified to the terms the §8.4 analysis needs).
//!
//! AL-DRAM's 5.8% DRAM power saving has two sources: (i) shorter tRAS
//! means rows spend less time open (IDD3N vs IDD2N background), and (ii)
//! the same work finishes in fewer cycles, shrinking background energy per
//! unit of work. Both fall out of the counters the controller already
//! keeps.

use crate::mem::Controller;

/// DDR3-1600 x8 2Gb device currents (mA) and voltage — representative
/// datasheet values, 8 devices per rank.
#[derive(Debug, Clone, Copy)]
pub struct IddSpec {
    pub vdd: f64,
    pub idd0: f64,   // ACT-PRE average
    pub idd2n: f64,  // precharge standby
    pub idd3n: f64,  // active standby (row open)
    pub idd4r: f64,  // read burst
    pub idd4w: f64,  // write burst
    pub idd5: f64,   // refresh
    pub devices_per_rank: f64,
}

impl Default for IddSpec {
    fn default() -> Self {
        IddSpec {
            vdd: 1.5,
            idd0: 95.0,
            idd2n: 42.0,
            idd3n: 67.0,
            idd4r: 180.0,
            idd4w: 185.0,
            idd5: 215.0,
            devices_per_rank: 8.0,
        }
    }
}

/// Activity counters for one channel over a run.
#[derive(Debug, Clone, Copy)]
pub struct PowerInputs {
    pub cycles: u64,
    pub tck_ns: f64,
    pub n_act: u64,
    pub n_read: u64,
    pub n_write: u64,
    pub n_refresh: u64,
    pub open_bank_cycles: u64,
    pub banks: u64,
    pub tras_cycles: u64,
    pub trfc_cycles: u64,
    pub burst_cycles: u64,
}

impl PowerInputs {
    pub fn from_controller(ctrl: &Controller, cycles: u64) -> Self {
        let t = ctrl.timings().to_cycles(ctrl.tck_ns());
        let mut n_act = 0;
        let mut n_read = 0;
        let mut n_write = 0;
        let mut n_refresh = 0;
        let mut open = 0;
        let mut banks = 0;
        for r in ctrl.ranks() {
            n_act += r.n_act;
            n_read += r.n_read;
            n_write += r.n_write;
            n_refresh += r.n_refresh;
            open += r.open_bank_cycles(cycles);
            banks += r.banks.len() as u64;
        }
        PowerInputs {
            cycles,
            tck_ns: ctrl.tck_ns(),
            n_act,
            n_read,
            n_write,
            n_refresh,
            open_bank_cycles: open,
            banks,
            tras_cycles: t.tras as u64,
            trfc_cycles: t.trfc as u64,
            burst_cycles: t.tburst as u64,
        }
    }
}

/// Average power (W) and total energy (J) for a run.
#[derive(Debug, Clone, Copy)]
pub struct PowerBreakdown {
    pub background_w: f64,
    pub activate_w: f64,
    pub rdwr_w: f64,
    pub refresh_w: f64,
}

impl PowerBreakdown {
    pub fn total_w(&self) -> f64 {
        self.background_w + self.activate_w + self.rdwr_w + self.refresh_w
    }

    /// Energy over the run (J).
    pub fn energy_j(&self, cycles: u64, tck_ns: f64) -> f64 {
        self.total_w() * cycles as f64 * tck_ns * 1e-9
    }
}

pub fn power(inputs: &PowerInputs, spec: &IddSpec) -> PowerBreakdown {
    let n = spec.devices_per_rank;
    let cyc = inputs.cycles.max(1) as f64;

    // Background: weighted active/precharge standby by row-open residency.
    let open_frac = (inputs.open_bank_cycles as f64
        / (cyc * inputs.banks.max(1) as f64))
        .clamp(0.0, 1.0);
    let background_w =
        spec.vdd * n / 1000.0
            * (spec.idd3n * open_frac + spec.idd2n * (1.0 - open_frac));

    // Activate/precharge: IDD0 above background for tRAS per ACT.
    let act_frac = (inputs.n_act as f64 * inputs.tras_cycles as f64 / cyc)
        .min(1.0);
    let activate_w =
        spec.vdd * n / 1000.0 * (spec.idd0 - spec.idd3n).max(0.0) * act_frac;

    // Read/write bursts above active standby.
    let rd_frac = (inputs.n_read as f64 * inputs.burst_cycles as f64 / cyc)
        .min(1.0);
    let wr_frac = (inputs.n_write as f64 * inputs.burst_cycles as f64 / cyc)
        .min(1.0);
    let rdwr_w = spec.vdd * n / 1000.0
        * ((spec.idd4r - spec.idd3n).max(0.0) * rd_frac
            + (spec.idd4w - spec.idd3n).max(0.0) * wr_frac);

    // Refresh above precharge standby.
    let ref_frac = (inputs.n_refresh as f64 * inputs.trfc_cycles as f64 / cyc)
        .min(1.0);
    let refresh_w =
        spec.vdd * n / 1000.0 * (spec.idd5 - spec.idd2n).max(0.0) * ref_frac;

    PowerBreakdown { background_w, activate_w, rdwr_w, refresh_w }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_inputs() -> PowerInputs {
        PowerInputs {
            cycles: 1_000_000,
            tck_ns: 1.25,
            n_act: 10_000,
            n_read: 60_000,
            n_write: 20_000,
            n_refresh: 160,
            open_bank_cycles: 3_000_000,
            banks: 8,
            tras_cycles: 28,
            trfc_cycles: 128,
            burst_cycles: 4,
        }
    }

    #[test]
    fn power_is_positive_and_plausible() {
        let p = power(&base_inputs(), &IddSpec::default());
        let total = p.total_w();
        // A busy 8-device DDR3 rank dissipates a few watts.
        assert!(total > 0.5 && total < 10.0, "total {total} W");
        assert!(p.background_w > 0.0);
        assert!(p.rdwr_w > 0.0);
    }

    #[test]
    fn shorter_tras_cuts_activate_power() {
        let spec = IddSpec::default();
        let mut a = base_inputs();
        let mut b = base_inputs();
        a.tras_cycles = 28;
        b.tras_cycles = 19; // 32% reduction
        let pa = power(&a, &spec);
        let pb = power(&b, &spec);
        assert!(pb.activate_w < pa.activate_w);
        assert!(pb.total_w() < pa.total_w());
    }

    #[test]
    fn less_row_open_time_cuts_background() {
        let spec = IddSpec::default();
        let mut a = base_inputs();
        let mut b = base_inputs();
        a.open_bank_cycles = 4_000_000;
        b.open_bank_cycles = 2_000_000;
        assert!(power(&b, &spec).background_w < power(&a, &spec).background_w);
    }

    #[test]
    fn idle_rank_draws_only_precharge_standby() {
        let spec = IddSpec::default();
        let idle = PowerInputs {
            cycles: 1_000_000,
            tck_ns: 1.25,
            n_act: 0,
            n_read: 0,
            n_write: 0,
            n_refresh: 0,
            open_bank_cycles: 0,
            banks: 8,
            tras_cycles: 28,
            trfc_cycles: 128,
            burst_cycles: 4,
        };
        let p = power(&idle, &spec);
        let expect = spec.vdd * spec.devices_per_rank / 1000.0 * spec.idd2n;
        assert!((p.total_w() - expect).abs() < 1e-9);
    }
}
