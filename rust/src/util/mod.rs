//! Self-contained infrastructure the offline crate mirror cannot provide:
//! JSON, deterministic RNG, a bench harness, property testing, and small
//! formatting/statistics helpers shared across the crate.

pub mod bench;
pub mod hist;
pub mod json;
pub mod quick;
pub mod rng;
pub mod trajectory;

/// Geometric mean of positive values (used for Fig 4 workload groups).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty(), "geomean of empty slice");
    let s: f64 = xs.iter().map(|x| {
        assert!(*x > 0.0, "geomean needs positive values, got {x}");
        x.ln()
    }).sum();
    (s / xs.len() as f64).exp()
}

/// Arithmetic mean.
pub fn mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64)
        .sqrt()
}

/// Percentile over a *sorted* slice (q in [0,1], nearest-rank).
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn stats_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((stddev(&[1.0, 2.0, 3.0]) - 1.0).abs() < 1e-12);
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_sorted(&v, 0.0), 1.0);
        assert_eq!(percentile_sorted(&v, 1.0), 4.0);
    }
}
