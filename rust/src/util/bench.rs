//! In-tree micro-benchmark harness (the offline mirror has no criterion).
//!
//! Usage from a `[[bench]] harness = false` target:
//!
//! ```ignore
//! let mut b = Bench::from_env("bench_controller");
//! b.bench("frfcfs/stream_64q", || sim.step_n(10_000));
//! b.finish();
//! ```
//!
//! Each benchmark is warmed up, then run for a target wall-clock window;
//! we report min/median/mean/p95 per-iteration times and iterations/sec.
//! Output is both human-readable and machine-readable (one JSON line per
//! benchmark, consumed by EXPERIMENTS.md tooling).

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub min_ns: f64,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
}

/// One SPEEDUP[*] comparison as a structured record — the machine-
/// readable twin of the `SPEEDUP[tag] a -> b` line. `repro bench all`
/// collects these into `BENCH_SIM.json` / `BENCH_PROFILE.json`, which CI
/// diffs structurally (suite/tag/base/test) against the committed
/// baselines at the repo root.
#[derive(Debug, Clone, PartialEq)]
pub struct SpeedupRecord {
    pub suite: String,
    pub tag: String,
    pub base: String,
    pub test: String,
    pub speedup: f64,
    pub base_median_ns: f64,
    pub test_median_ns: f64,
}

impl SpeedupRecord {
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut m = std::collections::BTreeMap::new();
        m.insert("suite".into(), Json::Str(self.suite.clone()));
        m.insert("tag".into(), Json::Str(self.tag.clone()));
        m.insert("base".into(), Json::Str(self.base.clone()));
        m.insert("test".into(), Json::Str(self.test.clone()));
        m.insert("speedup".into(), Json::Num(self.speedup));
        m.insert("base_median_ns".into(), Json::Num(self.base_median_ns));
        m.insert("test_median_ns".into(), Json::Num(self.test_median_ns));
        Json::Obj(m)
    }
}

pub struct Bench {
    suite: String,
    warmup: Duration,
    window: Duration,
    results: Vec<BenchResult>,
    filter: Option<String>,
}

impl Bench {
    pub fn new(suite: &str) -> Self {
        Bench {
            suite: suite.to_string(),
            warmup: Duration::from_millis(150),
            window: Duration::from_millis(900),
            results: Vec::new(),
            filter: None,
        }
    }

    /// Honors `--bench <filter>` / `BENCH_FILTER` and the cargo-supplied
    /// `--bench` flag; `BENCH_FAST=1` shrinks windows for CI.
    pub fn from_env(suite: &str) -> Self {
        let mut b = Bench::new(suite);
        let args: Vec<String> = std::env::args().collect();
        for (i, a) in args.iter().enumerate() {
            if a == "--filter" {
                b.filter = args.get(i + 1).cloned();
            }
        }
        if let Ok(f) = std::env::var("BENCH_FILTER") {
            b.filter = Some(f);
        }
        if std::env::var("BENCH_FAST").is_ok() {
            b.warmup = Duration::from_millis(20);
            b.window = Duration::from_millis(120);
        }
        println!("== bench suite: {} ==", suite);
        b
    }

    pub fn with_window(mut self, warmup_ms: u64, window_ms: u64) -> Self {
        self.warmup = Duration::from_millis(warmup_ms);
        self.window = Duration::from_millis(window_ms);
        self
    }

    fn skip(&self, name: &str) -> bool {
        match &self.filter {
            Some(f) => !name.contains(f.as_str()),
            None => false,
        }
    }

    /// Benchmark `f`, which performs one unit of work per call.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) {
        if self.skip(name) {
            return;
        }
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Measure individual iterations until the window closes.
        let mut samples: Vec<f64> = Vec::with_capacity(4096);
        let start = Instant::now();
        while start.elapsed() < self.window {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let pick = |q: f64| samples[((n - 1) as f64 * q) as usize];
        let mean = samples.iter().sum::<f64>() / n as f64;
        let r = BenchResult {
            name: name.to_string(),
            iters: n as u64,
            min_ns: samples[0],
            median_ns: pick(0.5),
            mean_ns: mean,
            p95_ns: pick(0.95),
        };
        println!(
            "{:<44} {:>10} it  min {:>12}  med {:>12}  p95 {:>12}  {:>12.1} it/s",
            r.name,
            r.iters,
            fmt_ns(r.min_ns),
            fmt_ns(r.median_ns),
            fmt_ns(r.p95_ns),
            1e9 / r.mean_ns,
        );
        println!(
            "BENCHJSON {{\"suite\":\"{}\",\"name\":\"{}\",\"iters\":{},\"min_ns\":{:.1},\"median_ns\":{:.1},\"mean_ns\":{:.1},\"p95_ns\":{:.1}}}",
            self.suite, r.name, r.iters, r.min_ns, r.median_ns, r.mean_ns, r.p95_ns
        );
        self.results.push(r);
    }

    /// Benchmark with an explicit per-iteration batch size; reported times
    /// are divided by `batch` (for hot loops too fast to time singly).
    pub fn bench_batch<T>(&mut self, name: &str, batch: u64,
                          mut f: impl FnMut() -> T) {
        if self.skip(name) {
            return;
        }
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        let mut samples: Vec<f64> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.window {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64 / batch as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let pick = |q: f64| samples[((n - 1) as f64 * q) as usize];
        let mean = samples.iter().sum::<f64>() / n as f64;
        let r = BenchResult {
            name: name.to_string(),
            iters: n as u64 * batch,
            min_ns: samples[0],
            median_ns: pick(0.5),
            mean_ns: mean,
            p95_ns: pick(0.95),
        };
        println!(
            "{:<44} {:>10} it  min {:>12}  med {:>12}  p95 {:>12}  {:>12.1} it/s",
            r.name, r.iters, fmt_ns(r.min_ns), fmt_ns(r.median_ns),
            fmt_ns(r.p95_ns), 1e9 / r.mean_ns,
        );
        println!(
            "BENCHJSON {{\"suite\":\"{}\",\"name\":\"{}\",\"iters\":{},\"min_ns\":{:.1},\"median_ns\":{:.1},\"mean_ns\":{:.1},\"p95_ns\":{:.1}}}",
            self.suite, r.name, r.iters, r.min_ns, r.median_ns, r.mean_ns, r.p95_ns
        );
        self.results.push(r);
    }

    /// Report the wall-clock speedup of benchmark `b` over benchmark `a`
    /// (ratio of median per-iteration times). Prints a human line plus a
    /// machine-readable SPEEDUPJSON line (consumed by the EXPERIMENTS.md
    /// tooling, like BENCHJSON); returns the ratio, or `None` when either
    /// benchmark was skipped by the filter.
    pub fn report_speedup(&self, a: &str, b: &str) -> Option<f64> {
        self.report_speedup_tagged("", a, b)
    }

    /// `report_speedup` with a tag in both output lines (e.g. `TIMESKIP`
    /// for the event-driven-vs-cycle-stepped driver comparison), so the
    /// EXPERIMENTS.md tooling can tell speedup families apart.
    pub fn report_speedup_tagged(&self, tag: &str, a: &str, b: &str)
                                 -> Option<f64> {
        self.speedup_record(tag, a, b).map(|r| r.speedup)
    }

    /// [`report_speedup_tagged`], returning the full structured record
    /// (for `repro bench all`'s JSON emitters) alongside the printed
    /// lines. `None` when either benchmark was skipped by the filter.
    pub fn speedup_record(&self, tag: &str, a: &str, b: &str)
                          -> Option<SpeedupRecord> {
        let ra = self.results.iter().find(|r| r.name == a)?;
        let rb = self.results.iter().find(|r| r.name == b)?;
        let ratio = ra.median_ns / rb.median_ns;
        let label = if tag.is_empty() {
            "SPEEDUP".to_string()
        } else {
            format!("SPEEDUP[{tag}]")
        };
        println!(
            "{} {:<30} -> {:<30} {:>6.2}x  ({} -> {})",
            label, ra.name, rb.name, ratio,
            fmt_ns(ra.median_ns), fmt_ns(rb.median_ns),
        );
        println!(
            "SPEEDUPJSON {{\"suite\":\"{}\",\"tag\":\"{}\",\"base\":\"{}\",\"test\":\"{}\",\"speedup\":{:.3},\"base_median_ns\":{:.1},\"test_median_ns\":{:.1}}}",
            self.suite, tag, ra.name, rb.name, ratio, ra.median_ns,
            rb.median_ns
        );
        Some(SpeedupRecord {
            suite: self.suite.clone(),
            tag: tag.to_string(),
            base: ra.name.clone(),
            test: rb.name.clone(),
            speedup: ratio,
            base_median_ns: ra.median_ns,
            test_median_ns: rb.median_ns,
        })
    }

    pub fn finish(self) {
        println!("== {} done: {} benchmarks ==", self.suite, self.results.len());
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let mut b = Bench::new("t").with_window(5, 20);
        let mut x = 0u64;
        b.bench("noop", || {
            x = x.wrapping_add(1);
            x
        });
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].iters > 0);
        assert!(b.results[0].min_ns <= b.results[0].p95_ns);
    }

    #[test]
    fn speedup_reporting() {
        let mut b = Bench::new("t").with_window(5, 20);
        b.bench("slow", || std::thread::sleep(
            std::time::Duration::from_micros(300)));
        b.bench("fastr", || std::hint::black_box(1 + 1));
        let r = b.report_speedup("slow", "fastr").unwrap();
        assert!(r > 1.0, "slow/fastr ratio {r}");
        assert!(b.report_speedup("slow", "missing").is_none());
    }

    #[test]
    fn tagged_speedup_reporting() {
        let mut b = Bench::new("t").with_window(5, 20);
        b.bench("slow2", || std::thread::sleep(
            std::time::Duration::from_micros(300)));
        b.bench("fast2", || std::hint::black_box(1 + 1));
        let r = b.report_speedup_tagged("TIMESKIP", "slow2", "fast2").unwrap();
        assert!(r > 1.0, "slow2/fast2 ratio {r}");
    }

    #[test]
    fn speedup_records_carry_the_comparison() {
        let mut b = Bench::new("t").with_window(5, 20);
        b.bench("slow3", || std::thread::sleep(
            std::time::Duration::from_micros(300)));
        b.bench("fast3", || std::hint::black_box(1 + 1));
        let r = b.speedup_record("SRC", "slow3", "fast3").unwrap();
        assert_eq!((r.suite.as_str(), r.tag.as_str()), ("t", "SRC"));
        assert_eq!((r.base.as_str(), r.test.as_str()), ("slow3", "fast3"));
        assert!(r.speedup > 1.0 && r.base_median_ns > r.test_median_ns);
        let j = r.to_json();
        assert_eq!(j.str("tag"), "SRC");
        assert_eq!(j.f64("speedup"), r.speedup);
        assert!(b.speedup_record("SRC", "slow3", "missing").is_none());
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(2e9).contains(" s"));
    }
}
