//! Tiny property-testing helper (the offline mirror has no proptest).
//!
//! `forall(cases, |rng| ...)` runs a closure against `cases` independent
//! deterministic RNG streams; on failure it re-raises with the failing
//! case index so `QUICK_CASE=<i>` reproduces it exactly. No shrinking —
//! generators are kept small enough that raw failures are readable.

use super::rng::Rng;

pub fn forall(cases: u64, mut prop: impl FnMut(&mut Rng)) {
    if let Ok(one) = std::env::var("QUICK_CASE") {
        let i: u64 = one.parse().expect("QUICK_CASE must be an integer");
        let mut rng = Rng::new(0x5eed_0000 ^ i.wrapping_mul(0x9E3779B97F4A7C15));
        prop(&mut rng);
        return;
    }
    for i in 0..cases {
        let mut rng = Rng::new(0x5eed_0000 ^ i.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || prop(&mut rng),
        ));
        if let Err(e) = result {
            eprintln!(
                "property failed on case {i}/{cases} — rerun with QUICK_CASE={i}"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall(50, |rng| {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic]
    fn reports_failures() {
        forall(50, |rng| {
            assert!(rng.f64() < 0.9, "intentional failure");
        });
    }
}
