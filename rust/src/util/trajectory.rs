//! Persisted bench trajectory: `repro bench all --json-dir` appends one
//! dated entry per run to `BENCH_SIM.json` / `BENCH_PROFILE.json`
//! instead of overwriting, so the SPEEDUP[*] history of the repo is a
//! first-class artifact. CI compares a fresh run's entry against the
//! committed baseline's latest entry (`repro bench compare`) and fails
//! on a vanished comparison or a >20% median speedup regression.
//!
//! File format: a top-level array of entries, newest last —
//!
//! ```json
//! [ { "date": "2026-08-08",
//!     "records": [ { "suite": "...", "tag": "TIMESKIP", ... } ] } ]
//! ```
//!
//! Legacy baselines (a flat array of records, the pre-trajectory
//! format) parse as a single undated entry, so appending to — or
//! comparing against — an old checkout keeps working.

use std::collections::BTreeMap;

use super::bench::SpeedupRecord;
use super::json::Json;

/// One dated trajectory entry.
#[derive(Debug, Clone)]
pub struct Entry {
    pub date: String,
    pub records: Vec<SpeedupRecord>,
}

impl Entry {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("date".into(), Json::Str(self.date.clone()));
        m.insert("records".into(),
                 Json::Arr(self.records.iter().map(|r| r.to_json())
                           .collect()));
        Json::Obj(m)
    }
}

fn record_from_json(j: &Json) -> anyhow::Result<SpeedupRecord> {
    for k in ["suite", "tag", "base", "test"] {
        anyhow::ensure!(j.get(k).and_then(Json::as_str).is_some(),
                        "speedup record missing string key `{k}`");
    }
    for k in ["speedup", "base_median_ns", "test_median_ns"] {
        anyhow::ensure!(j.get(k).and_then(Json::as_f64).is_some(),
                        "speedup record missing numeric key `{k}`");
    }
    Ok(SpeedupRecord {
        suite: j.str("suite").to_string(),
        tag: j.str("tag").to_string(),
        base: j.str("base").to_string(),
        test: j.str("test").to_string(),
        speedup: j.f64("speedup"),
        base_median_ns: j.f64("base_median_ns"),
        test_median_ns: j.f64("test_median_ns"),
    })
}

/// Parse a `BENCH_*.json` body into its entries (oldest first). A legacy
/// flat record array becomes one entry with an empty date.
pub fn parse(text: &str) -> anyhow::Result<Vec<Entry>> {
    let j = Json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
    let top = j.as_arr()
        .ok_or_else(|| anyhow::anyhow!("bench json is not an array"))?;
    if top.iter().all(|e| e.get("records").is_some()) {
        top.iter()
            .map(|e| {
                let records = e.arr("records")
                    .iter()
                    .map(record_from_json)
                    .collect::<anyhow::Result<_>>()?;
                Ok(Entry { date: e.str("date").to_string(), records })
            })
            .collect()
    } else {
        // Legacy flat array of records.
        let records = top.iter()
            .map(record_from_json)
            .collect::<anyhow::Result<_>>()?;
        Ok(vec![Entry { date: String::new(), records }])
    }
}

/// Append a dated entry to an existing trajectory body (or start a new
/// trajectory when `existing` is `None`); returns the serialized file.
pub fn append(existing: Option<&str>, date: &str,
              records: &[SpeedupRecord]) -> anyhow::Result<String> {
    let mut entries = match existing {
        Some(text) => parse(text)?,
        None => Vec::new(),
    };
    // The pre-trajectory flat files were committed once more as the first
    // dated entry, so legacy baselines start with an undated entry whose
    // records are a strict duplicate of the first dated one. Collapse that
    // duplicate the next time the file is appended to; a legacy entry with
    // records of its own is history and stays.
    if entries.len() >= 2
        && entries[0].date.is_empty()
        && entries[0].records.iter().all(|r| entries[1].records.contains(r))
    {
        entries.remove(0);
    }
    entries.push(Entry {
        date: date.to_string(),
        records: records.to_vec(),
    });
    let j = Json::Arr(entries.iter().map(Entry::to_json).collect());
    Ok(j.to_string_pretty() + "\n")
}

/// Compare the latest entries of a committed baseline and a fresh run.
/// Returns human-readable failures: a baseline comparison missing from
/// the fresh run (structure drift — a renamed or vanished SPEEDUP[*]
/// line), or a fresh median speedup below `(1 - max_regression)` of the
/// baseline's for the same (suite, tag, base, test). Extra fresh-side
/// comparisons are allowed — new benchmarks land before their baseline.
pub fn compare_latest(baseline: &str, fresh: &str, max_regression: f64)
                      -> anyhow::Result<Vec<String>> {
    let key = |r: &SpeedupRecord| {
        (r.suite.clone(), r.tag.clone(), r.base.clone(), r.test.clone())
    };
    let base_entry = parse(baseline)?
        .pop()
        .ok_or_else(|| anyhow::anyhow!("baseline trajectory is empty"))?;
    let fresh_entry = parse(fresh)?
        .pop()
        .ok_or_else(|| anyhow::anyhow!("fresh trajectory is empty"))?;
    let fresh_by_key: BTreeMap<_, _> = fresh_entry
        .records
        .iter()
        .map(|r| (key(r), r))
        .collect();
    let mut failures = Vec::new();
    for b in &base_entry.records {
        match fresh_by_key.get(&key(b)) {
            None => failures.push(format!(
                "missing comparison {}/{} ({} -> {})",
                b.suite, b.tag, b.base, b.test)),
            Some(f) => {
                let floor = b.speedup * (1.0 - max_regression);
                if f.speedup < floor {
                    failures.push(format!(
                        "{}/{} regressed: {:.3}x -> {:.3}x \
                         (floor {:.3}x at {:.0}% tolerance)",
                        b.suite, b.tag, b.speedup, f.speedup, floor,
                        max_regression * 100.0));
                }
            }
        }
    }
    Ok(failures)
}

/// `days` since 1970-01-01 → (year, month, day) in the proleptic
/// Gregorian calendar (Howard Hinnant's `civil_from_days`; the offline
/// mirror has no chrono).
pub fn civil_from_days(days: i64) -> (i64, u32, u32) {
    let z = days + 719_468;
    let era = z.div_euclid(146_097);
    let doe = z.rem_euclid(146_097);
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365;
    let y = yoe + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = (doy - (153 * mp + 2) / 5 + 1) as u32;
    let m = (if mp < 10 { mp + 3 } else { mp - 9 }) as u32;
    (if m <= 2 { y + 1 } else { y }, m, d)
}

/// Today's UTC date as `YYYY-MM-DD`.
pub fn today_utc() -> String {
    let secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs() as i64)
        .unwrap_or(0);
    let (y, m, d) = civil_from_days(secs.div_euclid(86_400));
    format!("{y:04}-{m:02}-{d:02}")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(tag: &str, speedup: f64) -> SpeedupRecord {
        SpeedupRecord {
            suite: "bench-sim".into(),
            tag: tag.into(),
            base: format!("{tag}/base"),
            test: format!("{tag}/test"),
            speedup,
            base_median_ns: 100.0 * speedup,
            test_median_ns: 100.0,
        }
    }

    #[test]
    fn civil_dates() {
        assert_eq!(civil_from_days(0), (1970, 1, 1));
        assert_eq!(civil_from_days(19_723), (2024, 1, 1));
        // 2026-08-08 (this repo's trajectory epoch) and a leap day.
        assert_eq!(civil_from_days(20_673), (2026, 8, 8));
        assert_eq!(civil_from_days(18_321), (2020, 2, 29));
        assert_eq!(civil_from_days(-1), (1969, 12, 31));
    }

    #[test]
    fn today_is_well_formed() {
        let d = today_utc();
        assert_eq!(d.len(), 10);
        assert_eq!(d.as_bytes()[4], b'-');
        assert_eq!(d.as_bytes()[7], b'-');
    }

    #[test]
    fn append_then_parse_roundtrips() {
        let t1 = append(None, "2026-08-08", &[rec("TIMESKIP", 3.0)]).unwrap();
        let t2 = append(Some(&t1), "2026-08-09",
                        &[rec("TIMESKIP", 3.1), rec("LOCKSTEP", 2.2)])
            .unwrap();
        let entries = parse(&t2).unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].date, "2026-08-08");
        assert_eq!(entries[1].date, "2026-08-09");
        assert_eq!(entries[1].records.len(), 2);
        assert_eq!(entries[1].records[1].tag, "LOCKSTEP");
        assert_eq!(entries[1].records[1].speedup, 2.2);
    }

    #[test]
    fn legacy_flat_arrays_wrap_as_one_entry() {
        let legacy = Json::Arr(vec![rec("SOURCE", 1.5).to_json()])
            .to_string_pretty();
        let entries = parse(&legacy).unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].date, "");
        assert_eq!(entries[0].records[0].tag, "SOURCE");
        // Appending to a legacy file upgrades it in place.
        let t = append(Some(&legacy), "2026-08-08", &[rec("SOURCE", 1.6)])
            .unwrap();
        let entries = parse(&t).unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[1].date, "2026-08-08");
    }

    #[test]
    fn legacy_duplicate_of_first_dated_entry_collapses_on_append() {
        // The committed BENCH_*.json shape before the fix: an undated
        // legacy entry whose records duplicate (a prefix of) the first
        // dated entry's.
        let legacy = Json::Arr(vec![rec("TIMESKIP", 3.0).to_json()])
            .to_string_pretty();
        let dup = append(Some(&legacy), "2026-08-07",
                         &[rec("TIMESKIP", 3.0), rec("SOURCE", 1.5)])
            .unwrap();
        assert_eq!(parse(&dup).unwrap().len(), 2, "dup not yet collapsible");
        let t = append(Some(&dup), "2026-08-08", &[rec("TIMESKIP", 3.1)])
            .unwrap();
        let entries = parse(&t).unwrap();
        assert_eq!(entries.len(), 2, "legacy duplicate survived: {t}");
        assert_eq!(entries[0].date, "2026-08-07");
        assert_eq!(entries[1].date, "2026-08-08");
        // And the collapse happens at most once — appending again is stable.
        let t2 = append(Some(&t), "2026-08-09", &[rec("TIMESKIP", 3.2)])
            .unwrap();
        assert_eq!(parse(&t2).unwrap().len(), 3);
    }

    #[test]
    fn legacy_entry_with_unique_records_is_kept() {
        // An undated entry that is *not* a duplicate is real history.
        let legacy = Json::Arr(vec![rec("SOURCE", 9.9).to_json()])
            .to_string_pretty();
        let dated = append(Some(&legacy), "2026-08-07",
                           &[rec("TIMESKIP", 3.0)])
            .unwrap();
        let t = append(Some(&dated), "2026-08-08", &[rec("TIMESKIP", 3.1)])
            .unwrap();
        let entries = parse(&t).unwrap();
        assert_eq!(entries.len(), 3, "unique legacy entry was dropped: {t}");
        assert_eq!(entries[0].date, "");
        assert_eq!(entries[0].records[0].tag, "SOURCE");
    }

    #[test]
    fn compare_passes_within_tolerance() {
        let base = append(None, "d1", &[rec("TIMESKIP", 3.0)]).unwrap();
        let fresh = append(None, "d2", &[rec("TIMESKIP", 2.5),
                                         rec("LOCKSTEP", 2.0)])
            .unwrap();
        // 2.5 ≥ 3.0 × 0.8 → ok; extra fresh-side LOCKSTEP is allowed.
        assert!(compare_latest(&base, &fresh, 0.2).unwrap().is_empty());
    }

    #[test]
    fn compare_fails_on_regression_and_missing() {
        let base = append(None, "d1", &[rec("TIMESKIP", 3.0),
                                        rec("LOCKSTEP", 2.0)])
            .unwrap();
        let fresh = append(None, "d2", &[rec("TIMESKIP", 2.0)]).unwrap();
        let fails = compare_latest(&base, &fresh, 0.2).unwrap();
        assert_eq!(fails.len(), 2, "{fails:?}");
        assert!(fails.iter().any(|f| f.contains("regressed")));
        assert!(fails.iter().any(|f| f.contains("missing comparison")));
    }

    #[test]
    fn compare_uses_the_latest_entry_only() {
        let old = append(None, "d1", &[rec("TIMESKIP", 9.0)]).unwrap();
        let base = append(Some(&old), "d2", &[rec("TIMESKIP", 2.0)]).unwrap();
        let fresh = append(None, "d3", &[rec("TIMESKIP", 1.9)]).unwrap();
        // Against d2's 2.0x, 1.9x is fine; d1's 9.0x is history, not a bar.
        assert!(compare_latest(&base, &fresh, 0.2).unwrap().is_empty());
    }
}
