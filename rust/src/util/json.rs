//! Minimal JSON parser/serializer (the offline crate mirror has no
//! serde_json; see Cargo.toml). Supports the full JSON grammar minus
//! exotic escapes; numbers are f64. Used for `model_params.json`,
//! `artifacts/manifest.json` and the CSV/JSON result emitters.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ----- typed accessors ---------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that panics with a useful message — parameters
    /// files are trusted inputs; a missing key is a programming error.
    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing json key `{key}` in {self:.60?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn f64(&self, key: &str) -> f64 {
        self.req(key)
            .as_f64()
            .unwrap_or_else(|| panic!("json key `{key}` is not a number"))
    }

    pub fn f32(&self, key: &str) -> f32 {
        self.f64(key) as f32
    }

    pub fn usize(&self, key: &str) -> usize {
        let x = self.f64(key);
        assert!(x >= 0.0 && x.fract() == 0.0, "json key `{key}` not a usize");
        x as usize
    }

    pub fn str(&self, key: &str) -> &str {
        self.req(key)
            .as_str()
            .unwrap_or_else(|| panic!("json key `{key}` is not a string"))
    }

    pub fn arr(&self, key: &str) -> &[Json] {
        self.req(key)
            .as_arr()
            .unwrap_or_else(|| panic!("json key `{key}` is not an array"))
    }

    // ----- serialization ------------------------------------------------
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String, indent: usize) {
        let pad = |n: usize| "  ".repeat(n);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for ch in s.chars() {
                    match ch {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push_str("[\n");
                for (i, item) in v.iter().enumerate() {
                    out.push_str(&pad(indent + 1));
                    item.write(out, indent + 1);
                    if i + 1 < v.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad(indent));
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    out.push_str(&pad(indent + 1));
                    Json::Str(k.clone()).write(out, indent + 1);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad(indent));
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 code point
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len()
                        && (self.b[self.i] & 0xC0) == 0x80
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
            {
                self.i += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -1.5e3 ").unwrap(), Json::Num(-1500.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, {"b": "x"}, null], "c": 2e-5}"#)
            .unwrap();
        assert_eq!(j.arr("a").len(), 3);
        assert_eq!(j.arr("a")[1].str("b"), "x");
        assert!((j.f64("c") - 2e-5).abs() < 1e-20);
    }

    #[test]
    fn parses_model_params() {
        let text = include_str!("../../../model_params.json");
        let j = Json::parse(text).unwrap();
        assert_eq!(j.f64("a_max"), 0.22);
        assert_eq!(j.req("population").arr("vendors").len(), 3);
    }

    #[test]
    fn roundtrips() {
        let text = r#"{"a": [1, 2.5, "s"], "b": {"c": true}}"#;
        let j = Json::parse(text).unwrap();
        let j2 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse("\"\\u0041\\u00e9\"").unwrap(),
            Json::Str("Aé".into())
        );
    }
}
