//! Deterministic RNG for population synthesis and workload generation.
//!
//! SplitMix64 core (Steele et al., "Fast splittable pseudorandom number
//! generators") with Box–Muller normal draws. Every DIMM, bank and
//! workload derives its own stream from a stable string seed, so profiling
//! results are reproducible across runs and across the native/PJRT
//! backends (the cell arrays are generated once and fed to both).

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second Box–Muller variate.
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed, spare: None }
    }

    /// Derive a stream from a string label (FNV-1a over the bytes), e.g.
    /// `Rng::from_label("dimm/042/bank3")`.
    pub fn from_label(label: &str) -> Self {
        let mut h: u64 = 0xcbf29ce484222325;
        for b in label.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        Rng::new(h)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's multiply-shift rejection-free mapping is fine here: the
        // modulo bias at n << 2^64 is far below anything observable.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        let (mut u1, u2) = (self.f64(), self.f64());
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * th.sin());
        r * th.cos()
    }

    /// Lognormal with log-space mean `mu` and std `sigma`.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[self.below(v.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_label() {
        let mut a = Rng::from_label("dimm/007");
        let mut b = Rng::from_label("dimm/007");
        let mut c = Rng::from_label("dimm/008");
        let (xa, xb, xc) = (a.next_u64(), b.next_u64(), c.next_u64());
        assert_eq!(xa, xb);
        assert_ne!(xa, xc);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn lognormal_median() {
        let mut r = Rng::new(3);
        let mut v: Vec<f64> = (0..10_001).map(|_| r.lognormal(1.6, 0.05)).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = v[5000];
        assert!((med - (1.6f64).exp()).abs() < 0.05, "median {med}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(4);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }
}
