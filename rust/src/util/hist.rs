//! Mergeable streaming histogram — the fixed-memory aggregation unit of
//! the fleet campaign engine (`fleet`).
//!
//! A campaign over O(10^4) nodes must never materialize per-node results,
//! so every distribution the fleet reports (speedup, latency, DIMM
//! temperature) is accumulated into one of these: a fixed bin grid plus
//! exact extremes and a fixed-point sum. The design constraint is the
//! determinism contract of `exec::Pool::run_fold` — merging per-worker
//! partials must give bit-identical results for *any* partition of the
//! input, so every field is an exact commutative monoid:
//!
//! * bin/underflow/overflow counts — `u64` addition,
//! * `min`/`max` — exact and order-free on finite floats,
//! * the sum — fixed-point `i128` (value × 2^32, ties-to-even at record
//!   time), so addition is associative, unlike `f64` accumulation whose
//!   rounding depends on grouping.
//!
//! The price is ~2^-33 relative quantization on means — invisible at the
//! 3-digit precision any report prints — and quantile resolution limited
//! to the bin width, which is the point of a histogram.

use std::collections::BTreeMap;

use super::json::Json;

/// Fixed-point scale for the exact sum: 32 fractional bits.
const FX_SCALE: f64 = 4294967296.0; // 2^32

/// A streaming histogram over `[lo, hi)` with `bins` equal-width bins
/// plus underflow/overflow counters. See the module docs for why every
/// field is an exact commutative accumulator.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamHist {
    lo: f64,
    hi: f64,
    counts: Vec<u64>,
    underflow: u64,
    overflow: u64,
    n: u64,
    min: f64,
    max: f64,
    /// Sum of recorded values in 32.32-ish fixed point (i128 is wide
    /// enough for ~2^64 samples of magnitude 2^32).
    sum_fx: i128,
}

impl StreamHist {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(bins >= 1, "histogram needs at least one bin");
        assert!(lo.is_finite() && hi.is_finite() && lo < hi,
                "bad histogram range [{lo}, {hi})");
        StreamHist {
            lo,
            hi,
            counts: vec![0; bins],
            underflow: 0,
            overflow: 0,
            n: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            sum_fx: 0,
        }
    }

    pub fn record(&mut self, x: f64) {
        assert!(x.is_finite(), "histogram got a non-finite sample: {x}");
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let w = (self.hi - self.lo) / self.counts.len() as f64;
            let i = (((x - self.lo) / w) as usize).min(self.counts.len() - 1);
            self.counts[i] += 1;
        }
        self.n += 1;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        self.sum_fx += (x * FX_SCALE).round() as i128;
    }

    /// Merge another histogram over the *same* grid into this one.
    /// Exact and commutative — the partition of samples across partials
    /// never shows in the merged result.
    pub fn merge(&mut self, other: &StreamHist) {
        assert!(self.lo == other.lo && self.hi == other.hi
                    && self.counts.len() == other.counts.len(),
                "merging histograms over different grids");
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.underflow += other.underflow;
        self.overflow += other.overflow;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.sum_fx += other.sum_fx;
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Samples recorded below `lo` (they count toward `n`, carry exact
    /// `min`/mean contributions, and anchor the underflow tail policy
    /// of [`Self::quantile_interp`]).
    pub fn underflow_count(&self) -> u64 {
        self.underflow
    }

    /// Samples recorded at or above `hi` — see [`Self::quantile_interp`]
    /// for the overflow tail policy.
    pub fn overflow_count(&self) -> u64 {
        self.overflow
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Mean of every recorded sample (fixed-point exact up to the 2^-32
    /// per-sample quantization).
    pub fn mean(&self) -> f64 {
        assert!(self.n > 0, "mean of an empty histogram");
        (self.sum_fx as f64 / FX_SCALE) / self.n as f64
    }

    /// Nearest-rank quantile at bin resolution: the center of the bin the
    /// rank lands in (the exact `min`/`max` for the under/overflow tails).
    pub fn quantile(&self, q: f64) -> f64 {
        assert!(self.n > 0, "quantile of an empty histogram");
        assert!((0.0..=1.0).contains(&q));
        let rank = ((self.n - 1) as f64 * q).round() as u64;
        let mut seen = self.underflow;
        if rank < seen {
            return self.min;
        }
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if rank < seen {
                return self.lo + (i as f64 + 0.5) * w;
            }
        }
        self.max
    }

    /// Interpolated quantile, the tail-latency extractor (p50/p95/p99/
    /// p99.9 of the open-loop latency histograms — DESIGN.md §16).
    ///
    /// The continuous rank `r = q·(n−1)` is located in the cumulative
    /// mass. The tail policy is explicit: a rank in the underflow tail
    /// returns the exact `min`, a rank in the **overflow tail returns
    /// the exact observed `max`** (a conservative upper bound — the
    /// histogram cannot resolve past its top edge, and under-reporting
    /// a tail latency is the one unacceptable failure), and `q = 0`/`q
    /// = 1` return the exact extremes. A rank inside bin `i` assumes
    /// the bin's `c` samples sit uniformly at `lo_i + w·(j+0.5)/c` and
    /// interpolates linearly between them, then clamps to the exact
    /// `[min, max]` so a sparse edge bin cannot extrapolate past real
    /// data. Resolution is the bin width; the unit tests pin the
    /// percentiles against exact sorted-sample quantiles.
    ///
    /// (The older [`Self::quantile`] keeps its nearest-rank bin-center
    /// behavior — fleet summaries were recorded against it.)
    pub fn quantile_interp(&self, q: f64) -> f64 {
        assert!(self.n > 0, "quantile of an empty histogram");
        assert!((0.0..=1.0).contains(&q));
        if q == 0.0 {
            return self.min;
        }
        if q == 1.0 {
            return self.max;
        }
        let rank = (self.n - 1) as f64 * q;
        if rank < self.underflow as f64 {
            return self.min;
        }
        let mut seen = self.underflow as f64;
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c > 0 && rank < seen + c as f64 {
                let bin_lo = self.lo + i as f64 * w;
                let v = bin_lo + w * (rank - seen + 0.5) / c as f64;
                return v.clamp(self.min, self.max);
            }
            seen += c as f64;
        }
        self.max // overflow tail
    }

    /// CDF points `(bin upper edge, cumulative fraction)` for plotting;
    /// the under/overflow tails fold into the first/last point.
    pub fn cdf(&self) -> Vec<(f64, f64)> {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        let mut cum = self.underflow;
        let mut out = Vec::with_capacity(self.counts.len());
        for (i, c) in self.counts.iter().enumerate() {
            cum += c;
            let mut frac = cum;
            if i == self.counts.len() - 1 {
                frac += self.overflow;
            }
            out.push((self.lo + (i as f64 + 1.0) * w,
                      frac as f64 / self.n.max(1) as f64));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("lo".into(), Json::Num(self.lo));
        m.insert("hi".into(), Json::Num(self.hi));
        m.insert("counts".into(),
                 Json::Arr(self.counts.iter()
                           .map(|c| Json::Num(*c as f64)).collect()));
        m.insert("underflow".into(), Json::Num(self.underflow as f64));
        m.insert("overflow".into(), Json::Num(self.overflow as f64));
        m.insert("n".into(), Json::Num(self.n as f64));
        // An empty histogram has infinite sentinels, which JSON cannot
        // spell; follow the registry's convention (infinite `max_c`) and
        // write them as null.
        let extreme = |x: f64| if x.is_finite() { Json::Num(x) } else { Json::Null };
        m.insert("min".into(), extreme(self.min));
        m.insert("max".into(), extreme(self.max));
        // i128 exceeds f64's exact-integer range; store as a decimal
        // string so the round trip stays bit-exact.
        m.insert("sum_fx".into(), Json::Str(self.sum_fx.to_string()));
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<StreamHist> {
        let num = |k: &str| -> anyhow::Result<f64> {
            j.get(k).and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("hist missing number `{k}`"))
        };
        let count = |k: &str| -> anyhow::Result<u64> {
            let x = num(k)?;
            anyhow::ensure!(x >= 0.0 && x.fract() == 0.0,
                            "hist `{k}` is not a count: {x}");
            Ok(x as u64)
        };
        let counts = j.get("counts").and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("hist missing `counts`"))?
            .iter()
            .map(|c| {
                let x = c.as_f64()
                    .ok_or_else(|| anyhow::anyhow!("non-number bin count"))?;
                anyhow::ensure!(x >= 0.0 && x.fract() == 0.0,
                                "bin count is not a count: {x}");
                Ok(x as u64)
            })
            .collect::<anyhow::Result<Vec<u64>>>()?;
        anyhow::ensure!(!counts.is_empty(), "hist has no bins");
        let sum_fx = j.get("sum_fx").and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("hist missing `sum_fx`"))?
            .parse::<i128>()
            .map_err(|e| anyhow::anyhow!("bad hist sum_fx: {e}"))?;
        // Null min/max are the empty-histogram sentinels.
        let extreme = |k: &str, empty: f64| -> anyhow::Result<f64> {
            match j.get(k) {
                Some(Json::Null) => Ok(empty),
                Some(v) => v.as_f64()
                    .ok_or_else(|| anyhow::anyhow!("hist `{k}` is not a number")),
                None => Err(anyhow::anyhow!("hist missing `{k}`")),
            }
        };
        let h = StreamHist {
            lo: num("lo")?,
            hi: num("hi")?,
            counts,
            underflow: count("underflow")?,
            overflow: count("overflow")?,
            n: count("n")?,
            min: extreme("min", f64::INFINITY)?,
            max: extreme("max", f64::NEG_INFINITY)?,
            sum_fx,
        };
        anyhow::ensure!(h.lo.is_finite() && h.hi.is_finite() && h.lo < h.hi,
                        "bad hist range [{}, {})", h.lo, h.hi);
        let binned: u64 = h.counts.iter().sum();
        anyhow::ensure!(binned + h.underflow + h.overflow == h.n,
                        "hist counts do not add up to n");
        Ok(h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn records_and_summarizes() {
        let mut h = StreamHist::new(0.0, 10.0, 10);
        for x in [0.5, 1.5, 2.5, 9.99, -1.0, 12.0] {
            h.record(x);
        }
        assert_eq!(h.count(), 6);
        assert_eq!(h.min(), -1.0);
        assert_eq!(h.max(), 12.0);
        assert!((h.mean() - (0.5 + 1.5 + 2.5 + 9.99 - 1.0 + 12.0) / 6.0).abs()
                < 1e-6);
        // CDF is monotone and ends at 1.
        let cdf = h.cdf();
        for w in cdf.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
        assert_eq!(cdf.last().unwrap().1, 1.0);
    }

    #[test]
    fn merge_is_partition_invariant() {
        // The determinism contract: any split of the sample stream into
        // partials merges to the bit-identical histogram, in any order.
        let mut rng = Rng::from_label("hist/partition");
        let xs: Vec<f64> = (0..500).map(|_| rng.range(-0.5, 3.5)).collect();
        let mut whole = StreamHist::new(0.0, 3.0, 24);
        for x in &xs {
            whole.record(*x);
        }
        for chunk in [1usize, 7, 64, 500] {
            let mut parts: Vec<StreamHist> = xs
                .chunks(chunk)
                .map(|c| {
                    let mut h = StreamHist::new(0.0, 3.0, 24);
                    for x in c {
                        h.record(*x);
                    }
                    h
                })
                .collect();
            // Merge in reverse order too — commutativity.
            parts.reverse();
            let mut merged = StreamHist::new(0.0, 3.0, 24);
            for p in &parts {
                merged.merge(p);
            }
            assert_eq!(merged, whole, "chunk {chunk}");
        }
    }

    #[test]
    fn quantiles_land_in_bins() {
        let mut h = StreamHist::new(0.0, 100.0, 100);
        for i in 0..1000 {
            h.record(i as f64 / 10.0);
        }
        assert!((h.quantile(0.5) - 50.0).abs() <= 1.0);
        assert!((h.quantile(0.1) - 10.0).abs() <= 1.0);
        assert_eq!(h.quantile(0.0), 0.5); // center of the first bin
        assert!(h.quantile(1.0) >= 99.0);
    }

    /// Exact sorted-sample quantile (linear interpolation between order
    /// statistics at rank q·(n−1)) — the reference quantile_interp is
    /// pinned against.
    fn exact_quantile(xs: &[f64], q: f64) -> f64 {
        let mut s = xs.to_vec();
        s.sort_by(f64::total_cmp);
        let h = q * (s.len() - 1) as f64;
        let i = h.floor() as usize;
        let frac = h - i as f64;
        if i + 1 < s.len() {
            s[i] + frac * (s[i + 1] - s[i])
        } else {
            s[i]
        }
    }

    #[test]
    fn quantile_interp_pins_to_exact_sorted_quantiles() {
        // Uniform samples, everything in range: p50/p95/p99/p99.9 must
        // land within one bin width of the exact sorted-sample value.
        let mut rng = Rng::from_label("hist/interp-uniform");
        let xs: Vec<f64> = (0..10_000).map(|_| rng.range(0.0, 100.0)).collect();
        let mut h = StreamHist::new(0.0, 100.0, 200);
        for x in &xs {
            h.record(*x);
        }
        let w = 100.0 / 200.0;
        for q in [0.5, 0.95, 0.99, 0.999] {
            let exact = exact_quantile(&xs, q);
            let got = h.quantile_interp(q);
            assert!((got - exact).abs() <= w,
                    "q={q}: interp {got} vs exact {exact} (bin width {w})");
        }
        assert_eq!(h.quantile_interp(0.0), h.min());
        assert_eq!(h.quantile_interp(1.0), h.max());
    }

    #[test]
    fn quantile_interp_overflow_policy_is_the_exact_max() {
        // Exponential samples with the histogram top edge inside the
        // tail: ~0.7% of the mass overflows. Quantiles that resolve in
        // the binned mass stay within a bin of exact; a quantile landing
        // in the overflow tail reports the exact observed max — the
        // conservative bound, never an under-report.
        let mut rng = Rng::from_label("hist/interp-exp");
        let xs: Vec<f64> = (0..20_000)
            .map(|_| -20.0 * rng.f64().max(1e-12).ln())
            .collect();
        let mut h = StreamHist::new(0.0, 100.0, 100);
        for x in &xs {
            h.record(*x);
        }
        assert!(h.overflow_count() > 0, "tail must overflow for this test");
        for q in [0.5, 0.95, 0.99] {
            let exact = exact_quantile(&xs, q);
            let got = h.quantile_interp(q);
            assert!((got - exact).abs() <= 1.0,
                    "q={q}: interp {got} vs exact {exact}");
        }
        // p99.9 of Exp(20) sits near 138 — past the top edge.
        assert_eq!(h.quantile_interp(0.999), h.max());
        assert!(h.max() > 100.0);
    }

    #[test]
    fn quantile_interp_underflow_policy_is_the_exact_min() {
        let mut h = StreamHist::new(0.0, 10.0, 10);
        for x in [-5.0, -4.0, -3.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0] {
            h.record(x);
        }
        // Ranks 0..3 are underflow mass: the exact min comes back.
        assert_eq!(h.quantile_interp(0.1), -5.0);
        assert_eq!(h.quantile_interp(0.2), -5.0);
        // In-range mass interpolates normally.
        assert!(h.quantile_interp(0.9) > 4.0);
    }

    #[test]
    fn json_round_trips_exactly() {
        let mut h = StreamHist::new(0.8, 1.6, 32);
        let mut rng = Rng::from_label("hist/json");
        for _ in 0..200 {
            h.record(rng.range(0.7, 1.7));
        }
        let j = h.to_json();
        let text = j.to_string_pretty();
        let back = StreamHist::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(h, back);

        // An empty histogram (infinite min/max sentinels) must round-trip
        // too — `fleet report` may load summaries with unused sub-hists.
        let empty = StreamHist::new(0.0, 1.0, 4);
        let text = empty.to_json().to_string_pretty();
        let back = StreamHist::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(empty, back);
    }

    #[test]
    fn corrupt_json_fails_loudly() {
        let h = StreamHist::new(0.0, 1.0, 4);
        let good = h.to_json().to_string_pretty();
        let bad = good.replace("\"n\": 0", "\"n\": 7");
        let j = Json::parse(&bad).unwrap();
        assert!(StreamHist::from_json(&j).is_err(), "count mismatch accepted");
    }
}
