//! Mergeable streaming histogram — the fixed-memory aggregation unit of
//! the fleet campaign engine (`fleet`).
//!
//! A campaign over O(10^4) nodes must never materialize per-node results,
//! so every distribution the fleet reports (speedup, latency, DIMM
//! temperature) is accumulated into one of these: a fixed bin grid plus
//! exact extremes and a fixed-point sum. The design constraint is the
//! determinism contract of `exec::Pool::run_fold` — merging per-worker
//! partials must give bit-identical results for *any* partition of the
//! input, so every field is an exact commutative monoid:
//!
//! * bin/underflow/overflow counts — `u64` addition,
//! * `min`/`max` — exact and order-free on finite floats,
//! * the sum — fixed-point `i128` (value × 2^32, ties-to-even at record
//!   time), so addition is associative, unlike `f64` accumulation whose
//!   rounding depends on grouping.
//!
//! The price is ~2^-33 relative quantization on means — invisible at the
//! 3-digit precision any report prints — and quantile resolution limited
//! to the bin width, which is the point of a histogram.

use std::collections::BTreeMap;

use super::json::Json;

/// Fixed-point scale for the exact sum: 32 fractional bits.
const FX_SCALE: f64 = 4294967296.0; // 2^32

/// A streaming histogram over `[lo, hi)` with `bins` equal-width bins
/// plus underflow/overflow counters. See the module docs for why every
/// field is an exact commutative accumulator.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamHist {
    lo: f64,
    hi: f64,
    counts: Vec<u64>,
    underflow: u64,
    overflow: u64,
    n: u64,
    min: f64,
    max: f64,
    /// Sum of recorded values in 32.32-ish fixed point (i128 is wide
    /// enough for ~2^64 samples of magnitude 2^32).
    sum_fx: i128,
}

impl StreamHist {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(bins >= 1, "histogram needs at least one bin");
        assert!(lo.is_finite() && hi.is_finite() && lo < hi,
                "bad histogram range [{lo}, {hi})");
        StreamHist {
            lo,
            hi,
            counts: vec![0; bins],
            underflow: 0,
            overflow: 0,
            n: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            sum_fx: 0,
        }
    }

    pub fn record(&mut self, x: f64) {
        assert!(x.is_finite(), "histogram got a non-finite sample: {x}");
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let w = (self.hi - self.lo) / self.counts.len() as f64;
            let i = (((x - self.lo) / w) as usize).min(self.counts.len() - 1);
            self.counts[i] += 1;
        }
        self.n += 1;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        self.sum_fx += (x * FX_SCALE).round() as i128;
    }

    /// Merge another histogram over the *same* grid into this one.
    /// Exact and commutative — the partition of samples across partials
    /// never shows in the merged result.
    pub fn merge(&mut self, other: &StreamHist) {
        assert!(self.lo == other.lo && self.hi == other.hi
                    && self.counts.len() == other.counts.len(),
                "merging histograms over different grids");
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.underflow += other.underflow;
        self.overflow += other.overflow;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.sum_fx += other.sum_fx;
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Mean of every recorded sample (fixed-point exact up to the 2^-32
    /// per-sample quantization).
    pub fn mean(&self) -> f64 {
        assert!(self.n > 0, "mean of an empty histogram");
        (self.sum_fx as f64 / FX_SCALE) / self.n as f64
    }

    /// Nearest-rank quantile at bin resolution: the center of the bin the
    /// rank lands in (the exact `min`/`max` for the under/overflow tails).
    pub fn quantile(&self, q: f64) -> f64 {
        assert!(self.n > 0, "quantile of an empty histogram");
        assert!((0.0..=1.0).contains(&q));
        let rank = ((self.n - 1) as f64 * q).round() as u64;
        let mut seen = self.underflow;
        if rank < seen {
            return self.min;
        }
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if rank < seen {
                return self.lo + (i as f64 + 0.5) * w;
            }
        }
        self.max
    }

    /// CDF points `(bin upper edge, cumulative fraction)` for plotting;
    /// the under/overflow tails fold into the first/last point.
    pub fn cdf(&self) -> Vec<(f64, f64)> {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        let mut cum = self.underflow;
        let mut out = Vec::with_capacity(self.counts.len());
        for (i, c) in self.counts.iter().enumerate() {
            cum += c;
            let mut frac = cum;
            if i == self.counts.len() - 1 {
                frac += self.overflow;
            }
            out.push((self.lo + (i as f64 + 1.0) * w,
                      frac as f64 / self.n.max(1) as f64));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("lo".into(), Json::Num(self.lo));
        m.insert("hi".into(), Json::Num(self.hi));
        m.insert("counts".into(),
                 Json::Arr(self.counts.iter()
                           .map(|c| Json::Num(*c as f64)).collect()));
        m.insert("underflow".into(), Json::Num(self.underflow as f64));
        m.insert("overflow".into(), Json::Num(self.overflow as f64));
        m.insert("n".into(), Json::Num(self.n as f64));
        // An empty histogram has infinite sentinels, which JSON cannot
        // spell; follow the registry's convention (infinite `max_c`) and
        // write them as null.
        let extreme = |x: f64| if x.is_finite() { Json::Num(x) } else { Json::Null };
        m.insert("min".into(), extreme(self.min));
        m.insert("max".into(), extreme(self.max));
        // i128 exceeds f64's exact-integer range; store as a decimal
        // string so the round trip stays bit-exact.
        m.insert("sum_fx".into(), Json::Str(self.sum_fx.to_string()));
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<StreamHist> {
        let num = |k: &str| -> anyhow::Result<f64> {
            j.get(k).and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("hist missing number `{k}`"))
        };
        let count = |k: &str| -> anyhow::Result<u64> {
            let x = num(k)?;
            anyhow::ensure!(x >= 0.0 && x.fract() == 0.0,
                            "hist `{k}` is not a count: {x}");
            Ok(x as u64)
        };
        let counts = j.get("counts").and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("hist missing `counts`"))?
            .iter()
            .map(|c| {
                let x = c.as_f64()
                    .ok_or_else(|| anyhow::anyhow!("non-number bin count"))?;
                anyhow::ensure!(x >= 0.0 && x.fract() == 0.0,
                                "bin count is not a count: {x}");
                Ok(x as u64)
            })
            .collect::<anyhow::Result<Vec<u64>>>()?;
        anyhow::ensure!(!counts.is_empty(), "hist has no bins");
        let sum_fx = j.get("sum_fx").and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("hist missing `sum_fx`"))?
            .parse::<i128>()
            .map_err(|e| anyhow::anyhow!("bad hist sum_fx: {e}"))?;
        // Null min/max are the empty-histogram sentinels.
        let extreme = |k: &str, empty: f64| -> anyhow::Result<f64> {
            match j.get(k) {
                Some(Json::Null) => Ok(empty),
                Some(v) => v.as_f64()
                    .ok_or_else(|| anyhow::anyhow!("hist `{k}` is not a number")),
                None => Err(anyhow::anyhow!("hist missing `{k}`")),
            }
        };
        let h = StreamHist {
            lo: num("lo")?,
            hi: num("hi")?,
            counts,
            underflow: count("underflow")?,
            overflow: count("overflow")?,
            n: count("n")?,
            min: extreme("min", f64::INFINITY)?,
            max: extreme("max", f64::NEG_INFINITY)?,
            sum_fx,
        };
        anyhow::ensure!(h.lo.is_finite() && h.hi.is_finite() && h.lo < h.hi,
                        "bad hist range [{}, {})", h.lo, h.hi);
        let binned: u64 = h.counts.iter().sum();
        anyhow::ensure!(binned + h.underflow + h.overflow == h.n,
                        "hist counts do not add up to n");
        Ok(h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn records_and_summarizes() {
        let mut h = StreamHist::new(0.0, 10.0, 10);
        for x in [0.5, 1.5, 2.5, 9.99, -1.0, 12.0] {
            h.record(x);
        }
        assert_eq!(h.count(), 6);
        assert_eq!(h.min(), -1.0);
        assert_eq!(h.max(), 12.0);
        assert!((h.mean() - (0.5 + 1.5 + 2.5 + 9.99 - 1.0 + 12.0) / 6.0).abs()
                < 1e-6);
        // CDF is monotone and ends at 1.
        let cdf = h.cdf();
        for w in cdf.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
        assert_eq!(cdf.last().unwrap().1, 1.0);
    }

    #[test]
    fn merge_is_partition_invariant() {
        // The determinism contract: any split of the sample stream into
        // partials merges to the bit-identical histogram, in any order.
        let mut rng = Rng::from_label("hist/partition");
        let xs: Vec<f64> = (0..500).map(|_| rng.range(-0.5, 3.5)).collect();
        let mut whole = StreamHist::new(0.0, 3.0, 24);
        for x in &xs {
            whole.record(*x);
        }
        for chunk in [1usize, 7, 64, 500] {
            let mut parts: Vec<StreamHist> = xs
                .chunks(chunk)
                .map(|c| {
                    let mut h = StreamHist::new(0.0, 3.0, 24);
                    for x in c {
                        h.record(*x);
                    }
                    h
                })
                .collect();
            // Merge in reverse order too — commutativity.
            parts.reverse();
            let mut merged = StreamHist::new(0.0, 3.0, 24);
            for p in &parts {
                merged.merge(p);
            }
            assert_eq!(merged, whole, "chunk {chunk}");
        }
    }

    #[test]
    fn quantiles_land_in_bins() {
        let mut h = StreamHist::new(0.0, 100.0, 100);
        for i in 0..1000 {
            h.record(i as f64 / 10.0);
        }
        assert!((h.quantile(0.5) - 50.0).abs() <= 1.0);
        assert!((h.quantile(0.1) - 10.0).abs() <= 1.0);
        assert_eq!(h.quantile(0.0), 0.5); // center of the first bin
        assert!(h.quantile(1.0) >= 99.0);
    }

    #[test]
    fn json_round_trips_exactly() {
        let mut h = StreamHist::new(0.8, 1.6, 32);
        let mut rng = Rng::from_label("hist/json");
        for _ in 0..200 {
            h.record(rng.range(0.7, 1.7));
        }
        let j = h.to_json();
        let text = j.to_string_pretty();
        let back = StreamHist::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(h, back);

        // An empty histogram (infinite min/max sentinels) must round-trip
        // too — `fleet report` may load summaries with unused sub-hists.
        let empty = StreamHist::new(0.0, 1.0, 4);
        let text = empty.to_json().to_string_pretty();
        let back = StreamHist::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(empty, back);
    }

    #[test]
    fn corrupt_json_fails_loudly() {
        let h = StreamHist::new(0.0, 1.0, 4);
        let good = h.to_json().to_string_pretty();
        let bad = good.replace("\"n\": 0", "\"n\": 7");
        let j = Json::parse(&bad).unwrap();
        assert!(StreamHist::from_json(&j).is_err(), "count mismatch accepted");
    }
}
