//! Cycle-level DDR3 memory-system simulator: device timing state machines,
//! the memory controller (FR-FCFS, row policies, refresh, AL-DRAM timing
//! hook), a bounded-MLP core model, and the full-system harness.

pub mod address;
pub mod controller;
pub mod cpu;
pub mod dram;
pub mod system;

pub use address::{AddrMap, RegionRemap, MAX_REMAP_REGIONS};
pub use controller::{Cmd, CmdKind, CmdSink, Controller, CtrlStats, Request,
                     RowPolicy};
pub use cpu::Core;
pub use dram::{Bank, BankState, Cycle, GateMutation, Rank, RegionCycles,
               MUTATION_SLACK};
pub use system::{ChannelConfig, ChannelStats, OpenLoopStats, System,
                 SystemConfig, SystemStats};
