//! Cycle-level DDR3 device model: per-bank state machines plus the rank-
//! level constraints (tRRD, tFAW, tRFC, shared data bus). The controller
//! may only issue a command when `can_*` says the JEDEC timing rules are
//! met; `issue_*` advances the state. All times are controller clock
//! cycles (tCK = 1.25 ns at DDR3-1600).

use crate::timing::TimingCycles;
use std::collections::VecDeque;
use std::sync::Arc;

pub type Cycle = u64;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BankState {
    Idle,
    Open(u64), // row open (row id)
}

#[derive(Debug, Clone)]
pub struct Bank {
    pub state: BankState,
    /// Earliest cycle an ACT may issue (tRC from last ACT, tRP from PRE).
    pub next_act: Cycle,
    /// Earliest cycle a column command may issue (tRCD from ACT).
    pub next_col: Cycle,
    /// Earliest cycle a PRE may issue (tRAS from ACT, tRTP/tWR from col).
    pub next_pre: Cycle,
    /// Bank-granular AL-DRAM (paper §5.2 future work): per-bank timing
    /// override for the core parameters; rank-level constraints (tRRD,
    /// tFAW, bus, tRFC) always come from the rank set.
    pub t_override: Option<TimingCycles>,
}

impl Bank {
    fn new() -> Self {
        Bank { state: BankState::Idle, next_act: 0, next_col: 0,
               next_pre: 0, t_override: None }
    }

    pub fn open_row(&self) -> Option<u64> {
        match self.state {
            BankState::Open(r) => Some(r),
            BankState::Idle => None,
        }
    }

    /// Earliest future cycle (strictly after `now`) at which one of this
    /// bank's gates (ACT / column / PRE) opens, or `Cycle::MAX` when every
    /// gate is already open. Device-level aggregate of the per-gate
    /// queries: between `now` and this cycle the bank's legality answers
    /// cannot change on their own. (The controller's `next_event_hint`
    /// uses the request-targeted `Rank::earliest_*` queries instead —
    /// this aggregate serves diagnostics and device-level tooling.)
    pub fn next_event(&self, now: Cycle) -> Cycle {
        let mut e = Cycle::MAX;
        for gate in [self.next_act, self.next_col, self.next_pre] {
            if gate > now && gate < e {
                e = gate;
            }
        }
        e
    }
}

/// Per-(bank, row-region) timing sets installed by the controller when a
/// region-indexed AL-DRAM table manages the channel. The region index is
/// the top row bits (`row >> shift`) — rows near the sense amps (low
/// index) are the fast ones. Takes precedence over the rank set and any
/// per-bank override for the *bank-scoped* parameters; rank-level gates
/// (tRRD, tFAW, data bus, tRFC) always come from the rank set.
#[derive(Debug, Clone, PartialEq)]
pub struct RegionCycles {
    pub regions_per_bank: usize,
    /// `row_bits - log2(regions_per_bank)`.
    pub shift: u32,
    /// Bank-major: `t[bank * regions_per_bank + region]`.
    pub t: Vec<TimingCycles>,
}

impl RegionCycles {
    #[inline]
    pub fn lookup(&self, bank: usize, row: u64) -> TimingCycles {
        let r = ((row >> self.shift) as usize).min(self.regions_per_bank - 1);
        self.t[bank * self.regions_per_bank + r]
    }
}

/// Seeded controller bugs for the protocol-checker mutation harness
/// (`repro check mutate`). Each variant perturbs exactly one timing gate
/// or the region lookup, always at the *deadline-baking* point inside
/// `issue_*` / `timings_for_row` — so the `can_*` predicates and the
/// time-skip `earliest_*` queries stay mutually consistent and the bug is
/// observable only in the emitted command stream, which is precisely what
/// the independent checker audits. `MUTATION_SLACK` cycles are shaved off
/// each mutated window.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GateMutation {
    /// ACT->column window too short.
    Trcd,
    /// PRE->ACT window too short.
    Trp,
    /// ACT->PRE window too short.
    Tras,
    /// ACT->ACT (different banks) window too short.
    Trrd,
    /// Rolling four-ACT window logged too early (fifth ACT admitted
    /// before the real window expires).
    Tfaw,
    /// Write recovery before PRE too short.
    Twr,
    /// Write->read turnaround too short.
    Twtr,
    /// Read->PRE window too short.
    Trtp,
    /// Column->column spacing too short.
    Tccd,
    /// Refresh fence released too early.
    Trfc,
    /// Read->write bus turnaround too short.
    Turnaround,
    /// Region lookup ignores the row: every row gets region 0's (fast)
    /// timings.
    RegionIgnoreRow,
    /// Region lookup mirrored: region r resolves to `regions-1-r`.
    RegionSwap,
    /// Refresh cadence stretched past the JEDEC 9x tREFI postponement
    /// bound (applied in `Controller::trefi`, not here).
    TrefiPostpone,
}

/// Cycles shaved off a mutated timing window.
pub const MUTATION_SLACK: u64 = 3;

/// One rank of DDR3 devices (8 banks).
#[derive(Debug, Clone)]
pub struct Rank {
    pub banks: Vec<Bank>,
    t: TimingCycles,
    /// Region-granular AL-DRAM timing (None = rank/bank granularity).
    /// Shared: every rank of a channel holds the same table, so the
    /// controller installs one `Arc` instead of per-rank copies.
    region: Option<Arc<RegionCycles>>,
    /// ACT-to-ACT (tRRD) gate.
    next_act_any: Cycle,
    /// Sliding window of the last 4 ACT times (tFAW).
    act_window: VecDeque<Cycle>,
    /// Earliest cycle the shared data bus is free.
    data_free: Cycle,
    /// Earliest cycle a READ may issue (tCCD, write->read tWTR).
    next_read: Cycle,
    /// Earliest cycle a WRITE may issue (tCCD, read->write turnaround).
    next_write: Cycle,
    /// Rank busy until (refresh).
    busy_until: Cycle,
    /// Seeded gate bug for the checker mutation harness (None = correct).
    mutation: Option<GateMutation>,
    /// Statistics: command counts.
    pub n_act: u64,
    pub n_pre: u64,
    pub n_read: u64,
    pub n_write: u64,
    pub n_refresh: u64,
    /// Cycles any row was open (for IDD3N vs IDD2N power weighting).
    open_cycles: u64,
    last_open_update: Cycle,
    open_banks: u32,
}

impl Rank {
    pub fn new(banks: usize, t: TimingCycles) -> Self {
        Rank {
            banks: (0..banks).map(|_| Bank::new()).collect(),
            t,
            region: None,
            next_act_any: 0,
            act_window: VecDeque::new(),
            data_free: 0,
            next_read: 0,
            next_write: 0,
            busy_until: 0,
            mutation: None,
            n_act: 0,
            n_pre: 0,
            n_read: 0,
            n_write: 0,
            n_refresh: 0,
            open_cycles: 0,
            last_open_update: 0,
            open_banks: 0,
        }
    }

    pub fn timings(&self) -> &TimingCycles {
        &self.t
    }

    /// Effective timing set for one bank (its override or the rank set).
    #[inline]
    pub fn bank_timings(&self, bank: usize) -> TimingCycles {
        self.banks[bank].t_override.unwrap_or(self.t)
    }

    /// Install a bank-granular timing override (None restores rank set).
    pub fn set_bank_timings(&mut self, bank: usize,
                            t: Option<TimingCycles>) {
        self.banks[bank].t_override = t;
    }

    /// Install (or clear) region-granular timing. Like `set_timings`,
    /// applied at a refresh boundary; in-flight constraints keep their
    /// already-computed deadlines. The table arrives behind an `Arc`:
    /// one allocation per epoch install, shared by all ranks.
    pub fn set_region_timings(&mut self, region: Option<Arc<RegionCycles>>) {
        if let Some(r) = &region {
            debug_assert_eq!(r.t.len(),
                             self.banks.len() * r.regions_per_bank);
        }
        self.region = region;
    }

    /// Effective timing set for one decoded (bank, row): the region
    /// entry when region timing is installed, else the bank override or
    /// rank set. All bank-scoped deadlines are baked at issue time from
    /// this lookup, which is what keeps the time-skip driver's gate
    /// queries (`earliest_*`) oblivious to region granularity.
    #[inline]
    pub fn timings_for_row(&self, bank: usize, row: u64) -> TimingCycles {
        match &self.region {
            Some(m) => match self.mutation {
                None => m.lookup(bank, row),
                Some(mu) => {
                    let mut r = ((row >> m.shift) as usize)
                        .min(m.regions_per_bank - 1);
                    match mu {
                        GateMutation::RegionIgnoreRow => r = 0,
                        GateMutation::RegionSwap => {
                            r = m.regions_per_bank - 1 - r;
                        }
                        _ => {}
                    }
                    m.t[bank * m.regions_per_bank + r]
                }
            },
            None => self.bank_timings(bank),
        }
    }

    /// Install (or clear) a seeded gate bug for the mutation harness.
    pub fn set_mutation(&mut self, m: Option<GateMutation>) {
        self.mutation = m;
    }

    /// `base` shaved by `MUTATION_SLACK` when `m` is the active mutation.
    #[inline]
    fn mutated(&self, m: GateMutation, base: u64) -> u64 {
        if self.mutation == Some(m) {
            base.saturating_sub(MUTATION_SLACK)
        } else {
            base
        }
    }

    /// AL-DRAM: swap the timing set (performed at a refresh boundary when
    /// the temperature bin changes; in-flight constraints keep their
    /// already-computed deadlines, which is exactly how a real controller
    /// applies a mode-register-less timing update).
    pub fn set_timings(&mut self, t: TimingCycles) {
        self.t = t;
    }

    fn track_open(&mut self, now: Cycle) {
        self.open_cycles += (now - self.last_open_update) * self.open_banks as u64;
        self.last_open_update = now;
    }

    /// Cycles of (bank x cycle) row-open time so far — power model input.
    pub fn open_bank_cycles(&self, now: Cycle) -> u64 {
        self.open_cycles + (now - self.last_open_update) * self.open_banks as u64
    }

    // ---- legality ------------------------------------------------------

    pub fn can_act(&self, bank: usize, now: Cycle) -> bool {
        let b = &self.banks[bank];
        b.state == BankState::Idle
            && now >= b.next_act
            && now >= self.next_act_any
            && now >= self.busy_until
            && (self.act_window.len() < 4
                || now >= self.act_window[0] + self.t.tfaw as u64)
    }

    pub fn can_read(&self, bank: usize, row: u64, now: Cycle) -> bool {
        let b = &self.banks[bank];
        b.state == BankState::Open(row)
            && now >= b.next_col
            && now >= self.next_read
            && now >= self.busy_until
    }

    pub fn can_write(&self, bank: usize, row: u64, now: Cycle) -> bool {
        let b = &self.banks[bank];
        b.state == BankState::Open(row)
            && now >= b.next_col
            && now >= self.next_write
            && now >= self.busy_until
    }

    pub fn can_pre(&self, bank: usize, now: Cycle) -> bool {
        let b = &self.banks[bank];
        matches!(b.state, BankState::Open(_))
            && now >= b.next_pre
            && now >= self.busy_until
    }

    pub fn can_refresh(&self, now: Cycle) -> bool {
        now >= self.busy_until
            && self.banks.iter().all(|b| b.state == BankState::Idle)
            && self.banks.iter().all(|b| now >= b.next_act)
    }

    pub fn all_banks_idle(&self) -> bool {
        self.banks.iter().all(|b| b.state == BankState::Idle)
    }

    // ---- issue ---------------------------------------------------------

    pub fn issue_act(&mut self, bank: usize, row: u64, now: Cycle) {
        debug_assert!(self.can_act(bank, now));
        self.track_open(now);
        let rank_t = self.t;
        let t = self.timings_for_row(bank, row);
        let trcd = self.mutated(GateMutation::Trcd, t.trcd as u64);
        let tras = self.mutated(GateMutation::Tras, t.tras as u64);
        let trrd = self.mutated(GateMutation::Trrd, rank_t.trrd as u64);
        let logged = if self.mutation == Some(GateMutation::Tfaw) {
            now.saturating_sub(MUTATION_SLACK)
        } else {
            now
        };
        let b = &mut self.banks[bank];
        b.state = BankState::Open(row);
        b.next_col = now + trcd;
        b.next_pre = now + tras;
        b.next_act = now + t.trc as u64;
        self.next_act_any = now + trrd;
        self.act_window.push_back(logged);
        if self.act_window.len() > 4 {
            self.act_window.pop_front();
        }
        self.open_banks += 1;
        self.n_act += 1;
    }

    /// Returns the cycle the read data burst completes.
    pub fn issue_read(&mut self, bank: usize, row: u64, now: Cycle) -> Cycle {
        debug_assert!(self.can_read(bank, row, now));
        let t = self.timings_for_row(bank, row);
        let data_start = (now + t.tcl as u64).max(self.data_free);
        let data_end = data_start + t.tburst as u64;
        self.data_free = data_end;
        self.next_read = now + self.mutated(GateMutation::Tccd, t.tccd as u64);
        // read->write turnaround: write CAS may not collide on the bus.
        let turn = (t.tcl as u64 + t.tburst as u64 + 2)
            .saturating_sub(t.tcwl as u64);
        self.next_write = self
            .next_write
            .max(now + self.mutated(GateMutation::Turnaround, turn));
        let trtp = self.mutated(GateMutation::Trtp, t.trtp as u64);
        let b = &mut self.banks[bank];
        b.next_pre = b.next_pre.max(now + trtp);
        self.n_read += 1;
        data_end
    }

    /// Returns the cycle the write data burst completes (write latency is
    /// posted; the requester does not wait for the array restore).
    pub fn issue_write(&mut self, bank: usize, row: u64, now: Cycle) -> Cycle {
        debug_assert!(self.can_write(bank, row, now));
        let t = self.timings_for_row(bank, row);
        let data_start = (now + t.tcwl as u64).max(self.data_free);
        let data_end = data_start + t.tburst as u64;
        self.data_free = data_end;
        self.next_write = now + self.mutated(GateMutation::Tccd, t.tccd as u64);
        // write->read same rank: tWTR after the data burst.
        let twtr = self.mutated(GateMutation::Twtr, t.twtr as u64);
        self.next_read = self.next_read.max(data_end + twtr);
        let twr = self.mutated(GateMutation::Twr, t.twr as u64);
        let b = &mut self.banks[bank];
        // tWR: write recovery after the data burst before PRE.
        b.next_pre = b.next_pre.max(data_end + twr);
        self.n_write += 1;
        data_end
    }

    pub fn issue_pre(&mut self, bank: usize, now: Cycle) {
        debug_assert!(self.can_pre(bank, now));
        self.track_open(now);
        // tRP is region-scoped: resolve via the row being closed.
        let row = self.banks[bank].open_row().unwrap_or(0);
        let t = self.timings_for_row(bank, row);
        let trp = self.mutated(GateMutation::Trp, t.trp as u64);
        let b = &mut self.banks[bank];
        b.state = BankState::Idle;
        b.next_act = b.next_act.max(now + trp);
        self.open_banks -= 1;
        self.n_pre += 1;
    }

    pub fn issue_refresh(&mut self, now: Cycle) {
        debug_assert!(self.can_refresh(now));
        self.busy_until = now + self.mutated(GateMutation::Trfc,
                                             self.t.trfc as u64);
        for b in &mut self.banks {
            b.next_act = b.next_act.max(self.busy_until);
        }
        self.n_refresh += 1;
    }

    // ---- time-skip gate queries ----------------------------------------
    //
    // Exact earliest-legal-cycle counterparts of the `can_*` predicates on
    // *frozen* rank state: for any t, `can_x(.., t)` holds iff the bank is
    // in the right state and `t >= earliest_x(..)`. The controller's
    // `next_event_hint` uses these to find the next cycle a command could
    // issue without polling every intermediate cycle.

    /// Earliest cycle an ACT to `bank` becomes legal (assumes the bank is
    /// idle; tRC/tRP via `next_act`, tRRD, tFAW window, refresh busy).
    pub fn earliest_act(&self, bank: usize) -> Cycle {
        let mut e = self.banks[bank]
            .next_act
            .max(self.next_act_any)
            .max(self.busy_until);
        if self.act_window.len() >= 4 {
            e = e.max(self.act_window[0] + self.t.tfaw as u64);
        }
        e
    }

    /// Earliest cycle a column command to `bank` becomes legal (assumes
    /// the right row is open; tRCD via `next_col`, tCCD/turnaround, busy).
    pub fn earliest_col(&self, bank: usize, is_write: bool) -> Cycle {
        let turn = if is_write { self.next_write } else { self.next_read };
        self.banks[bank].next_col.max(turn).max(self.busy_until)
    }

    /// Earliest cycle a PRE to `bank` becomes legal (assumes a row is
    /// open; tRAS/tRTP/tWR via `next_pre`, refresh busy).
    pub fn earliest_pre(&self, bank: usize) -> Cycle {
        self.banks[bank].next_pre.max(self.busy_until)
    }

    /// Earliest cycle REF becomes legal (assumes all banks idle).
    pub fn earliest_refresh(&self) -> Cycle {
        self.banks
            .iter()
            .map(|b| b.next_act)
            .fold(self.busy_until, Cycle::max)
    }

    /// Earliest future cycle at which any rank- or bank-level gate changes
    /// state (tRRD, tFAW expiry, data bus, read/write turnaround, refresh
    /// busy, or any per-bank gate), or `Cycle::MAX` if none will. Like
    /// `Bank::next_event`, this is the device-level aggregate view; the
    /// scheduler's hint path queries the targeted `earliest_*` gates.
    pub fn next_event(&self, now: Cycle) -> Cycle {
        let mut e = Cycle::MAX;
        let mut gates = [
            self.next_act_any,
            self.data_free,
            self.next_read,
            self.next_write,
            self.busy_until,
            Cycle::MAX,
        ];
        if self.act_window.len() >= 4 {
            gates[5] = self.act_window[0] + self.t.tfaw as u64;
        }
        for gate in gates {
            if gate > now && gate < e {
                e = gate;
            }
        }
        for b in &self.banks {
            let be = b.next_event(now);
            if be < e {
                e = be;
            }
        }
        e
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::timing::TimingParams;

    fn rank() -> Rank {
        Rank::new(8, TimingParams::ddr3_standard().to_cycles(1.25))
    }

    #[test]
    fn act_then_read_honors_trcd() {
        let mut r = rank();
        assert!(r.can_act(0, 0));
        r.issue_act(0, 42, 0);
        let trcd = r.timings().trcd as u64;
        assert!(!r.can_read(0, 42, trcd - 1));
        assert!(r.can_read(0, 42, trcd));
        assert!(!r.can_read(0, 43, trcd), "wrong row must not read");
    }

    #[test]
    fn pre_honors_tras_and_act_honors_trp() {
        let mut r = rank();
        r.issue_act(0, 1, 0);
        let tras = r.timings().tras as u64;
        let trp = r.timings().trp as u64;
        assert!(!r.can_pre(0, tras - 1));
        assert!(r.can_pre(0, tras));
        r.issue_pre(0, tras);
        assert!(!r.can_act(0, tras + trp - 1));
        assert!(r.can_act(0, tras + trp));
    }

    #[test]
    fn trrd_and_tfaw_limit_activates() {
        let mut r = rank();
        let trrd = r.timings().trrd as u64;
        let tfaw = r.timings().tfaw as u64;
        let mut now = 0;
        for b in 0..4 {
            assert!(r.can_act(b, now));
            r.issue_act(b, 0, now);
            now += trrd;
        }
        // 5th ACT within the tFAW window of the 1st must wait.
        assert!(!r.can_act(4, now));
        assert!(r.can_act(4, tfaw.max(now)));
    }

    #[test]
    fn write_recovery_blocks_pre() {
        let mut r = rank();
        r.issue_act(0, 7, 0);
        let t = *r.timings();
        let col = t.trcd as u64;
        let data_end = r.issue_write(0, 7, col);
        assert_eq!(data_end, col + t.tcwl as u64 + t.tburst as u64);
        let pre_ok = data_end + t.twr as u64;
        assert!(!r.can_pre(0, pre_ok - 1));
        assert!(r.can_pre(0, pre_ok));
    }

    #[test]
    fn refresh_needs_idle_banks_and_blocks_for_trfc() {
        let mut r = rank();
        r.issue_act(0, 1, 0);
        assert!(!r.can_refresh(100));
        let tras = r.timings().tras as u64;
        let trp = r.timings().trp as u64;
        r.issue_pre(0, tras);
        let idle = tras + trp;
        assert!(r.can_refresh(idle));
        r.issue_refresh(idle);
        let trfc = r.timings().trfc as u64;
        assert!(!r.can_act(1, idle + trfc - 1));
        assert!(r.can_act(1, idle + trfc));
    }

    #[test]
    fn reduced_timings_shorten_the_critical_path() {
        let std = TimingParams::ddr3_standard();
        let fast = std.reduced(0.27, 0.32, 0.33, 0.18);
        let (ts, tf) = (std.to_cycles(1.25), fast.to_cycles(1.25));
        assert!(tf.trcd < ts.trcd);
        assert!(tf.tras < ts.tras);
        assert!(tf.twr < ts.twr);
        assert!(tf.trp < ts.trp);
        // A full row-miss cycle (ACT..PRE..ACT) is shorter.
        assert!(tf.trc < ts.trc);
    }

    #[test]
    fn earliest_gates_match_can_predicates() {
        // Time-skip contract: for frozen rank state, `can_x(t)` flips from
        // false to true exactly at `earliest_x()`.
        let mut r = rank();
        r.issue_act(0, 1, 0);
        let col = r.earliest_col(0, false);
        assert!(!r.can_read(0, 1, col - 1));
        assert!(r.can_read(0, 1, col));
        let colw = r.earliest_col(0, true);
        assert!(!r.can_write(0, 1, colw - 1));
        assert!(r.can_write(0, 1, colw));
        let pre = r.earliest_pre(0);
        assert!(!r.can_pre(0, pre - 1));
        assert!(r.can_pre(0, pre));
        let act = r.earliest_act(1);
        assert!(!r.can_act(1, act - 1));
        assert!(r.can_act(1, act));
        // next_event reports the first future gate change.
        let e = r.next_event(0);
        assert!(e > 0 && e <= act, "first gate {e} vs trrd gate {act}");
    }

    #[test]
    fn earliest_refresh_matches_can_refresh() {
        let mut r = rank();
        r.issue_act(0, 1, 0);
        let tras = r.timings().tras as u64;
        r.issue_pre(0, tras);
        let gate = r.earliest_refresh();
        assert!(!r.can_refresh(gate - 1));
        assert!(r.can_refresh(gate));
        r.issue_refresh(gate);
        let gate2 = r.earliest_refresh();
        assert_eq!(gate2, gate + r.timings().trfc as u64);
        assert!(r.can_refresh(gate2));
    }

    #[test]
    fn data_bus_serializes_bursts() {
        let mut r = rank();
        r.issue_act(0, 1, 0);
        r.issue_act(1, 1, r.timings().trrd as u64);
        let t = *r.timings();
        let col0 = t.trcd as u64 + t.trrd as u64;
        let end0 = r.issue_read(0, 1, col0);
        let col1 = col0 + t.tccd as u64;
        let end1 = r.issue_read(1, 1, col1);
        assert!(end1 >= end0 + t.tburst as u64,
                "bursts overlap: {end0} {end1}");
    }
}

#[cfg(test)]
mod bank_override_tests {
    use super::*;
    use crate::timing::TimingParams;

    #[test]
    fn per_bank_override_applies_only_to_that_bank() {
        let std = TimingParams::ddr3_standard();
        let fast = std.reduced(0.27, 0.32, 0.33, 0.18);
        let mut r = Rank::new(8, std.to_cycles(1.25));
        r.set_bank_timings(2, Some(fast.to_cycles(1.25)));

        // Bank 2 opens its column gate earlier than bank 0.
        r.issue_act(0, 1, 0);
        let trrd = r.timings().trrd as u64;
        r.issue_act(2, 1, trrd);
        let trcd_std = std.to_cycles(1.25).trcd as u64;
        let trcd_fast = fast.to_cycles(1.25).trcd as u64;
        assert!(trcd_fast < trcd_std);
        assert!(!r.can_read(0, 1, trcd_std - 1));
        assert!(r.can_read(0, 1, trcd_std));
        assert!(r.can_read(2, 1, trrd + trcd_fast));
    }

    #[test]
    fn clearing_override_restores_rank_timings() {
        let std = TimingParams::ddr3_standard();
        let fast = std.reduced(0.27, 0.32, 0.33, 0.18);
        let mut r = Rank::new(8, std.to_cycles(1.25));
        r.set_bank_timings(5, Some(fast.to_cycles(1.25)));
        assert_eq!(r.bank_timings(5), fast.to_cycles(1.25));
        r.set_bank_timings(5, None);
        assert_eq!(r.bank_timings(5), *r.timings());
    }

    #[test]
    fn region_timings_select_by_row_region() {
        let std = TimingParams::ddr3_standard();
        let fast = std.reduced(0.27, 0.32, 0.33, 0.18);
        let mut r = Rank::new(8, std.to_cycles(1.25));
        // 2 regions per bank over 15 row bits: region 0 (rows below
        // 1<<14) fast, region 1 standard — for every bank.
        let mut t = Vec::new();
        for _ in 0..8 {
            t.push(fast.to_cycles(1.25));
            t.push(std.to_cycles(1.25));
        }
        r.set_region_timings(Some(Arc::new(RegionCycles {
            regions_per_bank: 2,
            shift: 14,
            t,
        })));
        let low_row = 100u64;
        let high_row = 1 << 14;
        assert_eq!(r.timings_for_row(0, low_row), fast.to_cycles(1.25));
        assert_eq!(r.timings_for_row(0, high_row), std.to_cycles(1.25));

        // ACT to a fast-region row opens the column gate sooner.
        let trcd_fast = fast.to_cycles(1.25).trcd as u64;
        let trcd_std = std.to_cycles(1.25).trcd as u64;
        r.issue_act(0, low_row, 0);
        assert!(r.can_read(0, low_row, trcd_fast));
        let trrd = r.timings().trrd as u64;
        r.issue_act(1, high_row, trrd);
        assert!(!r.can_read(1, high_row, trrd + trcd_std - 1));
        assert!(r.can_read(1, high_row, trrd + trcd_std));

        // PRE resolves tRP through the open row's region.
        let tras_fast = fast.to_cycles(1.25).tras as u64;
        let trp_fast = fast.to_cycles(1.25).trp as u64;
        assert!(r.can_pre(0, tras_fast));
        r.issue_pre(0, tras_fast);
        assert!(!r.can_act(0, tras_fast + trp_fast - 1));

        // Clearing restores the rank set.
        r.set_region_timings(None);
        assert_eq!(r.timings_for_row(0, low_row), *r.timings());
    }

    #[test]
    fn rank_constraints_stay_shared_under_overrides() {
        let std = TimingParams::ddr3_standard();
        let fast = std.reduced(0.27, 0.32, 0.33, 0.18);
        let mut r = Rank::new(8, std.to_cycles(1.25));
        for b in 0..8 {
            r.set_bank_timings(b, Some(fast.to_cycles(1.25)));
        }
        // tRRD/tFAW still enforced at rank level (standard values).
        let trrd = r.timings().trrd as u64;
        r.issue_act(0, 0, 0);
        assert!(!r.can_act(1, trrd - 1));
        assert!(r.can_act(1, trrd));
    }
}
