//! Trace-driven core model: an out-of-order core abstraction with bounded
//! MLP (outstanding misses), a reorder-buffer run-ahead limit, and
//! dependent-load support (pointer chasing). Deliberately simple — the
//! paper's Fig 4 effect is the translation of DRAM latency into IPC as a
//! function of memory intensity, which this captures.
//!
//! The core consumes its [`RequestSource`] in batches: `fill` refills a
//! core-owned buffer [`crate::workloads::SOURCE_BATCH`] references at a
//! time, so the hot loop pays one virtual call per batch instead of one
//! per reference (the `SPEEDUP[SOURCE]` benchmark line measures the
//! difference). A source that returns 0 from `fill` is exhausted — e.g.
//! a replayed trace run past its recorded horizon — and the core then
//! retires nothing further and stalls deterministically.

use super::controller::Request;
use crate::workloads::{MemRef, RequestSource};

/// CPU-to-DRAM-controller clock ratio (3.2 GHz core, 800 MHz controller).
pub const CPU_PER_DRAM: u32 = 4;
/// Peak retire width (instructions per CPU cycle).
pub const IPC_MAX: u32 = 4;
/// Max instructions the core may run ahead of the oldest outstanding miss.
pub const ROB_INSTS: u64 = 192;
/// Max outstanding read misses (MSHRs).
pub const MAX_MLP: usize = 6;

#[derive(Debug, Clone, Copy)]
struct Outstanding {
    id: u64,
    inst_pos: u64,
}

pub struct Core {
    pub id: usize,
    source: Box<dyn RequestSource>,
    /// Batched refill buffer (consumed front to back, then refilled).
    buf: Vec<MemRef>,
    buf_pos: usize,
    /// The source returned an empty batch: no further references exist.
    exhausted: bool,
    /// Instructions retired so far.
    pub insts: u64,
    /// Remaining non-memory instructions before the next reference.
    gap_left: u64,
    next_ref: Option<MemRef>,
    outstanding: Vec<Outstanding>,
    next_req_id: u64,
    /// Stalled-cycle statistics.
    pub stall_cycles: u64,
    pub reads_issued: u64,
    pub writes_issued: u64,
    /// The last enqueue attempt was refused (queue full). Cleared on a
    /// successful send, a completion, or by the time-skip driver when any
    /// controller dequeues (queue space can only open up then).
    queue_blocked: bool,
}

impl Core {
    pub fn new(id: usize, source: Box<dyn RequestSource>) -> Self {
        Core {
            id,
            source,
            buf: Vec::new(),
            buf_pos: 0,
            exhausted: false,
            insts: 0,
            gap_left: 0,
            next_ref: None,
            outstanding: Vec::new(),
            next_req_id: 1,
            stall_cycles: 0,
            reads_issued: 0,
            writes_issued: 0,
            queue_blocked: false,
        }
    }

    fn refill(&mut self) {
        if self.next_ref.is_some() {
            return;
        }
        if self.buf_pos == self.buf.len() {
            self.buf.clear();
            self.buf_pos = 0;
            if self.exhausted || self.source.fill(&mut self.buf) == 0 {
                self.exhausted = true;
                return;
            }
        }
        let r = self.buf[self.buf_pos];
        self.buf_pos += 1;
        self.gap_left = r.gap_insts as u64;
        self.next_ref = Some(r);
    }

    pub fn on_completion(&mut self, req_id: u64) {
        self.outstanding.retain(|o| o.id != req_id);
        self.queue_blocked = false;
    }

    pub fn outstanding(&self) -> usize {
        self.outstanding.len()
    }

    /// Time-skip driver: a controller dequeued, so a refused enqueue may
    /// now succeed — re-arm `next_event`.
    pub fn clear_queue_block(&mut self) {
        self.queue_blocked = false;
    }

    /// True while no reference has been pulled from the source yet — the
    /// window in which `wrap_source` (the trace-capture hook) can still
    /// observe the whole stream.
    pub fn source_untouched(&self) -> bool {
        self.next_ref.is_none() && self.buf.is_empty() && !self.exhausted
    }

    /// Replace the source with a wrapper around it (the `mem::System`
    /// trace-capture hook). Must run before the first reference is
    /// pulled, or the recording would miss the consumed prefix.
    pub fn wrap_source(
        &mut self,
        f: impl FnOnce(Box<dyn RequestSource>) -> Box<dyn RequestSource>,
    ) {
        assert!(self.source_untouched(),
                "wrap_source after references were already pulled");
        let inner = std::mem::replace(
            &mut self.source, Box::new(crate::workloads::NullSource));
        self.source = f(inner);
    }

    fn rob_limit(&self) -> u64 {
        self.outstanding
            .iter()
            .map(|o| o.inst_pos + ROB_INSTS)
            .min()
            .unwrap_or(u64::MAX)
    }

    /// Earliest cycle >= `now` at which this core will next attempt to
    /// enqueue a memory request, or `u64::MAX` when it cannot act until an
    /// external event (a completion frees an MSHR / ROB or dependence
    /// slot, or a controller dequeue frees queue space). Until then the
    /// core only retires instructions and stalls deterministically, which
    /// `skip` replays in O(1) — the time-skip driver contract.
    pub fn next_event(&mut self, now: u64) -> u64 {
        self.refill();
        if self.queue_blocked {
            return u64::MAX;
        }
        let Some(r) = self.next_ref else {
            return u64::MAX; // source exhausted: nothing left to enqueue
        };
        let headroom = self.rob_limit().saturating_sub(self.insts);
        if self.gap_left > headroom {
            return u64::MAX; // the ROB fills before the gap is consumed
        }
        if !r.is_write
            && (self.outstanding.len() >= MAX_MLP
                || (r.dependent && !self.outstanding.is_empty()))
        {
            return u64::MAX; // issue attempt is MLP/dependence-blocked
        }
        now + self.gap_left / (CPU_PER_DRAM * IPC_MAX) as u64
    }

    /// Replay `span` cycles in O(1) during which the driver has proven
    /// (via `next_event`) that this core makes no enqueue attempt: retire
    /// up to the ROB limit at full width, then stall.
    pub fn skip(&mut self, span: u64) {
        if span == 0 {
            return;
        }
        self.refill();
        let width = (CPU_PER_DRAM * IPC_MAX) as u64;
        let headroom = self.rob_limit().saturating_sub(self.insts);
        let retirable = self.gap_left.min(headroom);
        let retired = retirable.min(width * span);
        self.insts += retired;
        self.gap_left -= retired;
        // Cycles that retire at least one instruction count as progress;
        // the rest are stalls — exactly what per-cycle stepping records.
        let progressing = retirable.div_euclid(width)
            + u64::from(retirable % width != 0);
        self.stall_cycles += span.saturating_sub(progressing);
    }

    /// Advance one DRAM-controller cycle. `try_send` submits a request to
    /// the memory system and returns the request id on acceptance.
    pub fn step(&mut self, now: u64,
                try_send: &mut dyn FnMut(Request) -> bool) {
        let mut budget = (CPU_PER_DRAM * IPC_MAX) as u64;
        let mut progressed = false;

        while budget > 0 {
            self.refill();

            // ROB limit: cannot retire past oldest outstanding + ROB_INSTS.
            let rob_limit = self
                .outstanding
                .iter()
                .map(|o| o.inst_pos + ROB_INSTS)
                .min()
                .unwrap_or(u64::MAX);

            if self.gap_left > 0 {
                let can = budget
                    .min(self.gap_left)
                    .min(rob_limit.saturating_sub(self.insts));
                if can == 0 {
                    break; // ROB full — stalled on a miss
                }
                self.insts += can;
                self.gap_left -= can;
                budget -= can;
                progressed = true;
                continue;
            }

            // gap exhausted: issue the memory reference.
            let Some(r) = self.next_ref else {
                break; // source exhausted — the core idles from here on
            };
            if r.is_write {
                let req = Request {
                    id: self.next_req_id,
                    core: self.id,
                    addr: r.addr,
                    is_write: true,
                    arrival: now,
                };
                if try_send(req) {
                    // Writes retire via the store buffer: non-blocking.
                    self.queue_blocked = false;
                    self.next_req_id += 1;
                    self.writes_issued += 1;
                    self.insts += 1;
                    budget -= 1;
                    self.next_ref = None;
                    progressed = true;
                } else {
                    self.queue_blocked = true;
                    break; // write queue full
                }
            } else {
                let dep_ok = !r.dependent || self.outstanding.is_empty();
                if self.outstanding.len() >= MAX_MLP || !dep_ok {
                    break;
                }
                let req = Request {
                    id: self.next_req_id,
                    core: self.id,
                    addr: r.addr,
                    is_write: false,
                    arrival: now,
                };
                if try_send(req) {
                    self.queue_blocked = false;
                    self.outstanding.push(Outstanding {
                        id: self.next_req_id,
                        inst_pos: self.insts,
                    });
                    self.next_req_id += 1;
                    self.reads_issued += 1;
                    self.insts += 1;
                    budget -= 1;
                    self.next_ref = None;
                    progressed = true;
                } else {
                    self.queue_blocked = true;
                    break; // read queue full
                }
            }
        }

        if !progressed {
            self.stall_cycles += 1;
        }
    }

    /// Retired instructions per CPU cycle.
    pub fn ipc(&self, dram_cycles: u64) -> f64 {
        if dram_cycles == 0 {
            return 0.0;
        }
        self.insts as f64 / (dram_cycles * CPU_PER_DRAM as u64) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{MemRef, RequestSource, SOURCE_BATCH};

    /// Source with a fixed gap and sequential addresses.
    struct FixedSource {
        gap: u32,
        addr: u64,
        dependent: bool,
    }

    impl RequestSource for FixedSource {
        fn fill(&mut self, out: &mut Vec<MemRef>) -> usize {
            for _ in 0..SOURCE_BATCH {
                self.addr += 64;
                out.push(MemRef { gap_insts: self.gap, addr: self.addr,
                                  is_write: false,
                                  dependent: self.dependent });
            }
            SOURCE_BATCH
        }
    }

    /// Source that yields exactly `left` references, then exhausts.
    struct FiniteSource {
        left: usize,
        addr: u64,
    }

    impl RequestSource for FiniteSource {
        fn fill(&mut self, out: &mut Vec<MemRef>) -> usize {
            let n = self.left.min(SOURCE_BATCH);
            for _ in 0..n {
                self.addr += 64;
                out.push(MemRef { gap_insts: 3, addr: self.addr,
                                  is_write: false, dependent: false });
            }
            self.left -= n;
            n
        }
    }

    #[test]
    fn compute_bound_core_hits_peak_ipc() {
        let mut core = Core::new(0, Box::new(FixedSource {
            gap: 100_000, addr: 0, dependent: false }));
        let mut send = |_req: Request| true;
        for now in 0..1000u64 {
            core.step(now, &mut send);
        }
        let ipc = core.ipc(1000);
        assert!((ipc - IPC_MAX as f64).abs() < 0.1, "ipc {ipc}");
    }

    #[test]
    fn mlp_bounds_outstanding_reads() {
        let mut core = Core::new(0, Box::new(FixedSource {
            gap: 0, addr: 0, dependent: false }));
        let mut send = |_req: Request| true; // memory never completes
        for now in 0..100u64 {
            core.step(now, &mut send);
        }
        assert_eq!(core.outstanding(), MAX_MLP);
        assert!(core.stall_cycles > 0);
    }

    #[test]
    fn dependent_loads_serialize() {
        let mut core = Core::new(0, Box::new(FixedSource {
            gap: 0, addr: 0, dependent: true }));
        let mut send = |_req: Request| true;
        for now in 0..100u64 {
            core.step(now, &mut send);
        }
        assert_eq!(core.outstanding(), 1, "pointer chase has MLP 1");
    }

    #[test]
    fn completion_unblocks_core() {
        let mut core = Core::new(0, Box::new(FixedSource {
            gap: 0, addr: 0, dependent: true }));
        let mut ids = Vec::new();
        {
            let mut send = |req: Request| {
                ids.push(req.id);
                true
            };
            for now in 0..10u64 {
                core.step(now, &mut send);
            }
        }
        assert_eq!(core.outstanding(), 1);
        let before = core.reads_issued;
        core.on_completion(ids[0]);
        let mut send2 = |_req: Request| true;
        core.step(11, &mut send2);
        assert!(core.reads_issued > before);
    }

    #[test]
    fn skip_replays_per_cycle_stepping_exactly() {
        // Time-skip contract: next_event + skip must reproduce the exact
        // per-cycle trajectory (insts, stalls, issue cycles) of step().
        let mk = || Core::new(0, Box::new(FixedSource {
            gap: 37, addr: 0, dependent: false }));
        let horizon = 1000u64;
        let mut a = mk();
        let mut issues_a = Vec::new();
        {
            let mut send = |req: Request| {
                issues_a.push(req.arrival);
                true
            };
            for now in 0..horizon {
                a.step(now, &mut send);
            }
        }
        let mut b = mk();
        let mut issues_b = Vec::new();
        let mut now = 0u64;
        while now < horizon {
            let e = b.next_event(now).min(horizon);
            if e > now {
                b.skip(e - now);
                now = e;
                continue;
            }
            let mut send = |req: Request| {
                issues_b.push(req.arrival);
                true
            };
            b.step(now, &mut send);
            now += 1;
        }
        assert_eq!(a.insts, b.insts);
        assert_eq!(a.stall_cycles, b.stall_cycles);
        assert_eq!(a.reads_issued, b.reads_issued);
        assert_eq!(issues_a, issues_b, "issue cycles must match");
    }

    #[test]
    fn rob_limits_runahead() {
        // One unfulfilled miss, then a huge gap: the core must stop at
        // ROB_INSTS past the miss.
        let mut core = Core::new(0, Box::new(FixedSource {
            gap: 1_000_000, addr: 0, dependent: false }));
        let mut send = |_req: Request| true;
        // First step issues the miss quickly (gap consumed across steps).
        for now in 0..100_000u64 {
            core.step(now, &mut send);
            if core.reads_issued >= 1 {
                break;
            }
        }
        let at_issue = core.insts;
        for now in 0..10_000u64 {
            core.step(200_000 + now, &mut send);
        }
        assert!(core.insts <= at_issue + ROB_INSTS,
                "ran ahead {} past miss", core.insts - at_issue);
    }

    #[test]
    fn exhausted_source_idles_the_core() {
        // A finite source (trace replay past its horizon): every recorded
        // reference issues, then the core stalls forever — identically
        // under step() and the next_event/skip time-skip pair.
        let total = 2 * SOURCE_BATCH + 7;
        let run_stepped = || {
            let mut core = Core::new(0, Box::new(FiniteSource {
                left: total, addr: 0 }));
            let mut done = Vec::new();
            for now in 0..2_000u64 {
                let mut sent = Vec::new();
                let mut s = |req: Request| {
                    sent.push(req.id);
                    true
                };
                core.step(now, &mut s);
                for id in sent {
                    core.on_completion(id); // zero-latency memory
                    done.push(id);
                }
            }
            (core.insts, core.stall_cycles, core.reads_issued, done.len())
        };
        let (insts, stalls, reads, done) = run_stepped();
        assert_eq!(reads as usize, total, "every recorded ref issues");
        assert_eq!(done, total);
        assert!(stalls > 0, "core must stall after exhaustion");

        // Time-skip driver agrees.
        let mut core = Core::new(0, Box::new(FiniteSource {
            left: total, addr: 0 }));
        let mut now = 0u64;
        let horizon = 2_000u64;
        let mut reads_fast = 0usize;
        while now < horizon {
            let e = core.next_event(now).min(horizon);
            if e > now {
                core.skip(e - now);
                now = e;
                continue;
            }
            let mut sent = Vec::new();
            let mut s = |req: Request| {
                sent.push(req.id);
                true
            };
            core.step(now, &mut s);
            for id in sent {
                core.on_completion(id);
                reads_fast += 1;
            }
            now += 1;
        }
        assert_eq!(core.insts, insts);
        assert_eq!(core.stall_cycles, stalls);
        assert_eq!(reads_fast, total);
    }

    #[test]
    fn wrap_source_only_before_first_pull() {
        let mut core = Core::new(0, Box::new(FixedSource {
            gap: 5, addr: 0, dependent: false }));
        assert!(core.source_untouched());
        core.wrap_source(|inner| inner); // identity wrap is fine up front
        let mut send = |_req: Request| true;
        core.step(0, &mut send);
        assert!(!core.source_untouched());
    }
}
