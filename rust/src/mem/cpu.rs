//! Trace-driven core model: an out-of-order core abstraction with bounded
//! MLP (outstanding misses), a reorder-buffer run-ahead limit, and
//! dependent-load support (pointer chasing). Deliberately simple — the
//! paper's Fig 4 effect is the translation of DRAM latency into IPC as a
//! function of memory intensity, which this captures.
//!
//! The core consumes its [`RequestSource`] in batches: `fill` refills a
//! core-owned buffer [`crate::workloads::SOURCE_BATCH`] references at a
//! time, so the hot loop pays one virtual call per batch instead of one
//! per reference (the `SPEEDUP[SOURCE]` benchmark line measures the
//! difference). A source that returns 0 from `fill` is exhausted — e.g.
//! a replayed trace run past its recorded horizon — and the core then
//! retires nothing further and stalls deterministically.
//!
//! **Open-loop mode** ([`Core::set_open_loop`], DESIGN.md §16) replaces
//! the retire/ROB/MLP machinery with an arrival queue: each reference's
//! `gap_insts` is reinterpreted as an inter-arrival gap in controller
//! cycles (see `workloads::arrival`), arrivals join a bounded FIFO at
//! their arrival timestamp regardless of memory progress, and the core
//! drains the FIFO into the controller in order. Per-read
//! enqueue-to-completion latency (measured from the *arrival* timestamp,
//! so queueing delay in the arrival FIFO counts) is recorded into a
//! fixed-memory [`StreamHist`]. When an arrival finds the FIFO full the
//! core latches `saturated` — offered load exceeds sustainable
//! throughput — and the system halts the run at the next thermal-epoch
//! boundary instead of growing memory or silently wedging. The
//! `next_event`/`skip` contract carries over: with an empty FIFO the
//! next event is exactly the next arrival timestamp, which is what lets
//! the time-skip driver jump over idle inter-arrival gaps at low load.

use std::collections::VecDeque;

use super::controller::Request;
use crate::util::hist::StreamHist;
use crate::workloads::{MemRef, RequestSource};

/// CPU-to-DRAM-controller clock ratio (3.2 GHz core, 800 MHz controller).
pub const CPU_PER_DRAM: u32 = 4;
/// Peak retire width (instructions per CPU cycle).
pub const IPC_MAX: u32 = 4;
/// Max instructions the core may run ahead of the oldest outstanding miss.
pub const ROB_INSTS: u64 = 192;
/// Max outstanding read misses (MSHRs).
pub const MAX_MLP: usize = 6;

/// Open-loop latency histogram range (controller cycles). Latencies at
/// or past the upper edge land in the top bin; quantiles past the
/// histogrammed mass report the exact observed maximum (the overflow
/// policy of `StreamHist::quantile_interp`).
pub const LAT_HIST_MAX: f64 = 4096.0;
/// Open-loop latency histogram resolution (8-cycle bins).
pub const LAT_HIST_BINS: usize = 512;
/// Default open-loop arrival-queue bound.
pub const OPEN_LOOP_BOUND: usize = 4096;

#[derive(Debug, Clone, Copy)]
struct Outstanding {
    id: u64,
    inst_pos: u64,
}

/// Open-loop state: the arrival FIFO and its instrumentation.
struct OpenLoop {
    /// Absolute arrival cycle of `next_ref` (cumulative gap sum).
    next_at: u64,
    /// The next not-yet-admitted arrival, pulled ahead so `next_at` is
    /// known to `next_event`.
    next_ref: Option<MemRef>,
    /// Admitted arrivals waiting to enqueue: (arrival cycle, reference).
    pending: VecDeque<(u64, MemRef)>,
    /// FIFO capacity; an arrival finding it full latches `saturated`.
    bound: usize,
    saturated: bool,
    /// Arrivals admitted to the FIFO so far.
    offered: u64,
    /// Read enqueue-to-completion latency, from arrival timestamp.
    hist: StreamHist,
}

pub struct Core {
    pub id: usize,
    source: Box<dyn RequestSource>,
    /// Batched refill buffer (consumed front to back, then refilled).
    buf: Vec<MemRef>,
    buf_pos: usize,
    /// The source returned an empty batch: no further references exist.
    exhausted: bool,
    /// Instructions retired so far.
    pub insts: u64,
    /// Remaining non-memory instructions before the next reference.
    gap_left: u64,
    next_ref: Option<MemRef>,
    outstanding: Vec<Outstanding>,
    next_req_id: u64,
    /// Stalled-cycle statistics.
    pub stall_cycles: u64,
    pub reads_issued: u64,
    pub writes_issued: u64,
    /// The last enqueue attempt was refused (queue full). Cleared on a
    /// successful send, a completion, or by the time-skip driver when any
    /// controller dequeues (queue space can only open up then).
    queue_blocked: bool,
    /// `Some` puts the core in open-loop mode (module docs).
    open_loop: Option<OpenLoop>,
}

impl Core {
    pub fn new(id: usize, source: Box<dyn RequestSource>) -> Self {
        Core {
            id,
            source,
            buf: Vec::new(),
            buf_pos: 0,
            exhausted: false,
            insts: 0,
            gap_left: 0,
            next_ref: None,
            outstanding: Vec::new(),
            next_req_id: 1,
            stall_cycles: 0,
            reads_issued: 0,
            writes_issued: 0,
            queue_blocked: false,
            open_loop: None,
        }
    }

    /// Switch this core to open-loop mode with the given arrival-queue
    /// bound. Must run before the first cycle — the closed-loop retire
    /// state and the arrival clock both start from zero.
    pub fn set_open_loop(&mut self, bound: usize) {
        assert!(bound > 0, "arrival queue bound must be positive");
        assert!(self.insts == 0 && self.next_ref.is_none(),
                "set_open_loop after the core already ran");
        self.open_loop = Some(OpenLoop {
            next_at: 0,
            next_ref: None,
            pending: VecDeque::new(),
            bound,
            saturated: false,
            offered: 0,
            hist: StreamHist::new(0.0, LAT_HIST_MAX, LAT_HIST_BINS),
        });
    }

    pub fn is_open_loop(&self) -> bool {
        self.open_loop.is_some()
    }

    /// Open-loop saturation latch: an arrival found the FIFO full.
    pub fn open_loop_saturated(&self) -> bool {
        self.open_loop.as_ref().is_some_and(|ol| ol.saturated)
    }

    /// Arrivals admitted to the open-loop FIFO so far (0 closed-loop).
    pub fn arrivals_offered(&self) -> u64 {
        self.open_loop.as_ref().map_or(0, |ol| ol.offered)
    }

    /// The open-loop read-latency histogram (None closed-loop).
    pub fn latency_hist(&self) -> Option<&StreamHist> {
        self.open_loop.as_ref().map(|ol| &ol.hist)
    }

    /// Pull the next reference through the batched transport.
    fn pull_ref(&mut self) -> Option<MemRef> {
        if self.buf_pos == self.buf.len() {
            self.buf.clear();
            self.buf_pos = 0;
            if self.exhausted || self.source.fill(&mut self.buf) == 0 {
                self.exhausted = true;
                return None;
            }
        }
        let r = self.buf[self.buf_pos];
        self.buf_pos += 1;
        Some(r)
    }

    fn refill(&mut self) {
        if self.next_ref.is_some() {
            return;
        }
        if let Some(r) = self.pull_ref() {
            self.gap_left = r.gap_insts as u64;
            self.next_ref = Some(r);
        }
    }

    pub fn on_completion(&mut self, req_id: u64) {
        self.outstanding.retain(|o| o.id != req_id);
        self.queue_blocked = false;
    }

    /// Read-completion hook with timing: in open-loop mode the
    /// arrival-to-finish latency is recorded before the completion is
    /// applied; closed-loop this is exactly [`Self::on_completion`].
    pub fn complete_read(&mut self, req_id: u64, arrival: u64, finish: u64) {
        if let Some(ol) = &mut self.open_loop {
            ol.hist.record((finish - arrival) as f64);
        }
        self.on_completion(req_id);
    }

    pub fn outstanding(&self) -> usize {
        self.outstanding.len()
    }

    /// Time-skip driver: a controller dequeued, so a refused enqueue may
    /// now succeed — re-arm `next_event`.
    pub fn clear_queue_block(&mut self) {
        self.queue_blocked = false;
    }

    /// True while no reference has been pulled from the source yet — the
    /// window in which `wrap_source` (the trace-capture hook) can still
    /// observe the whole stream.
    pub fn source_untouched(&self) -> bool {
        self.next_ref.is_none() && self.buf.is_empty() && !self.exhausted
    }

    /// Replace the source with a wrapper around it (the `mem::System`
    /// trace-capture hook). Must run before the first reference is
    /// pulled, or the recording would miss the consumed prefix.
    pub fn wrap_source(
        &mut self,
        f: impl FnOnce(Box<dyn RequestSource>) -> Box<dyn RequestSource>,
    ) {
        assert!(self.source_untouched(),
                "wrap_source after references were already pulled");
        let inner = std::mem::replace(
            &mut self.source, Box::new(crate::workloads::NullSource));
        self.source = f(inner);
    }

    fn rob_limit(&self) -> u64 {
        self.outstanding
            .iter()
            .map(|o| o.inst_pos + ROB_INSTS)
            .min()
            .unwrap_or(u64::MAX)
    }

    /// Make sure the head-of-stream arrival (and its timestamp) is known.
    fn ol_refill(&mut self) {
        if self.open_loop.as_ref().is_some_and(|ol| ol.next_ref.is_some()) {
            return;
        }
        if let Some(r) = self.pull_ref() {
            let ol = self.open_loop.as_mut().unwrap();
            ol.next_at += r.gap_insts as u64;
            ol.next_ref = Some(r);
        }
    }

    /// Admit every arrival due by `now` into the FIFO, in timestamp
    /// order, up to the bound. An arrival finding the FIFO full latches
    /// `saturated` and stays un-admitted (it keeps its true timestamp,
    /// so if room opens before the run halts its recorded queueing delay
    /// is the real one). Admission depends only on timestamps and FIFO
    /// occupancy — never on when within a span it runs — so the
    /// time-skip driver may defer it to the next stepped cycle and still
    /// admit exactly the same set (the §16 equivalence argument).
    fn ol_admit(&mut self, now: u64) {
        loop {
            self.ol_refill();
            let ol = self.open_loop.as_mut().unwrap();
            let Some(r) = ol.next_ref else { return };
            if ol.next_at > now {
                return;
            }
            if ol.pending.len() >= ol.bound {
                ol.saturated = true; // fail-loud: halts at the next epoch
                return;
            }
            ol.pending.push_back((ol.next_at, r));
            ol.offered += 1;
            ol.next_ref = None;
        }
    }

    /// Open-loop cycle: admit due arrivals, then drain the FIFO head
    /// into the controller (FIFO order — head-of-line blocking is the
    /// model: an offered-load stream has no reorder window).
    fn ol_step(&mut self, now: u64,
               try_send: &mut dyn FnMut(Request) -> bool) {
        self.ol_admit(now);
        let mut budget = (CPU_PER_DRAM * IPC_MAX) as u64;
        let mut progressed = false;
        while budget > 0 {
            let Some(&(at, r)) =
                self.open_loop.as_ref().unwrap().pending.front()
            else {
                break;
            };
            let req = Request {
                id: self.next_req_id,
                core: self.id,
                addr: r.addr,
                is_write: r.is_write,
                // The *arrival* timestamp, not `now`: the controller's
                // completion then carries finish − arrival = queueing
                // delay in this FIFO + service, the latency that matters
                // under offered load.
                arrival: at,
            };
            if try_send(req) {
                self.queue_blocked = false;
                self.next_req_id += 1;
                if r.is_write {
                    self.writes_issued += 1;
                } else {
                    self.reads_issued += 1;
                }
                self.insts += 1; // one injected request (IPC is a proxy)
                self.open_loop.as_mut().unwrap().pending.pop_front();
                budget -= 1;
                progressed = true;
            } else {
                self.queue_blocked = true;
                break;
            }
        }
        if !progressed
            && !self.open_loop.as_ref().unwrap().pending.is_empty()
        {
            self.stall_cycles += 1;
        }
    }

    /// Earliest cycle >= `now` at which this core will next attempt to
    /// enqueue a memory request, or `u64::MAX` when it cannot act until an
    /// external event (a completion frees an MSHR / ROB or dependence
    /// slot, or a controller dequeue frees queue space). Until then the
    /// core only retires instructions and stalls deterministically, which
    /// `skip` replays in O(1) — the time-skip driver contract.
    ///
    /// Open-loop, the same contract with arrival awareness: a non-empty
    /// FIFO wants to enqueue *now* (unless a refused enqueue pins the
    /// core until a controller dequeue re-arms it), and an empty FIFO's
    /// next event is exactly the next arrival timestamp — the hint that
    /// lets `run_fast` skip whole inter-arrival gaps at low load.
    pub fn next_event(&mut self, now: u64) -> u64 {
        if self.open_loop.is_some() {
            self.ol_refill();
            let ol = self.open_loop.as_ref().unwrap();
            if !ol.pending.is_empty() {
                return if self.queue_blocked { u64::MAX } else { now };
            }
            return match ol.next_ref {
                Some(_) => ol.next_at.max(now),
                None => u64::MAX, // source exhausted
            };
        }
        self.refill();
        if self.queue_blocked {
            return u64::MAX;
        }
        let Some(r) = self.next_ref else {
            return u64::MAX; // source exhausted: nothing left to enqueue
        };
        let headroom = self.rob_limit().saturating_sub(self.insts);
        if self.gap_left > headroom {
            return u64::MAX; // the ROB fills before the gap is consumed
        }
        if !r.is_write
            && (self.outstanding.len() >= MAX_MLP
                || (r.dependent && !self.outstanding.is_empty()))
        {
            return u64::MAX; // issue attempt is MLP/dependence-blocked
        }
        now + self.gap_left / (CPU_PER_DRAM * IPC_MAX) as u64
    }

    /// Replay `span` cycles in O(1) during which the driver has proven
    /// (via `next_event`) that this core makes no enqueue attempt: retire
    /// up to the ROB limit at full width, then stall.
    pub fn skip(&mut self, span: u64) {
        if span == 0 {
            return;
        }
        if let Some(ol) = &self.open_loop {
            // Blocked with a waiting FIFO: every skipped cycle is a
            // stall, exactly as per-cycle stepping records. Idle (empty
            // FIFO): nothing changes — arrivals due by the end of the
            // span are admitted by the next stepped cycle's ol_admit,
            // which reaches the same state as cycle-by-cycle admission.
            if !ol.pending.is_empty() {
                self.stall_cycles += span;
            }
            return;
        }
        self.refill();
        let width = (CPU_PER_DRAM * IPC_MAX) as u64;
        let headroom = self.rob_limit().saturating_sub(self.insts);
        let retirable = self.gap_left.min(headroom);
        let retired = retirable.min(width * span);
        self.insts += retired;
        self.gap_left -= retired;
        // Cycles that retire at least one instruction count as progress;
        // the rest are stalls — exactly what per-cycle stepping records.
        let progressing = retirable.div_euclid(width)
            + u64::from(retirable % width != 0);
        self.stall_cycles += span.saturating_sub(progressing);
    }

    /// Advance one DRAM-controller cycle. `try_send` submits a request to
    /// the memory system and returns the request id on acceptance.
    pub fn step(&mut self, now: u64,
                try_send: &mut dyn FnMut(Request) -> bool) {
        if self.open_loop.is_some() {
            self.ol_step(now, try_send);
            return;
        }
        let mut budget = (CPU_PER_DRAM * IPC_MAX) as u64;
        let mut progressed = false;

        while budget > 0 {
            self.refill();

            // ROB limit: cannot retire past oldest outstanding + ROB_INSTS.
            let rob_limit = self
                .outstanding
                .iter()
                .map(|o| o.inst_pos + ROB_INSTS)
                .min()
                .unwrap_or(u64::MAX);

            if self.gap_left > 0 {
                let can = budget
                    .min(self.gap_left)
                    .min(rob_limit.saturating_sub(self.insts));
                if can == 0 {
                    break; // ROB full — stalled on a miss
                }
                self.insts += can;
                self.gap_left -= can;
                budget -= can;
                progressed = true;
                continue;
            }

            // gap exhausted: issue the memory reference.
            let Some(r) = self.next_ref else {
                break; // source exhausted — the core idles from here on
            };
            if r.is_write {
                let req = Request {
                    id: self.next_req_id,
                    core: self.id,
                    addr: r.addr,
                    is_write: true,
                    arrival: now,
                };
                if try_send(req) {
                    // Writes retire via the store buffer: non-blocking.
                    self.queue_blocked = false;
                    self.next_req_id += 1;
                    self.writes_issued += 1;
                    self.insts += 1;
                    budget -= 1;
                    self.next_ref = None;
                    progressed = true;
                } else {
                    self.queue_blocked = true;
                    break; // write queue full
                }
            } else {
                let dep_ok = !r.dependent || self.outstanding.is_empty();
                if self.outstanding.len() >= MAX_MLP || !dep_ok {
                    break;
                }
                let req = Request {
                    id: self.next_req_id,
                    core: self.id,
                    addr: r.addr,
                    is_write: false,
                    arrival: now,
                };
                if try_send(req) {
                    self.queue_blocked = false;
                    self.outstanding.push(Outstanding {
                        id: self.next_req_id,
                        inst_pos: self.insts,
                    });
                    self.next_req_id += 1;
                    self.reads_issued += 1;
                    self.insts += 1;
                    budget -= 1;
                    self.next_ref = None;
                    progressed = true;
                } else {
                    self.queue_blocked = true;
                    break; // read queue full
                }
            }
        }

        if !progressed {
            self.stall_cycles += 1;
        }
    }

    /// Retired instructions per CPU cycle.
    pub fn ipc(&self, dram_cycles: u64) -> f64 {
        if dram_cycles == 0 {
            return 0.0;
        }
        self.insts as f64 / (dram_cycles * CPU_PER_DRAM as u64) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{MemRef, RequestSource, SOURCE_BATCH};

    /// Source with a fixed gap and sequential addresses.
    struct FixedSource {
        gap: u32,
        addr: u64,
        dependent: bool,
    }

    impl RequestSource for FixedSource {
        fn fill(&mut self, out: &mut Vec<MemRef>) -> usize {
            for _ in 0..SOURCE_BATCH {
                self.addr += 64;
                out.push(MemRef { gap_insts: self.gap, addr: self.addr,
                                  is_write: false,
                                  dependent: self.dependent });
            }
            SOURCE_BATCH
        }
    }

    /// Source that yields exactly `left` references, then exhausts.
    struct FiniteSource {
        left: usize,
        addr: u64,
    }

    impl RequestSource for FiniteSource {
        fn fill(&mut self, out: &mut Vec<MemRef>) -> usize {
            let n = self.left.min(SOURCE_BATCH);
            for _ in 0..n {
                self.addr += 64;
                out.push(MemRef { gap_insts: 3, addr: self.addr,
                                  is_write: false, dependent: false });
            }
            self.left -= n;
            n
        }
    }

    #[test]
    fn compute_bound_core_hits_peak_ipc() {
        let mut core = Core::new(0, Box::new(FixedSource {
            gap: 100_000, addr: 0, dependent: false }));
        let mut send = |_req: Request| true;
        for now in 0..1000u64 {
            core.step(now, &mut send);
        }
        let ipc = core.ipc(1000);
        assert!((ipc - IPC_MAX as f64).abs() < 0.1, "ipc {ipc}");
    }

    #[test]
    fn mlp_bounds_outstanding_reads() {
        let mut core = Core::new(0, Box::new(FixedSource {
            gap: 0, addr: 0, dependent: false }));
        let mut send = |_req: Request| true; // memory never completes
        for now in 0..100u64 {
            core.step(now, &mut send);
        }
        assert_eq!(core.outstanding(), MAX_MLP);
        assert!(core.stall_cycles > 0);
    }

    #[test]
    fn dependent_loads_serialize() {
        let mut core = Core::new(0, Box::new(FixedSource {
            gap: 0, addr: 0, dependent: true }));
        let mut send = |_req: Request| true;
        for now in 0..100u64 {
            core.step(now, &mut send);
        }
        assert_eq!(core.outstanding(), 1, "pointer chase has MLP 1");
    }

    #[test]
    fn completion_unblocks_core() {
        let mut core = Core::new(0, Box::new(FixedSource {
            gap: 0, addr: 0, dependent: true }));
        let mut ids = Vec::new();
        {
            let mut send = |req: Request| {
                ids.push(req.id);
                true
            };
            for now in 0..10u64 {
                core.step(now, &mut send);
            }
        }
        assert_eq!(core.outstanding(), 1);
        let before = core.reads_issued;
        core.on_completion(ids[0]);
        let mut send2 = |_req: Request| true;
        core.step(11, &mut send2);
        assert!(core.reads_issued > before);
    }

    #[test]
    fn skip_replays_per_cycle_stepping_exactly() {
        // Time-skip contract: next_event + skip must reproduce the exact
        // per-cycle trajectory (insts, stalls, issue cycles) of step().
        let mk = || Core::new(0, Box::new(FixedSource {
            gap: 37, addr: 0, dependent: false }));
        let horizon = 1000u64;
        let mut a = mk();
        let mut issues_a = Vec::new();
        {
            let mut send = |req: Request| {
                issues_a.push(req.arrival);
                true
            };
            for now in 0..horizon {
                a.step(now, &mut send);
            }
        }
        let mut b = mk();
        let mut issues_b = Vec::new();
        let mut now = 0u64;
        while now < horizon {
            let e = b.next_event(now).min(horizon);
            if e > now {
                b.skip(e - now);
                now = e;
                continue;
            }
            let mut send = |req: Request| {
                issues_b.push(req.arrival);
                true
            };
            b.step(now, &mut send);
            now += 1;
        }
        assert_eq!(a.insts, b.insts);
        assert_eq!(a.stall_cycles, b.stall_cycles);
        assert_eq!(a.reads_issued, b.reads_issued);
        assert_eq!(issues_a, issues_b, "issue cycles must match");
    }

    #[test]
    fn rob_limits_runahead() {
        // One unfulfilled miss, then a huge gap: the core must stop at
        // ROB_INSTS past the miss.
        let mut core = Core::new(0, Box::new(FixedSource {
            gap: 1_000_000, addr: 0, dependent: false }));
        let mut send = |_req: Request| true;
        // First step issues the miss quickly (gap consumed across steps).
        for now in 0..100_000u64 {
            core.step(now, &mut send);
            if core.reads_issued >= 1 {
                break;
            }
        }
        let at_issue = core.insts;
        for now in 0..10_000u64 {
            core.step(200_000 + now, &mut send);
        }
        assert!(core.insts <= at_issue + ROB_INSTS,
                "ran ahead {} past miss", core.insts - at_issue);
    }

    #[test]
    fn exhausted_source_idles_the_core() {
        // A finite source (trace replay past its horizon): every recorded
        // reference issues, then the core stalls forever — identically
        // under step() and the next_event/skip time-skip pair.
        let total = 2 * SOURCE_BATCH + 7;
        let run_stepped = || {
            let mut core = Core::new(0, Box::new(FiniteSource {
                left: total, addr: 0 }));
            let mut done = Vec::new();
            for now in 0..2_000u64 {
                let mut sent = Vec::new();
                let mut s = |req: Request| {
                    sent.push(req.id);
                    true
                };
                core.step(now, &mut s);
                for id in sent {
                    core.on_completion(id); // zero-latency memory
                    done.push(id);
                }
            }
            (core.insts, core.stall_cycles, core.reads_issued, done.len())
        };
        let (insts, stalls, reads, done) = run_stepped();
        assert_eq!(reads as usize, total, "every recorded ref issues");
        assert_eq!(done, total);
        assert!(stalls > 0, "core must stall after exhaustion");

        // Time-skip driver agrees.
        let mut core = Core::new(0, Box::new(FiniteSource {
            left: total, addr: 0 }));
        let mut now = 0u64;
        let horizon = 2_000u64;
        let mut reads_fast = 0usize;
        while now < horizon {
            let e = core.next_event(now).min(horizon);
            if e > now {
                core.skip(e - now);
                now = e;
                continue;
            }
            let mut sent = Vec::new();
            let mut s = |req: Request| {
                sent.push(req.id);
                true
            };
            core.step(now, &mut s);
            for id in sent {
                core.on_completion(id);
                reads_fast += 1;
            }
            now += 1;
        }
        assert_eq!(core.insts, insts);
        assert_eq!(core.stall_cycles, stalls);
        assert_eq!(reads_fast, total);
    }

    #[test]
    fn open_loop_skip_replays_stepping_exactly() {
        // The open-loop leg of the time-skip contract: next_event + skip
        // must reproduce step()'s exact trajectory — issue cycles,
        // request arrival stamps, stalls — including across spans where
        // admission is deferred.
        let mk = || {
            let mut c = Core::new(0, Box::new(FixedSource {
                gap: 23, addr: 0, dependent: false }));
            c.set_open_loop(4);
            c
        };
        let horizon = 2_000u64;
        // Memory that accepts every 3rd attempt: forces refused
        // enqueues, head-of-line blocking, and saturation stretches.
        let mut a = mk();
        let mut issues_a = Vec::new();
        {
            let mut n = 0u64;
            let mut send = |req: Request| {
                n += 1;
                if n % 3 == 0 {
                    issues_a.push((req.addr, req.arrival));
                    true
                } else {
                    false
                }
            };
            for now in 0..horizon {
                a.step(now, &mut send);
                a.clear_queue_block(); // model: space may open any cycle
            }
        }
        let mut b = mk();
        let mut issues_b = Vec::new();
        let mut now = 0u64;
        let mut n = 0u64;
        while now < horizon {
            let e = b.next_event(now).min(horizon);
            if e > now {
                b.skip(e - now);
                now = e;
                continue;
            }
            let mut send = |req: Request| {
                n += 1;
                if n % 3 == 0 {
                    issues_b.push((req.addr, req.arrival));
                    true
                } else {
                    false
                }
            };
            b.step(now, &mut send);
            b.clear_queue_block();
            now += 1;
        }
        assert_eq!(issues_a, issues_b);
        assert_eq!(a.stall_cycles, b.stall_cycles);
        assert_eq!(a.arrivals_offered(), b.arrivals_offered());
        assert_eq!(a.open_loop_saturated(), b.open_loop_saturated());
    }

    #[test]
    fn open_loop_saturation_latches_and_bounds_memory() {
        // Memory that never accepts: the FIFO fills to its bound and
        // the saturation latch fires; pending never exceeds the bound.
        let mut c = Core::new(0, Box::new(FixedSource {
            gap: 1, addr: 0, dependent: false }));
        c.set_open_loop(8);
        let mut send = |_req: Request| false;
        for now in 0..100u64 {
            c.step(now, &mut send);
        }
        assert!(c.open_loop_saturated());
        assert_eq!(c.open_loop.as_ref().unwrap().pending.len(), 8);
        assert_eq!(c.arrivals_offered(), 8);
        assert!(c.stall_cycles > 0);
    }

    #[test]
    fn open_loop_latency_counts_arrival_queue_wait() {
        // One arrival at cycle 0, accepted at cycle 10, completed at
        // cycle 50: the recorded latency is 50, not 40 — the FIFO wait
        // is part of what the user experiences.
        let mut c = Core::new(0, Box::new(FiniteSource { left: 1, addr: 0 }));
        c.set_open_loop(4);
        // FiniteSource gaps are 3: arrival lands at cycle 3.
        let mut got = Vec::new();
        for now in 0..10u64 {
            let mut send = |req: Request| {
                if now < 9 {
                    return false;
                }
                got.push((req.id, req.arrival));
                true
            };
            c.step(now, &mut send);
            c.clear_queue_block();
        }
        assert_eq!(got.len(), 1);
        let (id, arrival) = got[0];
        assert_eq!(arrival, 3);
        c.complete_read(id, arrival, 53);
        let h = c.latency_hist().unwrap();
        assert_eq!(h.count(), 1);
        assert_eq!(h.max(), 50.0);
    }

    #[test]
    fn open_loop_idle_gaps_are_skippable() {
        // Empty FIFO: next_event is exactly the next arrival timestamp,
        // so at low load almost every cycle is skippable.
        let mut c = Core::new(0, Box::new(FixedSource {
            gap: 1000, addr: 0, dependent: false }));
        c.set_open_loop(4);
        assert_eq!(c.next_event(0), 1000);
        let mut send = |_req: Request| true;
        c.skip(1000);
        c.step(1000, &mut send);
        assert_eq!(c.reads_issued, 1);
        assert_eq!(c.stall_cycles, 0);
        assert_eq!(c.next_event(1001), 2000);
    }

    #[test]
    fn wrap_source_only_before_first_pull() {
        let mut core = Core::new(0, Box::new(FixedSource {
            gap: 5, addr: 0, dependent: false }));
        assert!(core.source_untouched());
        core.wrap_source(|inner| inner); // identity wrap is fine up front
        let mut send = |_req: Request| true;
        core.step(0, &mut send);
        assert!(!core.source_untouched());
    }
}
