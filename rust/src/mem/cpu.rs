//! Trace-driven core model: an out-of-order core abstraction with bounded
//! MLP (outstanding misses), a reorder-buffer run-ahead limit, and
//! dependent-load support (pointer chasing). Deliberately simple — the
//! paper's Fig 4 effect is the translation of DRAM latency into IPC as a
//! function of memory intensity, which this captures.

use super::controller::Request;
use crate::workloads::{MemRef, Trace};

/// CPU-to-DRAM-controller clock ratio (3.2 GHz core, 800 MHz controller).
pub const CPU_PER_DRAM: u32 = 4;
/// Peak retire width (instructions per CPU cycle).
pub const IPC_MAX: u32 = 4;
/// Max instructions the core may run ahead of the oldest outstanding miss.
pub const ROB_INSTS: u64 = 192;
/// Max outstanding read misses (MSHRs).
pub const MAX_MLP: usize = 6;

#[derive(Debug, Clone, Copy)]
struct Outstanding {
    id: u64,
    inst_pos: u64,
}

pub struct Core {
    pub id: usize,
    trace: Box<dyn Trace>,
    /// Instructions retired so far.
    pub insts: u64,
    /// Remaining non-memory instructions before the next reference.
    gap_left: u64,
    next_ref: Option<MemRef>,
    outstanding: Vec<Outstanding>,
    next_req_id: u64,
    /// Stalled-cycle statistics.
    pub stall_cycles: u64,
    pub reads_issued: u64,
    pub writes_issued: u64,
    /// The last enqueue attempt was refused (queue full). Cleared on a
    /// successful send, a completion, or by the time-skip driver when any
    /// controller dequeues (queue space can only open up then).
    queue_blocked: bool,
}

impl Core {
    pub fn new(id: usize, trace: Box<dyn Trace>) -> Self {
        Core {
            id,
            trace,
            insts: 0,
            gap_left: 0,
            next_ref: None,
            outstanding: Vec::new(),
            next_req_id: 1,
            stall_cycles: 0,
            reads_issued: 0,
            writes_issued: 0,
            queue_blocked: false,
        }
    }

    fn refill(&mut self) {
        if self.next_ref.is_none() {
            let r = self.trace.next();
            self.gap_left = r.gap_insts as u64;
            self.next_ref = Some(r);
        }
    }

    pub fn on_completion(&mut self, req_id: u64) {
        self.outstanding.retain(|o| o.id != req_id);
        self.queue_blocked = false;
    }

    pub fn outstanding(&self) -> usize {
        self.outstanding.len()
    }

    /// Time-skip driver: a controller dequeued, so a refused enqueue may
    /// now succeed — re-arm `next_event`.
    pub fn clear_queue_block(&mut self) {
        self.queue_blocked = false;
    }

    fn rob_limit(&self) -> u64 {
        self.outstanding
            .iter()
            .map(|o| o.inst_pos + ROB_INSTS)
            .min()
            .unwrap_or(u64::MAX)
    }

    /// Earliest cycle >= `now` at which this core will next attempt to
    /// enqueue a memory request, or `u64::MAX` when it cannot act until an
    /// external event (a completion frees an MSHR / ROB or dependence
    /// slot, or a controller dequeue frees queue space). Until then the
    /// core only retires instructions and stalls deterministically, which
    /// `skip` replays in O(1) — the time-skip driver contract.
    pub fn next_event(&mut self, now: u64) -> u64 {
        self.refill();
        if self.queue_blocked {
            return u64::MAX;
        }
        let headroom = self.rob_limit().saturating_sub(self.insts);
        if self.gap_left > headroom {
            return u64::MAX; // the ROB fills before the gap is consumed
        }
        let r = self.next_ref.expect("refill invariant");
        if !r.is_write
            && (self.outstanding.len() >= MAX_MLP
                || (r.dependent && !self.outstanding.is_empty()))
        {
            return u64::MAX; // issue attempt is MLP/dependence-blocked
        }
        now + self.gap_left / (CPU_PER_DRAM * IPC_MAX) as u64
    }

    /// Replay `span` cycles in O(1) during which the driver has proven
    /// (via `next_event`) that this core makes no enqueue attempt: retire
    /// up to the ROB limit at full width, then stall.
    pub fn skip(&mut self, span: u64) {
        if span == 0 {
            return;
        }
        self.refill();
        let width = (CPU_PER_DRAM * IPC_MAX) as u64;
        let headroom = self.rob_limit().saturating_sub(self.insts);
        let retirable = self.gap_left.min(headroom);
        let retired = retirable.min(width * span);
        self.insts += retired;
        self.gap_left -= retired;
        // Cycles that retire at least one instruction count as progress;
        // the rest are stalls — exactly what per-cycle stepping records.
        let progressing = retirable.div_euclid(width)
            + u64::from(retirable % width != 0);
        self.stall_cycles += span.saturating_sub(progressing);
    }

    /// Advance one DRAM-controller cycle. `try_send` submits a request to
    /// the memory system and returns the request id on acceptance.
    pub fn step(&mut self, now: u64,
                try_send: &mut dyn FnMut(Request) -> bool) {
        let mut budget = (CPU_PER_DRAM * IPC_MAX) as u64;
        let mut progressed = false;

        while budget > 0 {
            self.refill();

            // ROB limit: cannot retire past oldest outstanding + ROB_INSTS.
            let rob_limit = self
                .outstanding
                .iter()
                .map(|o| o.inst_pos + ROB_INSTS)
                .min()
                .unwrap_or(u64::MAX);

            if self.gap_left > 0 {
                let can = budget
                    .min(self.gap_left)
                    .min(rob_limit.saturating_sub(self.insts));
                if can == 0 {
                    break; // ROB full — stalled on a miss
                }
                self.insts += can;
                self.gap_left -= can;
                budget -= can;
                progressed = true;
                continue;
            }

            // gap exhausted: issue the memory reference.
            let r = self.next_ref.expect("refill invariant");
            if r.is_write {
                let req = Request {
                    id: self.next_req_id,
                    core: self.id,
                    addr: r.addr,
                    is_write: true,
                    arrival: now,
                };
                if try_send(req) {
                    // Writes retire via the store buffer: non-blocking.
                    self.queue_blocked = false;
                    self.next_req_id += 1;
                    self.writes_issued += 1;
                    self.insts += 1;
                    budget -= 1;
                    self.next_ref = None;
                    progressed = true;
                } else {
                    self.queue_blocked = true;
                    break; // write queue full
                }
            } else {
                let dep_ok = !r.dependent || self.outstanding.is_empty();
                if self.outstanding.len() >= MAX_MLP || !dep_ok {
                    break;
                }
                let req = Request {
                    id: self.next_req_id,
                    core: self.id,
                    addr: r.addr,
                    is_write: false,
                    arrival: now,
                };
                if try_send(req) {
                    self.queue_blocked = false;
                    self.outstanding.push(Outstanding {
                        id: self.next_req_id,
                        inst_pos: self.insts,
                    });
                    self.next_req_id += 1;
                    self.reads_issued += 1;
                    self.insts += 1;
                    budget -= 1;
                    self.next_ref = None;
                    progressed = true;
                } else {
                    self.queue_blocked = true;
                    break; // read queue full
                }
            }
        }

        if !progressed {
            self.stall_cycles += 1;
        }
    }

    /// Retired instructions per CPU cycle.
    pub fn ipc(&self, dram_cycles: u64) -> f64 {
        if dram_cycles == 0 {
            return 0.0;
        }
        self.insts as f64 / (dram_cycles * CPU_PER_DRAM as u64) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{MemRef, Trace};

    /// Trace with a fixed gap and sequential addresses.
    struct FixedTrace {
        gap: u32,
        addr: u64,
        dependent: bool,
    }

    impl Trace for FixedTrace {
        fn next(&mut self) -> MemRef {
            self.addr += 64;
            MemRef { gap_insts: self.gap, addr: self.addr, is_write: false,
                     dependent: self.dependent }
        }
    }

    #[test]
    fn compute_bound_core_hits_peak_ipc() {
        let mut core = Core::new(0, Box::new(FixedTrace {
            gap: 100_000, addr: 0, dependent: false }));
        let mut send = |_req: Request| true;
        for now in 0..1000u64 {
            core.step(now, &mut send);
        }
        let ipc = core.ipc(1000);
        assert!((ipc - IPC_MAX as f64).abs() < 0.1, "ipc {ipc}");
    }

    #[test]
    fn mlp_bounds_outstanding_reads() {
        let mut core = Core::new(0, Box::new(FixedTrace {
            gap: 0, addr: 0, dependent: false }));
        let mut send = |_req: Request| true; // memory never completes
        for now in 0..100u64 {
            core.step(now, &mut send);
        }
        assert_eq!(core.outstanding(), MAX_MLP);
        assert!(core.stall_cycles > 0);
    }

    #[test]
    fn dependent_loads_serialize() {
        let mut core = Core::new(0, Box::new(FixedTrace {
            gap: 0, addr: 0, dependent: true }));
        let mut send = |_req: Request| true;
        for now in 0..100u64 {
            core.step(now, &mut send);
        }
        assert_eq!(core.outstanding(), 1, "pointer chase has MLP 1");
    }

    #[test]
    fn completion_unblocks_core() {
        let mut core = Core::new(0, Box::new(FixedTrace {
            gap: 0, addr: 0, dependent: true }));
        let mut ids = Vec::new();
        {
            let mut send = |req: Request| {
                ids.push(req.id);
                true
            };
            for now in 0..10u64 {
                core.step(now, &mut send);
            }
        }
        assert_eq!(core.outstanding(), 1);
        let before = core.reads_issued;
        core.on_completion(ids[0]);
        let mut send2 = |_req: Request| true;
        core.step(11, &mut send2);
        assert!(core.reads_issued > before);
    }

    #[test]
    fn skip_replays_per_cycle_stepping_exactly() {
        // Time-skip contract: next_event + skip must reproduce the exact
        // per-cycle trajectory (insts, stalls, issue cycles) of step().
        let mk = || Core::new(0, Box::new(FixedTrace {
            gap: 37, addr: 0, dependent: false }));
        let horizon = 1000u64;
        let mut a = mk();
        let mut issues_a = Vec::new();
        {
            let mut send = |req: Request| {
                issues_a.push(req.arrival);
                true
            };
            for now in 0..horizon {
                a.step(now, &mut send);
            }
        }
        let mut b = mk();
        let mut issues_b = Vec::new();
        let mut now = 0u64;
        while now < horizon {
            let e = b.next_event(now).min(horizon);
            if e > now {
                b.skip(e - now);
                now = e;
                continue;
            }
            let mut send = |req: Request| {
                issues_b.push(req.arrival);
                true
            };
            b.step(now, &mut send);
            now += 1;
        }
        assert_eq!(a.insts, b.insts);
        assert_eq!(a.stall_cycles, b.stall_cycles);
        assert_eq!(a.reads_issued, b.reads_issued);
        assert_eq!(issues_a, issues_b, "issue cycles must match");
    }

    #[test]
    fn rob_limits_runahead() {
        // One unfulfilled miss, then a huge gap: the core must stop at
        // ROB_INSTS past the miss.
        let mut core = Core::new(0, Box::new(FixedTrace {
            gap: 1_000_000, addr: 0, dependent: false }));
        let mut send = |_req: Request| true;
        // First step issues the miss quickly (gap consumed across steps).
        for now in 0..100_000u64 {
            core.step(now, &mut send);
            if core.reads_issued >= 1 {
                break;
            }
        }
        let at_issue = core.insts;
        for now in 0..10_000u64 {
            core.step(200_000 + now, &mut send);
        }
        assert!(core.insts <= at_issue + ROB_INSTS,
                "ran ahead {} past miss", core.insts - at_issue);
    }
}
