//! The memory controller: per-channel request queues, FR-FCFS scheduling,
//! row-buffer policies, refresh management, and — the AL-DRAM hook — a
//! runtime-swappable timing set (the paper's evaluated system exposes
//! exactly this through BIOS-visible config registers [10, 11]).

use std::cell::{Cell, RefCell};
use std::rc::Rc;
use std::sync::Arc;

use super::address::AddrMap;
use super::dram::{Cycle, GateMutation, Rank, RegionCycles};
use crate::timing::{TimingCycles, TimingParams};

/// DDR3 command classes visible on the command bus. What the command tap
/// reports; the protocol checker re-derives legality from this stream
/// alone.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CmdKind {
    Act,
    Read,
    Write,
    Pre,
    Ref,
}

impl CmdKind {
    pub fn name(self) -> &'static str {
        match self {
            CmdKind::Act => "ACT",
            CmdKind::Read => "RD",
            CmdKind::Write => "WR",
            CmdKind::Pre => "PRE",
            CmdKind::Ref => "REF",
        }
    }
}

/// One issued command as seen at the controller's pins. For `Pre` the row
/// is the row being closed (tRP is region-scoped, so the auditor needs
/// it); for `Ref` bank and row are 0.
#[derive(Debug, Clone, Copy)]
pub struct Cmd {
    pub kind: CmdKind,
    pub rank: u8,
    pub bank: u8,
    pub row: u64,
    pub cycle: Cycle,
}

/// Consumer of the controller's command stream (protocol checker, command
/// trace writer). Timing notifications mirror the controller's own
/// `set_*` calls so a sink always knows which `TimingParams` were active
/// when a command issued — constraint windows must be baked from the set
/// live at issue time, exactly as the controller bakes its deadlines.
pub trait CmdSink {
    fn cmd(&mut self, c: Cmd);
    fn on_timings(&mut self, _t: &TimingParams) {}
    fn on_region_timings(&mut self, _regions_per_bank: usize,
                         _t: Option<&[TimingParams]>) {}
    fn on_refresh_scale(&mut self, _scale: f64) {}
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RowPolicy {
    /// Keep rows open; precharge on conflict (FR-FCFS default).
    Open,
    /// Precharge as soon as no queued request hits the open row.
    Closed,
}

#[derive(Debug, Clone, Copy)]
pub struct Request {
    pub id: u64,
    pub core: usize,
    pub addr: u64,
    pub is_write: bool,
    pub arrival: Cycle,
}

#[derive(Debug, Clone, Copy)]
pub struct Completion {
    pub id: u64,
    pub core: usize,
    pub is_write: bool,
    pub arrival: Cycle,
    pub finish: Cycle,
}

#[derive(Debug, Clone, Copy)]
struct Pending {
    req: Request,
    rank: usize,
    bank: usize,
    row: u64,
    /// Already charged to exactly one of row_hits/misses/conflicts. A
    /// request is classified by the *first* command issued on its behalf
    /// (PRE -> conflict, ACT -> miss, column with the row already open ->
    /// hit), so each request lands in exactly one bucket.
    counted: bool,
}

/// Sentinel slot index for the slab queues' linked chains.
const NIL: u32 = u32::MAX;

/// Index-linked FIFO over a preallocated slab arena. FIFO order lives in
/// a singly-linked chain of slot indices; slots never move, so FR-FCFS's
/// mid-queue removal is an O(1) relink (against `VecDeque::remove`'s
/// element shifting) and the arena is allocated once at queue capacity
/// and never grows or reallocates on the hot path.
struct SlabQueue {
    slots: Vec<Pending>,
    /// Chain link per slot: next-in-FIFO for live slots, next-free for
    /// free-list slots, `NIL` at either tail.
    next: Vec<u32>,
    head: u32,
    tail: u32,
    free: u32,
    len: usize,
}

impl SlabQueue {
    fn new(capacity: usize) -> Self {
        SlabQueue {
            slots: Vec::with_capacity(capacity),
            next: Vec::with_capacity(capacity),
            head: NIL,
            tail: NIL,
            free: NIL,
            len: 0,
        }
    }

    #[inline]
    fn len(&self) -> usize {
        self.len
    }

    #[inline]
    fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Append in FIFO order. The caller enforces capacity (`can_accept`),
    /// so the arena vectors reach queue capacity once and are reused via
    /// the free list from then on.
    fn push_back(&mut self, p: Pending) {
        let idx = if self.free != NIL {
            let i = self.free;
            self.free = self.next[i as usize];
            self.slots[i as usize] = p;
            i
        } else {
            self.slots.push(p);
            self.next.push(NIL);
            (self.slots.len() - 1) as u32
        };
        self.next[idx as usize] = NIL;
        if self.tail == NIL {
            self.head = idx;
        } else {
            self.next[self.tail as usize] = idx;
        }
        self.tail = idx;
        self.len += 1;
    }

    /// Unlink slot `at`, whose FIFO predecessor is `prev` (`NIL` when
    /// `at` is the head), and return its payload. The slot goes back on
    /// the free list; relative order of the survivors is untouched.
    fn remove_after(&mut self, prev: u32, at: u32) -> Pending {
        let nxt = self.next[at as usize];
        if prev == NIL {
            self.head = nxt;
        } else {
            self.next[prev as usize] = nxt;
        }
        if self.tail == at {
            self.tail = prev;
        }
        self.next[at as usize] = self.free;
        self.free = at;
        self.len -= 1;
        self.slots[at as usize]
    }

    /// FIFO-order iteration (same order `VecDeque::iter` gave).
    fn iter(&self) -> SlabIter<'_> {
        SlabIter { q: self, cur: self.head }
    }
}

struct SlabIter<'a> {
    q: &'a SlabQueue,
    cur: u32,
}

impl<'a> Iterator for SlabIter<'a> {
    type Item = &'a Pending;

    fn next(&mut self) -> Option<&'a Pending> {
        if self.cur == NIL {
            return None;
        }
        let p = &self.q.slots[self.cur as usize];
        self.cur = self.q.next[self.cur as usize];
        Some(p)
    }
}

/// Aggregate controller statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CtrlStats {
    pub reads_done: u64,
    pub writes_done: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    pub row_conflicts: u64,
    pub total_read_latency: u64,
    pub refreshes: u64,
    pub issued_cycles: u64,
    pub busy_cycles: u64,
}

impl CtrlStats {
    pub fn avg_read_latency(&self) -> f64 {
        if self.reads_done == 0 {
            0.0
        } else {
            self.total_read_latency as f64 / self.reads_done as f64
        }
    }

    pub fn row_hit_rate(&self) -> f64 {
        let total = self.row_hits + self.row_misses + self.row_conflicts;
        if total == 0 {
            0.0
        } else {
            self.row_hits as f64 / total as f64
        }
    }
}

pub struct Controller {
    pub map: AddrMap,
    ranks: Vec<Rank>,
    policy: RowPolicy,
    read_q: SlabQueue,
    write_q: SlabQueue,
    /// Write drain hysteresis (vLLM-router-style watermark batching, here
    /// the classic write-drain watermarks).
    draining_writes: bool,
    wq_hi: usize,
    wq_lo: usize,
    capacity: usize,
    /// Refresh bookkeeping: next refresh deadline per rank.
    next_refresh: Vec<Cycle>,
    refresh_due: Vec<bool>,
    /// In-flight column accesses: (data-ready cycle, completion record).
    inflight: Vec<(Cycle, Completion)>,
    /// Requests moved queue -> inflight so far. The time-skip driver
    /// watches this to learn when queue space opened up for a core whose
    /// enqueue was refused (`System::run_fast`).
    dequeues: u64,
    pub stats: CtrlStats,
    timings_ns: TimingParams,
    tck_ns: f64,
    /// Refresh-interval multiple of the 64 ms standard (AL-DRAM leaves it
    /// at 1.0; §7.1 experiments vary it).
    refresh_scale: f64,
    /// Command tap: every issued command (plus timing-set switches) is
    /// forwarded here. None in normal operation — the disabled cost is
    /// one branch per issue site.
    tap: Option<Rc<RefCell<dyn CmdSink>>>,
    /// Seeded bug for the checker mutation harness (None = correct).
    mutation: Option<GateMutation>,
    /// Completions retired by the latest `tick`, reused across calls so
    /// the per-cycle hot path never allocates.
    done: Vec<Completion>,
    /// Bumped on every state change that can move a scheduling gate or
    /// deadline (enqueue, any issued command, a retirement, a refresh
    /// deadline coming due, a timing install). `next_event_hint` caches
    /// its scan against this, so idle re-queries are O(1).
    gen: u64,
    /// `(gen at scan time, scanned bound)` — see `next_event_hint`.
    hint: Cell<(u64, Cycle)>,
}

impl Controller {
    pub fn new(map: AddrMap, timings: TimingParams, policy: RowPolicy) -> Self {
        let tck = 1.25;
        let tc = timings.to_cycles(tck);
        let ranks = (0..map.ranks()).map(|_| Rank::new(map.banks(), tc)).collect();
        let n_ranks = map.ranks();
        let capacity = 32;
        Controller {
            map,
            ranks,
            policy,
            read_q: SlabQueue::new(capacity),
            write_q: SlabQueue::new(capacity),
            draining_writes: false,
            wq_hi: 24,
            wq_lo: 8,
            capacity,
            next_refresh: vec![tc.trefi as u64; n_ranks],
            refresh_due: vec![false; n_ranks],
            inflight: Vec::new(),
            dequeues: 0,
            stats: CtrlStats::default(),
            timings_ns: timings,
            tck_ns: tck,
            refresh_scale: 1.0,
            tap: None,
            mutation: None,
            done: Vec::new(),
            gen: 1,
            hint: Cell::new((0, 0)),
        }
    }

    /// Record a state change that can move a scheduling gate or deadline;
    /// invalidates the cached `next_event_hint` scan. Over-bumping is
    /// safe (one extra scan); a missed bump would serve a stale hint and
    /// corrupt a time skip, so every mutating site below calls this.
    #[inline]
    fn touch(&mut self) {
        self.gen = self.gen.wrapping_add(1);
    }

    /// Attach a command sink (protocol checker / trace writer). The sink
    /// is immediately told the current timing set and refresh scale, and
    /// from then on sees every issued command and every timing switch in
    /// issue order. Must be attached before any region table is installed
    /// (the `System` constructor attaches taps first).
    pub fn attach_tap(&mut self, tap: Rc<RefCell<dyn CmdSink>>) {
        {
            let mut t = tap.borrow_mut();
            t.on_timings(&self.timings_ns);
            t.on_refresh_scale(self.refresh_scale);
        }
        self.tap = Some(tap);
    }

    #[inline]
    fn tap_cmd(&self, kind: CmdKind, rank: usize, bank: usize, row: u64,
               now: Cycle) {
        if let Some(tap) = &self.tap {
            tap.borrow_mut().cmd(Cmd { kind, rank: rank as u8,
                                       bank: bank as u8, row, cycle: now });
        }
    }

    /// Seed (or clear) a gate bug for the mutation harness. Forwarded to
    /// every rank; the tREFI-postponement mutant lives in `trefi()`.
    pub fn set_gate_mutation(&mut self, m: Option<GateMutation>) {
        self.mutation = m;
        for r in &mut self.ranks {
            r.set_mutation(m);
        }
        self.touch();
    }

    pub fn timings(&self) -> &TimingParams {
        &self.timings_ns
    }

    pub fn tck_ns(&self) -> f64 {
        self.tck_ns
    }

    /// AL-DRAM hook: install a new timing set. Takes effect immediately
    /// for new commands (the controller applies it between requests, and
    /// the mechanism only calls this at refresh boundaries).
    pub fn set_timings(&mut self, timings: TimingParams) {
        self.timings_ns = timings;
        let tc = timings.to_cycles(self.tck_ns);
        for r in &mut self.ranks {
            r.set_timings(tc);
        }
        self.touch();
        if let Some(tap) = &self.tap {
            tap.borrow_mut().on_timings(&timings);
        }
    }

    /// Bank-granular AL-DRAM (§5.2 future work): install per-bank core
    /// timings on one bank of the given rank (None restores the rank set).
    pub fn set_bank_timings(&mut self, rank: usize, bank: usize,
                            timings: Option<TimingParams>) {
        let tc = timings.map(|t| t.to_cycles(self.tck_ns));
        self.ranks[rank].set_bank_timings(bank, tc);
        self.touch();
    }

    /// Region-granular AL-DRAM: install per-(bank, row-region) core
    /// timings on every rank, bank-major with `banks * regions_per_bank`
    /// entries (`None` restores rank granularity). The region index is
    /// the decoded row's top bits (`row >> (row_bits - log2(regions))`),
    /// so `regions_per_bank` must be a power of two.
    pub fn set_region_timings(&mut self, regions_per_bank: usize,
                              timings: Option<&[TimingParams]>) {
        if let Some(tap) = &self.tap {
            tap.borrow_mut().on_region_timings(regions_per_bank, timings);
        }
        let Some(ts) = timings else {
            for r in &mut self.ranks {
                r.set_region_timings(None);
            }
            self.touch();
            return;
        };
        assert!(regions_per_bank.is_power_of_two(),
                "regions per bank must be a power of two, got \
                 {regions_per_bank}");
        let bits = regions_per_bank.trailing_zeros();
        assert!(bits <= self.map.row_bits,
                "{regions_per_bank} regions exceed {} row bits",
                self.map.row_bits);
        assert_eq!(ts.len(), self.map.banks() * regions_per_bank,
                   "region timing vector does not tile the banks");
        // One shared allocation per install: every rank holds the same
        // table, so an AL-DRAM epoch switch clones `Arc`s, not the
        // O(banks × regions) timing vector per rank.
        let rc = Arc::new(RegionCycles {
            regions_per_bank,
            shift: self.map.row_bits - bits,
            t: ts.iter().map(|t| t.to_cycles(self.tck_ns)).collect(),
        });
        for r in &mut self.ranks {
            r.set_region_timings(Some(Arc::clone(&rc)));
        }
        self.touch();
    }

    /// §7.1: scale the refresh interval (1.0 = standard 64 ms). Deadlines
    /// that have not yet come due are re-seeded so the *first* interval
    /// after the change already honors the new tREFI (they were laid out
    /// with the old interval at construction / the previous REF).
    pub fn set_refresh_scale(&mut self, scale: f64) {
        assert!(scale > 0.0);
        let old = self.trefi();
        self.refresh_scale = scale;
        let new = self.trefi();
        for (r, deadline) in self.next_refresh.iter_mut().enumerate() {
            if !self.refresh_due[r] {
                *deadline = (*deadline + new).saturating_sub(old);
            }
        }
        self.touch();
        if let Some(tap) = &self.tap {
            tap.borrow_mut().on_refresh_scale(scale);
        }
    }

    /// Whether the write queue is currently in drain mode (crossed `wq_hi`
    /// and has not yet fallen back below `wq_lo`).
    pub fn draining_writes(&self) -> bool {
        self.draining_writes
    }

    /// Whether rank `rank` has a refresh pending (its tREFI deadline has
    /// passed and the REF command has not issued yet). While pending, the
    /// scheduler fences the rank off from new commands.
    pub fn refresh_pending(&self, rank: usize) -> bool {
        self.refresh_due[rank]
    }

    fn trefi(&self) -> u64 {
        let tc: TimingCycles = self.timings_ns.to_cycles(self.tck_ns);
        let base = ((tc.trefi as f64) * self.refresh_scale).max(1.0) as u64;
        // Mutation harness: stretch the interval past the JEDEC 9x tREFI
        // postponement bound (16x so the bug is unambiguous).
        if self.mutation == Some(GateMutation::TrefiPostpone) {
            base * 16
        } else {
            base
        }
    }

    pub fn can_accept(&self, is_write: bool) -> bool {
        if is_write {
            self.write_q.len() < self.capacity
        } else {
            self.read_q.len() < self.capacity
        }
    }

    pub fn enqueue(&mut self, req: Request) -> bool {
        if !self.can_accept(req.is_write) {
            return false;
        }
        let d = self.map.decode(req.addr);
        let p = Pending { req, rank: d.rank, bank: d.bank, row: d.row,
                          counted: false };
        if req.is_write {
            self.write_q.push_back(p);
        } else {
            self.read_q.push_back(p);
        }
        self.touch();
        true
    }

    pub fn read_queue_len(&self) -> usize {
        self.read_q.len()
    }

    pub fn write_queue_len(&self) -> usize {
        self.write_q.len()
    }

    pub fn pending(&self) -> usize {
        self.read_q.len() + self.write_q.len() + self.inflight.len()
    }

    pub fn ranks(&self) -> &[Rank] {
        &self.ranks
    }

    /// Advance one controller cycle; returns completions whose data burst
    /// finished this cycle. The slice borrows a controller-owned buffer
    /// (valid until the next `tick`), so the per-cycle path allocates
    /// nothing.
    pub fn tick(&mut self, now: Cycle) -> &[Completion] {
        // 1. Retire finished bursts into the reused completion buffer.
        self.done.clear();
        let done = &mut self.done;
        self.inflight.retain(|(ready, c)| {
            if *ready <= now {
                done.push(*c);
                false
            } else {
                true
            }
        });
        if !self.done.is_empty() {
            self.touch();
        }
        for c in &self.done {
            if c.is_write {
                self.stats.writes_done += 1;
            } else {
                self.stats.reads_done += 1;
                self.stats.total_read_latency += c.finish - c.arrival;
            }
        }
        self.tick_commands(now);
        &self.done
    }

    /// The command-issue half of `tick` (split out so the early-exit
    /// "one command per cycle" returns don't fight the borrow on the
    /// completion buffer).
    fn tick_commands(&mut self, now: Cycle) {
        // 2. Refresh management: when tREFI elapses, drain the rank and
        //    issue REF (highest priority — postponement is bounded).
        //    Scheduling below refuses new commands to a rank with a
        //    pending refresh (see `schedule_queue`); without that, a
        //    row-hit-heavy stream keeps `can_pre` closed forever (every
        //    column command pushes the bank's earliest-PRE out by tRTP /
        //    tWR) and REF is postponed unboundedly.
        for r in 0..self.ranks.len() {
            if now >= self.next_refresh[r] && !self.refresh_due[r] {
                self.refresh_due[r] = true;
                self.touch();
            }
            if self.refresh_due[r] {
                // Close open rows as they become precharge-able.
                if !self.ranks[r].all_banks_idle() {
                    for b in 0..self.map.banks() {
                        if let Some(row) = self.ranks[r].banks[b].open_row() {
                            if self.ranks[r].can_pre(b, now) {
                                self.ranks[r].issue_pre(b, now);
                                self.tap_cmd(CmdKind::Pre, r, b, row, now);
                                self.stats.issued_cycles += 1;
                                self.touch();
                                return; // one command per cycle
                            }
                        }
                    }
                } else if self.ranks[r].can_refresh(now) {
                    self.ranks[r].issue_refresh(now);
                    self.tap_cmd(CmdKind::Ref, r, 0, 0, now);
                    self.refresh_due[r] = false;
                    self.next_refresh[r] += self.trefi();
                    self.stats.refreshes += 1;
                    self.stats.issued_cycles += 1;
                    self.touch();
                    return;
                }
            }
        }

        // 3. Write drain hysteresis.
        if self.write_q.len() >= self.wq_hi {
            self.draining_writes = true;
        }
        if self.write_q.len() <= self.wq_lo {
            self.draining_writes = false;
        }
        let writes_first = self.draining_writes || self.read_q.is_empty();

        // 4. FR-FCFS over the preferred queue, then the other.
        let issued = if writes_first {
            self.schedule_queue(true, now) || self.schedule_queue(false, now)
        } else {
            self.schedule_queue(false, now) || self.schedule_queue(true, now)
        };
        if issued {
            self.stats.issued_cycles += 1;
        }
        if self.pending() > 0 {
            self.stats.busy_cycles += 1;
        }

        // 5. Closed-page policy: precharge banks nobody wants.
        if self.policy == RowPolicy::Closed && !issued {
            'outer: for r in 0..self.ranks.len() {
                for b in 0..self.map.banks() {
                    if let Some(row) = self.ranks[r].banks[b].open_row() {
                        let wanted = self
                            .read_q
                            .iter()
                            .chain(self.write_q.iter())
                            .any(|p| p.rank == r && p.bank == b && p.row == row);
                        if !wanted && self.ranks[r].can_pre(b, now) {
                            self.ranks[r].issue_pre(b, now);
                            self.tap_cmd(CmdKind::Pre, r, b, row, now);
                            self.touch();
                            break 'outer;
                        }
                    }
                }
            }
        }
    }

    /// FR-FCFS: (1) oldest row-hit column command, (2) oldest request's
    /// ACT/PRE as needed. Returns true if a command issued.
    ///
    /// Ranks with a pending refresh are fenced off: issuing a column
    /// command there would push the bank's earliest-PRE deadline out
    /// (tRTP / tWR) and an ACT would reopen a row the refresh drain just
    /// closed, so either starves REF under a steady stream. Their
    /// requests stay queued until the refresh retires.
    fn schedule_queue(&mut self, writes: bool, now: Cycle) -> bool {
        let q = if writes { &self.write_q } else { &self.read_q };
        if q.is_empty() {
            return false;
        }

        // First-ready: oldest request whose column command can go now.
        // Walk the slab chain tracking the predecessor so the removal
        // below is a straight relink.
        let mut hit = NIL;
        let mut hit_prev = NIL;
        let mut prev = NIL;
        let mut cur = q.head;
        while cur != NIL {
            let p = &q.slots[cur as usize];
            if !self.refresh_due[p.rank] {
                let rk = &self.ranks[p.rank];
                let ok = if writes {
                    rk.can_write(p.bank, p.row, now)
                } else {
                    rk.can_read(p.bank, p.row, now)
                };
                if ok {
                    hit = cur;
                    hit_prev = prev;
                    break;
                }
            }
            prev = cur;
            cur = q.next[cur as usize];
        }
        if hit != NIL {
            let p = if writes {
                self.write_q.remove_after(hit_prev, hit)
            } else {
                self.read_q.remove_after(hit_prev, hit)
            };
            let rk = &mut self.ranks[p.rank];
            let data_end = if writes {
                rk.issue_write(p.bank, p.row, now)
            } else {
                rk.issue_read(p.bank, p.row, now)
            };
            let kind = if writes { CmdKind::Write } else { CmdKind::Read };
            self.tap_cmd(kind, p.rank, p.bank, p.row, now);
            if !p.counted {
                self.stats.row_hits += 1;
            }
            self.dequeues += 1;
            self.inflight.push((
                data_end,
                Completion {
                    id: p.req.id,
                    core: p.req.core,
                    is_write: writes,
                    arrival: p.req.arrival,
                    finish: data_end,
                },
            ));
            self.touch();
            return true;
        }

        // Otherwise service the oldest request on a refresh-free rank:
        // open its row (ACT) or close a conflicting row (PRE).
        let q = if writes { &self.write_q } else { &self.read_q };
        let mut head_idx = NIL;
        let mut cur = q.head;
        while cur != NIL {
            if !self.refresh_due[q.slots[cur as usize].rank] {
                head_idx = cur;
                break;
            }
            cur = q.next[cur as usize];
        }
        if head_idx == NIL {
            return false;
        }
        let head = q.slots[head_idx as usize];
        match self.ranks[head.rank].banks[head.bank].open_row() {
            Some(row) if row != head.row => {
                if self.ranks[head.rank].can_pre(head.bank, now) {
                    self.ranks[head.rank].issue_pre(head.bank, now);
                    self.tap_cmd(CmdKind::Pre, head.rank, head.bank, row, now);
                    if !head.counted {
                        self.stats.row_conflicts += 1;
                    }
                    self.mark_counted(writes, head_idx);
                    self.touch();
                    return true;
                }
            }
            None => {
                if self.ranks[head.rank].can_act(head.bank, now) {
                    self.ranks[head.rank].issue_act(head.bank, head.row, now);
                    self.tap_cmd(CmdKind::Act, head.rank, head.bank, head.row,
                                 now);
                    if !head.counted {
                        self.stats.row_misses += 1;
                    }
                    self.mark_counted(writes, head_idx);
                    self.touch();
                    return true;
                }
            }
            Some(_) => {
                // Row open but the column gate (tRCD/tCCD/turnaround) is
                // still closed — nothing to do this cycle.
            }
        }
        false
    }

    fn mark_counted(&mut self, writes: bool, slot: u32) {
        let q = if writes { &mut self.write_q } else { &mut self.read_q };
        q.slots[slot as usize].counted = true;
    }

    /// Requests moved from a queue into the in-flight set so far.
    pub fn dequeues(&self) -> u64 {
        self.dequeues
    }

    // ---- time-skip engine ----------------------------------------------

    /// Lower bound on the next cycle at which `tick` can make progress:
    /// retire an in-flight burst, hit a tREFI deadline, advance a pending
    /// refresh drain, or issue a command for a queued request. The bound
    /// is conservative (an early hint costs one no-op tick; a late one
    /// would corrupt the skip, so every gate `tick` consults is covered).
    /// Early-exits at `now` — on saturated phases this costs a handful of
    /// comparisons before the driver falls back to per-cycle stepping.
    ///
    /// The scan is cached against `gen`: while no gate-moving state
    /// change happened (no enqueue, issue, retirement, or deadline flip),
    /// the gate set is frozen, so the previously scanned bound is exact
    /// and re-queries are O(1). A cached early-exit bound stays valid
    /// too: the gate that was open at cache time stays open (≤ any later
    /// `now`) until a command services it — which bumps `gen`.
    pub fn next_event_hint(&self, now: Cycle) -> Cycle {
        let (gen, e) = self.hint.get();
        if gen == self.gen {
            return e.max(now);
        }
        let e = self.scan_next_event(now);
        self.hint.set((self.gen, e));
        e.max(now)
    }

    /// The uncached hint scan (see `next_event_hint`).
    fn scan_next_event(&self, now: Cycle) -> Cycle {
        let mut e = Cycle::MAX;
        for (ready, _) in &self.inflight {
            if *ready <= now {
                return *ready;
            }
            e = e.min(*ready);
        }
        for q in [&self.read_q, &self.write_q] {
            // Only the oldest non-fenced request is eligible for ACT/PRE
            // (FR-FCFS); every queued request is eligible for its column
            // command. Head identity is frozen until the next event, so
            // restricting ACT/PRE gates to it is exact, not a heuristic.
            let mut head = true;
            for p in q.iter() {
                if self.refresh_due[p.rank] {
                    continue;
                }
                let rk = &self.ranks[p.rank];
                let gate = match rk.banks[p.bank].open_row() {
                    Some(row) if row == p.row => {
                        Some(rk.earliest_col(p.bank, p.req.is_write))
                    }
                    Some(_) if head => Some(rk.earliest_pre(p.bank)),
                    None if head => Some(rk.earliest_act(p.bank)),
                    _ => None,
                };
                head = false;
                if let Some(g) = gate {
                    if g <= now {
                        return g;
                    }
                    e = e.min(g);
                }
            }
        }
        for (r, rk) in self.ranks.iter().enumerate() {
            if !self.refresh_due[r] {
                e = e.min(self.next_refresh[r]);
            } else if rk.all_banks_idle() {
                e = e.min(rk.earliest_refresh());
            } else {
                for b in 0..rk.banks.len() {
                    if rk.banks[b].open_row().is_some() {
                        e = e.min(rk.earliest_pre(b));
                    }
                }
            }
        }
        if self.policy == RowPolicy::Closed {
            for rk in &self.ranks {
                for b in 0..rk.banks.len() {
                    if rk.banks[b].open_row().is_some() {
                        e = e.min(rk.earliest_pre(b));
                    }
                }
            }
        }
        e
    }

    /// Account for `span` cycles the time-skip driver proved idle: `tick`
    /// would only have bumped `busy_cycles` on each of them.
    pub fn advance_idle(&mut self, span: u64) {
        if self.pending() > 0 {
            self.stats.busy_cycles += span;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctrl(policy: RowPolicy) -> Controller {
        Controller::new(AddrMap::ddr3_2gb(1), TimingParams::ddr3_standard(),
                        policy)
    }

    fn run_until_done(c: &mut Controller, mut now: Cycle, limit: Cycle)
                      -> (Vec<Completion>, Cycle) {
        let mut out = Vec::new();
        while c.pending() > 0 && now < limit {
            out.extend(c.tick(now));
            now += 1;
        }
        (out, now)
    }

    #[test]
    fn single_read_completes_with_miss_latency() {
        let mut c = ctrl(RowPolicy::Open);
        c.enqueue(Request { id: 1, core: 0, addr: 0x100_0000, is_write: false,
                            arrival: 0 });
        let (done, _) = run_until_done(&mut c, 0, 10_000);
        assert_eq!(done.len(), 1);
        let t = TimingParams::ddr3_standard().to_cycles(1.25);
        let expect = (t.trcd + t.tcl + t.tburst) as u64;
        assert_eq!(done[0].finish, expect);
        assert_eq!(c.stats.row_misses, 1);
    }

    #[test]
    fn reduced_timings_cut_read_latency() {
        let mut base = ctrl(RowPolicy::Open);
        let mut fast = ctrl(RowPolicy::Open);
        fast.set_timings(TimingParams::ddr3_standard()
            .reduced(0.27, 0.32, 0.33, 0.18));
        for c in [&mut base, &mut fast] {
            // row conflict chain: same bank, different rows
            c.enqueue(Request { id: 1, core: 0, addr: 0, is_write: false,
                                arrival: 0 });
            let row_stride = 8 * c.map.row_bytes(); // same bank, next row
            c.enqueue(Request { id: 2, core: 0, addr: row_stride,
                                is_write: false, arrival: 0 });
        }
        let (db, _) = run_until_done(&mut base, 0, 100_000);
        let (df, _) = run_until_done(&mut fast, 0, 100_000);
        let base_t = db.iter().map(|c| c.finish).max().unwrap();
        let fast_t = df.iter().map(|c| c.finish).max().unwrap();
        assert!(fast_t < base_t, "fast {fast_t} >= base {base_t}");
    }

    #[test]
    fn row_hits_beat_row_misses() {
        let mut c = ctrl(RowPolicy::Open);
        for i in 0..8u64 {
            c.enqueue(Request { id: i, core: 0, addr: i * 64,
                                is_write: false, arrival: 0 });
        }
        let (done, _) = run_until_done(&mut c, 0, 100_000);
        assert_eq!(done.len(), 8);
        assert_eq!(c.stats.row_misses, 1, "one ACT for the stream");
        // The ACT-causing request is the miss; the other 7 reuse its row.
        // Each request lands in exactly one bucket.
        assert_eq!(c.stats.row_hits, 7);
        assert_eq!(c.stats.row_hits + c.stats.row_misses
                   + c.stats.row_conflicts, 8);
        assert!(c.stats.row_hit_rate() > 0.8);
    }

    #[test]
    fn each_request_counts_once_in_row_stats() {
        // A conflict chain: same bank, alternating rows. Pre-fix, each
        // conflicting request was triple-counted (PRE conflict + ACT miss
        // + column "hit"), inflating row_hit_rate.
        let mut c = ctrl(RowPolicy::Open);
        let row_stride = 8 * c.map.row_bytes(); // same bank, next row
        for i in 0..6u64 {
            c.enqueue(Request { id: i, core: 0, addr: (i % 2) * row_stride,
                                is_write: false, arrival: 0 });
        }
        let (done, _) = run_until_done(&mut c, 0, 100_000);
        assert_eq!(done.len(), 6);
        let s = &c.stats;
        assert_eq!(s.row_hits + s.row_misses + s.row_conflicts, 6,
                   "hits {} misses {} conflicts {}", s.row_hits,
                   s.row_misses, s.row_conflicts);
        // FR-FCFS batches the same-row requests: one miss opens row 0,
        // one conflict closes it for row 1, everything else is a hit.
        assert_eq!(s.row_misses, 1);
        assert!(s.row_conflicts >= 1, "alternating rows must conflict");
    }

    #[test]
    fn writes_drain_with_hysteresis() {
        let mut c = ctrl(RowPolicy::Open);
        for i in 0..26u64 {
            assert!(c.enqueue(Request { id: i, core: 0, addr: i * 64,
                                        is_write: true, arrival: 0 }));
        }
        let (done, _) = run_until_done(&mut c, 0, 1_000_000);
        assert_eq!(done.len(), 26);
        assert_eq!(c.stats.writes_done, 26);
    }

    #[test]
    fn refresh_happens_on_schedule() {
        let mut c = ctrl(RowPolicy::Open);
        let trefi = TimingParams::ddr3_standard().to_cycles(1.25).trefi as u64;
        let horizon = trefi * 4 + 1000;
        for now in 0..horizon {
            c.tick(now);
        }
        assert!(c.stats.refreshes >= 4,
                "only {} refreshes in 4 tREFI", c.stats.refreshes);
    }

    #[test]
    fn closed_policy_precharges_idle_rows() {
        let mut c = ctrl(RowPolicy::Closed);
        c.enqueue(Request { id: 1, core: 0, addr: 0, is_write: false,
                            arrival: 0 });
        let (_, end) = run_until_done(&mut c, 0, 10_000);
        // Let the policy close the row afterwards.
        for now in end..end + 200 {
            c.tick(now);
        }
        assert!(c.ranks()[0].all_banks_idle());
    }

    #[test]
    fn row_hit_stream_cannot_starve_refresh() {
        // Regression for the refresh-starvation bug: a saturating
        // row-hit read stream keeps the bank's earliest-PRE deadline
        // perpetually in the future (every READ pushes it out by tRTP),
        // so a scheduler that keeps issuing to a refresh-pending rank
        // never finds a precharge-able bank and REF is postponed forever.
        // The fix fences refresh-pending ranks off from new commands.
        let mut c = ctrl(RowPolicy::Open);
        let trefi = TimingParams::ddr3_standard().to_cycles(1.25).trefi as u64;
        let horizon = trefi * 4 + 2000;
        let mut id = 0u64;
        let mut fence_cycles = 0u64;
        for now in 0..horizon {
            while c.can_accept(false) {
                id += 1;
                // Same 8 KiB row over and over: pure row hits.
                c.enqueue(Request { id, core: 0, addr: (id * 64) % 8192,
                                    is_write: false, arrival: now });
            }
            c.tick(now);
            if c.refresh_pending(0) {
                fence_cycles += 1;
            }
        }
        assert_eq!(c.stats.refreshes, 4,
                   "stream must not starve refresh: {} REFs in 4 tREFI",
                   c.stats.refreshes);
        assert!(c.stats.reads_done > 1000, "stream still makes progress");
        // The fence engages briefly around each tREFI deadline (drain +
        // REF), never for a significant fraction of the run.
        assert!(fence_cycles > 0, "fence never engaged");
        assert!(fence_cycles < horizon / 10,
                "fence held too long: {fence_cycles} of {horizon} cycles");
    }

    #[test]
    fn write_drain_hysteresis_flips_at_watermarks() {
        let mut c = ctrl(RowPolicy::Open);
        assert!(!c.draining_writes());
        // Fill to wq_hi (24): drain mode engages on the next tick.
        for i in 0..24u64 {
            assert!(c.enqueue(Request { id: i, core: 0, addr: i * 64,
                                        is_write: true, arrival: 0 }));
        }
        c.tick(0);
        assert!(c.draining_writes(), "crossing wq_hi engages drain");
        // Drain until the queue falls to wq_lo (8): mode must disengage,
        // and must have stayed engaged at every level in between
        // (hysteresis, not a single threshold).
        let mut now = 1u64;
        while c.write_queue_len() > 8 {
            assert!(c.draining_writes(),
                    "drain persists between wq_lo and wq_hi (len {})",
                    c.write_queue_len());
            c.tick(now);
            now += 1;
            assert!(now < 100_000, "drain stalled");
        }
        c.tick(now);
        assert!(!c.draining_writes(), "reaching wq_lo disengages drain");
    }

    #[test]
    fn refresh_scale_stretches_observed_period() {
        // §7.1: doubling the refresh interval halves the observed REF
        // rate on an idle controller.
        let trefi = TimingParams::ddr3_standard().to_cycles(1.25).trefi as u64;
        let horizon = trefi * 8;
        let mut base = ctrl(RowPolicy::Open);
        let mut scaled = ctrl(RowPolicy::Open);
        scaled.set_refresh_scale(2.0);
        for now in 0..horizon {
            base.tick(now);
            scaled.tick(now);
        }
        assert!(base.stats.refreshes >= 7,
                "base {} REFs in 8 tREFI", base.stats.refreshes);
        assert!(scaled.stats.refreshes >= 3 && scaled.stats.refreshes <= 5,
                "2x-scaled {} REFs in 8 tREFI (expect ~4)",
                scaled.stats.refreshes);
    }

    #[test]
    fn scaled_refresh_first_interval_honors_scale() {
        // Regression: next_refresh was seeded with the unscaled tREFI in
        // `new`, so the first REF of a 2x-scaled controller fired at
        // ~1*tREFI instead of ~2*tREFI.
        let trefi = TimingParams::ddr3_standard().to_cycles(1.25).trefi as u64;
        let mut c = ctrl(RowPolicy::Open);
        c.set_refresh_scale(2.0);
        let mut first_ref = None;
        for now in 0..3 * trefi {
            c.tick(now);
            if c.stats.refreshes >= 1 {
                first_ref = Some(now);
                break;
            }
        }
        let first = first_ref.expect("no REF within 3 tREFI");
        assert!(first >= 2 * trefi && first <= 2 * trefi + 200,
                "first REF at {first}, expected ~{}", 2 * trefi);
    }

    #[test]
    fn hint_matches_first_actionable_cycle() {
        // Time-skip contract on a live controller: between `now` and the
        // hint, tick() must be a pure no-op (the oracle equivalence test
        // in tests/integration_timeskip.rs covers the full system).
        let mut c = ctrl(RowPolicy::Open);
        c.enqueue(Request { id: 1, core: 0, addr: 0, is_write: false,
                            arrival: 0 });
        let mut now = 0;
        while c.pending() > 0 {
            let hint = c.next_event_hint(now);
            for idle in now..hint {
                let before = c.stats;
                assert!(c.tick(idle).is_empty(),
                        "tick acted at {idle} before hint {hint}");
                let mut after = c.stats;
                after.busy_cycles = before.busy_cycles;
                assert_eq!(before, after,
                           "stats changed at {idle} before hint {hint}");
            }
            now = hint.max(now);
            c.tick(now);
            now += 1;
            assert!(now < 10_000, "drain wedged");
        }
    }

    #[test]
    fn queue_capacity_backpressures() {
        let mut c = ctrl(RowPolicy::Open);
        let mut accepted = 0;
        for i in 0..100u64 {
            if c.enqueue(Request { id: i, core: 0, addr: i * 131072,
                                   is_write: false, arrival: 0 }) {
                accepted += 1;
            }
        }
        assert_eq!(accepted, 32, "read queue capacity");
    }
}
