//! Full-system simulation: N cores x M channels, cycle-stepped. This is
//! the "real system" of §6/Fig 4 — baseline DDR3 timings vs. AL-DRAM's
//! reduced timings, with the AL-DRAM mechanism optionally managing the
//! timing set from the thermal model at refresh granularity.
//!
//! The unit of configuration is the *channel*: each channel carries its
//! own DIMM identity — a timing set, an optional AL-DRAM table built from
//! that DIMM's profile, and an ambient temperature — and owns a private
//! `ThermalModel` fed by that channel's windowed bus utilization. This is
//! the paper's per-module adaptation (§4/§6): two channels populated with
//! different DIMMs run different timings and drift thermally apart.
//! `SystemConfig::uniform` keeps the common all-channels-alike case a
//! one-liner.

use std::cell::RefCell;
use std::path::Path;
use std::rc::Rc;

use super::address::AddrMap;
use super::controller::{CmdSink, Controller, Request, RowPolicy};
use super::cpu::Core;
use super::dram::GateMutation;
use crate::aldram::{AlDram, RegionTable, ThermalModel};
use crate::check::{self, CheckSummary, ProtocolChecker};
use crate::timing::TimingParams;
use crate::workloads::trace::{self, Recorder, SharedTraceWriter, StreamMeta};
use crate::workloads::{NamedSource, WorkloadSpec};

/// Per-channel DIMM identity: the timing set the channel boots with, an
/// optional AL-DRAM table managing it dynamically, and the channel's
/// ambient temperature (DIMMs in one chassis can sit in different airflow).
#[derive(Debug, Clone)]
pub struct ChannelConfig {
    pub timings: TimingParams,
    /// If set, AL-DRAM manages this channel's timings from its thermal
    /// model at refresh-epoch granularity. A uniform table reproduces the
    /// module-granular mechanism; a region table additionally installs
    /// per-(bank, row-region) timings on the controller (DESIGN.md §12).
    pub aldram: Option<RegionTable>,
    /// Ambient temperature for this channel's thermal model (degC).
    pub ambient_c: f64,
}

impl ChannelConfig {
    /// A channel at standard DDR3 timings, unmanaged.
    pub fn standard(ambient_c: f64) -> Self {
        ChannelConfig {
            timings: TimingParams::ddr3_standard(),
            aldram: None,
            ambient_c,
        }
    }

    /// A channel whose DIMM is AL-DRAM-managed by the given table; boots
    /// at standard timings until the first thermal epoch installs the
    /// table's bin for the measured temperature.
    pub fn profiled(table: AlDram, ambient_c: f64) -> Self {
        Self::profiled_regions(RegionTable::uniform(table), ambient_c)
    }

    /// [`ChannelConfig::profiled`] at region granularity: the table's
    /// per-(bank, row-region) bins are installed alongside the module
    /// collapse whenever the thermal bin changes.
    pub fn profiled_regions(table: RegionTable, ambient_c: f64) -> Self {
        ChannelConfig {
            timings: TimingParams::ddr3_standard(),
            aldram: Some(table),
            ambient_c,
        }
    }
}

#[derive(Debug, Clone)]
pub struct SystemConfig {
    /// One entry per channel (the length is the channel count; must be a
    /// power of two for the address interleave).
    pub channels: Vec<ChannelConfig>,
    pub ranks_per_channel: usize,
    pub policy: RowPolicy,
}

impl SystemConfig {
    /// The paper's evaluated configuration: one channel, one rank,
    /// open-page, 55degC operating temperature.
    pub fn paper_default() -> Self {
        SystemConfig::uniform(1, ChannelConfig::standard(55.0))
    }

    /// `n` identical channels (the pre-heterogeneity common case), one
    /// rank each, open-page.
    pub fn uniform(n: usize, channel: ChannelConfig) -> Self {
        SystemConfig {
            channels: vec![channel; n],
            ranks_per_channel: 1,
            policy: RowPolicy::Open,
        }
    }

    pub fn channel_count(&self) -> usize {
        self.channels.len()
    }

    /// Replicate the first channel's configuration across `n` channels.
    pub fn with_channels(mut self, n: usize) -> Self {
        let ch = self.channels.first().expect("config has no channels").clone();
        self.channels = vec![ch; n];
        self
    }

    /// Set every channel's timing set.
    pub fn with_timings(mut self, timings: TimingParams) -> Self {
        for ch in &mut self.channels {
            ch.timings = timings;
        }
        self
    }

    /// Set every channel's AL-DRAM table (module-uniform).
    pub fn with_aldram(mut self, aldram: Option<AlDram>) -> Self {
        self.with_region_table(aldram.map(RegionTable::uniform))
    }

    /// Set every channel's AL-DRAM table at region granularity.
    pub fn with_region_table(mut self, table: Option<RegionTable>) -> Self {
        for ch in &mut self.channels {
            ch.aldram = table.clone();
        }
        self
    }

    /// Set every channel's ambient temperature.
    pub fn with_ambient(mut self, ambient_c: f64) -> Self {
        for ch in &mut self.channels {
            ch.ambient_c = ambient_c;
        }
        self
    }
}

#[derive(Debug, Clone)]
pub struct CoreStats {
    pub name: String,
    pub insts: u64,
    pub ipc: f64,
    pub reads: u64,
    pub writes: u64,
    pub stall_cycles: u64,
}

/// Per-channel slice of the run: traffic, latency, and the thermal /
/// AL-DRAM trajectory of that channel's DIMM.
#[derive(Debug, Clone)]
pub struct ChannelStats {
    pub reads_done: u64,
    pub writes_done: u64,
    pub avg_read_latency_cycles: f64,
    pub row_hit_rate: f64,
    /// Mean / final temperature of this channel's DIMM over the run.
    pub mean_temp_c: f64,
    pub final_temp_c: f64,
    /// How many times AL-DRAM installed a *different* timing set on this
    /// channel (0 for unmanaged channels).
    pub timing_switches: u64,
}

#[derive(Debug, Clone)]
pub struct SystemStats {
    pub cycles: u64,
    pub cores: Vec<CoreStats>,
    pub avg_read_latency_cycles: f64,
    pub row_hit_rate: f64,
    pub reads_done: u64,
    pub writes_done: u64,
    pub refreshes: u64,
    /// Bus data cycles / total cycles (bandwidth utilization proxy).
    pub bus_utilization: f64,
    /// Per-channel traffic/latency/thermal breakdown.
    pub channels: Vec<ChannelStats>,
    /// Power-model inputs per channel.
    pub power_inputs: Vec<crate::power::PowerInputs>,
    /// Mean DIMM temperature over the run, averaged across channels.
    pub mean_temp_c: f64,
    /// Average across channels of the end-of-run DIMM temperature.
    pub final_temp_c: f64,
    /// Open-loop instrumentation (None for closed-loop runs).
    pub open_loop: Option<OpenLoopStats>,
}

/// What an open-loop run adds on top of `SystemStats`: the offered /
/// completed accounting, the saturation verdict, and the merged
/// read-latency histogram all tail quantiles come from (DESIGN.md §16).
/// `PartialEq` is exact — the run/run_fast equivalence tests compare
/// the whole struct, histogram bins included.
#[derive(Debug, Clone, PartialEq)]
pub struct OpenLoopStats {
    /// Arrivals admitted to the cores' arrival queues.
    pub offered: u64,
    /// An arrival queue overflowed: the offered load is past the knee
    /// (the run halts at the next thermal epoch when this latches).
    pub saturated: bool,
    /// The saturation halt ended the run before its cycle budget.
    pub halted: bool,
    /// Arrival-to-completion read latency, merged across cores (all
    /// cores share one grid: `cpu::LAT_HIST_MAX` × `LAT_HIST_BINS`).
    pub hist: crate::util::hist::StreamHist,
}

impl SystemStats {
    /// Weighted speedup against a baseline run of the same workload set:
    /// the mean over cores of the per-core IPC ratio — the standard
    /// multi-programmed metric (insensitive to one core dominating the
    /// throughput sum). This is the accounting `eval::fig6` and
    /// `eval::hetero_eval` report for named mixes.
    pub fn weighted_speedup(&self, base: &SystemStats) -> f64 {
        assert_eq!(self.cores.len(), base.cores.len(),
                   "weighted speedup needs matching core sets");
        crate::util::mean(
            &self
                .cores
                .iter()
                .zip(&base.cores)
                .map(|(f, b)| f.ipc / b.ipc)
                .collect::<Vec<_>>(),
        )
    }
}

/// Thermal + AL-DRAM management interval in controller cycles (~1.28 us —
/// far finer than the <= 0.1 degC/s drift the paper measures).
pub const THERMAL_EPOCH: u64 = 1024;

/// Per-channel runtime state: the thermal model and AL-DRAM bookkeeping
/// for one channel's DIMM.
struct ChannelState {
    thermal: ThermalModel,
    aldram: Option<RegionTable>,
    /// Timing set currently installed on the controller (tracked so a
    /// table lookup that resolves to the same bin is not a "switch").
    installed: TimingParams,
    /// Temperature bin whose region timings are installed (region tables
    /// only; module timings can coincide across bins whose region entries
    /// differ, so the bin is tracked separately from `installed`).
    installed_bin: Option<usize>,
    temp_acc: f64,
    temp_samples: u64,
    /// Column completions observed up to the previous thermal epoch, so
    /// the thermal model sees the *windowed* utilization of the last
    /// epoch, not a run-cumulative average.
    last_epoch_done: u64,
    timing_switches: u64,
}

pub struct System {
    controllers: Vec<Controller>,
    cores: Vec<Core>,
    core_names: Vec<String>,
    /// Identity of each core's request source (what the trace-capture
    /// hook persists into the file header).
    source_meta: Vec<StreamMeta>,
    channels: Vec<ChannelState>,
    chan_bits_mask: u64,
    /// Channel interleave shift: one row per channel stripe, derived from
    /// the address map's row size.
    chan_shift: u32,
    /// The address map's row size (the trace header's geometry anchor).
    row_bytes: u64,
    /// Protocol checkers, one per channel, when conformance auditing is
    /// on (explicitly via [`System::enable_check`] or globally via
    /// `check::enable_inline`). Empty otherwise — the tap in the
    /// controller is `None` and costs one branch per issued command.
    checkers: Vec<Rc<RefCell<ProtocolChecker>>>,
    now: u64,
    /// An open-loop saturation halt fired: the run ended early and any
    /// further `run`/`run_fast` call returns immediately (so chunked
    /// drivers like the lockstep engine stop at the same cycle as a
    /// single-call run — DESIGN.md §16).
    halted: bool,
}

impl System {
    pub fn new(cfg: &SystemConfig, workloads: &[(WorkloadSpec, String)]) -> Self {
        Self::new_with_map(cfg, AddrMap::ddr3_2gb(cfg.ranks_per_channel),
                           workloads)
    }

    /// Build with an explicit address map (the default is the paper's
    /// 2 GB single-channel map). Channel striping follows the map's row
    /// size, so a different row geometry keeps row-granular interleave.
    pub fn new_with_map(cfg: &SystemConfig, map: AddrMap,
                        workloads: &[(WorkloadSpec, String)]) -> Self {
        let sources = workloads
            .iter()
            .map(|(w, seed)| w.named_source(seed))
            .collect();
        Self::with_sources_map(cfg, map, sources)
    }

    /// Build from arbitrary request sources (synthetic generators, trace
    /// replays, mixes — anything implementing `RequestSource`), one per
    /// core, on the default address map.
    pub fn with_sources(cfg: &SystemConfig, sources: Vec<NamedSource>)
                        -> Self {
        Self::with_sources_map(cfg, AddrMap::ddr3_2gb(cfg.ranks_per_channel),
                               sources)
    }

    /// [`System::with_sources`] with an explicit address map.
    pub fn with_sources_map(cfg: &SystemConfig, map: AddrMap,
                            sources: Vec<NamedSource>) -> Self {
        assert!(!cfg.channels.is_empty(), "config has no channels");
        assert!(cfg.channels.len().is_power_of_two());
        assert!(!sources.is_empty(), "a system needs at least one core");
        let controllers = cfg
            .channels
            .iter()
            .map(|ch| Controller::new(map, ch.timings, cfg.policy))
            .collect();
        let channels = cfg
            .channels
            .iter()
            .map(|ch| ChannelState {
                thermal: ThermalModel::new(ch.ambient_c),
                aldram: ch.aldram.clone(),
                installed: ch.timings,
                installed_bin: None,
                temp_acc: 0.0,
                temp_samples: 0,
                last_epoch_done: 0,
                timing_switches: 0,
            })
            .collect();
        let core_names: Vec<String> =
            sources.iter().map(|s| s.name.clone()).collect();
        let source_meta: Vec<StreamMeta> = sources
            .iter()
            .map(|s| StreamMeta {
                name: s.name.clone(),
                seed: s.seed.clone(),
                footprint: s.footprint,
            })
            .collect();
        let cores = sources
            .into_iter()
            .enumerate()
            .map(|(i, s)| Core::new(i, s.source))
            .collect();
        let mut sys = System {
            controllers,
            cores,
            core_names,
            source_meta,
            channels,
            chan_bits_mask: cfg.channels.len() as u64 - 1,
            chan_shift: map.row_bytes().trailing_zeros(),
            row_bytes: map.row_bytes(),
            checkers: Vec::new(),
            now: 0,
            halted: false,
        };
        // `--check` attaches a conformance audit to every System any
        // harness builds, without threading a flag through each one.
        if check::inline_enabled() {
            sys.enable_check();
        }
        sys
    }

    /// Attach an independent `ProtocolChecker` to every channel's command
    /// tap. Must run before the first simulated cycle (the audit derives
    /// bank state from the stream, so it has to see it from cycle 0).
    /// Idempotent.
    pub fn enable_check(&mut self) {
        assert_eq!(self.now, 0,
                   "attach the protocol checker before running the system");
        if !self.checkers.is_empty() {
            return;
        }
        for ctrl in &mut self.controllers {
            let ck = Rc::new(RefCell::new(ProtocolChecker::new(
                ctrl.map.ranks(), ctrl.map.banks(), ctrl.map.row_bits,
                ctrl.tck_ns())));
            ctrl.attach_tap(ck.clone());
            self.checkers.push(ck);
        }
    }

    /// Aggregate conformance audit across channels (None when
    /// [`System::enable_check`] was never called).
    pub fn check_summary(&self) -> Option<CheckSummary> {
        if self.checkers.is_empty() {
            return None;
        }
        let mut total = CheckSummary::default();
        for ck in &self.checkers {
            total.merge(&ck.borrow().summary());
        }
        total.systems = 1;
        Some(total)
    }

    /// Full per-channel audit reports (summary line + coverage matrix +
    /// violation samples) for `repro check run`.
    pub fn check_reports(&self) -> Vec<String> {
        self.checkers.iter().map(|ck| ck.borrow().report()).collect()
    }

    /// Attach an arbitrary command sink (e.g. a `CmdTraceWriter`) to one
    /// channel's tap. Same cycle-0 restriction as [`System::enable_check`];
    /// a channel carries at most one tap, so this is mutually exclusive
    /// with checking that channel inline.
    pub fn attach_cmd_tap(&mut self, channel: usize,
                          tap: Rc<RefCell<dyn CmdSink>>) {
        assert_eq!(self.now, 0,
                   "attach command taps before running the system");
        self.controllers[channel].attach_tap(tap);
    }

    /// Mutation harness: apply one deliberately-broken timing gate to
    /// every channel (None restores correct gates).
    pub fn set_gate_mutation(&mut self, m: Option<GateMutation>) {
        for ctrl in &mut self.controllers {
            ctrl.set_gate_mutation(m);
        }
    }

    /// Trace-capture hook: tee every reference the cores pull from their
    /// sources into an ALDT trace file at `path`. Works for *any* run —
    /// synthetic workloads, mixes, even a replay. Must be attached before
    /// the first simulated cycle; call [`trace::finish_shared`] on the
    /// returned writer after the run to seal the file.
    pub fn record_to(&mut self, path: &Path)
                     -> anyhow::Result<SharedTraceWriter> {
        anyhow::ensure!(self.now == 0,
                        "attach the recorder before running the system");
        for core in &self.cores {
            anyhow::ensure!(core.source_untouched(),
                            "core {} already pulled references", core.id);
        }
        let writer = trace::create_shared(path, self.row_bytes as u32,
                                          &self.source_meta)?;
        for (i, core) in self.cores.iter_mut().enumerate() {
            let w = writer.clone();
            core.wrap_source(move |inner| Box::new(Recorder::new(inner, i, w)));
        }
        Ok(writer)
    }

    /// Channel selection: interleave by row-sized blocks so streams spread
    /// across channels without breaking row locality.
    pub fn channel_of(&self, addr: u64) -> usize {
        ((addr >> self.chan_shift) & self.chan_bits_mask) as usize
    }

    /// §7.1 experiments: scale every channel's refresh interval.
    pub fn set_refresh_scale(&mut self, scale: f64) {
        for ctrl in &mut self.controllers {
            ctrl.set_refresh_scale(scale);
        }
    }

    pub fn step(&mut self) {
        let now = self.now;

        // Cores issue (channel_of inlined: closures cannot borrow self
        // while controllers are mutably split out).
        for core in &mut self.cores {
            let controllers = &mut self.controllers;
            let mask = self.chan_bits_mask;
            let shift = self.chan_shift;
            let mut try_send = |req: Request| {
                let ch = ((req.addr >> shift) & mask) as usize;
                controllers[ch].enqueue(req)
            };
            core.step(now, &mut try_send);
        }

        // Memory advances; completions wake cores (open-loop cores also
        // record arrival-to-finish latency — complete_read is exactly
        // on_completion for closed-loop cores).
        for ctrl in &mut self.controllers {
            for c in ctrl.tick(now) {
                if !c.is_write {
                    self.cores[c.core].complete_read(c.id, c.arrival,
                                                     c.finish);
                }
            }
        }

        // Thermal + AL-DRAM management at the epoch granularity, per
        // channel: each DIMM heats with its own traffic and consults its
        // own table.
        if now % THERMAL_EPOCH == 0 {
            for (ch, ctrl) in
                self.channels.iter_mut().zip(&mut self.controllers)
            {
                let done = ctrl.stats.reads_done + ctrl.stats.writes_done;
                let delta = done - ch.last_epoch_done;
                ch.last_epoch_done = done;
                // Windowed utilization of the last epoch (run-cumulative
                // counts would hide phase changes from the thermal model).
                let util =
                    ((delta * 4) as f64 / THERMAL_EPOCH as f64).min(1.0);
                let temp =
                    ch.thermal.step(THERMAL_EPOCH as f64 * 1.25e-9, util);
                ch.temp_acc += temp;
                ch.temp_samples += 1;
                if let Some(rt) = &ch.aldram {
                    let t = rt.module().timings_for(temp);
                    if t != ch.installed {
                        ch.installed = t;
                        ch.timing_switches += 1;
                        ctrl.set_timings(t);
                    }
                    if !rt.is_uniform() {
                        let bin = rt.bin_index(temp);
                        if ch.installed_bin != Some(bin) {
                            ch.installed_bin = Some(bin);
                            ctrl.set_region_timings(
                                rt.regions_per_bank(),
                                Some(&rt.region_timings_for(temp)));
                        }
                    }
                }
            }
        }

        self.now += 1;
    }

    /// Switch every core to open-loop mode (bounded arrival queue of
    /// `bound`, per-read latency histograms — see `mem::cpu` and
    /// DESIGN.md §16). Must run before the first cycle; pair the system
    /// with `workloads::arrival` sources, whose `gap_insts` carry
    /// inter-arrival gaps in controller cycles.
    pub fn set_open_loop(&mut self, bound: usize) {
        assert_eq!(self.now, 0, "set_open_loop after the system ran");
        for core in &mut self.cores {
            core.set_open_loop(bound);
        }
    }

    /// Any open-loop core's arrival queue overflowed: offered load
    /// exceeds sustainable throughput (always false closed-loop).
    pub fn open_loop_saturated(&self) -> bool {
        self.cores.iter().any(Core::open_loop_saturated)
    }

    /// The run was terminated early by the saturation halt.
    pub fn halted(&self) -> bool {
        self.halted
    }

    /// The saturation halt, identical under both drivers: fire only
    /// right after an epoch-boundary cycle was *stepped* (the time-skip
    /// driver steps every epoch boundary — its skip target is clamped
    /// to the next one — and by then deferred admission has caught up,
    /// so the latch state agrees with per-cycle stepping there).
    fn halt_check(&mut self) -> bool {
        if self.now % THERMAL_EPOCH == 1 && self.open_loop_saturated() {
            self.halted = true;
        }
        self.halted
    }

    pub fn run(&mut self, cycles: u64) -> SystemStats {
        let start = self.now;
        while self.now - start < cycles && !self.halted {
            self.step();
            if self.halt_check() {
                break;
            }
        }
        self.stats()
    }

    /// Event-driven time-skip driver: identical semantics — bit-identical
    /// `SystemStats` — to `run`, but instead of polling every cycle it
    /// jumps `now` to the earliest cycle at which anything can happen:
    /// a core's next enqueue attempt (`Core::next_event`), a controller
    /// action (`Controller::next_event_hint`), or the next thermal/AL-DRAM
    /// epoch boundary. The skipped span is replayed in O(1) per component
    /// (`Core::skip`, `Controller::advance_idle`). `run` stays as the
    /// oracle; `tests/integration_timeskip.rs` asserts the equivalence.
    pub fn run_fast(&mut self, cycles: u64) -> SystemStats {
        let end = self.now + cycles;
        while self.now < end && !self.halted {
            let deq_before: u64 =
                self.controllers.iter().map(|c| c.dequeues()).sum();
            self.step();
            if self.halt_check() {
                break;
            }
            let deq_after: u64 =
                self.controllers.iter().map(|c| c.dequeues()).sum();
            if deq_after > deq_before {
                // Queue space opened up: cores whose enqueue was refused
                // may succeed again from the next cycle on.
                for core in &mut self.cores {
                    core.clear_queue_block();
                }
            }
            if self.now >= end {
                break;
            }
            let now = self.now;
            let epoch = if now % THERMAL_EPOCH == 0 {
                now
            } else {
                (now / THERMAL_EPOCH + 1) * THERMAL_EPOCH
            };
            let mut target = end.min(epoch);
            // Controllers first, lazily: on saturated phases the first
            // hint early-exits at `now` and the cores are never queried.
            for ctrl in &self.controllers {
                target = target.min(ctrl.next_event_hint(now));
                if target <= now {
                    break;
                }
            }
            if target > now {
                for core in &mut self.cores {
                    target = target.min(core.next_event(now));
                    if target <= now {
                        break;
                    }
                }
            }
            if target > now {
                let span = target - now;
                for core in &mut self.cores {
                    core.skip(span);
                }
                for ctrl in &mut self.controllers {
                    ctrl.advance_idle(span);
                }
                self.now = target;
            }
        }
        self.stats()
    }

    pub fn stats(&self) -> SystemStats {
        let cycles = self.now;
        let cores = self
            .cores
            .iter()
            .zip(&self.core_names)
            .map(|(c, name)| CoreStats {
                name: name.clone(),
                insts: c.insts,
                ipc: c.ipc(cycles),
                reads: c.reads_issued,
                writes: c.writes_issued,
                stall_cycles: c.stall_cycles,
            })
            .collect();
        let mut reads = 0;
        let mut writes = 0;
        let mut refreshes = 0;
        let mut lat_num = 0.0;
        let mut hit_num = 0.0;
        let mut hit_den = 0.0;
        let mut power_inputs = Vec::new();
        let mut channels = Vec::new();
        let mut temp_mean_sum = 0.0;
        let mut temp_final_sum = 0.0;
        for (ctrl, ch) in self.controllers.iter().zip(&self.channels) {
            let s = &ctrl.stats;
            reads += s.reads_done;
            writes += s.writes_done;
            refreshes += s.refreshes;
            lat_num += s.avg_read_latency() * s.reads_done as f64;
            hit_num += s.row_hits as f64;
            let ch_hit_den =
                (s.row_hits + s.row_misses + s.row_conflicts) as f64;
            hit_den += ch_hit_den;
            power_inputs.push(crate::power::PowerInputs::from_controller(
                ctrl, cycles));
            let mean_temp_c = if ch.temp_samples > 0 {
                ch.temp_acc / ch.temp_samples as f64
            } else {
                ch.thermal.temperature()
            };
            let final_temp_c = ch.thermal.temperature();
            temp_mean_sum += mean_temp_c;
            temp_final_sum += final_temp_c;
            channels.push(ChannelStats {
                reads_done: s.reads_done,
                writes_done: s.writes_done,
                avg_read_latency_cycles: s.avg_read_latency(),
                row_hit_rate: if ch_hit_den > 0.0 {
                    s.row_hits as f64 / ch_hit_den
                } else {
                    0.0
                },
                mean_temp_c,
                final_temp_c,
                timing_switches: ch.timing_switches,
            });
        }
        let n_ch = self.controllers.len() as f64;
        let open_loop = if self.cores.iter().any(Core::is_open_loop) {
            let mut hist = crate::util::hist::StreamHist::new(
                0.0, super::cpu::LAT_HIST_MAX, super::cpu::LAT_HIST_BINS);
            for c in &self.cores {
                hist.merge(c.latency_hist()
                    .expect("open-loop mode is per-system: set_open_loop \
                             converts every core"));
            }
            Some(OpenLoopStats {
                offered: self.cores.iter()
                    .map(Core::arrivals_offered).sum(),
                saturated: self.open_loop_saturated(),
                halted: self.halted,
                hist,
            })
        } else {
            None
        };
        SystemStats {
            cycles,
            cores,
            open_loop,
            avg_read_latency_cycles: if reads > 0 {
                lat_num / reads as f64
            } else {
                0.0
            },
            row_hit_rate: if hit_den > 0.0 { hit_num / hit_den } else { 0.0 },
            reads_done: reads,
            writes_done: writes,
            refreshes,
            bus_utilization: ((reads + writes) * 4) as f64
                / (cycles.max(1) * self.controllers.len() as u64) as f64,
            channels,
            power_inputs,
            mean_temp_c: temp_mean_sum / n_ch,
            final_temp_c: temp_final_sum / n_ch,
        }
    }

    /// Per-channel controllers (read-only; equivalence tests compare
    /// their `CtrlStats` across simulation drivers).
    pub fn controllers(&self) -> &[Controller] {
        &self.controllers
    }
}

impl Drop for System {
    /// Under the global `--check` flag, every System folds its audit into
    /// the process-wide accumulator when it dies, so `check::report_inline`
    /// at the end of `main` sees the whole fleet (including Systems built
    /// on `exec::Pool` worker threads).
    fn drop(&mut self) {
        if check::inline_enabled() {
            if let Some(s) = self.check_summary() {
                check::record_inline(&s);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::by_name;

    fn run_one(name: &str, timings: TimingParams, cycles: u64) -> SystemStats {
        let cfg = SystemConfig::paper_default().with_timings(timings);
        let w = by_name(name).unwrap();
        let mut sys = System::new(&cfg, &[(w, "t/0".to_string())]);
        sys.run(cycles)
    }

    #[test]
    fn stream_saturates_bandwidth() {
        let s = run_one("stream.copy", TimingParams::ddr3_standard(), 200_000);
        assert!(s.bus_utilization > 0.3, "util {}", s.bus_utilization);
        assert!(s.row_hit_rate > 0.5, "hit rate {}", s.row_hit_rate);
    }

    #[test]
    fn compute_bound_workload_is_memory_insensitive() {
        let base = run_one("povray", TimingParams::ddr3_standard(), 150_000);
        let fast = run_one(
            "povray",
            TimingParams::ddr3_standard().reduced(0.27, 0.32, 0.33, 0.18),
            150_000,
        );
        let speedup = fast.cores[0].ipc / base.cores[0].ipc;
        assert!(speedup < 1.05, "povray speedup {speedup}");
        assert!(base.cores[0].ipc > 3.0, "ipc {}", base.cores[0].ipc);
    }

    #[test]
    fn aldram_timings_speed_up_memory_bound_workload() {
        let base = run_one("mcf", TimingParams::ddr3_standard(), 200_000);
        let fast = run_one(
            "mcf",
            TimingParams::ddr3_standard().reduced(0.27, 0.32, 0.33, 0.18),
            200_000,
        );
        let speedup = fast.cores[0].ipc / base.cores[0].ipc;
        assert!(speedup > 1.03, "mcf speedup {speedup}");
    }

    #[test]
    fn multicore_contention_increases_latency() {
        let cfg = SystemConfig::paper_default();
        let w = by_name("gups").unwrap();
        let mut one = System::new(&cfg, &[(w.clone(), "a".into())]);
        let s1 = one.run(150_000);
        let four: Vec<_> = (0..4)
            .map(|i| (w.clone(), format!("c{i}")))
            .collect();
        let mut m = System::new(&cfg, &four);
        let s4 = m.run(150_000);
        assert!(s4.avg_read_latency_cycles > s1.avg_read_latency_cycles,
                "queueing must raise latency: {} vs {}",
                s4.avg_read_latency_cycles, s1.avg_read_latency_cycles);
    }

    #[test]
    fn refreshes_track_runtime() {
        let s = run_one("hmmer", TimingParams::ddr3_standard(), 50_000);
        // 50k cycles / 6240-cycle tREFI ~ 8 refreshes.
        assert!(s.refreshes >= 6 && s.refreshes <= 10, "{}", s.refreshes);
    }
}

#[cfg(test)]
mod channel_tests {
    use super::*;
    use crate::workloads::by_name;

    #[test]
    fn channel_interleave_is_row_granular() {
        let cfg = SystemConfig::paper_default().with_channels(2);
        let w = by_name("gups").unwrap();
        let sys = System::new(&cfg, &[(w, "c".into())]);
        assert_eq!(sys.channel_of(0), 0);
        assert_eq!(sys.channel_of(8192), 1);
        assert_eq!(sys.channel_of(16384), 0);
        // same 8 KiB block -> same channel (row locality preserved)
        assert_eq!(sys.channel_of(64), sys.channel_of(4096));
    }

    #[test]
    fn channel_interleave_follows_the_address_map() {
        // Regression: the shift was hardcoded to `>> 13`, so a map with a
        // different row size lost row-granular striping. 16 KiB rows
        // (col_bits 8) must stripe at 16 KiB granularity.
        let cfg = SystemConfig::paper_default().with_channels(2);
        let map = AddrMap { col_bits: 8, ..AddrMap::ddr3_2gb(1) };
        assert_eq!(map.row_bytes(), 16 * 1024);
        let w = by_name("gups").unwrap();
        let sys = System::new_with_map(&cfg, map, &[(w, "c".into())]);
        assert_eq!(sys.channel_of(0), 0);
        assert_eq!(sys.channel_of(8192), 0, "same 16 KiB row, same channel");
        assert_eq!(sys.channel_of(16384), 1);
        assert_eq!(sys.channel_of(32768), 0);
        // The simulation itself stays consistent on the wider map.
        let w2 = by_name("stream.copy").unwrap();
        let map2 = AddrMap { col_bits: 8, ..AddrMap::ddr3_2gb(1) };
        let mut sys2 = System::new_with_map(&cfg, map2, &[(w2, "m".into())]);
        let s = sys2.run(10_000);
        assert!(s.reads_done + s.writes_done > 0);
    }

    #[test]
    fn channels_run_distinct_timing_sets() {
        // Two channels, the second with its own (faster) fixed AL-DRAM
        // table: the managed channel must serve its reads with lower
        // latency than the standard one, from the same address stream.
        let fast = TimingParams::ddr3_standard()
            .reduced(0.27, 0.32, 0.33, 0.18);
        let cfg = SystemConfig {
            channels: vec![
                ChannelConfig::standard(55.0),
                ChannelConfig::profiled(AlDram::fixed(fast), 55.0),
            ],
            ranks_per_channel: 1,
            policy: RowPolicy::Open,
        };
        let w = by_name("gups").unwrap();
        let wl: Vec<_> =
            (0..4).map(|i| (w.clone(), format!("hc/{i}"))).collect();
        let mut sys = System::new(&cfg, &wl);
        let s = sys.run(120_000);
        assert_eq!(s.channels.len(), 2);
        assert!(s.channels[0].reads_done > 0 && s.channels[1].reads_done > 0);
        assert!(s.channels[1].avg_read_latency_cycles
                    < s.channels[0].avg_read_latency_cycles,
                "managed channel not faster: {} vs {}",
                s.channels[1].avg_read_latency_cycles,
                s.channels[0].avg_read_latency_cycles);
        // The fixed table differs from the boot timings: exactly one
        // switch on the managed channel, none on the standard one.
        assert_eq!(s.channels[0].timing_switches, 0);
        assert_eq!(s.channels[1].timing_switches, 1);
    }

    #[test]
    fn channels_have_independent_thermal_state() {
        // Different ambient temperatures per channel: the stats must keep
        // the two DIMMs' trajectories apart.
        let cfg = SystemConfig {
            channels: vec![ChannelConfig::standard(30.0),
                           ChannelConfig::standard(70.0)],
            ranks_per_channel: 1,
            policy: RowPolicy::Open,
        };
        let w = by_name("stream.copy").unwrap();
        let mut sys = System::new(&cfg, &[(w, "th".into())]);
        let s = sys.run(100_000);
        assert!(s.channels[0].mean_temp_c < 40.0,
                "cool channel at {}", s.channels[0].mean_temp_c);
        assert!(s.channels[1].mean_temp_c > 60.0,
                "hot channel at {}", s.channels[1].mean_temp_c);
        // The system-level temperature is the across-channel average.
        let avg = (s.channels[0].mean_temp_c + s.channels[1].mean_temp_c)
            / 2.0;
        assert!((s.mean_temp_c - avg).abs() < 1e-12);
    }

    #[test]
    fn uniform_builder_matches_explicit_config() {
        let w = by_name("mcf").unwrap();
        let a = SystemConfig::uniform(2, ChannelConfig::standard(55.0));
        let b = SystemConfig::paper_default().with_channels(2);
        let sa = System::new(&a, &[(w.clone(), "u".into())]).run(20_000);
        let sb = System::new(&b, &[(w, "u".into())]).run(20_000);
        assert_eq!(sa.reads_done, sb.reads_done);
        assert_eq!(sa.cores[0].ipc, sb.cores[0].ipc);
        assert_eq!(sa.mean_temp_c, sb.mean_temp_c);
    }
}

#[cfg(test)]
mod thermal_window_tests {
    use super::*;
    use crate::workloads::{Pattern, WorkloadSpec};

    const MB: u64 = 1024 * 1024;

    fn phased(name: &'static str, active_refs: u64, idle_gap: u32,
              repeat: bool) -> WorkloadSpec {
        WorkloadSpec {
            name,
            pattern: Pattern::Phased { active_refs, idle_gap, repeat },
            mpki: 40.0,
            write_ratio: 0.3,
            footprint: 256 * MB,
        }
    }

    #[test]
    fn temperature_tracks_workload_phases() {
        // Regression for the run-cumulative bus-utilization bug: the
        // thermal model must see *windowed* utilization, so a bursty and
        // a front-loaded schedule of comparable work heat differently.
        let cfg = SystemConfig::paper_default().with_ambient(40.0);
        let front = phased("frontload", 3000, 2_000_000, false);
        let burst = phased("bursty", 400, 250_000, true);
        let sf = System::new(&cfg, &[(front, "ph".into())]).run(400_000);
        let sb = System::new(&cfg, &[(burst, "ph".into())]).run(400_000);
        assert!((sf.mean_temp_c - sb.mean_temp_c).abs() > 1e-9,
                "phase schedules indistinguishable: front {} bursty {}",
                sf.mean_temp_c, sb.mean_temp_c);
        // With windowed utilization the front-loaded run stops heating
        // once its burst ends (final ~ mean). The cumulative bug kept
        // target > temp all run, so final kept climbing past the mean.
        let rise_final = sf.final_temp_c - 40.0;
        let rise_mean = sf.mean_temp_c - 40.0;
        assert!(rise_mean > 0.0, "front-loaded burst never heated");
        assert!(rise_final <= 1.3 * rise_mean,
                "heating continued after the burst: final rise {rise_final:e} \
                 vs mean rise {rise_mean:e}");
    }
}
