//! Physical-address decomposition. Row:Rank:Bank:Column:Offset layout —
//! consecutive cache lines stripe across columns within a row, then banks,
//! so streaming workloads see row hits and bank-level parallelism (the
//! standard open-page-friendly interleaving).
//!
//! The optional [`RegionRemap`] layer is the variation-aware page
//! placement of region-indexed timing (DESIGN.md §12): a permutation of
//! the top row bits applied in `decode` (inverted in `encode`), steering
//! the low — most frequently touched — logical rows into the physically
//! fastest row regions. Off by default; purely a relabeling, so any
//! remapped map stays bijective.

use crate::aldram::RegionTable;

/// Upper bound on remappable row regions — fixed-size arrays keep
/// `AddrMap` `Copy`, which the controller relies on.
pub const MAX_REMAP_REGIONS: usize = 16;

/// Permutation of the top `log2(regions)` row bits: logical row region
/// (address order) -> physical row region (distance from sense amps).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RegionRemap {
    pub regions: u8,
    /// `row_bits - log2(regions)`: bits below the region index.
    pub shift: u8,
    fwd: [u8; MAX_REMAP_REGIONS],
    inv: [u8; MAX_REMAP_REGIONS],
}

impl RegionRemap {
    /// Build from an explicit logical->physical permutation.
    pub fn new(row_bits: u32, fwd_perm: &[usize]) -> Self {
        let regions = fwd_perm.len();
        assert!(regions.is_power_of_two() && regions >= 2
                && regions <= MAX_REMAP_REGIONS,
                "remap regions must be a power of two in [2, {}], got {}",
                MAX_REMAP_REGIONS, regions);
        let bits = regions.trailing_zeros();
        assert!(bits <= row_bits, "{regions} regions exceed {row_bits} row bits");
        let mut fwd = [0u8; MAX_REMAP_REGIONS];
        let mut inv = [u8::MAX; MAX_REMAP_REGIONS];
        for (g, p) in fwd_perm.iter().enumerate() {
            assert!(*p < regions, "region {p} out of range");
            assert!(inv[*p] == u8::MAX, "region {p} appears twice");
            fwd[g] = *p as u8;
            inv[*p] = g as u8;
        }
        RegionRemap {
            regions: regions as u8,
            shift: (row_bits - bits) as u8,
            fwd,
            inv,
        }
    }

    /// Placement policy: logical region 0 (the low rows every footprint
    /// touches first and most) goes to the physically fastest region —
    /// ranked by the mean 55degC read-latency sum across banks — and so
    /// on down to the slowest. Identity when the table is uniform in the
    /// row direction.
    pub fn fastest_first(table: &RegionTable, row_bits: u32) -> Self {
        let r = table.regions_per_bank();
        let mut score: Vec<(f64, usize)> = (0..r)
            .map(|region| {
                let s: f64 = (0..table.banks())
                    .map(|b| table.timings_for(b, region, 55.0).read_sum_ns())
                    .sum();
                (s / table.banks() as f64, region)
            })
            .collect();
        score.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let fwd: Vec<usize> = score.into_iter().map(|(_, p)| p).collect();
        Self::new(row_bits, &fwd)
    }

    #[inline]
    fn apply(&self, map: &[u8; MAX_REMAP_REGIONS], row: u64) -> u64 {
        let region = (row >> self.shift) as usize;
        let low = row & ((1u64 << self.shift) - 1);
        ((map[region] as u64) << self.shift) | low
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AddrMap {
    pub line_bits: u32, // 64 B cache line
    pub col_bits: u32,  // columns per row (of cache-line granularity)
    pub bank_bits: u32,
    pub rank_bits: u32,
    pub row_bits: u32,
    /// Variation-aware page placement (region-indexed timing); `None` =
    /// identity, the default.
    pub remap: Option<RegionRemap>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Decoded {
    pub rank: usize,
    pub bank: usize,
    pub row: u64,
    pub col: u64,
}

impl AddrMap {
    /// 1 rank x 8 banks x 32k rows x 128 lines/row (8 KB row) — a 2 GB
    /// channel, matching the evaluated system's single-rank channel.
    pub fn ddr3_2gb(ranks: usize) -> Self {
        assert!(ranks >= 1 && ranks.is_power_of_two(),
                "rank count must be a power of two, got {ranks}");
        AddrMap {
            line_bits: 6,
            col_bits: 7,
            bank_bits: 3,
            rank_bits: ranks.trailing_zeros(),
            row_bits: 15,
            remap: None,
        }
    }

    /// The same map with a region remap installed.
    pub fn with_remap(mut self, remap: RegionRemap) -> Self {
        assert!(u32::from(remap.shift)
                + remap.regions.trailing_zeros() == self.row_bits,
                "remap built for a different row width");
        self.remap = Some(remap);
        self
    }

    pub fn decode(&self, addr: u64) -> Decoded {
        debug_assert!(addr < self.capacity_bytes(),
                      "address {addr:#x} beyond the {} B channel",
                      self.capacity_bytes());
        let mut a = addr >> self.line_bits;
        let col = a & ((1 << self.col_bits) - 1);
        a >>= self.col_bits;
        let bank = (a & ((1 << self.bank_bits) - 1)) as usize;
        a >>= self.bank_bits;
        let rank = (a & ((1 << self.rank_bits) - 1)) as usize;
        a >>= self.rank_bits;
        let mut row = a & ((1 << self.row_bits) - 1);
        if let Some(m) = &self.remap {
            row = m.apply(&m.fwd, row);
        }
        Decoded { rank, bank, row, col }
    }

    pub fn encode(&self, d: &Decoded) -> u64 {
        let mut a = d.row;
        if let Some(m) = &self.remap {
            a = m.apply(&m.inv, a);
        }
        a = (a << self.rank_bits) | d.rank as u64;
        a = (a << self.bank_bits) | d.bank as u64;
        a = (a << self.col_bits) | d.col;
        a << self.line_bits
    }

    pub fn ranks(&self) -> usize {
        1 << self.rank_bits
    }

    pub fn banks(&self) -> usize {
        1 << self.bank_bits
    }

    pub fn row_bytes(&self) -> u64 {
        1 << (self.col_bits + self.line_bits)
    }

    pub fn capacity_bytes(&self) -> u64 {
        1u64 << (self.line_bits + self.col_bits + self.bank_bits
                 + self.rank_bits + self.row_bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_is_bijective() {
        let m = AddrMap::ddr3_2gb(2);
        for addr in [0u64, 64, 4096, 1 << 20, (1 << 31) - 64, 0x1234_5678 & !63]
        {
            let d = m.decode(addr);
            assert_eq!(m.encode(&d), addr & !((1 << m.line_bits) - 1));
        }
    }

    #[test]
    fn sequential_lines_share_a_row() {
        let m = AddrMap::ddr3_2gb(1);
        let d0 = m.decode(0);
        let d1 = m.decode(64);
        assert_eq!(d0.row, d1.row);
        assert_eq!(d0.bank, d1.bank);
        assert_eq!(d1.col, d0.col + 1);
    }

    #[test]
    fn row_stride_changes_bank_first() {
        let m = AddrMap::ddr3_2gb(1);
        let row_bytes = m.row_bytes();
        let d0 = m.decode(0);
        let d1 = m.decode(row_bytes);
        assert_eq!(d0.row, d1.row);
        assert_ne!(d0.bank, d1.bank);
    }

    #[test]
    fn capacity_2gb_single_rank() {
        let m = AddrMap::ddr3_2gb(1);
        assert_eq!(m.capacity_bytes(), 2 * 1024 * 1024 * 1024);
        assert_eq!(m.ranks(), 1);
        assert_eq!(m.banks(), 8);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn non_power_of_two_ranks_rejected() {
        // Regression: `3usize.trailing_zeros() == 0` used to silently
        // build a 1-rank map for a 3-rank request.
        let _ = AddrMap::ddr3_2gb(3);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "beyond the")]
    fn decode_rejects_out_of_range_addresses_in_debug() {
        let m = AddrMap::ddr3_2gb(1);
        let _ = m.decode(m.capacity_bytes());
    }

    #[test]
    fn remap_permutes_row_regions_bijectively() {
        let m = AddrMap::ddr3_2gb(1);
        let remap = RegionRemap::new(m.row_bits, &[2, 0, 3, 1]);
        let rm = m.with_remap(remap);
        // Logical region 0 decodes into physical region 2.
        let shift = m.row_bits - 2;
        let addr_of_row = |row: u64| row << (m.line_bits + m.col_bits
                                             + m.bank_bits + m.rank_bits);
        let d = rm.decode(addr_of_row(1));
        assert_eq!(d.row >> shift, 2);
        assert_eq!(d.row & ((1 << shift) - 1), 1);
        // encode inverts decode for every region, and the physical rows
        // seen across regions form a permutation.
        let mut seen = std::collections::BTreeSet::new();
        for g in 0..4u64 {
            let addr = addr_of_row(g << shift | 17);
            let d = rm.decode(addr);
            assert_eq!(rm.encode(&d), addr, "region {g} round trip");
            seen.insert(d.row >> shift);
        }
        assert_eq!(seen.len(), 4);
        // Without a remap the same addresses decode to identity regions.
        assert_eq!(m.decode(addr_of_row(1)).row, 1);
    }

    #[test]
    #[should_panic(expected = "appears twice")]
    fn remap_rejects_non_permutations() {
        let _ = RegionRemap::new(15, &[0, 0, 1, 2]);
    }
}
