//! Physical-address decomposition. Row:Rank:Bank:Column:Offset layout —
//! consecutive cache lines stripe across columns within a row, then banks,
//! so streaming workloads see row hits and bank-level parallelism (the
//! standard open-page-friendly interleaving).

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AddrMap {
    pub line_bits: u32, // 64 B cache line
    pub col_bits: u32,  // columns per row (of cache-line granularity)
    pub bank_bits: u32,
    pub rank_bits: u32,
    pub row_bits: u32,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Decoded {
    pub rank: usize,
    pub bank: usize,
    pub row: u64,
    pub col: u64,
}

impl AddrMap {
    /// 1 rank x 8 banks x 32k rows x 128 lines/row (8 KB row) — a 2 GB
    /// channel, matching the evaluated system's single-rank channel.
    pub fn ddr3_2gb(ranks: usize) -> Self {
        AddrMap {
            line_bits: 6,
            col_bits: 7,
            bank_bits: 3,
            rank_bits: ranks.trailing_zeros(),
            row_bits: 15,
        }
    }

    pub fn decode(&self, addr: u64) -> Decoded {
        let mut a = addr >> self.line_bits;
        let col = a & ((1 << self.col_bits) - 1);
        a >>= self.col_bits;
        let bank = (a & ((1 << self.bank_bits) - 1)) as usize;
        a >>= self.bank_bits;
        let rank = (a & ((1 << self.rank_bits) - 1)) as usize;
        a >>= self.rank_bits;
        let row = a & ((1 << self.row_bits) - 1);
        Decoded { rank, bank, row, col }
    }

    pub fn encode(&self, d: &Decoded) -> u64 {
        let mut a = d.row;
        a = (a << self.rank_bits) | d.rank as u64;
        a = (a << self.bank_bits) | d.bank as u64;
        a = (a << self.col_bits) | d.col;
        a << self.line_bits
    }

    pub fn ranks(&self) -> usize {
        1 << self.rank_bits
    }

    pub fn banks(&self) -> usize {
        1 << self.bank_bits
    }

    pub fn row_bytes(&self) -> u64 {
        1 << (self.col_bits + self.line_bits)
    }

    pub fn capacity_bytes(&self) -> u64 {
        1u64 << (self.line_bits + self.col_bits + self.bank_bits
                 + self.rank_bits + self.row_bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_is_bijective() {
        let m = AddrMap::ddr3_2gb(2);
        for addr in [0u64, 64, 4096, 1 << 20, (1 << 31) - 64, 0x1234_5678 & !63]
        {
            let d = m.decode(addr);
            assert_eq!(m.encode(&d), addr & !((1 << m.line_bits) - 1));
        }
    }

    #[test]
    fn sequential_lines_share_a_row() {
        let m = AddrMap::ddr3_2gb(1);
        let d0 = m.decode(0);
        let d1 = m.decode(64);
        assert_eq!(d0.row, d1.row);
        assert_eq!(d0.bank, d1.bank);
        assert_eq!(d1.col, d0.col + 1);
    }

    #[test]
    fn row_stride_changes_bank_first() {
        let m = AddrMap::ddr3_2gb(1);
        let row_bytes = m.row_bytes();
        let d0 = m.decode(0);
        let d1 = m.decode(row_bytes);
        assert_eq!(d0.row, d1.row);
        assert_ne!(d0.bank, d1.bank);
    }

    #[test]
    fn capacity_2gb_single_rank() {
        let m = AddrMap::ddr3_2gb(1);
        assert_eq!(m.capacity_bytes(), 2 * 1024 * 1024 * 1024);
        assert_eq!(m.ranks(), 1);
        assert_eq!(m.banks(), 8);
    }
}
