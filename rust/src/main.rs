//! `repro` — the AL-DRAM reproduction CLI (Layer-3 leader binary).
//!
//! Commands (see DESIGN.md §8 for the experiment index):
//!   repro calibrate  [--dimms N] [--cells N]
//!                    [--backend native|simd|pjrt|auto] [--jobs N]
//!   repro profile    --dimm N [--cells N] [--backend ...]
//!   repro profile    --dimms N --save DIR [--regions R]  (profile a
//!                    population once and persist it as a JSON registry,
//!                    one dimm_NNN.json each; --regions R additionally bins
//!                    every (bank, row-region) — registry format v2)
//!   repro figure     fig2a|fig2bc|fig3|fig4|fig6|all [--out DIR] [--jobs N]
//!                    [--profiles DIR] [--regions R]  (fig4/fig6: drive the
//!                    AL-DRAM side with a registry module's own table;
//!                    --regions loads the v2 region registry and reports the
//!                    region-indexed vs module-uniform delta)
//!   repro ablate     refresh-latency|interdependence|repeatability|
//!                    bank-granularity|ecc|sweep|ode [--jobs N]
//!   repro eval       sensitivity|hetero|power|stress|fig6|load [--cycles N]
//!                    [--jobs N] [--profiles DIR]  (profile-driven variants;
//!                    hetero/fig6 profile modules when --profiles is absent;
//!                    fig6: --workloads a,b,c --mixes N --seed S;
//!                    hetero: --regions R [--placement] scores region-
//!                    indexed tables against their module-uniform collapse;
//!                    load: open-loop latency-vs-throughput curves +
//!                    adaptive knee search across JEDEC/profiled[/region]
//!                    tables over one shared arrival stream — --workload W
//!                    --arrival poisson|bursty|diurnal --cores N --points K
//!                    --bound B --tol T --seed S [--regions R] [--no-bench])
//!   repro trace      record|replay|info|convert   (trace capture/replay:
//!                    record --workload W|--mix M [--cores N] --out FILE;
//!                    replay --trace FILE; --trace accepts ALDT binary or
//!                    DRAMSim3 text; convert translates between the two;
//!                    record/replay print a bit-exact STATS line for
//!                    round-trip diffing)
//!   repro bench-sim  [--cycles N] [--trace FILE]  (quick end-to-end smoke;
//!                    prints the TIMESKIP line: event-driven vs
//!                    cycle-stepped, the SPEEDUP[SOURCE] line: batched
//!                    vs per-reference source refill, and the
//!                    SPEEDUP[CHECK] line: inline conformance-audit
//!                    overhead)
//!   repro bench-profile [--cells N]        (profiling-engine smoke; prints
//!                    the SPEEDUP[PROFILE] and SPEEDUP[SWEEP] lines:
//!                    scalar native vs vectorized simd / probed+warm sweep)
//!   repro bench-load [--cycles N] [--load L] [--load-k K]  (open-loop
//!                    perf smoke; prints the SPEEDUP[LOAD] line:
//!                    arrival-aware time-skip vs the cycle-stepped oracle
//!                    at low offered load, and the SPEEDUP[LOADSWEEP]
//!                    line: K-config shared-stream lockstep vs
//!                    independent stream generations)
//!   repro bench all  [--json-dir DIR]      (run every bench suite and
//!                    write their SPEEDUP[*] comparisons as structured
//!                    records to BENCH_SIM.json / BENCH_PROFILE.json /
//!                    BENCH_LOAD.json — the repo-root baselines CI diffs
//!                    structurally)
//!   repro check      run|capture|replay|info|mutate   (independent JEDEC
//!                    protocol-conformance audit, DESIGN.md §13: `run`
//!                    audits a simulation inline (--driver fast|step|both
//!                    cross-certifies the drivers; --fuzz sources by
//!                    default, or --workload/--mix; --grid for the
//!                    adversarial region config); `capture --out F` records
//!                    the command stream to an ALCT file; `replay`/`info`
//!                    audit/validate one offline; `mutate` (also `repro
//!                    check --mutate`) runs the seeded gate-mutation
//!                    harness and fails unless every mutant is detected)
//!
//! `--check` on any other command attaches the conformance checker to
//! every simulated memory system and fails the process at exit if any
//! command-stream violation was observed (the aggregate CHECK line).
//!
//! Every system-level evaluation runs on the event-driven time-skip
//! driver (`System::run_fast`), which is bit-identical to the
//! cycle-stepped oracle (see DESIGN.md §6 and tests/integration_timeskip);
//! every profiling campaign defaults to the vectorized simd engine
//! (DESIGN.md §7), which produces error counts identical to the scalar
//! `native` oracle.
//!
//! `--jobs N` sets the worker count of the parallel execution engine
//! (`exec::Pool`) for every independent-simulation fan-out; it defaults to
//! the machine's available parallelism. `--jobs 1` is the exact sequential
//! path (results are identical either way — the pool's reduction is
//! order-independent).

use std::path::PathBuf;

use aldram::aldram::{AlDram, RegionTable, DEFAULT_BIN_C};
use aldram::cli::Args;
use aldram::exec;
use aldram::figures::{ablate, calibrate, fig2, fig3, fig4};
use aldram::model::params;
use aldram::population::generate_dimm;
use aldram::profiler::{profile_dimm, profile_dimm_regions, DimmProfile,
                       RegionDimmProfile};
use aldram::registry;
use aldram::runtime::{artifacts_dir, auto_backend, NativeBackend,
                      ProfilingBackend, SimdBackend};
use aldram::util::bench::SpeedupRecord;

fn make_backend(kind: &str, cells: usize) -> Box<dyn ProfilingBackend> {
    match kind {
        "native" => Box::new(NativeBackend::new()),
        "simd" => Box::new(SimdBackend::new()),
        #[cfg(feature = "pjrt")]
        "pjrt" => Box::new(
            aldram::runtime::PjrtBackend::for_cells(&artifacts_dir(), cells)
                .expect("PJRT backend requested but unavailable — run `make artifacts`"),
        ),
        #[cfg(not(feature = "pjrt"))]
        "pjrt" => panic!(
            "PJRT backend requested but this binary was built without the \
             `pjrt` feature — rebuild with `--features pjrt` (requires the \
             vendored xla bindings, see Cargo.toml)"
        ),
        "auto" => auto_backend(&artifacts_dir(), cells),
        other => panic!("unknown backend `{other}`"),
    }
}

fn backend_for(args: &Args, cells: usize) -> Box<dyn ProfilingBackend> {
    make_backend(&args.str("backend", "auto"), cells)
}

/// Resolve the `--profiles DIR` registry into a loaded population.
fn load_profiles(args: &Args) -> anyhow::Result<Vec<DimmProfile>> {
    let dir = PathBuf::from(args.str("profiles", "registry"));
    let profiles = registry::load_registry(&dir)?;
    eprintln!("loaded {} profiles from {}", profiles.len(), dir.display());
    Ok(profiles)
}

/// Pick one module out of a registry population (`--dimm N`, default: the
/// lowest id present) and build its table.
fn table_for(args: &Args, profiles: &[DimmProfile])
             -> anyhow::Result<(usize, AlDram)> {
    let want = args.get("dimm", profiles[0].id);
    let p = profiles.iter().find(|p| p.id == want).ok_or_else(|| {
        anyhow::anyhow!("dimm {want} is not in the registry")
    })?;
    Ok((p.id, AlDram::from_profile(p, DEFAULT_BIN_C)))
}

/// The validated `--regions R` flag: `None` when absent (module-uniform
/// paths), `Some(R)` — a power of two, as the controller's row-region
/// decode requires — when present.
fn regions_flag(args: &Args) -> anyhow::Result<Option<usize>> {
    if !args.has("regions") {
        return Ok(None);
    }
    let r = args.get("regions", 4usize);
    anyhow::ensure!(r >= 1 && r.is_power_of_two(),
                    "--regions must be a power of two >= 1, got {r}");
    Ok(Some(r))
}

/// Resolve the `--profiles DIR` registry into a region-granularity (v2)
/// population. Scalar (v1) registries fail here with a re-profile hint.
fn load_region_profiles(args: &Args)
                        -> anyhow::Result<Vec<RegionDimmProfile>> {
    let dir = PathBuf::from(args.str("profiles", "registry"));
    let profiles = registry::load_region_registry(&dir)?;
    eprintln!("loaded {} region profiles from {}", profiles.len(),
              dir.display());
    Ok(profiles)
}

/// Pick one module out of a v2 registry and build its region table.
fn region_table_for(args: &Args, profiles: &[RegionDimmProfile])
                    -> anyhow::Result<(usize, RegionTable)> {
    let want = args.get("dimm", profiles[0].base.id);
    let p = profiles.iter().find(|p| p.base.id == want).ok_or_else(|| {
        anyhow::anyhow!("dimm {want} is not in the registry")
    })?;
    Ok((p.base.id, RegionTable::try_from_region_profile(p, DEFAULT_BIN_C)?))
}

/// One module's region table: from the `--profiles` v2 registry when
/// given (its stored granularity must match `--regions`), else freshly
/// region-profiled — the region analogue of [`table_or_profile`].
fn region_table_or_profile(args: &Args, regions: usize)
                           -> anyhow::Result<(String, RegionTable)> {
    if args.has("profiles") {
        let profiles = load_region_profiles(args)?;
        let (id, table) = region_table_for(args, &profiles)?;
        anyhow::ensure!(
            table.regions_per_bank() == regions,
            "--regions {regions} but the registry holds {} regions per \
             bank — re-profile, or pass --regions {}",
            table.regions_per_bank(), table.regions_per_bank()
        );
        return Ok((format!("dimm {id:03}"), table));
    }
    let g = &params().geometry;
    let cells = args.get("cells", g.cells_per_chip_bank_small);
    let id = args.get("dimm", 0usize);
    eprintln!("no --profiles registry; region-profiling dimm {id:03} at \
               {cells} cells x {regions} regions (save a population with \
               `repro profile --save --regions {regions}`)");
    let mut b = backend_for(args, cells);
    let d = generate_dimm(id, cells, params());
    let p = profile_dimm_regions(b.as_mut(), &d, regions)?;
    Ok((format!("dimm {id:03}"),
        RegionTable::try_from_region_profile(&p, DEFAULT_BIN_C)?))
}

/// One module's table: from the `--profiles` registry when given, else
/// freshly profiled (`--dimm N`, small-cell default — the `eval hetero`
/// precedent for profile-less invocations).
fn table_or_profile(args: &Args) -> anyhow::Result<(String, AlDram)> {
    if args.has("profiles") {
        let profiles = load_profiles(args)?;
        let (id, table) = table_for(args, &profiles)?;
        return Ok((format!("dimm {id:03}"), table));
    }
    let g = &params().geometry;
    let cells = args.get("cells", g.cells_per_chip_bank_small);
    let id = args.get("dimm", 0usize);
    eprintln!("no --profiles registry; profiling dimm {id:03} at {cells} \
               cells (save a population with `repro profile --save`)");
    let mut b = backend_for(args, cells);
    let d = generate_dimm(id, cells, params());
    let p = profile_dimm(b.as_mut(), &d)?;
    Ok((format!("dimm {id:03}"), AlDram::from_profile(&p, DEFAULT_BIN_C)))
}

/// The Fig-6 unit set: `--workloads a,b,c` filters the 35-workload suite
/// (default: all of it), `--mixes N` truncates the named mix pool
/// (default: all 10).
fn fig6_units(args: &Args)
              -> anyhow::Result<(Vec<aldram::workloads::WorkloadSpec>,
                                 Vec<aldram::workloads::mix::MixSpec>)> {
    let workloads = if args.has("workloads") {
        args.str("workloads", "")
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|name| {
                aldram::workloads::by_name(name)
                    .ok_or_else(|| anyhow::anyhow!("unknown workload `{name}`"))
            })
            .collect::<anyhow::Result<Vec<_>>>()?
    } else {
        aldram::workloads::suite()
    };
    let mixes: Vec<_> = aldram::workloads::mix::suite()
        .into_iter()
        .take(args.get("mixes", usize::MAX))
        .collect();
    Ok((workloads, mixes))
}

fn run_fig6(args: &Args, jobs: usize, out: &std::path::Path)
            -> anyhow::Result<()> {
    let cycles = args.get("cycles", 100_000u64);
    let (workloads, mixes) = fig6_units(args)?;
    if let Some(regions) = regions_flag(args)? {
        let (label, table) = region_table_or_profile(args, regions)?;
        aldram::figures::fig6::fig6_regions(cycles, jobs, &table, &label,
                                            &args.seed(), &workloads, &mixes,
                                            out)?;
        return Ok(());
    }
    let (label, table) = table_or_profile(args)?;
    aldram::figures::fig6::fig6(cycles, jobs, &table, &label, &args.seed(),
                                &workloads, &mixes, out)?;
    Ok(())
}

/// Refuse to write a trace onto the file it is being read from (the
/// reader streams lazily, so `File::create` on the same path would
/// destroy the input mid-replay).
fn ensure_distinct_paths(input: &std::path::Path, out: &std::path::Path)
                         -> anyhow::Result<()> {
    let same = input == out
        || match (input.canonicalize(), out.canonicalize()) {
            (Ok(a), Ok(b)) => a == b,
            _ => false,
        };
    anyhow::ensure!(!same,
                    "--out {} would overwrite the input trace — pick a \
                     different output path", out.display());
    Ok(())
}

/// Canonical, diffable run summary: every count exact, every float as
/// its raw bits — two runs print the same line iff their `SystemStats`
/// are bit-identical (the trace record→replay CI check diffs these).
fn stats_line(s: &aldram::mem::SystemStats) -> String {
    let cores: Vec<String> = s
        .cores
        .iter()
        .map(|c| format!("{}:{}:{}:{}:{}:{:016x}", c.name, c.insts, c.reads,
                         c.writes, c.stall_cycles, c.ipc.to_bits()))
        .collect();
    format!(
        "STATS cycles={} reads={} writes={} refreshes={} lat={:016x} \
         hit={:016x} cores=[{}]",
        s.cycles, s.reads_done, s.writes_done, s.refreshes,
        s.avg_read_latency_cycles.to_bits(), s.row_hit_rate.to_bits(),
        cores.join(",")
    )
}

/// The `bench-sim` suite: one request source, base vs AL-DRAM, the
/// time-skip driver vs the cycle-stepped oracle (identical numbers,
/// TIMESKIP wall-clock line per timing set), plus the SPEEDUP[SOURCE]
/// line: batched vs per-reference source refill, plus the SPEEDUP[CHECK]
/// line: the inline protocol-conformance audit's overhead (observation-
/// only, identical stats asserted). Every comparison is also returned as
/// a structured record for `bench all`'s JSON emitter.
fn bench_sim(args: &Args) -> anyhow::Result<Vec<SpeedupRecord>> {
    use aldram::mem::{System, SystemConfig};
    use aldram::timing::TimingParams;
    use aldram::util::bench::Bench;
    use aldram::workloads::{by_name, trace, NamedSource, SOURCE_BATCH};
    use std::time::Instant;
    let cycles = args.get("cycles", 100_000u64);
    let seed = args.seed();
    let mut records: Vec<SpeedupRecord> = Vec::new();
    let sources_for = |label: &str| -> anyhow::Result<Vec<NamedSource>> {
        if args.has("trace") {
            let path = PathBuf::from(args.str("trace", ""));
            Ok(trace::open_any(&path)?.1)
        } else {
            let w = by_name(&args.str("workload", "stream.copy"))
                .expect("unknown workload");
            Ok(vec![w.named_source(&format!("bench/{seed}/{label}"))])
        }
    };
    for (label, t) in [
        ("ddr3-standard", TimingParams::ddr3_standard()),
        ("al-dram-55C", TimingParams::ddr3_standard()
            .reduced(0.27, 0.32, 0.33, 0.18)),
    ] {
        t.validate()?;
        let cfg = SystemConfig::paper_default().with_timings(t);
        let mut seq = System::with_sources(&cfg, sources_for(label)?);
        let t0 = Instant::now();
        let s = seq.run(cycles);
        let seq_ms = t0.elapsed().as_secs_f64() * 1e3;
        let mut fast = System::with_sources(&cfg, sources_for(label)?);
        let t0 = Instant::now();
        let f = fast.run_fast(cycles);
        let fast_ms = t0.elapsed().as_secs_f64() * 1e3;
        anyhow::ensure!(s.reads_done == f.reads_done
                        && s.cores[0].ipc == f.cores[0].ipc,
                        "drivers diverged on {label}");
        println!(
            "{label:<14} ipc {:.3}  read-lat {:.1} cyc  bw {:.1}%  hits {:.1}%",
            s.cores[0].ipc, s.avg_read_latency_cycles,
            100.0 * s.bus_utilization, 100.0 * s.row_hit_rate
        );
        println!(
            "  TIMESKIP {:.1} ms -> {:.1} ms ({:.2}x, identical stats)",
            seq_ms, fast_ms, seq_ms / fast_ms.max(1e-9)
        );
        records.push(SpeedupRecord {
            suite: "bench-sim".into(),
            tag: "TIMESKIP".into(),
            base: format!("run/{label}"),
            test: format!("run_fast/{label}"),
            speedup: seq_ms / fast_ms.max(1e-9),
            base_median_ns: seq_ms * 1e6,
            test_median_ns: fast_ms * 1e6,
        });
    }

    // Request-source refill batching: batch=1 is the pre-refactor
    // one-virtual-call-per-reference regime. Identical stats
    // (asserted), wall-clock-only difference. Always benched on a
    // synthetic generator — trace replay pulls through the demux
    // at the fixed SOURCE_BATCH, so batch=1 is not expressible
    // there; say so rather than silently switching sources.
    let wname = args.str("workload", "stream.copy");
    if args.has("trace") {
        println!("SOURCE batching benched on synthetic `{wname}` \
                  (trace replay has a fixed refill batch)");
    }
    let wsrc = by_name(&wname).expect("unknown workload");
    let run_batched = |batch: usize| {
        let cfg = SystemConfig::paper_default();
        let src = NamedSource {
            name: wsrc.name.to_string(),
            seed: format!("srcbench/{seed}"),
            footprint: wsrc.footprint,
            source: wsrc.source_with_batch(
                &format!("srcbench/{seed}"), batch),
        };
        System::with_sources(&cfg, vec![src]).run_fast(cycles)
    };
    let a = run_batched(1);
    let b = run_batched(SOURCE_BATCH);
    anyhow::ensure!(
        a.reads_done == b.reads_done && a.cores[0].ipc == b.cores[0].ipc,
        "refill batch size changed the simulated stream"
    );
    let mut bench = Bench::new("bench-sim").with_window(100, 400);
    bench.bench("source/batch1", || run_batched(1).reads_done);
    bench.bench(&format!("source/batch{SOURCE_BATCH}"),
                || run_batched(SOURCE_BATCH).reads_done);
    records.extend(bench.speedup_record(
        "SOURCE", "source/batch1",
        &format!("source/batch{SOURCE_BATCH}")));

    // Inline protocol-checker overhead (satellite of DESIGN.md §13):
    // identical run with and without the conformance audit attached. The
    // checker is observation-only — identical stats asserted, zero
    // violations required — so SPEEDUP[CHECK] is purely the tap + audit
    // cost (a ratio just under 1.0; EXPERIMENTS.md records it).
    let run_checked = |checked: bool| {
        let cfg = SystemConfig::paper_default();
        let src = NamedSource {
            name: wsrc.name.to_string(),
            seed: format!("checkbench/{seed}"),
            footprint: wsrc.footprint,
            source: wsrc.source_with_batch(
                &format!("checkbench/{seed}"), SOURCE_BATCH),
        };
        let mut sys = System::with_sources(&cfg, vec![src]);
        if checked {
            sys.enable_check();
        }
        let stats = sys.run_fast(cycles);
        let sum = sys.check_summary();
        (stats, sum)
    };
    let (plain, _) = run_checked(false);
    let (audited, sum) = run_checked(true);
    let sum = sum.expect("checker was attached");
    anyhow::ensure!(plain.reads_done == audited.reads_done
                    && plain.cores[0].ipc == audited.cores[0].ipc,
                    "attaching the checker changed the simulated stream");
    anyhow::ensure!(sum.violations == 0,
                    "bench workload violated the protocol: {}", sum.line());
    bench.bench("check/off", || run_checked(false).0.reads_done);
    bench.bench("check/on", || run_checked(true).0.reads_done);
    records.extend(bench.speedup_record("CHECK", "check/off", "check/on"));

    // Lockstep multi-config grid vs the independent-system oracle
    // (DESIGN.md §14): a fig4-style grid at K config variants — the
    // DDR3 standard plus K−1 progressively deeper reductions toward the
    // paper's 55 °C point — run once per engine at equal `--jobs`.
    // Bit-identical throughput for every cell is asserted before any
    // timing; SPEEDUP[LOCKSTEP] is what sharing one stream generation
    // (and one pool job) across a cell's K systems buys.
    use aldram::eval::{lockstep, Driver, Engine, MULTI_CORES};
    use aldram::workloads::suite;
    let k = args.get("lockstep-k", 8usize);
    let grid_cycles = args.get("lockstep-cycles", (cycles / 4).max(1));
    let grid_wl = args.get("lockstep-workloads", 6usize);
    let jobs = args.jobs();
    anyhow::ensure!(k >= 2, "--lockstep-k must be at least 2");
    let cfgs: Vec<SystemConfig> = (0..k)
        .map(|i| {
            let s = i as f64 / (k - 1) as f64;
            let t = TimingParams::ddr3_standard()
                .reduced(0.27 * s, 0.32 * s, 0.33 * s, 0.18 * s);
            t.validate().map(|_| {
                SystemConfig::paper_default().with_timings(t)
            })
        })
        .collect::<anyhow::Result<_>>()?;
    let wls: Vec<_> = suite().into_iter().take(grid_wl).collect();
    let core_cfgs = [1usize, MULTI_CORES];
    let run_grid = |engine: Engine| {
        lockstep::grid(&cfgs, &wls, &core_cfgs, grid_cycles, 1, jobs,
                       Driver::TimeSkip, engine)
    };
    let ind = run_grid(Engine::Independent);
    let lck = run_grid(Engine::Lockstep);
    anyhow::ensure!(ind == lck,
                    "lockstep grid diverged from the independent oracle");
    let sum_bits = |v: Vec<f64>| v.iter().sum::<f64>().to_bits();
    bench.bench(&format!("grid/independent/k{k}"),
                || sum_bits(run_grid(Engine::Independent)));
    bench.bench(&format!("grid/lockstep/k{k}"),
                || sum_bits(run_grid(Engine::Lockstep)));
    records.extend(bench.speedup_record(
        "LOCKSTEP", &format!("grid/independent/k{k}"),
        &format!("grid/lockstep/k{k}")));
    bench.finish();
    Ok(records)
}

/// The `bench-profile` suite: scalar native vs the vectorized simd
/// kernel on one combo batch, and the cold full-profile sweep ladder vs
/// the probed + warm-started one. Identical results (asserted here),
/// SPEEDUP[PROFILE] / SPEEDUP[SWEEP] lines for EXPERIMENTS.md and the
/// CI grep, returned as structured records for `bench all`.
fn bench_profile(args: &Args) -> anyhow::Result<Vec<SpeedupRecord>> {
    use aldram::profiler::{sweep_seeded, TestKind};
    use aldram::util::bench::Bench;
    let cells = args.get("cells", 512usize);
    let combos_n = args.get("combos", 64usize);
    let d = generate_dimm(args.get("dimm", 0usize), cells, params());
    let combos: Vec<aldram::model::Combo> = (0..combos_n)
        .map(|i| aldram::model::Combo {
            trcd: 13.75 - (i % 7) as f32 * 1.25,
            tras: 35.0 - (i % 11) as f32 * 1.25,
            twr: 15.0 - (i % 8) as f32 * 1.25,
            trp: 13.75 - (i % 7) as f32 * 1.25,
            tref_ms: 64.0 + (i % 48) as f32 * 8.0,
            temp_c: if i % 2 == 0 { 85.0 } else { 55.0 },
        })
        .collect();
    let mut native = NativeBackend::new();
    let mut simd = SimdBackend::new();
    let a = native.profile(&d.arrays, &combos)?;
    let b = simd.profile(&d.arrays, &combos)?;
    anyhow::ensure!(a.tot_r == b.tot_r && a.tot_w == b.tot_w,
                    "simd/native error counts diverged");

    let mut bench = Bench::new("bench-profile").with_window(80, 400);
    bench.bench(&format!("profile/native/cells{cells}"), || {
        native.profile(&d.arrays, &combos).unwrap().tot_r[0]
    });
    bench.bench(&format!("profile/simd/cells{cells}"), || {
        simd.profile(&d.arrays, &combos).unwrap().tot_r[0]
    });
    let mut records: Vec<SpeedupRecord> = Vec::new();
    records.extend(bench.speedup_record(
        "PROFILE",
        &format!("profile/native/cells{cells}"),
        &format!("profile/simd/cells{cells}"),
    ));

    // Two-point temperature ladder, as the fig3 campaign runs it.
    bench.bench("sweep/native-cold", || {
        let hot = aldram::profiler::sweep(
            &mut native, &d.arrays, TestKind::Read, 85.0, 200.0)
            .unwrap();
        let cool = aldram::profiler::sweep(
            &mut native, &d.arrays, TestKind::Read, 55.0, 200.0)
            .unwrap();
        (hot.best.map(|b| b.sum_ns), cool.best.map(|b| b.sum_ns))
    });
    bench.bench("sweep/simd-probe-warm", || {
        let hot = aldram::profiler::sweep(
            &mut simd, &d.arrays, TestKind::Read, 85.0, 200.0)
            .unwrap();
        let cool = sweep_seeded(&mut simd, &d.arrays, TestKind::Read,
                                55.0, 200.0, Some(&hot))
            .unwrap();
        (hot.best.map(|b| b.sum_ns), cool.best.map(|b| b.sum_ns))
    });
    records.extend(bench.speedup_record("SWEEP", "sweep/native-cold",
                                        "sweep/simd-probe-warm"));
    bench.finish();
    Ok(records)
}

/// `repro eval load` (DESIGN.md §16): knee search per timing table,
/// then a shared geometric load grid where every point runs all K
/// tables lockstep over ONE shared arrival-stream generation. Prints
/// per-table curves, `KNEE` lines, the `LOADGATE` comparison CI greps
/// (profiled knee/p99 vs JEDEC), writes `load_curves.csv`, and unless
/// `--no-bench` runs the `bench-load` suite for the `SPEEDUP[LOAD]` /
/// `SPEEDUP[LOADSWEEP]` lines.
fn eval_load(args: &Args, jobs: usize, out: &std::path::Path)
             -> anyhow::Result<()> {
    use aldram::eval::load::{self as load_eval, LoadCurve, LoadPoint,
                             KNEE_TOL, LOAD_BOUND};
    use aldram::eval::Driver;
    use aldram::figures::csv::Csv;
    use aldram::mem::SystemConfig;
    use aldram::timing::TimingParams;
    use aldram::workloads::arrival::ArrivalKind;
    use aldram::workloads::by_name;

    let wname = args.str("workload", "gups");
    let w = by_name(&wname)
        .ok_or_else(|| anyhow::anyhow!("unknown workload `{wname}`"))?;
    let kname = args.str("arrival", "poisson");
    let kind = ArrivalKind::by_name(&kname).ok_or_else(|| {
        anyhow::anyhow!("unknown arrival process `{kname}` \
                         (poisson|bursty|diurnal)")
    })?;
    let setup = load_eval::LoadSetup {
        workload: w,
        kind,
        cores: args.get("cores", 1usize),
        cycles: args.get("cycles", 200_000u64),
        seed: args.seed(),
        bound: args.get("bound", LOAD_BOUND),
    };
    let tol = args.get("tol", KNEE_TOL);
    let points_n = args.get("points", 5usize).max(2);

    // The K timing tables: JEDEC baseline, the profiled (reduced)
    // point — a registry module's own thermally-managed table under
    // --profiles, the paper's 55 °C reductions otherwise — and, under
    // --regions, the region-indexed table.
    let [r_trcd, r_tras, r_twr, r_trp] = aldram::eval::PAPER_REDUCTIONS_55C;
    let mut tables: Vec<(String, SystemConfig)> =
        vec![("jedec".into(), SystemConfig::paper_default())];
    if args.has("profiles") {
        let (label, table) = table_or_profile(args)?;
        tables.push((format!("profiled[{label}]"),
                     SystemConfig::paper_default()
                         .with_aldram(Some(table))));
    } else {
        let t = TimingParams::ddr3_standard()
            .reduced(r_trcd, r_tras, r_twr, r_trp);
        t.validate()?;
        tables.push(("profiled".into(),
                     SystemConfig::paper_default().with_timings(t)));
    }
    if let Some(regions) = regions_flag(args)? {
        let (label, table) = region_table_or_profile(args, regions)?;
        tables.push((format!("region[{label}]"),
                     SystemConfig::paper_default()
                         .with_region_table(Some(table))));
    }

    println!("== open-loop load sweep: {wname} under {kname} arrivals, \
              {} core(s), {} cycles/point (seed {}, bound {}, {} \
              tables) ==",
             setup.cores, setup.cycles, setup.seed, setup.bound,
             tables.len());

    // Adaptive knee per table (independent searches — pool fan-out).
    let knees: Vec<LoadCurve> =
        exec::Pool::new(jobs).run(tables.len(), |i| {
            let mut c = load_eval::knee_search(&tables[i].1, &setup, tol,
                                               Driver::TimeSkip);
            c.table = tables[i].0.clone();
            c
        });
    for c in &knees {
        println!("KNEE table={} load={:.4} ({} probes, tol {:.0}%)",
                 c.table, c.knee, c.points.len(), 100.0 * tol);
    }

    // Shared load grid spanning the knees: every grid point runs all K
    // tables lockstep over one shared arrival stream.
    let kmin = knees.iter().map(|c| c.knee)
        .fold(f64::INFINITY, f64::min).max(1e-4);
    let kmax = knees.iter().map(|c| c.knee).fold(0.0, f64::max).max(1e-4);
    let (glo, ghi) = (kmin * 0.25, kmax * 1.25);
    let grid: Vec<f64> = (0..points_n)
        .map(|i| glo * (ghi / glo)
             .powf(i as f64 / (points_n - 1) as f64))
        .collect();
    let cfgs: Vec<SystemConfig> =
        tables.iter().map(|(_, c)| c.clone()).collect();
    let rows: Vec<Vec<LoadPoint>> =
        exec::Pool::new(jobs).run(grid.len(), |i| {
            load_eval::run_point(&cfgs, &setup, grid[i], Driver::TimeSkip)
        });

    let mut csv = Csv::new(&["table", "arrival", "phase", "load", "cycles",
                             "offered", "reads", "writes", "throughput",
                             "p50", "p95", "p99", "p999", "saturated"]);
    let mut push_row = |table: &str, phase: &str, p: &LoadPoint| {
        csv.row(&[table.to_string(), kname.clone(), phase.to_string(),
                  format!("{:.6}", p.load), p.cycles.to_string(),
                  p.offered.to_string(), p.reads_done.to_string(),
                  p.writes_done.to_string(),
                  format!("{:.6}", p.throughput), format!("{:.2}", p.p50),
                  format!("{:.2}", p.p95), format!("{:.2}", p.p99),
                  format!("{:.2}", p.p999),
                  (p.saturated as u8).to_string()]);
    };
    for (ti, (name, _)) in tables.iter().enumerate() {
        println!("-- {name} --");
        println!("{:>9} {:>9} {:>8} {:>8} {:>8} {:>8} {:>9}  {}",
                 "load", "thru", "p50", "p95", "p99", "p99.9", "offered",
                 "state");
        for (gi, _) in grid.iter().enumerate() {
            let p = &rows[gi][ti];
            println!("{:>9.4} {:>9.4} {:>8.1} {:>8.1} {:>8.1} {:>8.1} \
                      {:>9}  {}",
                     p.load, p.throughput, p.p50, p.p95, p.p99, p.p999,
                     p.offered,
                     if p.saturated { "SATURATED" } else { "ok" });
            push_row(name, "grid", p);
        }
        for p in &knees[ti].points {
            push_row(name, "probe", p);
        }
    }
    csv.write(out, "load_curves.csv")?;

    // The acceptance comparison: profiled vs JEDEC knee, and p99 at the
    // highest grid load both tables sustain with completed reads.
    let (kj, kp) = (knees[0].knee, knees[1].knee);
    let common = grid.iter().enumerate().rev().find(|(gi, _)| {
        let (a, b) = (&rows[*gi][0], &rows[*gi][1]);
        !a.saturated && !b.saturated && a.reads_done > 0 && b.reads_done > 0
    });
    let (p99j, p99p) = common
        .map(|(gi, _)| (rows[gi][0].p99, rows[gi][1].p99))
        .unwrap_or((f64::NAN, f64::NAN));
    println!("LOADGATE jedec_knee={kj:.4} profiled_knee={kp:.4} \
              knee_ge={} p99_jedec={p99j:.1} p99_profiled={p99p:.1} \
              p99_lower={} profiled_beats_jedec={}",
             if kp > kj { "yes" } else { "no" },
             if p99p < p99j { "yes" } else { "no" },
             if kp > kj && p99p < p99j { "yes" } else { "no" });

    if !args.has("no-bench") {
        bench_load(args)?;
    }
    Ok(())
}

/// The `bench-load` suite: open-loop perf comparisons, results asserted
/// bit-identical before any timing (both are single-shot wall-clock
/// comparisons like TIMESKIP/FLEET — the slow side is far too slow to
/// window). SPEEDUP[LOAD]: the arrival-aware time-skip driver vs the
/// cycle-stepped oracle at low offered load, where nearly every cycle
/// is an idle inter-arrival gap the driver can skip. SPEEDUP[LOADSWEEP]:
/// one load point across K timing configs, shared-stream lockstep vs
/// independent systems (K stream generations).
fn bench_load(args: &Args) -> anyhow::Result<Vec<SpeedupRecord>> {
    use aldram::eval::load::{self as load_eval, LOAD_BOUND};
    use aldram::eval::Driver;
    use aldram::mem::{System, SystemConfig};
    use aldram::timing::TimingParams;
    use aldram::workloads::arrival::{ArrivalKind, ArrivalSpec};
    use aldram::workloads::by_name;
    use std::time::Instant;

    let cycles = args.get("cycles", 200_000u64);
    let seed = args.seed();
    let wname = args.str("workload", "gups");
    let w = by_name(&wname)
        .ok_or_else(|| anyhow::anyhow!("unknown workload `{wname}`"))?;
    let mut records: Vec<SpeedupRecord> = Vec::new();

    // SPEEDUP[LOAD]: run vs run_fast at a low offered load.
    let load = args.get("load", 0.02f64);
    let cfg = SystemConfig::paper_default();
    let spec = ArrivalSpec { kind: ArrivalKind::Poisson, load };
    let build = || {
        let mut sys = System::with_sources(
            &cfg, vec![spec.named_source(&w, &format!("{seed}/core0"))]);
        sys.set_open_loop(LOAD_BOUND);
        sys
    };
    let mut seq = build();
    let t0 = Instant::now();
    let s = seq.run(cycles);
    let step_ms = t0.elapsed().as_secs_f64() * 1e3;
    let mut fast = build();
    let t0 = Instant::now();
    let f = fast.run_fast(cycles);
    let fast_ms = t0.elapsed().as_secs_f64() * 1e3;
    anyhow::ensure!(stats_line(&s) == stats_line(&f)
                    && s.open_loop == f.open_loop,
                    "open-loop drivers diverged at load {load}");
    let ratio = step_ms / fast_ms.max(1e-9);
    println!("SPEEDUP[LOAD] {:<30} -> {:<30} {ratio:>6.2}x  \
              ({step_ms:.1} ms -> {fast_ms:.1} ms, identical stats + \
              histograms)",
             format!("run@load{load}"), format!("run_fast@load{load}"));
    records.push(SpeedupRecord {
        suite: "bench-load".into(),
        tag: "LOAD".into(),
        base: "open-loop/run".into(),
        test: "open-loop/run_fast".into(),
        speedup: ratio,
        base_median_ns: step_ms * 1e6,
        test_median_ns: fast_ms * 1e6,
    });

    // SPEEDUP[LOADSWEEP]: K configs at one load point — shared-stream
    // lockstep vs the independent-system oracle.
    let k = args.get("load-k", 4usize);
    anyhow::ensure!(k >= 2, "--load-k must be at least 2");
    let cfgs: Vec<SystemConfig> = (0..k)
        .map(|i| {
            let sc = i as f64 / (k - 1) as f64;
            let t = TimingParams::ddr3_standard()
                .reduced(0.27 * sc, 0.32 * sc, 0.33 * sc, 0.18 * sc);
            t.validate()
                .map(|_| SystemConfig::paper_default().with_timings(t))
        })
        .collect::<anyhow::Result<_>>()?;
    let setup = load_eval::LoadSetup {
        workload: w,
        kind: ArrivalKind::Poisson,
        cores: 1,
        cycles,
        seed: seed.clone(),
        bound: LOAD_BOUND,
    };
    let sweep_load = args.get("sweep-load", 0.05f64);
    let ind = load_eval::run_point_independent(&cfgs, &setup, sweep_load,
                                               Driver::TimeSkip);
    let lck = load_eval::run_point(&cfgs, &setup, sweep_load,
                                   Driver::TimeSkip);
    anyhow::ensure!(ind == lck,
                    "shared-stream load point diverged from the \
                     independent oracle");
    let t0 = Instant::now();
    let _ = load_eval::run_point_independent(&cfgs, &setup, sweep_load,
                                             Driver::TimeSkip);
    let ind_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t0 = Instant::now();
    let _ = load_eval::run_point(&cfgs, &setup, sweep_load,
                                 Driver::TimeSkip);
    let lck_ms = t0.elapsed().as_secs_f64() * 1e3;
    let ratio = ind_ms / lck_ms.max(1e-9);
    println!("SPEEDUP[LOADSWEEP] {:<26} -> {:<26} {ratio:>6.2}x  \
              ({ind_ms:.1} ms -> {lck_ms:.1} ms)",
             format!("point/independent/k{k}"),
             format!("point/lockstep/k{k}"));
    records.push(SpeedupRecord {
        suite: "bench-load".into(),
        tag: "LOADSWEEP".into(),
        base: format!("point/independent/k{k}"),
        test: format!("point/lockstep/k{k}"),
        speedup: ratio,
        base_median_ns: ind_ms * 1e6,
        test_median_ns: lck_ms * 1e6,
    });
    Ok(records)
}

/// `fleet run`: the full campaign — shard the nodes over the pool,
/// characterize each through the content-keyed profile store, simulate,
/// and stream-fold into one fixed-memory summary — then the report (CDF /
/// archetype / budget CSVs), the persisted summary for `fleet report`,
/// and the SPEEDUP[FLEET] characterization bench (memoized vs
/// profile-every-node), appended to `BENCH_FLEET.json`.
fn fleet_run(args: &Args, out: &std::path::Path) -> anyhow::Result<()> {
    use aldram::fleet::{characterize_fleet, run_campaign, FleetSpec};
    use aldram::util::json::Json;
    use std::collections::BTreeMap;
    use std::time::Instant;

    let jobs = args.jobs();
    let spec = FleetSpec {
        nodes: args.get("nodes", 1000usize),
        archetypes: args.get("archetypes", 12usize),
        cells: args.get("cells", 96usize),
        cycles: args.get("cycles", 12_000u64),
        seed: args.seed(),
        chunk: args.get("chunk", 32usize),
        memoize: !args.has("no-memoize"),
        workloads: args.get("workloads", 6usize),
    };
    println!("== fleet campaign: {} nodes x {} archetypes ({jobs} jobs, \
              chunk {}, seed {}, memoize {}) ==",
             spec.nodes, spec.archetypes, spec.chunk, spec.seed,
             spec.memoize);
    let t0 = Instant::now();
    let r = run_campaign(&spec, jobs);
    let wall_s = t0.elapsed().as_secs_f64();
    println!("campaign: {} nodes in {:.1} s ({:.1} nodes/s)",
             spec.nodes, wall_s, spec.nodes as f64 / wall_s.max(1e-9));
    println!("archetype cache: {} hits / {} misses (hit rate {:.1}%), \
              {} unique profiles",
             r.hits, r.misses, 100.0 * r.hit_rate(), r.unique_profiles);
    aldram::figures::fleet::report(&r.summary, out)?;

    // Persist the streamed summary (+ provenance) for `fleet report`.
    let mut m = BTreeMap::new();
    m.insert("nodes".to_string(), Json::Num(spec.nodes as f64));
    m.insert("archetypes".to_string(), Json::Num(spec.archetypes as f64));
    m.insert("cells".to_string(), Json::Num(spec.cells as f64));
    m.insert("cycles".to_string(), Json::Num(spec.cycles as f64));
    m.insert("seed".to_string(), Json::Str(spec.seed.clone()));
    m.insert("jobs".to_string(), Json::Num(jobs as f64));
    m.insert("chunk".to_string(), Json::Num(spec.chunk as f64));
    m.insert("memoize".to_string(), Json::Bool(spec.memoize));
    m.insert("cache_hits".to_string(), Json::Num(r.hits as f64));
    m.insert("cache_misses".to_string(), Json::Num(r.misses as f64));
    m.insert("summary".to_string(), r.summary.to_json());
    std::fs::create_dir_all(out)?;
    let path = out.join("fleet_summary.json");
    std::fs::write(&path, Json::Obj(m).to_string_pretty())?;
    println!("wrote {}", path.display());

    if args.has("no-bench") {
        return Ok(());
    }

    // SPEEDUP[FLEET]: characterization-only sweep over a small fleet,
    // profile-every-node vs memoized. Like TIMESKIP this is a single-shot
    // wall-clock comparison (the baseline is far too slow to window), and
    // like every SPEEDUP[*] the result equivalence is asserted before any
    // timing: both paths must install bit-identical tables on every node.
    let bench_nodes = args.get("bench-nodes", 24usize);
    let bench = FleetSpec {
        nodes: bench_nodes,
        archetypes: args.get("bench-archetypes", (bench_nodes / 6).max(2)),
        cells: args.get("bench-cells", 64usize),
        chunk: args.get("bench-chunk", 4usize),
        memoize: false,
        ..spec.clone()
    };
    let t0 = Instant::now();
    let (_, _, fp_fresh) = characterize_fleet(&bench, jobs);
    let fresh_ms = t0.elapsed().as_secs_f64() * 1e3;
    let memo = FleetSpec { memoize: true, ..bench.clone() };
    let t0 = Instant::now();
    let (hits, misses, fp_memo) = characterize_fleet(&memo, jobs);
    let memo_ms = t0.elapsed().as_secs_f64() * 1e3;
    anyhow::ensure!(fp_fresh == fp_memo,
                    "memoized characterization diverged from the \
                     profile-every-node baseline");
    let ratio = fresh_ms / memo_ms.max(1e-9);
    println!("SPEEDUP[FLEET] {:<30} -> {:<30} {ratio:>6.2}x  \
              ({fresh_ms:.1} ms -> {memo_ms:.1} ms)",
             "characterize/fresh", "characterize/memoized");
    println!("  bench fleet: {} nodes x {} archetypes, {hits} hits / \
              {misses} misses memoized",
             bench.nodes, bench.archetypes);
    let rec = SpeedupRecord {
        suite: "fleet".into(),
        tag: "FLEET".into(),
        base: "characterize/fresh".into(),
        test: "characterize/memoized".into(),
        speedup: ratio,
        base_median_ns: fresh_ms * 1e6,
        test_median_ns: memo_ms * 1e6,
    };
    let dir = PathBuf::from(args.str("json-dir", "."));
    std::fs::create_dir_all(&dir)?;
    write_bench_json(&dir.join("BENCH_FLEET.json"), &[rec])?;
    Ok(())
}

/// `fleet report`: reload a persisted campaign summary and regenerate the
/// report + CSVs — no re-simulation (the summary is all that exists; see
/// fleet::summary).
fn fleet_report(args: &Args, out: &std::path::Path) -> anyhow::Result<()> {
    use aldram::fleet::FleetSummary;
    use aldram::util::json::Json;
    let default = out.join("fleet_summary.json");
    let path = PathBuf::from(args.str("summary",
                                      &default.to_string_lossy()));
    let j = Json::parse(&std::fs::read_to_string(&path)?)?;
    let s = FleetSummary::from_json(
        j.get("summary")
            .ok_or_else(|| anyhow::anyhow!("{} has no `summary` object",
                                           path.display()))?)?;
    println!("loaded {} ({} nodes, seed {})", path.display(), s.nodes,
             j.get("seed").and_then(Json::as_str).unwrap_or("?"));
    aldram::figures::fleet::report(&s, out)
}

/// Append `bench all`'s speedup records as a dated trajectory entry to
/// the committed `BENCH_SIM.json` / `BENCH_PROFILE.json` baselines
/// (`util::trajectory`); a missing or legacy flat-array file upgrades in
/// place. The file is the SPEEDUP[*] history of the repo, newest last.
fn write_bench_json(path: &std::path::Path, records: &[SpeedupRecord])
                    -> anyhow::Result<()> {
    use aldram::util::trajectory;
    let existing = std::fs::read_to_string(path).ok();
    let body = trajectory::append(existing.as_deref(),
                                  &trajectory::today_utc(), records)?;
    std::fs::write(path, body)?;
    println!("appended {} speedup records to {}", records.len(),
             path.display());
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    // `--check` attaches the independent protocol checker to every System
    // any command builds (zero code change per command — see check::
    // enable_inline). The `check` command itself manages checkers
    // explicitly (its mutation harness *expects* violations), so the
    // global audit stays off there.
    if args.has("check") && args.cmd() != Some("check") {
        aldram::check::enable_inline();
    }
    run(args)?;
    // No-op unless --check was enabled; errors if any audited system saw
    // a protocol violation. Sits outside run() so every early `return
    // Ok(())` path is still covered.
    aldram::check::report_inline()
}

fn run(args: Args) -> anyhow::Result<()> {
    let out = PathBuf::from(args.str("out", "results"));
    let g = &params().geometry;
    let jobs = args.jobs();

    match args.cmd() {
        Some("calibrate") => {
            let dimms = args.get("dimms", 30usize);
            let cells = args.get("cells", g.cells_per_chip_bank);
            let kind = args.str("backend", "auto");
            let r = calibrate::run_par(|| make_backend(&kind, cells), dimms,
                                       cells, jobs)?;
            calibrate::print_report(&r);
        }

        Some("profile") => {
            let cells = args.get("cells", g.cells_per_chip_bank);
            if args.has("dimms") || (args.has("save") && !args.has("dimm")) {
                // Population mode: profile --dimms modules (default 8) in
                // parallel and persist the registry (--save DIR, replacing
                // any previous population there) so every figure/eval
                // harness can reload it via --profiles. `--dimm N --save`
                // instead saves that single module (below).
                let dimms = args.get("dimms", 8usize);
                let kind = args.str("backend", "auto");
                if let Some(rpb) = regions_flag(&args)? {
                    // Region granularity: every module's weakest cells are
                    // swept per (bank, row-region); the registry is written
                    // in format v2 (scalar loaders still read it at module
                    // granularity).
                    let results: Vec<anyhow::Result<RegionDimmProfile>> =
                        exec::Pool::new(jobs).run(dimms, |i| {
                            let mut b = make_backend(&kind, cells);
                            let d = generate_dimm(i, cells, params());
                            profile_dimm_regions(b.as_mut(), &d, rpb)
                        });
                    let profiles = results.into_iter()
                        .collect::<anyhow::Result<Vec<_>>>()?;
                    for p in &profiles {
                        let sums: Vec<f64> = p.regions.iter()
                            .map(|r| r.at55.combined().read_sum_ns())
                            .collect();
                        let (lo, hi) = sums.iter().fold(
                            (f64::INFINITY, f64::NEG_INFINITY),
                            |(lo, hi), &s| (lo.min(s), hi.max(s)));
                        println!("dimm {:03} ({:<10}) {} banks x {} regions \
                                  @55C read-sum {:.2}..{:.2} ns",
                                 p.base.id, p.base.vendor,
                                 p.regions.len() / p.regions_per_bank,
                                 p.regions_per_bank, lo, hi);
                    }
                    if args.has("save") {
                        let dir = PathBuf::from(args.str("save", "registry"));
                        registry::save_region_registry(&dir, &profiles)?;
                        println!("saved {} region profiles (v2) to {}",
                                 profiles.len(), dir.display());
                    }
                    return Ok(());
                }
                let r = calibrate::run_par(|| make_backend(&kind, cells),
                                           dimms, cells, jobs)?;
                for p in &r.profiles {
                    let red = p.at55.param_reductions();
                    println!("dimm {:03} ({:<10}) @55C reductions \
                              {:>4.1}/{:>4.1}/{:>4.1}/{:>4.1}%",
                             p.id, p.vendor, 100.0 * red[0], 100.0 * red[1],
                             100.0 * red[2], 100.0 * red[3]);
                }
                if args.has("save") {
                    let dir = PathBuf::from(args.str("save", "registry"));
                    registry::save_registry(&dir, &r.profiles)?;
                    println!("saved {} profiles to {}", r.profiles.len(),
                             dir.display());
                }
            } else {
                let id = args.get("dimm", 0usize);
                let mut b = backend_for(&args, cells);
                let d = generate_dimm(id, cells, params());
                let p = profile_dimm(b.as_mut(), &d)?;
                if args.has("save") {
                    // Single-module save: add/refresh this one profile in
                    // the registry without disturbing the rest.
                    let dir = PathBuf::from(args.str("save", "registry"));
                    let path = registry::save_profile(&dir, &p)?;
                    println!("saved dimm {:03} to {}", p.id, path.display());
                }
                println!("dimm {:03} ({})", p.id, p.vendor);
                println!("  max refresh @85C: read {:.0} ms, write {:.0} ms",
                         p.refresh85.module_max_read_ms,
                         p.refresh85.module_max_write_ms);
                for tp in [&p.at85, &p.at55] {
                    let c = tp.combined();
                    let r = tp.param_reductions();
                    println!(
                        "  @{:.0}C: tRCD {:.2} tRAS {:.2} tWR {:.2} tRP {:.2} ns \
                         (reductions {:.1}/{:.1}/{:.1}/{:.1}%)",
                        tp.temp_c, c.trcd_ns, c.tras_ns, c.twr_ns, c.trp_ns,
                        100.0 * r[0], 100.0 * r[1], 100.0 * r[2], 100.0 * r[3]
                    );
                }
            }
        }

        Some("figure") => {
            let which = args.sub(1).unwrap_or("all");
            let cells = args.get("cells", g.cells_per_chip_bank);
            let rep = args.get("dimm", fig2::REPRESENTATIVE_DIMM);
            if which == "fig2a" || which == "fig2bc" || which == "all" {
                let mut b = backend_for(&args, cells);
                let d = generate_dimm(rep, cells, params());
                let refresh = fig2::fig2a(b.as_mut(), &d.arrays, &out)?;
                if which != "fig2a" {
                    fig2::fig2bc(b.as_mut(), &d.arrays, &refresh, &out)?;
                }
            }
            if which == "fig3" || which == "all" {
                let dimms =
                    args.get("dimms", params().population.n_dimms);
                let kind = args.str("backend", "auto");
                fig3::fig3_par(|| make_backend(&kind, cells), dimms, cells,
                               jobs, &out)?;
            }
            if which == "fig4" || which == "all" {
                let cycles = args.get("cycles", 300_000u64);
                let reps = args.get("reps", 3usize);
                if let Some(regions) = regions_flag(&args)? {
                    let (label, table) =
                        region_table_or_profile(&args, regions)?;
                    fig4::fig4_regions(cycles, reps, jobs, &table, &label,
                                       &out)?;
                } else if args.has("profiles") {
                    let profiles = load_profiles(&args)?;
                    let (id, table) = table_for(&args, &profiles)?;
                    fig4::fig4_profiled(cycles, reps, jobs, &table,
                                        &format!("dimm {id:03}"), &out)?;
                } else {
                    fig4::fig4(cycles, reps, jobs, &out)?;
                }
            }
            if which == "fig6" || which == "all" {
                run_fig6(&args, jobs, &out)?;
            }
            if !["fig2a", "fig2bc", "fig3", "fig4", "fig6", "all"]
                .contains(&which)
            {
                anyhow::bail!("unknown figure `{which}`");
            }
        }

        Some("ablate") => {
            let which = args.sub(1).unwrap_or("all");
            let cells = args.get("cells", g.cells_per_chip_bank_small);
            let dimm = args.get("dimm", 0usize);
            let kind = args.str("backend", "auto");
            let factory = || make_backend(&kind, cells);
            match which {
                "refresh-latency" => {
                    ablate::refresh_latency_par(factory, dimm, cells, jobs,
                                                &out)?
                }
                "interdependence" => {
                    ablate::interdependence_par(factory, dimm, cells, jobs,
                                                &out)?
                }
                "repeatability" => ablate::repeat(dimm, cells, &out)?,
                "bank-granularity" => {
                    ablate::bank_granularity_par(factory, dimm, cells, jobs,
                                                 &out)?
                }
                "ecc" => ablate::ecc_par(factory, dimm, cells, jobs, &out)?,
                "sweep" => {
                    let mut b = backend_for(&args, cells);
                    ablate::sweep_check(b.as_mut(), dimm, cells)?
                }
                "ode" => ablate::ode_check(&artifacts_dir())?,
                "all" => {
                    ablate::refresh_latency_par(factory, dimm, cells, jobs,
                                                &out)?;
                    ablate::interdependence_par(factory, dimm, cells, jobs,
                                                &out)?;
                    ablate::repeat(dimm, cells, &out)?;
                    ablate::bank_granularity_par(factory, dimm, cells, jobs,
                                                 &out)?;
                    ablate::ecc_par(factory, dimm, cells, jobs, &out)?;
                    {
                        let mut b = backend_for(&args, cells);
                        ablate::sweep_check(b.as_mut(), dimm, cells)?;
                    }
                    ablate::ode_check(&artifacts_dir())?;
                }
                other => anyhow::bail!("unknown ablation `{other}`"),
            }
        }

        Some("eval") => {
            let which = args.sub(1).unwrap_or("sensitivity");
            let cycles = args.get("cycles", 200_000u64);
            match which {
                "sensitivity" => {
                    let rows = if args.has("profiles") {
                        let profiles = load_profiles(&args)?;
                        println!("== §8.4: sensitivity (profiled modules, \
                                  {jobs} jobs) ==");
                        aldram::eval::sensitivity_profiled(cycles, &profiles,
                                                           jobs)
                    } else {
                        println!("== §8.4: sensitivity (memory-intensive \
                                  gmean, {jobs} jobs) ==");
                        aldram::eval::sensitivity_jobs(
                            cycles, aldram::eval::PAPER_REDUCTIONS_55C, jobs)
                    };
                    for row in rows {
                        println!("{:<18} {:>6.1}%", row.label,
                                 100.0 * (row.gmean_speedup - 1.0));
                    }
                }
                "hetero" => {
                    // True module heterogeneity: channels host distinct
                    // profiled DIMMs. Use the --profiles registry when
                    // given, else profile a small population now.
                    let channels = args.get("channels", 2usize);
                    anyhow::ensure!(
                        channels >= 2 && channels.is_power_of_two(),
                        "--channels must be a power of two >= 2, got \
                         {channels}"
                    );
                    if let Some(rpb) = regions_flag(&args)? {
                        // Region granularity: the same profiled population
                        // runs under its module-uniform collapse and under
                        // the region-indexed tables, so the reported delta
                        // isolates what region indexing buys.
                        let profiles = if args.has("profiles") {
                            load_region_profiles(&args)?
                        } else {
                            let cells = args.get(
                                "cells", g.cells_per_chip_bank_small);
                            let dimms =
                                args.get("dimms", (2 * channels).max(8));
                            eprintln!("no --profiles registry; \
                                       region-profiling {dimms} modules at \
                                       {cells} cells x {rpb} regions");
                            let kind = args.str("backend", "auto");
                            let results: Vec<anyhow::Result<
                                RegionDimmProfile>> =
                                exec::Pool::new(jobs).run(dimms, |i| {
                                    let mut b = make_backend(&kind, cells);
                                    let d = generate_dimm(i, cells, params());
                                    profile_dimm_regions(b.as_mut(), &d, rpb)
                                });
                            results.into_iter()
                                .collect::<anyhow::Result<Vec<_>>>()?
                        };
                        anyhow::ensure!(
                            profiles.iter()
                                .all(|p| p.regions_per_bank == rpb),
                            "--regions {rpb} but the registry holds a \
                             different granularity — re-profile or match \
                             the stored regions-per-bank"
                        );
                        anyhow::ensure!(
                            profiles.len() >= channels,
                            "registry has {} profiles but --channels \
                             {channels} needs one distinct module per \
                             channel",
                            profiles.len()
                        );
                        let placement = args.has("placement");
                        let mixes = aldram::eval::hetero_eval_regions(
                            cycles, args.get("mixes", 8usize), channels,
                            &profiles, placement);
                        println!("== §8.4: heterogeneous modules at region \
                                  granularity — {channels} channels, {rpb} \
                                  regions per bank ==");
                        let (mut wu, mut wr, mut wp) =
                            (Vec::new(), Vec::new(), Vec::new());
                        for m in &mixes {
                            let dimms: Vec<String> = m.dimm_ids.iter()
                                .map(|d| format!("{d:03}"))
                                .collect();
                            let place = m.ws_placement
                                .map(|p| format!("  +placement {:+5.1}%",
                                                 100.0 * (p - 1.0)))
                                .unwrap_or_default();
                            println!(
                                "{:<44} dimms[{}] uniform {:+5.1}%  region \
                                 {:+5.1}%  delta {:+.2}pp{place}",
                                m.mix.join("+"), dimms.join(","),
                                100.0 * (m.ws_uniform - 1.0),
                                100.0 * (m.ws_region - 1.0),
                                100.0 * m.delta
                            );
                            wu.push(m.ws_uniform);
                            wr.push(m.ws_region);
                            if let Some(p) = m.ws_placement {
                                wp.push(p);
                            }
                        }
                        let gu = aldram::util::geomean(&wu);
                        let gr = aldram::util::geomean(&wr);
                        println!("gmean weighted speedup: module-uniform \
                                  {:.1}%, region-indexed {:.1}%",
                                 100.0 * (gu - 1.0), 100.0 * (gr - 1.0));
                        println!("region-indexed vs module-uniform gmean \
                                  weighted-speedup delta: {:+.2}%",
                                 100.0 * (gr / gu - 1.0));
                        if !wp.is_empty() {
                            let gp = aldram::util::geomean(&wp);
                            println!("with variation-aware placement: \
                                      {:.1}% (delta vs uniform {:+.2}%)",
                                     100.0 * (gp - 1.0),
                                     100.0 * (gp / gu - 1.0));
                        }
                        return Ok(());
                    }
                    let profiles = if args.has("profiles") {
                        load_profiles(&args)?
                    } else {
                        let cells =
                            args.get("cells", g.cells_per_chip_bank_small);
                        let dimms =
                            args.get("dimms", (2 * channels).max(8));
                        eprintln!("no --profiles registry; profiling \
                                   {dimms} modules at {cells} cells \
                                   (save one with `repro profile --save`)");
                        let kind = args.str("backend", "auto");
                        calibrate::run_par(|| make_backend(&kind, cells),
                                           dimms, cells, jobs)?
                            .profiles
                    };
                    anyhow::ensure!(
                        profiles.len() >= channels,
                        "registry has {} profiles but --channels {channels} \
                         needs one distinct module per channel",
                        profiles.len()
                    );
                    let mixes = aldram::eval::hetero_eval(
                        cycles, args.get("mixes", 8usize), channels,
                        &profiles);
                    println!("== §8.4: heterogeneous modules — {channels} \
                              channels with distinct DIMMs ==");
                    let mut ws = Vec::new();
                    for m in &mixes {
                        let dimms: Vec<String> = m.dimm_ids.iter()
                            .map(|d| format!("{d:03}"))
                            .collect();
                        let lat: Vec<String> = m.channel_latency_reduction
                            .iter()
                            .map(|r| format!("{:+.1}%", 100.0 * r))
                            .collect();
                        println!(
                            "{:<44} dimms[{}] ws {:+5.1}%  ch-lat[{}] \
                             spread {:.1}pp",
                            m.mix.join("+"), dimms.join(","),
                            100.0 * (m.weighted_speedup - 1.0),
                            lat.join(","), 100.0 * m.channel_spread
                        );
                        ws.push(m.weighted_speedup);
                    }
                    println!("gmean weighted speedup: {:.1}%",
                             100.0 * (aldram::util::geomean(&ws) - 1.0));
                }
                "power" => {
                    let rows = if args.has("profiles") {
                        let profiles = load_profiles(&args)?;
                        let (id, table) = table_for(&args, &profiles)?;
                        println!("== §8.4: DRAM power (profiled dimm \
                                  {id:03}) ==");
                        aldram::eval::power_eval_profiled(cycles, &table)
                    } else {
                        println!("== §8.4: DRAM power ==");
                        aldram::eval::power_eval(
                            cycles, aldram::eval::PAPER_REDUCTIONS_55C)
                    };
                    println!("{:<14} {:>9} {:>9} {:>12} {:>12}", "workload",
                             "base W", "aldram W", "base J/Gi", "aldram J/Gi");
                    for r in &rows {
                        println!("{:<14} {:>9.3} {:>9.3} {:>12.4} {:>12.4}",
                                 r.name, r.base_w, r.aldram_w,
                                 r.base_j_per_ginst, r.aldram_j_per_ginst);
                    }
                    println!("average energy-per-work reduction: {:.1}% (paper 5.8%)",
                             100.0 * aldram::eval::power_saving(&rows));
                }
                "fig6" => {
                    // Per-workload/per-mix improvement table (paper Fig
                    // 6/7): all 35 workloads + the named mixes x {55 degC,
                    // 85 degC}, driven by a profiled module's own table.
                    run_fig6(&args, jobs, &out)?;
                }
                "stress" => {
                    let epochs = args.get("epochs", 64u64);
                    let r = aldram::eval::stress(
                        args.get("dimm", 0usize), epochs,
                        args.get("cycles", 50_000u64))?;
                    println!("== §6: stress run (scaled 33-day analogue) ==");
                    println!(
                        "epochs {}  errors {}  min margin {:.4}  temp {:.1}..{:.1}C",
                        r.epochs, r.errors, r.min_margin,
                        r.temp_range.0, r.temp_range.1
                    );
                    anyhow::ensure!(r.errors == 0, "stress run saw errors");
                }
                "load" => {
                    eval_load(&args, jobs, &out)?;
                }
                other => anyhow::bail!("unknown eval `{other}`"),
            }
        }

        Some("trace") => {
            use aldram::eval::Driver;
            use aldram::mem::{System, SystemConfig};
            use aldram::workloads::{by_name, mix, trace};

            let which = args.sub(1).unwrap_or("info");
            let driver = match args.str("driver", "fast").as_str() {
                "fast" => Driver::TimeSkip,
                "step" => Driver::CycleStepped,
                other => anyhow::bail!("unknown --driver `{other}` \
                                        (fast|step)"),
            };
            let trace_path = || -> anyhow::Result<PathBuf> {
                anyhow::ensure!(args.has("trace"),
                                "trace {which} needs --trace FILE");
                Ok(PathBuf::from(args.str("trace", "")))
            };
            match which {
                "record" => {
                    // Capture any run — a suite workload (--workload,
                    // optionally --cores N), a named mix (--mix), or even
                    // an existing trace (--trace) — into an ALDT file.
                    let out_path = PathBuf::from(args.str("out", "run.altr"));
                    let cycles = args.get("cycles", 200_000u64);
                    let seed = args.seed();
                    let sources = if args.has("mix") {
                        let name = args.str("mix", "");
                        let m = mix::mix_by_name(&name).ok_or_else(|| {
                            anyhow::anyhow!("unknown mix `{name}` (see \
                                             workloads::mix::suite)")
                        })?;
                        m.sources(&format!("trace/{seed}"))
                    } else if args.has("trace") {
                        let input = trace_path()?;
                        ensure_distinct_paths(&input, &out_path)?;
                        trace::open_any(&input)?.1
                    } else {
                        let name = args.str("workload", "stream.copy");
                        let w = by_name(&name).ok_or_else(|| {
                            anyhow::anyhow!("unknown workload `{name}`")
                        })?;
                        let cores = args.get("cores", 1usize);
                        (0..cores)
                            .map(|c| w.named_source(
                                &format!("trace/{seed}/core{c}")))
                            .collect()
                    };
                    let cfg = SystemConfig::paper_default();
                    let mut sys = System::with_sources(&cfg, sources);
                    let writer = sys.record_to(&out_path)?;
                    let stats = match driver {
                        Driver::TimeSkip => sys.run_fast(cycles),
                        Driver::CycleStepped => sys.run(cycles),
                    };
                    trace::finish_shared(&writer)?;
                    println!("recorded {} refs over {} cycles to {}",
                             writer.borrow().count(), stats.cycles,
                             out_path.display());
                    println!("{}", stats_line(&stats));
                }
                "replay" => {
                    let path = trace_path()?;
                    let cycles = args.get("cycles", 200_000u64);
                    let (info, sources) = trace::open_any(&path)?;
                    println!("replaying {} refs / {} streams from {}",
                             info.total_refs, info.streams.len(),
                             path.display());
                    let cfg = SystemConfig::paper_default();
                    let mut sys = System::with_sources(&cfg, sources);
                    let stats = match driver {
                        Driver::TimeSkip => sys.run_fast(cycles),
                        Driver::CycleStepped => sys.run(cycles),
                    };
                    println!("{}", stats_line(&stats));
                }
                "info" => {
                    let path = trace_path()?;
                    let (info, _) = trace::open_any(&path)?;
                    println!("trace {} (v{}, row_bytes {})", path.display(),
                             info.version, info.row_bytes);
                    for (m, n) in
                        info.streams.iter().zip(&info.per_stream_refs)
                    {
                        println!("  {:<16} seed {:<20} footprint {:>12} B  \
                                  refs {}",
                                 m.name, m.seed, m.footprint, n);
                    }
                    println!("total refs: {} (validated)", info.total_refs);
                }
                "convert" => {
                    // ALDT binary <-> DRAMSim3 text (direction sniffed
                    // from the input's magic bytes).
                    let path = trace_path()?;
                    anyhow::ensure!(args.has("out"),
                                    "trace convert needs --out FILE");
                    let out_path = PathBuf::from(args.str("out", ""));
                    ensure_distinct_paths(&path, &out_path)?;
                    let (info, mut sources) = trace::open_any(&path)?;
                    if info.binary {
                        anyhow::ensure!(
                            info.streams.len() == 1,
                            "DRAMSim3 text traces are single-stream; {} \
                             carries {} streams",
                            path.display(), info.streams.len()
                        );
                        let f = std::fs::File::create(&out_path)?;
                        let mut tw = trace::TextWriter::new(
                            std::io::BufWriter::new(f));
                        let mut src = sources.remove(0).source;
                        let mut buf = Vec::new();
                        loop {
                            buf.clear();
                            if src.fill(&mut buf) == 0 {
                                break;
                            }
                            for r in &buf {
                                tw.push(*r)?;
                            }
                        }
                        tw.flush()?;
                        println!("wrote {} text records to {}", tw.count(),
                                 out_path.display());
                    } else {
                        let src = sources.remove(0);
                        let metas = [trace::StreamMeta {
                            name: src.name.clone(),
                            seed: src.seed.clone(),
                            footprint: src.footprint,
                        }];
                        let w = trace::create_shared(&out_path, 0, &metas)?;
                        let mut rec =
                            trace::Recorder::new(src.source, 0, w.clone());
                        let mut buf = Vec::new();
                        loop {
                            buf.clear();
                            if aldram::workloads::RequestSource::fill(
                                &mut rec, &mut buf) == 0
                            {
                                break;
                            }
                        }
                        trace::finish_shared(&w)?;
                        println!("wrote {} binary records to {}",
                                 w.borrow().count(), out_path.display());
                    }
                }
                other => anyhow::bail!(
                    "unknown trace subcommand `{other}` \
                     (record|replay|info|convert)"),
            }
        }

        Some("check") => {
            use aldram::check::{cmd_trace, mutate};
            use aldram::eval::Driver;
            use aldram::mem::address::AddrMap;
            use aldram::mem::{ChannelConfig, System, SystemConfig};
            use aldram::timing::TimingParams;
            use aldram::workloads::{by_name, fuzz::FuzzSource, mix,
                                    NamedSource};

            // `repro check --mutate` is the ISSUE-spelled alias for
            // `repro check mutate`.
            let which = args.sub(1)
                .unwrap_or(if args.has("mutate") { "mutate" } else { "run" });
            let cycles = args.get("cycles", mutate::DEFAULT_CYCLES);
            let seed = args.seed();
            let map = AddrMap::ddr3_2gb(1);
            // Sources: adversarial fuzz by default (2 cores), or any suite
            // workload / named mix — same flags as `trace record`.
            let build_sources = |label: &str|
                                -> anyhow::Result<Vec<NamedSource>> {
                if args.has("mix") {
                    let name = args.str("mix", "");
                    let m = mix::mix_by_name(&name).ok_or_else(|| {
                        anyhow::anyhow!("unknown mix `{name}` (see \
                                         workloads::mix::suite)")
                    })?;
                    Ok(m.sources(&format!("check/{seed}/{label}")))
                } else if args.has("workload") {
                    let name = args.str("workload", "");
                    let w = by_name(&name).ok_or_else(|| {
                        anyhow::anyhow!("unknown workload `{name}`")
                    })?;
                    let cores = args.get("cores", 1usize);
                    Ok((0..cores)
                        .map(|c| w.named_source(
                            &format!("check/{seed}/{label}/core{c}")))
                        .collect())
                } else {
                    let cores = args.get("cores", 2usize);
                    Ok((0..cores)
                        .map(|c| FuzzSource::named(
                            map, &format!("{seed}/{label}/{c}")))
                        .collect())
                }
            };
            // Config: standard timings by default; --aldram for the
            // paper's 55degC uniformly-reduced module; --grid for the
            // adversarial 2-regions-per-bank table the mutation harness
            // uses (fast low rows, standard high rows).
            let config = || -> SystemConfig {
                if args.has("grid") {
                    SystemConfig::uniform(
                        1,
                        ChannelConfig::profiled_regions(
                            mutate::harness_table(), 55.0))
                } else if args.has("aldram") {
                    let fast = TimingParams::ddr3_standard()
                        .reduced(0.27, 0.32, 0.33, 0.18);
                    SystemConfig::uniform(
                        1, ChannelConfig::profiled(AlDram::fixed(fast), 55.0))
                } else {
                    SystemConfig::paper_default()
                }
            };
            let trace_path = || -> anyhow::Result<PathBuf> {
                anyhow::ensure!(args.has("trace"),
                                "check {which} needs --trace FILE");
                Ok(PathBuf::from(args.str("trace", "")))
            };
            match which {
                "run" => {
                    // Audit one simulation inline. `--driver both` runs
                    // the time-skip driver *and* the cycle-stepped oracle
                    // on the same sources and requires them to produce the
                    // same audited command count — the conformance leg of
                    // the run/run_fast equivalence matrix.
                    let drivers: Vec<(&str, Driver)> =
                        match args.str("driver", "fast").as_str() {
                            "fast" => vec![("fast", Driver::TimeSkip)],
                            "step" => vec![("step", Driver::CycleStepped)],
                            "both" => vec![("step", Driver::CycleStepped),
                                           ("fast", Driver::TimeSkip)],
                            other => anyhow::bail!(
                                "unknown --driver `{other}` (fast|step|both)"),
                        };
                    let mut sums = Vec::new();
                    for (dl, d) in &drivers {
                        let mut sys = System::with_sources_map(
                            &config(), map, build_sources("run")?);
                        sys.enable_check();
                        let stats = match d {
                            Driver::TimeSkip => sys.run_fast(cycles),
                            Driver::CycleStepped => sys.run(cycles),
                        };
                        let reports = sys.check_reports();
                        let sum = sys.check_summary()
                            .expect("checker was attached");
                        println!("driver {dl}: {}", stats_line(&stats));
                        for r in reports {
                            print!("{r}");
                        }
                        println!("{}", sum.line());
                        sums.push((*dl, sum));
                    }
                    if sums.len() == 2 {
                        anyhow::ensure!(
                            sums[0].1.commands == sums[1].1.commands,
                            "drivers audited different command counts: \
                             step {} vs fast {}",
                            sums[0].1.commands, sums[1].1.commands);
                        println!("drivers agree: {} audited commands each",
                                 sums[0].1.commands);
                    }
                    for (dl, s) in &sums {
                        anyhow::ensure!(
                            s.violations == 0,
                            "driver {dl}: {} protocol violation(s)",
                            s.violations);
                    }
                }
                "capture" => {
                    // Record the *command* stream (not the request stream
                    // `trace record` captures) to a versioned ALCT file
                    // for offline audit. Single-channel configs only —
                    // the ALCT header carries one geometry.
                    let out_path =
                        PathBuf::from(args.str("out", "run.alct"));
                    let cfg = config();
                    let mut sys = System::with_sources_map(
                        &cfg, map, build_sources("capture")?);
                    let tck = sys.controllers()[0].tck_ns();
                    let w = cmd_trace::create_shared(
                        map.ranks(), map.banks(), map.row_bits, tck);
                    sys.attach_cmd_tap(0, w.clone());
                    let driver = match args.str("driver", "fast").as_str() {
                        "fast" => Driver::TimeSkip,
                        "step" => Driver::CycleStepped,
                        other => anyhow::bail!(
                            "unknown --driver `{other}` (fast|step)"),
                    };
                    let stats = match driver {
                        Driver::TimeSkip => sys.run_fast(cycles),
                        Driver::CycleStepped => sys.run(cycles),
                    };
                    drop(sys); // release the controller's tap handle
                    let n = cmd_trace::finish_shared(w, &out_path)?;
                    println!("captured {n} command-trace records over {} \
                              cycles to {}",
                             stats.cycles, out_path.display());
                    println!("{}", stats_line(&stats));
                }
                "replay" => {
                    let path = trace_path()?;
                    let (info, ck, report) = cmd_trace::replay(&path)?;
                    println!("cmd trace {} (v{}): {}r x {}b, {} row bits, \
                              tck {} ns",
                             path.display(), info.version, info.ranks,
                             info.banks, info.row_bits, info.tck);
                    println!("  {} records: {} commands, {} timing / {} \
                              region / {} scale updates, last cycle {}",
                             info.records, info.commands,
                             info.timing_updates, info.region_updates,
                             info.scale_updates, info.last_cycle);
                    print!("{report}");
                    anyhow::ensure!(
                        ck.violations() == 0,
                        "{} protocol violation(s) in {}",
                        ck.violations(), path.display());
                }
                "info" => {
                    let path = trace_path()?;
                    let info = cmd_trace::info(&path)?;
                    println!("cmd trace {} (v{}): {}r x {}b, {} row bits, \
                              tck {} ns",
                             path.display(), info.version, info.ranks,
                             info.banks, info.row_bits, info.tck);
                    println!("  {} records: {} commands, {} timing / {} \
                              region / {} scale updates, last cycle {} \
                              (validated)",
                             info.records, info.commands,
                             info.timing_updates, info.region_updates,
                             info.scale_updates, info.last_cycle);
                }
                "mutate" => {
                    // The sensitivity harness: a clean baseline plus one
                    // run per seeded controller-gate mutant; fails unless
                    // the checker catches every one of them.
                    let r = mutate::run_harness(cycles, &seed, jobs);
                    println!("== mutation harness: {} mutants x {} cycles \
                              (seed {seed}) ==",
                             r.results.len(), r.cycles);
                    println!("baseline  {}", r.baseline.line());
                    println!("baseline* {}  (*widened-tFAW stress set, \
                              used for the Tfaw mutant)",
                             r.stress_baseline.line());
                    for m in &r.results {
                        let status = if m.detected() { "DETECTED" }
                                     else { "ESCAPED " };
                        match &m.first {
                            Some(v) => println!(
                                "{status}  {:<16} {:>6} violations  \
                                 first: {v}",
                                format!("{:?}", m.mutation), m.violations),
                            None => println!(
                                "{status}  {:<16} {:>6} violations",
                                format!("{:?}", m.mutation), m.violations),
                        }
                    }
                    println!("detected {}/{} mutants", r.detected(),
                             r.results.len());
                    r.require_all_detected()?;
                }
                other => anyhow::bail!(
                    "unknown check subcommand `{other}` \
                     (run|capture|replay|info|mutate)"),
            }
        }

        Some("fleet") => {
            match args.sub(1).unwrap_or("run") {
                "run" => fleet_run(&args, &out)?,
                "report" => fleet_report(&args, &out)?,
                other => anyhow::bail!(
                    "unknown fleet subcommand `{other}` (run|report)"),
            }
        }

        Some("bench-sim") => {
            bench_sim(&args)?;
        }

        Some("bench-profile") => {
            bench_profile(&args)?;
        }

        Some("bench-load") => {
            bench_load(&args)?;
        }

        Some("bench") => {
            match args.sub(1).unwrap_or("all") {
                // `bench all`: both suites end to end, with every
                // SPEEDUP[*] comparison appended as a dated trajectory
                // entry to the json-dir baselines (newest last; see
                // util::trajectory).
                "all" => {
                    let dir = PathBuf::from(args.str("json-dir", "."));
                    std::fs::create_dir_all(&dir)?;
                    let sim = bench_sim(&args)?;
                    write_bench_json(&dir.join("BENCH_SIM.json"), &sim)?;
                    let prof = bench_profile(&args)?;
                    write_bench_json(&dir.join("BENCH_PROFILE.json"),
                                     &prof)?;
                    let load = bench_load(&args)?;
                    write_bench_json(&dir.join("BENCH_LOAD.json"), &load)?;
                }
                // `bench compare --baseline A --fresh B`: compare the
                // two files' *latest* entries — CI's regression gate. A
                // comparison present in the baseline but missing from
                // the fresh run (structure drift), or a fresh median
                // speedup below (1 − --max-regression) of the
                // baseline's, fails the command.
                "compare" => {
                    use aldram::util::trajectory;
                    let baseline = args.str("baseline", "");
                    let fresh = args.str("fresh", "");
                    anyhow::ensure!(!baseline.is_empty() && !fresh.is_empty(),
                                    "bench compare needs --baseline and \
                                     --fresh");
                    let tol = args.get("max-regression", 0.2f64);
                    let fails = trajectory::compare_latest(
                        &std::fs::read_to_string(&baseline)?,
                        &std::fs::read_to_string(&fresh)?, tol)?;
                    for f in &fails {
                        println!("BENCH REGRESSION: {f}");
                    }
                    anyhow::ensure!(fails.is_empty(),
                                    "{} bench comparison(s) failed against \
                                     {baseline}", fails.len());
                    println!("bench trajectory ok: {fresh} within {:.0}% of \
                              {baseline}", tol * 100.0);
                }
                other => anyhow::bail!(
                    "unknown bench subcommand `{other}` (all|compare)"),
            }
        }

        _ => {
            println!("repro — AL-DRAM reproduction (see DESIGN.md)");
            println!("commands: calibrate | profile | figure | ablate | eval | trace | check | fleet run|report | bench all | bench-sim | bench-profile | bench-load");
            println!("global flags: --jobs N (parallel fan-out width, \
                      default {}), --seed S (workload/mix RNG label, \
                      default 0), --check (attach the protocol-conformance \
                      checker to every simulation)", exec::default_jobs());
        }
    }
    Ok(())
}
