//! Parallel execution engine for embarrassingly parallel fan-outs.
//!
//! Every independent-simulation fan-out in the crate — the Fig-4 workload
//! grid, the §8.4 sensitivity matrix, the population profiling campaign,
//! the ablation grids — runs through [`Pool`]. The pool is built on
//! `std::thread::scope` (the offline crate mirror has no rayon, matching
//! the no-proptest convention in `util::quick`) and makes one guarantee
//! the evaluation harnesses rely on: the reduction is **deterministic and
//! order-independent**. Workers pull job indices from a shared atomic
//! counter and write each result into its input-indexed slot, so the
//! output vector is identical for any job count — `Pool::new(1)` *is* the
//! sequential path, and `figures::fig4` asserts bit-identical results
//! across job counts.
//!
//! Workers never share mutable state with each other; jobs that need a
//! stateful resource (e.g. a `ProfilingBackend`, whose `profile()` takes
//! `&mut self`) construct their own instance inside the worker via a
//! `Sync` factory — see `figures::calibrate::run_par`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Default worker count: the machine's available parallelism (the
/// `--jobs N` CLI flag overrides it).
pub fn default_jobs() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// A scoped worker pool of fixed width.
#[derive(Debug, Clone, Copy)]
pub struct Pool {
    jobs: usize,
}

impl Pool {
    /// A pool with `jobs` workers (0 is clamped to 1).
    pub fn new(jobs: usize) -> Self {
        Pool { jobs: jobs.max(1) }
    }

    /// A pool as wide as the machine.
    pub fn auto() -> Self {
        Pool::new(default_jobs())
    }

    /// The strictly sequential pool (runs jobs in order on the caller's
    /// thread — the reference path for determinism tests).
    pub fn sequential() -> Self {
        Pool::new(1)
    }

    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// Evaluate `f(0..n)` across the pool and return the results in input
    /// order. With one worker (or one job) this degenerates to a plain
    /// in-order loop on the caller's thread; with more workers the jobs
    /// are claimed dynamically but each result still lands in its own
    /// slot, so the returned vector does not depend on scheduling.
    pub fn run<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        self.run_init(n, || (), |_, i| f(i))
    }

    /// Like [`Pool::run`], but each worker lazily constructs one private
    /// state value via `init` and threads it mutably through every job it
    /// claims. This is how stateful resources fan out: a worker-owned
    /// `ProfilingBackend` (whose `profile()` takes `&mut self`) is built
    /// once per worker — not once per job — and never crosses threads, so
    /// the state type needs neither `Send` nor `Sync`.
    pub fn run_init<S, T, FI, F>(&self, n: usize, init: FI, f: F) -> Vec<T>
    where
        T: Send,
        FI: Fn() -> S + Sync,
        F: Fn(&mut S, usize) -> T + Sync,
    {
        if self.jobs == 1 || n <= 1 {
            let mut state = init();
            return (0..n).map(|i| f(&mut state, i)).collect();
        }
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<T>>> =
            (0..n).map(|_| Mutex::new(None)).collect();
        std::thread::scope(|s| {
            for _ in 0..self.jobs.min(n) {
                s.spawn(|| {
                    // Lazy: a worker that never claims a job never pays
                    // for (potentially expensive) state construction.
                    let mut state: Option<S> = None;
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let st = state.get_or_insert_with(&init);
                        let r = f(st, i);
                        *slots[i].lock().unwrap() = Some(r);
                    }
                });
            }
        });
        slots
            .into_iter()
            .map(|m| {
                m.into_inner()
                    .expect("worker panicked would have propagated")
                    .expect("every slot filled exactly once")
            })
            .collect()
    }

    /// Fold `0..n` into a single accumulator across the pool, claiming
    /// work in contiguous chunks of `chunk` indices (0 is clamped to 1).
    ///
    /// This is the bounded-memory sibling of [`Pool::run_init`], built for
    /// campaigns whose per-job results must never be materialized: each
    /// worker folds every job it claims into one worker-local accumulator
    /// (`zero()` makes an empty one, `fold` absorbs one job into it), and
    /// the worker accumulators are merged at the end. Peak memory is
    /// O(workers × |A|) — independent of `n`.
    ///
    /// Chunked claiming amortizes the atomic traffic and keeps workers
    /// load-balanced under heterogeneous job costs (a worker stuck on an
    /// expensive chunk simply claims fewer chunks); `chunk = 1` degrades
    /// to per-job claiming.
    ///
    /// **Determinism contract:** which worker folds which chunk depends on
    /// scheduling, so the result is bit-identical across `jobs` and
    /// `chunk` choices *iff* `fold`/`merge` form an exactly commutative
    /// monoid — all-integer or fixed-point accumulators such as
    /// [`crate::util::hist::StreamHist`], not `f64` sums. The fleet
    /// campaign's determinism tests pin exactly this property.
    pub fn run_fold<S, A, FI, FA, F, M>(&self, n: usize, chunk: usize,
                                        init: FI, zero: FA, fold: F,
                                        merge: M) -> A
    where
        A: Send,
        FI: Fn() -> S + Sync,
        FA: Fn() -> A + Sync,
        F: Fn(&mut S, &mut A, usize) + Sync,
        M: Fn(A, A) -> A + Sync,
    {
        if self.jobs == 1 || n <= 1 {
            let mut state = init();
            let mut acc = zero();
            for i in 0..n {
                fold(&mut state, &mut acc, i);
            }
            return acc;
        }
        let chunk = chunk.max(1);
        let n_chunks = n.div_ceil(chunk);
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<A>>> =
            (0..self.jobs.min(n_chunks)).map(|_| Mutex::new(None)).collect();
        std::thread::scope(|s| {
            let (next, init, zero, fold) = (&next, &init, &zero, &fold);
            for slot in &slots {
                s.spawn(move || {
                    // Both lazy: a worker that never claims a chunk pays
                    // for neither state nor accumulator construction.
                    let mut state: Option<S> = None;
                    let mut acc: Option<A> = None;
                    loop {
                        let c = next.fetch_add(1, Ordering::Relaxed);
                        if c >= n_chunks {
                            break;
                        }
                        let st = state.get_or_insert_with(init);
                        let a = acc.get_or_insert_with(zero);
                        let lo = c * chunk;
                        for i in lo..(lo + chunk).min(n) {
                            fold(st, a, i);
                        }
                    }
                    if let Some(a) = acc {
                        *slot.lock().unwrap() = Some(a);
                    }
                });
            }
        });
        let mut out = zero();
        for m in slots {
            if let Some(a) = m.into_inner()
                .expect("worker panicked would have propagated")
            {
                out = merge(out, a);
            }
        }
        out
    }

    /// Fallible variant of [`Pool::run`]: runs everything, then surfaces
    /// the first error in input order (later results are dropped). Errors
    /// do not cancel in-flight jobs — fan-outs here are short and
    /// side-effect free.
    pub fn try_run<T, F>(&self, n: usize, f: F) -> anyhow::Result<Vec<T>>
    where
        T: Send,
        F: Fn(usize) -> anyhow::Result<T> + Sync,
    {
        self.run(n, f).into_iter().collect()
    }

    /// Fallible variant of [`Pool::run_init`].
    pub fn try_run_init<S, T, FI, F>(&self, n: usize, init: FI, f: F)
                                     -> anyhow::Result<Vec<T>>
    where
        T: Send,
        FI: Fn() -> S + Sync,
        F: Fn(&mut S, usize) -> anyhow::Result<T> + Sync,
    {
        self.run_init(n, init, f).into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_arrive_in_input_order() {
        let pool = Pool::new(4);
        let out = pool.run(100, |i| {
            // Stagger so late indices often finish first.
            if i % 7 == 0 {
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            i * i
        });
        assert_eq!(out.len(), 100);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn parallel_equals_sequential() {
        let f = |i: usize| (i as f64 + 1.0).sqrt().ln();
        let seq = Pool::sequential().run(64, f);
        let par = Pool::new(8).run(64, f);
        assert_eq!(seq, par, "identical results for any job count");
    }

    #[test]
    fn more_workers_than_jobs_is_fine() {
        let out = Pool::new(16).run(3, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3]);
    }

    #[test]
    fn zero_jobs_clamps_to_one() {
        assert_eq!(Pool::new(0).jobs(), 1);
        assert_eq!(Pool::new(0).run(4, |i| i), vec![0, 1, 2, 3]);
    }

    #[test]
    fn try_run_surfaces_first_error_in_input_order() {
        let pool = Pool::new(4);
        let r = pool.try_run(10, |i| {
            if i == 3 || i == 7 {
                anyhow::bail!("job {i} failed")
            }
            Ok(i)
        });
        let msg = format!("{}", r.unwrap_err());
        assert_eq!(msg, "job 3 failed");
        let ok = pool.try_run(5, |i| Ok::<_, anyhow::Error>(i)).unwrap();
        assert_eq!(ok, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn run_init_builds_at_most_one_state_per_worker() {
        let built = AtomicUsize::new(0);
        let jobs = 3;
        let out = Pool::new(jobs).run_init(
            32,
            || {
                built.fetch_add(1, Ordering::Relaxed);
                0u64 // per-worker job counter
            },
            |state, i| {
                *state += 1;
                i
            },
        );
        assert_eq!(out, (0..32).collect::<Vec<_>>());
        let n = built.load(Ordering::Relaxed);
        assert!(n >= 1 && n <= jobs, "built {n} states for {jobs} workers");
    }

    #[test]
    fn run_fold_matches_sequential_for_any_shape() {
        // Integer accumulators ⇒ the fold is an exact commutative monoid,
        // so every (jobs, chunk) shape must produce the identical result.
        let fold = |_: &mut (), acc: &mut (u64, u64), i: usize| {
            acc.0 += (i as u64) * (i as u64);
            acc.1 += 1;
        };
        let merge =
            |a: (u64, u64), b: (u64, u64)| (a.0 + b.0, a.1 + b.1);
        let want = Pool::sequential()
            .run_fold(257, 8, || (), || (0, 0), fold, merge);
        assert_eq!(want.1, 257);
        for jobs in [2usize, 4, 8] {
            for chunk in [1usize, 3, 64, 1000] {
                let got = Pool::new(jobs)
                    .run_fold(257, chunk, || (), || (0, 0), fold, merge);
                assert_eq!(got, want, "jobs {jobs} chunk {chunk}");
            }
        }
    }

    #[test]
    fn run_fold_clamps_chunk_and_handles_empty() {
        let sum = Pool::new(4).run_fold(
            10, 0, // chunk 0 clamps to 1
            || (),
            || 0u64,
            |_, acc, i| *acc += i as u64,
            |a, b| a + b,
        );
        assert_eq!(sum, 45);
        let none = Pool::new(4)
            .run_fold(0, 32, || (), || 7u64, |_, _, _| (), |a, b| a + b);
        assert_eq!(none, 7, "empty fold returns zero()");
    }

    #[test]
    fn run_fold_builds_at_most_one_state_per_worker() {
        let built = AtomicUsize::new(0);
        let jobs = 3;
        let count = Pool::new(jobs).run_fold(
            64,
            4,
            || built.fetch_add(1, Ordering::Relaxed),
            || 0u64,
            |_, acc, _| *acc += 1,
            |a, b| a + b,
        );
        assert_eq!(count, 64);
        let n = built.load(Ordering::Relaxed);
        assert!(n >= 1 && n <= jobs, "built {n} states for {jobs} workers");
    }

    #[test]
    fn pool_parallelizes_wall_clock() {
        // Smoke (not an assertion on speedup — CI machines vary): jobs
        // run concurrently without deadlock at width > core count.
        let pool = Pool::new(default_jobs().max(2));
        let out = pool.run(32, |i| {
            std::thread::sleep(std::time::Duration::from_millis(1));
            i
        });
        assert_eq!(out.len(), 32);
    }
}
