//! Fig 6/7: the paper's headline *per-workload* result — AL-DRAM's
//! performance improvement for every workload, in single-core and
//! multi-programmed-mix configurations, at two operating temperatures
//! (55 °C and 85 °C).
//!
//! Each evaluated unit (one of the 35 suite workloads single-core, or a
//! named intensive×non-intensive mix from `workloads::mix`) runs four
//! simulations per row: {baseline DDR3, AL-DRAM-managed} × {55 °C,
//! 85 °C}. The AL-DRAM side installs the profiled module's own
//! temperature-indexed table (reloaded from a `--profiles` registry, as
//! in `fig4_profiled`), so the 85 °C column genuinely exercises the
//! hotter — slower — table bins. The improvement metric is the weighted
//! speedup (`SystemStats::weighted_speedup`): for a single-core unit it
//! degenerates to the plain IPC ratio.

use super::lockstep;
use super::Driver;
use crate::aldram::{AlDram, RegionTable, FULL_LOAD_RISE_C};
use crate::exec::Pool;
use crate::mem::{ChannelConfig, SystemConfig, SystemStats};
use crate::util;
use crate::workloads::mix::MixSpec;
use crate::workloads::{NamedSource, WorkloadSpec};

/// The two evaluated operating temperatures (paper §8.3: performance
/// sensitivity to temperature).
pub const FIG6_TEMPS: [f64; 2] = [55.0, 85.0];

/// Ambient temperature that places a channel's *worst-case* DIMM
/// temperature at the operating point `temp_c`: full-load self-heating
/// plus the table's lookup guardband both fit under the target, so the
/// hottest bin the table can ever install is the `temp_c` bin — never
/// the above-range standard fallback.
pub fn ambient_for(temp_c: f64, guard_c: f64) -> f64 {
    temp_c - FULL_LOAD_RISE_C - guard_c
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RowKind {
    /// One suite workload on one core.
    Single,
    /// A named multi-programmed mix (`workloads::mix`), one core per
    /// member, scored by weighted speedup.
    Mix,
}

#[derive(Debug, Clone)]
pub struct Fig6Row {
    pub name: String,
    pub kind: RowKind,
    /// Workload MPKI (mean member MPKI for a mix).
    pub mpki: f64,
    /// Memory-intensive classification (any-member for a mix — always
    /// true for the paired mixes).
    pub intensive: bool,
    /// Weighted speedup of the AL-DRAM side at each operating point.
    pub speedup_55: f64,
    pub speedup_85: f64,
}

#[derive(Debug, Clone)]
pub struct Fig6Result {
    pub rows: Vec<Fig6Row>,
    pub gmean_intensive_55: f64,
    pub gmean_intensive_85: f64,
    pub gmean_nonintensive_55: f64,
    pub gmean_nonintensive_85: f64,
    pub gmean_mix_55: f64,
    pub gmean_mix_85: f64,
}

/// One evaluated unit of the Fig-6 grid.
enum Unit {
    Single(WorkloadSpec),
    Mix(MixSpec),
}

impl Unit {
    fn sources(&self, seed: &str) -> Vec<NamedSource> {
        match self {
            Unit::Single(w) => {
                vec![w.named_source(&format!("fig6/{seed}/core0"))]
            }
            Unit::Mix(m) => m.sources(&format!("fig6/{seed}")),
        }
    }
}

/// Run the Fig-6 grid: `workloads` singles plus `mixes`, each × 2
/// temperatures × {baseline, AL-DRAM `table`}, fanned out over `jobs`
/// pool workers (one simulation per job, input-indexed reduction — the
/// result is bit-identical for every job count). `seed` feeds every
/// source's seed label (`--seed` on the CLI), so two runs with the same
/// seed are bit-identical and different seeds draw different streams.
pub fn fig6(cycles: u64, jobs: usize, table: &AlDram, seed: &str,
            workloads: &[WorkloadSpec], mixes: &[MixSpec]) -> Fig6Result {
    fig6_regions(cycles, jobs, &RegionTable::uniform(table.clone()), seed,
                 workloads, mixes)
}

/// [`fig6`] at region granularity: the AL-DRAM side installs the region
/// table (per-(bank, row-region) bins) instead of a module-uniform one.
/// A uniform wrapper reproduces `fig6` bit for bit.
pub fn fig6_regions(cycles: u64, jobs: usize, table: &RegionTable,
                    seed: &str, workloads: &[WorkloadSpec],
                    mixes: &[MixSpec]) -> Fig6Result {
    let units: Vec<Unit> = workloads
        .iter()
        .cloned()
        .map(Unit::Single)
        .chain(mixes.iter().cloned().map(Unit::Mix))
        .collect();

    // One lockstep pool job per unit: its four (temp, side) variants
    // advance over a single shared generation of the unit's sources.
    // Flattened stats layout: ((unit * 2 + temp) * 2 + side).
    let variants: Vec<SystemConfig> = FIG6_TEMPS
        .iter()
        .flat_map(|&temp| {
            let ambient = ambient_for(temp, table.module().guard_c);
            [ChannelConfig::standard(ambient),
             ChannelConfig::profiled_regions(table.clone(), ambient)]
        })
        .map(|ch| SystemConfig::uniform(1, ch))
        .collect();
    let cells = lockstep::default_cells(&variants);
    let per_unit: Vec<Vec<SystemStats>> =
        Pool::new(jobs).run(units.len(), |ui| {
            lockstep::run_cells(&cells, units[ui].sources(seed), cycles,
                                Driver::TimeSkip, false)
                .into_iter()
                .map(|(s, _)| s)
                .collect()
        });
    let stats: Vec<SystemStats> = per_unit.into_iter().flatten().collect();

    let speedup_of = |ui: usize, ti: usize| -> f64 {
        let at = (ui * 2 + ti) * 2;
        stats[at + 1].weighted_speedup(&stats[at])
    };

    let rows: Vec<Fig6Row> = units
        .iter()
        .enumerate()
        .map(|(ui, u)| {
            let (name, kind, mpki, intensive) = match u {
                Unit::Single(w) => (w.name.to_string(), RowKind::Single,
                                    w.mpki, w.memory_intensive()),
                Unit::Mix(m) => (m.name.clone(), RowKind::Mix, m.mpki(),
                                 true),
            };
            Fig6Row {
                name,
                kind,
                mpki,
                intensive,
                speedup_55: speedup_of(ui, 0),
                speedup_85: speedup_of(ui, 1),
            }
        })
        .collect();

    let group = |kind: RowKind, intensive: bool, hot: bool| -> f64 {
        let v: Vec<f64> = rows
            .iter()
            .filter(|r| r.kind == kind
                        && (kind == RowKind::Mix || r.intensive == intensive))
            .map(|r| if hot { r.speedup_85 } else { r.speedup_55 })
            .collect();
        if v.is_empty() { 1.0 } else { util::geomean(&v) }
    };

    Fig6Result {
        gmean_intensive_55: group(RowKind::Single, true, false),
        gmean_intensive_85: group(RowKind::Single, true, true),
        gmean_nonintensive_55: group(RowKind::Single, false, false),
        gmean_nonintensive_85: group(RowKind::Single, false, true),
        gmean_mix_55: group(RowKind::Mix, true, false),
        gmean_mix_85: group(RowKind::Mix, true, true),
        rows,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aldram::DEFAULT_BIN_C;
    use crate::model::params;
    use crate::population::generate_dimm;
    use crate::profiler::profile_dimm;
    use crate::runtime::NativeBackend;
    use crate::workloads::{by_name, mix};

    fn table() -> AlDram {
        let d = generate_dimm(0, 64, params());
        let mut b = NativeBackend::new();
        let p = profile_dimm(&mut b, &d).unwrap();
        AlDram::from_profile(&p, DEFAULT_BIN_C)
    }

    fn picks(names: &[&str]) -> Vec<WorkloadSpec> {
        names.iter().map(|n| by_name(n).unwrap()).collect()
    }

    #[test]
    fn fig6_rows_cover_workloads_and_mixes() {
        let t = table();
        let ws = picks(&["gups", "povray"]);
        let mixes: Vec<_> = mix::suite().into_iter().take(2).collect();
        let r = fig6(8_000, 2, &t, "0", &ws, &mixes);
        assert_eq!(r.rows.len(), 4);
        assert_eq!(r.rows[0].kind, RowKind::Single);
        assert_eq!(r.rows[2].kind, RowKind::Mix);
        assert_eq!(r.rows[2].name, mixes[0].name);
        for row in &r.rows {
            assert!(row.speedup_55 > 0.0 && row.speedup_85 > 0.0, "{row:?}");
        }
    }

    #[test]
    fn fig6_is_deterministic_per_seed_and_job_count() {
        let t = table();
        let ws = picks(&["milc"]);
        let mixes: Vec<_> = mix::suite().into_iter().take(1).collect();
        let a = fig6(6_000, 1, &t, "s1", &ws, &mixes);
        let b = fig6(6_000, 4, &t, "s1", &ws, &mixes);
        let c = fig6(6_000, 2, &t, "s2", &ws, &mixes);
        for (x, y) in a.rows.iter().zip(&b.rows) {
            assert_eq!(x.speedup_55, y.speedup_55, "{}", x.name);
            assert_eq!(x.speedup_85, y.speedup_85, "{}", x.name);
        }
        // A different seed draws different address streams, so at least
        // one statistic moves.
        let moved = a.rows.iter().zip(&c.rows).any(|(x, y)| {
            x.speedup_55 != y.speedup_55 || x.speedup_85 != y.speedup_85
        });
        assert!(moved, "seed change had no effect on the grid");
    }

    #[test]
    fn cooler_operating_point_buys_at_least_as_much() {
        // The 55 °C bins are never slower than the 85 °C bins, so the
        // memory-intensive gmean at 55 °C must not fall below 85 °C's
        // (paper §8.3: benefit decreases with temperature).
        let t = table();
        let ws = picks(&["gups", "libquantum", "milc"]);
        let r = fig6(25_000, 2, &t, "0", &ws, &[]);
        assert!(r.gmean_intensive_55 >= r.gmean_intensive_85 - 1e-3,
                "55C {} < 85C {}", r.gmean_intensive_55,
                r.gmean_intensive_85);
        assert!(r.gmean_intensive_55 > 1.0,
                "AL-DRAM bought nothing at 55C: {}", r.gmean_intensive_55);
    }

    #[test]
    fn mixes_score_weighted_speedup_above_one() {
        let t = table();
        let mixes: Vec<_> = mix::suite().into_iter().take(2).collect();
        let r = fig6(25_000, 2, &t, "0", &[], &mixes);
        assert_eq!(r.rows.len(), 2);
        for row in &r.rows {
            assert_eq!(row.kind, RowKind::Mix);
            assert!(row.speedup_55 > 1.0,
                    "mix {} regressed at 55C: {}", row.name, row.speedup_55);
        }
        assert!(r.gmean_mix_55 > 1.0);
    }
}
