//! Open-loop load sweeps: latency-vs-throughput curves and the
//! saturation-knee search (`repro eval load`, DESIGN.md §16).
//!
//! The question AL-DRAM's Fig 4 cannot answer is what reduced timings
//! buy *under offered load*: how far the sustainable-throughput knee
//! moves, and what happens to p99/p99.9 below it. This module drives
//! open-loop systems (`System::set_open_loop` + `workloads::arrival`
//! sources) two ways:
//!
//! * [`run_point`] — ONE load point, K timing-table configs, run in
//!   lockstep over one shared arrival-stream generation through the
//!   `SharedSourceSet` machinery of DESIGN.md §14. Every config sees
//!   bit-identical arrivals (asserted in `tests/integration_load.rs`),
//!   so curve differences are purely the timing tables' doing, and the
//!   stream is generated once instead of K times (the
//!   `SPEEDUP[LOADSWEEP]` comparison).
//! * [`knee_search`] — the adaptive sweep: a coarse geometric ascent
//!   brackets the saturation knee (each probe is one bounded run that
//!   halts early past saturation), then geometric bisection narrows the
//!   bracket to `tol`. A full curve costs O(log(range)/log(1+tol))
//!   full-length runs instead of a dense load grid.
//!
//! A point is *saturated* when any core's bounded arrival FIFO
//! overflows within the cycle budget — the fail-loud divergence
//! condition: offered load exceeds what the config can drain, so
//! latency has no steady state and the run halts at the next thermal
//! epoch rather than growing memory. The knee reported here is thus a
//! deterministic function of (config, workload, arrival seed, cycle
//! budget, FIFO bound); EXPERIMENTS.md records the defaults.

use crate::mem::{System, SystemConfig, SystemStats};
use crate::workloads::arrival::{ArrivalKind, ArrivalSpec};
use crate::workloads::{NamedSource, WorkloadSpec};

use super::lockstep::{SharedSourceSet, LOCKSTEP_CHUNK};
use super::Driver;

/// Default open-loop arrival-queue bound for eval runs (re-exported so
/// the CLI and the bound used by regression tests agree).
pub const LOAD_BOUND: usize = crate::mem::cpu::OPEN_LOOP_BOUND;

/// Lowest load the knee ascent starts from (requests/cycle/core);
/// every DDR3 config sustains this.
pub const KNEE_FLOOR: f64 = 0.005;

/// Default relative knee-bracket tolerance.
pub const KNEE_TOL: f64 = 0.05;

/// One measured load point: offered load in, throughput and tail
/// latency out. `PartialEq` is exact (bit-level floats) — the
/// shared-stream lockstep engine must match the independent oracle.
#[derive(Debug, Clone, PartialEq)]
pub struct LoadPoint {
    /// Offered load, requests per controller cycle per core.
    pub load: f64,
    /// Cycles actually simulated (short of the budget iff saturated).
    pub cycles: u64,
    /// Arrivals admitted to the arrival FIFOs.
    pub offered: u64,
    pub reads_done: u64,
    pub writes_done: u64,
    /// Completed requests per cycle — the sustained-throughput measure.
    pub throughput: f64,
    /// Arrival-to-completion read-latency percentiles (cycles),
    /// `StreamHist::quantile_interp` of the merged histogram.
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub p999: f64,
    /// The arrival FIFO overflowed: this load is past the knee.
    pub saturated: bool,
}

/// One timing table's measured curve plus its knee.
#[derive(Debug, Clone)]
pub struct LoadCurve {
    pub table: String,
    pub points: Vec<LoadPoint>,
    /// Highest probed load the table sustained (see [`knee_search`]).
    pub knee: f64,
}

/// Everything one load point needs besides the config: workload,
/// arrival process, scale and seeds.
#[derive(Debug, Clone)]
pub struct LoadSetup {
    pub workload: WorkloadSpec,
    pub kind: ArrivalKind,
    pub cores: usize,
    pub cycles: u64,
    pub seed: String,
    pub bound: usize,
}

impl LoadSetup {
    fn sources(&self, load: f64) -> Vec<NamedSource> {
        let spec = ArrivalSpec { kind: self.kind, load };
        (0..self.cores)
            .map(|c| {
                spec.named_source(&self.workload,
                                  &format!("{}/core{c}", self.seed))
            })
            .collect()
    }
}

fn point_from(load: f64, s: &SystemStats) -> LoadPoint {
    let ol = s.open_loop.as_ref()
        .expect("load points come from open-loop runs");
    let q = |p: f64| {
        if ol.hist.is_empty() { 0.0 } else { ol.hist.quantile_interp(p) }
    };
    LoadPoint {
        load,
        cycles: s.cycles,
        offered: ol.offered,
        reads_done: s.reads_done,
        writes_done: s.writes_done,
        throughput: (s.reads_done + s.writes_done) as f64
            / s.cycles.max(1) as f64,
        p50: q(0.5),
        p95: q(0.95),
        p99: q(0.99),
        p999: q(0.999),
        saturated: ol.saturated,
    }
}

/// One load point across K configs, lockstep over ONE shared
/// arrival-stream generation: every config consumes bit-identical
/// arrivals, each batch is generated once, and passed batches are freed
/// as the slowest consumer moves on (`SharedSourceSet::trim`). A config
/// that saturates halts at its next thermal epoch and simply stops
/// consuming; the others run out their budget.
pub fn run_point(cfgs: &[SystemConfig], setup: &LoadSetup, load: f64,
                 driver: Driver) -> Vec<LoadPoint> {
    let shared = SharedSourceSet::new(setup.sources(load));
    let mut systems: Vec<System> = cfgs
        .iter()
        .map(|cfg| {
            let mut sys = System::with_sources(cfg, shared.consumer());
            sys.set_open_loop(setup.bound);
            sys
        })
        .collect();
    let mut left = setup.cycles;
    while left > 0 && !systems.iter().all(System::halted) {
        let span = LOCKSTEP_CHUNK.min(left);
        for sys in &mut systems {
            match driver {
                Driver::TimeSkip => sys.run_fast(span),
                Driver::CycleStepped => sys.run(span),
            };
        }
        shared.trim();
        left -= span;
    }
    systems.iter().map(|s| point_from(load, &s.stats())).collect()
}

/// The independent-system oracle for [`run_point`]: same seeds, one
/// full-length run and one private stream generation per config.
/// Bit-identical results (the `SPEEDUP[LOADSWEEP]` equivalence gate);
/// K× the generation work.
pub fn run_point_independent(cfgs: &[SystemConfig], setup: &LoadSetup,
                             load: f64, driver: Driver) -> Vec<LoadPoint> {
    cfgs.iter()
        .map(|cfg| {
            let mut sys = System::with_sources(cfg, setup.sources(load));
            sys.set_open_loop(setup.bound);
            let stats = match driver {
                Driver::TimeSkip => sys.run_fast(setup.cycles),
                Driver::CycleStepped => sys.run(setup.cycles),
            };
            point_from(load, &stats)
        })
        .collect()
}

/// The adaptive knee search for one config: geometric ascent from
/// [`KNEE_FLOOR`] (doubling until a probe saturates) brackets the knee,
/// then geometric bisection narrows the bracket until `hi/lo <= 1+tol`.
/// Returns the curve of every probe (sorted by load) with `knee` = the
/// highest sustained load. O(log) full-length runs total; saturated
/// probes are cheaper still because the run halts at the next epoch
/// after the FIFO overflows.
pub fn knee_search(cfg: &SystemConfig, setup: &LoadSetup, tol: f64,
                   driver: Driver) -> LoadCurve {
    assert!(tol > 0.0, "knee tolerance must be positive");
    let cfgs = std::slice::from_ref(cfg);
    let mut points: Vec<LoadPoint> = Vec::new();
    let mut probe = |load: f64, points: &mut Vec<LoadPoint>| -> bool {
        let p = run_point(cfgs, setup, load, driver).pop().unwrap();
        let sat = p.saturated;
        points.push(p);
        sat
    };
    let mut lo = KNEE_FLOOR;
    // Descend if even the floor saturates (a pathological config —
    // report a zero-ish knee rather than looping).
    let mut floor_tries = 0;
    while probe(lo, &mut points) {
        lo /= 4.0;
        floor_tries += 1;
        if floor_tries >= 4 {
            points.sort_by(|a, b| a.load.total_cmp(&b.load));
            return LoadCurve {
                table: String::new(),
                points,
                knee: 0.0,
            };
        }
    }
    // Geometric ascent: double until saturation (cap well past any
    // physical DDR3 per-core rate).
    let mut hi = lo * 2.0;
    while !probe(hi, &mut points) {
        lo = hi;
        hi *= 2.0;
        if hi > 8.0 {
            break; // sustained everything we can offer
        }
    }
    // Geometric bisection on the bracket.
    while hi / lo > 1.0 + tol {
        let mid = (lo * hi).sqrt();
        if probe(mid, &mut points) {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    points.sort_by(|a, b| a.load.total_cmp(&b.load));
    LoadCurve { table: String::new(), points, knee: lo }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::timing::TimingParams;
    use crate::workloads::by_name;

    fn setup(cycles: u64) -> LoadSetup {
        LoadSetup {
            workload: by_name("gups").unwrap(),
            kind: ArrivalKind::Poisson,
            cores: 1,
            cycles,
            seed: "t".into(),
            bound: 256,
        }
    }

    #[test]
    fn lockstep_point_matches_independent_oracle() {
        let cfgs = [
            SystemConfig::paper_default(),
            SystemConfig::paper_default().with_timings(
                TimingParams::ddr3_standard()
                    .reduced(0.27, 0.32, 0.33, 0.18)),
        ];
        let s = setup(40_000);
        for load in [0.01, 0.08] {
            let a = run_point(&cfgs, &s, load, Driver::TimeSkip);
            let b = run_point_independent(&cfgs, &s, load, Driver::TimeSkip);
            assert_eq!(a, b, "shared-stream lockstep diverged at {load}");
        }
    }

    #[test]
    fn drivers_agree_on_points() {
        let cfgs = [SystemConfig::paper_default()];
        let s = setup(30_000);
        for load in [0.02, 0.3] {
            let fast = run_point(&cfgs, &s, load, Driver::TimeSkip);
            let step = run_point(&cfgs, &s, load, Driver::CycleStepped);
            assert_eq!(fast, step, "drivers diverged at load {load}");
        }
    }

    #[test]
    fn knee_is_bracketed_and_monotone() {
        let s = setup(30_000);
        let curve = knee_search(&SystemConfig::paper_default(), &s,
                                0.1, Driver::TimeSkip);
        assert!(curve.knee > 0.0, "gups must sustain some load");
        // Every sustained probe sits at or below every saturated probe.
        let max_ok = curve.points.iter().filter(|p| !p.saturated)
            .map(|p| p.load).fold(0.0f64, f64::max);
        let min_sat = curve.points.iter().filter(|p| p.saturated)
            .map(|p| p.load).fold(f64::INFINITY, f64::min);
        assert!(max_ok <= min_sat,
                "saturation is not monotone: ok {max_ok} > sat {min_sat}");
        assert_eq!(curve.knee, max_ok);
        assert!(min_sat / curve.knee <= 1.1 + 1e-9,
                "bracket wider than tol: {} vs {}", curve.knee, min_sat);
    }
}
