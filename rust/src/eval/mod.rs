//! System-level evaluation harnesses: Fig 4 (real-system speedups), the
//! Fig-6 per-workload/per-mix improvement table (`fig6`), the §8.4
//! sensitivity and power analyses, and the §6 long-run stress test.
//!
//! Every harness comes in two flavors. The classic one drives the
//! AL-DRAM side with one global set of fractional reductions
//! (`PAPER_REDUCTIONS_55C` — the population-minimum operating point of
//! §6). The `*_profiled` flavor is the per-module mechanism the paper
//! actually proposes: each evaluated channel installs *its own DIMM's*
//! `AlDram` table (built by the profiler, or reloaded from the registry)
//! and lets the per-channel thermal model drive the bin selection.

pub mod fig6;
pub mod load;
pub mod lockstep;

pub use fig6::{fig6, fig6_regions, Fig6Result, Fig6Row, RowKind};
pub use load::{LoadCurve, LoadPoint};
pub use lockstep::Engine;

use crate::aldram::{AlDram, RegionTable, DEFAULT_BIN_C};
use crate::mem::{AddrMap, ChannelConfig, RegionRemap, RowPolicy, System,
                 SystemConfig, SystemStats};
use crate::power::{power, IddSpec};
use crate::profiler::{DimmProfile, RegionDimmProfile};
use crate::timing::TimingParams;
use crate::util;
use crate::workloads::{suite, WorkloadSpec};

/// The paper's evaluated AL-DRAM operating point at 55degC: the minimum
/// timing values that were error-free for every module (§6).
pub const PAPER_REDUCTIONS_55C: [f64; 4] = [0.27, 0.32, 0.33, 0.18];

/// How many cores the "multi-core" configuration runs (paper: multi-core
/// runs of the same application / multi-threaded workloads).
pub const MULTI_CORES: usize = 4;

#[derive(Debug, Clone)]
pub struct WorkloadResult {
    pub name: String,
    pub mpki: f64,
    pub intensive: bool,
    pub single_speedup: f64,
    pub single_stddev: f64,
    pub multi_speedup: f64,
    pub multi_stddev: f64,
}

#[derive(Debug, Clone)]
pub struct Fig4Result {
    pub per_workload: Vec<WorkloadResult>,
    pub gmean_intensive_multi: f64,
    pub gmean_nonintensive_multi: f64,
    pub gmean_intensive_single: f64,
    pub gmean_nonintensive_single: f64,
    pub mean_all_multi: f64,
    pub max_multi: f64,
}

/// Which simulation driver an evaluation harness runs. `TimeSkip`
/// (`System::run_fast`) is bit-identical to `CycleStepped` (`System::run`,
/// the oracle — equivalence asserted in `tests/integration_timeskip.rs`)
/// and is the default everywhere; the cycle-stepped oracle remains
/// selectable for the TIMESKIP speedup benchmarks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Driver {
    CycleStepped,
    TimeSkip,
}

fn throughput(stats: &SystemStats) -> f64 {
    stats.cores.iter().map(|c| c.ipc).sum::<f64>()
}

/// Run one (workload, core-count, config) simulation and return its
/// throughput. This is the single entry point every harness fans out
/// through — the timing side of an experiment lives entirely in `cfg`
/// (fixed per-channel timing sets, or AL-DRAM tables managing them).
fn run_config(w: &WorkloadSpec, cores: usize, cfg: &SystemConfig,
              cycles: u64, rep: usize, driver: Driver) -> f64 {
    let wl: Vec<(WorkloadSpec, String)> = (0..cores)
        .map(|c| (w.clone(), format!("rep{rep}/core{c}")))
        .collect();
    let mut sys = System::new(cfg, &wl);
    let stats = match driver {
        Driver::CycleStepped => sys.run(cycles),
        Driver::TimeSkip => sys.run_fast(cycles),
    };
    throughput(&stats)
}

/// Speedup of `fast` timings over `base` timings, averaged over reps;
/// returns (mean, stddev).
pub fn speedup(w: &WorkloadSpec, cores: usize, base: TimingParams,
               fast: TimingParams, cycles: u64, reps: usize,
               cfg: &SystemConfig) -> (f64, f64) {
    let base_cfg = cfg.clone().with_timings(base);
    let fast_cfg = cfg.clone().with_timings(fast);
    let ratios: Vec<f64> = (0..reps)
        .map(|rep| {
            let b = run_config(w, cores, &base_cfg, cycles, rep,
                               Driver::TimeSkip);
            let f = run_config(w, cores, &fast_cfg, cycles, rep,
                               Driver::TimeSkip);
            f / b
        })
        .collect();
    (util::mean(&ratios), util::stddev(&ratios))
}

/// Reproduce Fig 4 sequentially (`fig4_jobs` with one worker).
pub fn fig4(cycles: u64, reps: usize, reductions: [f64; 4]) -> Fig4Result {
    fig4_jobs(cycles, reps, reductions, 1)
}

/// Reproduce Fig 4: per-workload single-core and multi-core speedups of
/// AL-DRAM's 55degC timings over the DDR3 standard.
pub fn fig4_jobs(cycles: u64, reps: usize, reductions: [f64; 4],
                 jobs: usize) -> Fig4Result {
    fig4_jobs_with(cycles, reps, reductions, jobs, Driver::TimeSkip)
}

/// `fig4_jobs` with an explicit simulation driver (the TIMESKIP speedup
/// benchmark runs the grid once per driver; results are identical).
pub fn fig4_jobs_with(cycles: u64, reps: usize, reductions: [f64; 4],
                      jobs: usize, driver: Driver) -> Fig4Result {
    let base_cfg = SystemConfig::paper_default();
    let fast_cfg = SystemConfig::paper_default()
        .with_timings(reduced_validated(reductions));
    fig4_pair(cycles, reps, jobs, driver, &base_cfg, &fast_cfg)
}

/// Every caller-supplied reduction vector passes the timing validator
/// before it reaches a controller: a negative or >100% reduction would
/// otherwise silently simulate nonsensical (or super-standard) timings
/// that the protocol checker then has to audit against.
fn reduced_validated(reductions: [f64; 4]) -> TimingParams {
    let t = TimingParams::ddr3_standard().reduced(
        reductions[0], reductions[1], reductions[2], reductions[3]);
    t.validate()
        .expect("reduction percentages produce an invalid timing set");
    t
}

/// Fig 4 for *one profiled module*: the AL-DRAM side installs the DIMM's
/// own temperature-indexed table (thermal-model-managed at refresh-epoch
/// granularity) instead of the population-minimum fixed reductions. The
/// result depends only on the table, so a registry reload reproduces a
/// profile-fresh run bit for bit (`tests/integration_registry.rs`).
pub fn fig4_profiled(cycles: u64, reps: usize, table: &AlDram,
                     jobs: usize) -> Fig4Result {
    fig4_profiled_regions(cycles, reps, &RegionTable::uniform(table.clone()),
                          jobs)
}

/// [`fig4_profiled`] at region granularity: the AL-DRAM side installs the
/// full region table. A uniform wrapper reproduces `fig4_profiled` bit
/// for bit; comparing against `fig4_profiled_regions(&table.collapsed())`
/// isolates what region indexing buys over the module-uniform collapse.
pub fn fig4_profiled_regions(cycles: u64, reps: usize, table: &RegionTable,
                             jobs: usize) -> Fig4Result {
    let base_cfg = SystemConfig::paper_default();
    let fast_cfg = SystemConfig::paper_default()
        .with_region_table(Some(table.clone()));
    fig4_pair(cycles, reps, jobs, Driver::TimeSkip, &base_cfg, &fast_cfg)
}

/// The Fig-4 grid over an explicit (baseline, AL-DRAM) config pair.
///
/// The grid runs on the lockstep engine: one pool job per (workload,
/// core-count, rep) cell, with both configs simulated over *one* shared
/// generation of the cell's request stream (`eval::lockstep`). The
/// throughput vector keeps the historical config-minor layout, each job
/// writes input-indexed slots, and the speedup reduction below consumes
/// them in the exact order the sequential loop would — so the result is
/// bit-identical for every `jobs` value (asserted by
/// `parallel_fig4_matches_sequential`) and to the independent-system
/// oracle (asserted by `tests/integration_lockstep.rs`).
fn fig4_pair(cycles: u64, reps: usize, jobs: usize, driver: Driver,
             base_cfg: &SystemConfig, fast_cfg: &SystemConfig)
             -> Fig4Result {
    let workloads = suite();
    let cfgs = [base_cfg.clone(), fast_cfg.clone()];

    // Throughput layout: (((workload * 2 + core_cfg) * reps + rep) * 2
    //                      + config).
    let core_cfgs = [1usize, MULTI_CORES];
    let throughputs = lockstep::grid(&cfgs, &workloads, &core_cfgs, cycles,
                                     reps, jobs, driver, Engine::Lockstep);
    let speedup_of = |wi: usize, cc: usize| -> (f64, f64) {
        let ratios: Vec<f64> = (0..reps)
            .map(|rep| {
                let at = ((wi * 2 + cc) * reps + rep) * 2;
                throughputs[at + 1] / throughputs[at]
            })
            .collect();
        (util::mean(&ratios), util::stddev(&ratios))
    };

    let mut per_workload = Vec::new();
    for (wi, w) in workloads.iter().enumerate() {
        let (s1, e1) = speedup_of(wi, 0);
        let (sm, em) = speedup_of(wi, 1);
        per_workload.push(WorkloadResult {
            name: w.name.to_string(),
            mpki: w.mpki,
            intensive: w.memory_intensive(),
            single_speedup: s1,
            single_stddev: e1,
            multi_speedup: sm,
            multi_stddev: em,
        });
    }

    let group = |intensive: bool, multi: bool| -> f64 {
        let v: Vec<f64> = per_workload
            .iter()
            .filter(|r| r.intensive == intensive)
            .map(|r| if multi { r.multi_speedup } else { r.single_speedup })
            .collect();
        util::geomean(&v)
    };

    Fig4Result {
        gmean_intensive_multi: group(true, true),
        gmean_nonintensive_multi: group(false, true),
        gmean_intensive_single: group(true, false),
        gmean_nonintensive_single: group(false, false),
        mean_all_multi: util::mean(
            &per_workload.iter().map(|r| r.multi_speedup).collect::<Vec<_>>(),
        ),
        max_multi: per_workload
            .iter()
            .map(|r| r.multi_speedup)
            .fold(0.0, f64::max),
        per_workload,
    }
}

// ---------------------------------------------------------------------
// §8.4: sensitivity to channels / ranks / row policy.
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct SensitivityRow {
    pub label: String,
    pub channels: usize,
    pub ranks: usize,
    pub policy: RowPolicy,
    pub gmean_speedup: f64,
}

const SENSITIVITY_GRID: [(usize, usize, RowPolicy, &str); 5] = [
    (1, 1, RowPolicy::Open, "1ch/1rank/open"),
    (2, 1, RowPolicy::Open, "2ch/1rank/open"),
    (1, 2, RowPolicy::Open, "1ch/2rank/open"),
    (2, 2, RowPolicy::Open, "2ch/2rank/open"),
    (1, 1, RowPolicy::Closed, "1ch/1rank/closed"),
];

fn sensitivity_base_cfg(gi: usize) -> SystemConfig {
    let (channels, ranks, policy, _) = SENSITIVITY_GRID[gi];
    SystemConfig {
        ranks_per_channel: ranks,
        policy,
        ..SystemConfig::paper_default().with_channels(channels)
    }
}

/// Sequential §8.4 sensitivity (`sensitivity_jobs` with one worker).
pub fn sensitivity(cycles: u64, reductions: [f64; 4]) -> Vec<SensitivityRow> {
    sensitivity_jobs(cycles, reductions, 1)
}

/// AL-DRAM speedup (memory-intensive gmean, multi-core) across system
/// configurations — the paper's claim is that it helps in *all* of them.
pub fn sensitivity_jobs(cycles: u64, reductions: [f64; 4],
                        jobs: usize) -> Vec<SensitivityRow> {
    let fast = reduced_validated(reductions);
    let cfgs: Vec<(SystemConfig, SystemConfig)> = (0..SENSITIVITY_GRID.len())
        .map(|gi| {
            let base = sensitivity_base_cfg(gi);
            let fast_cfg = base.clone().with_timings(fast);
            (base, fast_cfg)
        })
        .collect();
    sensitivity_pairs(cycles, jobs, &cfgs)
}

/// §8.4 sensitivity on profiled modules: in every grid configuration each
/// channel installs its own DIMM's table (drawn round-robin from the
/// registry population), so the multi-channel rows genuinely mix module
/// identities.
pub fn sensitivity_profiled(cycles: u64, profiles: &[DimmProfile],
                            jobs: usize) -> Vec<SensitivityRow> {
    assert!(!profiles.is_empty());
    let tables: Vec<AlDram> = profiles
        .iter()
        .map(|p| AlDram::from_profile(p, DEFAULT_BIN_C))
        .collect();
    let cfgs: Vec<(SystemConfig, SystemConfig)> = (0..SENSITIVITY_GRID.len())
        .map(|gi| {
            let base = sensitivity_base_cfg(gi);
            let fast = SystemConfig {
                channels: (0..base.channel_count())
                    .map(|ch| ChannelConfig::profiled(
                        tables[ch % tables.len()].clone(), 55.0))
                    .collect(),
                ..base.clone()
            };
            (base, fast)
        })
        .collect();
    sensitivity_pairs(cycles, jobs, &cfgs)
}

/// One lockstep pool job per workload: all grid configurations (both
/// sides of every row) advance over one shared generation of the
/// workload's stream, with the same order-independent reduction as the
/// Fig-4 grid.
fn sensitivity_pairs(cycles: u64, jobs: usize,
                     cfgs: &[(SystemConfig, SystemConfig)])
                     -> Vec<SensitivityRow> {
    let picks: Vec<WorkloadSpec> = suite()
        .into_iter()
        .filter(|w| w.memory_intensive())
        .take(6)
        .collect();

    // Config-minor throughput layout: (workload * K + config * 2 + side),
    // K = 2 × grid rows.
    let flat: Vec<SystemConfig> = cfgs
        .iter()
        .flat_map(|(base, fast)| [base.clone(), fast.clone()])
        .collect();
    let k = flat.len();
    let throughputs = lockstep::grid(&flat, &picks, &[MULTI_CORES], cycles,
                                     1, jobs, Driver::TimeSkip,
                                     Engine::Lockstep);

    SENSITIVITY_GRID
        .iter()
        .enumerate()
        .map(|(gi, (channels, ranks, policy, label))| {
            let speedups: Vec<f64> = (0..picks.len())
                .map(|wi| {
                    let at = wi * k + gi * 2;
                    throughputs[at + 1] / throughputs[at]
                })
                .collect();
            SensitivityRow {
                label: label.to_string(),
                channels: *channels,
                ranks: *ranks,
                policy: *policy,
                gmean_speedup: util::geomean(&speedups),
            }
        })
        .collect()
}

// ---------------------------------------------------------------------
// §8.4: heterogeneous *module* populations.
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct HeteroResult {
    /// Workload names of the 4-application mix.
    pub mix: Vec<String>,
    /// DIMM id installed on each channel.
    pub dimm_ids: Vec<usize>,
    /// Weighted speedup: mean over cores of per-core IPC ratios (the
    /// standard multi-programmed metric — insensitive to one core
    /// dominating the throughput sum).
    pub weighted_speedup: f64,
    /// Per-channel read-latency reduction (1 − profiled/base) — how much
    /// each individual module's profile bought on its channel.
    pub channel_latency_reduction: Vec<f64>,
    /// Timing-set switches AL-DRAM performed on each channel.
    pub channel_switches: Vec<u64>,
    /// max − min of the per-channel reductions: the spread module
    /// heterogeneity introduces (FLY-DRAM's inter-module variation).
    pub channel_spread: f64,
}

/// §8.4 extended to true *module* heterogeneity: every mix populates the
/// channels with distinct profiled DIMMs — one drawn from the fastest
/// quartile of the population and one from the slowest (FLY-DRAM's
/// observation: outlier-slow modules sit next to fast ones), the rest at
/// random — each channel running its own AL-DRAM table on its own
/// thermal model. Reports the per-channel speedup spread, not just the
/// workload mix.
pub fn hetero_eval(cycles: u64, n_mixes: usize, channels: usize,
                   profiles: &[DimmProfile]) -> Vec<HeteroResult> {
    use crate::util::rng::Rng;
    assert!(channels >= 2 && channels.is_power_of_two(),
            "module heterogeneity needs >= 2 channels (power of two)");
    assert!(profiles.len() >= channels,
            "need at least one distinct profile per channel: {} < {}",
            profiles.len(), channels);

    // Loop-invariant state, hoisted out of the per-mix closure: the
    // workload pool, its memory-intensive subset, the per-DIMM tables,
    // and the population's speed ordering.
    let pool = suite();
    let intensive: Vec<WorkloadSpec> = pool
        .iter()
        .filter(|w| w.memory_intensive())
        .cloned()
        .collect();
    let tables: Vec<AlDram> = profiles
        .iter()
        .map(|p| AlDram::from_profile(p, DEFAULT_BIN_C))
        .collect();
    let mut order: Vec<usize> = (0..profiles.len()).collect();
    order.sort_by(|&a, &b| {
        let key = |i: usize| profiles[i].at55.combined().read_sum_ns();
        key(a).partial_cmp(&key(b)).unwrap()
    });
    let quart = (profiles.len() / 4).max(1);
    let mut rng = Rng::from_label("hetero-mixes");

    (0..n_mixes)
        .map(|mi| {
            // Channel population: fastest-quartile module on channel 0,
            // slowest-quartile outlier on channel 1, the rest random but
            // distinct.
            let mut picks: Vec<usize> = Vec::with_capacity(channels);
            picks.push(order[rng.below(quart as u64) as usize]);
            picks.push(order[profiles.len() - 1
                             - rng.below(quart as u64) as usize]);
            while picks.len() < channels {
                let cand = rng.below(profiles.len() as u64) as usize;
                if !picks.contains(&cand) {
                    picks.push(cand);
                }
            }

            // 2 intensive + 2 drawn from the whole pool: the paper's
            // mixes keep memory pressure while mixing intensity classes.
            let mix = [
                rng.choose(&intensive).clone(),
                rng.choose(&intensive).clone(),
                rng.choose(&pool).clone(),
                rng.choose(&pool).clone(),
            ];
            let wl: Vec<(WorkloadSpec, String)> = mix
                .iter()
                .enumerate()
                .map(|(i, w)| (w.clone(), format!("hx{mi}/{i}")))
                .collect();

            let base_cfg = SystemConfig::uniform(
                channels, ChannelConfig::standard(55.0));
            let prof_cfg = SystemConfig {
                channels: picks
                    .iter()
                    .map(|&di| ChannelConfig::profiled(tables[di].clone(),
                                                       55.0))
                    .collect(),
                ..base_cfg.clone()
            };
            let run = |cfg: &SystemConfig| {
                let mut sys = System::new(cfg, &wl);
                sys.run_fast(cycles)
            };
            let base = run(&base_cfg);
            let prof = run(&prof_cfg);

            let ws = prof.weighted_speedup(&base);
            let reductions: Vec<f64> = base
                .channels
                .iter()
                .zip(&prof.channels)
                .map(|(b, f)| {
                    if b.avg_read_latency_cycles > 0.0 {
                        1.0 - f.avg_read_latency_cycles
                            / b.avg_read_latency_cycles
                    } else {
                        0.0
                    }
                })
                .collect();
            let hi = reductions.iter().cloned().fold(f64::MIN, f64::max);
            let lo = reductions.iter().cloned().fold(f64::MAX, f64::min);
            HeteroResult {
                mix: mix.iter().map(|w| w.name.to_string()).collect(),
                dimm_ids: picks.iter().map(|&di| profiles[di].id).collect(),
                weighted_speedup: ws,
                channel_latency_reduction: reductions,
                channel_switches: prof
                    .channels
                    .iter()
                    .map(|c| c.timing_switches)
                    .collect(),
                channel_spread: hi - lo,
            }
        })
        .collect()
}

/// One mix of the region-granularity heterogeneity eval: the same
/// channel population evaluated three ways against the standard-timing
/// baseline — module-uniform (each channel installs its table's
/// per-parameter-max collapse), region-indexed (the full per-(bank,
/// row-region) table), and optionally region-indexed plus
/// variation-aware page placement.
#[derive(Debug, Clone)]
pub struct HeteroRegionResult {
    pub mix: Vec<String>,
    pub dimm_ids: Vec<usize>,
    /// Weighted speedup of the module-uniform collapse over baseline.
    pub ws_uniform: f64,
    /// Weighted speedup of the region-indexed tables over baseline.
    pub ws_region: f64,
    /// Region-indexed + fastest-first row-region remap (only when
    /// placement was requested and the grid has >= 2 regions).
    pub ws_placement: Option<f64>,
    /// `ws_region - ws_uniform`: what region indexing buys on this mix.
    pub delta: f64,
}

/// Region-granularity module heterogeneity (§8.4 extended): every mix
/// populates the channels with distinct region-profiled DIMMs and runs
/// the *same* workloads and baseline under the module-uniform collapse
/// and under the region-indexed tables, so `delta` isolates the value of
/// region indexing on the same profiled population. With `placement`,
/// a third run adds the fastest-first row-region remap (derived from
/// channel 0's table; the shared address map carries one permutation).
pub fn hetero_eval_regions(cycles: u64, n_mixes: usize, channels: usize,
                           profiles: &[RegionDimmProfile], placement: bool)
                           -> Vec<HeteroRegionResult> {
    use crate::util::rng::Rng;
    assert!(channels >= 2 && channels.is_power_of_two(),
            "module heterogeneity needs >= 2 channels (power of two)");
    assert!(profiles.len() >= channels,
            "need at least one distinct profile per channel: {} < {}",
            profiles.len(), channels);

    let pool = suite();
    let intensive: Vec<WorkloadSpec> = pool
        .iter()
        .filter(|w| w.memory_intensive())
        .cloned()
        .collect();
    let tables: Vec<RegionTable> = profiles
        .iter()
        .map(|p| RegionTable::from_region_profile(p, DEFAULT_BIN_C))
        .collect();
    let mut order: Vec<usize> = (0..profiles.len()).collect();
    order.sort_by(|&a, &b| {
        let key = |i: usize| profiles[i].base.at55.combined().read_sum_ns();
        key(a).partial_cmp(&key(b)).unwrap()
    });
    let quart = (profiles.len() / 4).max(1);
    // Own stream: the scalar hetero eval's draws stay untouched.
    let mut rng = Rng::from_label("hetero-mixes-regions");

    (0..n_mixes)
        .map(|mi| {
            let mut picks: Vec<usize> = Vec::with_capacity(channels);
            picks.push(order[rng.below(quart as u64) as usize]);
            picks.push(order[profiles.len() - 1
                             - rng.below(quart as u64) as usize]);
            while picks.len() < channels {
                let cand = rng.below(profiles.len() as u64) as usize;
                if !picks.contains(&cand) {
                    picks.push(cand);
                }
            }

            let mix = [
                rng.choose(&intensive).clone(),
                rng.choose(&intensive).clone(),
                rng.choose(&pool).clone(),
                rng.choose(&pool).clone(),
            ];
            let wl: Vec<(WorkloadSpec, String)> = mix
                .iter()
                .enumerate()
                .map(|(i, w)| (w.clone(), format!("hxr{mi}/{i}")))
                .collect();

            let base_cfg = SystemConfig::uniform(
                channels, ChannelConfig::standard(55.0));
            let uni_cfg = SystemConfig {
                channels: picks
                    .iter()
                    .map(|&di| ChannelConfig::profiled(
                        tables[di].module().clone(), 55.0))
                    .collect(),
                ..base_cfg.clone()
            };
            let reg_cfg = SystemConfig {
                channels: picks
                    .iter()
                    .map(|&di| ChannelConfig::profiled_regions(
                        tables[di].clone(), 55.0))
                    .collect(),
                ..base_cfg.clone()
            };
            let map = AddrMap::ddr3_2gb(1);
            let run = |cfg: &SystemConfig, map: AddrMap| {
                let mut sys = System::new_with_map(cfg, map, &wl);
                sys.run_fast(cycles)
            };
            let base = run(&base_cfg, map);
            let ws_uniform = run(&uni_cfg, map).weighted_speedup(&base);
            let ws_region = run(&reg_cfg, map).weighted_speedup(&base);
            let ws_placement = (placement
                                && tables[picks[0]].regions_per_bank() >= 2)
                .then(|| {
                    let remap = RegionRemap::fastest_first(
                        &tables[picks[0]], map.row_bits);
                    run(&reg_cfg, map.with_remap(remap))
                        .weighted_speedup(&base)
                });

            HeteroRegionResult {
                mix: mix.iter().map(|w| w.name.to_string()).collect(),
                dimm_ids: picks.iter()
                    .map(|&di| profiles[di].base.id)
                    .collect(),
                ws_uniform,
                ws_region,
                ws_placement,
                delta: ws_region - ws_uniform,
            }
        })
        .collect()
}

// ---------------------------------------------------------------------
// §8.4: DRAM power.
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct PowerResult {
    pub name: String,
    pub base_w: f64,
    pub aldram_w: f64,
    /// Energy to complete the same instruction count.
    pub base_j_per_ginst: f64,
    pub aldram_j_per_ginst: f64,
}

/// DRAM power comparison on memory-intensive multi-core runs. The paper's
/// §8.4 reports 5.8% average DRAM power reduction.
pub fn power_eval(cycles: u64, reductions: [f64; 4]) -> Vec<PowerResult> {
    let fast = reduced_validated(reductions);
    power_pair(cycles, &SystemConfig::paper_default(),
               &SystemConfig::paper_default().with_timings(fast))
}

/// DRAM power with the AL-DRAM side running one profiled module's own
/// table instead of the fixed population-minimum reductions.
pub fn power_eval_profiled(cycles: u64, table: &AlDram) -> Vec<PowerResult> {
    power_pair(cycles, &SystemConfig::paper_default(),
               &SystemConfig::paper_default().with_aldram(
                   Some(table.clone())))
}

fn power_pair(cycles: u64, base_cfg: &SystemConfig,
              fast_cfg: &SystemConfig) -> Vec<PowerResult> {
    let spec = IddSpec::default();
    let run = |cfg: &SystemConfig, w: &WorkloadSpec| -> (f64, f64) {
        let wl: Vec<_> = (0..MULTI_CORES)
            .map(|i| (w.clone(), format!("pw/{i}")))
            .collect();
        let mut sys = System::new(cfg, &wl);
        let stats = sys.run_fast(cycles);
        let watts: f64 = stats
            .power_inputs
            .iter()
            .map(|pi| power(pi, &spec).total_w())
            .sum();
        let ginsts: f64 = stats.cores.iter()
            .map(|c| c.insts as f64)
            .sum::<f64>() / 1e9;
        let joules = watts * stats.cycles as f64 * 1.25e-9;
        (watts, joules / ginsts.max(1e-12))
    };

    let mut out = Vec::new();
    for w in suite().into_iter().filter(|w| w.memory_intensive()).take(8) {
        let (bw, bj) = run(base_cfg, &w);
        let (aw, aj) = run(fast_cfg, &w);
        out.push(PowerResult {
            name: w.name.to_string(),
            base_w: bw,
            aldram_w: aw,
            base_j_per_ginst: bj,
            aldram_j_per_ginst: aj,
        });
    }
    out
}

/// Average fractional energy-per-work reduction across the power rows.
pub fn power_saving(rows: &[PowerResult]) -> f64 {
    util::mean(
        &rows
            .iter()
            .map(|r| 1.0 - r.aldram_j_per_ginst / r.base_j_per_ginst)
            .collect::<Vec<_>>(),
    )
}

// ---------------------------------------------------------------------
// §6: long-run stress test (scaled stand-in for the 33-day run).
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct StressResult {
    pub epochs: u64,
    pub errors: u64,
    pub min_margin: f32,
    pub temp_range: (f64, f64),
}

/// Run the AL-DRAM-managed system for `epochs` verification epochs; at
/// every epoch the installed timing set is re-verified against the DIMM's
/// charge model at the *current* thermal-model temperature. This is the
/// simulated analogue of "33 days without interruption, no errors".
pub fn stress(dimm_id: usize, epochs: u64, cycles_per_epoch: u64)
              -> anyhow::Result<StressResult> {
    use crate::model::{params, Combo};
    use crate::population::generate_dimm;
    use crate::profiler::profile_dimm;
    use crate::runtime::NativeBackend;

    let d = generate_dimm(dimm_id, 128, params());
    let mut backend = NativeBackend::new();
    let prof = profile_dimm(&mut backend, &d)?;
    let table = AlDram::from_profile(&prof, DEFAULT_BIN_C);

    let w = crate::workloads::by_name("stream.copy").unwrap();
    let cfg = SystemConfig::paper_default()
        .with_aldram(Some(table.clone()));
    let wl: Vec<_> = (0..MULTI_CORES)
        .map(|i| (w.clone(), format!("stress/{i}")))
        .collect();
    let mut sys = System::new(&cfg, &wl);

    let mut errors = 0u64;
    let mut min_margin = f32::INFINITY;
    let mut tmin = f64::MAX;
    let mut tmax = f64::MIN;
    for _ in 0..epochs {
        let stats = sys.run_fast(cycles_per_epoch);
        let temp = stats.mean_temp_c;
        tmin = tmin.min(temp);
        tmax = tmax.max(temp);
        let t = table.timings_for(temp);
        let combo = |tref: f64| Combo {
            trcd: t.trcd_ns as f32,
            tras: t.tras_ns as f32,
            twr: t.twr_ns as f32,
            trp: t.trp_ns as f32,
            tref_ms: tref as f32,
            temp_c: temp as f32,
        };
        let combos = [combo(prof.at55.tref_read_ms),
                      combo(prof.at55.tref_write_ms)];
        let out = crate::runtime::ProfilingBackend::profile(
            &mut backend, &d.arrays, &combos)?;
        errors += (out.read_errors(0) + out.write_errors(1)) as u64;
        let m = out
            .mmin_r
            .iter()
            .chain(out.mmin_w.iter())
            .cloned()
            .fold(f32::INFINITY, f32::min);
        min_margin = min_margin.min(m);
    }
    Ok(StressResult { epochs, errors, min_margin, temp_range: (tmin, tmax) })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params;
    use crate::population::generate_dimm;
    use crate::profiler::profile_dimm;
    use crate::runtime::NativeBackend;

    fn profiles(n: usize) -> Vec<DimmProfile> {
        let mut b = NativeBackend::new();
        (0..n)
            .map(|id| {
                let d = generate_dimm(id, 64, params());
                profile_dimm(&mut b, &d).unwrap()
            })
            .collect()
    }

    #[test]
    fn stress_run_is_error_free() {
        let r = stress(0, 4, 20_000).unwrap();
        assert_eq!(r.errors, 0, "AL-DRAM stress errors");
        assert!(r.min_margin > 0.0);
        assert!(r.temp_range.0 >= 30.0 && r.temp_range.1 <= 85.0);
    }

    #[test]
    fn hetero_modules_all_benefit_with_distinct_channels() {
        let ps = profiles(4);
        let mixes = hetero_eval(30_000, 2, 2, &ps);
        assert_eq!(mixes.len(), 2);
        for m in &mixes {
            assert_eq!(m.mix.len(), 4);
            assert_eq!(m.dimm_ids.len(), 2);
            assert_ne!(m.dimm_ids[0], m.dimm_ids[1],
                       "channels must host distinct modules");
            assert_eq!(m.channel_latency_reduction.len(), 2);
            assert!(m.weighted_speedup > 0.99,
                    "mix {:?} regressed: {}", m.mix, m.weighted_speedup);
            assert!(m.channel_spread >= 0.0);
            // Every managed channel actually engaged its table.
            for (ch, sw) in m.channel_switches.iter().enumerate() {
                assert!(*sw >= 1, "channel {ch} never switched timings");
            }
        }
    }

    #[test]
    fn region_indexing_never_hurts_and_placement_runs() {
        // Region bins are per-parameter <= the module collapse, so the
        // region-indexed run can only speed channels up relative to the
        // uniform run (modulo cycle quantization — hence the tolerance).
        let mut b = NativeBackend::new();
        let ps: Vec<_> = (0..4)
            .map(|id| {
                let d = generate_dimm(id, 64, params());
                crate::profiler::profile_dimm_regions(&mut b, &d, 2).unwrap()
            })
            .collect();
        let mixes = hetero_eval_regions(30_000, 2, 2, &ps, true);
        assert_eq!(mixes.len(), 2);
        for m in &mixes {
            assert_eq!(m.mix.len(), 4);
            assert_ne!(m.dimm_ids[0], m.dimm_ids[1]);
            assert!(m.ws_uniform > 0.99,
                    "uniform run regressed on {:?}: {}", m.mix, m.ws_uniform);
            assert!(m.ws_region >= m.ws_uniform - 5e-3,
                    "region indexing hurt {:?}: {} vs {}", m.mix,
                    m.ws_region, m.ws_uniform);
            assert_eq!(m.delta, m.ws_region - m.ws_uniform);
            let wp = m.ws_placement.expect("placement run requested");
            assert!(wp > 0.99, "placement regressed on {:?}: {wp}", m.mix);
        }
    }

    #[test]
    fn profiled_fig4_beats_baseline_on_intensive_workloads() {
        let ps = profiles(1);
        let table = AlDram::from_profile(&ps[0], DEFAULT_BIN_C);
        let r = fig4_profiled(20_000, 1, &table, 2);
        assert_eq!(r.per_workload.len(), 35);
        assert!(r.gmean_intensive_multi > 1.0,
                "profiled table bought nothing: {}",
                r.gmean_intensive_multi);
        assert!(r.gmean_intensive_multi > r.gmean_nonintensive_multi);
    }

    #[test]
    fn profiled_sensitivity_helps_in_every_config() {
        let ps = profiles(2);
        for row in sensitivity_profiled(30_000, &ps, 2) {
            assert!(row.gmean_speedup > 1.0,
                    "profiled AL-DRAM must help in {}: {}", row.label,
                    row.gmean_speedup);
        }
    }

    #[test]
    fn timeskip_driver_matches_cycle_stepped_on_fig4() {
        // Eval-level equivalence on top of the system-level matrix in
        // tests/integration_timeskip.rs: the whole Fig-4 reduction is
        // bit-identical across drivers.
        let seq = fig4_jobs_with(3_000, 1, PAPER_REDUCTIONS_55C, 1,
                                 Driver::CycleStepped);
        let fast = fig4_jobs_with(3_000, 1, PAPER_REDUCTIONS_55C, 1,
                                  Driver::TimeSkip);
        for (a, b) in seq.per_workload.iter().zip(&fast.per_workload) {
            assert_eq!(a.single_speedup, b.single_speedup, "{}", a.name);
            assert_eq!(a.multi_speedup, b.multi_speedup, "{}", a.name);
        }
        assert_eq!(seq.gmean_intensive_multi, fast.gmean_intensive_multi);
        assert_eq!(seq.mean_all_multi, fast.mean_all_multi);
    }

    #[test]
    fn parallel_fig4_matches_sequential() {
        // The determinism contract of the execution engine: the job-pool
        // fan-out must be bit-identical to the sequential path at fixed
        // seeds, for every statistic.
        let seq = fig4_jobs(3_000, 2, PAPER_REDUCTIONS_55C, 1);
        let par = fig4_jobs(3_000, 2, PAPER_REDUCTIONS_55C, 4);
        assert_eq!(seq.per_workload.len(), par.per_workload.len());
        for (a, b) in seq.per_workload.iter().zip(&par.per_workload) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.single_speedup, b.single_speedup, "{}", a.name);
            assert_eq!(a.single_stddev, b.single_stddev, "{}", a.name);
            assert_eq!(a.multi_speedup, b.multi_speedup, "{}", a.name);
            assert_eq!(a.multi_stddev, b.multi_stddev, "{}", a.name);
        }
        assert_eq!(seq.gmean_intensive_multi, par.gmean_intensive_multi);
        assert_eq!(seq.gmean_nonintensive_multi, par.gmean_nonintensive_multi);
        assert_eq!(seq.mean_all_multi, par.mean_all_multi);
        assert_eq!(seq.max_multi, par.max_multi);
    }

    #[test]
    fn parallel_sensitivity_matches_sequential() {
        let seq = sensitivity_jobs(5_000, PAPER_REDUCTIONS_55C, 1);
        let par = sensitivity_jobs(5_000, PAPER_REDUCTIONS_55C, 4);
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.gmean_speedup, b.gmean_speedup, "{}", a.label);
        }
    }

    #[test]
    fn intensive_beats_nonintensive() {
        // Small-cycle smoke of the Fig-4 machinery on two workloads.
        let base = TimingParams::ddr3_standard();
        let fast = base.reduced(0.27, 0.32, 0.33, 0.18);
        let cfg = SystemConfig::paper_default();
        let hi = crate::workloads::by_name("gups").unwrap();
        let lo = crate::workloads::by_name("povray").unwrap();
        let (s_hi, _) = speedup(&hi, 2, base, fast, 60_000, 1, &cfg);
        let (s_lo, _) = speedup(&lo, 2, base, fast, 60_000, 1, &cfg);
        assert!(s_hi > s_lo, "gups {s_hi} <= povray {s_lo}");
        assert!(s_lo > 0.95, "non-intensive should be ~flat, got {s_lo}");
    }
}
