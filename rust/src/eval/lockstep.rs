//! Lockstep multi-config simulation: run K config-variant `System`s over
//! *one* shared generation of each workload's request stream.
//!
//! Every headline grid (Fig 4, Fig 6, §8.4 sensitivity) simulates the
//! same request stream under several timing configurations. Spawning one
//! independent `System` per (workload, config, rep) cell regenerates the
//! stream — RNG, gap sampling, address synthesis — K times over. The
//! reference sequence a core pulls is timing-independent (timings decide
//! *when* references are pulled, not *what*), and the seed labels the
//! harnesses use carry no config identity, so generation can be shared:
//! each batch is produced once and every config's core reads it through
//! its own cursor.
//!
//! The K systems advance in lockstep over shared chunk boundaries
//! ([`LOCKSTEP_CHUNK`] cycles, a multiple of the thermal epoch so the
//! chunked `run_fast` trajectory is bit-identical to an unchunked run —
//! skips already stop at epoch boundaries). Configs drift apart *within*
//! a chunk (a faster config drains its queues sooner and pulls
//! references earlier), which is safe: a [`StreamBuf`] retains every
//! batch between the laggard's and the leader's cursor and frees the
//! prefix all consumers have passed after each chunk round, so the
//! divergence window — not the run length — bounds buffered memory.
//!
//! Correctness contract (asserted by `tests/integration_lockstep.rs`):
//! for every cell, the lockstep result is bit-identical `SystemStats` —
//! and, with the protocol checker attached, identical audited command
//! counts — to an independent `System` given its own freshly-built
//! sources, under both drivers.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::rc::Rc;

use super::{throughput, Driver};
use crate::check::CheckSummary;
use crate::exec::Pool;
use crate::mem::system::THERMAL_EPOCH;
use crate::mem::{AddrMap, System, SystemConfig, SystemStats};
use crate::workloads::{MemRef, NamedSource, RequestSource, WorkloadSpec};

/// Cycles each system advances per lockstep round. Must be a multiple of
/// [`THERMAL_EPOCH`]: `run_fast` never skips across an epoch boundary,
/// so cutting the run at epoch multiples reproduces the exact step/skip
/// trajectory of an unchunked run (the final partial chunk ends at the
/// caller's horizon, where the unchunked run ends too).
pub const LOCKSTEP_CHUNK: u64 = 8 * THERMAL_EPOCH;

/// One core's shared stream: batches generated once, read by K consumer
/// cursors. Batches the slowest consumer has passed are freed by
/// [`StreamBuf::trim`].
struct StreamBuf {
    source: Box<dyn RequestSource>,
    /// Retained batches; `batches[0]` is batch index `base`.
    batches: VecDeque<Vec<MemRef>>,
    base: usize,
    /// Next batch index per consumer.
    cursors: Vec<usize>,
    exhausted: bool,
}

impl StreamBuf {
    /// Append consumer `id`'s next batch to `out`; generates it on first
    /// demand. Returns the batch length (0 = source exhausted), exactly
    /// the [`RequestSource::fill`] contract the underlying source obeys.
    fn fill_for(&mut self, id: usize, out: &mut Vec<MemRef>) -> usize {
        let c = self.cursors[id];
        if c - self.base == self.batches.len() {
            if self.exhausted {
                return 0;
            }
            let mut batch = Vec::new();
            if self.source.fill(&mut batch) == 0 {
                self.exhausted = true;
                return 0;
            }
            self.batches.push_back(batch);
        }
        let batch = &self.batches[c - self.base];
        out.extend_from_slice(batch);
        self.cursors[id] += 1;
        batch.len()
    }

    /// Free batches every consumer has passed.
    fn trim(&mut self) {
        let min = self.cursors.iter().copied().min().unwrap_or(self.base);
        while self.base < min {
            self.batches.pop_front();
            self.base += 1;
        }
    }
}

/// One consumer's view of a [`StreamBuf`] — what each lockstep system's
/// core holds as its `RequestSource`.
struct SharedStream {
    buf: Rc<RefCell<StreamBuf>>,
    id: usize,
}

impl RequestSource for SharedStream {
    fn fill(&mut self, out: &mut Vec<MemRef>) -> usize {
        self.buf.borrow_mut().fill_for(self.id, out)
    }
}

/// A workload's source set shared across the K lockstep systems: one
/// [`StreamBuf`] per core, with each system registered as one consumer
/// over all of them.
pub struct SharedSourceSet {
    bufs: Vec<Rc<RefCell<StreamBuf>>>,
    meta: Vec<(String, String, u64)>,
}

impl SharedSourceSet {
    pub fn new(sources: Vec<NamedSource>) -> Self {
        let meta = sources
            .iter()
            .map(|s| (s.name.clone(), s.seed.clone(), s.footprint))
            .collect();
        let bufs = sources
            .into_iter()
            .map(|s| {
                Rc::new(RefCell::new(StreamBuf {
                    source: s.source,
                    batches: VecDeque::new(),
                    base: 0,
                    cursors: Vec::new(),
                    exhausted: false,
                }))
            })
            .collect();
        SharedSourceSet { bufs, meta }
    }

    /// Register one more consumer and hand back its per-core sources —
    /// same names/seeds/footprints as the originals, so the consuming
    /// `System` carries identical source identity to an independent one.
    pub fn consumer(&self) -> Vec<NamedSource> {
        self.bufs
            .iter()
            .zip(&self.meta)
            .map(|(buf, (name, seed, footprint))| {
                let id = {
                    let mut b = buf.borrow_mut();
                    b.cursors.push(b.base);
                    b.cursors.len() - 1
                };
                NamedSource {
                    name: name.clone(),
                    seed: seed.clone(),
                    footprint: *footprint,
                    source: Box::new(SharedStream { buf: buf.clone(), id }),
                }
            })
            .collect()
    }

    /// Free batches every consumer has passed (called between rounds).
    pub fn trim(&self) {
        for buf in &self.bufs {
            buf.borrow_mut().trim();
        }
    }
}

/// Run K config-variant systems over one shared generation of `sources`,
/// advancing them in lockstep chunks; returns per-config stats (and the
/// conformance summary when `check` attached the protocol checker) in
/// config order. Each `(SystemConfig, AddrMap)` cell gets its own
/// `System`; results are bit-identical to running each independently.
pub fn run_cells(cells: &[(SystemConfig, AddrMap)],
                 sources: Vec<NamedSource>, cycles: u64, driver: Driver,
                 check: bool) -> Vec<(SystemStats, Option<CheckSummary>)> {
    let shared = SharedSourceSet::new(sources);
    let mut systems: Vec<System> = cells
        .iter()
        .map(|(cfg, map)| {
            let mut sys =
                System::with_sources_map(cfg, *map, shared.consumer());
            if check {
                sys.enable_check();
            }
            sys
        })
        .collect();
    let mut left = cycles;
    while left > 0 {
        let span = LOCKSTEP_CHUNK.min(left);
        for sys in &mut systems {
            match driver {
                Driver::CycleStepped => {
                    sys.run(span);
                }
                Driver::TimeSkip => {
                    sys.run_fast(span);
                }
            }
        }
        shared.trim();
        left -= span;
    }
    systems
        .iter()
        .map(|s| (s.stats(), s.check_summary()))
        .collect()
}

/// [`run_cells`] on each config's default address map, stats only.
pub fn run_configs(cfgs: &[SystemConfig], sources: Vec<NamedSource>,
                   cycles: u64, driver: Driver) -> Vec<SystemStats> {
    let cells = default_cells(cfgs);
    run_cells(&cells, sources, cycles, driver, false)
        .into_iter()
        .map(|(s, _)| s)
        .collect()
}

/// Pair each config with its default address map (what `System::new` /
/// `System::with_sources` would derive).
pub fn default_cells(cfgs: &[SystemConfig]) -> Vec<(SystemConfig, AddrMap)> {
    cfgs.iter()
        .map(|c| (c.clone(), AddrMap::ddr3_2gb(c.ranks_per_channel)))
        .collect()
}

/// Grid execution engine: the independent-system oracle (one `System`
/// per cell, one pool job per cell) or the shared-generation lockstep
/// engine (one pool job per (workload, core-config, rep), K systems per
/// job). Both produce bit-identical throughput vectors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    Independent,
    Lockstep,
}

/// Fig-4-style throughput grid over arbitrary config sets: every
/// (workload, core-config, rep) cell runs under all K configs with the
/// harness-standard seed labels (`rep{rep}/core{c}`). Returns the flat
/// SoA throughput vector indexed
/// `(((wi * core_cfgs.len() + cc) * reps + rep) * K + k)` — config-minor,
/// so a cell's K variants are adjacent and reductions index it exactly
/// like the historical per-job layout.
#[allow(clippy::too_many_arguments)]
pub fn grid(cfgs: &[SystemConfig], workloads: &[WorkloadSpec],
            core_cfgs: &[usize], cycles: u64, reps: usize, jobs: usize,
            driver: Driver, engine: Engine) -> Vec<f64> {
    let k = cfgs.len();
    match engine {
        Engine::Independent => {
            let n_jobs = workloads.len() * core_cfgs.len() * reps * k;
            Pool::new(jobs).run(n_jobs, |i| {
                let ki = i % k;
                let rep = (i / k) % reps;
                let cc = (i / (k * reps)) % core_cfgs.len();
                let wi = i / (k * reps * core_cfgs.len());
                super::run_config(&workloads[wi], core_cfgs[cc], &cfgs[ki],
                                  cycles, rep, driver)
            })
        }
        Engine::Lockstep => {
            let cells = default_cells(cfgs);
            let n_jobs = workloads.len() * core_cfgs.len() * reps;
            let per_cell: Vec<Vec<f64>> = Pool::new(jobs).run(n_jobs, |i| {
                let rep = i % reps;
                let cc = (i / reps) % core_cfgs.len();
                let wi = i / (reps * core_cfgs.len());
                let sources = (0..core_cfgs[cc])
                    .map(|c| workloads[wi]
                         .named_source(&format!("rep{rep}/core{c}")))
                    .collect();
                run_cells(&cells, sources, cycles, driver, false)
                    .into_iter()
                    .map(|(s, _)| throughput(&s))
                    .collect()
            });
            per_cell.into_iter().flatten().collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::PAPER_REDUCTIONS_55C;
    use crate::timing::TimingParams;
    use crate::workloads::by_name;

    fn two_cfgs() -> Vec<SystemConfig> {
        let fast = TimingParams::ddr3_standard().reduced(
            PAPER_REDUCTIONS_55C[0], PAPER_REDUCTIONS_55C[1],
            PAPER_REDUCTIONS_55C[2], PAPER_REDUCTIONS_55C[3]);
        vec![SystemConfig::paper_default(),
             SystemConfig::paper_default().with_timings(fast)]
    }

    #[test]
    fn shared_streams_replay_the_generator_stream() {
        // Two consumers at different paces read byte-identical streams,
        // equal to a fresh independent source.
        let w = by_name("gups").unwrap();
        let shared = SharedSourceSet::new(vec![w.named_source("ls")]);
        let (a, b) = {
            let mut cs = shared.consumer();
            let mut ds = shared.consumer();
            (cs.remove(0), ds.remove(0))
        };
        let mut sa = a.source;
        let mut sb = b.source;
        let mut va = Vec::new();
        let mut vb = Vec::new();
        for round in 0..6 {
            assert!(sa.fill(&mut va) > 0);
            if round % 2 == 0 {
                // Consumer B lags by every other batch.
                assert!(sb.fill(&mut vb) > 0);
            }
            shared.trim();
        }
        while vb.len() < va.len() {
            assert!(sb.fill(&mut vb) > 0);
        }
        let mut fresh = w.source("ls");
        let mut vf = Vec::new();
        while vf.len() < va.len() {
            assert!(fresh.fill(&mut vf) > 0);
        }
        let key = |r: &MemRef| (r.gap_insts, r.addr, r.is_write, r.dependent);
        assert_eq!(va.iter().map(key).collect::<Vec<_>>(),
                   vf[..va.len()].iter().map(key).collect::<Vec<_>>());
        assert_eq!(va.iter().map(key).collect::<Vec<_>>(),
                   vb.iter().map(key).collect::<Vec<_>>());
    }

    #[test]
    fn trim_frees_fully_consumed_batches() {
        let w = by_name("stream.copy").unwrap();
        let shared = SharedSourceSet::new(vec![w.named_source("tr")]);
        let mut a = shared.consumer().remove(0).source;
        let mut b = shared.consumer().remove(0).source;
        let mut sink = Vec::new();
        for _ in 0..8 {
            a.fill(&mut sink);
        }
        shared.trim();
        assert_eq!(shared.bufs[0].borrow().batches.len(), 8,
                   "laggard pins every batch");
        for _ in 0..8 {
            b.fill(&mut sink);
        }
        shared.trim();
        let buf = shared.bufs[0].borrow();
        assert_eq!(buf.batches.len(), 0, "caught-up buffers are freed");
        assert_eq!(buf.base, 8);
    }

    #[test]
    fn lockstep_grid_matches_independent_grid() {
        let cfgs = two_cfgs();
        let w = vec![by_name("gups").unwrap(), by_name("povray").unwrap()];
        let a = grid(&cfgs, &w, &[1, 2], 6_000, 2, 2, Driver::TimeSkip,
                     Engine::Independent);
        let b = grid(&cfgs, &w, &[1, 2], 6_000, 2, 2, Driver::TimeSkip,
                     Engine::Lockstep);
        assert_eq!(a, b, "lockstep grid must be bit-identical");
    }

    #[test]
    fn lockstep_grid_is_jobs_invariant() {
        let cfgs = two_cfgs();
        let w = vec![by_name("mcf").unwrap()];
        let one = grid(&cfgs, &w, &[2], 6_000, 2, 1, Driver::TimeSkip,
                       Engine::Lockstep);
        let four = grid(&cfgs, &w, &[2], 6_000, 2, 4, Driver::TimeSkip,
                        Engine::Lockstep);
        assert_eq!(one, four, "grid must be identical for any --jobs");
    }
}
