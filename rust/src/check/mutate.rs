//! Mutation harness: proves the protocol checker is *sensitive*, not
//! vacuously green. Each `GateMutation` (see `mem::dram`) shortens one
//! controller timing gate by `MUTATION_SLACK` cycles — or corrupts the
//! region lookup / refresh cadence — and the harness asserts the checker
//! flags every mutant while the unmutated baseline stays clean.
//!
//! The adversarial configuration is chosen so every gate actually
//! *binds* during the run (a gate that never constrains scheduling can't
//! produce an observable violation when shortened):
//!
//! * a 2-regions-per-bank table — low rows at the paper's 55degC
//!   operating point, high rows at standard timings (so the region-lookup
//!   mutants misresolve to observably-wrong sets);
//! * two `FuzzSource` cores (bank-conflict storms, boundary hammering,
//!   write/read drain flips, refresh-straddling spread);
//! * long enough (~140k cycles) that the x16 refresh-postponement mutant
//!   overruns the 9 x tREFI bound on its second REF;
//! * for the tFAW mutant only, a widened-tFAW module set under which the
//!   four-ACT window genuinely constrains scheduling — see
//!   [`stress_timings`] for the reachability analysis.
//!
//! Note `tRC` has no mutant: in this controller tRC = tRAS + tRP exactly,
//! so the tRC gate is redundant with the tRAS and tRP gates it follows —
//! shortening it alone can never change the command stream. The checker
//! still audits tRC (the coverage matrix shows it exercised); there is
//! simply no single-gate mutation that violates only it.

use anyhow::{ensure, Result};

use crate::aldram::{AlDram, RegionTable};
use crate::exec::Pool;
use crate::mem::address::AddrMap;
use crate::mem::dram::GateMutation;
use crate::mem::system::{ChannelConfig, System, SystemConfig};
use crate::timing::TimingParams;
use crate::workloads::fuzz::FuzzSource;

use super::{CheckSummary, Violation};

/// Long enough for two REFs under the x16 postponement mutant.
pub const DEFAULT_CYCLES: u64 = 140_000;

/// Every seeded mutant, one per perturbable gate / lookup.
pub fn mutants() -> Vec<GateMutation> {
    vec![
        GateMutation::Trcd,
        GateMutation::Trp,
        GateMutation::Tras,
        GateMutation::Trrd,
        GateMutation::Tfaw,
        GateMutation::Twr,
        GateMutation::Twtr,
        GateMutation::Trtp,
        GateMutation::Tccd,
        GateMutation::Trfc,
        GateMutation::Turnaround,
        GateMutation::RegionIgnoreRow,
        GateMutation::RegionSwap,
        GateMutation::TrefiPostpone,
    ]
}

/// The harness's per-(bank, region) table: region 0 (low rows) at the
/// paper's 55degC reduced timings, region 1 at the DDR3 standard.
pub fn harness_table() -> RegionTable {
    let std_t = TimingParams::ddr3_standard();
    let fast = std_t.reduced(0.27, 0.32, 0.33, 0.18);
    let map = AddrMap::ddr3_2gb(1);
    let mut entries = Vec::with_capacity(map.banks() * 2);
    for _bank in 0..map.banks() {
        entries.push(AlDram::fixed(fast));
        entries.push(AlDram::fixed(std_t));
    }
    RegionTable::from_regions(map.banks(), 2, entries)
        .expect("harness table is statically valid")
}

/// tFAW stress set: the DDR3 standard with tFAW widened 30 ns -> 60 ns
/// (24 -> 48 cycles at tCK = 1.25 ns); every other parameter keeps its
/// JEDEC value.
///
/// Reachability: at the JEDEC 24-cycle window this controller can never
/// supply a fifth same-rank ACT inside it. ACT/PRE issue only from a
/// queue head, each head is pinned ~tRCD cycles until its column
/// command retires it, and the rank-level read<->write turnarounds
/// throttle the two heads further — putting a measured >= 29-cycle
/// floor on any same-rank four-ACT span, above tFAW = 24. The gate
/// therefore never binds, and no workload can observe it being
/// weakened (a mutant must be *reachable* to be killable). Auditing the
/// tFAW mutant under a 48-cycle window, well above that structural
/// floor, makes the gate bind constantly; the harness also re-audits
/// the unmutated baseline under this set to prove the stress
/// configuration itself is conformant.
pub fn stress_timings() -> TimingParams {
    let mut t = TimingParams::ddr3_standard();
    t.tfaw_ns = 60.0;
    t.validate().expect("stress set is statically valid");
    t
}

/// The module timing set a given run is audited under: the DDR3
/// standard, except the tFAW mutant which needs [`stress_timings`] for
/// its gate to bind at all.
pub fn module_timings(mutation: Option<GateMutation>) -> TimingParams {
    match mutation {
        Some(GateMutation::Tfaw) => stress_timings(),
        _ => TimingParams::ddr3_standard(),
    }
}

/// One audited adversarial run; `mutation: None` is the baseline.
pub fn run_audit(mutation: Option<GateMutation>, cycles: u64, seed: &str)
                 -> CheckSummary {
    run_audit_with(mutation, cycles, seed, module_timings(mutation))
}

/// [`run_audit`] with an explicit module timing set (the harness uses
/// this to re-audit the clean baseline under [`stress_timings`]).
pub fn run_audit_with(mutation: Option<GateMutation>, cycles: u64,
                      seed: &str, timings: TimingParams) -> CheckSummary {
    let map = AddrMap::ddr3_2gb(1);
    let mut ch = ChannelConfig::profiled_regions(harness_table(), 55.0);
    ch.timings = timings;
    let cfg = SystemConfig::uniform(1, ch);
    let sources = (0..2)
        .map(|i| FuzzSource::named(map, &format!("{seed}/{i}")))
        .collect();
    let mut sys = System::with_sources_map(&cfg, map, sources);
    sys.enable_check();
    sys.set_gate_mutation(mutation);
    sys.run(cycles);
    sys.check_summary().expect("checker was attached")
}

#[derive(Debug, Clone)]
pub struct MutantResult {
    pub mutation: GateMutation,
    pub commands: u64,
    pub violations: u64,
    pub first: Option<Violation>,
}

impl MutantResult {
    pub fn detected(&self) -> bool {
        self.violations > 0
    }
}

#[derive(Debug, Clone)]
pub struct MutationReport {
    pub cycles: u64,
    pub baseline: CheckSummary,
    /// The unmutated controller re-audited under [`stress_timings`] —
    /// the set the tFAW mutant runs with must itself be conformant.
    pub stress_baseline: CheckSummary,
    pub results: Vec<MutantResult>,
}

impl MutationReport {
    pub fn detected(&self) -> usize {
        self.results.iter().filter(|r| r.detected()).count()
    }

    /// The harness's acceptance predicate: clean baselines (standard and
    /// stress sets) AND every mutant caught.
    pub fn all_detected(&self) -> bool {
        self.baseline.violations == 0
            && self.stress_baseline.violations == 0
            && self.results.iter().all(|r| r.detected())
    }

    pub fn require_all_detected(&self) -> Result<()> {
        ensure!(self.baseline.violations == 0,
                "mutation baseline is not clean: {} violation(s) — the \
                 checker disagrees with the unmutated controller",
                self.baseline.violations);
        ensure!(self.stress_baseline.violations == 0,
                "stress-set baseline is not clean: {} violation(s) — the \
                 checker disagrees with the unmutated controller under \
                 the widened-tFAW set",
                self.stress_baseline.violations);
        for r in &self.results {
            ensure!(r.detected(),
                    "mutant {:?} escaped: {} commands, no violations",
                    r.mutation, r.commands);
        }
        Ok(())
    }
}

/// Run the full harness: clean baselines under the standard and stress
/// sets plus every mutant, fanned out over `jobs` workers.
pub fn run_harness(cycles: u64, seed: &str, jobs: usize) -> MutationReport {
    let ms = mutants();
    let runs: Vec<(Option<GateMutation>, TimingParams)> =
        [(None, TimingParams::ddr3_standard()), (None, stress_timings())]
            .into_iter()
            .chain(ms.into_iter().map(|m| (Some(m), module_timings(Some(m)))))
            .collect();
    let summaries = Pool::new(jobs)
        .run(runs.len(), |i| run_audit_with(runs[i].0, cycles, seed,
                                            runs[i].1));
    let mut it = summaries.into_iter();
    let baseline = it.next().expect("baseline run present");
    let stress_baseline = it.next().expect("stress baseline run present");
    let results = runs[2..]
        .iter()
        .zip(it)
        .map(|((m, _), s)| MutantResult {
            mutation: m.expect("mutant runs carry a mutation"),
            commands: s.commands,
            violations: s.violations,
            first: s.sample.first().cloned(),
        })
        .collect();
    MutationReport { cycles, baseline, stress_baseline, results }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn at_least_ten_mutants_and_no_duplicates() {
        let ms = mutants();
        assert!(ms.len() >= 10, "{} mutants", ms.len());
        for (i, a) in ms.iter().enumerate() {
            assert!(!ms[i + 1..].contains(a), "duplicate mutant {a:?}");
        }
    }

    #[test]
    fn stress_set_widens_only_tfaw() {
        let std_t = TimingParams::ddr3_standard();
        let s = stress_timings();
        assert_eq!(s.tfaw_ns, 60.0);
        let mut back = s;
        back.tfaw_ns = std_t.tfaw_ns;
        assert_eq!(back, std_t, "stress set differs beyond tFAW");
        assert_eq!(module_timings(Some(GateMutation::Tfaw)), s);
        assert_eq!(module_timings(Some(GateMutation::Trcd)), std_t);
        assert_eq!(module_timings(None), std_t);
    }

    #[test]
    fn baseline_is_clean_and_a_core_gate_mutant_is_caught() {
        // The full 14-mutant sweep lives in tests/integration_check.rs;
        // this is the cheap smoke: a clean baseline and the most direct
        // mutant (tRCD) at short horizon.
        let base = run_audit(None, 30_000, "smoke");
        assert!(base.commands > 1_000, "harness idle: {} cmds", base.commands);
        assert_eq!(base.violations, 0, "{}", base.line());
        let m = run_audit(Some(GateMutation::Trcd), 30_000, "smoke");
        assert!(m.violations > 0, "tRCD mutant escaped: {}", m.line());
    }
}
