//! Independent JEDEC DDR3 protocol-conformance checker.
//!
//! Every other correctness argument in this repo is self-referential:
//! `run_fast` is verified bit-identical to `run()`, but both drive the
//! same `Controller` gates, so a systematic gate bug passes every
//! equivalence test. This module audits the *command stream* instead: a
//! tap in `mem::controller` reports each issued ACT/RD/WR/PRE/REF, and
//! `ProtocolChecker` re-derives the inter-command constraints (tRCD,
//! tRP, tRAS, tRC, tRRD, tFAW, tWR, tWTR, tRTP, tCCD, tRFC, tREFI, bus
//! turnaround) from the active `TimingParams` alone — it shares *no*
//! gate code with `Controller` and never looks at its deadlines.
//!
//! Constraint windows are baked from the timing set live at each
//! command's issue cycle (the tap forwards `set_timings` /
//! `set_region_timings` in stream order), mirroring how a real
//! controller applies a timing update: in-flight windows keep the old
//! values. The ns->cycle quantization deliberately re-implements the
//! same documented rounding rule as `TimingParams::to_cycles`
//! (`ceil(ns/tck - 1e-9)`) — a checker that rounded differently would
//! flag conforming streams.
//!
//! The hot path is allocation-free: per-bank/rank state is fixed-size,
//! the violation sample vector is pre-reserved (overflow only counts),
//! and coverage counters are flat arrays. Only a region-table install
//! (thermal-epoch rate) allocates.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Result};

use crate::mem::controller::{Cmd, CmdKind, CmdSink};
use crate::timing::TimingParams;

pub mod cmd_trace;
pub mod mutate;

/// The audited inter-command constraints. `Structural` covers command
/// legality that is not a timing window (ACT to an open bank, column to
/// a closed/wrong row, REF with open banks, PRE on an idle bank).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Constraint {
    Trcd,
    Trp,
    Tras,
    Trc,
    Trrd,
    Tfaw,
    Twr,
    Twtr,
    Trtp,
    Tccd,
    Trfc,
    Trefi,
    Turnaround,
    Structural,
}

pub const N_CONSTRAINTS: usize = 14;

impl Constraint {
    pub const ALL: [Constraint; N_CONSTRAINTS] = [
        Constraint::Trcd, Constraint::Trp, Constraint::Tras, Constraint::Trc,
        Constraint::Trrd, Constraint::Tfaw, Constraint::Twr, Constraint::Twtr,
        Constraint::Trtp, Constraint::Tccd, Constraint::Trfc,
        Constraint::Trefi, Constraint::Turnaround, Constraint::Structural,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Constraint::Trcd => "tRCD",
            Constraint::Trp => "tRP",
            Constraint::Tras => "tRAS",
            Constraint::Trc => "tRC",
            Constraint::Trrd => "tRRD",
            Constraint::Tfaw => "tFAW",
            Constraint::Twr => "tWR",
            Constraint::Twtr => "tWTR",
            Constraint::Trtp => "tRTP",
            Constraint::Tccd => "tCCD",
            Constraint::Trfc => "tRFC",
            Constraint::Trefi => "tREFI",
            Constraint::Turnaround => "RD->WR",
            Constraint::Structural => "structural",
        }
    }

    #[inline]
    fn idx(self) -> usize {
        self as usize
    }
}

/// One detected conformance violation, with full command context.
#[derive(Debug, Clone)]
pub struct Violation {
    pub constraint: Constraint,
    pub kind: CmdKind,
    pub rank: usize,
    pub bank: usize,
    pub row: u64,
    pub cycle: u64,
    /// Earliest cycle the command would have been legal (0 for
    /// structural violations).
    pub earliest: u64,
    pub detail: &'static str,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f,
               "{} violation: {} rank {} bank {} row {:#x} at cycle {} \
                (earliest legal {}; {})",
               self.constraint.name(), self.kind.name(), self.rank,
               self.bank, self.row, self.cycle, self.earliest, self.detail)
    }
}

/// Independent cycle-domain timing set. Deliberately *not*
/// `timing::TimingCycles`: the conversion is re-derived here from the ns
/// fields so a quantization bug in `timing/` cannot silently agree with
/// itself (the rounding *rule* is the same by spec — see module docs).
#[derive(Debug, Clone, Copy)]
struct CkTimings {
    trcd: u64,
    tras: u64,
    trp: u64,
    trc: u64,
    trrd: u64,
    tfaw: u64,
    twr: u64,
    twtr: u64,
    trtp: u64,
    tccd: u64,
    tcl: u64,
    tcwl: u64,
    tburst: u64,
    trfc: u64,
    trefi: u64,
}

impl CkTimings {
    fn from_ns(p: &TimingParams, tck: f64) -> Self {
        let c = |ns: f64| ((ns / tck - 1e-9).ceil()).max(0.0) as u64;
        CkTimings {
            trcd: c(p.trcd_ns),
            tras: c(p.tras_ns),
            trp: c(p.trp_ns),
            trc: c(p.tras_ns + p.trp_ns),
            trrd: c(p.trrd_ns),
            tfaw: c(p.tfaw_ns),
            twr: c(p.twr_ns),
            twtr: c(p.twtr_ns),
            trtp: c(p.trtp_ns),
            tccd: c(p.tccd_ns),
            tcl: c(p.tcl_ns),
            tcwl: c(p.tcwl_ns),
            tburst: c(p.tburst_ns),
            trfc: c(p.trfc_ns),
            trefi: c(p.trefi_us * 1000.0),
        }
    }
}

/// Per-(bank, row-region) sets plus the checker's own region lookup
/// (again re-derived: `row >> shift`, clamped to the last region).
#[derive(Debug, Clone)]
struct CkRegion {
    regions_per_bank: usize,
    shift: u32,
    t: Vec<CkTimings>,
}

impl CkRegion {
    #[inline]
    fn region_of(&self, row: u64) -> usize {
        ((row >> self.shift) as usize).min(self.regions_per_bank - 1)
    }
}

/// Per-bank audit state: the open row plus the constraint windows baked
/// when each predecessor command was observed.
#[derive(Debug, Clone, Copy)]
struct BankAudit {
    open_row: Option<u64>,
    /// ACT + tRCD: earliest column command.
    col_ok: u64,
    /// ACT + tRAS: earliest PRE (row-restore component).
    pre_ok_ras: u64,
    /// last RD + tRTP: earliest PRE (read-to-precharge component).
    pre_ok_rtp: u64,
    /// last WR data end + tWR: earliest PRE (write-recovery component).
    pre_ok_wr: u64,
    /// ACT + tRC: earliest next ACT (cycle-time component).
    act_ok_trc: u64,
    /// PRE + tRP: earliest next ACT (precharge component).
    act_ok_trp: u64,
}

impl BankAudit {
    fn new() -> Self {
        BankAudit { open_row: None, col_ok: 0, pre_ok_ras: 0, pre_ok_rtp: 0,
                    pre_ok_wr: 0, act_ok_trc: 0, act_ok_trp: 0 }
    }
}

/// Per-rank audit state: rank-shared gates (tRRD, tFAW, data bus,
/// turnaround, refresh) plus the banks.
#[derive(Debug, Clone)]
struct RankAudit {
    banks: Vec<BankAudit>,
    /// last ACT + tRRD.
    act_ok_any: u64,
    /// Rolling window of the last four ACT cycles (tFAW).
    faw: [u64; 4],
    faw_len: usize,
    faw_head: usize,
    /// Earliest cycle the shared data bus is free.
    data_free: u64,
    /// last RD + tCCD.
    rd_ok_ccd: u64,
    /// last WR data end + tWTR.
    rd_ok_wtr: u64,
    /// last WR + tCCD.
    wr_ok_ccd: u64,
    /// last RD + (tCL + tBURST + 2 - tCWL): read->write bus turnaround.
    wr_ok_turn: u64,
    /// last REF + tRFC: no command before this.
    ref_fence: u64,
    /// Cycle of the last REF (tREFI postponement bound).
    last_ref: u64,
    refs: u64,
}

impl RankAudit {
    fn new(banks: usize) -> Self {
        RankAudit {
            banks: vec![BankAudit::new(); banks],
            act_ok_any: 0,
            faw: [0; 4],
            faw_len: 0,
            faw_head: 0,
            data_free: 0,
            rd_ok_ccd: 0,
            rd_ok_wtr: 0,
            wr_ok_ccd: 0,
            wr_ok_turn: 0,
            ref_fence: 0,
            last_ref: 0,
            refs: 0,
        }
    }
}

/// How many violation records are kept verbatim (the count is exact
/// regardless).
pub const MAX_VIOLATION_SAMPLE: usize = 32;

/// JEDEC allows postponing up to 8 REF commands, i.e. the gap between
/// consecutive REFs may not exceed 9 x tREFI.
pub const TREFI_POSTPONE_LIMIT: u64 = 9;

pub struct ProtocolChecker {
    ranks: Vec<RankAudit>,
    row_bits: u32,
    tck: f64,
    module: CkTimings,
    region: Option<CkRegion>,
    refresh_scale: f64,
    commands: u64,
    n_violations: u64,
    sample: Vec<Violation>,
    /// Check counts, `checks[rank * N_CONSTRAINTS + c]`: how often each
    /// constraint was actually evaluated against a live predecessor
    /// window on that rank (the coverage matrix).
    checks: Vec<u64>,
    /// Region-lookup counts per region index (across ranks/banks).
    region_hits: Vec<u64>,
}

impl ProtocolChecker {
    pub fn new(ranks: usize, banks: usize, row_bits: u32, tck: f64) -> Self {
        ProtocolChecker {
            ranks: (0..ranks).map(|_| RankAudit::new(banks)).collect(),
            row_bits,
            tck,
            module: CkTimings::from_ns(&TimingParams::ddr3_standard(), tck),
            region: None,
            refresh_scale: 1.0,
            commands: 0,
            n_violations: 0,
            sample: Vec::with_capacity(MAX_VIOLATION_SAMPLE),
            checks: vec![0; ranks * N_CONSTRAINTS],
            region_hits: Vec::new(),
        }
    }

    pub fn commands(&self) -> u64 {
        self.commands
    }

    pub fn violations(&self) -> u64 {
        self.n_violations
    }

    pub fn sample(&self) -> &[Violation] {
        &self.sample
    }

    /// Total times `c` was evaluated against a live window, over all
    /// ranks.
    pub fn checked(&self, c: Constraint) -> u64 {
        (0..self.ranks.len())
            .map(|r| self.checks[r * N_CONSTRAINTS + c.idx()])
            .sum()
    }

    pub fn exercised(&self, c: Constraint) -> bool {
        self.checked(c) > 0
    }

    /// Region-lookup counts per region index (empty when no region table
    /// was ever installed).
    pub fn region_hits(&self) -> &[u64] {
        &self.region_hits
    }

    #[allow(clippy::too_many_arguments)]
    fn violate(&mut self, c: Constraint, cmd: CmdKind, rank: usize,
               bank: usize, row: u64, cycle: u64, earliest: u64,
               detail: &'static str) {
        self.n_violations += 1;
        if self.sample.len() < MAX_VIOLATION_SAMPLE {
            self.sample.push(Violation {
                constraint: c, kind: cmd, rank, bank, row, cycle, earliest,
                detail,
            });
        }
    }

    /// Evaluate one window: counts coverage when a predecessor actually
    /// armed it (earliest > 0), records a violation when `cycle` lands
    /// inside it.
    #[inline]
    #[allow(clippy::too_many_arguments)]
    fn require(&mut self, c: Constraint, cmd: CmdKind, rank: usize,
               bank: usize, row: u64, cycle: u64, earliest: u64,
               detail: &'static str) {
        if earliest > 0 {
            self.checks[rank * N_CONSTRAINTS + c.idx()] += 1;
        }
        if cycle < earliest {
            self.violate(c, cmd, rank, bank, row, cycle, earliest, detail);
        }
    }

    /// Timing set governing (bank, row): the region entry when a region
    /// table is installed, else the module set — the checker's own
    /// resolution-at-issue-time lookup.
    #[inline]
    fn timings_for(&mut self, bank: usize, row: u64) -> CkTimings {
        match &self.region {
            Some(m) => {
                let r = m.region_of(row);
                self.region_hits[r] += 1;
                m.t[bank * m.regions_per_bank + r]
            }
            None => self.module,
        }
    }

    fn scaled_trefi(&self) -> u64 {
        ((self.module.trefi as f64) * self.refresh_scale).max(1.0) as u64
    }

    pub fn cmd_at(&mut self, kind: CmdKind, rank: usize, bank: usize,
                  row: u64, cycle: u64) {
        self.commands += 1;
        // Structural legality (open/closed/row-match) is evaluated for
        // every command; count it so the coverage matrix reflects that.
        self.checks[rank * N_CONSTRAINTS + Constraint::Structural.idx()] += 1;
        let fence = self.ranks[rank].ref_fence;
        if self.ranks[rank].refs > 0 {
            self.require(Constraint::Trfc, kind, rank, bank, row, cycle,
                         fence, "command inside the tRFC window of a REF");
        }
        match kind {
            CmdKind::Act => self.on_act(rank, bank, row, cycle),
            CmdKind::Read => self.on_col(false, rank, bank, row, cycle),
            CmdKind::Write => self.on_col(true, rank, bank, row, cycle),
            CmdKind::Pre => self.on_pre(rank, bank, row, cycle),
            CmdKind::Ref => self.on_ref(rank, cycle),
        }
    }

    fn on_act(&mut self, rank: usize, bank: usize, row: u64, cycle: u64) {
        let k = CmdKind::Act;
        if self.ranks[rank].banks[bank].open_row.is_some() {
            self.violate(Constraint::Structural, k, rank, bank, row, cycle,
                         0, "ACT to a bank with an open row");
            return;
        }
        let b = self.ranks[rank].banks[bank];
        self.require(Constraint::Trc, k, rank, bank, row, cycle,
                     b.act_ok_trc, "ACT inside tRC of the previous ACT");
        self.require(Constraint::Trp, k, rank, bank, row, cycle,
                     b.act_ok_trp, "ACT inside tRP of the previous PRE");
        let act_any = self.ranks[rank].act_ok_any;
        self.require(Constraint::Trrd, k, rank, bank, row, cycle, act_any,
                     "ACT inside tRRD of the previous ACT");
        // tFAW: evaluated with the module set live *now* (the rolling
        // window is a rank-level resource, not a per-row one).
        if self.ranks[rank].faw_len == 4 {
            let oldest = self.ranks[rank].faw[self.ranks[rank].faw_head];
            self.require(Constraint::Tfaw, k, rank, bank, row, cycle,
                         oldest + self.module.tfaw,
                         "fifth ACT inside the tFAW window");
        }
        let t = self.timings_for(bank, row);
        let trrd = self.module.trrd;
        let r = &mut self.ranks[rank];
        let b = &mut r.banks[bank];
        b.open_row = Some(row);
        b.col_ok = cycle + t.trcd;
        b.pre_ok_ras = cycle + t.tras;
        b.act_ok_trc = cycle + t.trc;
        r.act_ok_any = cycle + trrd;
        if r.faw_len == 4 {
            r.faw[r.faw_head] = cycle;
            r.faw_head = (r.faw_head + 1) % 4;
        } else {
            r.faw[(r.faw_head + r.faw_len) % 4] = cycle;
            r.faw_len += 1;
        }
    }

    fn on_col(&mut self, is_write: bool, rank: usize, bank: usize, row: u64,
              cycle: u64) {
        let k = if is_write { CmdKind::Write } else { CmdKind::Read };
        match self.ranks[rank].banks[bank].open_row {
            Some(r) if r == row => {}
            Some(_) => {
                self.violate(Constraint::Structural, k, rank, bank, row,
                             cycle, 0, "column command to the wrong row");
                return;
            }
            None => {
                self.violate(Constraint::Structural, k, rank, bank, row,
                             cycle, 0, "column command to a closed bank");
                return;
            }
        }
        let col_ok = self.ranks[rank].banks[bank].col_ok;
        self.require(Constraint::Trcd, k, rank, bank, row, cycle, col_ok,
                     "column command inside tRCD of the ACT");
        let t = self.timings_for(bank, row);
        let r = &mut self.ranks[rank];
        if is_write {
            let ccd = r.wr_ok_ccd;
            let turn = r.wr_ok_turn;
            self.require(Constraint::Tccd, k, rank, bank, row, cycle, ccd,
                         "WR inside tCCD of the previous WR");
            self.require(Constraint::Turnaround, k, rank, bank, row, cycle,
                         turn, "WR inside the read->write bus turnaround");
            let r = &mut self.ranks[rank];
            let data_end = (cycle + t.tcwl).max(r.data_free) + t.tburst;
            r.data_free = data_end;
            r.wr_ok_ccd = cycle + t.tccd;
            r.rd_ok_wtr = r.rd_ok_wtr.max(data_end + t.twtr);
            let b = &mut r.banks[bank];
            b.pre_ok_wr = b.pre_ok_wr.max(data_end + t.twr);
        } else {
            let ccd = r.rd_ok_ccd;
            let wtr = r.rd_ok_wtr;
            self.require(Constraint::Tccd, k, rank, bank, row, cycle, ccd,
                         "RD inside tCCD of the previous RD");
            self.require(Constraint::Twtr, k, rank, bank, row, cycle, wtr,
                         "RD inside tWTR of the previous WR's data burst");
            let r = &mut self.ranks[rank];
            let data_end = (cycle + t.tcl).max(r.data_free) + t.tburst;
            r.data_free = data_end;
            r.rd_ok_ccd = cycle + t.tccd;
            r.wr_ok_turn = r.wr_ok_turn
                .max(cycle + (t.tcl + t.tburst + 2).saturating_sub(t.tcwl));
            let b = &mut r.banks[bank];
            b.pre_ok_rtp = b.pre_ok_rtp.max(cycle + t.trtp);
        }
    }

    fn on_pre(&mut self, rank: usize, bank: usize, row: u64, cycle: u64) {
        let k = CmdKind::Pre;
        if self.ranks[rank].banks[bank].open_row.is_none() {
            self.violate(Constraint::Structural, k, rank, bank, row, cycle,
                         0, "PRE to an idle bank");
            return;
        }
        let b = self.ranks[rank].banks[bank];
        self.require(Constraint::Tras, k, rank, bank, row, cycle,
                     b.pre_ok_ras, "PRE inside tRAS of the ACT");
        self.require(Constraint::Trtp, k, rank, bank, row, cycle,
                     b.pre_ok_rtp, "PRE inside tRTP of the last RD");
        self.require(Constraint::Twr, k, rank, bank, row, cycle,
                     b.pre_ok_wr, "PRE inside tWR of the last WR's data");
        // tRP resolves through the *closed* row's region (the tap reports
        // it on PRE for exactly this reason).
        let t = self.timings_for(bank, row);
        let b = &mut self.ranks[rank].banks[bank];
        b.open_row = None;
        b.act_ok_trp = cycle + t.trp;
    }

    fn on_ref(&mut self, rank: usize, cycle: u64) {
        let k = CmdKind::Ref;
        let nb = self.ranks[rank].banks.len();
        for bank in 0..nb {
            let b = self.ranks[rank].banks[bank];
            if b.open_row.is_some() {
                self.violate(Constraint::Structural, k, rank, bank,
                             b.open_row.unwrap_or(0), cycle, 0,
                             "REF with a row open");
            } else {
                // A precharged bank must still be tRP-complete.
                self.require(Constraint::Trp, k, rank, bank, 0, cycle,
                             b.act_ok_trp, "REF inside tRP of a PRE");
            }
        }
        // Postponement bound: consecutive REFs no further apart than
        // 9 x (scaled) tREFI. Applied from cycle 0 — JEDEC requires the
        // cadence from init, and the controller seeds its first deadline
        // at one tREFI.
        let gap = cycle - self.ranks[rank].last_ref;
        let limit = TREFI_POSTPONE_LIMIT * self.scaled_trefi();
        self.checks[rank * N_CONSTRAINTS + Constraint::Trefi.idx()] += 1;
        if gap > limit {
            self.violate(Constraint::Trefi, k, rank, 0, 0, cycle,
                         self.ranks[rank].last_ref + limit,
                         "REF gap exceeds the 9 x tREFI postponement bound");
        }
        let trfc = self.module.trfc;
        let r = &mut self.ranks[rank];
        r.last_ref = cycle;
        r.ref_fence = cycle + trfc;
        r.refs += 1;
    }

    /// One-line summary plus the constraint-coverage matrix.
    pub fn report(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        let exercised =
            Constraint::ALL.iter().filter(|c| self.exercised(**c)).count();
        let _ = writeln!(
            s, "commands={} violations={} constraints_exercised={}/{}",
            self.commands, self.n_violations, exercised, N_CONSTRAINTS);
        for c in Constraint::ALL {
            let per_rank: Vec<String> = (0..self.ranks.len())
                .map(|r| self.checks[r * N_CONSTRAINTS + c.idx()].to_string())
                .collect();
            let _ = writeln!(s, "  {:10} checks={:10} per-rank=[{}]",
                             c.name(), self.checked(c), per_rank.join(", "));
        }
        if !self.region_hits.is_empty() {
            let hits: Vec<String> =
                self.region_hits.iter().map(|h| h.to_string()).collect();
            let _ = writeln!(s, "  region lookups per region: [{}]",
                             hits.join(", "));
        }
        for v in &self.sample {
            let _ = writeln!(s, "  {v}");
        }
        s
    }

    pub fn summary(&self) -> CheckSummary {
        let mut checks = [0u64; N_CONSTRAINTS];
        for c in Constraint::ALL {
            checks[c.idx()] = self.checked(c);
        }
        CheckSummary {
            systems: 1,
            commands: self.commands,
            violations: self.n_violations,
            checks,
            region_hits: self.region_hits.clone(),
            sample: self.sample.clone(),
        }
    }
}

impl CmdSink for ProtocolChecker {
    fn cmd(&mut self, c: Cmd) {
        self.cmd_at(c.kind, c.rank as usize, c.bank as usize, c.row, c.cycle);
    }

    fn on_timings(&mut self, t: &TimingParams) {
        self.module = CkTimings::from_ns(t, self.tck);
    }

    fn on_region_timings(&mut self, regions_per_bank: usize,
                         t: Option<&[TimingParams]>) {
        match t {
            None => self.region = None,
            Some(ts) => {
                assert!(regions_per_bank.is_power_of_two());
                let bits = regions_per_bank.trailing_zeros();
                if self.region_hits.len() != regions_per_bank {
                    self.region_hits = vec![0; regions_per_bank];
                }
                self.region = Some(CkRegion {
                    regions_per_bank,
                    shift: self.row_bits - bits,
                    t: ts.iter()
                        .map(|p| CkTimings::from_ns(p, self.tck))
                        .collect(),
                });
            }
        }
    }

    fn on_refresh_scale(&mut self, scale: f64) {
        self.refresh_scale = scale;
    }
}

/// Mergeable audit aggregate (per-`System`, or fleet-wide for the
/// process-global `--check` accumulator).
#[derive(Debug, Clone)]
pub struct CheckSummary {
    pub systems: u64,
    pub commands: u64,
    pub violations: u64,
    pub checks: [u64; N_CONSTRAINTS],
    pub region_hits: Vec<u64>,
    pub sample: Vec<Violation>,
}

impl Default for CheckSummary {
    fn default() -> Self {
        CheckSummary { systems: 0, commands: 0, violations: 0,
                       checks: [0; N_CONSTRAINTS], region_hits: Vec::new(),
                       sample: Vec::new() }
    }
}

impl CheckSummary {
    pub fn merge(&mut self, other: &CheckSummary) {
        self.systems += other.systems;
        self.commands += other.commands;
        self.violations += other.violations;
        for i in 0..N_CONSTRAINTS {
            self.checks[i] += other.checks[i];
        }
        if self.region_hits.len() < other.region_hits.len() {
            self.region_hits.resize(other.region_hits.len(), 0);
        }
        for (i, h) in other.region_hits.iter().enumerate() {
            self.region_hits[i] += h;
        }
        for v in &other.sample {
            if self.sample.len() >= MAX_VIOLATION_SAMPLE {
                break;
            }
            self.sample.push(v.clone());
        }
    }

    pub fn exercised(&self) -> usize {
        self.checks.iter().filter(|c| **c > 0).count()
    }

    /// The `CHECK` summary line printed by `--check` / `repro check`.
    pub fn line(&self) -> String {
        format!("CHECK systems={} commands={} violations={} \
                 constraints_exercised={}/{}",
                self.systems, self.commands, self.violations,
                self.exercised(), N_CONSTRAINTS)
    }
}

// ---- process-global inline audit (`--check` flag) -----------------------
//
// `System::with_sources_map` consults `inline_enabled()` and attaches a
// fresh checker to every controller it builds, so a single flag covers
// every eval/figure path without threading state through each harness.
// Each `System` folds its summary into the global accumulator on drop;
// `report_inline` prints the aggregate and fails the process on any
// violation. `exec::Pool` workers build their Systems on worker threads,
// hence the mutex.

static INLINE: AtomicBool = AtomicBool::new(false);
static AUDIT: Mutex<Option<CheckSummary>> = Mutex::new(None);

pub fn enable_inline() {
    INLINE.store(true, Ordering::SeqCst);
}

pub fn inline_enabled() -> bool {
    INLINE.load(Ordering::SeqCst)
}

/// Fold one System's audit into the global accumulator.
pub fn record_inline(summary: &CheckSummary) {
    let mut audit = AUDIT.lock().unwrap();
    audit.get_or_insert_with(CheckSummary::default).merge(summary);
}

/// Take the accumulated audit (None when nothing was recorded).
pub fn take_inline() -> Option<CheckSummary> {
    AUDIT.lock().unwrap().take()
}

/// End-of-run report for `--check`: print the aggregate `CHECK` line and
/// fail on any violation. No-op when the flag was never enabled.
pub fn report_inline() -> Result<()> {
    if !inline_enabled() {
        return Ok(());
    }
    let Some(audit) = take_inline() else {
        println!("CHECK systems=0 commands=0 violations=0 (no simulations \
                  ran with the checker attached)");
        return Ok(());
    };
    println!("{}", audit.line());
    for v in &audit.sample {
        println!("  {v}");
    }
    if audit.violations > 0 {
        bail!("protocol checker found {} violation(s)", audit.violations);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn checker() -> ProtocolChecker {
        ProtocolChecker::new(1, 8, 15, 1.25)
    }

    // Standard DDR3-1600 cycle values at tCK=1.25 ns: trcd=11 tras=28
    // trp=11 trc=39 trrd=5 tfaw=24 twr=12 twtr=6 trtp=6 tccd=4 tcl=11
    // tcwl=8 tburst=4 trfc=128 trefi=6240.

    #[test]
    fn independent_conversion_matches_timing_module() {
        // Same documented rounding rule — the values must agree or the
        // checker would flag conforming streams.
        let p = TimingParams::ddr3_standard();
        let ours = CkTimings::from_ns(&p, 1.25);
        let theirs = p.to_cycles(1.25);
        assert_eq!(ours.trcd, theirs.trcd as u64);
        assert_eq!(ours.tras, theirs.tras as u64);
        assert_eq!(ours.trp, theirs.trp as u64);
        assert_eq!(ours.trc, theirs.trc as u64);
        assert_eq!(ours.trfc, theirs.trfc as u64);
        assert_eq!(ours.trefi, theirs.trefi as u64);
        let f = p.reduced(0.27, 0.32, 0.33, 0.18);
        let of = CkTimings::from_ns(&f, 1.25);
        let tf = f.to_cycles(1.25);
        assert_eq!(of.trcd, tf.trcd as u64);
        assert_eq!(of.tras, tf.tras as u64);
        assert_eq!(of.trp, tf.trp as u64);
    }

    #[test]
    fn legal_sequence_is_clean() {
        let mut c = checker();
        c.cmd_at(CmdKind::Act, 0, 0, 5, 0);
        c.cmd_at(CmdKind::Read, 0, 0, 5, 11); // tRCD
        c.cmd_at(CmdKind::Read, 0, 0, 5, 15); // tCCD
        c.cmd_at(CmdKind::Pre, 0, 0, 5, 28); // tRAS, tRTP ok
        c.cmd_at(CmdKind::Act, 0, 0, 6, 39); // tRP + tRC
        assert_eq!(c.violations(), 0, "{}", c.report());
        assert!(c.exercised(Constraint::Trcd));
        assert!(c.exercised(Constraint::Tccd));
        assert!(c.exercised(Constraint::Tras));
        assert!(c.exercised(Constraint::Trp));
        assert!(c.exercised(Constraint::Trc));
    }

    #[test]
    fn early_column_read_flags_trcd() {
        let mut c = checker();
        c.cmd_at(CmdKind::Act, 0, 0, 5, 0);
        c.cmd_at(CmdKind::Read, 0, 0, 5, 10); // one early
        assert_eq!(c.violations(), 1);
        assert_eq!(c.sample()[0].constraint, Constraint::Trcd);
        assert_eq!(c.sample()[0].earliest, 11);
    }

    #[test]
    fn early_pre_flags_tras_and_early_act_flags_trp() {
        let mut c = checker();
        c.cmd_at(CmdKind::Act, 0, 0, 5, 0);
        c.cmd_at(CmdKind::Pre, 0, 0, 5, 27);
        assert_eq!(c.violations(), 1);
        assert_eq!(c.sample()[0].constraint, Constraint::Tras);
        // tRP from the (early) PRE at 27: next ACT legal at 38; 37 is
        // early. The tRC window (0+39) flags too.
        c.cmd_at(CmdKind::Act, 0, 0, 6, 37);
        assert_eq!(c.violations(), 3);
        assert!(c.sample().iter().any(|v| v.constraint == Constraint::Trp));
        assert!(c.sample().iter().any(|v| v.constraint == Constraint::Trc));
    }

    #[test]
    fn trrd_and_tfaw_window() {
        let mut c = checker();
        c.cmd_at(CmdKind::Act, 0, 0, 1, 0);
        c.cmd_at(CmdKind::Act, 0, 1, 1, 4); // tRRD=5: early
        assert_eq!(c.violations(), 1);
        assert_eq!(c.sample()[0].constraint, Constraint::Trrd);
        let mut c = checker();
        for (b, t) in [(0u64, 0u64), (1, 5), (2, 10), (3, 15)] {
            c.cmd_at(CmdKind::Act, 0, b as usize, 1, t);
        }
        c.cmd_at(CmdKind::Act, 0, 4, 1, 23); // tFAW=24: one early
        assert!(c.sample().iter().any(|v| v.constraint == Constraint::Tfaw),
                "{}", c.report());
        let mut c = checker();
        for (b, t) in [(0u64, 0u64), (1, 5), (2, 10), (3, 15)] {
            c.cmd_at(CmdKind::Act, 0, b as usize, 1, t);
        }
        c.cmd_at(CmdKind::Act, 0, 4, 1, 24);
        assert_eq!(c.violations(), 0, "{}", c.report());
    }

    #[test]
    fn write_recovery_and_wtr() {
        let mut c = checker();
        c.cmd_at(CmdKind::Act, 0, 0, 5, 0);
        c.cmd_at(CmdKind::Write, 0, 0, 5, 11);
        // data end = 11 + tCWL(8) + tBURST(4) = 23; PRE legal at 23 +
        // tWR(12) = 35, RD legal at 23 + tWTR(6) = 29.
        c.cmd_at(CmdKind::Read, 0, 0, 5, 28);
        assert_eq!(c.violations(), 1);
        assert_eq!(c.sample()[0].constraint, Constraint::Twtr);
        c.cmd_at(CmdKind::Pre, 0, 0, 5, 34);
        assert_eq!(c.violations(), 2);
        assert_eq!(c.sample()[1].constraint, Constraint::Twr);
    }

    #[test]
    fn read_to_write_turnaround() {
        let mut c = checker();
        c.cmd_at(CmdKind::Act, 0, 0, 5, 0);
        c.cmd_at(CmdKind::Read, 0, 0, 5, 11);
        // turnaround = tCL(11) + tBURST(4) + 2 - tCWL(8) = 9.
        c.cmd_at(CmdKind::Write, 0, 0, 5, 19);
        assert_eq!(c.violations(), 1);
        assert_eq!(c.sample()[0].constraint, Constraint::Turnaround);
        assert_eq!(c.sample()[0].earliest, 20);
    }

    #[test]
    fn refresh_fence_and_cadence() {
        let mut c = checker();
        c.cmd_at(CmdKind::Ref, 0, 0, 0, 100);
        c.cmd_at(CmdKind::Act, 0, 0, 1, 100 + 127); // tRFC=128: one early
        assert_eq!(c.violations(), 1);
        assert_eq!(c.sample()[0].constraint, Constraint::Trfc);
        // Postponement bound: 9 x 6240 after the last REF.
        let mut c = checker();
        c.cmd_at(CmdKind::Ref, 0, 0, 0, 6240);
        c.cmd_at(CmdKind::Ref, 0, 0, 0, 6240 + 9 * 6240 + 1);
        assert_eq!(c.violations(), 1, "{}", c.report());
        assert_eq!(c.sample()[0].constraint, Constraint::Trefi);
    }

    #[test]
    fn structural_violations() {
        let mut c = checker();
        c.cmd_at(CmdKind::Read, 0, 0, 5, 0); // closed bank
        c.cmd_at(CmdKind::Act, 0, 0, 5, 100);
        c.cmd_at(CmdKind::Act, 0, 0, 6, 200); // already open
        c.cmd_at(CmdKind::Read, 0, 0, 7, 300); // wrong row
        c.cmd_at(CmdKind::Ref, 0, 0, 0, 400); // row open
        // 600, not 500: the PRE must sit past the REF's tRFC fence
        // (400 + 128) so only the idle-bank violation fires.
        c.cmd_at(CmdKind::Pre, 0, 1, 0, 600); // idle bank
        assert_eq!(c.violations(), 5);
        assert!(c.sample().iter()
            .all(|v| v.constraint == Constraint::Structural));
    }

    #[test]
    fn region_table_scopes_the_windows() {
        let std = TimingParams::ddr3_standard();
        let fast = std.reduced(0.27, 0.32, 0.33, 0.18);
        let mut c = checker();
        // 2 regions x 8 banks: region 0 fast, region 1 standard.
        let mut ts = Vec::new();
        for _ in 0..8 {
            ts.push(fast);
            ts.push(std);
        }
        c.on_region_timings(2, Some(&ts));
        let fast_trcd = CkTimings::from_ns(&fast, 1.25).trcd;
        assert!(fast_trcd < 11);
        // Fast-region row: the reduced tRCD is enough.
        c.cmd_at(CmdKind::Act, 0, 0, 100, 0);
        c.cmd_at(CmdKind::Read, 0, 0, 100, fast_trcd);
        assert_eq!(c.violations(), 0, "{}", c.report());
        // Standard-region row (top bit set): the reduced tRCD is an
        // early column command.
        let slow_row = 1 << 14;
        c.cmd_at(CmdKind::Act, 0, 1, slow_row, 1000);
        c.cmd_at(CmdKind::Read, 0, 1, slow_row, 1000 + fast_trcd);
        assert_eq!(c.violations(), 1);
        assert_eq!(c.sample()[0].constraint, Constraint::Trcd);
        assert_eq!(c.region_hits().len(), 2);
        assert!(c.region_hits()[0] > 0 && c.region_hits()[1] > 0);
    }

    #[test]
    fn timing_switch_applies_to_new_windows_only() {
        // Windows are baked at issue time: an ACT observed under the
        // standard set keeps its tRCD=11 window even if a faster set is
        // installed mid-flight — and vice versa.
        let std = TimingParams::ddr3_standard();
        let fast = std.reduced(0.27, 0.32, 0.33, 0.18);
        let fast_trcd = CkTimings::from_ns(&fast, 1.25).trcd;
        let mut c = checker();
        c.cmd_at(CmdKind::Act, 0, 0, 5, 0);
        c.on_timings(&fast);
        c.cmd_at(CmdKind::Read, 0, 0, 5, fast_trcd); // still under old tRCD
        assert_eq!(c.violations(), 1);
        assert_eq!(c.sample()[0].constraint, Constraint::Trcd);
        assert_eq!(c.sample()[0].earliest, 11);
        // New ACT after the switch uses the fast window.
        let mut c = checker();
        c.on_timings(&fast);
        c.cmd_at(CmdKind::Act, 0, 0, 5, 0);
        c.cmd_at(CmdKind::Read, 0, 0, 5, fast_trcd);
        assert_eq!(c.violations(), 0, "{}", c.report());
    }

    #[test]
    fn summary_merges() {
        let mut a = checker();
        a.cmd_at(CmdKind::Act, 0, 0, 5, 0);
        a.cmd_at(CmdKind::Read, 0, 0, 5, 11);
        let mut b = checker();
        b.cmd_at(CmdKind::Act, 0, 0, 5, 0);
        b.cmd_at(CmdKind::Read, 0, 0, 5, 10); // violation
        let mut total = CheckSummary::default();
        total.merge(&a.summary());
        total.merge(&b.summary());
        assert_eq!(total.systems, 2);
        assert_eq!(total.commands, 4);
        assert_eq!(total.violations, 1);
        assert_eq!(total.sample.len(), 1);
        assert!(total.line().contains("violations=1"));
    }
}
