//! ALCT: a versioned binary command-trace format (sibling of the ALDT
//! request-trace format in `workloads::trace`). Where ALDT records what
//! the cores *asked for*, ALCT records what the controller actually *put
//! on the command bus* — the stream the protocol checker audits — plus
//! the timing-environment events (timing-set installs, region-table
//! installs, refresh-scale changes) needed to re-derive the constraint
//! windows offline.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! header   : b"ALCT"  version:u8  ranks:u8  banks:u8  row_bits:u8  tck:f64
//! CMD      : kind:u8 (0=ACT 1=RD 2=WR 3=PRE 4=REF)  rank:u8  bank:u8
//!            pad:u8=0  row:u32  cycle:u64                      (16 bytes)
//! TIMING   : 5  then 14 x f64 — the TimingParams ns fields in
//!            declaration order (trcd, tras, twr, trp, tcl, tcwl, tccd,
//!            trrd, tfaw, trtp, twtr, trfc, trefi_us, tburst)
//! REGION   : 6  rpb:u8 (0 = table cleared)  count:u16  count x 14 f64
//! SCALE    : 7  then f64
//! footer   : 0xFF  records:u64 (count of CMD/TIMING/REGION/SCALE records)
//! ```

use std::cell::RefCell;
use std::fs;
use std::path::Path;
use std::rc::Rc;

use anyhow::{bail, ensure, Context, Result};

use crate::mem::controller::{Cmd, CmdKind, CmdSink};
use crate::timing::TimingParams;

use super::{CheckSummary, ProtocolChecker};

pub const MAGIC: &[u8; 4] = b"ALCT";
pub const VERSION: u8 = 1;

const TAG_TIMING: u8 = 5;
const TAG_REGION: u8 = 6;
const TAG_SCALE: u8 = 7;
const END_TAG: u8 = 0xFF;
const N_TIMING_FIELDS: usize = 14;

fn kind_tag(k: CmdKind) -> u8 {
    match k {
        CmdKind::Act => 0,
        CmdKind::Read => 1,
        CmdKind::Write => 2,
        CmdKind::Pre => 3,
        CmdKind::Ref => 4,
    }
}

fn tag_kind(t: u8) -> Option<CmdKind> {
    match t {
        0 => Some(CmdKind::Act),
        1 => Some(CmdKind::Read),
        2 => Some(CmdKind::Write),
        3 => Some(CmdKind::Pre),
        4 => Some(CmdKind::Ref),
        _ => None,
    }
}

fn timing_fields(t: &TimingParams) -> [f64; N_TIMING_FIELDS] {
    [t.trcd_ns, t.tras_ns, t.twr_ns, t.trp_ns, t.tcl_ns, t.tcwl_ns,
     t.tccd_ns, t.trrd_ns, t.tfaw_ns, t.trtp_ns, t.twtr_ns, t.trfc_ns,
     t.trefi_us, t.tburst_ns]
}

fn fields_timing(f: &[f64; N_TIMING_FIELDS]) -> TimingParams {
    TimingParams {
        trcd_ns: f[0], tras_ns: f[1], twr_ns: f[2], trp_ns: f[3],
        tcl_ns: f[4], tcwl_ns: f[5], tccd_ns: f[6], trrd_ns: f[7],
        tfaw_ns: f[8], trtp_ns: f[9], twtr_ns: f[10], trfc_ns: f[11],
        trefi_us: f[12], tburst_ns: f[13],
    }
}

/// In-memory ALCT writer. Buffering in memory keeps the `CmdSink`
/// methods infallible (no I/O in the simulation hot path); the file is
/// written once at [`CmdTraceWriter::finish_to`]. A 140k-cycle adversarial
/// run is well under a megabyte of records.
pub struct CmdTraceWriter {
    buf: Vec<u8>,
    records: u64,
}

impl CmdTraceWriter {
    pub fn new(ranks: usize, banks: usize, row_bits: u32, tck: f64) -> Self {
        let mut buf = Vec::with_capacity(1 << 16);
        buf.extend_from_slice(MAGIC);
        buf.push(VERSION);
        buf.push(ranks as u8);
        buf.push(banks as u8);
        buf.push(row_bits as u8);
        buf.extend_from_slice(&tck.to_le_bytes());
        CmdTraceWriter { buf, records: 0 }
    }

    pub fn records(&self) -> u64 {
        self.records
    }

    /// Append the footer and return the completed byte image.
    pub fn finish(mut self) -> Vec<u8> {
        self.buf.push(END_TAG);
        self.buf.extend_from_slice(&self.records.to_le_bytes());
        self.buf
    }

    /// Seal and write the trace; returns the record count.
    pub fn finish_to(self, path: &Path) -> Result<u64> {
        let records = self.records;
        let bytes = self.finish();
        fs::write(path, bytes)
            .with_context(|| format!("writing cmd trace {}", path.display()))?;
        Ok(records)
    }
}

impl CmdSink for CmdTraceWriter {
    fn cmd(&mut self, c: Cmd) {
        self.buf.push(kind_tag(c.kind));
        self.buf.push(c.rank);
        self.buf.push(c.bank);
        self.buf.push(0);
        self.buf.extend_from_slice(&(c.row as u32).to_le_bytes());
        self.buf.extend_from_slice(&c.cycle.to_le_bytes());
        self.records += 1;
    }

    fn on_timings(&mut self, t: &TimingParams) {
        self.buf.push(TAG_TIMING);
        for v in timing_fields(t) {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
        self.records += 1;
    }

    fn on_region_timings(&mut self, regions_per_bank: usize,
                         t: Option<&[TimingParams]>) {
        self.buf.push(TAG_REGION);
        match t {
            None => {
                self.buf.push(0);
                self.buf.extend_from_slice(&0u16.to_le_bytes());
            }
            Some(ts) => {
                self.buf.push(regions_per_bank as u8);
                self.buf.extend_from_slice(&(ts.len() as u16).to_le_bytes());
                for p in ts {
                    for v in timing_fields(p) {
                        self.buf.extend_from_slice(&v.to_le_bytes());
                    }
                }
            }
        }
        self.records += 1;
    }

    fn on_refresh_scale(&mut self, scale: f64) {
        self.buf.push(TAG_SCALE);
        self.buf.extend_from_slice(&scale.to_le_bytes());
        self.records += 1;
    }
}

/// Shared-writer handle for attaching to a controller tap
/// (`Rc<RefCell<dyn CmdSink>>`), mirroring `trace::create_shared`.
pub type SharedCmdWriter = Rc<RefCell<CmdTraceWriter>>;

pub fn create_shared(ranks: usize, banks: usize, row_bits: u32, tck: f64)
                     -> SharedCmdWriter {
    Rc::new(RefCell::new(CmdTraceWriter::new(ranks, banks, row_bits, tck)))
}

/// Seal a shared writer and write the file; returns the record count.
pub fn finish_shared(w: SharedCmdWriter, path: &Path) -> Result<u64> {
    let w = Rc::try_unwrap(w)
        .map_err(|_| anyhow::anyhow!(
            "cmd-trace writer still attached to a live controller"))?
        .into_inner();
    w.finish_to(path)
}

/// Header + validated whole-file statistics (`repro check info`).
#[derive(Debug, Clone)]
pub struct CmdTraceInfo {
    pub version: u8,
    pub ranks: usize,
    pub banks: usize,
    pub row_bits: u32,
    pub tck: f64,
    pub records: u64,
    pub commands: u64,
    pub timing_updates: u64,
    pub region_updates: u64,
    pub scale_updates: u64,
    /// Cycle of the last command (0 for an empty trace).
    pub last_cycle: u64,
}

struct Cursor<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.pos + n <= self.b.len(),
                "cmd trace truncated at byte {} (wanted {} more)",
                self.pos, n);
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn timing(&mut self) -> Result<TimingParams> {
        let mut f = [0.0; N_TIMING_FIELDS];
        for v in &mut f {
            *v = self.f64()?;
        }
        let t = fields_timing(&f);
        for v in timing_fields(&t) {
            ensure!(v.is_finite(), "non-finite timing field in cmd trace");
        }
        Ok(t)
    }
}

/// One parsed event, in stream order.
enum Event {
    Cmd(CmdKind, u8, u8, u64, u64),
    Timings(TimingParams),
    Region(usize, Option<Vec<TimingParams>>),
    Scale(f64),
}

/// Streaming walk over a trace: header checks, then `f` per record, then
/// footer checks (count match, no trailing bytes).
fn walk(bytes: &[u8],
        mut f: impl FnMut(Event, &CmdTraceInfo) -> Result<()>)
        -> Result<CmdTraceInfo> {
    let mut c = Cursor { b: bytes, pos: 0 };
    ensure!(c.take(4)? == MAGIC, "not an ALCT cmd trace (bad magic)");
    let version = c.u8()?;
    ensure!(version == VERSION,
            "unsupported ALCT version {version} (expected {VERSION})");
    let mut info = CmdTraceInfo {
        version,
        ranks: c.u8()? as usize,
        banks: c.u8()? as usize,
        row_bits: c.u8()? as u32,
        tck: c.f64()?,
        records: 0,
        commands: 0,
        timing_updates: 0,
        region_updates: 0,
        scale_updates: 0,
        last_cycle: 0,
    };
    ensure!(info.ranks > 0 && info.banks > 0, "cmd trace has no geometry");
    ensure!(info.tck.is_finite() && info.tck > 0.0,
            "cmd trace tck {} is not a positive clock period", info.tck);
    loop {
        let tag = c.u8()?;
        if tag == END_TAG {
            let footer = c.u64()?;
            ensure!(footer == info.records,
                    "cmd trace footer says {footer} records, file has {}",
                    info.records);
            ensure!(c.pos == bytes.len(),
                    "{} trailing bytes after cmd trace footer",
                    bytes.len() - c.pos);
            return Ok(info);
        }
        let ev = if let Some(kind) = tag_kind(tag) {
            let rank = c.u8()?;
            let bank = c.u8()?;
            let pad = c.u8()?;
            ensure!(pad == 0, "nonzero pad byte in cmd record");
            let row = c.u32()? as u64;
            let cycle = c.u64()?;
            ensure!((rank as usize) < info.ranks,
                    "cmd rank {rank} out of range (trace has {})", info.ranks);
            ensure!((bank as usize) < info.banks,
                    "cmd bank {bank} out of range (trace has {})", info.banks);
            ensure!(row < (1u64 << info.row_bits),
                    "cmd row {row:#x} out of range for {} row bits",
                    info.row_bits);
            ensure!(cycle >= info.last_cycle,
                    "cmd trace not cycle-ordered: {cycle} after {}",
                    info.last_cycle);
            info.last_cycle = cycle;
            info.commands += 1;
            Event::Cmd(kind, rank, bank, row, cycle)
        } else {
            match tag {
                TAG_TIMING => {
                    info.timing_updates += 1;
                    Event::Timings(c.timing()?)
                }
                TAG_REGION => {
                    let rpb = c.u8()? as usize;
                    let count = c.u16()? as usize;
                    info.region_updates += 1;
                    if rpb == 0 {
                        ensure!(count == 0,
                                "cleared region record carries {count} sets");
                        Event::Region(0, None)
                    } else {
                        ensure!(rpb.is_power_of_two(),
                                "regions/bank {rpb} is not a power of two");
                        ensure!(count == rpb * info.banks,
                                "region record has {count} sets, geometry \
                                 needs {} ({} banks x {rpb})",
                                rpb * info.banks, info.banks);
                        let mut ts = Vec::with_capacity(count);
                        for _ in 0..count {
                            ts.push(c.timing()?);
                        }
                        Event::Region(rpb, Some(ts))
                    }
                }
                TAG_SCALE => {
                    let s = c.f64()?;
                    ensure!(s.is_finite() && s > 0.0,
                            "refresh scale {s} must be positive");
                    info.scale_updates += 1;
                    Event::Scale(s)
                }
                t => bail!("unknown cmd-trace record tag {t:#x} at byte {}",
                           c.pos - 1),
            }
        };
        info.records += 1;
        f(ev, &info)?;
    }
}

/// Validate a trace end-to-end and summarize it (`repro check info`).
pub fn info(path: &Path) -> Result<CmdTraceInfo> {
    let bytes = fs::read(path)
        .with_context(|| format!("reading cmd trace {}", path.display()))?;
    walk(&bytes, |_, _| Ok(()))
}

/// Replay a trace through a fresh `ProtocolChecker` built from the
/// header, returning the audit (`repro check replay`).
pub fn replay(path: &Path) -> Result<(CmdTraceInfo, ProtocolChecker, String)> {
    let bytes = fs::read(path)
        .with_context(|| format!("reading cmd trace {}", path.display()))?;
    let mut ck: Option<ProtocolChecker> = None;
    let info = walk(&bytes, |ev, info| {
        let ck = ck.get_or_insert_with(|| {
            ProtocolChecker::new(info.ranks, info.banks, info.row_bits,
                                 info.tck)
        });
        match ev {
            Event::Cmd(kind, rank, bank, row, cycle) => {
                ck.cmd_at(kind, rank as usize, bank as usize, row, cycle)
            }
            Event::Timings(t) => ck.on_timings(&t),
            Event::Region(rpb, ts) => ck.on_region_timings(rpb, ts.as_deref()),
            Event::Scale(s) => ck.on_refresh_scale(s),
        }
        Ok(())
    })?;
    let ck = ck.unwrap_or_else(|| {
        ProtocolChecker::new(info.ranks, info.banks, info.row_bits, info.tck)
    });
    let report = ck.report();
    Ok((info, ck, report))
}

/// Replay and reduce to a summary (library callers / tests).
pub fn replay_summary(path: &Path) -> Result<CheckSummary> {
    let (_, ck, _) = replay(path)?;
    Ok(ck.summary())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::env;

    fn tmp(name: &str) -> std::path::PathBuf {
        env::temp_dir().join(format!("alct_test_{}_{name}", std::process::id()))
    }

    #[test]
    fn golden_header_and_record_bytes() {
        // Pin the on-disk layout: header, one ACT record, footer.
        let mut w = CmdTraceWriter::new(1, 8, 15, 1.25);
        w.cmd(Cmd { kind: CmdKind::Act, rank: 0, bank: 2, row: 5, cycle: 7 });
        let bytes = w.finish();
        let expect: Vec<u8> = [
            // "ALCT", version 1, ranks 1, banks 8, row_bits 15
            &[0x41, 0x4C, 0x43, 0x54, 0x01, 0x01, 0x08, 0x0F][..],
            // tck = 1.25 f64 LE
            &[0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0xF4, 0x3F],
            // ACT rank 0 bank 2 pad, row 5, cycle 7
            &[0x00, 0x00, 0x02, 0x00],
            &[0x05, 0x00, 0x00, 0x00],
            &[0x07, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00],
            // footer: END, 1 record
            &[0xFF, 0x01, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00],
        ]
        .concat();
        assert_eq!(bytes, expect);
    }

    #[test]
    fn round_trip_with_timing_events() {
        let path = tmp("round_trip");
        let std_t = TimingParams::ddr3_standard();
        let fast = std_t.reduced(0.27, 0.32, 0.33, 0.18);
        let mut w = CmdTraceWriter::new(1, 8, 15, 1.25);
        w.on_timings(&std_t);
        w.on_refresh_scale(1.0);
        w.cmd(Cmd { kind: CmdKind::Act, rank: 0, bank: 0, row: 5, cycle: 0 });
        w.cmd(Cmd { kind: CmdKind::Read, rank: 0, bank: 0, row: 5, cycle: 11 });
        w.on_timings(&fast);
        w.cmd(Cmd { kind: CmdKind::Pre, rank: 0, bank: 0, row: 5, cycle: 28 });
        let n = w.finish_to(&path).unwrap();
        assert_eq!(n, 6);

        let i = info(&path).unwrap();
        assert_eq!(i.version, VERSION);
        assert_eq!((i.ranks, i.banks, i.row_bits), (1, 8, 15));
        assert_eq!(i.records, 6);
        assert_eq!(i.commands, 3);
        assert_eq!(i.timing_updates, 2);
        assert_eq!(i.scale_updates, 1);
        assert_eq!(i.last_cycle, 28);

        let s = replay_summary(&path).unwrap();
        assert_eq!(s.commands, 3);
        assert_eq!(s.violations, 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn replay_flags_a_violating_trace() {
        let path = tmp("violating");
        let mut w = CmdTraceWriter::new(1, 8, 15, 1.25);
        w.cmd(Cmd { kind: CmdKind::Act, rank: 0, bank: 0, row: 5, cycle: 0 });
        w.cmd(Cmd { kind: CmdKind::Read, rank: 0, bank: 0, row: 5, cycle: 10 });
        w.finish_to(&path).unwrap();
        let s = replay_summary(&path).unwrap();
        assert_eq!(s.violations, 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn region_records_round_trip_and_scope_replay() {
        let path = tmp("regions");
        let std_t = TimingParams::ddr3_standard();
        let fast = std_t.reduced(0.27, 0.32, 0.33, 0.18);
        let fast_trcd =
            ((fast.trcd_ns / 1.25 - 1e-9).ceil()).max(0.0) as u64;
        let mut ts = Vec::new();
        for _ in 0..8 {
            ts.push(fast); // region 0
            ts.push(std_t); // region 1
        }
        let mut w = CmdTraceWriter::new(1, 8, 15, 1.25);
        w.on_region_timings(2, Some(&ts));
        // Fast-region row is fine at the reduced tRCD; slow-region row
        // (top bit set) at the same offset violates.
        w.cmd(Cmd { kind: CmdKind::Act, rank: 0, bank: 0, row: 100,
                    cycle: 0 });
        w.cmd(Cmd { kind: CmdKind::Read, rank: 0, bank: 0, row: 100,
                    cycle: fast_trcd });
        w.cmd(Cmd { kind: CmdKind::Act, rank: 0, bank: 1, row: 1 << 14,
                    cycle: 1000 });
        w.cmd(Cmd { kind: CmdKind::Read, rank: 0, bank: 1, row: 1 << 14,
                    cycle: 1000 + fast_trcd });
        w.finish_to(&path).unwrap();
        let i = info(&path).unwrap();
        assert_eq!(i.region_updates, 1);
        let s = replay_summary(&path).unwrap();
        assert_eq!(s.violations, 1, "only the slow-region read violates");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn validation_rejects_corrupt_traces() {
        // Bad magic.
        let path = tmp("corrupt");
        std::fs::write(&path, b"NOPE").unwrap();
        assert!(info(&path).is_err());
        // Truncated mid-record: drop the footer and a few bytes.
        let mut w = CmdTraceWriter::new(1, 8, 15, 1.25);
        w.cmd(Cmd { kind: CmdKind::Act, rank: 0, bank: 0, row: 5, cycle: 0 });
        let mut bytes = w.finish();
        bytes.truncate(bytes.len() - 12);
        std::fs::write(&path, &bytes).unwrap();
        assert!(info(&path).is_err());
        // Footer count mismatch.
        let mut w = CmdTraceWriter::new(1, 8, 15, 1.25);
        w.cmd(Cmd { kind: CmdKind::Act, rank: 0, bank: 0, row: 5, cycle: 0 });
        let mut bytes = w.finish();
        let n = bytes.len();
        bytes[n - 8] = 9;
        std::fs::write(&path, &bytes).unwrap();
        assert!(info(&path).is_err());
        // Out-of-range bank.
        let mut w = CmdTraceWriter::new(1, 8, 15, 1.25);
        w.cmd(Cmd { kind: CmdKind::Act, rank: 0, bank: 9, row: 5, cycle: 0 });
        let bytes = w.finish();
        std::fs::write(&path, &bytes).unwrap();
        assert!(info(&path).is_err());
        // Cycle ordering violation.
        let mut w = CmdTraceWriter::new(1, 8, 15, 1.25);
        w.cmd(Cmd { kind: CmdKind::Act, rank: 0, bank: 0, row: 5,
                    cycle: 100 });
        w.cmd(Cmd { kind: CmdKind::Pre, rank: 0, bank: 0, row: 5,
                    cycle: 50 });
        let bytes = w.finish();
        std::fs::write(&path, &bytes).unwrap();
        assert!(info(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
