//! Synthetic DIMM population — the stand-in for the paper's 115 real DDR3
//! modules (920 chips). See DESIGN.md §2 for the substitution argument.
//!
//! Each module is generated from a stable label (`dimm/NNN`) so every
//! experiment (and both profiling backends) sees identical silicon. The
//! three synthetic vendors differ in their sensing-speed and leakage
//! distributions, reproducing the vendor spread visible in Fig. 3.

use crate::model::{params, CellArrays, ModelParams};
use crate::util::rng::Rng;

/// Identity + sampled cells of one synthetic DIMM.
#[derive(Debug, Clone)]
pub struct Dimm {
    pub id: usize,
    pub vendor: String,
    /// Vendor index into `ModelParams::population.vendors`.
    pub vendor_idx: usize,
    pub arrays: CellArrays,
    /// The spatial (design-induced) variation map baked into `arrays`.
    pub spatial: SpatialMap,
}

impl Dimm {
    pub fn label(&self) -> String {
        format!("dimm/{:03}", self.id)
    }
}

/// Design-induced variation map: a per-bank RC multiplier (banks far
/// from the I/O pads are slower) plus a monotone distance-from-sense-amp
/// gradient across the row axis of each bank. Seeded from the DIMM label
/// (stream `dimm/NNN/spatial`) so the map is persisted with the module
/// identity and identical at every sampling resolution.
#[derive(Debug, Clone, PartialEq)]
pub struct SpatialMap {
    pub bank_offset: Vec<f64>,
    /// Fractional RC increase from row-position 0 (at the sense amps) to
    /// row-position 1 (the far edge of the bank).
    pub grad_span: f64,
}

impl SpatialMap {
    pub fn generate(id: usize, p: &ModelParams) -> Self {
        let pop = &p.population;
        let mut rng = Rng::from_label(&format!("dimm/{id:03}/spatial"));
        let bank_offset = (0..p.geometry.banks)
            .map(|_| rng.lognormal(0.0, pop.spatial_bank_sigma))
            .collect();
        SpatialMap { bank_offset, grad_span: pop.spatial_grad_span }
    }

    /// RC multiplier for bank `b` at normalized row position `pos` in
    /// [0, 1). Monotone in `pos` by construction.
    pub fn factor(&self, b: usize, pos: f64) -> f64 {
        self.bank_offset[b] * (1.0 + self.grad_span * pos)
    }
}

/// Assign DIMM `id` to a vendor by the configured market shares —
/// deterministic striping so every population slice is well-mixed.
pub fn vendor_of(id: usize, p: &ModelParams) -> usize {
    let mut rng = Rng::from_label(&format!("vendor-assign/{id}"));
    let x = rng.f64();
    let mut acc = 0.0;
    for (vi, v) in p.population.vendors.iter().enumerate() {
        acc += v.share;
        if x < acc {
            return vi;
        }
    }
    p.population.vendors.len() - 1
}

/// Generate one DIMM's sampled cell arrays at full profiling resolution.
///
/// Per-cell draws (all lognormal, per DESIGN.md §4):
///   tau_s  — sensing RC; vendor-shifted mean.
///   tau_r  — restoration RC, correlated with tau_s (same access path).
///   tau_p  — bitline equalization RC.
///   lam85  — leak rate at 85degC; vendor-shifted; a `weak_frac` mixture
///            tail multiplies lam by U(weak_mult_min, weak_mult_max),
///            modelling the retention-weak outlier cells that set each
///            module's maximum error-free refresh interval (Fig. 2a/3a).
///   qcap   — full-charge capacity, clipped.
pub fn generate_dimm(id: usize, cells_per_chip_bank: usize,
                     p: &ModelParams) -> Dimm {
    let pop = &p.population;
    let vi = vendor_of(id, p);
    let vendor = &pop.vendors[vi];
    let g = &p.geometry;

    let spatial = SpatialMap::generate(id, p);
    let mut arrays = CellArrays::zeroed(g.banks, g.chips, cells_per_chip_bank);
    // One stream per (dimm, bank, chip) so downsampled and full populations
    // share structure and bank-level statistics are independent. Cell j
    // samples normalized row position j/cells, so the spatial gradient is
    // resolution-consistent (downsampling picks src = j*cells/cells_out,
    // preserving the position fraction).
    for b in 0..g.banks {
        for c in 0..g.chips {
            let mut rng = Rng::from_label(&format!("dimm/{id:03}/b{b}/c{c}"));
            for j in 0..cells_per_chip_bank {
                let i = arrays.idx(b, c, j);
                let sf = spatial.factor(b, j as f64 / cells_per_chip_bank as f64);
                let tau_s = rng.lognormal(
                    vendor.mu_ln_tau_s + vendor.tau_shift, pop.sigma_tau_s);
                let tau_r = pop.tau_r_ratio * tau_s
                    * rng.lognormal(0.0, pop.sigma_tau_r);
                let tau_p = rng.lognormal(pop.mu_ln_tau_p, pop.sigma_tau_p);
                let mut lam85 = rng.lognormal(
                    pop.mu_ln_lam85 + vendor.lam_shift, pop.sigma_lam);
                if rng.chance(pop.weak_frac) {
                    lam85 *= rng.range(pop.weak_mult_min, pop.weak_mult_max);
                }
                let qcap = rng
                    .lognormal(0.0, pop.sigma_qcap)
                    .clamp(pop.qcap_clip_lo, pop.qcap_clip_hi);
                arrays.qcap[i] = qcap as f32;
                arrays.tau_s[i] = (tau_s * sf) as f32;
                arrays.tau_r[i] = (tau_r * sf) as f32;
                arrays.tau_p[i] = (tau_p * sf) as f32;
                arrays.lam85[i] = lam85 as f32;
            }
        }
    }
    // One-time weakest-first screening order for the pass-probe fast path
    // (runtime::ProfilingBackend::pass_probe); heuristic only — results
    // never depend on it.
    arrays.compute_screening();
    Dimm { id, vendor: vendor.name.clone(), vendor_idx: vi, arrays, spatial }
}

/// One manufacturer/speed-bin archetype of the fleet model: a module
/// design that a datacenter bought by the pallet, so thousands of nodes
/// carry *the same* silicon characterization target. Fleet nodes sample
/// an archetype index, and the archetype's module silicon is simply
/// `generate_dimm(dimm_id, …)` — identical content for every node of the
/// bin, which is what makes the fleet's content-keyed profile cache hit.
#[derive(Debug, Clone, PartialEq)]
pub struct Archetype {
    /// Index into the catalog (what fleet nodes sample).
    pub idx: usize,
    pub vendor_idx: usize,
    pub vendor: String,
    /// Ordinal of this archetype within its vendor (its "speed bin"):
    /// bin 0 is the vendor's first design, bin 1 the next, … — used by
    /// the profile cache to pick the nearest warm-seed neighbor.
    pub speed_bin: usize,
    /// The population DIMM id whose generated arrays are this
    /// archetype's silicon.
    pub dimm_id: usize,
}

/// Build a catalog of `n` archetypes by walking the deterministic vendor
/// striping of the population: DIMM ids 0, 1, 2, … are assigned to their
/// `vendor_of` vendor in order, so the catalog's vendor mix follows the
/// configured market shares and the whole catalog is a pure function of
/// `n` (no RNG state beyond the per-id vendor draw).
pub fn archetype_catalog(n: usize, p: &ModelParams) -> Vec<Archetype> {
    assert!(n >= 1, "a fleet needs at least one archetype");
    let mut per_vendor = vec![0usize; p.population.vendors.len()];
    (0..n)
        .map(|idx| {
            let vi = vendor_of(idx, p);
            let speed_bin = per_vendor[vi];
            per_vendor[vi] += 1;
            Archetype {
                idx,
                vendor_idx: vi,
                vendor: p.population.vendors[vi].name.clone(),
                speed_bin,
                dimm_id: idx,
            }
        })
        .collect()
}

/// The full population at a given per-chip-bank sampling resolution.
pub fn generate_population(cells_per_chip_bank: usize) -> Vec<Dimm> {
    let p = params();
    (0..p.population.n_dimms)
        .map(|id| generate_dimm(id, cells_per_chip_bank, p))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let p = params();
        let a = generate_dimm(7, 64, p);
        let b = generate_dimm(7, 64, p);
        assert_eq!(a.arrays.qcap, b.arrays.qcap);
        assert_eq!(a.arrays.lam85, b.arrays.lam85);
        assert_eq!(a.vendor, b.vendor);
    }

    #[test]
    fn different_dimms_differ() {
        let p = params();
        let a = generate_dimm(1, 64, p);
        let b = generate_dimm(2, 64, p);
        assert_ne!(a.arrays.tau_s, b.arrays.tau_s);
    }

    #[test]
    fn vendor_assignment_covers_all() {
        let p = params();
        let mut seen = vec![0usize; p.population.vendors.len()];
        for id in 0..p.population.n_dimms {
            seen[vendor_of(id, p)] += 1;
        }
        for (vi, count) in seen.iter().enumerate() {
            assert!(*count > 10, "vendor {vi} got only {count} dimms");
        }
    }

    #[test]
    fn parameters_in_physical_ranges() {
        let p = params();
        let d = generate_dimm(0, 256, p);
        let a = &d.arrays;
        for i in 0..a.len() {
            assert!(a.qcap[i] >= p.population.qcap_clip_lo as f32
                && a.qcap[i] <= p.population.qcap_clip_hi as f32);
            assert!(a.tau_s[i] > 1.0 && a.tau_s[i] < 20.0, "tau_s {}", a.tau_s[i]);
            assert!(a.tau_r[i] > 0.3 && a.tau_r[i] < 20.0);
            assert!(a.tau_p[i] > 0.5 && a.tau_p[i] < 5.0);
            assert!(a.lam85[i] > 0.0 && a.lam85[i] < 0.1);
        }
    }

    #[test]
    fn weak_tail_exists_at_scale() {
        // Across the whole population at small resolution there must be at
        // least a handful of weak cells (the Fig 2a/3a retention setters).
        let p = params();
        let mut weak = 0usize;
        for id in 0..20 {
            let d = generate_dimm(id, 256, p);
            let lam_med = p.population.mu_ln_lam85.exp();
            weak += d.arrays.lam85.iter()
                .filter(|l| **l as f64 > lam_med * 5.0).count();
        }
        assert!(weak > 0, "no weak-tail cells generated");
    }

    #[test]
    fn spatial_map_is_persisted_with_the_dimm() {
        let p = params();
        let a = generate_dimm(5, 64, p);
        let b = generate_dimm(5, 256, p);
        // Same map at every sampling resolution — it is module identity.
        assert_eq!(a.spatial, b.spatial);
        assert_eq!(a.spatial.bank_offset.len(), p.geometry.banks);
        assert!(a.spatial.grad_span > 0.0);
        for off in &a.spatial.bank_offset {
            assert!(*off > 0.8 && *off < 1.25, "bank offset {off}");
        }
    }

    #[test]
    fn spatial_gradient_is_monotone_across_row_regions() {
        // Rows far from the sense amps (high j) must be slower on average:
        // the mean tau_s of the last quarter exceeds the first quarter in
        // every bank (the gradient dominates the i.i.d. noise at n=64*8).
        let p = params();
        let d = generate_dimm(11, 256, p);
        let a = &d.arrays;
        let q = a.cells / 4;
        for b in 0..a.banks {
            let over = |lo: usize, hi: usize| -> f64 {
                let mut s = 0.0;
                let mut n = 0;
                for c in 0..a.chips {
                    for j in lo..hi {
                        s += a.tau_s[a.idx(b, c, j)] as f64;
                        n += 1;
                    }
                }
                s / n as f64
            };
            let near = over(0, q);
            let far = over(a.cells - q, a.cells);
            assert!(far > near, "bank {b}: far {far} <= near {near}");
        }
    }

    #[test]
    fn archetype_catalog_is_deterministic_and_striped() {
        let p = params();
        let a = archetype_catalog(12, p);
        let b = archetype_catalog(12, p);
        assert_eq!(a, b);
        assert_eq!(a.len(), 12);
        // Prefix property: a bigger catalog extends a smaller one, so a
        // fleet grown from 12 to 16 archetypes keeps bins 0..12 stable.
        let big = archetype_catalog(16, p);
        assert_eq!(&big[..12], &a[..]);
        // Vendor striping matches the population assignment, and speed
        // bins count up within each vendor.
        let mut per_vendor = vec![0usize; p.population.vendors.len()];
        for at in &a {
            assert_eq!(at.vendor_idx, vendor_of(at.dimm_id, p));
            assert_eq!(at.speed_bin, per_vendor[at.vendor_idx]);
            per_vendor[at.vendor_idx] += 1;
        }
        // At 12 archetypes every vendor should field at least one design.
        assert!(per_vendor.iter().all(|c| *c > 0), "{per_vendor:?}");
    }

    #[test]
    fn downsample_preserves_bank_structure() {
        let p = params();
        let d = generate_dimm(3, 256, p);
        let small = d.arrays.downsample(64);
        assert_eq!(small.banks, d.arrays.banks);
        assert_eq!(small.cells, 64);
        // First cell of each (bank, chip) must match the full population.
        for b in 0..small.banks {
            for c in 0..small.chips {
                assert_eq!(small.qcap[small.idx(b, c, 0)],
                           d.arrays.qcap[d.arrays.idx(b, c, 0)]);
            }
        }
    }
}
