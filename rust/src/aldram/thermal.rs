//! DIMM thermal model.
//!
//! §2 of the paper: server DIMM temperatures never exceeded 34degC in a
//! memory-intensive cluster and drift at <= 0.1 degC/s. We model DIMM
//! temperature as a first-order system driven by memory-bus utilization
//! (self-heating) above the ambient, with the drift-rate bound enforced —
//! which is what makes AL-DRAM's refresh-epoch timing updates safe.

/// Steady-state self-heating at 100% bus utilization (degC above
/// ambient). Exposed so evaluation harnesses can place a channel's
/// ambient such that its *worst-case* DIMM temperature lands on a chosen
/// operating point (see `eval::fig6::ambient_for`).
pub const FULL_LOAD_RISE_C: f64 = 12.0;

#[derive(Debug, Clone)]
pub struct ThermalModel {
    ambient_c: f64,
    temp_c: f64,
    /// Steady-state self-heating at 100% utilization (degC).
    heat_full_util_c: f64,
    /// First-order time constant (s).
    tau_s: f64,
    /// Paper-measured bound on drift rate (degC/s).
    max_drift_c_per_s: f64,
}

impl ThermalModel {
    pub fn new(ambient_c: f64) -> Self {
        ThermalModel {
            ambient_c,
            temp_c: ambient_c,
            heat_full_util_c: FULL_LOAD_RISE_C,
            tau_s: 30.0,
            max_drift_c_per_s: 0.1,
        }
    }

    pub fn temperature(&self) -> f64 {
        self.temp_c
    }

    pub fn ambient(&self) -> f64 {
        self.ambient_c
    }

    /// Move the ambient setpoint without touching the current DIMM
    /// temperature — the fleet's per-node ambient model (inlet + seasonal
    /// + diurnal drift) retargets the first-order system between steps,
    /// and the DIMM then relaxes toward the new ambient under the same
    /// drift-rate bound as any other excursion.
    pub fn set_ambient(&mut self, ambient_c: f64) {
        self.ambient_c = ambient_c;
    }

    /// Advance `dt_s` seconds at the given bus utilization; returns the
    /// new temperature.
    pub fn step(&mut self, dt_s: f64, utilization: f64) -> f64 {
        let target = self.ambient_c
            + self.heat_full_util_c * utilization.clamp(0.0, 1.0);
        let alpha = 1.0 - (-dt_s / self.tau_s).exp();
        let raw = self.temp_c + (target - self.temp_c) * alpha;
        // Enforce the measured drift bound.
        let max_step = self.max_drift_c_per_s * dt_s;
        self.temp_c = raw.clamp(self.temp_c - max_step, self.temp_c + max_step);
        self.temp_c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converges_to_ambient_plus_heating() {
        let mut t = ThermalModel::new(30.0);
        for _ in 0..100_000 {
            t.step(0.01, 1.0);
        }
        assert!((t.temperature() - 42.0).abs() < 0.5, "{}", t.temperature());
    }

    #[test]
    fn idle_dimm_stays_at_ambient() {
        let mut t = ThermalModel::new(30.0);
        for _ in 0..10_000 {
            t.step(0.01, 0.0);
        }
        assert!((t.temperature() - 30.0).abs() < 0.01);
    }

    #[test]
    fn drift_rate_is_bounded() {
        let mut t = ThermalModel::new(30.0);
        let mut prev = t.temperature();
        for _ in 0..1000 {
            let now = t.step(1.0, 1.0); // 1-second steps, full blast
            assert!((now - prev).abs() <= 0.1 + 1e-12,
                    "drift {} degC/s", (now - prev).abs());
            prev = now;
        }
    }

    #[test]
    fn ambient_retarget_relaxes_under_the_drift_bound() {
        let mut t = ThermalModel::new(25.0);
        for _ in 0..10_000 {
            t.step(0.01, 0.0);
        }
        // A diurnal swing retargets the setpoint; temperature follows
        // gradually (bounded drift), not as a jump.
        t.set_ambient(31.0);
        assert_eq!(t.ambient(), 31.0);
        let before = t.temperature();
        let after = t.step(1.0, 0.0);
        assert!(after > before && after - before <= 0.1 + 1e-12);
        for _ in 0..100_000 {
            t.step(0.01, 0.0);
        }
        assert!((t.temperature() - 31.0).abs() < 0.01);
    }

    #[test]
    fn server_cluster_never_exceeds_34c() {
        // §2's measurement reproduced: 30 degC ambient + realistic
        // sustained utilization stays below 34 degC... only with the
        // utilization servers actually see (~30%).
        let mut t = ThermalModel::new(30.0);
        for _ in 0..100_000 {
            t.step(0.01, 0.3);
        }
        assert!(t.temperature() < 34.0, "{}", t.temperature());
    }
}
