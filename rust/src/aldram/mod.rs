//! The AL-DRAM mechanism (the paper's §4): per-DIMM, temperature-indexed
//! timing tables in the memory controller, populated from profiling and
//! consulted at refresh-epoch granularity. No DRAM-side changes — only
//! multiple timing sets plus a temperature input, exactly the hardware
//! cost the paper claims.

pub mod thermal;

pub use thermal::{ThermalModel, FULL_LOAD_RISE_C};

use crate::profiler::{DimmProfile, RegionDimmProfile};
use crate::timing::TimingParams;

/// Default interpolation bin width (degC) for tables built from profiles
/// — the single knob shared by the eval harnesses and the registry's
/// load-time validation.
pub const DEFAULT_BIN_C: f64 = 10.0;

/// One table row: use `timings` when the DIMM temperature is <= `max_c`.
#[derive(Debug, Clone, PartialEq)]
pub struct TableEntry {
    pub max_c: f64,
    pub timings: TimingParams,
}

/// Temperature-indexed timing table for one DIMM.
#[derive(Debug, Clone, PartialEq)]
pub struct AlDram {
    /// Ascending by `max_c`; the last entry is the standard worst-case set
    /// (the fallback above the highest profiled temperature).
    entries: Vec<TableEntry>,
    /// Guardband added to the measured temperature before lookup (degC) —
    /// conservative against sensor error and intra-DIMM gradients.
    pub guard_c: f64,
}

impl AlDram {
    /// Build from a profile: entries at the profiled temperatures (55degC
    /// and 85degC), with linear interpolation bins every `bin_c` degrees
    /// in between (interpolating *toward the conservative side*: each
    /// bin uses the timings valid at its upper edge).
    ///
    /// Panics on a profile that fails [`TimingParams::validate`] — use
    /// [`AlDram::try_from_profile`] when the profile comes from an
    /// untrusted source (a hand-edited registry file).
    pub fn from_profile(p: &DimmProfile, bin_c: f64) -> Self {
        Self::try_from_profile(p, bin_c)
            .expect("profile produced an invalid timing table")
    }

    /// Fallible [`AlDram::from_profile`]: every entry is validated, so a
    /// corrupt registry file surfaces as an error at load time.
    ///
    /// The table is monotone by construction: the 85degC anchor takes the
    /// per-parameter max of the two profiled sets. The pass surface is
    /// monotone in each parameter, so raising a parameter of a passing
    /// combo keeps it passing — whereas the sweep's sum-minimizing best
    /// at 55degC is not guaranteed to dominate the 85degC best
    /// parameter-wise, and a non-monotone table would let a *hotter* bin
    /// install a *shorter* timing.
    pub fn try_from_profile(p: &DimmProfile, bin_c: f64)
                            -> anyhow::Result<Self> {
        Self::try_from_anchors(p.at55.combined(), p.at85.combined(), bin_c)
    }

    /// The table-building core shared by module-level and region-level
    /// profiles: two profiled anchors (55degC / 85degC) plus interpolation
    /// bins, the standard set above 85degC.
    pub fn try_from_anchors(t55: TimingParams, t85_raw: TimingParams,
                            bin_c: f64) -> anyhow::Result<Self> {
        anyhow::ensure!(bin_c > 0.0 && bin_c.is_finite(),
                        "bin width must be positive, got {bin_c}");
        let t85 = t85_raw.with_core(
            t85_raw.trcd_ns.max(t55.trcd_ns),
            t85_raw.tras_ns.max(t55.tras_ns),
            t85_raw.twr_ns.max(t55.twr_ns),
            t85_raw.trp_ns.max(t55.trp_ns),
        );
        let mut entries = Vec::new();
        entries.push(TableEntry { max_c: 55.0, timings: t55 });
        let mut temp = 55.0 + bin_c;
        while temp < 85.0 - 1e-9 {
            let f = (temp - 55.0) / 30.0;
            let lerp = |a: f64, b: f64| a + (b - a) * f;
            entries.push(TableEntry {
                max_c: temp,
                timings: t55.with_core(
                    lerp(t55.trcd_ns, t85.trcd_ns),
                    lerp(t55.tras_ns, t85.tras_ns),
                    lerp(t55.twr_ns, t85.twr_ns),
                    lerp(t55.trp_ns, t85.trp_ns),
                ),
            });
            temp += bin_c;
        }
        entries.push(TableEntry { max_c: 85.0, timings: t85 });
        // Above 85degC: the standard worst-case set.
        entries.push(TableEntry {
            max_c: f64::INFINITY,
            timings: TimingParams::ddr3_standard(),
        });
        Self::from_entries(entries, 2.0)
    }

    /// Assemble a table from explicit entries (the registry load path),
    /// enforcing the invariants every other constructor guarantees:
    /// non-empty, strictly ascending `max_c`, each timing set valid, and
    /// per-parameter monotone (a cooler bin is never slower).
    pub fn from_entries(entries: Vec<TableEntry>, guard_c: f64)
                        -> anyhow::Result<Self> {
        anyhow::ensure!(!entries.is_empty(), "empty AL-DRAM table");
        anyhow::ensure!(guard_c >= 0.0 && guard_c.is_finite(),
                        "guardband must be non-negative, got {guard_c}");
        for (i, e) in entries.iter().enumerate() {
            e.timings.validate().map_err(|err| {
                anyhow::anyhow!("table entry {i} (<= {} C): {err}", e.max_c)
            })?;
        }
        for w in entries.windows(2) {
            anyhow::ensure!(w[0].max_c < w[1].max_c,
                            "table entries must ascend by max_c: {} then {}",
                            w[0].max_c, w[1].max_c);
            let (a, b) = (&w[0].timings, &w[1].timings);
            anyhow::ensure!(
                a.trcd_ns <= b.trcd_ns + 1e-9
                    && a.tras_ns <= b.tras_ns + 1e-9
                    && a.twr_ns <= b.twr_ns + 1e-9
                    && a.trp_ns <= b.trp_ns + 1e-9,
                "non-monotone table: the bin at {} C is slower than the \
                 hotter bin at {} C",
                w[0].max_c, w[1].max_c
            );
        }
        Ok(AlDram { entries, guard_c })
    }

    /// A fixed-operating-point table (the paper's Fig-4 evaluation: one
    /// reduced set installed for 55degC operation). The timing set passes
    /// the same validator as every registry-loaded entry — `fixed` and
    /// [`RegionTable::uniform`] (which wraps it) are the only constructor
    /// paths that previously skipped it.
    pub fn fixed(timings: TimingParams) -> Self {
        timings.validate()
            .expect("fixed-operating-point timing set is invalid");
        AlDram {
            entries: vec![TableEntry { max_c: f64::INFINITY, timings }],
            guard_c: 0.0,
        }
    }

    /// Timing set for the current DIMM temperature.
    pub fn timings_for(&self, temp_c: f64) -> TimingParams {
        self.entries[self.bin_index(temp_c)].timings
    }

    /// Index of the bin selected at `temp_c` (guardband applied) —
    /// region tables use this to detect bin transitions, which is finer
    /// than watching the module timing set alone (two bins can share the
    /// collapsed module timings while their region entries differ).
    pub fn bin_index(&self, temp_c: f64) -> usize {
        let t = temp_c + self.guard_c;
        self.entries
            .iter()
            .position(|e| t <= e.max_c)
            .unwrap_or(self.entries.len() - 1)
    }

    pub fn entries(&self) -> &[TableEntry] {
        &self.entries
    }
}

/// Region-indexed timing table: one temperature-indexed [`AlDram`] per
/// (bank, row-region), bank-major. The unit of timing in the memory
/// controller is a *region* — a module-uniform table is just the
/// 1-region special case ([`RegionTable::uniform`]), which keeps every
/// pre-region call site a one-liner and bit-compatible with the scalar
/// path.
#[derive(Debug, Clone, PartialEq)]
pub struct RegionTable {
    banks: usize,
    regions_per_bank: usize,
    /// Length 1 (uniform) or `banks * regions_per_bank`, bank-major.
    entries: Vec<AlDram>,
    /// Per-parameter max collapse across regions — the timing set a
    /// controller without region support would have to install to be
    /// safe for every region (the "module-uniform" comparison point).
    module: AlDram,
}

impl RegionTable {
    /// Wrap a module-level table: one region covering everything. Every
    /// `AlDram` constructor validates its timing sets, so the wrapped
    /// table is valid by construction; the debug re-check here guards the
    /// (test-only) struct-literal escape hatch.
    pub fn uniform(table: AlDram) -> Self {
        debug_assert!(table.entries()
                          .iter()
                          .all(|e| e.timings.validate().is_ok()),
                      "uniform region table wraps an invalid timing set");
        RegionTable {
            banks: 1,
            regions_per_bank: 1,
            entries: vec![table.clone()],
            module: table,
        }
    }

    /// Assemble from per-(bank, region) tables, bank-major. All entries
    /// must share the same bin structure (max_c ladder and guardband) —
    /// true by construction when each comes from
    /// [`AlDram::try_from_anchors`] with one bin width — so a single
    /// temperature selects the same bin index in every region.
    pub fn from_regions(banks: usize, regions_per_bank: usize,
                        entries: Vec<AlDram>) -> anyhow::Result<Self> {
        anyhow::ensure!(banks > 0 && regions_per_bank > 0,
                        "degenerate region geometry {banks}x{regions_per_bank}");
        anyhow::ensure!(entries.len() == banks * regions_per_bank,
                        "expected {} region tables, got {}",
                        banks * regions_per_bank, entries.len());
        let first = &entries[0];
        for (i, e) in entries.iter().enumerate() {
            anyhow::ensure!(
                e.guard_c == first.guard_c
                    && e.entries.len() == first.entries.len()
                    && e.entries
                        .iter()
                        .zip(&first.entries)
                        .all(|(a, b)| a.max_c == b.max_c),
                "region {i} has a different bin structure"
            );
        }
        // Collapse: per-parameter max across regions at each bin. Max of
        // per-entry-monotone sequences is monotone, so `from_entries`
        // revalidates cleanly.
        let module_entries: Vec<TableEntry> = (0..first.entries.len())
            .map(|k| {
                let t = entries.iter().map(|e| e.entries[k].timings).fold(
                    first.entries[k].timings,
                    |acc, t| acc.with_core(
                        acc.trcd_ns.max(t.trcd_ns),
                        acc.tras_ns.max(t.tras_ns),
                        acc.twr_ns.max(t.twr_ns),
                        acc.trp_ns.max(t.trp_ns),
                    ),
                );
                TableEntry { max_c: first.entries[k].max_c, timings: t }
            })
            .collect();
        let module = AlDram::from_entries(module_entries, first.guard_c)?;
        Ok(RegionTable { banks, regions_per_bank, entries, module })
    }

    /// Build from a region profile: one anchored-and-interpolated table
    /// per (bank, region), all sharing `bin_c` (hence one bin ladder).
    /// Never faster than profiled per region by construction — each
    /// entry's 55/85degC anchors *are* that region's profiled combined
    /// sets, and interpolation bins sit between them.
    pub fn try_from_region_profile(p: &RegionDimmProfile, bin_c: f64)
                                   -> anyhow::Result<Self> {
        anyhow::ensure!(p.regions_per_bank >= 1, "no regions in profile");
        anyhow::ensure!(
            !p.regions.is_empty()
                && p.regions.len() % p.regions_per_bank == 0,
            "region list ({}) does not tile {} regions per bank",
            p.regions.len(), p.regions_per_bank
        );
        let banks = p.regions.len() / p.regions_per_bank;
        for (i, r) in p.regions.iter().enumerate() {
            anyhow::ensure!(
                r.bank == i / p.regions_per_bank
                    && r.region == i % p.regions_per_bank,
                "region list not bank-major at index {i} \
                 (bank {}, region {})", r.bank, r.region
            );
        }
        let entries = p
            .regions
            .iter()
            .map(|r| AlDram::try_from_anchors(
                r.at55.combined(), r.at85.combined(), bin_c))
            .collect::<anyhow::Result<Vec<_>>>()?;
        Self::from_regions(banks, p.regions_per_bank, entries)
    }

    /// Panicking [`RegionTable::try_from_region_profile`], for profiles
    /// we just computed (mirrors `AlDram::from_profile`).
    pub fn from_region_profile(p: &RegionDimmProfile, bin_c: f64) -> Self {
        Self::try_from_region_profile(p, bin_c)
            .expect("region profile produced an invalid timing table")
    }

    pub fn is_uniform(&self) -> bool {
        self.entries.len() == 1
    }

    pub fn banks(&self) -> usize {
        self.banks
    }

    pub fn regions_per_bank(&self) -> usize {
        self.regions_per_bank
    }

    pub fn entry(&self, bank: usize, region: usize) -> &AlDram {
        if self.is_uniform() {
            &self.entries[0]
        } else {
            &self.entries[bank * self.regions_per_bank + region]
        }
    }

    pub fn entries(&self) -> &[AlDram] {
        &self.entries
    }

    /// The per-parameter max collapse — what a region-unaware controller
    /// would install. Equals the wrapped table for uniform tables.
    pub fn module(&self) -> &AlDram {
        &self.module
    }

    /// Collapse to a module-uniform table (for the region-vs-uniform
    /// comparison evals).
    pub fn collapsed(&self) -> RegionTable {
        RegionTable::uniform(self.module.clone())
    }

    pub fn timings_for(&self, bank: usize, region: usize, temp_c: f64)
                       -> TimingParams {
        self.entry(bank, region).timings_for(temp_c)
    }

    /// Bin selected at `temp_c` — identical across regions because all
    /// entries share one ladder (enforced by `from_regions`).
    pub fn bin_index(&self, temp_c: f64) -> usize {
        self.entries[0].bin_index(temp_c)
    }

    /// All region timing sets at `temp_c`, bank-major — the controller
    /// install vector.
    pub fn region_timings_for(&self, temp_c: f64) -> Vec<TimingParams> {
        self.entries.iter().map(|e| e.timings_for(temp_c)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params;
    use crate::population::generate_dimm;
    use crate::profiler::profile_dimm;
    use crate::runtime::NativeBackend;

    fn table() -> AlDram {
        let d = generate_dimm(1, 64, params());
        let mut b = NativeBackend::new();
        let p = profile_dimm(&mut b, &d).unwrap();
        AlDram::from_profile(&p, 10.0)
    }

    #[test]
    fn cooler_bins_are_no_slower() {
        let t = table();
        let a = t.timings_for(40.0);
        let b = t.timings_for(84.0);
        assert!(a.trcd_ns <= b.trcd_ns + 1e-9);
        assert!(a.tras_ns <= b.tras_ns + 1e-9);
        assert!(a.twr_ns <= b.twr_ns + 1e-9);
        assert!(a.trp_ns <= b.trp_ns + 1e-9);
    }

    #[test]
    fn above_85_falls_back_to_standard() {
        let t = table();
        let hot = t.timings_for(95.0);
        let std = TimingParams::ddr3_standard();
        assert_eq!(hot, std);
    }

    #[test]
    fn guardband_is_conservative() {
        let t = table();
        // Just under a bin edge with the guardband must select the bin
        // above (slower timings), never the one below.
        let at_edge = t.timings_for(55.0 - t.guard_c / 2.0);
        let below = t.timings_for(40.0);
        assert!(at_edge.trcd_ns >= below.trcd_ns - 1e-9);
    }

    #[test]
    fn all_bins_are_at_least_as_fast_as_standard() {
        let t = table();
        let std = TimingParams::ddr3_standard();
        for e in t.entries() {
            assert!(e.timings.trcd_ns <= std.trcd_ns + 1e-9);
            assert!(e.timings.tras_ns <= std.tras_ns + 1e-9);
            assert!(e.timings.twr_ns <= std.twr_ns + 1e-9);
            assert!(e.timings.trp_ns <= std.trp_ns + 1e-9);
        }
    }

    #[test]
    fn from_profile_tables_are_monotone_for_arbitrary_bins() {
        // Property: for any bin width — including bin_c >= 30, where no
        // interpolation bin fits between the two profiled anchors — the
        // table ascends by max_c and a cooler bin is never slower in any
        // of the four core parameters.
        let mut b = NativeBackend::new();
        let profiles: Vec<_> = (0..3)
            .map(|id| {
                let d = generate_dimm(id, 64, params());
                profile_dimm(&mut b, &d).unwrap()
            })
            .collect();
        crate::util::quick::forall(24, |rng| {
            let p = rng.choose(&profiles);
            let bin_c = rng.range(0.5, 45.0);
            let t = AlDram::from_profile(p, bin_c);
            let e = t.entries();
            assert!(e.len() >= 3, "bin_c {bin_c}: entries {}", e.len());
            for w in e.windows(2) {
                assert!(w[0].max_c < w[1].max_c, "bin_c {bin_c}");
                let (a, b) = (&w[0].timings, &w[1].timings);
                assert!(a.trcd_ns <= b.trcd_ns + 1e-9, "bin_c {bin_c}: tRCD");
                assert!(a.tras_ns <= b.tras_ns + 1e-9, "bin_c {bin_c}: tRAS");
                assert!(a.twr_ns <= b.twr_ns + 1e-9, "bin_c {bin_c}: tWR");
                assert!(a.trp_ns <= b.trp_ns + 1e-9, "bin_c {bin_c}: tRP");
            }
        });
    }

    #[test]
    fn region_tables_monotone_and_never_faster_than_profiled() {
        // Property (satellite): for generated spatial maps, every region's
        // table is monotone in temperature and never installs a timing
        // faster than that region's profiled bins.
        use crate::profiler::profile_dimm_regions;
        let mut b = NativeBackend::new();
        for id in [2usize, 9] {
            let d = generate_dimm(id, 64, params());
            let rp = profile_dimm_regions(&mut b, &d, 4).unwrap();
            let t = RegionTable::from_region_profile(&rp, DEFAULT_BIN_C);
            assert_eq!(t.banks(), d.arrays.banks);
            assert_eq!(t.regions_per_bank(), 4);
            let dominates = |a: &TimingParams, b: &TimingParams| {
                a.trcd_ns >= b.trcd_ns - 1e-9
                    && a.tras_ns >= b.tras_ns - 1e-9
                    && a.twr_ns >= b.twr_ns - 1e-9
                    && a.trp_ns >= b.trp_ns - 1e-9
            };
            let temps = [40.0, 50.0, 58.0, 66.0, 74.0, 82.0, 90.0];
            for bank in 0..t.banks() {
                for r in 0..t.regions_per_bank() {
                    let prof = &rp.regions[bank * 4 + r];
                    // Monotone in temperature.
                    for w in temps.windows(2) {
                        assert!(dominates(
                            &t.timings_for(bank, r, w[1]),
                            &t.timings_for(bank, r, w[0])),
                            "dimm {id} bank {bank} region {r}: \
                             {} C slower than {} C", w[0], w[1]);
                    }
                    // Never faster than the profiled bins: every bin
                    // dominates the 55degC anchor, and every bin at or
                    // above the hot anchor dominates the 85degC profile.
                    let t55 = prof.at55.combined();
                    let t85 = prof.at85.combined();
                    for temp in temps {
                        let inst = t.timings_for(bank, r, temp);
                        assert!(dominates(&inst, &t55),
                                "dimm {id} b{bank}r{r}@{temp}: faster than \
                                 the 55C profile");
                        if temp + 2.0 > 80.0 {
                            assert!(dominates(&inst, &t85),
                                    "dimm {id} b{bank}r{r}@{temp}: faster \
                                     than the 85C profile");
                        }
                    }
                    // The module collapse dominates every region.
                    for temp in temps {
                        assert!(dominates(&t.module().timings_for(temp),
                                          &t.timings_for(bank, r, temp)));
                    }
                }
            }
            // Some spatial spread must actually be visible: not all
            // regions identical at 55degC (the gradient spans a grid step).
            let distinct: std::collections::BTreeSet<String> = rp
                .regions
                .iter()
                .map(|r| format!("{:?}", r.at55.combined()))
                .collect();
            assert!(distinct.len() > 1,
                    "dimm {id}: spatial map produced no region spread");
        }
    }

    #[test]
    fn uniform_region_table_is_the_wrapped_module_table() {
        let t = table();
        let rt = RegionTable::uniform(t.clone());
        assert!(rt.is_uniform());
        assert_eq!(rt.banks(), 1);
        assert_eq!(rt.regions_per_bank(), 1);
        for temp in [30.0, 55.0, 70.0, 90.0] {
            assert_eq!(rt.timings_for(0, 0, temp), t.timings_for(temp));
            assert_eq!(rt.module().timings_for(temp), t.timings_for(temp));
            assert_eq!(rt.bin_index(temp), t.bin_index(temp));
        }
        // Out-of-range (bank, region) still resolves for uniform tables —
        // the controller may index any decoded (bank, row).
        assert_eq!(rt.timings_for(7, 3, 55.0), t.timings_for(55.0));
    }

    #[test]
    fn from_regions_rejects_mismatched_shapes() {
        let t = table();
        assert!(RegionTable::from_regions(2, 2, vec![t.clone(); 3]).is_err());
        assert!(RegionTable::from_regions(0, 1, vec![t.clone()]).is_err());
        // Mismatched bin structure across regions.
        let other = AlDram::fixed(TimingParams::ddr3_standard());
        assert!(RegionTable::from_regions(1, 2, vec![t.clone(), other])
            .is_err());
        // A well-formed grid is accepted and collapses to itself.
        let rt = RegionTable::from_regions(1, 2, vec![t.clone(), t.clone()])
            .unwrap();
        assert_eq!(rt.module().entries(), t.entries());
    }

    #[test]
    fn from_entries_rejects_corrupt_tables() {
        let std = TimingParams::ddr3_standard();
        let fast = std.reduced(0.27, 0.32, 0.33, 0.18);
        // Empty.
        assert!(AlDram::from_entries(Vec::new(), 2.0).is_err());
        // Invalid timings inside an entry.
        let bad = std.with_core(-1.0, 35.0, 15.0, 13.75);
        assert!(AlDram::from_entries(
            vec![TableEntry { max_c: f64::INFINITY, timings: bad }], 2.0)
            .is_err());
        // Non-ascending temperatures.
        assert!(AlDram::from_entries(
            vec![TableEntry { max_c: 85.0, timings: std },
                 TableEntry { max_c: 55.0, timings: fast }], 2.0)
            .is_err());
        // Non-monotone: cooler bin slower than the hotter one.
        assert!(AlDram::from_entries(
            vec![TableEntry { max_c: 55.0, timings: std },
                 TableEntry { max_c: 85.0, timings: fast }], 2.0)
            .is_err());
        // Negative guardband.
        assert!(AlDram::from_entries(
            vec![TableEntry { max_c: f64::INFINITY, timings: std }], -1.0)
            .is_err());
        // A well-formed table is accepted.
        AlDram::from_entries(
            vec![TableEntry { max_c: 55.0, timings: fast },
                 TableEntry { max_c: f64::INFINITY, timings: std }], 2.0)
            .unwrap();
    }

    #[test]
    fn try_from_profile_rejects_degenerate_bins() {
        let d = generate_dimm(1, 64, params());
        let mut b = NativeBackend::new();
        let p = profile_dimm(&mut b, &d).unwrap();
        assert!(AlDram::try_from_profile(&p, 0.0).is_err());
        assert!(AlDram::try_from_profile(&p, -5.0).is_err());
        assert!(AlDram::try_from_profile(&p, f64::NAN).is_err());
    }
}
