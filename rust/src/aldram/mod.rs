//! The AL-DRAM mechanism (the paper's §4): per-DIMM, temperature-indexed
//! timing tables in the memory controller, populated from profiling and
//! consulted at refresh-epoch granularity. No DRAM-side changes — only
//! multiple timing sets plus a temperature input, exactly the hardware
//! cost the paper claims.

pub mod thermal;

pub use thermal::{ThermalModel, FULL_LOAD_RISE_C};

use crate::profiler::DimmProfile;
use crate::timing::TimingParams;

/// Default interpolation bin width (degC) for tables built from profiles
/// — the single knob shared by the eval harnesses and the registry's
/// load-time validation.
pub const DEFAULT_BIN_C: f64 = 10.0;

/// One table row: use `timings` when the DIMM temperature is <= `max_c`.
#[derive(Debug, Clone, PartialEq)]
pub struct TableEntry {
    pub max_c: f64,
    pub timings: TimingParams,
}

/// Temperature-indexed timing table for one DIMM.
#[derive(Debug, Clone, PartialEq)]
pub struct AlDram {
    /// Ascending by `max_c`; the last entry is the standard worst-case set
    /// (the fallback above the highest profiled temperature).
    entries: Vec<TableEntry>,
    /// Guardband added to the measured temperature before lookup (degC) —
    /// conservative against sensor error and intra-DIMM gradients.
    pub guard_c: f64,
}

impl AlDram {
    /// Build from a profile: entries at the profiled temperatures (55degC
    /// and 85degC), with linear interpolation bins every `bin_c` degrees
    /// in between (interpolating *toward the conservative side*: each
    /// bin uses the timings valid at its upper edge).
    ///
    /// Panics on a profile that fails [`TimingParams::validate`] — use
    /// [`AlDram::try_from_profile`] when the profile comes from an
    /// untrusted source (a hand-edited registry file).
    pub fn from_profile(p: &DimmProfile, bin_c: f64) -> Self {
        Self::try_from_profile(p, bin_c)
            .expect("profile produced an invalid timing table")
    }

    /// Fallible [`AlDram::from_profile`]: every entry is validated, so a
    /// corrupt registry file surfaces as an error at load time.
    ///
    /// The table is monotone by construction: the 85degC anchor takes the
    /// per-parameter max of the two profiled sets. The pass surface is
    /// monotone in each parameter, so raising a parameter of a passing
    /// combo keeps it passing — whereas the sweep's sum-minimizing best
    /// at 55degC is not guaranteed to dominate the 85degC best
    /// parameter-wise, and a non-monotone table would let a *hotter* bin
    /// install a *shorter* timing.
    pub fn try_from_profile(p: &DimmProfile, bin_c: f64)
                            -> anyhow::Result<Self> {
        anyhow::ensure!(bin_c > 0.0 && bin_c.is_finite(),
                        "bin width must be positive, got {bin_c}");
        let t55 = p.at55.combined();
        let t85_raw = p.at85.combined();
        let t85 = t85_raw.with_core(
            t85_raw.trcd_ns.max(t55.trcd_ns),
            t85_raw.tras_ns.max(t55.tras_ns),
            t85_raw.twr_ns.max(t55.twr_ns),
            t85_raw.trp_ns.max(t55.trp_ns),
        );
        let mut entries = Vec::new();
        entries.push(TableEntry { max_c: 55.0, timings: t55 });
        let mut temp = 55.0 + bin_c;
        while temp < 85.0 - 1e-9 {
            let f = (temp - 55.0) / 30.0;
            let lerp = |a: f64, b: f64| a + (b - a) * f;
            entries.push(TableEntry {
                max_c: temp,
                timings: t55.with_core(
                    lerp(t55.trcd_ns, t85.trcd_ns),
                    lerp(t55.tras_ns, t85.tras_ns),
                    lerp(t55.twr_ns, t85.twr_ns),
                    lerp(t55.trp_ns, t85.trp_ns),
                ),
            });
            temp += bin_c;
        }
        entries.push(TableEntry { max_c: 85.0, timings: t85 });
        // Above 85degC: the standard worst-case set.
        entries.push(TableEntry {
            max_c: f64::INFINITY,
            timings: TimingParams::ddr3_standard(),
        });
        Self::from_entries(entries, 2.0)
    }

    /// Assemble a table from explicit entries (the registry load path),
    /// enforcing the invariants every other constructor guarantees:
    /// non-empty, strictly ascending `max_c`, each timing set valid, and
    /// per-parameter monotone (a cooler bin is never slower).
    pub fn from_entries(entries: Vec<TableEntry>, guard_c: f64)
                        -> anyhow::Result<Self> {
        anyhow::ensure!(!entries.is_empty(), "empty AL-DRAM table");
        anyhow::ensure!(guard_c >= 0.0 && guard_c.is_finite(),
                        "guardband must be non-negative, got {guard_c}");
        for (i, e) in entries.iter().enumerate() {
            e.timings.validate().map_err(|err| {
                anyhow::anyhow!("table entry {i} (<= {} C): {err}", e.max_c)
            })?;
        }
        for w in entries.windows(2) {
            anyhow::ensure!(w[0].max_c < w[1].max_c,
                            "table entries must ascend by max_c: {} then {}",
                            w[0].max_c, w[1].max_c);
            let (a, b) = (&w[0].timings, &w[1].timings);
            anyhow::ensure!(
                a.trcd_ns <= b.trcd_ns + 1e-9
                    && a.tras_ns <= b.tras_ns + 1e-9
                    && a.twr_ns <= b.twr_ns + 1e-9
                    && a.trp_ns <= b.trp_ns + 1e-9,
                "non-monotone table: the bin at {} C is slower than the \
                 hotter bin at {} C",
                w[0].max_c, w[1].max_c
            );
        }
        Ok(AlDram { entries, guard_c })
    }

    /// A fixed-operating-point table (the paper's Fig-4 evaluation: one
    /// reduced set installed for 55degC operation).
    pub fn fixed(timings: TimingParams) -> Self {
        AlDram {
            entries: vec![TableEntry { max_c: f64::INFINITY, timings }],
            guard_c: 0.0,
        }
    }

    /// Timing set for the current DIMM temperature.
    pub fn timings_for(&self, temp_c: f64) -> TimingParams {
        let t = temp_c + self.guard_c;
        for e in &self.entries {
            if t <= e.max_c {
                return e.timings;
            }
        }
        self.entries.last().expect("table non-empty").timings
    }

    pub fn entries(&self) -> &[TableEntry] {
        &self.entries
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params;
    use crate::population::generate_dimm;
    use crate::profiler::profile_dimm;
    use crate::runtime::NativeBackend;

    fn table() -> AlDram {
        let d = generate_dimm(1, 64, params());
        let mut b = NativeBackend::new();
        let p = profile_dimm(&mut b, &d).unwrap();
        AlDram::from_profile(&p, 10.0)
    }

    #[test]
    fn cooler_bins_are_no_slower() {
        let t = table();
        let a = t.timings_for(40.0);
        let b = t.timings_for(84.0);
        assert!(a.trcd_ns <= b.trcd_ns + 1e-9);
        assert!(a.tras_ns <= b.tras_ns + 1e-9);
        assert!(a.twr_ns <= b.twr_ns + 1e-9);
        assert!(a.trp_ns <= b.trp_ns + 1e-9);
    }

    #[test]
    fn above_85_falls_back_to_standard() {
        let t = table();
        let hot = t.timings_for(95.0);
        let std = TimingParams::ddr3_standard();
        assert_eq!(hot, std);
    }

    #[test]
    fn guardband_is_conservative() {
        let t = table();
        // Just under a bin edge with the guardband must select the bin
        // above (slower timings), never the one below.
        let at_edge = t.timings_for(55.0 - t.guard_c / 2.0);
        let below = t.timings_for(40.0);
        assert!(at_edge.trcd_ns >= below.trcd_ns - 1e-9);
    }

    #[test]
    fn all_bins_are_at_least_as_fast_as_standard() {
        let t = table();
        let std = TimingParams::ddr3_standard();
        for e in t.entries() {
            assert!(e.timings.trcd_ns <= std.trcd_ns + 1e-9);
            assert!(e.timings.tras_ns <= std.tras_ns + 1e-9);
            assert!(e.timings.twr_ns <= std.twr_ns + 1e-9);
            assert!(e.timings.trp_ns <= std.trp_ns + 1e-9);
        }
    }

    #[test]
    fn from_profile_tables_are_monotone_for_arbitrary_bins() {
        // Property: for any bin width — including bin_c >= 30, where no
        // interpolation bin fits between the two profiled anchors — the
        // table ascends by max_c and a cooler bin is never slower in any
        // of the four core parameters.
        let mut b = NativeBackend::new();
        let profiles: Vec<_> = (0..3)
            .map(|id| {
                let d = generate_dimm(id, 64, params());
                profile_dimm(&mut b, &d).unwrap()
            })
            .collect();
        crate::util::quick::forall(24, |rng| {
            let p = rng.choose(&profiles);
            let bin_c = rng.range(0.5, 45.0);
            let t = AlDram::from_profile(p, bin_c);
            let e = t.entries();
            assert!(e.len() >= 3, "bin_c {bin_c}: entries {}", e.len());
            for w in e.windows(2) {
                assert!(w[0].max_c < w[1].max_c, "bin_c {bin_c}");
                let (a, b) = (&w[0].timings, &w[1].timings);
                assert!(a.trcd_ns <= b.trcd_ns + 1e-9, "bin_c {bin_c}: tRCD");
                assert!(a.tras_ns <= b.tras_ns + 1e-9, "bin_c {bin_c}: tRAS");
                assert!(a.twr_ns <= b.twr_ns + 1e-9, "bin_c {bin_c}: tWR");
                assert!(a.trp_ns <= b.trp_ns + 1e-9, "bin_c {bin_c}: tRP");
            }
        });
    }

    #[test]
    fn from_entries_rejects_corrupt_tables() {
        let std = TimingParams::ddr3_standard();
        let fast = std.reduced(0.27, 0.32, 0.33, 0.18);
        // Empty.
        assert!(AlDram::from_entries(Vec::new(), 2.0).is_err());
        // Invalid timings inside an entry.
        let bad = std.with_core(-1.0, 35.0, 15.0, 13.75);
        assert!(AlDram::from_entries(
            vec![TableEntry { max_c: f64::INFINITY, timings: bad }], 2.0)
            .is_err());
        // Non-ascending temperatures.
        assert!(AlDram::from_entries(
            vec![TableEntry { max_c: 85.0, timings: std },
                 TableEntry { max_c: 55.0, timings: fast }], 2.0)
            .is_err());
        // Non-monotone: cooler bin slower than the hotter one.
        assert!(AlDram::from_entries(
            vec![TableEntry { max_c: 55.0, timings: std },
                 TableEntry { max_c: 85.0, timings: fast }], 2.0)
            .is_err());
        // Negative guardband.
        assert!(AlDram::from_entries(
            vec![TableEntry { max_c: f64::INFINITY, timings: std }], -1.0)
            .is_err());
        // A well-formed table is accepted.
        AlDram::from_entries(
            vec![TableEntry { max_c: 55.0, timings: fast },
                 TableEntry { max_c: f64::INFINITY, timings: std }], 2.0)
            .unwrap();
    }

    #[test]
    fn try_from_profile_rejects_degenerate_bins() {
        let d = generate_dimm(1, 64, params());
        let mut b = NativeBackend::new();
        let p = profile_dimm(&mut b, &d).unwrap();
        assert!(AlDram::try_from_profile(&p, 0.0).is_err());
        assert!(AlDram::try_from_profile(&p, -5.0).is_err());
        assert!(AlDram::try_from_profile(&p, f64::NAN).is_err());
    }
}
