//! The AL-DRAM mechanism (the paper's §4): per-DIMM, temperature-indexed
//! timing tables in the memory controller, populated from profiling and
//! consulted at refresh-epoch granularity. No DRAM-side changes — only
//! multiple timing sets plus a temperature input, exactly the hardware
//! cost the paper claims.

pub mod thermal;

pub use thermal::ThermalModel;

use crate::profiler::DimmProfile;
use crate::timing::TimingParams;

/// One table row: use `timings` when the DIMM temperature is <= `max_c`.
#[derive(Debug, Clone)]
pub struct TableEntry {
    pub max_c: f64,
    pub timings: TimingParams,
}

/// Temperature-indexed timing table for one DIMM.
#[derive(Debug, Clone)]
pub struct AlDram {
    /// Ascending by `max_c`; the last entry is the standard worst-case set
    /// (the fallback above the highest profiled temperature).
    entries: Vec<TableEntry>,
    /// Guardband added to the measured temperature before lookup (degC) —
    /// conservative against sensor error and intra-DIMM gradients.
    pub guard_c: f64,
}

impl AlDram {
    /// Build from a profile: entries at the profiled temperatures (55degC
    /// and 85degC), with linear interpolation bins every `bin_c` degrees
    /// in between (interpolating *toward the conservative side*: each
    /// bin uses the timings valid at its upper edge).
    pub fn from_profile(p: &DimmProfile, bin_c: f64) -> Self {
        let t55 = p.at55.combined();
        let t85 = p.at85.combined();
        let mut entries = Vec::new();
        entries.push(TableEntry { max_c: 55.0, timings: t55 });
        let mut temp = 55.0 + bin_c;
        while temp < 85.0 - 1e-9 {
            let f = (temp - 55.0) / 30.0;
            let lerp = |a: f64, b: f64| a + (b - a) * f;
            entries.push(TableEntry {
                max_c: temp,
                timings: t55.with_core(
                    lerp(t55.trcd_ns, t85.trcd_ns),
                    lerp(t55.tras_ns, t85.tras_ns),
                    lerp(t55.twr_ns, t85.twr_ns),
                    lerp(t55.trp_ns, t85.trp_ns),
                ),
            });
            temp += bin_c;
        }
        entries.push(TableEntry { max_c: 85.0, timings: t85 });
        // Above 85degC: the standard worst-case set.
        entries.push(TableEntry {
            max_c: f64::INFINITY,
            timings: TimingParams::ddr3_standard(),
        });
        AlDram { entries, guard_c: 2.0 }
    }

    /// A fixed-operating-point table (the paper's Fig-4 evaluation: one
    /// reduced set installed for 55degC operation).
    pub fn fixed(timings: TimingParams) -> Self {
        AlDram {
            entries: vec![TableEntry { max_c: f64::INFINITY, timings }],
            guard_c: 0.0,
        }
    }

    /// Timing set for the current DIMM temperature.
    pub fn timings_for(&self, temp_c: f64) -> TimingParams {
        let t = temp_c + self.guard_c;
        for e in &self.entries {
            if t <= e.max_c {
                return e.timings;
            }
        }
        self.entries.last().expect("table non-empty").timings
    }

    pub fn entries(&self) -> &[TableEntry] {
        &self.entries
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params;
    use crate::population::generate_dimm;
    use crate::profiler::profile_dimm;
    use crate::runtime::NativeBackend;

    fn table() -> AlDram {
        let d = generate_dimm(1, 64, params());
        let mut b = NativeBackend::new();
        let p = profile_dimm(&mut b, &d).unwrap();
        AlDram::from_profile(&p, 10.0)
    }

    #[test]
    fn cooler_bins_are_no_slower() {
        let t = table();
        let a = t.timings_for(40.0);
        let b = t.timings_for(84.0);
        assert!(a.trcd_ns <= b.trcd_ns + 1e-9);
        assert!(a.tras_ns <= b.tras_ns + 1e-9);
        assert!(a.twr_ns <= b.twr_ns + 1e-9);
        assert!(a.trp_ns <= b.trp_ns + 1e-9);
    }

    #[test]
    fn above_85_falls_back_to_standard() {
        let t = table();
        let hot = t.timings_for(95.0);
        let std = TimingParams::ddr3_standard();
        assert_eq!(hot, std);
    }

    #[test]
    fn guardband_is_conservative() {
        let t = table();
        // Just under a bin edge with the guardband must select the bin
        // above (slower timings), never the one below.
        let at_edge = t.timings_for(55.0 - t.guard_c / 2.0);
        let below = t.timings_for(40.0);
        assert!(at_edge.trcd_ns >= below.trcd_ns - 1e-9);
    }

    #[test]
    fn all_bins_are_at_least_as_fast_as_standard() {
        let t = table();
        let std = TimingParams::ddr3_standard();
        for e in t.entries() {
            assert!(e.timings.trcd_ns <= std.trcd_ns + 1e-9);
            assert!(e.timings.tras_ns <= std.tras_ns + 1e-9);
            assert!(e.timings.twr_ns <= std.twr_ns + 1e-9);
            assert!(e.timings.trp_ns <= std.trp_ns + 1e-9);
        }
    }
}
