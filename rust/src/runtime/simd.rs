//! Vectorized profiling backend (the lane-chunked SoA kernel).
//!
//! `profile` runs `model::profile_simd` — error counts identical to the
//! scalar mirror, margins within the documented guard band. `pass_probe`
//! overrides the trait default with the weakest-first early-exit probe
//! (`model::profile_simd::probe_one`), which is what makes the timing
//! sweeps cheap: failing combos touch only the weak-cell prefix of the
//! screening order instead of the whole array. Both paths are
//! cross-checked against `NativeBackend` by `tests/runtime_simd_xcheck.rs`.

use std::sync::Arc;

use anyhow::Result;

use crate::model::{profile_simd, CellArrays, Combo, ModelParams,
                   ProfileOutput};

use super::backend::{PassCriterion, ProbeKind, ProfilingBackend};

pub struct SimdBackend {
    /// Shared, not owned: per-worker backends in a fan-out all point at
    /// the one process-wide `ModelParams` (see `model::params_arc`).
    params: Arc<ModelParams>,
}

impl SimdBackend {
    pub fn new() -> Self {
        SimdBackend { params: crate::model::params_arc() }
    }

    /// Calibration path: evaluate under experimental constants.
    pub fn with_params(params: ModelParams) -> Self {
        SimdBackend { params: Arc::new(params) }
    }
}

impl Default for SimdBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl ProfilingBackend for SimdBackend {
    fn name(&self) -> &'static str {
        "simd"
    }

    fn profile(&mut self, arrays: &CellArrays, combos: &[Combo])
               -> Result<ProfileOutput> {
        Ok(profile_simd::profile_simd(arrays, combos, &self.params))
    }

    fn pass_probe(&mut self, arrays: &CellArrays, combos: &[Combo],
                  kind: ProbeKind, criterion: PassCriterion)
                  -> Result<Vec<bool>> {
        let read_chain = kind == ProbeKind::Read;
        let (bank, budget) = match criterion {
            PassCriterion::Module { budget } => (None, budget),
            PassCriterion::Bank { bank } => (Some(bank), 0.0),
        };
        Ok(combos
            .iter()
            .map(|k| {
                profile_simd::probe_one(arrays, k, &self.params, read_chain,
                                        bank, budget)
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::population::generate_dimm;
    use crate::runtime::NativeBackend;

    #[test]
    fn simd_backend_counts_match_native() {
        let d = generate_dimm(6, 48, crate::model::params());
        let mut simd = SimdBackend::new();
        let mut native = NativeBackend::new();
        let combos = [
            Combo { trcd: 13.75, tras: 35.0, twr: 15.0, trp: 13.75,
                    tref_ms: 64.0, temp_c: 85.0 },
            Combo { trcd: 6.25, tras: 17.5, twr: 5.0, trp: 6.25,
                    tref_ms: 400.0, temp_c: 85.0 },
            Combo::sentinel(),
        ];
        let a = simd.profile(&d.arrays, &combos).unwrap();
        let b = native.profile(&d.arrays, &combos).unwrap();
        assert_eq!(a.err_r, b.err_r);
        assert_eq!(a.err_w, b.err_w);
        assert_eq!(a.tot_r, b.tot_r);
        assert_eq!(a.tot_w, b.tot_w);
    }

    #[test]
    fn probe_override_agrees_with_trait_default() {
        let d = generate_dimm(6, 48, crate::model::params());
        let mut simd = SimdBackend::new();
        let mut native = NativeBackend::new();
        let combos: Vec<Combo> = (0..6)
            .map(|i| Combo {
                trcd: 13.75 - i as f32 * 1.25,
                tras: 35.0 - i as f32 * 2.5,
                twr: 15.0 - i as f32 * 1.25,
                trp: 13.75 - i as f32 * 1.25,
                tref_ms: 64.0 + i as f32 * 64.0,
                temp_c: 85.0,
            })
            .collect();
        for kind in [ProbeKind::Read, ProbeKind::Write] {
            for criterion in [
                PassCriterion::Module { budget: 0.0 },
                PassCriterion::Module { budget: 8.0 },
                PassCriterion::Bank { bank: 3 },
            ] {
                let fast = simd
                    .pass_probe(&d.arrays, &combos, kind, criterion)
                    .unwrap();
                let slow = native
                    .pass_probe(&d.arrays, &combos, kind, criterion)
                    .unwrap();
                assert_eq!(fast, slow, "{kind:?} {criterion:?}");
            }
        }
    }
}
