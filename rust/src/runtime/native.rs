//! Pure-rust profiling backend (mirror of the AOT artifact's math).

use std::sync::Arc;

use anyhow::Result;

use crate::model::{profile, CellArrays, Combo, ModelParams, ProfileOutput};

pub struct NativeBackend {
    /// Shared, not owned: per-worker backends in a fan-out all point at
    /// the one process-wide `ModelParams` (see `model::params_arc`).
    params: Arc<ModelParams>,
}

impl NativeBackend {
    pub fn new() -> Self {
        NativeBackend { params: crate::model::params_arc() }
    }

    /// Calibration path: evaluate under experimental constants.
    pub fn with_params(params: ModelParams) -> Self {
        NativeBackend { params: Arc::new(params) }
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl super::backend::ProfilingBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn profile(&mut self, arrays: &CellArrays, combos: &[Combo])
               -> Result<ProfileOutput> {
        Ok(profile::profile_native(arrays, combos, &self.params))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::population::generate_dimm;
    use crate::runtime::backend::{profile_one, ProfilingBackend};

    #[test]
    fn native_backend_runs_any_batch_size() {
        let d = generate_dimm(0, 32, crate::model::params());
        let mut b = NativeBackend::new();
        let std = Combo { trcd: 13.75, tras: 35.0, twr: 15.0, trp: 13.75,
                          tref_ms: 64.0, temp_c: 85.0 };
        for n in [1usize, 3, 64, 100] {
            let combos = vec![std; n];
            let out = b.profile(&d.arrays, &combos).unwrap();
            assert_eq!(out.k, n);
            assert_eq!(out.read_errors(0), 0.0);
        }
        let (r, w) = profile_one(&mut b, &d.arrays, &std).unwrap();
        assert_eq!((r, w), (0.0, 0.0));
    }
}
