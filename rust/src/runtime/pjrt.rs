//! PJRT profiling backend: load the HLO-text artifact, compile it once on
//! the CPU PJRT client, and execute profiling batches from the rust hot
//! path. Python never runs here — the artifact was produced at build time
//! by `make artifacts` (python/compile/aot.py).
//!
//! Compiled only with the `pjrt` cargo feature. The feature alone is not
//! enough: this module needs the vendored `xla` bindings crate, which the
//! offline registry mirror does not carry, so it is deliberately NOT
//! declared in Cargo.toml (even an inactive optional dependency must
//! resolve). If you hit "unresolved crate `xla`" here, add
//! `xla = { path = "<vendored checkout>" }` under `[dependencies]` next
//! to enabling the feature — see the note at the top of rust/Cargo.toml.
//!
//! Perf notes (EXPERIMENTS.md §Perf): the five cell-parameter arrays are
//! uploaded to device once per `profile()` call and *reused* across all
//! combo chunks via `execute_b`; only the small [K, 6] combo table is
//! re-uploaded per chunk. Combos are padded to the artifact's static K
//! with sentinels (temp_c < 0), which the kernel maps to zero errors.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::model::{CellArrays, Combo, ProfileOutput};
use crate::util::json::Json;

pub struct PjrtBackend {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    banks: usize,
    chips: usize,
    cells: usize,
    k: usize,
    artifact: String,
}

/// Parsed `artifacts/manifest.json`.
pub struct Manifest {
    pub dir: PathBuf,
    pub banks: usize,
    pub chips: usize,
    pub combo_batch: usize,
    pub json: Json,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts`"))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;
        Ok(Manifest {
            dir: dir.to_path_buf(),
            banks: json.usize("banks"),
            chips: json.usize("chips"),
            combo_batch: json.usize("combo_batch"),
            json,
        })
    }

    pub fn artifact_file(&self, name: &str) -> Result<PathBuf> {
        let arts = self.json.req("artifacts");
        let meta = arts
            .get(name)
            .ok_or_else(|| anyhow!("artifact `{name}` not in manifest"))?;
        Ok(self.dir.join(meta.str("file")))
    }

    pub fn artifact_cells(&self, name: &str) -> Result<usize> {
        let arts = self.json.req("artifacts");
        let meta = arts
            .get(name)
            .ok_or_else(|| anyhow!("artifact `{name}` not in manifest"))?;
        Ok(meta.usize("cells"))
    }
}

impl PjrtBackend {
    /// Load + compile a profile artifact (`profile_full` or `profile_small`).
    pub fn new(dir: &Path, artifact: &str) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let path = manifest.artifact_file(artifact)?;
        let cells = manifest.artifact_cells(artifact)?;

        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().expect("artifact path utf-8"),
        )
        .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {artifact}: {e:?}"))?;

        Ok(PjrtBackend {
            client,
            exe,
            banks: manifest.banks,
            chips: manifest.chips,
            cells,
            k: manifest.combo_batch,
            artifact: artifact.to_string(),
        })
    }

    /// Load the artifact matching the given cell resolution.
    pub fn for_cells(dir: &Path, cells: usize) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        for name in ["profile_full", "profile_small"] {
            if manifest.artifact_cells(name)? == cells {
                return Self::new(dir, name);
            }
        }
        bail!("no profile artifact with {cells} cells per (bank, chip)")
    }

    pub fn artifact(&self) -> &str {
        &self.artifact
    }

    pub fn combo_batch(&self) -> usize {
        self.k
    }

    fn upload(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload {dims:?}: {e:?}"))
    }
}

/// Result of the ODE-vs-analytic cross-check (see figures::ablate).
pub struct OdeReport {
    pub cells: usize,
    pub max_abs_diff: f32,
    pub sign_agreement: f64,
}

/// Execute the `ode_check` artifact (Euler-integrated sense margins) on a
/// random cell population and compare with the native analytic margins.
pub fn run_ode_check(dir: &Path, cells: usize) -> Result<OdeReport> {
    use crate::model::params;
    use crate::util::rng::Rng;

    let manifest = Manifest::load(dir)?;
    let want = manifest.artifact_cells("ode_check")?;
    anyhow::ensure!(cells == want, "ode_check artifact has {want} cells");
    let path = manifest.artifact_file("ode_check")?;
    let client = xla::PjRtClient::cpu()
        .map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().expect("utf-8"))
        .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
    let exe = client
        .compile(&xla::XlaComputation::from_proto(&proto))
        .map_err(|e| anyhow!("compile ode_check: {e:?}"))?;

    let p = params();
    let mut rng = Rng::from_label("ode-check");
    let q0: Vec<f32> = (0..cells).map(|_| rng.range(0.05, 1.1) as f32).collect();
    let tau_s: Vec<f32> =
        (0..cells).map(|_| rng.lognormal(1.61, 0.05) as f32).collect();
    let tau_p: Vec<f32> =
        (0..cells).map(|_| rng.lognormal(0.515, 0.04) as f32).collect();
    let scalars: Vec<f32> = vec![9.0, 9.0, 64.0, 85.0, 0.0, 0.0, 0.0, 0.0];

    let lit = |v: &[f32]| xla::Literal::vec1(v);
    let result = exe
        .execute::<xla::Literal>(&[lit(&q0), lit(&tau_s), lit(&tau_p),
                                   lit(&scalars)])
        .map_err(|e| anyhow!("execute ode_check: {e:?}"))?[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("fetch: {e:?}"))?;
    let ode = result
        .to_tuple1()
        .map_err(|e| anyhow!("untuple: {e:?}"))?
        .to_vec::<f32>()
        .map_err(|e| anyhow!("to_vec: {e:?}"))?;

    // Native analytic margins (same math as charge::sense_margin).
    let (trcd, trp, temp) = (scalars[0], scalars[1], scalars[3]);
    let mut max_abs_diff = 0.0f32;
    let mut agree = 0usize;
    for i in 0..cells {
        let off = crate::model::charge::precharge_offset(tau_p[i], trp, p);
        let ana =
            crate::model::charge::sense_margin(q0[i], tau_s[i], trcd, off,
                                               temp, p);
        let d = (ode[i] - ana).abs();
        max_abs_diff = max_abs_diff.max(d);
        // Near-zero margins may legitimately flip under Euler error.
        if (ode[i] >= 0.0) == (ana >= 0.0) || ana.abs() < 5e-3 {
            agree += 1;
        }
    }
    Ok(OdeReport {
        cells,
        max_abs_diff,
        sign_agreement: agree as f64 / cells as f64,
    })
}

impl super::backend::ProfilingBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn supported_cells(&self) -> Option<usize> {
        Some(self.cells)
    }

    fn profile(&mut self, arrays: &CellArrays, combos: &[Combo])
               -> Result<ProfileOutput> {
        if arrays.banks != self.banks || arrays.chips != self.chips
            || arrays.cells != self.cells
        {
            bail!(
                "cell arrays [{},{},{}] do not match artifact `{}` [{},{},{}]",
                arrays.banks, arrays.chips, arrays.cells,
                self.artifact, self.banks, self.chips, self.cells
            );
        }
        let dims = [self.banks, self.chips, self.cells];
        // Upload the cell population once; reuse across combo chunks.
        let cell_bufs = [
            self.upload(&arrays.qcap, &dims)?,
            self.upload(&arrays.tau_s, &dims)?,
            self.upload(&arrays.tau_r, &dims)?,
            self.upload(&arrays.tau_p, &dims)?,
            self.upload(&arrays.lam85, &dims)?,
        ];

        let mut out = ProfileOutput::zeroed(combos.len(), self.banks, self.chips);
        let bc = self.banks * self.chips;

        for (chunk_i, chunk) in combos.chunks(self.k).enumerate() {
            let mut rows = vec![0.0f32; self.k * 6];
            for (i, c) in chunk.iter().enumerate() {
                rows[i * 6..i * 6 + 6].copy_from_slice(&c.to_row());
            }
            for i in chunk.len()..self.k {
                rows[i * 6..i * 6 + 6]
                    .copy_from_slice(&Combo::sentinel().to_row());
            }
            let combo_buf = self.upload(&rows, &[self.k, 6])?;

            let args: Vec<&xla::PjRtBuffer> = cell_bufs
                .iter()
                .chain(std::iter::once(&combo_buf))
                .collect();
            let result = self
                .exe
                .execute_b(&args)
                .map_err(|e| anyhow!("execute {}: {e:?}", self.artifact))?;
            let tuple = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch result: {e:?}"))?
                .to_tuple()
                .map_err(|e| anyhow!("untuple result: {e:?}"))?;
            if tuple.len() != 6 {
                bail!("artifact returned {} outputs, expected 6", tuple.len());
            }
            let fetch = |lit: &xla::Literal| -> Result<Vec<f32>> {
                lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
            };
            let err_r = fetch(&tuple[0])?;
            let err_w = fetch(&tuple[1])?;
            let mmin_r = fetch(&tuple[2])?;
            let mmin_w = fetch(&tuple[3])?;
            let tot_r = fetch(&tuple[4])?;
            let tot_w = fetch(&tuple[5])?;

            let base = chunk_i * self.k;
            for i in 0..chunk.len() {
                let dst = (base + i) * bc;
                let src = i * bc;
                out.err_r[dst..dst + bc].copy_from_slice(&err_r[src..src + bc]);
                out.err_w[dst..dst + bc].copy_from_slice(&err_w[src..src + bc]);
                out.mmin_r[dst..dst + bc]
                    .copy_from_slice(&mmin_r[src..src + bc]);
                out.mmin_w[dst..dst + bc]
                    .copy_from_slice(&mmin_w[src..src + bc]);
                out.tot_r[base + i] = tot_r[i];
                out.tot_w[base + i] = tot_w[i];
            }
        }
        Ok(out)
    }
}
