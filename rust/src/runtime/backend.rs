//! The profiling-backend abstraction: one trait, two engines.
//!
//! `PjrtBackend` executes the AOT-compiled HLO artifact (the production
//! path: python authored it at build time, rust runs it). `NativeBackend`
//! is the pure-rust mirror used as a cross-validation oracle, a fallback
//! when artifacts are absent, and the calibration fast path. The profiler
//! is written against this trait and cannot tell them apart (the
//! cross-check test asserts exactly that).

use anyhow::Result;

use crate::model::{CellArrays, Combo, ProfileOutput};

pub trait ProfilingBackend {
    /// Human-readable engine name (for logs and EXPERIMENTS.md).
    fn name(&self) -> &'static str;

    /// Evaluate every combo against the DIMM's cell arrays. Implementations
    /// must accept any combo-slice length (internal batching/padding) and
    /// any cell resolution they advertise via `supported_cells`.
    fn profile(&mut self, arrays: &CellArrays, combos: &[Combo])
               -> Result<ProfileOutput>;

    /// Cell-per-(bank,chip) resolutions this backend can evaluate
    /// (`None` = any resolution).
    fn supported_cells(&self) -> Option<usize> {
        None
    }
}

/// Convenience: evaluate a single combo and return (read_errs, write_errs).
pub fn profile_one(backend: &mut dyn ProfilingBackend, arrays: &CellArrays,
                   combo: &Combo) -> Result<(f64, f64)> {
    let out = backend.profile(arrays, std::slice::from_ref(combo))?;
    Ok((out.read_errors(0), out.write_errors(0)))
}
