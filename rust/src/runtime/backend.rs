//! The profiling-backend abstraction: one trait, three engines.
//!
//! `PjrtBackend` executes the AOT-compiled HLO artifact (the production
//! path: python authored it at build time, rust runs it). `NativeBackend`
//! is the pure-rust scalar mirror used as a cross-validation oracle and
//! the bit-exactness reference. `SimdBackend` is the lane-chunked
//! vectorized engine (identical error counts, margins within a guard
//! band) the characterization pipeline rides on. The profiler is written
//! against this trait and cannot tell them apart (the cross-check tests
//! assert exactly that).

use anyhow::Result;

use crate::model::{CellArrays, Combo, ProfileOutput};

/// Which test chain a pass probe inspects (mirrors `profiler::TestKind`
/// without the dependency inversion).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProbeKind {
    Read,
    Write,
}

/// Pass criterion for `pass_probe` — the three acceptance rules the
/// timing sweeps use (module-wide zero-error / ECC budget, and the §5.2
/// bank-granular zero-error extension).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PassCriterion {
    /// Module-wide failing-cell budget; `budget: 0.0` is the standard
    /// zero-error rule, positive budgets model §9.2 ECC correction.
    Module { budget: f64 },
    /// Zero errors within one bank (other banks may err — they run their
    /// own timings).
    Bank { bank: usize },
}

impl Default for PassCriterion {
    /// The standard module-wide zero-error rule.
    fn default() -> Self {
        PassCriterion::Module { budget: 0.0 }
    }
}

impl PassCriterion {
    /// Evaluate the criterion against a full profiling output — the
    /// reference semantics every `pass_probe` implementation must match.
    pub fn evaluate(&self, out: &ProfileOutput, k: usize, kind: ProbeKind)
                    -> bool {
        match *self {
            PassCriterion::Module { budget } => match kind {
                ProbeKind::Read => out.read_errors(k) <= budget,
                ProbeKind::Write => out.write_errors(k) <= budget,
            },
            PassCriterion::Bank { bank } => match kind {
                ProbeKind::Read => out.bank_errors_read(k)[bank] == 0.0,
                ProbeKind::Write => out.bank_errors_write(k)[bank] == 0.0,
            },
        }
    }
}

pub trait ProfilingBackend {
    /// Human-readable engine name (for logs and EXPERIMENTS.md).
    fn name(&self) -> &'static str;

    /// Evaluate every combo against the DIMM's cell arrays. Implementations
    /// must accept any combo-slice length (internal batching/padding) and
    /// any cell resolution they advertise via `supported_cells`.
    fn profile(&mut self, arrays: &CellArrays, combos: &[Combo])
               -> Result<ProfileOutput>;

    /// Pass/fail decision per combo under `criterion` — the sweep fast
    /// path. The default implementation derives the decisions from a full
    /// `profile` call; engines that can do better (early exit over a
    /// weakest-first screening order — see `SimdBackend`) override it.
    /// Every implementation must agree with
    /// `PassCriterion::evaluate(profile(...))` exactly.
    fn pass_probe(&mut self, arrays: &CellArrays, combos: &[Combo],
                  kind: ProbeKind, criterion: PassCriterion)
                  -> Result<Vec<bool>> {
        let out = self.profile(arrays, combos)?;
        Ok((0..combos.len())
            .map(|k| criterion.evaluate(&out, k, kind))
            .collect())
    }

    /// Cell-per-(bank,chip) resolutions this backend can evaluate
    /// (`None` = any resolution).
    fn supported_cells(&self) -> Option<usize> {
        None
    }
}

/// Convenience: evaluate a single combo and return (read_errs, write_errs).
pub fn profile_one(backend: &mut dyn ProfilingBackend, arrays: &CellArrays,
                   combo: &Combo) -> Result<(f64, f64)> {
    let out = backend.profile(arrays, std::slice::from_ref(combo))?;
    Ok((out.read_errors(0), out.write_errors(0)))
}
