//! Runtime bridge: the `ProfilingBackend` trait and its engines.
//!
//! `NativeBackend` (always available) is the pure-rust scalar mirror of
//! the AOT artifact's math — the bit-exactness oracle. `SimdBackend` is
//! the lane-chunked vectorized engine (identical error counts, margins
//! within a guard band; DESIGN.md §7) that the characterization pipeline
//! rides on. `PjrtBackend` executes the HLO-text artifact on the `xla`
//! crate's PJRT CPU client; it is gated behind the off-by-default `pjrt`
//! cargo feature so the offline build needs no XLA toolchain (see
//! Cargo.toml for how to enable it).

pub mod backend;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod simd;

pub use backend::{profile_one, PassCriterion, ProbeKind, ProfilingBackend};
pub use native::NativeBackend;
#[cfg(feature = "pjrt")]
pub use pjrt::{Manifest, PjrtBackend};
pub use simd::SimdBackend;

use std::path::{Path, PathBuf};
use std::sync::Once;

/// Default artifact directory: `$ARTIFACTS_DIR` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("ARTIFACTS_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// One-shot fallback notice: a parallel profiling campaign calls
/// `auto_backend` once per worker per DIMM, and N_workers x N_dimms
/// copies of the same line are noise.
fn fallback_notice(msg: &str) {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| eprintln!("{msg}"));
}

/// Best backend for a given cell resolution: PJRT when the feature is
/// enabled and an artifact with a matching shape exists, the vectorized
/// SIMD engine otherwise (with a once-per-process notice — it produces
/// error counts identical to the scalar oracle, see the xcheck tests).
/// `--backend native` still selects the scalar mirror explicitly.
pub fn auto_backend(dir: &Path, cells: usize) -> Box<dyn ProfilingBackend> {
    #[cfg(feature = "pjrt")]
    match PjrtBackend::for_cells(dir, cells) {
        Ok(b) => return Box::new(b),
        Err(e) => fallback_notice(&format!(
            "note: PJRT backend unavailable ({e}); using the vectorized \
             simd engine"
        )),
    }
    #[cfg(not(feature = "pjrt"))]
    {
        let _ = (dir, cells);
        fallback_notice(
            "note: PJRT backend disabled (built without the `pjrt` \
             feature); using the vectorized simd engine",
        );
    }
    Box::new(SimdBackend::new())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_backend_falls_back_to_simd_without_artifacts() {
        // Point at a directory with no manifest: must not error, and the
        // notice must fire at most once for any number of calls.
        let dir = std::env::temp_dir().join("aldram_no_artifacts");
        for _ in 0..3 {
            let b = auto_backend(&dir, 64);
            assert_eq!(b.name(), "simd");
        }
    }
}
