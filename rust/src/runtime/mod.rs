//! Runtime bridge: load AOT artifacts (HLO text) and execute them via the
//! `xla` crate's PJRT CPU client, behind the `ProfilingBackend` trait.

pub mod backend;
pub mod native;
pub mod pjrt;

pub use backend::{profile_one, ProfilingBackend};
pub use native::NativeBackend;
pub use pjrt::{artifacts_dir, Manifest, PjrtBackend};

use std::path::Path;

/// Best backend for a given cell resolution: PJRT when an artifact with a
/// matching shape exists, native otherwise (with a notice — the native
/// mirror is bit-equivalent within float tolerance, see the xcheck test).
pub fn auto_backend(dir: &Path, cells: usize) -> Box<dyn ProfilingBackend> {
    match PjrtBackend::for_cells(dir, cells) {
        Ok(b) => Box::new(b),
        Err(e) => {
            eprintln!(
                "note: PJRT backend unavailable ({e}); using native mirror"
            );
            Box::new(NativeBackend::new())
        }
    }
}
