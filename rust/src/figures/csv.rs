//! Tiny CSV emitter for figure data (written under `results/`).

use std::io::Write;
use std::path::Path;

pub struct Csv {
    rows: Vec<Vec<String>>,
}

impl Csv {
    pub fn new(header: &[&str]) -> Self {
        Csv { rows: vec![header.iter().map(|s| s.to_string()).collect()] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.rows[0].len(), "csv arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rowf(&mut self, cells: &[f64]) {
        self.row(&cells.iter().map(|x| format!("{x}")).collect::<Vec<_>>());
    }

    pub fn write(&self, dir: &Path, name: &str) -> anyhow::Result<()> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(name);
        let mut f = std::fs::File::create(&path)?;
        for r in &self.rows {
            writeln!(f, "{}", r.join(","))?;
        }
        eprintln!("wrote {}", path.display());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_csv() {
        let dir = std::env::temp_dir().join("aldram_csv_test");
        let mut c = Csv::new(&["a", "b"]);
        c.row(&["1".into(), "x".into()]);
        c.rowf(&[2.5, 3.0]);
        c.write(&dir, "t.csv").unwrap();
        let text = std::fs::read_to_string(dir.join("t.csv")).unwrap();
        assert_eq!(text, "a,b\n1,x\n2.5,3\n");
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(&["1".into()]);
    }
}
