//! Calibration: population statistics vs. the paper's headline numbers.
//!
//! Run `repro calibrate` after touching `model_params.json`; the
//! distributions were iterated until these match (log in EXPERIMENTS.md).

use anyhow::Result;

use crate::model::params;
use crate::population::generate_dimm;
use crate::profiler::{profile_dimm, summarize, DimmProfile};
use crate::runtime::ProfilingBackend;
use crate::util;

/// Paper targets (§5, Fig 2/3).
pub struct Targets;

impl Targets {
    pub const READ_RED_85: f64 = 0.211;
    pub const READ_RED_55: f64 = 0.327;
    pub const WRITE_RED_85: f64 = 0.344;
    pub const WRITE_RED_55: f64 = 0.551;
    pub const PARAM_RED_85: [f64; 4] = [0.156, 0.204, 0.206, 0.285];
    pub const PARAM_RED_55: [f64; 4] = [0.173, 0.377, 0.548, 0.352];
    /// Representative module (Fig 2a): max error-free refresh intervals.
    pub const REP_MAX_READ_MS: f64 = 208.0;
    pub const REP_MAX_WRITE_MS: f64 = 160.0;
}

pub struct CalibrationReport {
    pub profiles: Vec<DimmProfile>,
    pub summary: crate::profiler::PopulationSummary,
    pub max_read_ms: Vec<f64>,
    pub max_write_ms: Vec<f64>,
}

/// Profile `n_dimms` modules at `cells` resolution with the given backend.
pub fn run(backend: &mut dyn ProfilingBackend, n_dimms: usize, cells: usize)
           -> Result<CalibrationReport> {
    let p = params();
    let mut profiles = Vec::new();
    for id in 0..n_dimms {
        let d = generate_dimm(id, cells, p);
        profiles.push(profile_dimm(backend, &d)?);
        if (id + 1) % 10 == 0 {
            eprintln!("  profiled {}/{} modules", id + 1, n_dimms);
        }
    }
    report_from(profiles)
}

/// Parallel population campaign: one pool job per DIMM. `profile()` takes
/// `&mut self`, so each worker builds its own backend from the `Sync`
/// factory; per-DIMM profiles land in DIMM-id order regardless of which
/// worker ran them, so the report is identical to the sequential `run`
/// (every DIMM's cell arrays derive from its stable label, not from
/// sampling order).
pub fn run_par<F>(make_backend: F, n_dimms: usize, cells: usize,
                  jobs: usize) -> Result<CalibrationReport>
where
    F: Fn() -> Box<dyn ProfilingBackend> + Sync,
{
    use std::sync::atomic::{AtomicUsize, Ordering};
    let p = params();
    let finished = AtomicUsize::new(0);
    let profiles = crate::exec::Pool::new(jobs).try_run_init(
        n_dimms,
        make_backend,
        |backend, id| {
            let d = generate_dimm(id, cells, p);
            let profile = profile_dimm(backend.as_mut(), &d);
            let n = finished.fetch_add(1, Ordering::Relaxed) + 1;
            if n % 10 == 0 {
                eprintln!("  profiled {n}/{n_dimms} modules");
            }
            profile
        },
    )?;
    report_from(profiles)
}

fn report_from(profiles: Vec<DimmProfile>) -> Result<CalibrationReport> {
    let summary = summarize(&profiles);
    let max_read_ms =
        profiles.iter().map(|p| p.refresh85.module_max_read_ms).collect();
    let max_write_ms =
        profiles.iter().map(|p| p.refresh85.module_max_write_ms).collect();
    Ok(CalibrationReport { summary, profiles, max_read_ms, max_write_ms })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;

    #[test]
    fn parallel_campaign_matches_sequential() {
        let mut b = NativeBackend::new();
        let seq = run(&mut b, 4, 64).unwrap();
        let factory = || -> Box<dyn ProfilingBackend> {
            Box::new(NativeBackend::new())
        };
        let par = run_par(factory, 4, 64, 3).unwrap();
        assert_eq!(seq.profiles.len(), par.profiles.len());
        for (a, o) in seq.profiles.iter().zip(&par.profiles) {
            assert_eq!(a.id, o.id);
            assert_eq!(a.refresh85.module_max_read_ms,
                       o.refresh85.module_max_read_ms);
            assert_eq!(a.refresh85.module_max_write_ms,
                       o.refresh85.module_max_write_ms);
            assert_eq!(a.at55.combined(), o.at55.combined());
            assert_eq!(a.at85.combined(), o.at85.combined());
        }
        assert_eq!(seq.summary.read_reduction_55,
                   par.summary.read_reduction_55);
        assert_eq!(seq.summary.param_reduction_55,
                   par.summary.param_reduction_55);
    }
}

pub fn print_report(r: &CalibrationReport) {
    let s = &r.summary;
    let pct = |x: f64| format!("{:5.1}%", 100.0 * x);
    println!("== calibration: {} modules ==", s.n_dimms);
    println!("{:<34} {:>10} {:>10}", "metric", "measured", "paper");
    let row = |name: &str, got: f64, want: f64| {
        println!("{:<34} {:>10} {:>10}", name, pct(got), pct(want));
    };
    row("read latency reduction @85C", s.read_reduction_85,
        Targets::READ_RED_85);
    row("read latency reduction @55C", s.read_reduction_55,
        Targets::READ_RED_55);
    row("write latency reduction @85C", s.write_reduction_85,
        Targets::WRITE_RED_85);
    row("write latency reduction @55C", s.write_reduction_55,
        Targets::WRITE_RED_55);
    for (i, name) in ["tRCD", "tRAS", "tWR", "tRP"].iter().enumerate() {
        row(&format!("{name} reduction @85C"), s.param_reduction_85[i],
            Targets::PARAM_RED_85[i]);
    }
    for (i, name) in ["tRCD", "tRAS", "tWR", "tRP"].iter().enumerate() {
        row(&format!("{name} reduction @55C"), s.param_reduction_55[i],
            Targets::PARAM_RED_55[i]);
    }
    let sorted = |v: &[f64]| {
        let mut x = v.to_vec();
        x.sort_by(|a, b| a.partial_cmp(b).unwrap());
        x
    };
    let mr = sorted(&r.max_read_ms);
    let mw = sorted(&r.max_write_ms);
    println!(
        "max refresh read  ms: min {:.0} / med {:.0} / max {:.0}  (paper rep. module {:.0})",
        mr[0], util::percentile_sorted(&mr, 0.5),
        mr[mr.len() - 1], Targets::REP_MAX_READ_MS
    );
    println!(
        "max refresh write ms: min {:.0} / med {:.0} / max {:.0}  (paper rep. module {:.0})",
        mw[0], util::percentile_sorted(&mw, 0.5),
        mw[mw.len() - 1], Targets::REP_MAX_WRITE_MS
    );
    println!(
        "min param reductions @55C (Fig-4 operating point): \
         tRCD {} tRAS {} tWR {} tRP {}  (paper 27/32/33/18%)",
        pct(s.min_param_reduction_55[0]),
        pct(s.min_param_reduction_55[1]),
        pct(s.min_param_reduction_55[2]),
        pct(s.min_param_reduction_55[3]),
    );
}
