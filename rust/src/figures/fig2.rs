//! Fig 2: representative-module characterization.
//!
//! 2a — maximum error-free refresh interval at 85degC per bank / chip /
//!      module, for the read and write tests.
//! 2b — error-free (tRCD, tRAS, tRP) read-test combinations at the safe
//!      refresh interval, 55degC and 85degC.
//! 2c — same for the write test (tRCD, tWR, tRP).

use std::path::Path;

use anyhow::Result;

use crate::model::CellArrays;
use crate::profiler::{profile_refresh, sweep_seeded, RefreshProfile,
                      SweepResult, TestKind};
use crate::runtime::ProfilingBackend;
use crate::timing::TimingParams;

use super::csv::Csv;

/// The paper's representative module — picked during calibration as the
/// DIMM whose retention profile sits closest to Fig 2a: at full sampling
/// resolution, dimm 011 shows a 200 ms / 160 ms maximum error-free refresh
/// interval (read / write) vs. the paper's 208 ms / 160 ms.
pub const REPRESENTATIVE_DIMM: usize = 11;

pub fn fig2a(backend: &mut dyn ProfilingBackend, arrays: &CellArrays,
             out: &Path) -> Result<RefreshProfile> {
    let p = profile_refresh(backend, arrays, 85.0)?;
    println!("== Fig 2a: max error-free refresh interval @85C (ms) ==");
    println!("module: read {:.0}  write {:.0}   (paper: 208 / 160)",
             p.module_max_read_ms, p.module_max_write_ms);
    println!("safe intervals: read {:.0}  write {:.0}   (paper: 200 / 152)",
             p.safe_read_ms(), p.safe_write_ms());
    let fmt = |v: &[f64]| v.iter().map(|x| format!("{x:.0}"))
        .collect::<Vec<_>>().join(" ");
    println!("banks read : {}", fmt(&p.bank_max_read_ms));
    println!("banks write: {}", fmt(&p.bank_max_write_ms));
    println!("chips read : {}", fmt(&p.chip_max_read_ms));
    println!("chips write: {}", fmt(&p.chip_max_write_ms));

    let mut csv = Csv::new(&["unit", "kind", "max_refresh_ms"]);
    csv.row(&["module".into(), "read".into(),
              format!("{}", p.module_max_read_ms)]);
    csv.row(&["module".into(), "write".into(),
              format!("{}", p.module_max_write_ms)]);
    for (i, v) in p.bank_max_read_ms.iter().enumerate() {
        csv.row(&[format!("bank{i}"), "read".into(), format!("{v}")]);
    }
    for (i, v) in p.bank_max_write_ms.iter().enumerate() {
        csv.row(&[format!("bank{i}"), "write".into(), format!("{v}")]);
    }
    for (i, v) in p.chip_max_read_ms.iter().enumerate() {
        csv.row(&[format!("chip{i}"), "read".into(), format!("{v}")]);
    }
    for (i, v) in p.chip_max_write_ms.iter().enumerate() {
        csv.row(&[format!("chip{i}"), "write".into(), format!("{v}")]);
    }
    csv.write(out, "fig2a.csv")?;
    Ok(p)
}

fn print_sweep(label: &str, s: &SweepResult, std_sum: f64) {
    println!("== {label} @{}C (refresh {} ms) ==", s.temp_c, s.tref_ms);
    let feasible = s.frontier.iter().filter(|f| f.min_third_ns.is_some())
        .count();
    println!("feasible (tRCD, tRP) pairs: {}/{}", feasible, s.frontier.len());
    if let Some(b) = &s.best {
        println!(
            "best combo: tRCD {:.2} + third {:.2} + tRP {:.2} = {:.2} ns \
             ({:.1}% below the {:.1} ns standard)",
            b.trcd_ns, b.third_ns, b.trp_ns, b.sum_ns,
            100.0 * b.reduction, std_sum
        );
    } else {
        println!("no feasible combos");
    }
}

pub fn fig2bc(backend: &mut dyn ProfilingBackend, arrays: &CellArrays,
              refresh: &RefreshProfile, out: &Path) -> Result<()> {
    let std = TimingParams::ddr3_standard();
    let mut csv = Csv::new(&["test", "temp_c", "trcd_ns", "third_ns",
                             "trp_ns", "acceptable"]);
    for (kind, label, tref, std_sum) in [
        (TestKind::Read, "Fig 2b: read test (tRCD/tRAS/tRP)",
         refresh.safe_read_ms(), std.read_sum_ns()),
        (TestKind::Write, "Fig 2c: write test (tRCD/tWR/tRP)",
         refresh.safe_write_ms(), std.write_sum_ns()),
    ] {
        // The 85C sweep is warm-started from the 55C frontier (monotone
        // across temperature; the seed is re-proven, not trusted).
        let mut prev: Option<SweepResult> = None;
        for temp in [55.0, 85.0] {
            let s = sweep_seeded(backend, arrays, kind, temp, tref,
                                 prev.as_ref())?;
            print_sweep(label, &s, std_sum);
            for f in &s.frontier {
                csv.row(&[
                    format!("{kind:?}"),
                    format!("{temp}"),
                    format!("{}", f.trcd_ns),
                    f.min_third_ns.map(|t| format!("{t}"))
                        .unwrap_or_else(|| "inf".into()),
                    format!("{}", f.trp_ns),
                    format!("{}", f.min_third_ns.is_some()),
                ]);
            }
            prev = Some(s);
        }
    }
    csv.write(out, "fig2bc.csv")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params;
    use crate::population::generate_dimm;
    use crate::runtime::NativeBackend;

    #[test]
    fn fig2_pipeline_runs() {
        let d = generate_dimm(REPRESENTATIVE_DIMM, 64, params());
        let mut b = NativeBackend::new();
        let dir = std::env::temp_dir().join("aldram_fig2_test");
        let refresh = fig2a(&mut b, &d.arrays, &dir).unwrap();
        assert!(refresh.module_max_read_ms >= 64.0);
        fig2bc(&mut b, &d.arrays, &refresh, &dir).unwrap();
        assert!(dir.join("fig2a.csv").exists());
        assert!(dir.join("fig2bc.csv").exists());
    }
}
