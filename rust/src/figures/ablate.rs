//! §7 ablations and analyses:
//!   refresh-latency  — §7.1: shorter refresh interval -> more reduction
//!   interdependence  — §7.2: reducing one parameter shrinks the others
//!   repeatability    — §7.6: failures repeat across runs/patterns/temps
//!   bank-granularity — §5.2 future work: per-bank AL-DRAM headroom
//!   ecc              — §9.2 future work: correctable-error latency budget
//!   sweep            — bisection sweep vs exhaustive grid (oracle check)
//!   ode              — Euler-integrated sensing vs the analytic model,
//!                      through the AOT `ode_check` artifact

use std::path::Path;

use anyhow::Result;

use crate::model::{params, Combo};
use crate::population::generate_dimm;
use crate::profiler::{repeatability, sweep, sweep_exhaustive, sweep_par,
                      SweepOpts, SweepResult, TestKind};
use crate::runtime::ProfilingBackend;

use super::csv::Csv;

/// §7.1 ladder: the refresh-interval points run in ascending order so
/// each sweep warm-starts from the previous point's frontier (the pass
/// surface is monotone in tREF; seeds are re-proven, so results match the
/// cold sweeps exactly), while the independent (tRCD, tRP) pairs *within*
/// each sweep fan out over the job pool (`sweep_par`). `jobs = 1` is the
/// sequential ablation; output is identical for any job count.
pub fn refresh_latency_par<F>(make_backend: F, dimm_id: usize, cells: usize,
                              jobs: usize, out: &Path) -> Result<()>
where
    F: Fn() -> Box<dyn ProfilingBackend> + Sync,
{
    let d = generate_dimm(dimm_id, cells, params());
    const TREFS: [f64; 5] = [16.0, 32.0, 64.0, 128.0, 200.0];
    let mut bests = Vec::with_capacity(TREFS.len());
    let mut prev: Option<SweepResult> = None;
    for &tref in &TREFS {
        let s = sweep_par(&make_backend, &d.arrays, TestKind::Read, 85.0,
                          tref,
                          SweepOpts { seed: prev.as_ref(),
                                      ..SweepOpts::default() },
                          jobs)?;
        bests.push(s.best.expect("std timings are always acceptable"));
        prev = Some(s);
    }
    println!("== §7.1: refresh interval vs latency reduction \
              (dimm {dimm_id}, 85C, {jobs} jobs) ==");
    let mut csv = Csv::new(&["tref_ms", "best_read_sum_ns", "reduction"]);
    let mut last = 0.0f64;
    for (tref, best) in TREFS.iter().zip(&bests) {
        println!("tref {tref:>5.0} ms -> best read sum {:>6.2} ns \
                  ({:>5.1}% reduction)",
                 best.sum_ns, 100.0 * best.reduction);
        csv.rowf(&[*tref, best.sum_ns, best.reduction]);
        anyhow::ensure!(best.sum_ns >= last - 1e-9,
                        "§7.1 violated: longer refresh raised the potential");
        last = best.sum_ns;
    }
    csv.write(out, "ablate_refresh_latency.csv")?;
    Ok(())
}

/// Parallel §9.2 grid: one pool job per ECC budget point.
pub fn ecc_par<F>(make_backend: F, dimm_id: usize, cells: usize, jobs: usize,
                  out: &Path) -> Result<()>
where
    F: Fn() -> Box<dyn ProfilingBackend> + Sync,
{
    use crate::profiler::sweep::sweep_ecc;
    let d = generate_dimm(dimm_id, cells, params());
    // Prelude backend for the refresh profile. It cannot be handed to the
    // pool afterwards (`ProfilingBackend` is not `Send`, and worker state
    // must be constructible on the worker's own thread), so the grid below
    // builds one fresh backend per worker — a bounded one-extra-build cost.
    let tref = {
        let mut b = make_backend();
        crate::profiler::profile_refresh(b.as_mut(), &d.arrays, 85.0)?
            .safe_read_ms()
    };
    const BUDGETS: [f64; 6] = [0.0, 1.0, 4.0, 16.0, 64.0, 256.0];
    let bests = crate::exec::Pool::new(jobs).try_run_init(
        BUDGETS.len(),
        &make_backend,
        |b, i| {
            Ok(sweep_ecc(b.as_mut(), &d.arrays, TestKind::Read, 85.0, tref,
                         BUDGETS[i])?
                .best
                .expect("ecc sweep feasible"))
        },
    )?;
    println!("== §9.2 future work: ECC-assisted latency reduction \
              (dimm {dimm_id}, 85C, tref {tref} ms, {jobs} jobs) ==");
    let mut csv = Csv::new(&["ecc_budget_cells", "read_sum_ns", "reduction"]);
    let mut last = f64::MAX;
    for (budget, s) in BUDGETS.iter().zip(&bests) {
        println!("budget {budget:>5.0} cells -> read sum {:.2} ns \
                  ({:.1}% reduction)", s.sum_ns, 100.0 * s.reduction);
        csv.rowf(&[*budget, s.sum_ns, s.reduction]);
        anyhow::ensure!(s.sum_ns <= last + 1e-9,
                        "more ECC budget must not reduce the potential");
        last = s.sum_ns;
    }
    csv.write(out, "ablate_ecc.csv")?;
    Ok(())
}

/// Parallel §5.2 grid: the module-granularity sweep on the caller's
/// backend, then one pool job per bank.
pub fn bank_granularity_par<F>(make_backend: F, dimm_id: usize, cells: usize,
                               jobs: usize, out: &Path) -> Result<()>
where
    F: Fn() -> Box<dyn ProfilingBackend> + Sync,
{
    use crate::profiler::sweep::sweep_bank;
    let d = generate_dimm(dimm_id, cells, params());
    let (tref, module) = {
        let mut b = make_backend();
        let refresh =
            crate::profiler::profile_refresh(b.as_mut(), &d.arrays, 85.0)?;
        let tref = refresh.safe_read_ms();
        let module = sweep(b.as_mut(), &d.arrays, TestKind::Read, 85.0,
                           tref)?
            .best
            .expect("module sweep feasible");
        (tref, module)
    };
    println!("== §5.2 future work: bank-granularity AL-DRAM \
              (dimm {dimm_id}, 85C, {jobs} jobs) ==");
    println!("module-granularity read sum: {:.2} ns ({:.1}% reduction)",
             module.sum_ns, 100.0 * module.reduction);

    let banks = d.arrays.banks;
    let bank_bests = crate::exec::Pool::new(jobs).try_run_init(
        banks,
        &make_backend,
        |b, bank| {
            Ok(sweep_bank(b.as_mut(), &d.arrays, TestKind::Read, 85.0, tref,
                          bank)?
                .best
                .expect("bank sweep feasible"))
        },
    )?;

    let mut csv = Csv::new(&["bank", "read_sum_ns", "reduction",
                             "extra_vs_module_ns"]);
    let mut extra_total = 0.0;
    for (bank, b) in bank_bests.iter().enumerate() {
        let extra = module.sum_ns - b.sum_ns;
        extra_total += extra;
        println!(
            "bank {bank}: {:.2} ns ({:.1}% reduction, {:+.2} ns vs module)",
            b.sum_ns, 100.0 * b.reduction, -extra
        );
        csv.rowf(&[bank as f64, b.sum_ns, b.reduction, extra]);
        anyhow::ensure!(b.sum_ns <= module.sum_ns + 1e-9);
    }
    println!(
        "average additional reduction at bank granularity: {:.2} ns \
         ({:.1}% of the standard read sum)",
        extra_total / banks as f64,
        100.0 * extra_total / banks as f64
            / crate::timing::TimingParams::ddr3_standard().read_sum_ns()
    );
    csv.write(out, "ablate_bank_granularity.csv")?;
    Ok(())
}

/// §7.2: the acceptable-tRAS frontier as tRCD is reduced (and vice versa):
/// cutting one parameter consumes the slack of the other. The frontier's
/// independent (tRCD, tRP) pairs probe through the job pool.
pub fn interdependence_par<F>(make_backend: F, dimm_id: usize, cells: usize,
                              jobs: usize, out: &Path) -> Result<()>
where
    F: Fn() -> Box<dyn ProfilingBackend> + Sync,
{
    let d = generate_dimm(dimm_id, cells, params());
    // Stress just inside the module's retention envelope: charge slack is
    // scarce there, so the parameter coupling is visible.
    let tref = {
        let mut b = make_backend();
        crate::profiler::profile_refresh(b.as_mut(), &d.arrays, 85.0)?
            .safe_read_ms()
    };
    let s = sweep_par(&make_backend, &d.arrays, TestKind::Read, 85.0, tref,
                      SweepOpts::default(), jobs)?;
    println!("== §7.2: min acceptable tRAS vs (tRCD, tRP) @85C, tref {tref} ms ==");
    let mut csv = Csv::new(&["trcd_ns", "trp_ns", "min_tras_ns"]);
    for f in &s.frontier {
        csv.row(&[
            format!("{}", f.trcd_ns),
            format!("{}", f.trp_ns),
            f.min_third_ns.map(|t| format!("{t}"))
                .unwrap_or_else(|| "inf".into()),
        ]);
    }
    csv.write(out, "ablate_interdependence.csv")?;

    // Print the diagonal: tightest tRP per tRCD.
    let grids = crate::timing::SweepGrids::standard();
    for &trcd in &grids.trcd {
        let row: Vec<String> = s
            .frontier
            .iter()
            .filter(|f| f.trcd_ns == trcd)
            .map(|f| {
                f.min_third_ns
                    .map(|t| format!("{t:.2}"))
                    .unwrap_or_else(|| "  —  ".into())
            })
            .collect();
        println!("tRCD {trcd:>6.2}: {}", row.join(" "));
    }
    Ok(())
}

/// §7.6: repeatability battery. The stress combo sits just past the
/// module's retention envelope (standard timings, max error-free interval
/// + 3 sweep steps) so the failing set is the weak tail, not the whole
/// array — matching how the paper's battery targets marginal cells.
pub fn repeat(dimm_id: usize, cells: usize, out: &Path) -> Result<()> {
    let d = generate_dimm(dimm_id, cells, params());
    let mut nb = crate::runtime::NativeBackend::new();
    let refresh = crate::profiler::profile_refresh(&mut nb, &d.arrays, 85.0)?;
    let combo = Combo {
        trcd: 12.5,
        tras: 30.0,
        twr: 12.5,
        trp: 12.5,
        tref_ms: (refresh.module_max_read_ms * 1.4) as f32,
        temp_c: 85.0,
    };
    let r = repeatability(&d.arrays, &combo, 10)?;
    println!("== §7.6: failure repeatability (dimm {dimm_id}, {} failing cells) ==",
             r.base_failures);
    let mut csv = Csv::new(&["scenario", "repeat_fraction"]);
    for (name, frac) in r.rows() {
        println!("{name:<16} {:.1}%  (paper: >95% for most scenarios)",
                 100.0 * frac);
        csv.row(&[name.to_string(), format!("{frac}")]);
    }
    csv.write(out, "ablate_repeatability.csv")?;
    Ok(())
}

/// Bisection sweep vs exhaustive grid: identical frontiers, fewer calls.
pub fn sweep_check(backend: &mut dyn ProfilingBackend, dimm_id: usize,
                   cells: usize) -> Result<()> {
    let d = generate_dimm(dimm_id, cells, params());
    for kind in [TestKind::Read, TestKind::Write] {
        let fast = sweep(backend, &d.arrays, kind, 85.0, 200.0)?;
        let full = sweep_exhaustive(backend, &d.arrays, kind, 85.0, 200.0)?;
        let mut mismatches = 0;
        for (a, b) in fast.frontier.iter().zip(&full.frontier) {
            if a.min_third_ns != b.min_third_ns {
                mismatches += 1;
            }
        }
        println!("{kind:?}: {} frontier points, {} mismatches",
                 fast.frontier.len(), mismatches);
        anyhow::ensure!(mismatches == 0, "bisection diverged from oracle");
    }
    println!("sweep bisection == exhaustive grid");
    Ok(())
}

/// ODE-vs-analytic sensing check through the AOT artifact (PJRT path).
/// Without the `pjrt` feature there is nothing to cross-check against, so
/// the ablation reports itself as skipped instead of failing `ablate all`.
#[cfg(feature = "pjrt")]
pub fn ode_check(dir: &Path) -> Result<()> {
    let report = crate::runtime::pjrt::run_ode_check(dir, 16384)?;
    println!("== ODE vs analytic sensing (artifact: ode_check) ==");
    println!("cells: {}   max |Δmargin|: {:.2e}   sign agreement: {:.3}%",
             report.cells, report.max_abs_diff,
             100.0 * report.sign_agreement);
    anyhow::ensure!(report.max_abs_diff < 5e-3, "ODE diverged from analytic");
    anyhow::ensure!(report.sign_agreement > 0.999);
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
pub fn ode_check(_dir: &Path) -> Result<()> {
    println!("== ODE vs analytic sensing: skipped (built without the \
              `pjrt` feature) ==");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;

    fn native_factory() -> Box<dyn ProfilingBackend> {
        Box::new(NativeBackend::new())
    }

    #[test]
    fn refresh_latency_monotone_sequential() {
        // jobs = 1 is the sequential ablation (the §7.1 monotonicity
        // check runs inside the function either way).
        let dir = std::env::temp_dir().join("aldram_ablate_test");
        refresh_latency_par(native_factory, 0, 64, 1, &dir).unwrap();
    }

    #[test]
    fn repeat_battery_runs() {
        let dir = std::env::temp_dir().join("aldram_ablate_test");
        repeat(0, 128, &dir).unwrap();
    }

    #[test]
    fn refresh_latency_par_runs_through_the_pool() {
        let dir = std::env::temp_dir().join("aldram_ablate_par_test");
        refresh_latency_par(native_factory, 0, 64, 2, &dir).unwrap();
        assert!(dir.join("ablate_refresh_latency.csv").exists());
    }

    #[test]
    fn interdependence_par_runs_through_the_pool() {
        let dir = std::env::temp_dir().join("aldram_ablate_inter_test");
        interdependence_par(native_factory, 0, 64, 2, &dir).unwrap();
        assert!(dir.join("ablate_interdependence.csv").exists());
    }
}
