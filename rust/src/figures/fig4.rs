//! Fig 4: real-system performance improvement of AL-DRAM.

use std::path::Path;

use anyhow::Result;

use crate::aldram::{AlDram, RegionTable};
use crate::eval::{self, fig4_jobs, Fig4Result, PAPER_REDUCTIONS_55C};

use super::csv::Csv;

fn print_and_csv(r: &Fig4Result, out: &Path, file: &str) -> Result<()> {
    println!("{:<14} {:>6} {:>10} {:>10} {:>10} {:>10}",
             "workload", "mpki", "1core", "+/-", "4core", "+/-");
    let mut csv = Csv::new(&["workload", "mpki", "intensive",
                             "single_speedup", "single_stddev",
                             "multi_speedup", "multi_stddev"]);
    for w in &r.per_workload {
        println!(
            "{:<14} {:>6.1} {:>9.1}% {:>9.2}% {:>9.1}% {:>9.2}%",
            w.name, w.mpki,
            100.0 * (w.single_speedup - 1.0), 100.0 * w.single_stddev,
            100.0 * (w.multi_speedup - 1.0), 100.0 * w.multi_stddev
        );
        csv.row(&[
            w.name.clone(), format!("{}", w.mpki),
            format!("{}", w.intensive),
            format!("{}", w.single_speedup), format!("{}", w.single_stddev),
            format!("{}", w.multi_speedup), format!("{}", w.multi_stddev),
        ]);
    }
    csv.write(out, file)?;

    println!("---");
    println!("multi-core  memory-intensive gmean: {:>5.1}%  (paper 14.0%)",
             100.0 * (r.gmean_intensive_multi - 1.0));
    println!("multi-core  non-intensive gmean:    {:>5.1}%  (paper  2.9%)",
             100.0 * (r.gmean_nonintensive_multi - 1.0));
    println!("multi-core  all-35 average:         {:>5.1}%  (paper 10.5%)",
             100.0 * (r.mean_all_multi - 1.0));
    println!("best multi-core speedup:            {:>5.1}%  (paper 20.5%, STREAM)",
             100.0 * (r.max_multi - 1.0));
    Ok(())
}

/// Regenerate Fig 4, fanning the (workload, cores, rep, timing-set) grid
/// out over `jobs` pool workers. Results are identical for every job
/// count (`eval::fig4_jobs` reduces order-independently).
pub fn fig4(cycles: u64, reps: usize, jobs: usize, out: &Path)
            -> Result<Fig4Result> {
    let r = fig4_jobs(cycles, reps, PAPER_REDUCTIONS_55C, jobs);
    println!("== Fig 4: AL-DRAM speedup over DDR3 standard (55C point, \
              {jobs} jobs) ==");
    print_and_csv(&r, out, "fig4.csv")?;
    Ok(r)
}

/// Fig 4 driven by one profiled module's own temperature-indexed table
/// (freshly profiled or reloaded from a `--profiles` registry) instead of
/// the population-minimum fixed reductions. The result is a function of
/// the table alone, so a registry reload reproduces a profile-fresh run
/// exactly.
pub fn fig4_profiled(cycles: u64, reps: usize, jobs: usize, table: &AlDram,
                     label: &str, out: &Path) -> Result<Fig4Result> {
    let r = eval::fig4_profiled(cycles, reps, table, jobs);
    println!("== Fig 4 (profiled {label}): per-module AL-DRAM table vs \
              DDR3 standard ({jobs} jobs) ==");
    print_and_csv(&r, out, "fig4_profiled.csv")?;
    Ok(r)
}

/// Fig 4 at region granularity: the grid runs twice — the module-uniform
/// collapse of `table`, then the full region-indexed table — and the
/// summary reports the gmean speedup delta region indexing buys on the
/// *same* profiled module. Returns the region-indexed result.
pub fn fig4_regions(cycles: u64, reps: usize, jobs: usize,
                    table: &RegionTable, label: &str, out: &Path)
                    -> Result<Fig4Result> {
    let uni = eval::fig4_profiled_regions(cycles, reps, &table.collapsed(),
                                          jobs);
    let reg = eval::fig4_profiled_regions(cycles, reps, table, jobs);
    println!("== Fig 4 (profiled {label}, region-indexed {} banks x {} \
              regions) vs DDR3 standard ({jobs} jobs) ==",
             table.banks(), table.regions_per_bank());
    print_and_csv(&reg, out, "fig4_regions.csv")?;
    let pp = |r: f64, u: f64| 100.0 * (r / u - 1.0);
    println!("region-indexed vs module-uniform (same profile):");
    println!("  intensive multi-core gmean delta: {:+.2}%  ({:.1}% vs {:.1}%)",
             pp(reg.gmean_intensive_multi, uni.gmean_intensive_multi),
             100.0 * (reg.gmean_intensive_multi - 1.0),
             100.0 * (uni.gmean_intensive_multi - 1.0));
    println!("  all-35 multi-core mean delta:     {:+.2}%  ({:.1}% vs {:.1}%)",
             pp(reg.mean_all_multi, uni.mean_all_multi),
             100.0 * (reg.mean_all_multi - 1.0),
             100.0 * (uni.mean_all_multi - 1.0));
    Ok(reg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_smoke() {
        // Tiny cycle budget: just proves the plumbing + CSV, through a
        // 2-worker pool.
        let dir = std::env::temp_dir().join("aldram_fig4_test");
        let r = fig4(4_000, 1, 2, &dir).unwrap();
        assert_eq!(r.per_workload.len(), 35);
        assert!(dir.join("fig4.csv").exists());
    }

    #[test]
    fn fig4_profiled_smoke() {
        use crate::model::params;
        use crate::population::generate_dimm;
        use crate::profiler::profile_dimm;
        use crate::runtime::NativeBackend;
        let d = generate_dimm(0, 64, params());
        let mut b = NativeBackend::new();
        let p = profile_dimm(&mut b, &d).unwrap();
        let table = AlDram::from_profile(&p, crate::aldram::DEFAULT_BIN_C);
        let dir = std::env::temp_dir().join("aldram_fig4_profiled_test");
        let r = fig4_profiled(3_000, 1, 2, &table, "dimm 000", &dir).unwrap();
        assert_eq!(r.per_workload.len(), 35);
        assert!(dir.join("fig4_profiled.csv").exists());
    }
}
