//! Per-figure reproduction drivers: each paper table/figure has a function
//! that prints the paper's rows/series and writes CSV under `results/`.
//! See DESIGN.md §8 for the experiment index.

pub mod ablate;
pub mod calibrate;
pub mod csv;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig6;
pub mod fleet;
