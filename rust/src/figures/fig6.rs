//! Fig 6/7: per-workload (and per-mix) AL-DRAM improvement table at
//! 55 °C and 85 °C, driven by one profiled module's own table.

use std::path::Path;

use anyhow::Result;

use crate::aldram::{AlDram, RegionTable};
use crate::eval::{fig6 as fig6_eval, fig6_regions as fig6_regions_eval,
                  Fig6Result, RowKind};
use crate::util;
use crate::workloads::mix::MixSpec;
use crate::workloads::WorkloadSpec;

use super::csv::Csv;

fn print_and_csv(r: &Fig6Result, out: &Path, file: &str) -> Result<()> {
    println!("{:<24} {:>6} {:>6} {:>9} {:>9}",
             "unit", "kind", "mpki", "55C", "85C");
    let mut csv = Csv::new(&["name", "kind", "mpki", "intensive",
                             "speedup_55c", "speedup_85c"]);
    for row in &r.rows {
        let kind = match row.kind {
            RowKind::Single => "1core",
            RowKind::Mix => "mix",
        };
        println!("{:<24} {:>6} {:>6.1} {:>8.1}% {:>8.1}%",
                 row.name, kind, row.mpki,
                 100.0 * (row.speedup_55 - 1.0),
                 100.0 * (row.speedup_85 - 1.0));
        csv.row(&[
            row.name.clone(), kind.to_string(), format!("{}", row.mpki),
            format!("{}", row.intensive),
            format!("{}", row.speedup_55), format!("{}", row.speedup_85),
        ]);
    }
    csv.write(out, file)?;

    println!("---");
    println!("single-core memory-intensive gmean: {:>5.1}% @55C, {:>5.1}% @85C",
             100.0 * (r.gmean_intensive_55 - 1.0),
             100.0 * (r.gmean_intensive_85 - 1.0));
    println!("single-core non-intensive gmean:    {:>5.1}% @55C, {:>5.1}% @85C",
             100.0 * (r.gmean_nonintensive_55 - 1.0),
             100.0 * (r.gmean_nonintensive_85 - 1.0));
    println!("mix weighted-speedup gmean:         {:>5.1}% @55C, {:>5.1}% @85C",
             100.0 * (r.gmean_mix_55 - 1.0),
             100.0 * (r.gmean_mix_85 - 1.0));
    Ok(())
}

/// Regenerate the Fig-6 table for one profiled module's table, fanning
/// (unit × temperature × side) out over `jobs` pool workers.
#[allow(clippy::too_many_arguments)]
pub fn fig6(cycles: u64, jobs: usize, table: &AlDram, label: &str,
            seed: &str, workloads: &[WorkloadSpec], mixes: &[MixSpec],
            out: &Path) -> Result<Fig6Result> {
    let r = fig6_eval(cycles, jobs, table, seed, workloads, mixes);
    println!("== Fig 6 (profiled {label}): per-workload AL-DRAM improvement, \
              {} workloads + {} mixes x {{55C, 85C}} ({jobs} jobs, seed \
              {seed}) ==",
             workloads.len(), mixes.len());
    print_and_csv(&r, out, "fig6.csv")?;
    Ok(r)
}

/// [`fig6`] at region granularity: the grid runs twice — module-uniform
/// collapse, then region-indexed — and the summary reports the gmean
/// weighted-speedup delta region indexing buys at each operating point.
/// Returns the region-indexed result.
#[allow(clippy::too_many_arguments)]
pub fn fig6_regions(cycles: u64, jobs: usize, table: &RegionTable,
                    label: &str, seed: &str, workloads: &[WorkloadSpec],
                    mixes: &[MixSpec], out: &Path) -> Result<Fig6Result> {
    let uni = fig6_regions_eval(cycles, jobs, &table.collapsed(), seed,
                                workloads, mixes);
    let reg = fig6_regions_eval(cycles, jobs, table, seed, workloads, mixes);
    println!("== Fig 6 (profiled {label}, region-indexed {} banks x {} \
              regions): {} workloads + {} mixes x {{55C, 85C}} ({jobs} \
              jobs, seed {seed}) ==",
             table.banks(), table.regions_per_bank(), workloads.len(),
             mixes.len());
    print_and_csv(&reg, out, "fig6_regions.csv")?;
    let gmean_ratio = |hot: bool| -> f64 {
        let v: Vec<f64> = reg
            .rows
            .iter()
            .zip(&uni.rows)
            .map(|(r, u)| if hot { r.speedup_85 / u.speedup_85 }
                          else { r.speedup_55 / u.speedup_55 })
            .collect();
        util::geomean(&v)
    };
    println!("region-indexed vs module-uniform gmean weighted-speedup \
              delta: {:+.2}% @55C, {:+.2}% @85C",
             100.0 * (gmean_ratio(false) - 1.0),
             100.0 * (gmean_ratio(true) - 1.0));
    Ok(reg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aldram::DEFAULT_BIN_C;
    use crate::model::params;
    use crate::population::generate_dimm;
    use crate::profiler::profile_dimm;
    use crate::runtime::NativeBackend;
    use crate::workloads::{by_name, mix};

    #[test]
    fn fig6_smoke() {
        let d = generate_dimm(0, 64, params());
        let mut b = NativeBackend::new();
        let p = profile_dimm(&mut b, &d).unwrap();
        let table = AlDram::from_profile(&p, DEFAULT_BIN_C);
        let dir = std::env::temp_dir().join("aldram_fig6_test");
        let ws = vec![by_name("gups").unwrap(), by_name("povray").unwrap()];
        let mixes: Vec<_> = mix::suite().into_iter().take(1).collect();
        let r = fig6(4_000, 2, &table, "dimm 000", "0", &ws, &mixes, &dir)
            .unwrap();
        assert_eq!(r.rows.len(), 3);
        assert!(dir.join("fig6.csv").exists());
    }
}
