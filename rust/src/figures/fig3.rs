//! Fig 3: population-level analysis of 115 DIMMs.
//!
//! 3a/3b — per-DIMM maximum error-free refresh interval (module line +
//!          per-bank dots), read and write tests.
//! 3c/3d — per-DIMM acceptable latency sums at 85degC and 55degC against
//!          the DDR3 standard, with population averages.

use std::path::Path;

use anyhow::Result;

use crate::figures::calibrate::{self, run as campaign, CalibrationReport};
use crate::runtime::ProfilingBackend;
use crate::timing::TimingParams;

use super::csv::Csv;

pub fn fig3(backend: &mut dyn ProfilingBackend, n_dimms: usize, cells: usize,
            out: &Path) -> Result<CalibrationReport> {
    let report = campaign(backend, n_dimms, cells)?;
    render(report, out)
}

/// Fig 3 with the population campaign fanned out over the job pool (one
/// job per DIMM; see `calibrate::run_par`).
pub fn fig3_par<F>(make_backend: F, n_dimms: usize, cells: usize,
                   jobs: usize, out: &Path) -> Result<CalibrationReport>
where
    F: Fn() -> Box<dyn ProfilingBackend> + Sync,
{
    let report = calibrate::run_par(make_backend, n_dimms, cells, jobs)?;
    render(report, out)
}

fn render(report: CalibrationReport, out: &Path) -> Result<CalibrationReport> {
    // --- 3a / 3b ---------------------------------------------------------
    let mut csv = Csv::new(&["dimm", "vendor", "kind", "module_max_ms",
                             "bank_min_ms", "bank_max_ms"]);
    println!("== Fig 3a/3b: max error-free refresh interval per DIMM @85C ==");
    for p in &report.profiles {
        let bmin = |v: &[f64]| v.iter().cloned().fold(f64::MAX, f64::min);
        let bmax = |v: &[f64]| v.iter().cloned().fold(f64::MIN, f64::max);
        csv.row(&[
            format!("{}", p.id), p.vendor.clone(), "read".into(),
            format!("{}", p.refresh85.module_max_read_ms),
            format!("{}", bmin(&p.refresh85.bank_max_read_ms)),
            format!("{}", bmax(&p.refresh85.bank_max_read_ms)),
        ]);
        csv.row(&[
            format!("{}", p.id), p.vendor.clone(), "write".into(),
            format!("{}", p.refresh85.module_max_write_ms),
            format!("{}", bmin(&p.refresh85.bank_max_write_ms)),
            format!("{}", bmax(&p.refresh85.bank_max_write_ms)),
        ]);
    }
    csv.write(out, "fig3ab.csv")?;
    let reads: Vec<f64> = report.max_read_ms.clone();
    let writes: Vec<f64> = report.max_write_ms.clone();
    let minmax = |v: &[f64]| (v.iter().cloned().fold(f64::MAX, f64::min),
                              v.iter().cloned().fold(f64::MIN, f64::max));
    let (rlo, rhi) = minmax(&reads);
    let (wlo, whi) = minmax(&writes);
    println!("read : {rlo:.0}..{rhi:.0} ms across {} DIMMs (std: 64 ms)",
             reads.len());
    println!("write: {wlo:.0}..{whi:.0} ms across {} DIMMs", writes.len());

    // --- 3c / 3d ---------------------------------------------------------
    let std = TimingParams::ddr3_standard();
    let mut csv = Csv::new(&["dimm", "vendor", "test", "sum85_ns", "sum55_ns",
                             "std_ns"]);
    for p in &report.profiles {
        csv.row(&[
            format!("{}", p.id), p.vendor.clone(), "read".into(),
            format!("{}", p.at85.read.sum_ns),
            format!("{}", p.at55.read.sum_ns),
            format!("{}", std.read_sum_ns()),
        ]);
        csv.row(&[
            format!("{}", p.id), p.vendor.clone(), "write".into(),
            format!("{}", p.at85.write.sum_ns),
            format!("{}", p.at55.write.sum_ns),
            format!("{}", std.write_sum_ns()),
        ]);
    }
    csv.write(out, "fig3cd.csv")?;

    let s = &report.summary;
    println!("== Fig 3c: read latency (tRCD+tRAS+tRP, std {:.1} ns) ==",
             std.read_sum_ns());
    println!("average reduction: {:.1}% @85C (paper 21.1), {:.1}% @55C (paper 32.7)",
             100.0 * s.read_reduction_85, 100.0 * s.read_reduction_55);
    println!("== Fig 3d: write latency (tRCD+tWR+tRP, std {:.1} ns) ==",
             std.write_sum_ns());
    println!("average reduction: {:.1}% @85C (paper 34.4), {:.1}% @55C (paper 55.1)",
             100.0 * s.write_reduction_85, 100.0 * s.write_reduction_55);
    println!(
        "per-parameter averages @55C: tRCD {:.1}% tRAS {:.1}% tWR {:.1}% tRP {:.1}% \
         (paper 17.3/37.7/54.8/35.2)",
        100.0 * s.param_reduction_55[0], 100.0 * s.param_reduction_55[1],
        100.0 * s.param_reduction_55[2], 100.0 * s.param_reduction_55[3]
    );
    println!(
        "per-parameter averages @85C: tRCD {:.1}% tRAS {:.1}% tWR {:.1}% tRP {:.1}% \
         (paper 15.6/20.4/20.6/28.5)",
        100.0 * s.param_reduction_85[0], 100.0 * s.param_reduction_85[1],
        100.0 * s.param_reduction_85[2], 100.0 * s.param_reduction_85[3]
    );
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;

    #[test]
    fn fig3_small_population_runs() {
        let mut b = NativeBackend::new();
        let dir = std::env::temp_dir().join("aldram_fig3_test");
        let r = fig3(&mut b, 4, 64, &dir).unwrap();
        assert_eq!(r.profiles.len(), 4);
        assert!(dir.join("fig3ab.csv").exists());
        assert!(dir.join("fig3cd.csv").exists());
        // vendor labels present
        assert!(r.profiles.iter().all(|p| p.vendor.starts_with("vendor_")));
    }
}
