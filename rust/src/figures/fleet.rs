//! Fleet campaign report: speedup CDF, per-archetype breakdown with the
//! slowest decile, and the re-profiling-budget sweep — all derived from
//! the streamed [`FleetSummary`] alone (no per-node data exists to read).

use std::path::Path;

use anyhow::Result;

use crate::fleet::FleetSummary;

use super::csv::Csv;

/// Print the campaign report and write `fleet_cdf.csv`,
/// `fleet_archetypes.csv`, and `fleet_budget.csv` under `out`.
pub fn report(s: &FleetSummary, out: &Path) -> Result<()> {
    anyhow::ensure!(s.nodes > 0, "fleet summary is empty");
    println!("== Fleet campaign: {} nodes x {} archetypes ==",
             s.nodes, s.archetypes());

    println!("speedup: mean {:.4}  p10 {:.4}  p50 {:.4}  p90 {:.4}  \
              [{:.4}, {:.4}]",
             s.speedup.mean(), s.speedup.quantile(0.1),
             s.speedup.quantile(0.5), s.speedup.quantile(0.9),
             s.speedup.min(), s.speedup.max());
    println!("read latency (cycles): mean {:.1}  p50 {:.1}  p90 {:.1}",
             s.latency.mean(), s.latency.quantile(0.5),
             s.latency.quantile(0.9));
    println!("peak DIMM temp (degC): mean {:.1}  p90 {:.1}  max {:.1}",
             s.peak_temp.mean(), s.peak_temp.quantile(0.9), s.peak_temp.max());
    println!("error budget: {} bin-crossing nodes ({:.2}%), {} fallback \
              nodes ({:.2}%)",
             s.bin_crossing_nodes,
             100.0 * s.bin_crossing_nodes as f64 / s.nodes as f64,
             s.fallback_nodes,
             100.0 * s.fallback_nodes as f64 / s.nodes as f64);

    let mut cdf = Csv::new(&["speedup", "cum_frac"]);
    for (x, f) in s.speedup.cdf() {
        cdf.rowf(&[x, f]);
    }
    cdf.write(out, "fleet_cdf.csv")?;

    let mut arch = Csv::new(&["archetype", "nodes", "mean_speedup",
                              "p10_speedup"]);
    println!("{:<10} {:>7} {:>12} {:>12}",
             "archetype", "nodes", "mean", "p10");
    for i in 0..s.archetypes() {
        let n = s.archetype_nodes[i];
        let (mean, p10) = if n > 0 {
            (s.archetype_speedup[i].mean(), s.archetype_speedup[i].quantile(0.1))
        } else {
            (f64::NAN, f64::NAN)
        };
        if n > 0 {
            println!("{:<10} {:>7} {:>12.4} {:>12.4}", i, n, mean, p10);
        }
        arch.rowf(&[i as f64, n as f64, mean, p10]);
    }
    arch.write(out, "fleet_archetypes.csv")?;
    if let Some((p10, worst, mean, share)) = s.slowest_decile() {
        println!("slowest decile: fleet p10 {:.4}; weakest archetype {} \
                  (mean {:.4}, {:.1}% of nodes)",
                 p10, worst, mean, 100.0 * share);
    }

    let mut budget = Csv::new(&["profiled_archetypes", "fleet_mean_speedup"]);
    println!("re-profiling budget sweep (profile top-K archetypes by \
              population, rest run standard timings):");
    for (k, mean) in s.budget_sweep() {
        println!("  K={k:<3} fleet mean speedup {mean:.4}");
        budget.rowf(&[k as f64, mean]);
    }
    budget.write(out, "fleet_budget.csv")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::NodeOutcome;
    use crate::util::rng::Rng;

    #[test]
    fn report_smoke() {
        let mut rng = Rng::from_label("figures/fleet");
        let mut s = FleetSummary::new(3);
        for _ in 0..120 {
            s.record(&NodeOutcome {
                archetype: rng.below(3) as usize,
                speedup: rng.range(1.02, 1.25),
                read_latency_cycles: rng.range(50.0, 200.0),
                peak_temp_c: rng.range(25.0, 45.0),
                bin_crossing: rng.chance(0.1),
                fallback: false,
            });
        }
        let dir = std::env::temp_dir().join("aldram_fleet_report_test");
        report(&s, &dir).unwrap();
        for f in ["fleet_cdf.csv", "fleet_archetypes.csv", "fleet_budget.csv"] {
            assert!(dir.join(f).exists(), "{f} missing");
        }
        assert!(report(&FleetSummary::new(2), &dir).is_err());
    }
}
