//! Fixed-memory fleet aggregates.
//!
//! A campaign folds every node into one [`FleetSummary`] — streaming
//! histograms plus per-archetype sub-histograms and error-budget
//! counters. The struct's size is O(archetypes × bins), independent of
//! how many nodes were simulated; per-node results are never
//! materialized. Every field is an exact commutative accumulator (see
//! `util::hist`), so [`FleetSummary::merge`] is partition-invariant and
//! the campaign is bit-identical across `--jobs` and `--chunk` choices —
//! the property `tests/integration_fleet.rs` pins.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::util::hist::StreamHist;
use crate::util::json::Json;

/// Histogram grids `(lo, hi, bins)` — fixed per format version so
/// summaries from different runs always merge.
pub const SPEEDUP_GRID: (f64, f64, usize) = (0.5, 2.0, 150);
pub const LATENCY_GRID: (f64, f64, usize) = (0.0, 400.0, 100);
pub const TEMP_GRID: (f64, f64, usize) = (10.0, 100.0, 90);
/// Per-archetype speedup sub-histograms are coarser — there are
/// `archetypes` of them and they only feed mean/decile analysis.
pub const ARCHETYPE_BINS: usize = 50;

/// What one simulated node contributes to the aggregate.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeOutcome {
    pub archetype: usize,
    /// AL-DRAM over standard-timing IPC ratio on the node's workload.
    pub speedup: f64,
    /// Average read latency (controller cycles) of the AL-DRAM run.
    pub read_latency_cycles: f64,
    /// Worst DIMM temperature across the node's simulated day.
    pub peak_temp_c: f64,
    /// The node's day crosses a timing-table bin boundary, so its
    /// controller must re-bin at runtime.
    pub bin_crossing: bool,
    /// The node's peak temperature exceeds the hottest profiled anchor
    /// (85degC) — its profile is unusable there and it falls back to
    /// standard timings.
    pub fallback: bool,
}

/// The campaign aggregate. All counters are exact and commutative;
/// `PartialEq` is bitwise on every accumulator, which is what the
/// determinism tests compare.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSummary {
    pub nodes: u64,
    pub speedup: StreamHist,
    pub latency: StreamHist,
    pub peak_temp: StreamHist,
    pub archetype_nodes: Vec<u64>,
    pub archetype_speedup: Vec<StreamHist>,
    /// Error-budget counters (see [`NodeOutcome`]).
    pub bin_crossing_nodes: u64,
    pub fallback_nodes: u64,
}

impl FleetSummary {
    pub fn new(archetypes: usize) -> Self {
        assert!(archetypes >= 1);
        let hist = |(lo, hi, bins): (f64, f64, usize)| StreamHist::new(lo, hi, bins);
        FleetSummary {
            nodes: 0,
            speedup: hist(SPEEDUP_GRID),
            latency: hist(LATENCY_GRID),
            peak_temp: hist(TEMP_GRID),
            archetype_nodes: vec![0; archetypes],
            archetype_speedup: (0..archetypes)
                .map(|_| StreamHist::new(SPEEDUP_GRID.0, SPEEDUP_GRID.1,
                                         ARCHETYPE_BINS))
                .collect(),
            bin_crossing_nodes: 0,
            fallback_nodes: 0,
        }
    }

    pub fn archetypes(&self) -> usize {
        self.archetype_nodes.len()
    }

    pub fn record(&mut self, o: &NodeOutcome) {
        assert!(o.archetype < self.archetypes(),
                "archetype {} out of range", o.archetype);
        self.nodes += 1;
        self.speedup.record(o.speedup);
        self.latency.record(o.read_latency_cycles);
        self.peak_temp.record(o.peak_temp_c);
        self.archetype_nodes[o.archetype] += 1;
        self.archetype_speedup[o.archetype].record(o.speedup);
        self.bin_crossing_nodes += o.bin_crossing as u64;
        self.fallback_nodes += o.fallback as u64;
    }

    /// Merge a worker partial into this one (exact, commutative — see
    /// module docs).
    pub fn merge(&mut self, other: &FleetSummary) {
        assert_eq!(self.archetypes(), other.archetypes(),
                   "merging summaries over different catalogs");
        self.nodes += other.nodes;
        self.speedup.merge(&other.speedup);
        self.latency.merge(&other.latency);
        self.peak_temp.merge(&other.peak_temp);
        for (a, b) in self.archetype_nodes.iter_mut()
            .zip(&other.archetype_nodes) {
            *a += b;
        }
        for (a, b) in self.archetype_speedup.iter_mut()
            .zip(&other.archetype_speedup) {
            a.merge(b);
        }
        self.bin_crossing_nodes += other.bin_crossing_nodes;
        self.fallback_nodes += other.fallback_nodes;
    }

    /// Re-profiling-budget sweep: with a budget of `K` characterizations,
    /// an operator profiles the `K` most-populous archetypes (ties to the
    /// lower index) and leaves the rest on standard timings (speedup 1.0).
    /// Returns `(K, fleet mean speedup)` for `K = 0..=archetypes` —
    /// computed from the per-archetype sub-histograms alone, no
    /// re-simulation.
    pub fn budget_sweep(&self) -> Vec<(usize, f64)> {
        let a = self.archetypes();
        let mut order: Vec<usize> = (0..a).collect();
        order.sort_by_key(|i| (std::cmp::Reverse(self.archetype_nodes[*i]), *i));
        let mut out = Vec::with_capacity(a + 1);
        if self.nodes == 0 {
            return (0..=a).map(|k| (k, 1.0)).collect();
        }
        // Start from "everyone standard" and add archetypes in
        // population order.
        let mut covered_sum = 0.0;
        let mut covered_nodes = 0u64;
        for k in 0..=a {
            if k > 0 {
                let i = order[k - 1];
                let n = self.archetype_nodes[i];
                if n > 0 {
                    covered_sum += self.archetype_speedup[i].mean() * n as f64;
                    covered_nodes += n;
                }
            }
            let uncovered = (self.nodes - covered_nodes) as f64;
            out.push((k, (covered_sum + uncovered) / self.nodes as f64));
        }
        out
    }

    /// Slowest-decile analysis: the fleet-wide p10 speedup and the
    /// archetype with the lowest mean speedup (index, mean, node share).
    pub fn slowest_decile(&self) -> Option<(f64, usize, f64, f64)> {
        if self.nodes == 0 {
            return None;
        }
        let p10 = self.speedup.quantile(0.1);
        let worst = (0..self.archetypes())
            .filter(|i| self.archetype_nodes[*i] > 0)
            .min_by(|a, b| {
                self.archetype_speedup[*a].mean()
                    .total_cmp(&self.archetype_speedup[*b].mean())
            })?;
        Some((p10, worst, self.archetype_speedup[worst].mean(),
              self.archetype_nodes[worst] as f64 / self.nodes as f64))
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("format_version".into(), Json::Num(1.0));
        m.insert("nodes".into(), Json::Num(self.nodes as f64));
        m.insert("speedup".into(), self.speedup.to_json());
        m.insert("latency".into(), self.latency.to_json());
        m.insert("peak_temp".into(), self.peak_temp.to_json());
        m.insert("archetype_nodes".into(),
                 Json::Arr(self.archetype_nodes.iter()
                           .map(|n| Json::Num(*n as f64)).collect()));
        m.insert("archetype_speedup".into(),
                 Json::Arr(self.archetype_speedup.iter()
                           .map(StreamHist::to_json).collect()));
        m.insert("bin_crossing_nodes".into(),
                 Json::Num(self.bin_crossing_nodes as f64));
        m.insert("fallback_nodes".into(),
                 Json::Num(self.fallback_nodes as f64));
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<FleetSummary> {
        let count = |k: &str| -> Result<u64> {
            let x = j.get(k).and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("summary missing `{k}`"))?;
            anyhow::ensure!(x >= 0.0 && x.fract() == 0.0,
                            "summary `{k}` is not a count: {x}");
            Ok(x as u64)
        };
        let version = count("format_version")?;
        anyhow::ensure!(version == 1, "unknown fleet summary version {version}");
        let hist = |k: &str| -> Result<StreamHist> {
            StreamHist::from_json(
                j.get(k).ok_or_else(|| anyhow::anyhow!("summary missing `{k}`"))?)
        };
        let archetype_nodes = j.get("archetype_nodes").and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("summary missing `archetype_nodes`"))?
            .iter()
            .map(|v| {
                let x = v.as_f64()
                    .ok_or_else(|| anyhow::anyhow!("non-number archetype count"))?;
                anyhow::ensure!(x >= 0.0 && x.fract() == 0.0,
                                "archetype count is not a count: {x}");
                Ok(x as u64)
            })
            .collect::<Result<Vec<u64>>>()?;
        let archetype_speedup = j.get("archetype_speedup").and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("summary missing `archetype_speedup`"))?
            .iter()
            .map(StreamHist::from_json)
            .collect::<Result<Vec<StreamHist>>>()?;
        let s = FleetSummary {
            nodes: count("nodes")?,
            speedup: hist("speedup")?,
            latency: hist("latency")?,
            peak_temp: hist("peak_temp")?,
            archetype_nodes,
            archetype_speedup,
            bin_crossing_nodes: count("bin_crossing_nodes")?,
            fallback_nodes: count("fallback_nodes")?,
        };
        anyhow::ensure!(!s.archetype_nodes.is_empty(), "summary has no archetypes");
        anyhow::ensure!(s.archetype_nodes.len() == s.archetype_speedup.len(),
                        "archetype arrays disagree");
        anyhow::ensure!(s.archetype_nodes.iter().sum::<u64>() == s.nodes,
                        "archetype node counts do not add up");
        anyhow::ensure!(s.speedup.count() == s.nodes,
                        "speedup histogram count disagrees with nodes");
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn outcome(rng: &mut Rng, archetypes: usize) -> NodeOutcome {
        let archetype = rng.below(archetypes as u64) as usize;
        NodeOutcome {
            archetype,
            // Per-archetype speedup level + noise, all above 1.0 so the
            // budget sweep's monotonicity precondition holds.
            speedup: 1.05 + 0.05 * archetype as f64 + rng.range(0.0, 0.04),
            read_latency_cycles: rng.range(40.0, 220.0),
            peak_temp_c: rng.range(24.0, 48.0),
            bin_crossing: rng.chance(0.2),
            fallback: false,
        }
    }

    fn filled(label: &str, n: usize, archetypes: usize) -> FleetSummary {
        let mut rng = Rng::from_label(label);
        let mut s = FleetSummary::new(archetypes);
        for _ in 0..n {
            s.record(&outcome(&mut rng, archetypes));
        }
        s
    }

    #[test]
    fn merge_is_partition_invariant() {
        let whole = filled("fleet-summary/part", 300, 5);
        let mut rng = Rng::from_label("fleet-summary/part");
        for cut in [1usize, 37, 150, 299] {
            let mut lo = FleetSummary::new(5);
            let mut hi = FleetSummary::new(5);
            for i in 0..300 {
                let o = outcome(&mut rng, 5);
                if i < cut { lo.record(&o) } else { hi.record(&o) }
            }
            // Merge in both orders — commutativity.
            let mut a = FleetSummary::new(5);
            a.merge(&hi);
            a.merge(&lo);
            assert_eq!(a, whole, "cut {cut}");
            rng = Rng::from_label("fleet-summary/part");
        }
    }

    #[test]
    fn budget_sweep_is_monotone_and_anchored() {
        let s = filled("fleet-summary/budget", 400, 6);
        let sweep = s.budget_sweep();
        assert_eq!(sweep.len(), 7);
        assert_eq!(sweep[0], (0, 1.0), "no budget means everyone standard");
        // Every archetype mean is > 1.0 by construction, so coverage can
        // only help; the full budget hits the unconstrained fleet mean.
        for w in sweep.windows(2) {
            assert!(w[1].1 >= w[0].1 - 1e-12,
                    "budget sweep not monotone: {sweep:?}");
        }
        let full = sweep.last().unwrap().1;
        assert!((full - s.speedup.mean()).abs() < 1e-6,
                "full budget {full} != fleet mean {}", s.speedup.mean());
    }

    #[test]
    fn slowest_decile_points_at_the_weakest_archetype() {
        let s = filled("fleet-summary/decile", 400, 4);
        let (p10, worst, mean, share) = s.slowest_decile().unwrap();
        // Archetype 0 has the lowest speedup level by construction.
        assert_eq!(worst, 0);
        assert!(p10 >= 1.0 && mean >= 1.0 && share > 0.0 && share < 1.0);
        assert!(FleetSummary::new(3).slowest_decile().is_none());
    }

    #[test]
    fn json_round_trips_exactly() {
        let s = filled("fleet-summary/json", 250, 4);
        let text = s.to_json().to_string_pretty();
        let back =
            FleetSummary::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn corrupt_summary_fails_loudly() {
        let s = filled("fleet-summary/corrupt", 50, 3);
        let good = s.to_json().to_string_pretty();
        let bad = good.replace("\"nodes\": 50", "\"nodes\": 51");
        assert!(FleetSummary::from_json(&Json::parse(&bad).unwrap()).is_err(),
                "node count mismatch accepted");
    }
}
