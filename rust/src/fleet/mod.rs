//! Fleet-scale campaign engine (DESIGN.md §15).
//!
//! Scales the single-DIMM pipeline (profile → install → simulate) to
//! O(10^3..10^4) nodes: each node is one server drawing its DIMM from an
//! archetype catalog ([`crate::population::archetype_catalog`]) and its
//! environment from a per-node ambient model (rack position, season,
//! diurnal cycle). Nodes are sharded over [`crate::exec::Pool::run_fold`]
//! in bounded chunks and folded online into a fixed-memory
//! [`FleetSummary`] — per-node results are never materialized, so a
//! 10^4-node campaign uses the same memory as a 10-node one.
//!
//! The perf core is profile memoization: every node of an archetype bin
//! shares bit-identical silicon, so a content-keyed
//! [`crate::registry::ProfileStore`] collapses 10^4 characterizations to
//! O(archetypes) — a miss runs the probed-SIMD sweep battery warm-seeded
//! from the nearest cached archetype, a hit reuses the stored table
//! outright. `repro fleet run` reports the hit rate and benches the
//! memoized characterization against the profile-every-node baseline
//! (`SPEEDUP[FLEET]`, trajectory in `BENCH_FLEET.json`).
//!
//! Determinism: every per-node quantity is a pure function of
//! `(campaign seed, node index)`, and the summary fold is an exact
//! commutative monoid, so campaign results are bit-identical across
//! `--jobs`, `--chunk`, and cache hit/miss paths (the cache stores what
//! profiling would have produced). Only the hit/miss *counts* are
//! schedule-dependent (concurrent first touches of one archetype can
//! both miss); `tests/integration_fleet.rs` pins all of this.

pub mod summary;

pub use summary::{FleetSummary, NodeOutcome};

use std::sync::Arc;

use crate::aldram::{AlDram, ThermalModel, DEFAULT_BIN_C};
use crate::exec::Pool;
use crate::mem::{System, SystemConfig};
use crate::model::params;
use crate::population::{archetype_catalog, generate_dimm, Archetype};
use crate::profiler::profile_dimm_seeded;
use crate::registry::{ProfileStore, StoredProfile};
use crate::runtime::SimdBackend;
use crate::util::rng::Rng;
use crate::workloads::{suite, WorkloadSpec};

/// Steps in the simulated-day thermal sweep (15-minute resolution).
const DAY_STEPS: usize = 96;
const DAY_STEP_S: f64 = 900.0;
/// Hottest profiled anchor: a node whose DIMM exceeds this falls back to
/// standard timings (error-budget counter, not a simulated path).
const PROFILE_CEILING_C: f64 = 85.0;

/// Campaign parameters. `Default` is the `repro fleet run` baseline shape
/// (overridable per flag); tests shrink `cells`/`cycles` for speed.
#[derive(Debug, Clone)]
pub struct FleetSpec {
    pub nodes: usize,
    /// Catalog size — distinct DIMM designs fielded across the fleet.
    pub archetypes: usize,
    /// Per-chip-bank sampling resolution of each archetype's arrays.
    pub cells: usize,
    /// Simulated controller cycles per (base, AL-DRAM) run.
    pub cycles: u64,
    /// Campaign seed label; every node derives from `fleet/<seed>/node/<i>`.
    pub seed: String,
    /// Nodes per work-claim (`Pool::run_fold` chunk).
    pub chunk: usize,
    /// Content-keyed profile memoization (off = profile every node; the
    /// bench baseline).
    pub memoize: bool,
    /// Workload variety: nodes draw from the first `workloads` entries of
    /// the suite.
    pub workloads: usize,
}

impl Default for FleetSpec {
    fn default() -> Self {
        FleetSpec {
            nodes: 1000,
            archetypes: 12,
            cells: 96,
            cycles: 12_000,
            seed: "0".into(),
            chunk: 32,
            memoize: true,
            workloads: 6,
        }
    }
}

/// Per-node ambient temperature model: rack inlet (cold-aisle temperature
/// plus vertical stratification), a seasonal offset, and a diurnal
/// sinusoid. All parameters are sampled once per node from its seed
/// stream, so a node's environment is part of its identity.
#[derive(Debug, Clone, PartialEq)]
pub struct AmbientModel {
    /// Rack inlet at this node's height, degC.
    pub inlet_c: f64,
    /// Seasonal offset, degC.
    pub seasonal_c: f64,
    /// Diurnal swing amplitude, degC.
    pub diurnal_amp_c: f64,
    /// Diurnal phase, fraction of a day.
    pub phase: f64,
    /// Cooling-fault excess, degC — 0 for healthy nodes. A few percent
    /// of fleet nodes sit behind a failed fan or blocked tile and run
    /// far above the aisle setpoint; they are what the error-budget
    /// counters (bin crossings, >85degC fallbacks) exist to count —
    /// healthy racks never leave the coolest timing bin.
    pub hotspot_c: f64,
}

/// Fraction of nodes with a cooling fault, and its excess range.
const HOTSPOT_RATE: f64 = 0.03;
const HOTSPOT_RANGE_C: (f64, f64) = (8.0, 45.0);

impl AmbientModel {
    fn sample(rng: &mut Rng) -> Self {
        // Cold-aisle setpoint varies by row; hot air stratifies upward so
        // higher rack positions run ~4degC warmer at the top.
        let row_inlet = rng.range(18.0, 24.0);
        let height = rng.f64();
        let inlet_c = row_inlet + 4.0 * height;
        let seasonal_c = rng.range(-3.0, 5.0);
        let diurnal_amp_c = rng.range(0.5, 2.5);
        let phase = rng.f64();
        let hotspot_c = if rng.chance(HOTSPOT_RATE) {
            rng.range(HOTSPOT_RANGE_C.0, HOTSPOT_RANGE_C.1)
        } else {
            0.0
        };
        AmbientModel { inlet_c, seasonal_c, diurnal_amp_c, phase, hotspot_c }
    }

    /// Ambient at `day_frac` in [0, 1) of the simulated day.
    pub fn ambient_at(&self, day_frac: f64) -> f64 {
        self.inlet_c + self.hotspot_c + self.seasonal_c
            + self.diurnal_amp_c
                * (std::f64::consts::TAU * (day_frac + self.phase)).sin()
    }
}

/// Everything a node is, derived purely from `(campaign seed, index)`:
/// which archetype it fields, which workload it runs, its ambient model,
/// and the time of day its speedup window is observed.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeSpec {
    pub index: usize,
    pub archetype: usize,
    pub workload: usize,
    pub ambient: AmbientModel,
    /// Day fraction at which the (base, AL-DRAM) windows are simulated.
    pub obs: f64,
}

/// Derive node `i`'s spec. Draw order is part of the campaign format —
/// reordering draws changes every node identity.
pub fn node_spec(spec: &FleetSpec, i: usize) -> NodeSpec {
    let mut rng = Rng::from_label(&format!("fleet/{}/node/{i}", spec.seed));
    let archetype = rng.below(spec.archetypes as u64) as usize;
    let workload = rng.below(spec.workloads as u64) as usize;
    let ambient = AmbientModel::sample(&mut rng);
    let obs = rng.f64();
    NodeSpec { index: i, archetype, workload, ambient, obs }
}

/// Characterize one archetype through the store: identity fast path
/// (repeat node of a known `(dimm_id, cells)` — no array regeneration),
/// then content-key lookup, then a real profiling run warm-seeded from
/// the nearest cached neighbor. With `store == None` (memoization off)
/// every call profiles from scratch — the bench baseline.
fn characterize(backend: &mut SimdBackend, at: &Archetype, cells: usize,
                store: Option<&ProfileStore>) -> Arc<StoredProfile> {
    if let Some(store) = store {
        if let Some(sp) = store.cached_key(at.dimm_id, cells)
            .and_then(|key| store.get(key)) {
            return sp;
        }
    }
    let dimm = generate_dimm(at.dimm_id, cells, params());
    let key = dimm.arrays.content_key();
    if let Some(store) = store {
        if let Some(sp) = store.get(key) {
            return sp;
        }
    }
    let seed = store.and_then(|s| s.nearest_seed(at.vendor_idx, at.speed_bin));
    let (profile, read85, write85) = profile_dimm_seeded(
        backend, &dimm,
        seed.as_deref().map(|sp| (&sp.read85, &sp.write85)))
        .expect("archetype characterization failed");
    let table = AlDram::from_profile(&profile, DEFAULT_BIN_C);
    let sp = StoredProfile {
        profile,
        table,
        read85,
        write85,
        vendor_idx: at.vendor_idx,
        speed_bin: at.speed_bin,
    };
    match store {
        Some(store) => store.insert(key, at.dimm_id, cells, sp),
        None => Arc::new(sp),
    }
}

/// Simulate node `ns` with its installed table and fold the outcome.
/// The speedup window runs at the node's observation-time ambient; the
/// error-budget counters come from sweeping its DIMM temperature across
/// the whole simulated day under the AL-DRAM run's bus load.
fn simulate_node(spec: &FleetSpec, ns: &NodeSpec, sp: &StoredProfile,
                 workloads: &[WorkloadSpec]) -> NodeOutcome {
    let w = &workloads[ns.workload];
    let label = format!("fleet/{}/node/{}", spec.seed, ns.index);
    let ambient_now = ns.ambient.ambient_at(ns.obs);
    let run = |aldram: Option<AlDram>| {
        let cfg = SystemConfig::paper_default()
            .with_aldram(aldram)
            .with_ambient(ambient_now);
        System::new(&cfg, &[(w.clone(), label.clone())]).run_fast(spec.cycles)
    };
    let base = run(None);
    let fast = run(Some(sp.table.clone()));
    let throughput = |s: &crate::mem::SystemStats|
        s.cores.iter().map(|c| c.ipc).sum::<f64>();
    let speedup = throughput(&fast) / throughput(&base);

    // Day sweep: track the DIMM temperature envelope under the AL-DRAM
    // run's bus load as the ambient walks the node's diurnal cycle.
    let mut thermal = ThermalModel::new(ns.ambient.ambient_at(0.0));
    let (mut peak, mut trough) = (f64::NEG_INFINITY, f64::INFINITY);
    for s in 0..DAY_STEPS {
        let frac = s as f64 / DAY_STEPS as f64;
        thermal.set_ambient(ns.ambient.ambient_at(frac));
        let t = thermal.step(DAY_STEP_S, fast.bus_utilization);
        peak = peak.max(t);
        trough = trough.min(t);
    }
    NodeOutcome {
        archetype: ns.archetype,
        speedup,
        read_latency_cycles: fast.avg_read_latency_cycles,
        peak_temp_c: peak,
        bin_crossing: sp.table.bin_index(peak) != sp.table.bin_index(trough),
        fallback: peak > PROFILE_CEILING_C,
    }
}

/// What a campaign returns: the streamed aggregate plus cache telemetry.
/// `hits`/`misses` are schedule-dependent (see module docs); `summary`
/// is not.
#[derive(Debug, Clone)]
pub struct CampaignResult {
    pub summary: FleetSummary,
    pub hits: u64,
    pub misses: u64,
    /// Distinct characterizations held at the end (O(archetypes)).
    pub unique_profiles: usize,
}

impl CampaignResult {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 { 0.0 } else { self.hits as f64 / total as f64 }
    }
}

/// Run the campaign: shard `spec.nodes` over `jobs` workers in
/// `spec.chunk`-node claims, characterize through the shared store, and
/// fold every node into one [`FleetSummary`].
pub fn run_campaign(spec: &FleetSpec, jobs: usize) -> CampaignResult {
    assert!(spec.nodes >= 1 && spec.archetypes >= 1 && spec.workloads >= 1);
    let catalog = archetype_catalog(spec.archetypes, params());
    let workloads: Vec<WorkloadSpec> =
        suite().into_iter().take(spec.workloads).collect();
    assert_eq!(workloads.len(), spec.workloads,
               "suite has fewer than {} workloads", spec.workloads);
    let store = spec.memoize.then(ProfileStore::new);

    let summary = Pool::new(jobs).run_fold(
        spec.nodes,
        spec.chunk,
        SimdBackend::new,
        || FleetSummary::new(spec.archetypes),
        |backend, acc, i| {
            let ns = node_spec(spec, i);
            let sp = characterize(backend, &catalog[ns.archetype], spec.cells,
                                  store.as_ref());
            acc.record(&simulate_node(spec, &ns, &sp, &workloads));
        },
        |mut a, b| {
            a.merge(&b);
            a
        },
    );
    let (hits, misses, unique) = match &store {
        Some(s) => (s.hits(), s.misses(), s.len()),
        None => (0, spec.nodes as u64, spec.nodes),
    };
    CampaignResult { summary, hits, misses, unique_profiles: unique }
}

/// Characterization-only sweep for the bench: walk every node's
/// characterize step (no simulation) and return cache telemetry plus an
/// order-independent fingerprint of the tables each node would install.
/// `SPEEDUP[FLEET]` times this with `spec.memoize` on vs off; the
/// fingerprints must match first — the cache must be invisible in
/// results.
pub fn characterize_fleet(spec: &FleetSpec, jobs: usize) -> (u64, u64, u64) {
    let catalog = archetype_catalog(spec.archetypes, params());
    let store = spec.memoize.then(ProfileStore::new);
    let fingerprint = Pool::new(jobs).run_fold(
        spec.nodes,
        spec.chunk,
        SimdBackend::new,
        || 0u64,
        |backend, acc, i| {
            let ns = node_spec(spec, i);
            let sp = characterize(backend, &catalog[ns.archetype], spec.cells,
                                  store.as_ref());
            // wrapping_add is commutative, so the fingerprint is
            // schedule-independent even though per-worker partials vary.
            *acc = acc.wrapping_add(table_fingerprint(&sp.table));
        },
        |a, b| a.wrapping_add(b),
    );
    match &store {
        Some(s) => (s.hits(), s.misses(), fingerprint),
        None => (0, spec.nodes as u64, fingerprint),
    }
}

/// FNV-1a over an installed table's observable content.
fn table_fingerprint(t: &AlDram) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |x: u64| {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    };
    eat(t.guard_c.to_bits());
    for e in t.entries() {
        eat(e.max_c.to_bits());
        eat(e.timings.trcd_ns.to_bits());
        eat(e.timings.tras_ns.to_bits());
        eat(e.timings.twr_ns.to_bits());
        eat(e.timings.trp_ns.to_bits());
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_specs_are_deterministic_and_in_range() {
        let spec = FleetSpec { nodes: 64, archetypes: 5, workloads: 4,
                               seed: "t".into(), ..FleetSpec::default() };
        for i in 0..spec.nodes {
            let a = node_spec(&spec, i);
            let b = node_spec(&spec, i);
            assert_eq!(a, b);
            assert!(a.archetype < 5 && a.workload < 4);
            assert!((0.0..1.0).contains(&a.obs));
            // Ambient stays within the rack envelope across the day:
            // healthy nodes under 36degC, cooling faults bounded by the
            // hotspot ceiling.
            let cap = if a.ambient.hotspot_c > 0.0 { 81.0 } else { 36.0 };
            for s in 0..24 {
                let t = a.ambient.ambient_at(s as f64 / 24.0);
                assert!((12.0..82.0).contains(&t), "ambient {t} off-model");
                assert!(t < cap, "ambient {t} above class ceiling {cap}");
            }
        }
        // Different seeds decorrelate node identities.
        let other = FleetSpec { seed: "u".into(), ..spec.clone() };
        assert!((0..64).any(|i| node_spec(&spec, i) != node_spec(&other, i)));
    }

    #[test]
    fn ambient_model_cycles_with_the_day() {
        let m = AmbientModel { inlet_c: 22.0, seasonal_c: 2.0,
                               diurnal_amp_c: 1.5, phase: 0.25,
                               hotspot_c: 0.0 };
        // Half a day apart the diurnal term flips sign.
        let a = m.ambient_at(0.0);
        let b = m.ambient_at(0.5);
        assert!(((a + b) / 2.0 - 24.0).abs() < 1e-9);
        assert!((a - b).abs() > 1.0);
    }

    #[test]
    fn table_fingerprint_tracks_table_content() {
        let p = params();
        let mut backend = SimdBackend::new();
        let d0 = generate_dimm(0, 32, p);
        let d1 = generate_dimm(1, 32, p);
        let t0 = AlDram::from_profile(
            &crate::profiler::profile_dimm(&mut backend, &d0).unwrap(),
            DEFAULT_BIN_C);
        let t1 = AlDram::from_profile(
            &crate::profiler::profile_dimm(&mut backend, &d1).unwrap(),
            DEFAULT_BIN_C);
        assert_eq!(table_fingerprint(&t0), table_fingerprint(&t0));
        assert_ne!(table_fingerprint(&t0), table_fingerprint(&t1));
    }
}
