//! # AL-DRAM reproduction
//!
//! Reproduction of *"Adaptive-Latency DRAM: Reducing DRAM Latency by
//! Exploiting Timing Margins"* (Lee et al., HPCA'15 / 2018 summary) as a
//! three-layer rust + JAX + Pallas system:
//!
//! * **Layer 1/2 (build-time python)** — the per-cell charge model as a
//!   Pallas kernel inside a JAX profiling graph, AOT-lowered to HLO text.
//! * **Layer 3 (this crate)** — everything else: the synthetic DIMM
//!   population, the SoftMC-style profiler, the AL-DRAM mechanism, a
//!   cycle-level DDR3 memory-system simulator, the power model, and the
//!   figure/evaluation harnesses.
//!
//! See DESIGN.md for the system inventory and the per-experiment index,
//! and EXPERIMENTS.md for paper-vs-measured results.

pub mod aldram;
pub mod check;
pub mod cli;
pub mod eval;
pub mod exec;
pub mod figures;
pub mod fleet;
pub mod mem;
pub mod model;
pub mod population;
pub mod power;
pub mod profiler;
pub mod registry;
pub mod runtime;
pub mod timing;
pub mod util;
pub mod workloads;
