//! The profile registry: persistent, per-DIMM characterization results.
//!
//! AL-DRAM's economics come from profiling a module *once* (at
//! manufacture or deployment time) and reusing the result for the
//! module's whole life (§4/§6). This module is that artifact: a
//! `DimmProfile` — or a derived `AlDram` table — serialized to JSON
//! through `util::json` (the offline crate mirror has no serde), one
//! file per DIMM in a registry directory. `repro profile --save <dir>`
//! writes a profiled population; every figure/eval harness reloads it
//! with `--profiles <dir>` instead of re-running the characterization.
//!
//! Loading validates: timing sets go through [`TimingParams::validate`]
//! and table assembly through [`AlDram::from_entries`], so a corrupt or
//! hand-edited file fails loudly at load time, not as silent nonsense
//! timings in a simulation.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::aldram::{AlDram, RegionTable, TableEntry};
use crate::profiler::{BestCombo, DimmProfile, RefreshProfile,
                      RegionDimmProfile, RegionProfile, SweepResult,
                      TimingProfile};
use crate::timing::TimingParams;
use crate::util::json::Json;

/// Bumped when the on-disk layout changes; loaders reject unknown
/// versions instead of guessing.
pub const FORMAT_VERSION: f64 = 1.0;

/// Region registries: the same per-DIMM file layout with the module
/// profile at top level (so v1 readers of the *fields* keep working via
/// [`load_registry`]) plus a bank-major `regions` array of per-region
/// 55/85degC anchors. Scalar registries stay at [`FORMAT_VERSION`] —
/// their bytes are unchanged by the region feature.
pub const REGION_FORMAT_VERSION: f64 = 2.0;

// ---------------------------------------------------------------------
// JSON builders (util::json works on BTreeMap object nodes).
// ---------------------------------------------------------------------

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in pairs {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

fn nums(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
}

// Non-panicking lookups: `util::json`'s `req`/`f64`/`str` accessors
// panic on a missing or mistyped key (fine for the trusted
// model_params.json), but a registry file is user-editable — every
// corruption must surface as the Result that `load_profile` wraps with
// the file path.
fn field<'j>(j: &'j Json, key: &str) -> Result<&'j Json> {
    j.get(key)
        .ok_or_else(|| anyhow::anyhow!("missing key `{key}`"))
}

fn f64_of(j: &Json, key: &str) -> Result<f64> {
    field(j, key)?
        .as_f64()
        .ok_or_else(|| anyhow::anyhow!("`{key}` is not a number"))
}

fn usize_of(j: &Json, key: &str) -> Result<usize> {
    let x = f64_of(j, key)?;
    anyhow::ensure!(x >= 0.0 && x.fract() == 0.0,
                    "`{key}` is not a non-negative integer: {x}");
    Ok(x as usize)
}

fn str_of(j: &Json, key: &str) -> Result<String> {
    field(j, key)?
        .as_str()
        .map(|s| s.to_string())
        .ok_or_else(|| anyhow::anyhow!("`{key}` is not a string"))
}

fn f64_vec(j: &Json, key: &str) -> Result<Vec<f64>> {
    field(j, key)?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("`{key}` is not an array"))?
        .iter()
        .map(|x| {
            x.as_f64().ok_or_else(|| {
                anyhow::anyhow!("`{key}` contains a non-number")
            })
        })
        .collect()
}

fn bool_of(j: &Json, key: &str) -> Result<bool> {
    match field(j, key)? {
        Json::Bool(b) => Ok(*b),
        other => anyhow::bail!("`{key}` is not a bool: {other:?}"),
    }
}

// ---------------------------------------------------------------------
// DimmProfile <-> JSON
// ---------------------------------------------------------------------

fn combo_to_json(c: &BestCombo) -> Json {
    obj(vec![
        ("trcd_ns", Json::Num(c.trcd_ns)),
        ("third_ns", Json::Num(c.third_ns)),
        ("trp_ns", Json::Num(c.trp_ns)),
        ("sum_ns", Json::Num(c.sum_ns)),
        ("reduction", Json::Num(c.reduction)),
    ])
}

fn combo_from_json(j: &Json) -> Result<BestCombo> {
    Ok(BestCombo {
        trcd_ns: f64_of(j, "trcd_ns")?,
        third_ns: f64_of(j, "third_ns")?,
        trp_ns: f64_of(j, "trp_ns")?,
        sum_ns: f64_of(j, "sum_ns")?,
        reduction: f64_of(j, "reduction")?,
    })
}

fn timing_profile_to_json(t: &TimingProfile) -> Json {
    obj(vec![
        ("temp_c", Json::Num(t.temp_c)),
        ("tref_read_ms", Json::Num(t.tref_read_ms)),
        ("tref_write_ms", Json::Num(t.tref_write_ms)),
        ("read", combo_to_json(&t.read)),
        ("write", combo_to_json(&t.write)),
    ])
}

fn timing_profile_from_json(j: &Json) -> Result<TimingProfile> {
    let t = TimingProfile {
        temp_c: f64_of(j, "temp_c")?,
        tref_read_ms: f64_of(j, "tref_read_ms")?,
        tref_write_ms: f64_of(j, "tref_write_ms")?,
        read: combo_from_json(field(j, "read")?)?,
        write: combo_from_json(field(j, "write")?)?,
    };
    // The operational set this profile resolves to must be a sane timing
    // set — this is where a hand-edited negative tRCD or a tRAS below
    // tRCD is caught.
    t.combined()
        .validate()
        .with_context(|| format!("timing profile at {} C", t.temp_c))?;
    Ok(t)
}

fn refresh_to_json(r: &RefreshProfile) -> Json {
    obj(vec![
        ("temp_c", Json::Num(r.temp_c)),
        ("module_max_read_ms", Json::Num(r.module_max_read_ms)),
        ("module_max_write_ms", Json::Num(r.module_max_write_ms)),
        ("bank_max_read_ms", nums(&r.bank_max_read_ms)),
        ("bank_max_write_ms", nums(&r.bank_max_write_ms)),
        ("chip_max_read_ms", nums(&r.chip_max_read_ms)),
        ("chip_max_write_ms", nums(&r.chip_max_write_ms)),
        ("saturated_read", Json::Bool(r.saturated_read)),
        ("saturated_write", Json::Bool(r.saturated_write)),
    ])
}

fn refresh_from_json(j: &Json) -> Result<RefreshProfile> {
    let r = RefreshProfile {
        temp_c: f64_of(j, "temp_c")?,
        module_max_read_ms: f64_of(j, "module_max_read_ms")?,
        module_max_write_ms: f64_of(j, "module_max_write_ms")?,
        bank_max_read_ms: f64_vec(j, "bank_max_read_ms")?,
        bank_max_write_ms: f64_vec(j, "bank_max_write_ms")?,
        chip_max_read_ms: f64_vec(j, "chip_max_read_ms")?,
        chip_max_write_ms: f64_vec(j, "chip_max_write_ms")?,
        saturated_read: bool_of(j, "saturated_read")?,
        saturated_write: bool_of(j, "saturated_write")?,
    };
    anyhow::ensure!(
        r.module_max_read_ms > 0.0 && r.module_max_write_ms > 0.0,
        "non-positive refresh maxima at {} C", r.temp_c
    );
    Ok(r)
}

/// Serialize one DIMM's full characterization.
pub fn profile_to_json(p: &DimmProfile) -> Json {
    obj(vec![
        ("format_version", Json::Num(FORMAT_VERSION)),
        ("id", Json::Num(p.id as f64)),
        ("vendor", Json::Str(p.vendor.clone())),
        ("refresh85", refresh_to_json(&p.refresh85)),
        ("at85", timing_profile_to_json(&p.at85)),
        ("at55", timing_profile_to_json(&p.at55)),
    ])
}

/// Parse + validate one DIMM profile. Accepts both the scalar v1 layout
/// and the v2 region layout (whose module-level fields are a superset of
/// v1), so pre-region registries and region registries both resolve to a
/// module-granularity [`DimmProfile`] here.
pub fn profile_from_json(j: &Json) -> Result<DimmProfile> {
    let version = f64_of(j, "format_version")?;
    anyhow::ensure!(version == FORMAT_VERSION
                        || version == REGION_FORMAT_VERSION,
                    "unknown registry format version {version} \
                     (this build reads {FORMAT_VERSION} and \
                      {REGION_FORMAT_VERSION})");
    let p = DimmProfile {
        id: usize_of(j, "id")?,
        vendor: str_of(j, "vendor")?,
        refresh85: refresh_from_json(field(j, "refresh85")?)?,
        at85: timing_profile_from_json(field(j, "at85")?)?,
        at55: timing_profile_from_json(field(j, "at55")?)?,
    };
    // The profile must also assemble into a valid table (monotone bins);
    // surface that here rather than at first use.
    AlDram::try_from_profile(&p, crate::aldram::DEFAULT_BIN_C)
        .with_context(|| format!("dimm {:03}", p.id))?;
    Ok(p)
}

// ---------------------------------------------------------------------
// RegionDimmProfile <-> JSON (format v2)
// ---------------------------------------------------------------------

/// Serialize a region-granular characterization: the module profile's
/// fields at top level (stamped v2) plus the per-(bank, region) anchors.
pub fn region_profile_to_json(p: &RegionDimmProfile) -> Json {
    let regions: Vec<Json> = p
        .regions
        .iter()
        .map(|r| obj(vec![
            ("bank", Json::Num(r.bank as f64)),
            ("region", Json::Num(r.region as f64)),
            ("at85", timing_profile_to_json(&r.at85)),
            ("at55", timing_profile_to_json(&r.at55)),
        ]))
        .collect();
    let Json::Obj(mut m) = profile_to_json(&p.base) else {
        unreachable!("profile_to_json returns an object")
    };
    m.insert("format_version".to_string(),
             Json::Num(REGION_FORMAT_VERSION));
    m.insert("regions_per_bank".to_string(),
             Json::Num(p.regions_per_bank as f64));
    m.insert("regions".to_string(), Json::Arr(regions));
    Json::Obj(m)
}

/// Parse + validate a region profile. A scalar (v1) file is a distinct,
/// actionable error — the region data was simply never profiled.
pub fn region_profile_from_json(j: &Json) -> Result<RegionDimmProfile> {
    let version = f64_of(j, "format_version")?;
    anyhow::ensure!(
        version != FORMAT_VERSION,
        "scalar (v{FORMAT_VERSION}) registry has no region data; \
         re-profile with --regions to write a v{REGION_FORMAT_VERSION} \
         registry"
    );
    anyhow::ensure!(version == REGION_FORMAT_VERSION,
                    "unknown registry format version {version} \
                     (region loader reads {REGION_FORMAT_VERSION})");
    let base = profile_from_json(j)?;
    let regions_per_bank = usize_of(j, "regions_per_bank")?;
    let regions = field(j, "regions")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("`regions` is not an array"))?
        .iter()
        .map(|r| {
            Ok(RegionProfile {
                bank: usize_of(r, "bank")?,
                region: usize_of(r, "region")?,
                at85: timing_profile_from_json(field(r, "at85")?)?,
                at55: timing_profile_from_json(field(r, "at55")?)?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let p = RegionDimmProfile { base, regions_per_bank, regions };
    // Geometry and table invariants (bank-major order, monotone bins)
    // surface at load time, mirroring the scalar path.
    RegionTable::try_from_region_profile(&p, crate::aldram::DEFAULT_BIN_C)
        .with_context(|| format!("dimm {:03} region table", p.base.id))?;
    Ok(p)
}

// ---------------------------------------------------------------------
// AlDram table <-> JSON
// ---------------------------------------------------------------------

fn timings_to_json(t: &TimingParams) -> Json {
    obj(vec![
        ("trcd_ns", Json::Num(t.trcd_ns)),
        ("tras_ns", Json::Num(t.tras_ns)),
        ("twr_ns", Json::Num(t.twr_ns)),
        ("trp_ns", Json::Num(t.trp_ns)),
    ])
}

fn timings_from_json(j: &Json) -> Result<TimingParams> {
    Ok(TimingParams::ddr3_standard().with_core(
        f64_of(j, "trcd_ns")?,
        f64_of(j, "tras_ns")?,
        f64_of(j, "twr_ns")?,
        f64_of(j, "trp_ns")?,
    ))
}

/// Serialize a temperature-indexed timing table. The unbounded fallback
/// entry's `max_c` is stored as JSON `null` (JSON has no infinity).
pub fn aldram_to_json(t: &AlDram) -> Json {
    let entries: Vec<Json> = t
        .entries()
        .iter()
        .map(|e| {
            let max_c = if e.max_c.is_finite() {
                Json::Num(e.max_c)
            } else {
                Json::Null
            };
            obj(vec![("max_c", max_c),
                     ("timings", timings_to_json(&e.timings))])
        })
        .collect();
    obj(vec![
        ("format_version", Json::Num(FORMAT_VERSION)),
        ("guard_c", Json::Num(t.guard_c)),
        ("entries", Json::Arr(entries)),
    ])
}

/// Parse + validate a timing table (invariants enforced by
/// [`AlDram::from_entries`]).
pub fn aldram_from_json(j: &Json) -> Result<AlDram> {
    let version = f64_of(j, "format_version")?;
    anyhow::ensure!(version == FORMAT_VERSION,
                    "unknown registry format version {version}");
    let entries: Vec<TableEntry> = field(j, "entries")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("`entries` is not an array"))?
        .iter()
        .map(|e| {
            let max_c = match field(e, "max_c")? {
                Json::Null => f64::INFINITY,
                Json::Num(x) => *x,
                other => anyhow::bail!(
                    "`max_c` must be a number or null, got {other:?}"),
            };
            Ok(TableEntry {
                max_c,
                timings: timings_from_json(field(e, "timings")?)?,
            })
        })
        .collect::<Result<_>>()?;
    AlDram::from_entries(entries, f64_of(j, "guard_c")?)
}

// ---------------------------------------------------------------------
// Registry directory: one `dimm_NNN.json` per module.
// ---------------------------------------------------------------------

fn profile_path(dir: &Path, id: usize) -> PathBuf {
    dir.join(format!("dimm_{id:03}.json"))
}

/// Write one profile into the registry directory (created if missing);
/// returns the file path.
pub fn save_profile(dir: &Path, p: &DimmProfile) -> Result<PathBuf> {
    fs::create_dir_all(dir)
        .with_context(|| format!("creating registry dir {}", dir.display()))?;
    let path = profile_path(dir, p.id);
    fs::write(&path, profile_to_json(p).to_string_pretty())
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(path)
}

/// Write a whole profiled population, replacing any previous registry:
/// stale `dimm_*.json` from an earlier (larger, or differently-sampled)
/// campaign are removed, so `--profiles` never loads a silently mixed
/// population. The new files are fully staged as `*.json.tmp` (which
/// loaders ignore) before the old registry is touched, so an
/// interrupted save leaves either the old population intact or an
/// empty-looking registry that `load_registry` rejects loudly — never
/// a plausible truncated one.
pub fn save_registry(dir: &Path, profiles: &[DimmProfile]) -> Result<()> {
    install_registry(dir,
                     profiles.iter()
                         .map(|p| (p.id, profile_to_json(p)))
                         .collect())
}

/// [`save_registry`] for region-granular profiles: same directory
/// layout, one v2 `dimm_NNN.json` per module, same replace-the-whole-
/// population staging.
pub fn save_region_registry(dir: &Path, profiles: &[RegionDimmProfile])
                            -> Result<()> {
    install_registry(dir,
                     profiles.iter()
                         .map(|p| (p.base.id, region_profile_to_json(p)))
                         .collect())
}

fn install_registry(dir: &Path, files: Vec<(usize, Json)>) -> Result<()> {
    fs::create_dir_all(dir)
        .with_context(|| format!("creating registry dir {}", dir.display()))?;
    let staged: Vec<(PathBuf, PathBuf)> = files
        .iter()
        .map(|(id, j)| {
            let path = profile_path(dir, *id);
            let tmp = path.with_extension("json.tmp");
            fs::write(&tmp, j.to_string_pretty())
                .with_context(|| format!("writing {}", tmp.display()))?;
            Ok((tmp, path))
        })
        .collect::<Result<_>>()?;
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if name.starts_with("dimm_") && name.ends_with(".json") {
            fs::remove_file(&path)
                .with_context(|| format!("removing stale {}", path.display()))?;
        }
    }
    for (tmp, path) in staged {
        fs::rename(&tmp, &path)
            .with_context(|| format!("installing {}", path.display()))?;
    }
    Ok(())
}

/// Load and validate one profile file.
pub fn load_profile(path: &Path) -> Result<DimmProfile> {
    let text = fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let j = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    profile_from_json(&j)
        .with_context(|| format!("loading {}", path.display()))
}

/// Load and validate one region profile file.
pub fn load_region_profile(path: &Path) -> Result<RegionDimmProfile> {
    let text = fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let j = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    region_profile_from_json(&j)
        .with_context(|| format!("loading {}", path.display()))
}

/// Load every `dimm_*.json` in the registry directory, sorted by DIMM id.
/// Region (v2) files load too, at module granularity.
pub fn load_registry(dir: &Path) -> Result<Vec<DimmProfile>> {
    let mut profiles = load_dir(dir, load_profile)?;
    profiles.sort_by_key(|p| p.id);
    Ok(profiles)
}

/// Load a region registry, sorted by DIMM id. Scalar (v1) files are an
/// error — region data cannot be conjured from a module profile.
pub fn load_region_registry(dir: &Path) -> Result<Vec<RegionDimmProfile>> {
    let mut profiles = load_dir(dir, load_region_profile)?;
    profiles.sort_by_key(|p| p.base.id);
    Ok(profiles)
}

fn load_dir<T>(dir: &Path, load: impl Fn(&Path) -> Result<T>)
               -> Result<Vec<T>> {
    let mut out = Vec::new();
    let entries = fs::read_dir(dir)
        .with_context(|| format!("reading registry dir {}", dir.display()))?;
    for entry in entries {
        let path = entry?.path();
        let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if name.starts_with("dimm_") && name.ends_with(".json") {
            out.push(load(&path)?);
        }
    }
    anyhow::ensure!(!out.is_empty(),
                    "no dimm_*.json profiles in {}", dir.display());
    Ok(out)
}

// ---------------------------------------------------------------------
// In-memory content-keyed store: the fleet's profile memoization cache.
// ---------------------------------------------------------------------

/// One cached characterization: everything a fleet node needs to install
/// timings without re-profiling (the profile and its derived table), plus
/// the 85degC sweep frontiers kept as warm-seed material for future
/// misses (`profiler::profile_dimm_seeded`) and the archetype coordinates
/// `nearest_seed` searches over.
#[derive(Debug, Clone)]
pub struct StoredProfile {
    pub profile: DimmProfile,
    pub table: AlDram,
    pub read85: SweepResult,
    pub write85: SweepResult,
    pub vendor_idx: usize,
    pub speed_bin: usize,
}

/// Content-keyed profile cache, shared across `exec::Pool` workers behind
/// one `Arc` (interior mutability; all methods take `&self`). Keys are
/// [`crate::model::CellArrays::content_key`] hashes, so two nodes share a
/// characterization exactly when their module silicon is bit-identical —
/// the archetype-bin case. A second identity index `(dimm_id, cells) →
/// key` lets repeat nodes of an already-characterized archetype skip even
/// the array regeneration that computing a content key would need
/// (`generate_dimm` is deterministic, so the identity pair pins the
/// content).
///
/// Concurrent misses of the same key may both profile and insert; the
/// first insert wins and the results are identical (profiling is
/// deterministic), so the race costs duplicated work, never divergent
/// state.
#[derive(Debug, Default)]
pub struct ProfileStore {
    by_key: std::sync::Mutex<BTreeMap<u64, std::sync::Arc<StoredProfile>>>,
    key_of: std::sync::Mutex<BTreeMap<(usize, usize), u64>>,
    hits: std::sync::atomic::AtomicU64,
    misses: std::sync::atomic::AtomicU64,
}

impl ProfileStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// The content key of an already-characterized `(dimm_id, cells)`
    /// identity, if any — the regeneration-free fast path.
    pub fn cached_key(&self, dimm_id: usize, cells: usize) -> Option<u64> {
        self.key_of.lock().unwrap().get(&(dimm_id, cells)).copied()
    }

    /// Look a content key up; a hit is counted toward the hit rate.
    pub fn get(&self, key: u64) -> Option<std::sync::Arc<StoredProfile>> {
        let found = self.by_key.lock().unwrap().get(&key).cloned();
        if found.is_some() {
            self.hits.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        }
        found
    }

    /// Record a freshly profiled characterization (counted as a miss) and
    /// return the stored copy — the existing one if a concurrent worker
    /// got there first.
    pub fn insert(&self, key: u64, dimm_id: usize, cells: usize,
                  sp: StoredProfile) -> std::sync::Arc<StoredProfile> {
        self.misses.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let arc = std::sync::Arc::new(sp);
        let stored = self.by_key.lock().unwrap()
            .entry(key)
            .or_insert_with(|| std::sync::Arc::clone(&arc))
            .clone();
        self.key_of.lock().unwrap().insert((dimm_id, cells), key);
        stored
    }

    /// The cached characterization nearest to `(vendor_idx, speed_bin)`:
    /// same vendor and closest bin if the vendor is represented, else the
    /// closest bin of any vendor. Used to warm-seed a miss's 85degC
    /// sweeps; seeding never changes sweep results, so the choice only
    /// affects probe cost.
    pub fn nearest_seed(&self, vendor_idx: usize, speed_bin: usize)
                        -> Option<std::sync::Arc<StoredProfile>> {
        let map = self.by_key.lock().unwrap();
        let dist = |sp: &StoredProfile| {
            let bin_gap = sp.speed_bin.abs_diff(speed_bin);
            // Vendor mismatch dominates any bin gap.
            (sp.vendor_idx != vendor_idx, bin_gap, sp.speed_bin)
        };
        map.values()
            .min_by_key(|sp| dist(sp))
            .cloned()
    }

    pub fn len(&self) -> usize {
        self.by_key.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(std::sync::atomic::Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Fraction of lookups served from cache (0 when nothing was looked
    /// up yet).
    pub fn hit_rate(&self) -> f64 {
        let (h, m) = (self.hits(), self.misses());
        if h + m == 0 { 0.0 } else { h as f64 / (h + m) as f64 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params;
    use crate::population::generate_dimm;
    use crate::profiler::profile_dimm;
    use crate::runtime::NativeBackend;

    fn profile(id: usize) -> DimmProfile {
        let d = generate_dimm(id, 64, params());
        let mut b = NativeBackend::new();
        profile_dimm(&mut b, &d).unwrap()
    }

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("aldram_registry_{name}"));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn profile_json_round_trips_exactly() {
        let p = profile(3);
        let j = profile_to_json(&p);
        let reparsed = Json::parse(&j.to_string_pretty()).unwrap();
        let q = profile_from_json(&reparsed).unwrap();
        // f64 Display in util::json is shortest-round-trip, so the whole
        // profile — and therefore any table derived from it — is
        // bit-identical after a disk round trip.
        assert_eq!(p, q);
    }

    #[test]
    fn aldram_table_round_trips_including_infinity_bin() {
        let t = AlDram::from_profile(&profile(1), 10.0);
        let j = aldram_to_json(&t);
        let reparsed = Json::parse(&j.to_string_pretty()).unwrap();
        let u = aldram_from_json(&reparsed).unwrap();
        assert_eq!(t.entries(), u.entries());
        assert_eq!(t.guard_c, u.guard_c);
        assert!(u.entries().last().unwrap().max_c.is_infinite());
    }

    #[test]
    fn registry_dir_saves_and_loads_sorted() {
        let dir = tmp("sorted");
        let (a, b) = (profile(5), profile(2));
        save_registry(&dir, &[a.clone(), b.clone()]).unwrap();
        let loaded = load_registry(&dir).unwrap();
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded[0], b);
        assert_eq!(loaded[1], a);
    }

    #[test]
    fn corrupt_registry_fails_loudly() {
        let dir = tmp("corrupt");
        let p = profile(0);
        let path = save_profile(&dir, &p).unwrap();
        let good = fs::read_to_string(&path).unwrap();

        // Unknown format version.
        fs::write(&path, good.replace("\"format_version\": 1",
                                      "\"format_version\": 99"))
            .unwrap();
        assert!(load_profile(&path).is_err(), "version check");

        // A hand-edited negative timing must be rejected by validation.
        // (`third_ns` of the read chain becomes the operational tRAS
        // directly, so corrupting every occurrence of this value is
        // guaranteed to surface through `TimingParams::validate`.)
        let key = format!("\"third_ns\": {}", p.at55.read.third_ns);
        assert!(good.contains(&key), "fixture drifted: {key} not found");
        fs::write(&path, good.replace(&key, "\"third_ns\": -4")).unwrap();
        assert!(load_profile(&path).is_err(), "negative timing accepted");

        // Truncated JSON.
        fs::write(&path, &good[..good.len() / 2]).unwrap();
        assert!(load_profile(&path).is_err(), "truncated file accepted");

        // A deleted field must be an error (with the file path in the
        // chain), not a panic from the trusted-input json accessors.
        let mut j = Json::parse(&good).unwrap();
        if let Json::Obj(m) = &mut j {
            m.remove("vendor");
        }
        fs::write(&path, j.to_string_pretty()).unwrap();
        let err = load_profile(&path).unwrap_err();
        assert!(format!("{err:#}").contains("vendor"), "{err:#}");
    }

    #[test]
    fn profile_store_memoizes_and_counts() {
        let store = ProfileStore::new();
        let d = generate_dimm(1, 64, params());
        let key = d.arrays.content_key();
        assert!(store.cached_key(1, 64).is_none());
        assert!(store.get(key).is_none());
        assert_eq!(store.hit_rate(), 0.0);

        let mut b = NativeBackend::new();
        let (p, r85, w85) =
            crate::profiler::profile_dimm_seeded(&mut b, &d, None).unwrap();
        let table = AlDram::from_profile(&p, 10.0);
        store.insert(key, 1, 64, StoredProfile {
            profile: p,
            table,
            read85: r85,
            write85: w85,
            vendor_idx: d.vendor_idx,
            speed_bin: 0,
        });

        assert_eq!(store.cached_key(1, 64), Some(key));
        let got = store.get(key).expect("content hit");
        assert_eq!(got.profile.id, 1);
        assert_eq!((store.hits(), store.misses()), (1, 1));
        assert!((store.hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(store.len(), 1);

        // nearest_seed prefers the stored vendor over a bin-0 stranger.
        let seed = store.nearest_seed(d.vendor_idx, 3).expect("non-empty");
        assert_eq!(seed.vendor_idx, d.vendor_idx);
        let other = (d.vendor_idx + 1) % params().population.vendors.len();
        assert!(store.nearest_seed(other, 0).is_some(),
                "cross-vendor fallback must still seed");
    }

    fn region_profile(id: usize) -> RegionDimmProfile {
        let d = generate_dimm(id, 64, params());
        let mut b = NativeBackend::new();
        crate::profiler::profile_dimm_regions(&mut b, &d, 2).unwrap()
    }

    #[test]
    fn region_registry_round_trips_exactly() {
        let dir = tmp("regions");
        let p = region_profile(4);
        save_region_registry(&dir, &[p.clone()]).unwrap();
        let loaded = load_region_registry(&dir).unwrap();
        assert_eq!(loaded.len(), 1);
        // Bit-exact: every per-region anchor survives the disk round trip,
        // so the rebuilt RegionTable is identical too.
        assert_eq!(loaded[0], p);
    }

    #[test]
    fn scalar_loader_reads_region_registries_at_module_granularity() {
        let dir = tmp("regions_as_scalar");
        let p = region_profile(6);
        save_region_registry(&dir, &[p.clone()]).unwrap();
        let loaded = load_registry(&dir).unwrap();
        assert_eq!(loaded, vec![p.base]);
    }

    #[test]
    fn region_loader_rejects_scalar_registries_with_guidance() {
        let dir = tmp("scalar_as_regions");
        save_registry(&dir, &[profile(1)]).unwrap();
        let err = load_region_registry(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("--regions"), "{err:#}");
    }

    #[test]
    fn scalar_writer_format_is_unchanged_by_the_region_feature() {
        // Back-compat pin: v1 files keep their version stamp and gain no
        // region keys, so registries written before the region feature
        // and after it are byte-compatible.
        let text = profile_to_json(&profile(2)).to_string_pretty();
        assert!(text.contains("\"format_version\": 1"), "{text}");
        assert!(!text.contains("regions"), "{text}");
    }

    #[test]
    fn save_registry_replaces_stale_population() {
        let dir = tmp("stale");
        save_registry(&dir, &[profile(0), profile(5)]).unwrap();
        // Re-saving a smaller population must not leave dimm_005 behind.
        save_registry(&dir, &[profile(2)]).unwrap();
        let loaded = load_registry(&dir).unwrap();
        assert_eq!(loaded.len(), 1);
        assert_eq!(loaded[0].id, 2);
    }

    #[test]
    fn missing_registry_dir_is_an_error() {
        assert!(load_registry(Path::new("/nonexistent/registry")).is_err());
    }
}
