//! Hand-rolled CLI (the offline mirror has no clap). Flags are
//! `--name value`; the first free token is the subcommand, subsequent free
//! tokens are its arguments.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: impl Iterator<Item = String>) -> Self {
        let mut positional = Vec::new();
        let mut flags = BTreeMap::new();
        let mut it = argv.peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                let val = match it.peek() {
                    Some(v) if !v.starts_with("--") => it.next().unwrap(),
                    _ => "true".to_string(),
                };
                flags.insert(name.to_string(), val);
            } else {
                positional.push(a);
            }
        }
        Args { positional, flags }
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn cmd(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn sub(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }

    pub fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.flags.get(name) {
            Some(v) => v.parse().unwrap_or_else(|_| {
                panic!("--{name} expects a {}", std::any::type_name::<T>())
            }),
            None => default,
        }
    }

    pub fn str(&self, name: &str, default: &str) -> String {
        self.flags
            .get(name)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    /// The crate-wide `--jobs` resolution: every entry point (binary and
    /// examples) goes through here, so the absent-flag default is always
    /// `exec::default_jobs()` — no call site can quietly fall back to a
    /// different width.
    pub fn jobs(&self) -> usize {
        self.get("jobs", crate::exec::default_jobs())
    }

    /// The crate-wide `--seed` resolution: the label every workload / mix
    /// instantiation folds into its RNG stream. Same seed ⇒ bit-identical
    /// runs; different seed ⇒ different address streams (regression-
    /// tested in `tests/integration_trace.rs`).
    pub fn seed(&self) -> String {
        self.str("seed", "0")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn parses_subcommands_and_flags() {
        let a = parse("figure fig2a --cells 256 --backend native --verbose");
        assert_eq!(a.cmd(), Some("figure"));
        assert_eq!(a.sub(1), Some("fig2a"));
        assert_eq!(a.get::<usize>("cells", 0), 256);
        assert_eq!(a.str("backend", "pjrt"), "native");
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("calibrate");
        assert_eq!(a.get::<usize>("dimms", 30), 30);
        assert_eq!(a.str("out", "results"), "results");
    }

    #[test]
    fn seed_flag_threads_through() {
        let a = parse("trace record --workload milc --seed 42");
        assert_eq!(a.seed(), "42");
        // Absent: one crate-wide default label, shared by every entry
        // point, so unseeded runs stay reproducible.
        assert_eq!(parse("eval fig6").seed(), "0");
    }

    #[test]
    fn jobs_flag_threads_through() {
        let a = parse("figure fig4 --jobs 8");
        assert_eq!(a.jobs(), 8);
        // Absent: the single crate-wide default is the machine's
        // available parallelism — never a hard-coded 1.
        let b = parse("figure fig4");
        assert_eq!(b.jobs(), crate::exec::default_jobs());
        assert!(b.jobs() >= 1);
    }
}
