//! Flattened per-DIMM cell-parameter arrays — the common currency between
//! the population generator, the native backend, and the PJRT runtime
//! (which uploads them as [banks, chips, cells] f32 literals).

use super::charge::{Cell, Combo};

/// Reference stress point for the screening order: timings near the grid
/// floors at the hottest/longest-retention corner, so the cells that fail
/// first under *any* reduced combo sort to the front (see `screening`).
const SCREEN_COMBO: Combo = Combo {
    trcd: 7.5,
    tras: 17.5,
    twr: 7.5,
    trp: 7.5,
    tref_ms: 448.0,
    temp_c: 85.0,
};

/// Sampled cell population of one DIMM: five parallel [B, C, N] arrays in
/// row-major (bank, chip, cell) order.
#[derive(Debug, Clone)]
pub struct CellArrays {
    pub banks: usize,
    pub chips: usize,
    pub cells: usize,
    pub qcap: Vec<f32>,
    pub tau_s: Vec<f32>,
    pub tau_r: Vec<f32>,
    pub tau_p: Vec<f32>,
    pub lam85: Vec<f32>,
    /// Weakest-first visiting order for `pass_probe` (flat indices sorted
    /// by the conservative dominance key of `compute_screening`). Empty
    /// when not yet computed — probing then falls back to array order;
    /// the order affects only speed, never results.
    pub screen: Vec<u32>,
}

impl CellArrays {
    pub fn zeroed(banks: usize, chips: usize, cells: usize) -> Self {
        let n = banks * chips * cells;
        CellArrays {
            banks,
            chips,
            cells,
            qcap: vec![0.0; n],
            tau_s: vec![0.0; n],
            tau_r: vec![0.0; n],
            tau_p: vec![0.0; n],
            lam85: vec![0.0; n],
            screen: Vec::new(),
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.banks * self.chips * self.cells
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    #[inline]
    pub fn idx(&self, bank: usize, chip: usize, cell: usize) -> usize {
        debug_assert!(bank < self.banks && chip < self.chips && cell < self.cells);
        (bank * self.chips + chip) * self.cells + cell
    }

    #[inline]
    pub fn cell(&self, i: usize) -> Cell {
        Cell {
            qcap: self.qcap[i],
            tau_s: self.tau_s[i],
            tau_r: self.tau_r[i],
            tau_p: self.tau_p[i],
            lam85: self.lam85[i],
        }
    }

    #[inline]
    pub fn set(&mut self, i: usize, c: Cell) {
        self.qcap[i] = c.qcap;
        self.tau_s[i] = c.tau_s;
        self.tau_r[i] = c.tau_r;
        self.tau_p[i] = c.tau_p;
        self.lam85[i] = c.lam85;
    }

    /// Downsample to `cells_out` cells per (bank, chip) — used to feed the
    /// `profile_small` artifact and fast test paths. Indices are spread
    /// evenly across the full range (`src = j * cells / cells_out`), so the
    /// weak-tail cells stay representative rather than clustered. A plain
    /// integer stride would leave the trailing `cells % cells_out * stride`
    /// region unsampled whenever `cells_out` does not divide `cells`,
    /// systematically excluding weak cells that land there.
    pub fn downsample(&self, cells_out: usize) -> CellArrays {
        assert!(cells_out <= self.cells && cells_out > 0);
        let mut out = CellArrays::zeroed(self.banks, self.chips, cells_out);
        for b in 0..self.banks {
            for c in 0..self.chips {
                for j in 0..cells_out {
                    let src = self.idx(b, c, j * self.cells / cells_out);
                    let dst = out.idx(b, c, j);
                    out.set(dst, self.cell(src));
                }
            }
        }
        if !self.screen.is_empty() {
            out.compute_screening();
        }
        out
    }

    /// Extract the cell sub-population of one (bank, row-region) as a
    /// standalone 1-bank array: cells [cells*r/R, cells*(r+1)/R) of every
    /// chip of bank `bank`. Cell j samples normalized row position
    /// j/cells, so contiguous cell ranges *are* contiguous row regions.
    /// Both profiling backends accept arbitrary geometry, which is what
    /// makes per-region sweeps a plain reuse of the module path.
    pub fn region_view(&self, bank: usize, region: usize,
                       regions: usize) -> CellArrays {
        assert!(bank < self.banks && region < regions);
        assert!(regions <= self.cells,
                "{} regions over {} cells per chip", regions, self.cells);
        let lo = self.cells * region / regions;
        let hi = self.cells * (region + 1) / regions;
        let mut out = CellArrays::zeroed(1, self.chips, hi - lo);
        for c in 0..self.chips {
            for (dj, j) in (lo..hi).enumerate() {
                out.set(out.idx(0, c, dj), self.cell(self.idx(bank, c, j)));
            }
        }
        out.compute_screening();
        out
    }

    /// Precompute the weakest-first screening order consumed by
    /// `pass_probe`. The key is the worse of the two test margins at the
    /// fixed stress point `SCREEN_COMBO` — a conservative scalar dominance
    /// proxy (every margin term is monotone in the same cell parameters,
    /// so a cell ranked weak here is weak under any nearby combo). Called
    /// once per generated population; `probe` correctness never depends on
    /// the order, only its early-exit cost does.
    pub fn compute_screening(&mut self) {
        let p = super::params::params();
        let mut keyed: Vec<(f32, u32)> = (0..self.len())
            .map(|i| {
                let (m_r, m_w) = super::charge::test_margins(
                    &self.cell(i), &SCREEN_COMBO, p);
                (m_r.min(m_w), i as u32)
            })
            .collect();
        keyed.sort_unstable_by(|a, b| {
            a.0.total_cmp(&b.0).then(a.1.cmp(&b.1))
        });
        self.screen = keyed.into_iter().map(|(_, i)| i).collect();
    }

    /// The screening order, if computed and consistent with the current
    /// geometry.
    pub fn screening(&self) -> Option<&[u32]> {
        (self.screen.len() == self.len()).then_some(self.screen.as_slice())
    }

    /// Content key over the sampled silicon: FNV-1a across the geometry
    /// and the raw f32 bit patterns of every per-cell parameter. Two
    /// arrays hash equal iff they describe bit-identical cells at the
    /// same resolution, which is exactly the fleet profile cache's
    /// memoization question — archetype bins regenerate the same
    /// `generate_dimm` output, so their keys collide by construction
    /// (and the screening order, a derived heuristic, is excluded).
    pub fn content_key(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut eat = |x: u64| {
            for b in x.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        };
        eat(self.banks as u64);
        eat(self.chips as u64);
        eat(self.cells as u64);
        for arr in [&self.qcap, &self.tau_s, &self.tau_r, &self.tau_p,
                    &self.lam85] {
            for x in arr.iter() {
                eat(x.to_bits() as u64);
            }
        }
        h
    }
}

/// Result of one profiling batch: per-(combo, bank, chip) reductions plus
/// per-combo totals — mirrors the 6-tuple returned by the AOT artifact.
#[derive(Debug, Clone)]
pub struct ProfileOutput {
    pub k: usize,
    pub banks: usize,
    pub chips: usize,
    /// Error counts, shape [K, B, C] flattened row-major.
    pub err_r: Vec<f32>,
    pub err_w: Vec<f32>,
    /// Minimum margins, shape [K, B, C].
    pub mmin_r: Vec<f32>,
    pub mmin_w: Vec<f32>,
    /// Per-combo totals, shape [K].
    pub tot_r: Vec<f32>,
    pub tot_w: Vec<f32>,
}

impl ProfileOutput {
    pub fn zeroed(k: usize, banks: usize, chips: usize) -> Self {
        ProfileOutput {
            k,
            banks,
            chips,
            err_r: vec![0.0; k * banks * chips],
            err_w: vec![0.0; k * banks * chips],
            mmin_r: vec![f32::INFINITY; k * banks * chips],
            mmin_w: vec![f32::INFINITY; k * banks * chips],
            tot_r: vec![0.0; k],
            tot_w: vec![0.0; k],
        }
    }

    #[inline]
    pub fn idx(&self, combo: usize, bank: usize, chip: usize) -> usize {
        (combo * self.banks + bank) * self.chips + chip
    }

    /// Total read-test errors for combo `k` across the module.
    pub fn read_errors(&self, k: usize) -> f64 {
        self.tot_r[k] as f64
    }

    pub fn write_errors(&self, k: usize) -> f64 {
        self.tot_w[k] as f64
    }

    /// Per-bank error counts (summed over chips) for combo `k`.
    pub fn bank_errors_read(&self, k: usize) -> Vec<f64> {
        (0..self.banks)
            .map(|b| {
                (0..self.chips)
                    .map(|c| self.err_r[self.idx(k, b, c)] as f64)
                    .sum()
            })
            .collect()
    }

    pub fn bank_errors_write(&self, k: usize) -> Vec<f64> {
        (0..self.banks)
            .map(|b| {
                (0..self.chips)
                    .map(|c| self.err_w[self.idx(k, b, c)] as f64)
                    .sum()
            })
            .collect()
    }

    /// Per-chip error counts (summed over banks) for combo `k`.
    pub fn chip_errors_read(&self, k: usize) -> Vec<f64> {
        (0..self.chips)
            .map(|c| {
                (0..self.banks)
                    .map(|b| self.err_r[self.idx(k, b, c)] as f64)
                    .sum()
            })
            .collect()
    }

    pub fn chip_errors_write(&self, k: usize) -> Vec<f64> {
        (0..self.chips)
            .map(|c| {
                (0..self.banks)
                    .map(|b| self.err_w[self.idx(k, b, c)] as f64)
                    .sum()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn content_key_tracks_cell_content() {
        let mut a = CellArrays::zeroed(2, 3, 4);
        let b = CellArrays::zeroed(2, 3, 4);
        assert_eq!(a.content_key(), b.content_key());
        // Geometry is part of the key even when the flat length matches.
        assert_ne!(a.content_key(), CellArrays::zeroed(3, 2, 4).content_key());
        // A single-cell change moves the key; the screening order does not.
        let i = a.idx(1, 2, 3);
        a.tau_s[i] = 1.0;
        let changed = a.content_key();
        assert_ne!(changed, b.content_key());
        a.compute_screening();
        assert_eq!(a.content_key(), changed);
    }

    #[test]
    fn indexing_roundtrip() {
        let mut a = CellArrays::zeroed(2, 3, 4);
        let c = Cell { qcap: 1.0, tau_s: 2.0, tau_r: 3.0, tau_p: 4.0, lam85: 5.0 };
        let i = a.idx(1, 2, 3);
        a.set(i, c);
        assert_eq!(a.cell(i), c);
        assert_eq!(a.len(), 24);
    }

    #[test]
    fn downsample_strides() {
        let mut a = CellArrays::zeroed(1, 1, 8);
        for j in 0..8 {
            let i = a.idx(0, 0, j);
            a.qcap[i] = j as f32;
        }
        let d = a.downsample(4);
        assert_eq!(d.cells, 4);
        assert_eq!(d.qcap, vec![0.0, 2.0, 4.0, 6.0]);
    }

    #[test]
    fn downsample_non_divisible_reaches_the_tail() {
        // 100 -> 64 used to collapse to stride 1 (cells 0..63), never
        // sampling the trailing 36 cells; the even spread must cover the
        // whole range.
        let mut a = CellArrays::zeroed(1, 1, 100);
        for j in 0..100 {
            a.qcap[j] = j as f32;
        }
        let d = a.downsample(64);
        assert_eq!(d.cells, 64);
        let expected: Vec<f32> =
            (0..64).map(|j| (j * 100 / 64) as f32).collect();
        assert_eq!(d.qcap, expected);
        // The last sampled index must land in the old dead zone.
        assert!(*d.qcap.last().unwrap() >= 64.0,
                "tail still unsampled: max src {}", d.qcap.last().unwrap());
        // 10 -> 4: indices 0,2,5,7 (old stride-2 gave 0,2,4,6).
        let mut a = CellArrays::zeroed(1, 1, 10);
        for j in 0..10 {
            a.qcap[j] = j as f32;
        }
        assert_eq!(a.downsample(4).qcap, vec![0.0, 2.0, 5.0, 7.0]);
    }

    #[test]
    fn region_view_partitions_the_bank() {
        let mut a = CellArrays::zeroed(2, 2, 10);
        for b in 0..2 {
            for c in 0..2 {
                for j in 0..10 {
                    a.qcap[a.idx(b, c, j)] = (b * 100 + c * 10 + j) as f32;
                }
            }
        }
        let regions = 4;
        let mut total = 0;
        for r in 0..regions {
            let v = a.region_view(1, r, regions);
            assert_eq!(v.banks, 1);
            assert_eq!(v.chips, 2);
            total += v.cells;
            assert!(v.screening().is_some());
            // Region r covers [10r/4, 10(r+1)/4) of each chip of bank 1.
            let lo = 10 * r / regions;
            for c in 0..2 {
                assert_eq!(v.qcap[v.idx(0, c, 0)],
                           (100 + c * 10 + lo) as f32);
            }
        }
        assert_eq!(total, 10, "regions must partition the cells");
    }

    #[test]
    fn screening_orders_weakest_first() {
        use crate::model::charge::Cell;
        let mut a = CellArrays::zeroed(1, 1, 16);
        for j in 0..16 {
            // Identical healthy cells except for a progressively leakier
            // tail; higher lam85 = weaker.
            a.set(j, Cell { qcap: 1.0, tau_s: 5.0, tau_r: 3.1, tau_p: 1.85,
                            lam85: 1e-4 * (1.0 + j as f32) });
        }
        assert!(a.screening().is_none());
        a.compute_screening();
        let s = a.screening().expect("computed");
        assert_eq!(s.len(), 16);
        // Weakest (leakiest) cell first, strongest last.
        assert_eq!(s[0], 15);
        assert_eq!(s[15], 0);
        let mut sorted = s.to_vec();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..16u32).collect::<Vec<_>>(), "permutation");
    }

    #[test]
    fn output_reductions() {
        let mut o = ProfileOutput::zeroed(1, 2, 2);
        o.err_r = vec![1.0, 2.0, 3.0, 4.0]; // banks x chips
        assert_eq!(o.bank_errors_read(0), vec![3.0, 7.0]);
        assert_eq!(o.chip_errors_read(0), vec![4.0, 6.0]);
    }
}
