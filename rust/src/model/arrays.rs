//! Flattened per-DIMM cell-parameter arrays — the common currency between
//! the population generator, the native backend, and the PJRT runtime
//! (which uploads them as [banks, chips, cells] f32 literals).

use super::charge::Cell;

/// Sampled cell population of one DIMM: five parallel [B, C, N] arrays in
/// row-major (bank, chip, cell) order.
#[derive(Debug, Clone)]
pub struct CellArrays {
    pub banks: usize,
    pub chips: usize,
    pub cells: usize,
    pub qcap: Vec<f32>,
    pub tau_s: Vec<f32>,
    pub tau_r: Vec<f32>,
    pub tau_p: Vec<f32>,
    pub lam85: Vec<f32>,
}

impl CellArrays {
    pub fn zeroed(banks: usize, chips: usize, cells: usize) -> Self {
        let n = banks * chips * cells;
        CellArrays {
            banks,
            chips,
            cells,
            qcap: vec![0.0; n],
            tau_s: vec![0.0; n],
            tau_r: vec![0.0; n],
            tau_p: vec![0.0; n],
            lam85: vec![0.0; n],
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.banks * self.chips * self.cells
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    #[inline]
    pub fn idx(&self, bank: usize, chip: usize, cell: usize) -> usize {
        debug_assert!(bank < self.banks && chip < self.chips && cell < self.cells);
        (bank * self.chips + chip) * self.cells + cell
    }

    #[inline]
    pub fn cell(&self, i: usize) -> Cell {
        Cell {
            qcap: self.qcap[i],
            tau_s: self.tau_s[i],
            tau_r: self.tau_r[i],
            tau_p: self.tau_p[i],
            lam85: self.lam85[i],
        }
    }

    #[inline]
    pub fn set(&mut self, i: usize, c: Cell) {
        self.qcap[i] = c.qcap;
        self.tau_s[i] = c.tau_s;
        self.tau_r[i] = c.tau_r;
        self.tau_p[i] = c.tau_p;
        self.lam85[i] = c.lam85;
    }

    /// Downsample to `cells_out` cells per (bank, chip) — used to feed the
    /// `profile_small` artifact and fast test paths. Takes every k-th cell
    /// so the weak-tail cells stay representative rather than clustered.
    pub fn downsample(&self, cells_out: usize) -> CellArrays {
        assert!(cells_out <= self.cells && cells_out > 0);
        let stride = self.cells / cells_out;
        let mut out = CellArrays::zeroed(self.banks, self.chips, cells_out);
        for b in 0..self.banks {
            for c in 0..self.chips {
                for j in 0..cells_out {
                    let src = self.idx(b, c, j * stride);
                    let dst = out.idx(b, c, j);
                    out.set(dst, self.cell(src));
                }
            }
        }
        out
    }
}

/// Result of one profiling batch: per-(combo, bank, chip) reductions plus
/// per-combo totals — mirrors the 6-tuple returned by the AOT artifact.
#[derive(Debug, Clone)]
pub struct ProfileOutput {
    pub k: usize,
    pub banks: usize,
    pub chips: usize,
    /// Error counts, shape [K, B, C] flattened row-major.
    pub err_r: Vec<f32>,
    pub err_w: Vec<f32>,
    /// Minimum margins, shape [K, B, C].
    pub mmin_r: Vec<f32>,
    pub mmin_w: Vec<f32>,
    /// Per-combo totals, shape [K].
    pub tot_r: Vec<f32>,
    pub tot_w: Vec<f32>,
}

impl ProfileOutput {
    pub fn zeroed(k: usize, banks: usize, chips: usize) -> Self {
        ProfileOutput {
            k,
            banks,
            chips,
            err_r: vec![0.0; k * banks * chips],
            err_w: vec![0.0; k * banks * chips],
            mmin_r: vec![f32::INFINITY; k * banks * chips],
            mmin_w: vec![f32::INFINITY; k * banks * chips],
            tot_r: vec![0.0; k],
            tot_w: vec![0.0; k],
        }
    }

    #[inline]
    pub fn idx(&self, combo: usize, bank: usize, chip: usize) -> usize {
        (combo * self.banks + bank) * self.chips + chip
    }

    /// Total read-test errors for combo `k` across the module.
    pub fn read_errors(&self, k: usize) -> f64 {
        self.tot_r[k] as f64
    }

    pub fn write_errors(&self, k: usize) -> f64 {
        self.tot_w[k] as f64
    }

    /// Per-bank error counts (summed over chips) for combo `k`.
    pub fn bank_errors_read(&self, k: usize) -> Vec<f64> {
        (0..self.banks)
            .map(|b| {
                (0..self.chips)
                    .map(|c| self.err_r[self.idx(k, b, c)] as f64)
                    .sum()
            })
            .collect()
    }

    pub fn bank_errors_write(&self, k: usize) -> Vec<f64> {
        (0..self.banks)
            .map(|b| {
                (0..self.chips)
                    .map(|c| self.err_w[self.idx(k, b, c)] as f64)
                    .sum()
            })
            .collect()
    }

    /// Per-chip error counts (summed over banks) for combo `k`.
    pub fn chip_errors_read(&self, k: usize) -> Vec<f64> {
        (0..self.chips)
            .map(|c| {
                (0..self.banks)
                    .map(|b| self.err_r[self.idx(k, b, c)] as f64)
                    .sum()
            })
            .collect()
    }

    pub fn chip_errors_write(&self, k: usize) -> Vec<f64> {
        (0..self.chips)
            .map(|c| {
                (0..self.banks)
                    .map(|b| self.err_w[self.idx(k, b, c)] as f64)
                    .sum()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_roundtrip() {
        let mut a = CellArrays::zeroed(2, 3, 4);
        let c = Cell { qcap: 1.0, tau_s: 2.0, tau_r: 3.0, tau_p: 4.0, lam85: 5.0 };
        let i = a.idx(1, 2, 3);
        a.set(i, c);
        assert_eq!(a.cell(i), c);
        assert_eq!(a.len(), 24);
    }

    #[test]
    fn downsample_strides() {
        let mut a = CellArrays::zeroed(1, 1, 8);
        for j in 0..8 {
            let i = a.idx(0, 0, j);
            a.qcap[i] = j as f32;
        }
        let d = a.downsample(4);
        assert_eq!(d.cells, 4);
        assert_eq!(d.qcap, vec![0.0, 2.0, 4.0, 6.0]);
    }

    #[test]
    fn output_reductions() {
        let mut o = ProfileOutput::zeroed(1, 2, 2);
        o.err_r = vec![1.0, 2.0, 3.0, 4.0]; // banks x chips
        assert_eq!(o.bank_errors_read(0), vec![3.0, 7.0]);
        assert_eq!(o.chip_errors_read(0), vec![4.0, 6.0]);
    }
}
