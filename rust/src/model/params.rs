//! Charge-model constants, read from the repo-level `model_params.json` —
//! the same file `python/compile/params.py` bakes into the AOT artifacts.
//! `rust/tests/runtime_native_xcheck.rs` guards against drift between the
//! two readers.

use std::sync::{Arc, OnceLock};

use crate::util::json::Json;

/// The embedded copy: the binary is self-contained after build. A path
/// override (`MODEL_PARAMS` env var) exists for calibration experiments.
const EMBEDDED: &str = include_str!("../../../model_params.json");

#[derive(Debug, Clone)]
pub struct Vendor {
    pub name: String,
    pub share: f64,
    pub mu_ln_tau_s: f64,
    pub lam_shift: f64,
    pub tau_shift: f64,
}

#[derive(Debug, Clone)]
pub struct Population {
    pub n_dimms: usize,
    pub sigma_tau_s: f64,
    pub tau_r_ratio: f64,
    pub sigma_tau_r: f64,
    pub mu_ln_tau_p: f64,
    pub sigma_tau_p: f64,
    pub mu_ln_lam85: f64,
    pub sigma_lam: f64,
    pub weak_frac: f64,
    pub weak_mult_min: f64,
    pub weak_mult_max: f64,
    pub sigma_qcap: f64,
    pub qcap_clip_lo: f64,
    pub qcap_clip_hi: f64,
    /// Spatial (design-induced) variation: lognormal sigma of the
    /// per-bank RC multiplier (banks far from the I/O pads are slower).
    pub spatial_bank_sigma: f64,
    /// Fractional RC increase from the row nearest the sense amps to the
    /// farthest row of the bank (monotone gradient; arxiv 1610.09604).
    pub spatial_grad_span: f64,
    pub vendors: Vec<Vendor>,
}

#[derive(Debug, Clone)]
pub struct Spec {
    pub tck_ns: f64,
    pub trcd_ns: f64,
    pub tras_ns: f64,
    pub twr_ns: f64,
    pub trp_ns: f64,
    pub trefi_standard_ms: f64,
}

#[derive(Debug, Clone)]
pub struct Floors {
    pub trcd_min_ns: f64,
    pub twr_min_ns: f64,
    pub trp_min_ns: f64,
    pub tras_over_trcd_ns: f64,
}

#[derive(Debug, Clone)]
pub struct Geometry {
    pub banks: usize,
    pub chips: usize,
    pub cells_per_chip_bank: usize,
    pub cells_per_chip_bank_small: usize,
    pub combo_batch: usize,
}

/// All analytic charge-model constants (DESIGN.md §4). Field-for-field
/// mirror of `python/compile/params.py::ModelParams`.
#[derive(Debug, Clone)]
pub struct ModelParams {
    pub t_soff_ns: f32,
    pub a_max: f32,
    pub q_knee: f32,
    pub knee_pow: f32,
    pub v_read_frac: f32,
    pub g_off: f32,
    pub alpha_t_per_c: f32,
    pub q_share: f32,
    pub t_rest0_ns: f32,
    pub t_wr0_ns: f32,
    pub wr_tau_ratio: f32,
    pub kw_pattern: f32,
    pub v_bl: f32,
    pub t_pre0_ns: f32,
    pub leak_doubling_c: f32,
    pub t_ref_base_c: f32,
    /// Write-access settle terms (write test; DESIGN.md §4).
    pub c_rcd_w: f32,
    pub c_rp_w: f32,
    pub k_lin: f32,
    pub spec: Spec,
    pub floors: Floors,
    pub geometry: Geometry,
    pub population: Population,
}

impl ModelParams {
    pub fn v_read(&self) -> f32 {
        self.v_read_frac * self.a_max
    }

    pub fn from_json(j: &Json) -> Self {
        let spec = j.req("spec");
        let floors = j.req("floors");
        let g = j.req("geometry");
        let pop = j.req("population");
        ModelParams {
            t_soff_ns: j.f32("t_soff_ns"),
            a_max: j.f32("a_max"),
            q_knee: j.f32("q_knee"),
            knee_pow: j.f32("knee_pow"),
            v_read_frac: j.f32("v_read_frac"),
            g_off: j.f32("g_off"),
            alpha_t_per_c: j.f32("alpha_t_per_c"),
            q_share: j.f32("q_share"),
            t_rest0_ns: j.f32("t_rest0_ns"),
            t_wr0_ns: j.f32("t_wr0_ns"),
            wr_tau_ratio: j.f32("wr_tau_ratio"),
            kw_pattern: j.f32("kw_pattern"),
            v_bl: j.f32("v_bl"),
            t_pre0_ns: j.f32("t_pre0_ns"),
            leak_doubling_c: j.f32("leak_doubling_c"),
            t_ref_base_c: j.f32("t_ref_base_c"),
            c_rcd_w: j.f32("c_rcd_w"),
            c_rp_w: j.f32("c_rp_w"),
            k_lin: j.f32("k_lin"),
            spec: Spec {
                tck_ns: spec.f64("tck_ns"),
                trcd_ns: spec.f64("trcd_ns"),
                tras_ns: spec.f64("tras_ns"),
                twr_ns: spec.f64("twr_ns"),
                trp_ns: spec.f64("trp_ns"),
                trefi_standard_ms: spec.f64("trefi_standard_ms"),
            },
            floors: Floors {
                trcd_min_ns: floors.f64("trcd_min_ns"),
                twr_min_ns: floors.f64("twr_min_ns"),
                trp_min_ns: floors.f64("trp_min_ns"),
                tras_over_trcd_ns: floors.f64("tras_over_trcd_ns"),
            },
            geometry: Geometry {
                banks: g.usize("banks"),
                chips: g.usize("chips"),
                cells_per_chip_bank: g.usize("cells_per_chip_bank"),
                cells_per_chip_bank_small: g.usize("cells_per_chip_bank_small"),
                combo_batch: g.usize("combo_batch"),
            },
            population: Population {
                n_dimms: pop.usize("n_dimms"),
                sigma_tau_s: pop.f64("sigma_tau_s"),
                tau_r_ratio: pop.f64("tau_r_ratio"),
                sigma_tau_r: pop.f64("sigma_tau_r"),
                mu_ln_tau_p: pop.f64("mu_ln_tau_p"),
                sigma_tau_p: pop.f64("sigma_tau_p"),
                mu_ln_lam85: pop.f64("mu_ln_lam85"),
                sigma_lam: pop.f64("sigma_lam"),
                weak_frac: pop.f64("weak_frac"),
                weak_mult_min: pop.f64("weak_mult_min"),
                weak_mult_max: pop.f64("weak_mult_max"),
                sigma_qcap: pop.f64("sigma_qcap"),
                qcap_clip_lo: pop.f64("qcap_clip_lo"),
                qcap_clip_hi: pop.f64("qcap_clip_hi"),
                spatial_bank_sigma: pop.f64("spatial_bank_sigma"),
                spatial_grad_span: pop.f64("spatial_grad_span"),
                vendors: pop
                    .arr("vendors")
                    .iter()
                    .map(|v| Vendor {
                        name: v.str("name").to_string(),
                        share: v.f64("share"),
                        mu_ln_tau_s: v.f64("mu_ln_tau_s"),
                        lam_shift: v.f64("lam_shift"),
                        tau_shift: v.f64("tau_shift"),
                    })
                    .collect(),
            },
        }
    }

    pub fn load() -> Self {
        let text = match std::env::var("MODEL_PARAMS") {
            Ok(path) => std::fs::read_to_string(&path)
                .unwrap_or_else(|e| panic!("MODEL_PARAMS={path}: {e}")),
            Err(_) => EMBEDDED.to_string(),
        };
        let j = Json::parse(&text).expect("model_params.json must parse");
        ModelParams::from_json(&j)
    }
}

static PARAMS: OnceLock<Arc<ModelParams>> = OnceLock::new();

/// Process-wide parameters (the common case; calibration constructs its
/// own instances instead).
pub fn params() -> &'static ModelParams {
    &**PARAMS.get_or_init(|| Arc::new(ModelParams::load()))
}

/// The same process-wide parameters behind a cheap `Arc` clone — this is
/// what fan-outs hand to per-worker backends so `model_params.json` is
/// parsed once per process instead of deep-cloned (vendors `Vec` and all)
/// per worker. Both accessors share one `OnceLock`, so the underlying
/// allocation is the same either way.
pub fn params_arc() -> Arc<ModelParams> {
    Arc::clone(PARAMS.get_or_init(|| Arc::new(ModelParams::load())))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_embedded() {
        let p = ModelParams::load();
        assert_eq!(p.geometry.banks, 8);
        assert_eq!(p.geometry.chips, 8);
        assert_eq!(p.population.vendors.len(), 3);
        assert!(p.a_max > 0.0 && p.q_knee > 0.0);
        assert!((p.v_read() - p.v_read_frac * p.a_max).abs() < 1e-9);
    }

    #[test]
    fn vendor_shares_sum_to_one() {
        let p = ModelParams::load();
        let s: f64 = p.population.vendors.iter().map(|v| v.share).sum();
        assert!((s - 1.0).abs() < 1e-9, "vendor shares sum to {s}");
    }

    #[test]
    fn spec_is_ddr3() {
        let p = ModelParams::load();
        assert_eq!(p.spec.trcd_ns, 13.75);
        assert_eq!(p.spec.tras_ns, 35.0);
        assert_eq!(p.spec.twr_ns, 15.0);
        assert_eq!(p.spec.trp_ns, 13.75);
    }
}
