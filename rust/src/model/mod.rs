//! Charge-level DRAM cell model — native mirror of the L1/L2 python stack.
//!
//! The single source of truth for constants is `model_params.json` at the
//! repo root (embedded into the binary at build time); the physics is
//! documented in DESIGN.md §4.

pub mod arrays;
pub mod charge;
pub mod params;
pub mod profile;
pub mod profile_simd;

pub use arrays::{CellArrays, ProfileOutput};
pub use charge::{Cell, Combo};
pub use params::{params, params_arc, ModelParams};
