//! Native (pure-rust) evaluation of the profiling batch — the same
//! computation as the AOT `profile_step` artifact, used as the
//! cross-validation oracle, the no-artifact fallback backend, and the
//! calibration fast path.

use super::arrays::{CellArrays, ProfileOutput};
use super::charge::{self, Cell, Combo};
use super::params::ModelParams;

/// Matches `ref.SENTINEL_MARGIN` on the python side.
pub const SENTINEL_MARGIN: f32 = 1.0e9;

/// Per-profile hoisted constants plus the exact per-cell margin math —
/// the single scalar source of truth shared by `profile_native` (its
/// inner loop) and `profile_simd` (its guard-band fallback and remainder
/// lanes). Expressions preserve the floating-point evaluation *order* of
/// `charge_math.py`, so error counts stay bit-identical to the AOT
/// artifact (runtime_native_xcheck).
pub(crate) struct ScalarPre<'p> {
    p: &'p ModelParams,
    pub(crate) w_rcd_std: f32,
    pub(crate) w_rp_std: f32,
    pub(crate) q_deficit: f32,
    pub(crate) v_read: f32,
    knee_int: Option<i32>,
}

impl<'p> ScalarPre<'p> {
    pub(crate) fn new(p: &'p ModelParams) -> Self {
        ScalarPre {
            p,
            w_rcd_std: (p.spec.trcd_ns as f32 - p.t_soff_ns).max(0.0),
            w_rp_std: (p.spec.trp_ns as f32 - p.t_pre0_ns).max(0.0),
            q_deficit: 1.0 - p.q_share,
            v_read: p.v_read(),
            // knee_pow is integral (6.0): x.powi is ~8x faster than powf.
            // Guarded by runtime_native_xcheck — if the rounding ever
            // diverges from the artifact's pow lowering, fall back to powf
            // by making knee_pow non-integral in model_params.json.
            knee_int: if p.knee_pow.fract() == 0.0 {
                Some(p.knee_pow as i32)
            } else {
                None
            },
        }
    }

    #[inline]
    pub(crate) fn knee(&self, x: f32) -> f32 {
        match self.knee_int {
            Some(n) => x.powi(n),
            None => x.powf(self.p.knee_pow),
        }
    }

    /// Combo-independent per-cell standard-timing precharge offset.
    #[inline]
    pub(crate) fn off_std(&self, tau_p: f32) -> f32 {
        self.p.v_bl * (-self.w_rp_std / tau_p).exp()
    }

    /// Exact (read, write) margins for one cell under one hoisted combo.
    /// `off_std` must be `self.off_std(cell.tau_p)` (hoisted per cell by
    /// `profile_native`; recomputed on demand by the SIMD fallback).
    #[inline]
    pub(crate) fn margins(&self, kp: &ComboPre, cell: &Cell, off_std: f32)
                          -> (f32, f32) {
        let p = self.p;
        let k = &kp.combo;

        // leak (temperature scaling hoisted; same op order as
        // charge_math.leak_factor: lam = lam85 * pow2).
        let lam = cell.lam85 * kp.pow2;
        let decay = (-lam * k.tref_ms).exp();

        // read chain
        let off = p.v_bl * (-kp.w_rp / cell.tau_p).exp();
        let q_r = cell.qcap
            * (1.0 - self.q_deficit * (-kp.w_ras / cell.tau_r).exp())
            * decay;
        let tau_t = cell.tau_s * kp.tau_fac;
        let amp_r = p.a_max * self.knee((q_r / p.q_knee).max(0.0)).min(1.0);
        let v_r = amp_r * (1.0 - (-kp.w_rcd / tau_t).exp());
        let m_r = v_r - p.g_off * off - self.v_read;

        // write chain (readback at standard timings)
        let q_w = cell.qcap * p.kw_pattern
            * (1.0 - (-kp.w_wr / (p.wr_tau_ratio * cell.tau_r)).exp())
            * decay;
        let amp_w = p.a_max * self.knee((q_w / p.q_knee).max(0.0)).min(1.0);
        let v_w = amp_w * (1.0 - (-self.w_rcd_std / tau_t).exp());
        let m_w_rb = v_w - p.g_off * off_std - self.v_read;
        let m_w_rcd = p.k_lin * (k.trcd - (p.t_soff_ns + p.c_rcd_w * tau_t));
        let m_w_rp = p.k_lin * (k.trp - (p.t_pre0_ns + p.c_rp_w * cell.tau_p));
        let m_w = m_w_rb.min(m_w_rcd).min(m_w_rp);
        (m_r, m_w)
    }
}

/// Evaluate `combos` against every sampled cell; reduce per (bank, chip).
///
/// Loop order mirrors the Pallas kernel's tiling: cells outer (parameter
/// loads amortized), combos inner. Perf (EXPERIMENTS.md §Perf, L3-native):
/// the combo-only sub-expressions (the `2^((T-85)/10)` temperature scaling,
/// the `tau_s` thermal factor, the clamped timing windows) are hoisted out
/// of the inner loop, and the combo-independent per-cell standard-timing
/// precharge offset is computed once per cell. All hoists preserve the
/// floating-point evaluation *order* of `charge_math.py`, so error counts
/// stay bit-identical to the AOT artifact (runtime_native_xcheck).
pub fn profile_native(arrays: &CellArrays, combos: &[Combo],
                      p: &ModelParams) -> ProfileOutput {
    let mut out = ProfileOutput::zeroed(combos.len(), arrays.banks, arrays.chips);

    let pre: Vec<ComboPre> = combos.iter().map(|k| ComboPre::new(k, p)).collect();
    let spre = ScalarPre::new(p);

    for b in 0..arrays.banks {
        for c in 0..arrays.chips {
            let base = (b * arrays.chips + c) * arrays.cells;
            for j in 0..arrays.cells {
                let i = base + j;
                let cell = arrays.cell(i);
                // Combo-independent per-cell terms.
                let off_std = spre.off_std(cell.tau_p);

                for (ki, kp) in pre.iter().enumerate() {
                    let oi = out.idx(ki, b, c);
                    if kp.sentinel {
                        if out.mmin_r[oi] > SENTINEL_MARGIN {
                            out.mmin_r[oi] = SENTINEL_MARGIN;
                            out.mmin_w[oi] = SENTINEL_MARGIN;
                        }
                        continue;
                    }
                    let (m_r, m_w) = spre.margins(kp, &cell, off_std);

                    if m_r < 0.0 {
                        out.err_r[oi] += 1.0;
                    }
                    if m_w < 0.0 {
                        out.err_w[oi] += 1.0;
                    }
                    if m_r < out.mmin_r[oi] {
                        out.mmin_r[oi] = m_r;
                    }
                    if m_w < out.mmin_w[oi] {
                        out.mmin_w[oi] = m_w;
                    }
                }
            }
        }
    }

    finalize_output(&mut out, combos.len());
    out
}

/// Shared epilogue: sentinel combos report the sentinel margin (mirrors
/// the kernel), any (combo, bank, chip) that saw no cells is fixed up,
/// and the per-combo totals are reduced.
pub(crate) fn finalize_output(out: &mut ProfileOutput, k: usize) {
    for v in out.mmin_r.iter_mut().chain(out.mmin_w.iter_mut()) {
        if !v.is_finite() || *v > SENTINEL_MARGIN {
            *v = SENTINEL_MARGIN;
        }
    }

    for ki in 0..k {
        let (mut tr, mut tw) = (0.0f32, 0.0f32);
        for b in 0..out.banks {
            for c in 0..out.chips {
                let oi = out.idx(ki, b, c);
                tr += out.err_r[oi];
                tw += out.err_w[oi];
            }
        }
        out.tot_r[ki] = tr;
        out.tot_w[ki] = tw;
    }
}

/// Hoisted per-combo constants (see `profile_native`).
pub(crate) struct ComboPre {
    pub(crate) combo: Combo,
    pub(crate) sentinel: bool,
    /// 2^((T - 85) / 10) — the leak temperature scaling.
    pub(crate) pow2: f32,
    /// 1 + alpha_t * max(T - 55, 0) — the tau_s thermal factor.
    pub(crate) tau_fac: f32,
    pub(crate) w_rcd: f32,
    pub(crate) w_ras: f32,
    pub(crate) w_wr: f32,
    pub(crate) w_rp: f32,
}

impl ComboPre {
    pub(crate) fn new(k: &Combo, p: &ModelParams) -> Self {
        ComboPre {
            combo: *k,
            sentinel: k.is_sentinel(),
            pow2: 2f32.powf((k.temp_c - p.t_ref_base_c) / p.leak_doubling_c),
            tau_fac: 1.0 + p.alpha_t_per_c * (k.temp_c - 55.0).max(0.0),
            w_rcd: (k.trcd - p.t_soff_ns).max(0.0),
            w_ras: (k.tras - p.t_rest0_ns).max(0.0),
            w_wr: k.twr + p.t_wr0_ns,
            w_rp: (k.trp - p.t_pre0_ns).max(0.0),
        }
    }
}

/// Per-cell margins for a single combo — mirror of the `margin_step`
/// artifact (used by the repeatability battery, which needs cell identity).
pub fn margins_native(arrays: &CellArrays, combo: &Combo,
                      p: &ModelParams) -> (Vec<f32>, Vec<f32>) {
    let n = arrays.len();
    let mut m_r = vec![0.0f32; n];
    let mut m_w = vec![0.0f32; n];
    for i in 0..n {
        let (r, w) = charge::test_margins(&arrays.cell(i), combo, p);
        m_r[i] = r;
        m_w[i] = w;
    }
    (m_r, m_w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::params;
    use crate::util::rng::Rng;

    fn tiny_arrays() -> CellArrays {
        let p = params();
        let mut rng = Rng::from_label("test/profile");
        let mut a = CellArrays::zeroed(2, 2, 64);
        for i in 0..a.len() {
            a.qcap[i] = rng
                .lognormal(0.0, p.population.sigma_qcap)
                .clamp(p.population.qcap_clip_lo, p.population.qcap_clip_hi)
                as f32;
            a.tau_s[i] = rng.lognormal(1.61, p.population.sigma_tau_s) as f32;
            a.tau_r[i] = (p.population.tau_r_ratio * a.tau_s[i] as f64
                * rng.lognormal(0.0, p.population.sigma_tau_r))
                as f32;
            a.tau_p[i] = rng
                .lognormal(p.population.mu_ln_tau_p, p.population.sigma_tau_p)
                as f32;
            a.lam85[i] = rng
                .lognormal(p.population.mu_ln_lam85, p.population.sigma_lam)
                as f32;
        }
        a
    }

    fn std(tref: f32, temp: f32) -> Combo {
        Combo { trcd: 13.75, tras: 35.0, twr: 15.0, trp: 13.75,
                tref_ms: tref, temp_c: temp }
    }

    #[test]
    fn std_timings_error_free_at_85() {
        let a = tiny_arrays();
        let out = profile_native(&a, &[std(64.0, 85.0)], params());
        assert_eq!(out.read_errors(0), 0.0);
        assert_eq!(out.write_errors(0), 0.0);
        assert!(out.mmin_r.iter().all(|m| *m > 0.0));
    }

    #[test]
    fn sentinel_contributes_nothing() {
        let a = tiny_arrays();
        let out = profile_native(&a, &[Combo::sentinel()], params());
        assert_eq!(out.read_errors(0), 0.0);
        assert_eq!(out.mmin_r[0], SENTINEL_MARGIN);
    }

    #[test]
    fn aggressive_timings_fail_many_cells() {
        let a = tiny_arrays();
        let combo = Combo { trcd: 5.0, tras: 16.25, twr: 5.0, trp: 5.0,
                            tref_ms: 448.0, temp_c: 85.0 };
        let out = profile_native(&a, &[combo], params());
        assert!(out.read_errors(0) > 0.0);
        assert!(out.write_errors(0) > 0.0);
    }

    #[test]
    fn totals_match_bank_sums() {
        let a = tiny_arrays();
        let combo = Combo { trcd: 6.25, tras: 20.0, twr: 6.25, trp: 6.25,
                            tref_ms: 300.0, temp_c: 85.0 };
        let out = profile_native(&a, &[std(64.0, 85.0), combo], params());
        for k in 0..2 {
            let bank_sum: f64 = out.bank_errors_read(k).iter().sum();
            assert_eq!(bank_sum, out.read_errors(k));
            let chip_sum: f64 = out.chip_errors_write(k).iter().sum();
            assert_eq!(chip_sum, out.write_errors(k));
        }
    }

    #[test]
    fn margins_native_matches_profile_counts() {
        let a = tiny_arrays();
        let combo = Combo { trcd: 7.5, tras: 22.5, twr: 7.5, trp: 7.5,
                            tref_ms: 256.0, temp_c: 85.0 };
        let out = profile_native(&a, &[combo], params());
        let (m_r, m_w) = margins_native(&a, &combo, params());
        let n_r = m_r.iter().filter(|m| **m < 0.0).count() as f64;
        let n_w = m_w.iter().filter(|m| **m < 0.0).count() as f64;
        assert_eq!(n_r, out.read_errors(0));
        assert_eq!(n_w, out.write_errors(0));
    }
}
