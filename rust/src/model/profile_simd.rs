//! Lane-chunked SoA profiling kernel — the vectorized counterpart of
//! `profile_native`.
//!
//! Cells are processed in fixed-width chunks of [`LANES`] f32 lanes laid
//! out for auto-vectorization: per-combo constants are hoisted once
//! (`ComboPre`), the transcendental hot spots go through a lane-wise
//! polynomial `exp` ([`exp_lanes`]), and the per-lane combine is straight
//! arithmetic with no calls. This breaks the bit-identical contract the
//! scalar mirror keeps with the AOT artifact, so exactness is recovered
//! with a guard band: any lane whose |margin| falls below [`GUARD`] is
//! recomputed through the exact scalar path (`ScalarPre::margins`, the
//! same code `profile_native` runs). Error counts are therefore
//! *identical* to `profile_native` as long as the approximation error
//! stays below `GUARD` — measured at < 3e-7 against a 1e-4 band (~350x
//! slack; see DESIGN.md §7), and continuously asserted by
//! `tests/runtime_simd_xcheck.rs`. Reported margins are approximate
//! within `GUARD`.
//!
//! [`probe_one`] is the early-exit companion used by
//! `ProfilingBackend::pass_probe`: it visits cells weakest-first via the
//! precomputed screening order (`CellArrays::screening`) and stops at the
//! first budget-exceeding failure, so failing combos cost O(weak prefix)
//! instead of O(N).

use super::arrays::{CellArrays, ProfileOutput};
use super::charge::Combo;
use super::params::ModelParams;
use super::profile::{finalize_output, ComboPre, ScalarPre, SENTINEL_MARGIN};

/// Chunk width. Eight f32 lanes = one AVX2 register / two NEON registers;
/// the compiler keeps the lane loops branch-free and vectorizes them.
pub const LANES: usize = 8;

/// Guard band (absolute, on margins): lanes with |margin| below this are
/// re-evaluated exactly. Sized ~350x above the measured worst-case
/// |approx - exact| margin deviation (< 3e-7 over the physical parameter
/// ranges); at this width ~0.02% of cell evaluations take the fallback.
pub const GUARD: f32 = 1e-4;

const LOG2E: f32 = std::f32::consts::LOG2_E;
const LN2: f32 = std::f32::consts::LN_2;
/// 1.5 * 2^23: adding and subtracting rounds an f32 in [-2^22, 2^22] to
/// the nearest integer (the usual round-to-nearest trick).
const MAGIC: f32 = 12_582_912.0;

/// Lane-wise polynomial exp for non-positive arguments.
///
/// exp(x) = 2^n * e^r with n = round(x * log2 e) and r = (x*log2 e - n) * ln 2,
/// |r| <= ln2/2; e^r by a degree-6 Taylor polynomial (max relative error
/// ~4e-6 including the f32 argument rounding at large |x|), 2^n by exponent
/// bit assembly. Arguments are clamped to [-87, 0] — every call site feeds
/// a decay term `-w/tau` with w >= 0, tau > 0, so the upper clamp is inert
/// and the lower clamp flushes to ~1e-38 where exact exp underflows anyway.
#[inline]
fn exp_lanes(x: [f32; LANES]) -> [f32; LANES] {
    let mut out = [0.0f32; LANES];
    for l in 0..LANES {
        let xc = x[l].clamp(-87.0, 0.0);
        let y = xc * LOG2E;
        let n_f = (y + MAGIC) - MAGIC;
        let r = (y - n_f) * LN2;
        let mut p = 1.0 / 720.0;
        p = p * r + 1.0 / 120.0;
        p = p * r + 1.0 / 24.0;
        p = p * r + 1.0 / 6.0;
        p = p * r + 0.5;
        p = p * r + 1.0;
        p = p * r + 1.0;
        let scale = f32::from_bits(((n_f as i32 + 127) << 23) as u32);
        out[l] = p * scale;
    }
    out
}

/// f32 copies of the per-profile constants the lane loops consume.
struct KernelConsts {
    a_max: f32,
    q_knee: f32,
    g_off: f32,
    v_read: f32,
    v_bl: f32,
    q_deficit: f32,
    kw_pattern: f32,
    wr_tau_ratio: f32,
    k_lin: f32,
    t_soff: f32,
    c_rcd_w: f32,
    t_pre0: f32,
    c_rp_w: f32,
    w_rcd_std: f32,
    w_rp_std: f32,
    knee6: bool,
    knee_pow: f32,
}

impl KernelConsts {
    fn new(p: &ModelParams) -> Self {
        KernelConsts {
            a_max: p.a_max,
            q_knee: p.q_knee,
            g_off: p.g_off,
            v_read: p.v_read(),
            v_bl: p.v_bl,
            q_deficit: 1.0 - p.q_share,
            kw_pattern: p.kw_pattern,
            wr_tau_ratio: p.wr_tau_ratio,
            k_lin: p.k_lin,
            t_soff: p.t_soff_ns,
            c_rcd_w: p.c_rcd_w,
            t_pre0: p.t_pre0_ns,
            c_rp_w: p.c_rp_w,
            w_rcd_std: (p.spec.trcd_ns as f32 - p.t_soff_ns).max(0.0),
            w_rp_std: (p.spec.trp_ns as f32 - p.t_pre0_ns).max(0.0),
            knee6: p.knee_pow == 6.0,
            knee_pow: p.knee_pow,
        }
    }
}

/// Knee-shaped sense amplitude, in place: x -> min(max(x, 0)^knee_pow, 1).
/// The shipped knee_pow = 6 specializes to three lane-parallel multiplies.
#[inline]
fn knee_lanes(kc: &KernelConsts, x: &mut [f32; LANES]) {
    if kc.knee6 {
        for v in x.iter_mut() {
            let c = v.max(0.0);
            let c2 = c * c;
            *v = (c2 * c2 * c2).min(1.0);
        }
    } else {
        for v in x.iter_mut() {
            *v = v.max(0.0).powf(kc.knee_pow).min(1.0);
        }
    }
}

/// One chunk of cell-parameter lanes (plus the hoisted standard-tRP
/// precharge offsets); each slice must hold at least LANES values.
#[derive(Clone, Copy)]
struct LaneRefs<'a> {
    qcap: &'a [f32],
    tau_s: &'a [f32],
    tau_r: &'a [f32],
    tau_p: &'a [f32],
    lam85: &'a [f32],
    off_std: &'a [f32],
}

/// Approximate (read, write) margins for one chunk of LANES cells under
/// one hoisted combo.
#[inline]
fn lane_margins(kp: &ComboPre, kc: &KernelConsts, ln: &LaneRefs)
                -> ([f32; LANES], [f32; LANES]) {
    let LaneRefs { qcap, tau_s, tau_r, tau_p, lam85, off_std } = *ln;
    let tref = kp.combo.tref_ms;
    let trcd = kp.combo.trcd;
    let trp = kp.combo.trp;

    let mut a_decay = [0.0f32; LANES];
    let mut a_off = [0.0f32; LANES];
    let mut a_ras = [0.0f32; LANES];
    let mut a_rcd = [0.0f32; LANES];
    let mut a_wr = [0.0f32; LANES];
    let mut a_rcd_std = [0.0f32; LANES];
    for l in 0..LANES {
        let tau_t = tau_s[l] * kp.tau_fac;
        a_decay[l] = -(lam85[l] * kp.pow2) * tref;
        a_off[l] = -kp.w_rp / tau_p[l];
        a_ras[l] = -kp.w_ras / tau_r[l];
        a_rcd[l] = -kp.w_rcd / tau_t;
        a_wr[l] = -kp.w_wr / (kc.wr_tau_ratio * tau_r[l]);
        a_rcd_std[l] = -kc.w_rcd_std / tau_t;
    }
    let e_decay = exp_lanes(a_decay);
    let e_off = exp_lanes(a_off);
    let e_ras = exp_lanes(a_ras);
    let e_rcd = exp_lanes(a_rcd);
    let e_wr = exp_lanes(a_wr);
    let e_rcd_std = exp_lanes(a_rcd_std);

    let mut amp_r = [0.0f32; LANES];
    let mut amp_w = [0.0f32; LANES];
    for l in 0..LANES {
        let decay = e_decay[l];
        amp_r[l] = qcap[l] * (1.0 - kc.q_deficit * e_ras[l]) * decay
            / kc.q_knee;
        amp_w[l] = qcap[l] * kc.kw_pattern * (1.0 - e_wr[l]) * decay
            / kc.q_knee;
    }
    knee_lanes(kc, &mut amp_r);
    knee_lanes(kc, &mut amp_w);

    let mut m_r = [0.0f32; LANES];
    let mut m_w = [0.0f32; LANES];
    for l in 0..LANES {
        let tau_t = tau_s[l] * kp.tau_fac;
        let v_r = kc.a_max * amp_r[l] * (1.0 - e_rcd[l]);
        m_r[l] = v_r - kc.g_off * (kc.v_bl * e_off[l]) - kc.v_read;

        let v_w = kc.a_max * amp_w[l] * (1.0 - e_rcd_std[l]);
        let m_w_rb = v_w - kc.g_off * off_std[l] - kc.v_read;
        let m_w_rcd = kc.k_lin * (trcd - (kc.t_soff + kc.c_rcd_w * tau_t));
        let m_w_rp = kc.k_lin * (trp - (kc.t_pre0 + kc.c_rp_w * tau_p[l]));
        m_w[l] = m_w_rb.min(m_w_rcd).min(m_w_rp);
    }
    (m_r, m_w)
}

/// Vectorized evaluation of `combos` against every sampled cell — the
/// drop-in counterpart of `profile_native` (identical error counts;
/// margins within [`GUARD`]).
pub fn profile_simd(arrays: &CellArrays, combos: &[Combo],
                    p: &ModelParams) -> ProfileOutput {
    let mut out =
        ProfileOutput::zeroed(combos.len(), arrays.banks, arrays.chips);
    let pre: Vec<ComboPre> =
        combos.iter().map(|k| ComboPre::new(k, p)).collect();
    let spre = ScalarPre::new(p);
    let kc = KernelConsts::new(p);

    let n = arrays.cells;
    let chunks = n / LANES;
    let mut off_std = vec![0.0f32; chunks * LANES];

    for b in 0..arrays.banks {
        for c in 0..arrays.chips {
            let base = (b * arrays.chips + c) * n;
            let qcap = &arrays.qcap[base..base + n];
            let tau_s = &arrays.tau_s[base..base + n];
            let tau_r = &arrays.tau_r[base..base + n];
            let tau_p = &arrays.tau_p[base..base + n];
            let lam85 = &arrays.lam85[base..base + n];

            // Combo-independent per-cell precharge offsets (approx).
            for ch in 0..chunks {
                let o = ch * LANES;
                let mut a = [0.0f32; LANES];
                for l in 0..LANES {
                    a[l] = -kc.w_rp_std / tau_p[o + l];
                }
                let e = exp_lanes(a);
                for l in 0..LANES {
                    off_std[o + l] = kc.v_bl * e[l];
                }
            }

            for (ki, kp) in pre.iter().enumerate() {
                let oi = out.idx(ki, b, c);
                if kp.sentinel {
                    if out.mmin_r[oi] > SENTINEL_MARGIN {
                        out.mmin_r[oi] = SENTINEL_MARGIN;
                        out.mmin_w[oi] = SENTINEL_MARGIN;
                    }
                    continue;
                }
                let mut nr = 0u32;
                let mut nw = 0u32;
                let mut min_r = f32::INFINITY;
                let mut min_w = f32::INFINITY;

                for ch in 0..chunks {
                    let o = ch * LANES;
                    let (m_r, m_w) = lane_margins(kp, &kc, &LaneRefs {
                        qcap: &qcap[o..],
                        tau_s: &tau_s[o..],
                        tau_r: &tau_r[o..],
                        tau_p: &tau_p[o..],
                        lam85: &lam85[o..],
                        off_std: &off_std[o..],
                    });
                    for l in 0..LANES {
                        let (mut r, mut w) = (m_r[l], m_w[l]);
                        if r.abs() < GUARD || w.abs() < GUARD {
                            let cell = arrays.cell(base + o + l);
                            let ex = spre.margins(
                                kp, &cell, spre.off_std(cell.tau_p));
                            r = ex.0;
                            w = ex.1;
                        }
                        nr += (r < 0.0) as u32;
                        nw += (w < 0.0) as u32;
                        min_r = min_r.min(r);
                        min_w = min_w.min(w);
                    }
                }
                // Remainder cells (< LANES): exact scalar path.
                for j in chunks * LANES..n {
                    let cell = arrays.cell(base + j);
                    let (r, w) =
                        spre.margins(kp, &cell, spre.off_std(cell.tau_p));
                    nr += (r < 0.0) as u32;
                    nw += (w < 0.0) as u32;
                    min_r = min_r.min(r);
                    min_w = min_w.min(w);
                }

                out.err_r[oi] = nr as f32;
                out.err_w[oi] = nw as f32;
                out.mmin_r[oi] = min_r;
                out.mmin_w[oi] = min_w;
            }
        }
    }

    finalize_output(&mut out, combos.len());
    out
}

/// Early-exit pass probe for one combo: does the failing-cell count of the
/// selected test chain stay within `budget` (over the whole module, or
/// over one bank when `bank` is given)?
///
/// Cells are visited weakest-first via the precomputed screening order
/// (falling back to array order when absent), in LANES-wide gathered
/// chunks of the approximate kernel with the same [`GUARD`]-band exact
/// fallback — so the decision always equals the one derived from a full
/// `profile_native` pass, while failing combos exit after the weak prefix.
pub fn probe_one(arrays: &CellArrays, combo: &Combo, p: &ModelParams,
                 read_chain: bool, bank: Option<usize>, budget: f64) -> bool {
    if combo.is_sentinel() {
        // Sentinels contribute zero failures; compare like everything else
        // so degenerate (negative) budgets agree with the full profile.
        return 0.0 <= budget;
    }
    let kp = ComboPre::new(combo, p);
    let spre = ScalarPre::new(p);
    let kc = KernelConsts::new(p);
    let order = arrays.screening();
    let per_bank = arrays.chips * arrays.cells;

    let mut fails = 0.0f64;
    let mut gathered = [0usize; LANES];
    let mut g = 0usize;
    for pos in 0..arrays.len() {
        let i = match order {
            Some(s) => s[pos] as usize,
            None => pos,
        };
        if let Some(bk) = bank {
            if i / per_bank != bk {
                continue;
            }
        }
        gathered[g] = i;
        g += 1;
        if g == LANES {
            g = 0;
            fails +=
                chunk_fails(arrays, &gathered, &kp, &spre, &kc, read_chain)
                    as f64;
            if fails > budget {
                return false;
            }
        }
    }
    for &i in gathered.iter().take(g) {
        let cell = arrays.cell(i);
        let (m_r, m_w) = spre.margins(&kp, &cell, spre.off_std(cell.tau_p));
        let m = if read_chain { m_r } else { m_w };
        if m < 0.0 {
            fails += 1.0;
            if fails > budget {
                return false;
            }
        }
    }
    // Final comparison (not a constant `true`) so degenerate budgets —
    // e.g. a negative one that fails even error-free combos — agree with
    // `PassCriterion::evaluate` exactly, as the trait contract requires.
    fails <= budget
}

/// Failure count of one gathered chunk for the selected chain, with the
/// guard-band exact fallback.
fn chunk_fails(arrays: &CellArrays, idxs: &[usize; LANES], kp: &ComboPre,
               spre: &ScalarPre, kc: &KernelConsts, read_chain: bool) -> u32 {
    let mut qcap = [0.0f32; LANES];
    let mut tau_s = [0.0f32; LANES];
    let mut tau_r = [0.0f32; LANES];
    let mut tau_p = [0.0f32; LANES];
    let mut lam85 = [0.0f32; LANES];
    for l in 0..LANES {
        let i = idxs[l];
        qcap[l] = arrays.qcap[i];
        tau_s[l] = arrays.tau_s[i];
        tau_r[l] = arrays.tau_r[i];
        tau_p[l] = arrays.tau_p[i];
        lam85[l] = arrays.lam85[i];
    }
    let mut a = [0.0f32; LANES];
    for l in 0..LANES {
        a[l] = -kc.w_rp_std / tau_p[l];
    }
    let e = exp_lanes(a);
    let mut off_std = [0.0f32; LANES];
    for l in 0..LANES {
        off_std[l] = kc.v_bl * e[l];
    }
    let (m_r, m_w) = lane_margins(kp, kc, &LaneRefs {
        qcap: &qcap,
        tau_s: &tau_s,
        tau_r: &tau_r,
        tau_p: &tau_p,
        lam85: &lam85,
        off_std: &off_std,
    });
    let mut fails = 0u32;
    for l in 0..LANES {
        let m = if read_chain { m_r[l] } else { m_w[l] };
        let m = if m.abs() < GUARD {
            let cell = arrays.cell(idxs[l]);
            let ex = spre.margins(kp, &cell, spre.off_std(cell.tau_p));
            if read_chain {
                ex.0
            } else {
                ex.1
            }
        } else {
            m
        };
        fails += (m < 0.0) as u32;
    }
    fails
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::params;
    use crate::model::profile::profile_native;
    use crate::population::generate_dimm;

    #[test]
    fn poly_exp_is_accurate_over_the_domain() {
        // Dense log-spaced sweep of the magnitude range the kernel feeds.
        let mut worst = 0.0f64;
        let mut mag = 1e-6f64;
        while mag < 87.0 {
            let mut lanes = [0.0f32; LANES];
            for (l, v) in lanes.iter_mut().enumerate() {
                // Spread the lanes below the magnitude, capped inside the
                // [-87, 0] domain the kernel guarantees its callers stay in.
                let m = (mag * (1.0 + l as f64 / LANES as f64)).min(86.5);
                *v = -m as f32;
            }
            let approx = exp_lanes(lanes);
            for l in 0..LANES {
                let exact = lanes[l].exp();
                if exact > 0.0 {
                    let rel = ((approx[l] as f64 - exact as f64)
                        / exact as f64)
                        .abs();
                    worst = worst.max(rel);
                }
            }
            mag *= 1.01;
        }
        assert!(worst < 1e-5, "poly exp rel err {worst:.3e}");
        // Exact endpoints.
        assert_eq!(exp_lanes([0.0; LANES])[0], 1.0);
    }

    #[test]
    fn simd_matches_native_on_a_generated_dimm() {
        let p = params();
        // 67 cells: exercises both the lane chunks and the remainder path.
        let d = generate_dimm(4, 67, p);
        let combos = [
            Combo { trcd: 13.75, tras: 35.0, twr: 15.0, trp: 13.75,
                    tref_ms: 64.0, temp_c: 85.0 },
            Combo { trcd: 5.0, tras: 16.25, twr: 5.0, trp: 5.0,
                    tref_ms: 448.0, temp_c: 85.0 },
            Combo::sentinel(),
            Combo { trcd: 8.75, tras: 20.0, twr: 6.25, trp: 7.5,
                    tref_ms: 200.0, temp_c: 55.0 },
        ];
        let a = profile_simd(&d.arrays, &combos, p);
        let b = profile_native(&d.arrays, &combos, p);
        assert_eq!(a.err_r, b.err_r);
        assert_eq!(a.err_w, b.err_w);
        assert_eq!(a.tot_r, b.tot_r);
        assert_eq!(a.tot_w, b.tot_w);
        for (x, y) in a.mmin_r.iter().zip(&b.mmin_r) {
            assert!((x - y).abs() <= GUARD, "mmin_r {x} vs {y}");
        }
        for (x, y) in a.mmin_w.iter().zip(&b.mmin_w) {
            assert!((x - y).abs() <= GUARD, "mmin_w {x} vs {y}");
        }
        // Sentinel slot reports the sentinel margin.
        assert_eq!(a.mmin_r[a.idx(2, 0, 0)], SENTINEL_MARGIN);
    }

    #[test]
    fn probe_matches_full_profile_decision() {
        let p = params();
        let d = generate_dimm(2, 96, p);
        for combo in [
            Combo { trcd: 13.75, tras: 35.0, twr: 15.0, trp: 13.75,
                    tref_ms: 64.0, temp_c: 85.0 },
            Combo { trcd: 6.25, tras: 17.5, twr: 5.0, trp: 6.25,
                    tref_ms: 384.0, temp_c: 85.0 },
        ] {
            let out = profile_native(&d.arrays, &[combo], p);
            for (read, errs) in
                [(true, out.read_errors(0)), (false, out.write_errors(0))]
            {
                for budget in [0.0, 2.0, 64.0] {
                    assert_eq!(
                        probe_one(&d.arrays, &combo, p, read, None, budget),
                        errs <= budget,
                        "read={read} budget={budget} errs={errs}"
                    );
                }
            }
            for bank in 0..d.arrays.banks {
                let be = out.bank_errors_read(0)[bank];
                assert_eq!(
                    probe_one(&d.arrays, &combo, p, true, Some(bank), 0.0),
                    be == 0.0,
                    "bank {bank}"
                );
            }
        }
    }
}
