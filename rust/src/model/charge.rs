//! Native mirror of the charge model (python/compile/kernels/charge_math.py).
//!
//! Scalar f32 expressions kept term-for-term identical to the jnp versions
//! so the native backend and the AOT artifact agree to float tolerance
//! (asserted by rust/tests/runtime_native_xcheck.rs). See DESIGN.md §4 for
//! the physics.

use super::params::ModelParams;

/// Per-cell process-variation parameters (one sampled DRAM cell).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Cell {
    /// Full stored charge (normalized to VDD = 1); capacitance variation.
    pub qcap: f32,
    /// Sensing time constant (ns); bitline/access-transistor RC.
    pub tau_s: f32,
    /// Restoration time constant (ns).
    pub tau_r: f32,
    /// Precharge/equalization time constant (ns).
    pub tau_p: f32,
    /// Leak rate at 85degC (1/ms); retention variation.
    pub lam85: f32,
}

/// One timing combination under test (ns / ms / degC) — matches the
/// [K, 6] combo rows fed to the kernel.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Combo {
    pub trcd: f32,
    pub tras: f32,
    pub twr: f32,
    pub trp: f32,
    pub tref_ms: f32,
    pub temp_c: f32,
}

impl Combo {
    pub fn to_row(&self) -> [f32; 6] {
        [self.trcd, self.tras, self.twr, self.trp, self.tref_ms, self.temp_c]
    }

    /// Padding sentinel (ignored by the kernel: zero errors, +inf margin).
    pub fn sentinel() -> Self {
        Combo { trcd: 0.0, tras: 0.0, twr: 0.0, trp: 0.0, tref_ms: 0.0, temp_c: -1.0 }
    }

    pub fn is_sentinel(&self) -> bool {
        self.temp_c < 0.0
    }
}

/// Multiplicative charge decay over one refresh window at `temp_c`.
#[inline]
pub fn leak_factor(lam85: f32, temp_c: f32, tref_ms: f32, p: &ModelParams) -> f32 {
    let lam = lam85 * 2f32.powf((temp_c - p.t_ref_base_c) / p.leak_doubling_c);
    (-lam * tref_ms).exp()
}

/// Cell charge after a read access's truncated restoration window (tRAS).
#[inline]
pub fn restore_read(qcap: f32, tau_r: f32, tras_ns: f32, p: &ModelParams) -> f32 {
    let w = (tras_ns - p.t_rest0_ns).max(0.0);
    qcap * (1.0 - (1.0 - p.q_share) * (-w / tau_r).exp())
}

/// Cell charge after a write-recovery window (tWR), worst-pattern derated.
#[inline]
pub fn restore_write(qcap: f32, tau_r: f32, twr_ns: f32, p: &ModelParams) -> f32 {
    let tau_w = p.wr_tau_ratio * tau_r;
    qcap * p.kw_pattern * (1.0 - (-(twr_ns + p.t_wr0_ns) / tau_w).exp())
}

/// Residual bitline differential left by a truncated precharge (tRP).
#[inline]
pub fn precharge_offset(tau_p: f32, trp_ns: f32, p: &ModelParams) -> f32 {
    let w = (trp_ns - p.t_pre0_ns).max(0.0);
    p.v_bl * (-w / tau_p).exp()
}

/// Sense margin after tRCD given initial charge `q0` (>= 0 means PASS).
#[inline]
pub fn sense_margin(q0: f32, tau_s: f32, trcd_ns: f32, offset: f32,
                    temp_c: f32, p: &ModelParams) -> f32 {
    let amp = p.a_max * (q0 / p.q_knee).max(0.0).powf(p.knee_pow).min(1.0);
    let tau_t = tau_s * (1.0 + p.alpha_t_per_c * (temp_c - 55.0).max(0.0));
    let w = (trcd_ns - p.t_soff_ns).max(0.0);
    let v = amp * (1.0 - (-w / tau_t).exp());
    v - p.g_off * offset - p.v_read()
}

/// Full test chains: `(margin_read, margin_write)`. Mirrors
/// `charge_math.test_margins` exactly: the read test accesses with the
/// combo's timings; the write test writes with the combo's timings and
/// reads back with *standard* timings, with linear driver-settle slack
/// terms for the write-side tRCD/tRP (see the python docstring).
#[inline]
pub fn test_margins(c: &Cell, k: &Combo, p: &ModelParams) -> (f32, f32) {
    let decay = leak_factor(c.lam85, k.temp_c, k.tref_ms, p);
    let tau_t = c.tau_s * (1.0 + p.alpha_t_per_c * (k.temp_c - 55.0).max(0.0));

    // read test
    let off = precharge_offset(c.tau_p, k.trp, p);
    let q_r = restore_read(c.qcap, c.tau_r, k.tras, p) * decay;
    let m_r = sense_margin(q_r, c.tau_s, k.trcd, off, k.temp_c, p);

    // write test
    let q_w = restore_write(c.qcap, c.tau_r, k.twr, p) * decay;
    let off_std = precharge_offset(c.tau_p, p.spec.trp_ns as f32, p);
    let m_w_rb =
        sense_margin(q_w, c.tau_s, p.spec.trcd_ns as f32, off_std, k.temp_c, p);
    let m_w_rcd = p.k_lin * (k.trcd - (p.t_soff_ns + p.c_rcd_w * tau_t));
    let m_w_rp = p.k_lin * (k.trp - (p.t_pre0_ns + p.c_rp_w * c.tau_p));
    let m_w = m_w_rb.min(m_w_rcd).min(m_w_rp);
    (m_r, m_w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::params;

    fn typical_cell() -> Cell {
        Cell { qcap: 1.0, tau_s: 5.0, tau_r: 3.1, tau_p: 1.85, lam85: 6.5e-4 }
    }

    fn std_combo(temp_c: f32) -> Combo {
        Combo { trcd: 13.75, tras: 35.0, twr: 15.0, trp: 13.75,
                tref_ms: 64.0, temp_c }
    }

    #[test]
    fn typical_cell_passes_std_at_85() {
        let p = params();
        let (m_r, m_w) = test_margins(&typical_cell(), &std_combo(85.0), p);
        assert!(m_r > 0.0, "read margin {m_r}");
        assert!(m_w > 0.0, "write margin {m_w}");
    }

    #[test]
    fn leak_doubles_per_10c() {
        let p = params();
        let l55 = leak_factor(1e-3, 55.0, 100.0, p).ln();
        let l65 = leak_factor(1e-3, 65.0, 100.0, p).ln();
        assert!((l65 / l55 - 2.0).abs() < 1e-4);
    }

    #[test]
    fn restore_monotone_in_time() {
        let p = params();
        let mut prev = -1.0f32;
        for t in [8.0f32, 12.0, 20.0, 35.0, 60.0] {
            let q = restore_read(1.0, 3.0, t, p);
            assert!(q >= prev);
            prev = q;
        }
        assert!(restore_read(1.0, 3.0, 1e6, p) <= 1.0 + 1e-6);
    }

    #[test]
    fn write_floor_is_zero_not_negative() {
        let p = params();
        let q = restore_write(1.0, 3.0, 0.0, p);
        assert!(q >= 0.0 && q < p.kw_pattern);
    }

    #[test]
    fn precharge_offset_decays() {
        let p = params();
        let o1 = precharge_offset(1.85, 5.0, p);
        let o2 = precharge_offset(1.85, 13.75, p);
        assert!(o1 > o2 && o2 > 0.0);
        assert!(precharge_offset(1.85, 0.0, p) <= p.v_bl);
    }

    #[test]
    fn amplitude_knee_saturates() {
        let p = params();
        // Above the knee, margin no longer improves with charge.
        let hi = sense_margin(1.0, 5.0, 13.75, 0.0, 55.0, p);
        let knee = sense_margin(p.q_knee, 5.0, 13.75, 0.0, 55.0, p);
        let lo = sense_margin(p.q_knee * 0.5, 5.0, 13.75, 0.0, 55.0, p);
        assert!((hi - knee).abs() < 1e-7);
        assert!(lo < knee);
    }

    #[test]
    fn hot_sensing_is_slower() {
        let p = params();
        let cool = sense_margin(1.0, 5.0, 8.0, 0.0, 55.0, p);
        let hot = sense_margin(1.0, 5.0, 8.0, 0.0, 85.0, p);
        assert!(hot < cool);
    }
}
