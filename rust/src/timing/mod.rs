//! DDR3 timing parameters: the JEDEC-style standard set, reduced sets, and
//! the ns<->cycle conversions the memory controller works in.
//!
//! The four parameters AL-DRAM optimizes (tRCD, tRAS, tWR, tRP) are carried
//! in nanoseconds (the profiler's sweep domain); everything the cycle-level
//! controller needs is derived against the DDR3-1600 clock (tCK = 1.25 ns).

use crate::model::params;

/// The four AL-DRAM-optimized core timings plus the fixed secondary set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimingParams {
    pub trcd_ns: f64,
    pub tras_ns: f64,
    pub twr_ns: f64,
    pub trp_ns: f64,
    // Fixed (not optimized by AL-DRAM; JEDEC DDR3-1600 values).
    pub tcl_ns: f64,
    pub tcwl_ns: f64,
    pub tccd_ns: f64,
    pub trrd_ns: f64,
    pub tfaw_ns: f64,
    pub trtp_ns: f64,
    pub twtr_ns: f64,
    pub trfc_ns: f64,
    pub trefi_us: f64,
    pub tburst_ns: f64,
}

impl TimingParams {
    /// JEDEC DDR3-1600 (11-11-11) standard timings — the worst-case set
    /// every module must honor.
    pub fn ddr3_standard() -> Self {
        let p = params();
        TimingParams {
            trcd_ns: p.spec.trcd_ns,
            tras_ns: p.spec.tras_ns,
            twr_ns: p.spec.twr_ns,
            trp_ns: p.spec.trp_ns,
            tcl_ns: 13.75,
            tcwl_ns: 10.0,
            tccd_ns: 5.0,   // 4 tCK
            trrd_ns: 6.25,  // 5 tCK (1KB page)
            tfaw_ns: 30.0,
            trtp_ns: 7.5,
            twtr_ns: 7.5,
            trfc_ns: 160.0, // 2Gb device
            trefi_us: 7.8,
            tburst_ns: 5.0, // BL8 on a DDR bus = 4 tCK
        }
    }

    /// Replace the four optimized parameters (ns), keeping the fixed set.
    pub fn with_core(&self, trcd: f64, tras: f64, twr: f64, trp: f64) -> Self {
        TimingParams { trcd_ns: trcd, tras_ns: tras, twr_ns: twr,
                       trp_ns: trp, ..*self }
    }

    /// Apply fractional reductions to the four core parameters, e.g.
    /// `reduced(0.27, 0.32, 0.33, 0.18)` is the paper's Fig-4 operating
    /// point at 55degC.
    pub fn reduced(&self, r_trcd: f64, r_tras: f64, r_twr: f64,
                   r_trp: f64) -> Self {
        self.with_core(
            self.trcd_ns * (1.0 - r_trcd),
            self.tras_ns * (1.0 - r_tras),
            self.twr_ns * (1.0 - r_twr),
            self.trp_ns * (1.0 - r_trp),
        )
    }

    /// Sanity-check the four AL-DRAM-optimized parameters: finite and
    /// non-negative, protocol-ordered (tRAS must cover tRCD), and never
    /// slower than the JEDEC worst-case set (AL-DRAM tables only ever
    /// *reduce* timings). Called from every `AlDram` table construction,
    /// so a corrupt or hand-edited registry file fails loudly at load
    /// time instead of silently simulating nonsense.
    pub fn validate(&self) -> anyhow::Result<()> {
        let std = TimingParams::ddr3_standard();
        let core = [("tRCD", self.trcd_ns, std.trcd_ns),
                    ("tRAS", self.tras_ns, std.tras_ns),
                    ("tWR", self.twr_ns, std.twr_ns),
                    ("tRP", self.trp_ns, std.trp_ns)];
        for (name, v, max) in core {
            anyhow::ensure!(v.is_finite() && v >= 0.0,
                            "{name} = {v} ns must be finite and non-negative");
            anyhow::ensure!(v <= max + 1e-6,
                            "{name} = {v} ns exceeds the DDR3 standard \
                             {max} ns (timing tables only reduce)");
        }
        anyhow::ensure!(self.tras_ns >= self.trcd_ns - 1e-6,
                        "tRAS {} ns < tRCD {} ns: the row must stay open at \
                         least until the column access can start",
                        self.tras_ns, self.trcd_ns);
        Ok(())
    }

    /// Row-cycle time: tRC = tRAS + tRP, the back-to-back ACT period.
    pub fn trc_ns(&self) -> f64 {
        self.tras_ns + self.trp_ns
    }

    /// Sum of the read-path parameters (Fig 3c's y-axis).
    pub fn read_sum_ns(&self) -> f64 {
        self.trcd_ns + self.tras_ns + self.trp_ns
    }

    /// Sum of the write-path parameters (Fig 3d's y-axis).
    pub fn write_sum_ns(&self) -> f64 {
        self.trcd_ns + self.twr_ns + self.trp_ns
    }

    /// Convert to controller cycles (ceil — timings are minimums).
    pub fn to_cycles(&self, tck_ns: f64) -> TimingCycles {
        let c = |ns: f64| (ns / tck_ns - 1e-9).ceil().max(0.0) as u32;
        TimingCycles {
            trcd: c(self.trcd_ns),
            tras: c(self.tras_ns),
            twr: c(self.twr_ns),
            trp: c(self.trp_ns),
            tcl: c(self.tcl_ns),
            tcwl: c(self.tcwl_ns),
            tccd: c(self.tccd_ns),
            trrd: c(self.trrd_ns),
            tfaw: c(self.tfaw_ns),
            trtp: c(self.trtp_ns),
            twtr: c(self.twtr_ns),
            trfc: c(self.trfc_ns),
            trefi: c(self.trefi_us * 1000.0),
            tburst: c(self.tburst_ns),
            trc: c(self.trc_ns()),
        }
    }
}

/// Integer-cycle timings consumed by the bank state machines.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimingCycles {
    pub trcd: u32,
    pub tras: u32,
    pub twr: u32,
    pub trp: u32,
    pub tcl: u32,
    pub tcwl: u32,
    pub tccd: u32,
    pub trrd: u32,
    pub tfaw: u32,
    pub trtp: u32,
    pub twtr: u32,
    pub trfc: u32,
    pub trefi: u32,
    pub tburst: u32,
    pub trc: u32,
}

/// Profiler sweep grids: every value from the standard down to the floor in
/// controller-clock steps — the quantization a real memory controller
/// imposes (and the paper's sweep granularity).
pub struct SweepGrids {
    pub trcd: Vec<f64>,
    pub tras: Vec<f64>,
    pub twr: Vec<f64>,
    pub trp: Vec<f64>,
    pub tref_ms: Vec<f64>,
}

impl SweepGrids {
    pub fn standard() -> Self {
        let p = params();
        let tck = p.spec.tck_ns;
        let down = |from: f64, floor: f64| -> Vec<f64> {
            let mut v = Vec::new();
            let mut x = from;
            while x >= floor - 1e-9 {
                v.push((x * 100.0).round() / 100.0);
                x -= tck;
            }
            v
        };
        SweepGrids {
            trcd: down(p.spec.trcd_ns, p.floors.trcd_min_ns),
            tras: down(p.spec.tras_ns, p.floors.trcd_min_ns
                       + p.floors.tras_over_trcd_ns),
            twr: down(p.spec.twr_ns, p.floors.twr_min_ns),
            trp: down(p.spec.trp_ns, p.floors.trp_min_ns),
            // Fig 2a/3ab sweep: 64..448 ms in 8 ms increments.
            tref_ms: (0..=48).map(|i| 64.0 + 8.0 * i as f64).collect(),
        }
    }

    /// Is (trcd, tras) pair protocol-legal? tRAS must cover row activation
    /// plus column access/restore start.
    pub fn tras_legal(trcd: f64, tras: f64) -> bool {
        let p = params();
        tras >= trcd + p.floors.tras_over_trcd_ns - 1e-9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_matches_spec() {
        let t = TimingParams::ddr3_standard();
        assert_eq!(t.trcd_ns, 13.75);
        assert_eq!(t.tras_ns, 35.0);
        assert_eq!(t.twr_ns, 15.0);
        assert_eq!(t.trp_ns, 13.75);
        assert_eq!(t.trc_ns(), 48.75);
        assert_eq!(t.read_sum_ns(), 62.5);
        assert_eq!(t.write_sum_ns(), 42.5);
    }

    #[test]
    fn cycles_conversion_rounds_up() {
        let t = TimingParams::ddr3_standard();
        let c = t.to_cycles(1.25);
        assert_eq!(c.trcd, 11);
        assert_eq!(c.tras, 28);
        assert_eq!(c.twr, 12);
        assert_eq!(c.trp, 11);
        assert_eq!(c.trefi, 6240);
        // non-multiple rounds up
        let t2 = t.with_core(13.0, 35.0, 15.0, 13.75);
        assert_eq!(t2.to_cycles(1.25).trcd, 11); // 13.0/1.25 = 10.4 -> 11
    }

    #[test]
    fn reduced_applies_fractions() {
        let t = TimingParams::ddr3_standard().reduced(0.27, 0.32, 0.33, 0.18);
        assert!((t.trcd_ns - 13.75 * 0.73).abs() < 1e-9);
        assert!((t.tras_ns - 35.0 * 0.68).abs() < 1e-9);
        assert!((t.twr_ns - 15.0 * 0.67).abs() < 1e-9);
        assert!((t.trp_ns - 13.75 * 0.82).abs() < 1e-9);
    }

    #[test]
    fn grids_are_monotone_and_bounded() {
        let g = SweepGrids::standard();
        for grid in [&g.trcd, &g.tras, &g.twr, &g.trp] {
            assert!(!grid.is_empty());
            for w in grid.windows(2) {
                assert!(w[0] > w[1]);
            }
            assert_eq!(grid[0], grid[0].max(grid[grid.len() - 1]));
        }
        assert_eq!(g.tref_ms[0], 64.0);
        assert_eq!(*g.tref_ms.last().unwrap(), 448.0);
    }

    #[test]
    fn validate_accepts_standard_and_reduced_sets() {
        TimingParams::ddr3_standard().validate().unwrap();
        TimingParams::ddr3_standard()
            .reduced(0.27, 0.32, 0.33, 0.18)
            .validate()
            .unwrap();
    }

    #[test]
    fn validate_rejects_corrupt_sets() {
        let std = TimingParams::ddr3_standard();
        // Negative parameter.
        assert!(std.with_core(-1.0, 35.0, 15.0, 13.75).validate().is_err());
        // Non-finite parameter.
        assert!(std.with_core(f64::NAN, 35.0, 15.0, 13.75)
                    .validate()
                    .is_err());
        // tRAS below tRCD.
        assert!(std.with_core(13.75, 10.0, 15.0, 13.75).validate().is_err());
        // Slower than the JEDEC worst case.
        assert!(std.with_core(13.75, 40.0, 15.0, 13.75).validate().is_err());
    }

    #[test]
    fn tras_legality() {
        assert!(SweepGrids::tras_legal(13.75, 35.0));
        assert!(SweepGrids::tras_legal(5.0, 16.25));
        assert!(!SweepGrids::tras_legal(13.75, 15.0));
    }
}
