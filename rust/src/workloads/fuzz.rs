//! Seeded adversarial request source for protocol auditing.
//!
//! Synthetic workloads are tuned to look like SPEC; this source is tuned
//! to look like trouble. It cycles through four phases chosen to pin the
//! controller against every timing gate the checker audits:
//!
//! * **storm** — round-robin over all banks with an advancing row per
//!   visit, so *every* access is a row conflict (PRE/ACT churn: tRP, tRC,
//!   tRRD, tFAW) inside the low — fast, under a region table — rows;
//! * **hammer** — 8-column bursts alternating between the two rows that
//!   straddle a region boundary on one bank, finishing each burst with
//!   writes (per-region tRCD/tRP resolution, tWR against the conflict
//!   PRE);
//! * **rwmix** — one open row, 32-write/32-read blocks (write-drain
//!   flips: tWTR and read->write turnaround back to back);
//! * **spread** — seeded random traffic over the whole address space
//!   (refresh-straddling pressure on every rank).
//!
//! Addresses are built through [`AddrMap::encode`], which inverts any
//! page-placement remap — the phases target *physical* (rank, bank, row)
//! coordinates, so region-boundary hammering stays on the boundary even
//! when `region+placement` configs permute the row space.

use crate::mem::address::{AddrMap, Decoded};
use crate::util::rng::Rng;
use crate::workloads::{MemRef, NamedSource, RequestSource, SOURCE_BATCH};

/// References per phase before rotating to the next.
const PHASE_LEN: u64 = 192;
/// Column hits per hammered row.
const HAMMER_BURST: u64 = 8;
/// Reads/writes per rwmix block.
const RWMIX_BLOCK: u64 = 32;

pub struct FuzzSource {
    map: AddrMap,
    rng: Rng,
    v: u64,
}

impl FuzzSource {
    pub fn new(map: AddrMap, seed_label: &str) -> Self {
        FuzzSource {
            map,
            rng: Rng::from_label(&format!("fuzz/{seed_label}")),
            v: 0,
        }
    }

    /// A [`NamedSource`] wrapper (what `System::with_sources` consumes).
    pub fn named(map: AddrMap, seed_label: &str) -> NamedSource {
        NamedSource {
            name: "fuzz".to_string(),
            seed: seed_label.to_string(),
            footprint: map.capacity_bytes(),
            source: Box::new(FuzzSource::new(map, seed_label)),
        }
    }

    /// The row where the coarsest (2-region) table changes timing sets —
    /// the hammer phase straddles it.
    fn boundary_row(&self) -> u64 {
        1 << (self.map.row_bits - 1)
    }

    fn gen_ref(&mut self) -> MemRef {
        let v = self.v;
        self.v += 1;
        let ranks = self.map.ranks() as u64;
        let banks = self.map.banks() as u64;
        let cols = 1u64 << self.map.col_bits;
        let rows = 1u64 << self.map.row_bits;
        let (rank, bank, row, is_write) = match (v / PHASE_LEN) % 4 {
            // storm: every visit to a bank lands on a fresh row.
            0 => {
                let bank = v % banks;
                let row = (v / banks) % (rows / 8);
                (v % ranks, bank, row, v % 10 < 3)
            }
            // hammer: alternate the rows on either side of the region
            // boundary, 8 hits each, last two of each burst writes.
            1 => {
                let burst = v / HAMMER_BURST;
                let row = if burst % 2 == 0 {
                    self.boundary_row() - 1
                } else {
                    self.boundary_row()
                };
                (0, 0, row, v % HAMMER_BURST >= HAMMER_BURST - 2)
            }
            // rwmix: one open row, alternating write/read blocks.
            2 => (0, 1, 77 % rows, (v / RWMIX_BLOCK) % 2 == 0),
            // spread: seeded random over everything.
            _ => (
                self.rng.below(ranks),
                self.rng.below(banks),
                self.rng.below(rows),
                self.rng.chance(0.4),
            ),
        };
        let addr = self.map.encode(&Decoded {
            rank: rank as usize,
            bank: bank as usize,
            row,
            col: v % cols,
        });
        MemRef { gap_insts: 0, addr, is_write, dependent: false }
    }
}

impl RequestSource for FuzzSource {
    fn fill(&mut self, out: &mut Vec<MemRef>) -> usize {
        for _ in 0..SOURCE_BATCH {
            let r = self.gen_ref();
            out.push(r);
        }
        SOURCE_BATCH
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let map = AddrMap::ddr3_2gb(1);
        let mut a = FuzzSource::new(map, "s1");
        let mut b = FuzzSource::new(map, "s1");
        let mut c = FuzzSource::new(map, "s2");
        let (mut ra, mut rb, mut rc) = (Vec::new(), Vec::new(), Vec::new());
        for _ in 0..20 {
            a.fill(&mut ra);
            b.fill(&mut rb);
            c.fill(&mut rc);
        }
        assert_eq!(ra, rb);
        assert_ne!(ra, rc, "seeds must differentiate the spread phase");
    }

    #[test]
    fn phases_target_their_coordinates() {
        let map = AddrMap::ddr3_2gb(1);
        let mut s = FuzzSource::new(map, "ph");
        let mut refs = Vec::new();
        while refs.len() < 4 * PHASE_LEN as usize {
            s.fill(&mut refs);
        }
        let d: Vec<_> = refs.iter().map(|r| map.decode(r.addr)).collect();
        let pl = PHASE_LEN as usize;
        // storm: all banks touched, every visit to a bank a fresh row.
        let storm = &d[..pl];
        assert_eq!(storm.iter().map(|x| x.bank)
                        .collect::<std::collections::BTreeSet<_>>().len(), 8);
        for w in storm.windows(9) {
            assert_ne!(w[0].row, w[8].row, "storm must conflict per visit");
            assert_eq!(w[0].bank, w[8].bank);
        }
        // hammer: exactly the two boundary rows, one bank.
        let hammer = &d[pl..2 * pl];
        let boundary = 1u64 << 14;
        for x in hammer {
            assert_eq!(x.bank, 0);
            assert!(x.row == boundary || x.row == boundary - 1, "{}", x.row);
        }
        assert!(hammer.iter().any(|x| x.row == boundary));
        assert!(hammer.iter().any(|x| x.row == boundary - 1));
        // rwmix: single (bank, row), both reads and writes in blocks.
        let rw = &d[2 * pl..3 * pl];
        assert!(rw.iter().all(|x| x.bank == 1 && x.row == 77));
        let writes = refs[2 * pl..3 * pl].iter()
            .filter(|r| r.is_write).count();
        assert_eq!(writes, pl / 2);
        // spread: everything in range (decode asserts in debug), and all
        // refs dense (no instruction gaps).
        assert!(refs.iter().all(|r| r.gap_insts == 0 && !r.dependent));
    }

    #[test]
    fn targets_physical_rows_through_a_remap() {
        use crate::mem::address::RegionRemap;
        let base = AddrMap::ddr3_2gb(1);
        let remap = RegionRemap::new(base.row_bits, &[3, 1, 0, 2]);
        let map = base.with_remap(remap);
        let mut s = FuzzSource::new(map, "rm");
        let mut refs = Vec::new();
        while refs.len() < 2 * PHASE_LEN as usize {
            s.fill(&mut refs);
        }
        // The hammer phase must land on the *physical* boundary rows even
        // though the address space is permuted.
        let boundary = 1u64 << 14;
        let hammer = &refs[PHASE_LEN as usize..2 * PHASE_LEN as usize];
        for r in hammer {
            let d = map.decode(r.addr);
            assert!(d.row == boundary || d.row == boundary - 1, "{}", d.row);
        }
    }
}
