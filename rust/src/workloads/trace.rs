//! Trace capture and replay: recorded request streams as first-class
//! request sources.
//!
//! Two on-disk formats:
//!
//! * **ALDT binary (v1)** — the native format: a small header (geometry
//!   anchor, per-stream workload name / seed label / footprint) followed
//!   by delta-encoded records, streamed through hand-rolled varint codecs
//!   with bounded memory in both directions. A trailing sentinel carries
//!   the record count, so truncated files fail loudly at open time.
//! * **DRAMSim3 text** — `0x<ADDR> READ|WRITE <cycle>` lines (the
//!   interop format DRAMSim3's trace CPU consumes). Lossy: the cycle
//!   column carries the cumulative instruction position (so gaps round
//!   trip exactly) but the `dependent` flag is dropped and only one
//!   stream fits per file.
//!
//! Capture is a [`Recorder`] wrapper around any [`RequestSource`]; the
//! `mem::System::record_to` hook installs one per core, so *any* run —
//! synthetic, mix, even a replay — can be recorded. Replaying a recorded
//! file through [`open_sources`] reproduces the recorded run's
//! `SystemStats` bit-identically (asserted in
//! `tests/integration_trace.rs` and the Python mirror).
//!
//! ## ALDT v1 byte layout
//!
//! ```text
//! magic   b"ALDT"
//! u8      version (= 1)
//! u32 LE  row_bytes          (address-map row size of the recorded run)
//! u8      n_streams          (1 ..= 48)
//! per stream:
//!   u8 len + bytes           workload name (UTF-8)
//!   u8 len + bytes           seed label (UTF-8)
//!   u64 LE                   footprint in bytes
//! records (any order, tagged):
//!   u8      tag: bits 0-5 stream index, bit 6 is_write, bit 7 dependent
//!   varint  gap_insts
//!   varint  zigzag(addr - prev_addr[stream])   (prev starts at 0)
//! footer:
//!   u8 0xFF + u64 LE total record count
//! ```

use std::cell::RefCell;
use std::collections::VecDeque;
use std::fs::File;
use std::io::{self, BufRead, BufReader, BufWriter, Lines, Read, Write};
use std::path::Path;
use std::rc::Rc;

use anyhow::Context;

use super::{MemRef, NamedSource, RequestSource, SOURCE_BATCH};

pub const MAGIC: [u8; 4] = *b"ALDT";
pub const VERSION: u8 = 1;
/// Stream indices live in the tag's low 6 bits, but the end sentinel
/// (0xFF) must stay unambiguous, so the index stops short of 63.
pub const MAX_STREAMS: usize = 48;
const END_TAG: u8 = 0xFF;

/// Identity of one recorded stream (one simulated core's source).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StreamMeta {
    pub name: String,
    pub seed: String,
    pub footprint: u64,
}

/// Header + validation summary of a trace file.
#[derive(Debug, Clone)]
pub struct TraceInfo {
    pub version: u8,
    /// Address-map row size of the recorded run (0 for text imports).
    pub row_bytes: u32,
    /// True for the ALDT binary format, false for a DRAMSim3 text import
    /// (`row_bytes` cannot distinguish them: a converted text trace is a
    /// binary file that legitimately stores 0).
    pub binary: bool,
    pub streams: Vec<StreamMeta>,
    pub total_refs: u64,
    pub per_stream_refs: Vec<u64>,
}

// ---------------------------------------------------------------------
// varint / zigzag codecs
// ---------------------------------------------------------------------

fn write_varint<W: Write>(w: &mut W, mut v: u64) -> io::Result<()> {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            return w.write_all(&[b]);
        }
        w.write_all(&[b | 0x80])?;
    }
}

fn read_u8<R: Read>(r: &mut R) -> io::Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

fn corrupt(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

fn read_varint<R: Read>(r: &mut R) -> io::Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let b = read_u8(r)?;
        // The 10th byte may only carry u64 bit 63: anything else (payload
        // bits that would be shifted out, or a continuation bit) is a
        // corrupt encoding and must fail loudly, not silently truncate.
        if shift == 63 && (b & !0x01) != 0 {
            return Err(corrupt("varint overflows u64"));
        }
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
        if shift >= 64 {
            return Err(corrupt("varint overflows u64"));
        }
    }
}

fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

fn unzigzag(z: u64) -> i64 {
    ((z >> 1) as i64) ^ -((z & 1) as i64)
}

// ---------------------------------------------------------------------
// Writer + capture
// ---------------------------------------------------------------------

/// Streaming ALDT writer: header up front, one delta-encoded record per
/// `push`, sentinel + count on `finish`. Memory use is O(streams).
pub struct TraceWriter<W: Write> {
    w: W,
    prev_addr: Vec<u64>,
    count: u64,
    finished: bool,
}

/// The concrete writer the recording paths share.
pub type FileTraceWriter = TraceWriter<BufWriter<File>>;

/// A writer shared by the per-core [`Recorder`] wrappers of one run.
pub type SharedTraceWriter = Rc<RefCell<FileTraceWriter>>;

impl<W: Write> TraceWriter<W> {
    /// Write the header for `streams` onto `w`.
    pub fn new(mut w: W, row_bytes: u32, streams: &[StreamMeta])
               -> anyhow::Result<Self> {
        anyhow::ensure!(!streams.is_empty(), "a trace needs >= 1 stream");
        anyhow::ensure!(streams.len() <= MAX_STREAMS,
                        "trace format carries at most {MAX_STREAMS} streams, \
                         got {}", streams.len());
        w.write_all(&MAGIC)?;
        w.write_all(&[VERSION])?;
        w.write_all(&row_bytes.to_le_bytes())?;
        w.write_all(&[streams.len() as u8])?;
        for m in streams {
            for s in [&m.name, &m.seed] {
                let b = s.as_bytes();
                anyhow::ensure!(b.len() <= 255,
                                "stream label longer than 255 bytes");
                w.write_all(&[b.len() as u8])?;
                w.write_all(b)?;
            }
            w.write_all(&m.footprint.to_le_bytes())?;
        }
        Ok(TraceWriter {
            w,
            prev_addr: vec![0; streams.len()],
            count: 0,
            finished: false,
        })
    }

    /// Append one reference of stream `stream`.
    pub fn push(&mut self, stream: usize, r: MemRef) -> io::Result<()> {
        assert!(!self.finished, "push after finish");
        assert!(stream < self.prev_addr.len(), "stream {stream} out of range");
        let mut tag = stream as u8;
        if r.is_write {
            tag |= 0x40;
        }
        if r.dependent {
            tag |= 0x80;
        }
        self.w.write_all(&[tag])?;
        write_varint(&mut self.w, r.gap_insts as u64)?;
        // Wrapping, mirroring the reader's wrapping_add: addresses that
        // straddle 2^63 (possible in imported traces) stay round-trippable
        // and never overflow in debug builds.
        let delta =
            (r.addr as i64).wrapping_sub(self.prev_addr[stream] as i64);
        write_varint(&mut self.w, zigzag(delta))?;
        self.prev_addr[stream] = r.addr;
        self.count += 1;
        Ok(())
    }

    /// Write the end sentinel + record count and flush. Idempotent.
    pub fn finish(&mut self) -> io::Result<()> {
        if self.finished {
            return Ok(());
        }
        self.w.write_all(&[END_TAG])?;
        self.w.write_all(&self.count.to_le_bytes())?;
        self.w.flush()?;
        self.finished = true;
        Ok(())
    }

    pub fn count(&self) -> u64 {
        self.count
    }
}

/// Create a shared file-backed writer (what `System::record_to` uses).
pub fn create_shared(path: &Path, row_bytes: u32, streams: &[StreamMeta])
                     -> anyhow::Result<SharedTraceWriter> {
    let f = File::create(path)
        .with_context(|| format!("creating trace file {}", path.display()))?;
    let w = TraceWriter::new(BufWriter::new(f), row_bytes, streams)?;
    Ok(Rc::new(RefCell::new(w)))
}

/// Finish a shared writer (sentinel + flush). Call after the recorded run.
pub fn finish_shared(w: &SharedTraceWriter) -> anyhow::Result<()> {
    w.borrow_mut().finish().context("finishing trace file")
}

/// Capture wrapper: tees every reference the wrapped source emits into
/// the shared writer, preserving the stream untouched.
pub struct Recorder {
    inner: Box<dyn RequestSource>,
    stream: usize,
    writer: SharedTraceWriter,
}

impl Recorder {
    pub fn new(inner: Box<dyn RequestSource>, stream: usize,
               writer: SharedTraceWriter) -> Self {
        Recorder { inner, stream, writer }
    }
}

impl RequestSource for Recorder {
    fn fill(&mut self, out: &mut Vec<MemRef>) -> usize {
        let start = out.len();
        let n = self.inner.fill(out);
        let mut w = self.writer.borrow_mut();
        for r in &out[start..] {
            w.push(self.stream, *r).expect("trace capture write failed");
        }
        n
    }
}

// ---------------------------------------------------------------------
// Reader + replay
// ---------------------------------------------------------------------

enum Record {
    Ref { stream: usize, gap: u64, delta: i64, is_write: bool,
          dependent: bool },
    End { count: u64 },
}

fn read_label<R: Read>(r: &mut R, i: usize, what: &str)
                       -> anyhow::Result<String> {
    let len = read_u8(r)
        .with_context(|| format!("stream {i} {what} truncated"))?;
    let mut b = vec![0u8; len as usize];
    r.read_exact(&mut b)
        .with_context(|| format!("stream {i} {what} truncated"))?;
    String::from_utf8(b)
        .with_context(|| format!("stream {i} {what} is not UTF-8"))
}

fn read_record<R: Read>(r: &mut R, n_streams: usize) -> io::Result<Record> {
    let tag = read_u8(r)?;
    if tag == END_TAG {
        let mut c = [0u8; 8];
        r.read_exact(&mut c)?;
        return Ok(Record::End { count: u64::from_le_bytes(c) });
    }
    let stream = (tag & 0x3f) as usize;
    if stream >= n_streams {
        return Err(corrupt("record stream index out of range"));
    }
    let gap = read_varint(r)?;
    let delta = unzigzag(read_varint(r)?);
    Ok(Record::Ref {
        stream,
        gap,
        delta,
        is_write: tag & 0x40 != 0,
        dependent: tag & 0x80 != 0,
    })
}

fn read_header<R: Read>(r: &mut R) -> anyhow::Result<(u8, u32, Vec<StreamMeta>)> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic).context("trace header truncated")?;
    anyhow::ensure!(magic == MAGIC,
                    "not an ALDT trace (magic {magic:02x?})");
    let version = read_u8(r).context("trace header truncated")?;
    anyhow::ensure!(version == VERSION,
                    "unsupported trace version {version} (this build reads \
                     v{VERSION})");
    let mut rb = [0u8; 4];
    r.read_exact(&mut rb).context("trace header truncated")?;
    let row_bytes = u32::from_le_bytes(rb);
    let n = read_u8(r).context("trace header truncated")? as usize;
    anyhow::ensure!((1..=MAX_STREAMS).contains(&n),
                    "stream count {n} out of range 1..={MAX_STREAMS}");
    let mut streams = Vec::with_capacity(n);
    for i in 0..n {
        let name = read_label(r, i, "name")?;
        let seed = read_label(r, i, "seed")?;
        let mut fp = [0u8; 8];
        r.read_exact(&mut fp)
            .with_context(|| format!("stream {i} footprint truncated"))?;
        streams.push(StreamMeta {
            name,
            seed,
            footprint: u64::from_le_bytes(fp),
        });
    }
    Ok((version, row_bytes, streams))
}

/// Parse + fully validate a trace file: header well-formed, every record
/// decodable, the footer present and its count matching. O(file) time,
/// O(streams) memory. This runs before any replay, so a truncated or
/// corrupt file fails loudly at open time — never mid-simulation.
pub fn info(path: &Path) -> anyhow::Result<TraceInfo> {
    let f = File::open(path)
        .with_context(|| format!("opening trace {}", path.display()))?;
    let mut r = BufReader::new(f);
    let (version, row_bytes, streams) = read_header(&mut r)?;
    let n = streams.len();
    let mut per = vec![0u64; n];
    let mut total = 0u64;
    loop {
        let rec = read_record(&mut r, n).map_err(|e| {
            anyhow::anyhow!("trace body truncated or corrupt after \
                             {total} records: {e}")
        })?;
        match rec {
            Record::End { count } => {
                anyhow::ensure!(count == total,
                                "trace footer says {count} records but \
                                 {total} were read");
                break;
            }
            Record::Ref { stream, gap, .. } => {
                anyhow::ensure!(gap <= u32::MAX as u64,
                                "record {total}: gap {gap} overflows u32");
                per[stream] += 1;
                total += 1;
            }
        }
    }
    let mut one = [0u8; 1];
    anyhow::ensure!(r.read(&mut one)? == 0,
                    "trailing bytes after the trace footer");
    Ok(TraceInfo { version, row_bytes, binary: true, streams,
                   total_refs: total, per_stream_refs: per })
}

/// Shared demultiplexer: records are read from the file in recorded
/// order and parked per stream until that stream's source pulls them.
/// Queues stay small in practice because replay consumes in roughly the
/// recorded order.
struct Demux {
    r: BufReader<File>,
    n: usize,
    pending: Vec<VecDeque<MemRef>>,
    prev_addr: Vec<u64>,
    done: bool,
}

impl Demux {
    /// Advance by one record; false once the end sentinel is reached.
    fn pump(&mut self) -> bool {
        if self.done {
            return false;
        }
        // The open-time validation pass proved the body decodable; an
        // error here means the file changed underneath us.
        let rec = read_record(&mut self.r, self.n)
            .expect("trace read failed after validation");
        match rec {
            Record::End { .. } => {
                self.done = true;
                false
            }
            Record::Ref { stream, gap, delta, is_write, dependent } => {
                let addr =
                    (self.prev_addr[stream] as i64).wrapping_add(delta) as u64;
                self.prev_addr[stream] = addr;
                self.pending[stream].push_back(MemRef {
                    gap_insts: gap as u32,
                    addr,
                    is_write,
                    dependent,
                });
                true
            }
        }
    }
}

/// One recorded stream as a request source (replay side).
pub struct TraceStream {
    idx: usize,
    demux: Rc<RefCell<Demux>>,
}

impl RequestSource for TraceStream {
    fn fill(&mut self, out: &mut Vec<MemRef>) -> usize {
        let mut d = self.demux.borrow_mut();
        let mut n = 0;
        while n < SOURCE_BATCH {
            if let Some(r) = d.pending[self.idx].pop_front() {
                out.push(r);
                n += 1;
                continue;
            }
            if !d.pump() {
                break;
            }
        }
        n
    }
}

/// Open an ALDT trace for replay: validates the whole file, then hands
/// back one streaming [`NamedSource`] per recorded stream.
pub fn open_sources(path: &Path)
                    -> anyhow::Result<(TraceInfo, Vec<NamedSource>)> {
    let inf = info(path)?;
    let f = File::open(path)?;
    let mut r = BufReader::new(f);
    read_header(&mut r)?; // reposition past the header
    let n = inf.streams.len();
    let demux = Rc::new(RefCell::new(Demux {
        r,
        n,
        pending: vec![VecDeque::new(); n],
        prev_addr: vec![0; n],
        done: false,
    }));
    let sources = inf
        .streams
        .iter()
        .enumerate()
        .map(|(i, m)| NamedSource {
            name: m.name.clone(),
            seed: m.seed.clone(),
            footprint: m.footprint,
            source: Box::new(TraceStream { idx: i, demux: Rc::clone(&demux) }),
        })
        .collect();
    Ok((inf, sources))
}

/// Open either format: ALDT binary (sniffed by magic) or DRAMSim3 text.
/// The returned `TraceInfo::binary` records which format was detected.
pub fn open_any(path: &Path)
                -> anyhow::Result<(TraceInfo, Vec<NamedSource>)> {
    let is_binary = {
        let mut f = File::open(path)
            .with_context(|| format!("opening trace {}", path.display()))?;
        let mut magic = [0u8; 4];
        // read_exact, not read: a short read must not misclassify a valid
        // ALDT file. A file shorter than the magic cannot be ALDT.
        match f.read_exact(&mut magic) {
            Ok(()) => magic == MAGIC,
            Err(_) => false,
        }
    };
    if is_binary {
        return open_sources(path);
    }
    let (count, src) = open_text(path)?;
    let meta = StreamMeta {
        name: src.name.clone(),
        seed: src.seed.clone(),
        footprint: src.footprint,
    };
    Ok((
        TraceInfo {
            version: VERSION,
            row_bytes: 0,
            binary: false,
            streams: vec![meta],
            total_refs: count,
            per_stream_refs: vec![count],
        },
        vec![src],
    ))
}

// ---------------------------------------------------------------------
// DRAMSim3 text interop
// ---------------------------------------------------------------------

/// Streaming `0x<ADDR> READ|WRITE <cycle>` emitter; the cycle column is
/// the cumulative instruction position (sum of gaps), so a round trip
/// reconstructs every gap exactly.
pub struct TextWriter<W: Write> {
    w: W,
    cycle: u64,
    count: u64,
}

impl<W: Write> TextWriter<W> {
    pub fn new(w: W) -> Self {
        TextWriter { w, cycle: 0, count: 0 }
    }

    pub fn push(&mut self, r: MemRef) -> io::Result<()> {
        self.cycle += r.gap_insts as u64;
        writeln!(self.w, "0x{:X} {} {}", r.addr,
                 if r.is_write { "WRITE" } else { "READ" }, self.cycle)?;
        self.count += 1;
        Ok(())
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn flush(&mut self) -> io::Result<()> {
        self.w.flush()
    }
}

/// One-shot [`TextWriter`] convenience: emit `refs` and return the line
/// count.
pub fn write_text<W: Write>(w: &mut W, refs: impl IntoIterator<Item = MemRef>)
                            -> io::Result<u64> {
    let mut tw = TextWriter::new(w);
    for r in refs {
        tw.push(r)?;
    }
    Ok(tw.count())
}

fn parse_text_line(line: &str, lineno: usize, prev_cycle: u64)
                   -> anyhow::Result<(MemRef, u64)> {
    let mut it = line.split_whitespace();
    let err = |what: &str| {
        anyhow::anyhow!("text trace line {lineno}: {what}: `{line}`")
    };
    let addr_s = it.next().ok_or_else(|| err("missing address"))?;
    let op = it.next().ok_or_else(|| err("missing READ/WRITE"))?;
    let cyc_s = it.next().ok_or_else(|| err("missing cycle"))?;
    anyhow::ensure!(it.next().is_none(), err("trailing fields"));
    let hex = addr_s
        .strip_prefix("0x")
        .or_else(|| addr_s.strip_prefix("0X"))
        .ok_or_else(|| err("address must be 0x-prefixed hex"))?;
    let addr = u64::from_str_radix(hex, 16)
        .map_err(|_| err("bad hex address"))?;
    let is_write = match op {
        "READ" => false,
        "WRITE" => true,
        _ => return Err(err("op must be READ or WRITE")),
    };
    let cycle: u64 = cyc_s.parse().map_err(|_| err("bad cycle"))?;
    anyhow::ensure!(cycle >= prev_cycle,
                    err("cycle column must be non-decreasing"));
    let gap = cycle - prev_cycle;
    anyhow::ensure!(gap <= u32::MAX as u64, err("gap overflows u32"));
    Ok((
        MemRef { gap_insts: gap as u32, addr, is_write, dependent: false },
        cycle,
    ))
}

/// Streaming text-trace source (single stream — the format carries no
/// stream tag).
pub struct TextSource {
    lines: Lines<BufReader<File>>,
    prev_cycle: u64,
    lineno: usize,
}

impl RequestSource for TextSource {
    fn fill(&mut self, out: &mut Vec<MemRef>) -> usize {
        let mut n = 0;
        while n < SOURCE_BATCH {
            match self.lines.next() {
                None => break,
                Some(line) => {
                    let line = line.expect("text trace read failed");
                    self.lineno += 1;
                    if line.trim().is_empty() {
                        continue;
                    }
                    let (r, c) =
                        parse_text_line(&line, self.lineno, self.prev_cycle)
                            .expect("text trace corrupt after validation");
                    self.prev_cycle = c;
                    out.push(r);
                    n += 1;
                }
            }
        }
        n
    }
}

/// Open a DRAMSim3 text trace: full validation pass first (bad lines
/// fail loudly here), then a streaming source named after the file.
pub fn open_text(path: &Path) -> anyhow::Result<(u64, NamedSource)> {
    let f = File::open(path)
        .with_context(|| format!("opening text trace {}", path.display()))?;
    let mut prev = 0u64;
    let mut count = 0u64;
    for (i, line) in BufReader::new(f).lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let (_, c) = parse_text_line(&line, i + 1, prev)?;
        prev = c;
        count += 1;
    }
    anyhow::ensure!(count > 0, "text trace {} has no records",
                    path.display());
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "text-trace".to_string());
    let src = TextSource {
        lines: BufReader::new(File::open(path)?).lines(),
        prev_cycle: 0,
        lineno: 0,
    };
    Ok((
        count,
        NamedSource {
            name,
            seed: "text".to_string(),
            footprint: 0,
            source: Box::new(src),
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn refs() -> Vec<MemRef> {
        vec![
            MemRef { gap_insts: 5, addr: 0x1000, is_write: false,
                     dependent: false },
            MemRef { gap_insts: 0, addr: 0x2A40, is_write: true,
                     dependent: false },
            MemRef { gap_insts: 17, addr: 0x1040, is_write: false,
                     dependent: true },
        ]
    }

    #[test]
    fn varint_zigzag_roundtrip() {
        for v in [0u64, 1, 127, 128, 300, 1 << 20, u64::MAX] {
            let mut b = Vec::new();
            write_varint(&mut b, v).unwrap();
            assert_eq!(read_varint(&mut Cursor::new(&b)).unwrap(), v);
        }
        for d in [0i64, 1, -1, 63, -64, 1 << 40, -(1 << 40), i64::MAX,
                  i64::MIN] {
            assert_eq!(unzigzag(zigzag(d)), d);
        }
        // Small deltas stay small on disk: |d| < 64 is one byte.
        let mut b = Vec::new();
        write_varint(&mut b, zigzag(-63)).unwrap();
        assert_eq!(b.len(), 1);
        // Non-canonical 10-byte encodings whose final byte would shift
        // payload bits past bit 63 are corrupt, not silently truncated.
        let mut bad = vec![0xFFu8; 9];
        bad.push(0x03);
        assert!(read_varint(&mut Cursor::new(&bad)).is_err());
        let mut cont = vec![0xFFu8; 9];
        cont.push(0x81);
        assert!(read_varint(&mut Cursor::new(&cont)).is_err());
    }

    #[test]
    fn extreme_addresses_roundtrip() {
        // Addresses straddling 2^63 (legal in imported traces): the
        // wrapping delta encode/decode pair must reproduce them exactly.
        let metas = [StreamMeta { name: "x".into(), seed: "s".into(),
                                  footprint: 0 }];
        let addrs = [0u64, u64::MAX & !63, 0x40, 1 << 63, 0];
        let mut buf = Vec::new();
        let mut w = TraceWriter::new(&mut buf, 0, &metas).unwrap();
        for &a in &addrs {
            w.push(0, MemRef { gap_insts: 1, addr: a, is_write: false,
                               dependent: false }).unwrap();
        }
        w.finish().unwrap();
        drop(w);
        let mut c = Cursor::new(&buf);
        read_header(&mut c).unwrap();
        let mut prev = 0u64;
        let mut got = Vec::new();
        while let Record::Ref { delta, .. } = read_record(&mut c, 1).unwrap()
        {
            prev = (prev as i64).wrapping_add(delta) as u64;
            got.push(prev);
        }
        assert_eq!(got, addrs);
    }

    #[test]
    fn binary_codec_roundtrip_in_memory() {
        let metas = [
            StreamMeta { name: "mcf".into(), seed: "s/0".into(),
                         footprint: 1 << 20 },
            StreamMeta { name: "gups".into(), seed: "s/1".into(),
                         footprint: 1 << 22 },
        ];
        let mut buf = Vec::new();
        let mut w = TraceWriter::new(&mut buf, 8192, &metas).unwrap();
        for (i, r) in refs().iter().enumerate() {
            w.push(i % 2, *r).unwrap();
        }
        assert_eq!(w.count(), 3);
        w.finish().unwrap();
        w.finish().unwrap(); // idempotent
        drop(w);

        let mut c = Cursor::new(&buf);
        let (version, row_bytes, streams) = read_header(&mut c).unwrap();
        assert_eq!(version, VERSION);
        assert_eq!(row_bytes, 8192);
        assert_eq!(streams, metas);
        // Decode the three records back, tracking per-stream deltas.
        let mut prev = [0u64; 2];
        let mut got = Vec::new();
        loop {
            match read_record(&mut c, 2).unwrap() {
                Record::End { count } => {
                    assert_eq!(count, 3);
                    break;
                }
                Record::Ref { stream, gap, delta, is_write, dependent } => {
                    let addr =
                        (prev[stream] as i64).wrapping_add(delta) as u64;
                    prev[stream] = addr;
                    got.push((stream, MemRef { gap_insts: gap as u32, addr,
                                               is_write, dependent }));
                }
            }
        }
        let want = refs();
        assert_eq!(got, vec![(0, want[0]), (1, want[1]), (0, want[2])]);
    }

    #[test]
    fn binary_format_golden_bytes() {
        // Byte-for-byte pin of the v1 format. The Python mirror
        // (mirror/source_checks.py) pins the *same* hex string, so the
        // two codecs are provably bit-compatible.
        let metas = [
            StreamMeta { name: "mcf".into(), seed: "s/0".into(),
                         footprint: 1 << 20 },
            StreamMeta { name: "gups".into(), seed: "s/1".into(),
                         footprint: 1 << 22 },
        ];
        let mut buf = Vec::new();
        let mut w = TraceWriter::new(&mut buf, 8192, &metas).unwrap();
        for (i, r) in refs().iter().enumerate() {
            w.push(i % 2, *r).unwrap();
        }
        w.finish().unwrap();
        drop(w);
        let hex: String = buf.iter().map(|b| format!("{b:02x}")).collect();
        assert_eq!(
            hex,
            "414c4454010020000002036d636603732f30000010000000000004677570\
             7303732f31000040000000000000058040410080a90180118001ff030000\
             0000000000"
        );
    }

    #[test]
    fn writer_rejects_bad_stream_sets() {
        assert!(TraceWriter::new(Vec::new(), 0, &[]).is_err());
        let many: Vec<StreamMeta> = (0..MAX_STREAMS + 1)
            .map(|i| StreamMeta { name: format!("w{i}"), seed: "s".into(),
                                  footprint: 0 })
            .collect();
        assert!(TraceWriter::new(Vec::new(), 0, &many).is_err());
    }

    #[test]
    fn dramsim3_text_golden() {
        // The exact interop byte stream: cumulative instruction position
        // in the cycle column, upper-case hex, upper-case op.
        let mut out = Vec::new();
        let n = write_text(&mut out, refs()).unwrap();
        assert_eq!(n, 3);
        assert_eq!(
            String::from_utf8(out).unwrap(),
            "0x1000 READ 5\n0x2A40 WRITE 5\n0x1040 READ 22\n"
        );
    }

    #[test]
    fn text_lines_roundtrip_gaps() {
        let mut out = Vec::new();
        write_text(&mut out, refs()).unwrap();
        let text = String::from_utf8(out).unwrap();
        let mut prev = 0u64;
        let mut got = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let (r, c) = parse_text_line(line, i + 1, prev).unwrap();
            prev = c;
            got.push(r);
        }
        // dependent is not representable in the text format; everything
        // else survives.
        let want: Vec<MemRef> = refs()
            .into_iter()
            .map(|r| MemRef { dependent: false, ..r })
            .collect();
        assert_eq!(got, want);
    }

    #[test]
    fn text_parser_rejects_garbage() {
        assert!(parse_text_line("0x10 READ", 1, 0).is_err());
        assert!(parse_text_line("10 READ 5", 1, 0).is_err());
        assert!(parse_text_line("0xZZ READ 5", 1, 0).is_err());
        assert!(parse_text_line("0x10 FETCH 5", 1, 0).is_err());
        assert!(parse_text_line("0x10 READ x", 1, 0).is_err());
        assert!(parse_text_line("0x10 READ 5 extra", 1, 0).is_err());
        // Non-monotone cycle: the previous line ended at 10.
        assert!(parse_text_line("0x10 READ 5", 2, 10).is_err());
    }
}
